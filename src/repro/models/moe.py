"""Mixture-of-Experts FFN: capacity-based top-k routing with shared experts.

Two implementations with identical math:

* ``moe_ffn_dense``  -- reference path (single device / smoke tests): top-C
  token selection per expert, gather -> expert FFN -> weighted scatter-add.
* ``moe_ffn_sharded`` -- production path: an explicit ``shard_map`` over the
  mesh. Tokens stay sharded over the data axes and *replicated* over
  ``model``; experts shard over ``model`` (EP); FSDP-sharded expert weights
  are all-gathered per layer inside the region; outputs ``psum`` over
  ``model``. No all-to-all is needed because every model-rank sees its data
  group's tokens -- the EP collective cost is one activation psum, which the
  roofline analysis attributes explicitly.

Experts are padded to a multiple of EP_PAD (=16, the production model-axis
size) at init; the router masks padding experts to -inf.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..sharding.compat import shard_map

from ..configs.base import ArchConfig
from ..sharding.rules import constrain, dp_axes
from .layers import Param, make, _dtype

EP_PAD = 16


def n_experts_padded(cfg: ArchConfig) -> int:
    return -(-cfg.n_experts // EP_PAD) * EP_PAD


def init_moe(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, cfg.d_expert or cfg.d_ff
    E = n_experts_padded(cfg)
    dt = _dtype(cfg)
    p = dict(
        w_router=make(ks[0], (d, E), ("wembed", None), 1.0, jnp.float32),
        w_gate=make(ks[1], (E, d, f), ("experts", "wembed", "expert_mlp"), 1.0, dt),
        w_up=make(ks[2], (E, d, f), ("experts", "wembed", "expert_mlp"), 1.0, dt),
        w_down=make(ks[3], (E, f, d), ("experts", "expert_mlp", "wembed"), 1.0, dt),
    )
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        kss = jax.random.split(ks[4], 3)
        p["shared"] = dict(
            w_gate=make(kss[0], (d, fs), ("wembed", "mlp"), 1.0, dt),
            w_up=make(kss[1], (d, fs), ("wembed", "mlp"), 1.0, dt),
            w_down=make(kss[2], (fs, d), ("mlp", "wembed"), 1.0, dt),
        )
    return p


def _shared_ffn(p: Dict, x: jax.Array, rules) -> jax.Array:
    g = constrain(x @ p["w_gate"], ("batch", "seq", "act_mlp"), rules)
    u = constrain(x @ p["w_up"], ("batch", "seq", "act_mlp"), rules)
    return constrain((jax.nn.silu(g) * u) @ p["w_down"], ("batch", "seq", "embed"), rules)


def _route(x2d: jax.Array, w_router: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """(T, d) -> (probs (T, E) f32 with padding masked, topk idx (T, K))."""
    E = w_router.shape[1]
    logits = (x2d.astype(jnp.float32) @ w_router).astype(jnp.float32)
    if E > cfg.n_experts:
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_idx = jax.lax.top_k(logits, cfg.moe_topk)
    return probs, top_idx


def _expert_compute(xg: jax.Array, wg, wu, wd) -> jax.Array:
    """xg: (E, C, d); weights (E, d, f)/(E, f, d) -> (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", xg, wg)
    u = jnp.einsum("ecd,edf->ecf", xg, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)


def _capacity(n_tokens: int, cfg: ArchConfig, n_experts: int) -> int:
    c = int(n_tokens * cfg.moe_topk * cfg.capacity_factor / max(n_experts, 1))
    return max(8, -(-c // 8) * 8)


def _select_and_apply(
    x2d: jax.Array, probs: jax.Array, top_idx: jax.Array, wg, wu, wd, cfg: ArchConfig,
    e_lo: int, e_n: int, cap: int,
) -> jax.Array:
    """Top-C selection per expert in [e_lo, e_lo+e_n), FFN, weighted combine.

    Returns (T, d) partial output covering only these experts.
    """
    T, d = x2d.shape
    K = top_idx.shape[1]
    # score[e_local, t] = prob if expert in token's top-k else -1
    eids = e_lo + jnp.arange(e_n)  # (e_n,)
    chosen = (top_idx[None, :, :] == eids[:, None, None]).any(-1)  # (e_n, T)
    gate = jax.lax.dynamic_slice_in_dim(probs, e_lo, e_n, axis=1).T  # (e_n, T)
    score = jnp.where(chosen, gate, -1.0)
    top_val, tok_idx = jax.lax.top_k(score, min(cap, T))  # (e_n, C)
    valid = top_val > 0.0
    xg = x2d[tok_idx.reshape(-1)].reshape(e_n, -1, d)  # (e_n, C, d)
    yg = _expert_compute(xg, wg, wu, wd)
    w = jnp.where(valid, top_val, 0.0).astype(yg.dtype)[..., None]  # (e_n, C, 1)
    y = jnp.zeros((T, d), yg.dtype)
    y = y.at[tok_idx.reshape(-1)].add((yg * w).reshape(-1, d))
    return y


def moe_ffn_dense(params: Dict, x: jax.Array, cfg: ArchConfig, rules) -> jax.Array:
    """Reference MoE (no shard_map): full expert set on every device."""
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    probs, top_idx = _route(x2d, params["w_router"], cfg)
    E = params["w_gate"].shape[0]
    cap = _capacity(x2d.shape[0], cfg, cfg.n_experts)
    y = _select_and_apply(
        x2d, probs, top_idx, params["w_gate"], params["w_up"], params["w_down"], cfg, 0, E, cap
    )
    out = y.reshape(B, S, d).astype(x.dtype)
    if "shared" in params:
        out = out + _shared_ffn(params["shared"], x, rules)
    return constrain(out, ("batch", "seq", "embed"), rules)


def moe_ffn_sharded(params: Dict, x: jax.Array, cfg: ArchConfig, rules, mesh: Mesh) -> jax.Array:
    """Production MoE: shard_map EP over 'model', DP over data axes."""
    B, S, d = x.shape
    dp = dp_axes(mesh)
    E = params["w_gate"].shape[0]
    n_model = mesh.shape["model"]
    e_n = E // n_model
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    t_loc = max(1, (B * S) // n_dp)
    cap = _capacity(t_loc, cfg, cfg.n_experts)

    def local(xb, wr, wg, wu, wd):
        # xb: (B_loc, S, d) local tokens; weights: local experts, d FSDP-sharded
        wg = jax.lax.all_gather(wg, dp, axis=1, tiled=True) if dp else wg
        wu = jax.lax.all_gather(wu, dp, axis=1, tiled=True) if dp else wu
        wd = jax.lax.all_gather(wd, dp, axis=2, tiled=True) if dp else wd
        x2d = xb.reshape(-1, d)
        probs, top_idx = _route(x2d, wr, cfg)
        e_lo = jax.lax.axis_index("model") * e_n
        y = _select_and_apply(x2d, probs, top_idx, wg, wu, wd, cfg, e_lo, e_n, cap)
        y = jax.lax.psum(y, "model")
        return y.reshape(xb.shape)

    y = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(dp if dp else None, None, None),
            P(None, None),
            P("model", dp if dp else None, None),
            P("model", dp if dp else None, None),
            P("model", None, dp if dp else None),
        ),
        out_specs=P(dp if dp else None, None, None),
        check_vma=False,
    )(x, params["w_router"], params["w_gate"], params["w_up"], params["w_down"])
    out = y.astype(x.dtype)
    if "shared" in params:
        out = out + _shared_ffn(params["shared"], x, rules)
    return constrain(out, ("batch", "seq", "embed"), rules)


def moe_ffn(params: Dict, x: jax.Array, cfg: ArchConfig, rules, mesh: Optional[Mesh]) -> jax.Array:
    if mesh is not None and "model" in mesh.axis_names and mesh.shape["model"] > 1:
        E = params["w_gate"].shape[0]
        if E % mesh.shape["model"] == 0:
            return moe_ffn_sharded(params, x, cfg, rules, mesh)
    return moe_ffn_dense(params, x, cfg, rules)
