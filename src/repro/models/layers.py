"""Shared model layers: params-with-specs helpers, norms, RoPE, embeddings,
GQA attention (chunked flash-pattern train/prefill + sequence-sharded
decode), SwiGLU / GELU FFN.

Every ``init_*`` returns a pytree whose leaves are ``Param(value, spec)``;
``split_params`` separates values from logical-name specs (consumed by
``sharding/rules.py``). All matmul compute runs in the config dtype
(bf16 on TPU); softmax/norm accumulate in f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.rules import constrain


class Param(NamedTuple):
    value: Any
    spec: Tuple[Optional[str], ...]


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=is_param)
    return values, specs


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def make(key, shape, spec, scale: float = 1.0, dtype=jnp.bfloat16) -> Param:
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale / max(fan_in, 1) ** 0.5
    return Param(jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype) * std, spec)


def zeros(shape, spec, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), spec)


def ones(shape, spec, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), spec)


# ------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# -------------------------------------------------------------- embeddings
def init_embedding(key, cfg: ArchConfig) -> Dict:
    return dict(table=make(key, (cfg.vocab, cfg.d_model), ("vocab", "wembed"), 1.0, _dtype(cfg)))


def embed_lookup(params: Dict, ids: jax.Array, rules) -> jax.Array:
    """One-hot matmul lookup (partitions cleanly with vocab sharded)."""
    table = params["table"]
    oh = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
    oh = constrain(oh, ("batch", "seq", "act_vocab"), rules)
    out = oh @ table
    return constrain(out, ("batch", "seq", "embed"), rules)


def init_lm_head(key, cfg: ArchConfig) -> Dict:
    return dict(w=make(key, (cfg.d_model, cfg.vocab), ("wembed", "vocab"), 1.0, _dtype(cfg)))


def lm_logits(params: Dict, x: jax.Array, rules) -> jax.Array:
    out = x @ params["w"]
    return constrain(out, ("batch", "seq", "act_vocab"), rules)


def softmax_xent(logits: jax.Array, labels: jax.Array, rules) -> jax.Array:
    """Mean CE over all positions; vocab may be sharded (reductions psum)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    oh = jax.nn.one_hot(labels, lf.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(lf * oh, axis=-1)
    return jnp.mean(lse - gold)


# --------------------------------------------------------------- attention
def init_attention(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 4)
    d, KV, hd = cfg.d_model, cfg.n_kv_heads, cfg.head_dim
    H = cfg.pad_heads_to or cfg.n_heads
    dt = _dtype(cfg)
    p = dict(
        wq=make(ks[0], (d, H, hd), ("wembed", "heads", "head_dim"), 1.0, dt),
        wk=make(ks[1], (d, KV, hd), ("wembed", "kv_heads", "head_dim"), 1.0, dt),
        wv=make(ks[2], (d, KV, hd), ("wembed", "kv_heads", "head_dim"), 1.0, dt),
        wo=make(ks[3], (H, hd, d), ("heads", "head_dim", "wembed"), 1.0, dt),
    )
    if H > cfg.n_heads:
        # Zero the padded head slices *per KV group* (tail padding would
        # shift the GQA head->kv mapping). g real q-heads per kv head become
        # g_pad slots; the extra slots stay exactly 0 under gradient descent
        # (wq/wo zeros form a stationary subspace), so this is function-
        # preserving: a 36-head model remains a 36-head model.
        g = cfg.n_heads // KV
        g_pad = H // KV
        mask = (jnp.arange(H) % g_pad) < g  # valid q-head slots
        p["wq"] = Param(p["wq"].value * mask[None, :, None], p["wq"].spec)
        p["wo"] = Param(p["wo"].value * mask[:, None, None], p["wo"].spec)
    return p


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, H, D) by repeating each kv head H/KV times."""
    B, S, KV, D = k.shape
    if KV == n_heads:
        return k
    g = n_heads // KV
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, g, D)).reshape(B, S, n_heads, D)


def _chunked_causal_attn(
    q: jax.Array, k: jax.Array, v: jax.Array, chunk: int, causal: bool, impl: str
) -> jax.Array:
    """Flash-pattern attention. q,k,v: (B, S, H, D) (k/v already H-expanded).

    ``masked_scan``: scan over KV chunks with running (max, denom) -- O(S*C)
    memory, computes all S^2 scores (causal entries masked).
    ``unrolled_prefix``: python loop over Q chunks, each attending only to
    its causal KV prefix -- ~2x fewer FLOPs for causal, larger HLO.
    """
    B, S, H, D = q.shape
    scale = 1.0 / D**0.5
    qf = (q * scale).astype(q.dtype)
    Skv = k.shape[1]
    C = min(chunk, Skv)
    if Skv % C:
        pad = C - Skv % C
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = Skv
        Skv = Skv + pad
    else:
        kv_valid = Skv
    n_chunks = Skv // C

    if impl == "unrolled_prefix" and causal:
        CQ = min(chunk, S)
        assert S % CQ == 0
        outs = []
        for i in range(S // CQ):
            q_i = qf[:, i * CQ : (i + 1) * CQ]
            hi = min((i + 1) * CQ, Skv)
            k_i, v_i = k[:, :hi], v[:, :hi]
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_i).astype(jnp.float32)
            qpos = i * CQ + jnp.arange(CQ)
            kpos = jnp.arange(hi)
            mask = (qpos[:, None] >= kpos[None, :]) & (kpos[None, :] < kv_valid)
            s = jnp.where(mask[None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
            outs.append(jnp.einsum("bhqk,bkhd->bqhd", p, v_i))
        return jnp.concatenate(outs, axis=1)

    # masked scan with running softmax
    kc = k.reshape(B, n_chunks, C, H, D).swapaxes(0, 1)  # (n, B, C, H, D)
    vc = v.reshape(B, n_chunks, C, H, D).swapaxes(0, 1)
    qpos = jnp.arange(S)

    def body(carry, xs):
        acc, m, denom, ci = carry
        k_i, v_i = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_i).astype(jnp.float32)  # (B,H,S,C)
        kpos = ci * C + jnp.arange(C)
        mask = kpos[None, :] < kv_valid
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(v.dtype), v_i
        ).astype(jnp.float32)
        return (acc, m_new, denom, ci + 1), None

    acc0 = jnp.zeros((B, H, S, D), jnp.float32)
    m0 = jnp.full((B, H, S), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, H, S), jnp.float32)
    (acc, m, denom, _), _ = jax.lax.scan(body, (acc0, m0, d0, 0), (kc, vc))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q.dtype)  # (B, S, H, D)


def attention(
    params: Dict,
    x: jax.Array,  # (B, S, d)
    cfg: ArchConfig,
    rules,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    kv_x: Optional[jax.Array] = None,  # cross-attention source
) -> jax.Array:
    B, S, _ = x.shape
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    q = constrain(q, ("batch", "seq", "act_heads", "head_dim"), rules)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    if kv_x is None:
        k = apply_rope(k, positions, cfg.rope_theta)
    n_heads = params["wq"].shape[1]
    k = _expand_kv(k, n_heads)
    v = _expand_kv(v, n_heads)
    out = _chunked_causal_attn(q, k, v, cfg.attn_chunk, causal and kv_x is None, cfg.causal_impl)
    out = constrain(out, ("batch", "seq", "act_heads", "head_dim"), rules)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, ("batch", "seq", "embed"), rules)


def decode_attention(
    params: Dict,
    x: jax.Array,  # (B, 1, d)
    cache_k: jax.Array,  # (B, S, KV, hd) -- seq dim sharded (kv_seq)
    cache_v: jax.Array,
    pos: jax.Array,  # () current position
    cfg: ArchConfig,
    rules,
    update_cache: bool = True,
    rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode with flash-decoding-style sequence-sharded KV."""
    B, S, KV, hd = cache_k.shape
    H = params["wq"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])[:, 0]  # (B, H, hd)
    if rope:
        q = apply_rope(q[:, None], pos[None, None], cfg.rope_theta)[:, 0]
    if update_cache:
        k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])  # (B,1,KV,hd)
        v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if rope:
            k_new = apply_rope(k_new, pos[None, None], cfg.rope_theta)
        cache_k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))
    g = H // KV
    qg = q.reshape(B, KV, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k).astype(jnp.float32) / hd**0.5
    mask = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)  # reductions over sharded S -> psum
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cache_v.dtype), cache_v)
    out = out.reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, ("batch", None, "embed"), rules), cache_k, cache_v


# --------------------------------------------------------------------- FFN
def init_ffn(key, cfg: ArchConfig, d_ff: Optional[int] = None, gelu: bool = False) -> Dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    p = dict(
        w_up=make(ks[1], (d, f), ("wembed", "mlp"), 1.0, dt),
        w_out=make(ks[2], (f, d), ("mlp", "wembed"), 1.0, dt),
    )
    if not gelu:
        p["w_gate"] = make(ks[0], (d, f), ("wembed", "mlp"), 1.0, dt)
    return p


def ffn(params: Dict, x: jax.Array, rules) -> jax.Array:
    up = x @ params["w_up"]
    up = constrain(up, ("batch", "seq", "act_mlp"), rules)
    if "w_gate" in params:
        gate = constrain(x @ params["w_gate"], ("batch", "seq", "act_mlp"), rules)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    y = h @ params["w_out"]
    return constrain(y, ("batch", "seq", "embed"), rules)
