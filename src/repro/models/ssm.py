"""Mamba selective-SSM block (Jamba's sequence mixer).

TPU adaptation (see DESIGN.md): the CUDA selective-scan kernel becomes a
*chunked associative scan*: the sequence is processed in chunks of
``cfg.ssm_chunk``; within a chunk the linear recurrence
``h_t = dA_t * h_{t-1} + dB_t x_t`` runs as a log-depth
``jax.lax.associative_scan`` over ``(B, Lc, d_inner, d_state)`` VMEM-sized
blocks; chunks are stitched with an outer ``lax.scan`` carrying the state.
The depthwise causal conv is expressed as ``d_conv`` shifted elementwise
multiplies so channel sharding (d_inner over "model") partitions trivially.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.rules import constrain
from .layers import Param, _dtype, make, zeros


def init_mamba(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 7)
    d, di, ds, dc, dtr = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv, cfg.dt_rank
    dt = _dtype(cfg)
    return dict(
        in_proj=make(ks[0], (d, 2 * di), ("wembed", "inner"), 1.0, dt),
        conv_w=make(ks[1], (dc, di), ("conv", "inner"), 1.0, jnp.float32),
        conv_b=zeros((di,), ("inner",)),
        x_proj=make(ks[2], (di, dtr + 2 * ds), ("inner", None), 1.0, dt),
        dt_proj=make(ks[3], (dtr, di), (None, "inner"), 1.0, jnp.float32),
        dt_bias=zeros((di,), ("inner",)),
        A_log=Param(
            jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
            ("inner", "state"),
        ),
        D=Param(jnp.ones((di,), jnp.float32), ("inner",)),
        out_proj=make(ks[4], (di, d), ("inner", "wembed"), 1.0, dt),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, di); w: (dc, di) -> causal depthwise conv via shifts."""
    dc = w.shape[0]
    out = x * w[-1]
    for j in range(1, dc):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[dc - 1 - j]
    return out + b


def _ssm_scan(dA: jax.Array, dBx: jax.Array, h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Associative scan of h_t = dA_t h_{t-1} + dBx_t within a chunk.

    dA, dBx: (B, L, di, ds); h0: (B, di, ds). Returns (h (B,L,di,ds), h_last).
    """

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    prodA, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = h + prodA * h0[:, None]
    return h, h[:, -1]


def mamba_mixer(params: Dict, x: jax.Array, cfg: ArchConfig, rules) -> jax.Array:
    """Full-sequence (train/prefill) mamba mixer."""
    B, S, d = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    xz = x @ params["in_proj"]
    xz = constrain(xz, ("batch", "seq", "inner"), rules)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in.astype(jnp.float32), params["conv_w"], params["conv_b"]))
    bcdt = (x_c.astype(x.dtype)) @ params["x_proj"]
    dtr = cfg.dt_rank
    dt_in, Bm, Cm = bcdt[..., :dtr], bcdt[..., dtr : dtr + ds], bcdt[..., dtr + ds :]
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) @ params["dt_proj"] + params["dt_bias"])  # (B,S,di)
    A = -jnp.exp(params["A_log"])  # (di, ds)

    Lc = min(cfg.ssm_chunk, S)
    assert S % Lc == 0, "seq must divide ssm_chunk"
    n_chunks = S // Lc

    def chunk_body(h_prev, xs):
        dt_c, B_c, C_c, x_c_ = xs  # (B,Lc,di) (B,Lc,ds) (B,Lc,ds) (B,Lc,di)
        dA = jnp.exp(dt_c[..., None] * A)  # (B,Lc,di,ds)
        dBx = (dt_c * x_c_)[..., None] * B_c[:, :, None, :].astype(jnp.float32)
        h, h_last = _ssm_scan(dA, dBx, h_prev)
        y = jnp.einsum("blds,bls->bld", h, C_c.astype(jnp.float32))
        return h_last, y

    resh = lambda a: a.reshape(B, n_chunks, Lc, *a.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0, (resh(dt), resh(Bm), resh(Cm), resh(x_c)))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y + params["D"] * x_c
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, ("batch", "seq", "inner"), rules)
    out = y @ params["out_proj"]
    return constrain(out, ("batch", "seq", "embed"), rules)


def init_mamba_state(cfg: ArchConfig, batch: int) -> Dict[str, jax.Array]:
    return dict(
        h=jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), jnp.float32),
    )


def mamba_decode(
    params: Dict, x: jax.Array, state: Dict[str, jax.Array], cfg: ArchConfig, rules
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token recurrent step. x: (B, 1, d)."""
    B = x.shape[0]
    di, ds, dc = cfg.d_inner, cfg.d_state, cfg.d_conv
    xz = x[:, 0] @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"], x_in.astype(jnp.float32)[:, None]], axis=1)  # (B,dc,di)
    conv_out = jnp.einsum("bcd,cd->bd", window, params["conv_w"]) + params["conv_b"]
    x_c = jax.nn.silu(conv_out)
    bcdt = x_c.astype(x.dtype) @ params["x_proj"]
    dtr = cfg.dt_rank
    dt_in, Bm, Cm = bcdt[..., :dtr], bcdt[..., dtr : dtr + ds], bcdt[..., dtr + ds :]
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) @ params["dt_proj"] + params["dt_bias"])  # (B,di)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A)  # (B,di,ds)
    dBx = (dt * x_c)[..., None] * Bm[:, None, :].astype(jnp.float32)
    h = dA * state["h"] + dBx
    y = jnp.einsum("bds,bs->bd", h, Cm.astype(jnp.float32)) + params["D"] * x_c
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["out_proj"])[:, None]
    new_state = dict(h=h, conv=window[:, 1:])
    return constrain(out, ("batch", None, "embed"), rules), new_state
