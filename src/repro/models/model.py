"""Model zoo assembly: init / train-loss / prefill / decode for every
assigned architecture family.

Families:
  dense, vlm      -- GQA decoder-only stack (vlm prepends stub patch embeds)
  moe             -- every layer's FFN is shared+routed MoE (qwen2-moe)
  mla_moe         -- MLA attention, 3 leading dense layers + MoE stack + MTP
                     (deepseek-v3)
  encdec          -- whisper: bidirectional encoder (stub frame embeds) +
                     causal decoder with cross attention
  xlstm           -- mLSTM/sLSTM repeating unit
  hybrid          -- jamba: 8-layer superblock (1 attention + 7 mamba,
                     alternating dense/MoE FFN), scanned over repeats

Parameters are ``Param(value, logical-spec)`` trees; layer stacks carry a
leading "layers"/"repeat" dim and are consumed by ``lax.scan`` so HLO size
is O(1) in depth. ``jax.checkpoint`` wraps scan bodies when cfg.remat=="full".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs.base import ArchConfig
from ..sharding.rules import constrain
from . import layers as L
from . import mla as MLA
from . import moe as MOE
from . import ssm as SSM
from . import xlstm as XL
from .layers import Param, is_param, split_params


# ----------------------------------------------------------------- helpers
def stack_init(init_fn: Callable, key, n: int, axis_name: str = "layers"):
    """Stack n independent inits into leading-dim-stacked Param tree."""
    captured = {}

    def value_init(k):
        tree = init_fn(k)
        vals, specs = split_params(tree)
        captured["specs"] = specs  # concrete python data, captured at trace time
        return vals

    stacked = jax.vmap(value_init)(jax.random.split(key, n))
    leaves_v, treedef = jax.tree.flatten(stacked)
    leaves_s = treedef.flatten_up_to(captured["specs"])
    return treedef.unflatten(
        [Param(v, (axis_name,) + tuple(s)) for v, s in zip(leaves_v, leaves_s)]
    )


def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _norm(w, x):
    return L.rms_norm(x, w)


# ------------------------------------------------ decoder block (attn+ffn)
def _init_block(key, cfg: ArchConfig, kind: str):
    """kind: dense | moe | mla_dense | mla_moe"""
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = dict(
        ln1=L.ones((cfg.d_model,), ("embed",)),
        ln2=L.ones((cfg.d_model,), ("embed",)),
    )
    if kind.startswith("mla"):
        p["attn"] = MLA.init_mla(ks[0], cfg)
    else:
        p["attn"] = L.init_attention(ks[0], cfg)
    if kind.endswith("moe"):
        p["moe"] = MOE.init_moe(ks[1], cfg)
    else:
        p["ffn"] = L.init_ffn(ks[1], cfg)
    return p


def _apply_block(p, x, cfg: ArchConfig, rules, mesh, kind: str, positions=None):
    h = _norm(p["ln1"], x)
    if kind.startswith("mla"):
        a = MLA.mla_attention(p["attn"], h, cfg, rules, positions)
    else:
        a = L.attention(p["attn"], h, cfg, rules, positions)
    x = x + a
    h = _norm(p["ln2"], x)
    if kind.endswith("moe"):
        f = MOE.moe_ffn(p["moe"], h, cfg, rules, mesh)
    else:
        f = L.ffn(p["ffn"], h, rules)
    return x + f


def _decode_block(p, x, cache_k, cache_v, pos, cfg, rules, kind: str):
    h = _norm(p["ln1"], x)
    if kind.startswith("mla"):
        a, ck, cv = MLA.mla_decode(p["attn"], h, cache_k, cache_v, pos, cfg, rules)
    else:
        a, ck, cv = L.decode_attention(p["attn"], h, cache_k, cache_v, pos, cfg, rules)
    x = x + a
    h = _norm(p["ln2"], x)
    if kind.endswith("moe"):
        f = MOE.moe_ffn_dense(p["moe"], h, cfg, rules)  # decode: tiny token count
    else:
        f = L.ffn(p["ffn"], h, rules)
    return x + f, ck, cv


# ---------------------------------------------------------------- Bundle
@dataclasses.dataclass
class ModelBundle:
    cfg: ArchConfig
    init: Callable  # key -> Param tree
    loss: Callable  # (params, batch, rules, mesh) -> scalar
    prefill: Callable  # (params, batch, rules, mesh) -> (logits_last, cache)
    decode: Callable  # (params, cache, tokens, pos, rules, mesh) -> (logits, cache)
    cache_shape: Callable  # (batch, seq) -> pytree of (shape, dtype, logical names)


def _lm_losses(params, x, tokens, cfg, rules, loss_start: int = 0):
    h = _norm(params["final_norm"], x)
    logits = L.lm_logits(params["head"], h, rules)
    lo = logits[:, loss_start:-1] if loss_start else logits[:, :-1]
    la = tokens[:, loss_start + 1 :] if loss_start else tokens[:, 1:]
    return L.softmax_xent(lo, la, rules)


# ------------------------------------------------------------ decoder-only
def build_decoder_only(cfg: ArchConfig) -> ModelBundle:
    """dense / vlm / moe / mla_moe families."""
    is_mla = cfg.use_mla
    moe_kind = ("mla_moe" if is_mla else "moe") if cfg.n_experts else ("mla_dense" if is_mla else "dense")
    dense_kind = "mla_dense" if is_mla else "dense"
    n_dense = cfg.first_dense if cfg.n_experts else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.n_experts else 0

    def init(key):
        ks = jax.random.split(key, 6)
        p: Dict[str, Any] = dict(
            embed=L.init_embedding(ks[0], cfg),
            head=L.init_lm_head(ks[1], cfg),
            final_norm=L.ones((cfg.d_model,), ("embed",)),
        )
        if n_dense:
            p["dense_blocks"] = stack_init(lambda k: _init_block(k, cfg, dense_kind), ks[2], n_dense)
        if n_moe:
            p["moe_blocks"] = stack_init(lambda k: _init_block(k, cfg, moe_kind), ks[3], n_moe)
        if cfg.mtp_depth:
            p["mtp"] = dict(
                proj=L.make(ks[4], (2 * cfg.d_model, cfg.d_model), ("wembed", None), 1.0, jnp.dtype(cfg.dtype)),
                block=_init_block(ks[5], cfg, dense_kind),
                norm=L.ones((cfg.d_model,), ("embed",)),
            )
        return p

    def backbone(params, x, rules, mesh, positions=None):
        def run_stack(x, stack, kind):
            def body(carry, lp):
                return _apply_block(lp, carry, cfg, rules, mesh, kind, positions), None

            body = _maybe_remat(body, cfg)
            x, _ = jax.lax.scan(body, x, stack)
            return x

        if "dense_blocks" in params:
            x = run_stack(x, params["dense_blocks"], dense_kind)
        if "moe_blocks" in params:
            x = run_stack(x, params["moe_blocks"], moe_kind)
        return x

    def embed_inputs(params, batch, rules):
        x = L.embed_lookup(params["embed"], batch["tokens"], rules)
        loss_start = 0
        if cfg.family == "vlm" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
            loss_start = batch["patches"].shape[1]
        return x, loss_start

    def loss(params, batch, rules, mesh):
        x, loss_start = embed_inputs(params, batch, rules)
        x = backbone(params, x, rules, mesh)
        if cfg.family == "vlm" and loss_start:
            # labels exist only for the text region
            h = _norm(params["final_norm"], x[:, loss_start:])
            logits = L.lm_logits(params["head"], h, rules)
            l = L.softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], rules)
        else:
            l = _lm_losses(params, x, batch["tokens"], cfg, rules)
        if cfg.mtp_depth and "mtp" in params:
            tok = batch["tokens"]
            emb_next = L.embed_lookup(params["embed"], jnp.roll(tok, -1, axis=1), rules)
            if cfg.family == "vlm" and loss_start:
                x_t = x[:, loss_start:]
            else:
                x_t = x
            hcat = jnp.concatenate([_norm(params["mtp"]["norm"], x_t), emb_next], axis=-1)
            h2 = hcat @ params["mtp"]["proj"]
            h2 = _apply_block(params["mtp"]["block"], h2, cfg, rules, mesh, dense_kind)
            logits2 = L.lm_logits(params["head"], _norm(params["final_norm"], h2), rules)
            l = l + 0.3 * L.softmax_xent(logits2[:, :-2], tok[:, 2:], rules)
        return l

    def cache_shape(batch, seq):
        n_layers = cfg.n_layers
        if is_mla:
            return dict(
                ckv=((n_layers, batch, seq, cfg.kv_lora), jnp.bfloat16, ("layers", "batch", "kv_seq", None)),
                kr=((n_layers, batch, seq, cfg.qk_rope), jnp.bfloat16, ("layers", "batch", "kv_seq", None)),
            )
        return dict(
            k=((n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16,
               ("layers", "batch", "kv_seq", "kv_heads", "head_dim")),
            v=((n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16,
               ("layers", "batch", "kv_seq", "kv_heads", "head_dim")),
        )

    def _split_cache(cache):
        a, b = (("ckv", "kr") if is_mla else ("k", "v"))
        nd = n_dense
        return (
            {a: cache[a][:nd], b: cache[b][:nd]},
            {a: cache[a][nd:], b: cache[b][nd:]},
        )

    def decode(params, cache, tokens, pos, rules, mesh):
        x = L.embed_lookup(params["embed"], tokens, rules)
        a, b = (("ckv", "kr") if is_mla else ("k", "v"))
        cache_d, cache_m = _split_cache(cache)
        new_d, new_m = cache_d, cache_m

        def run_decode_stack(x, stack, cch, kind):
            def body(carry, xs):
                lp, ck, cv = xs
                y, ck, cv = _decode_block(lp, carry, ck, cv, pos, cfg, rules, kind)
                return y, (ck, cv)

            x, (cks, cvs) = jax.lax.scan(body, x, (stack, cch[a], cch[b]))
            return x, {a: cks, b: cvs}

        if "dense_blocks" in params:
            x, new_d = run_decode_stack(x, params["dense_blocks"], cache_d, dense_kind)
        if "moe_blocks" in params:
            x, new_m = run_decode_stack(x, params["moe_blocks"], cache_m, moe_kind)
        h = _norm(params["final_norm"], x)
        logits = L.lm_logits(params["head"], h, rules)
        new_cache = {a: jnp.concatenate([new_d[a], new_m[a]], 0) if n_moe and n_dense else (new_m[a] if n_moe else new_d[a]),
                     b: jnp.concatenate([new_d[b], new_m[b]], 0) if n_moe and n_dense else (new_m[b] if n_moe else new_d[b])}
        return logits, new_cache

    def prefill(params, batch, rules, mesh):
        x, loss_start = embed_inputs(params, batch, rules)
        x = backbone(params, x, rules, mesh)
        h = _norm(params["final_norm"], x[:, -1:])
        logits = L.lm_logits(params["head"], h, rules)
        return logits

    return ModelBundle(cfg, init, loss, prefill, decode, cache_shape)


# ----------------------------------------------------------------- encdec
def build_encdec(cfg: ArchConfig) -> ModelBundle:
    def init_enc_block(key):
        ks = jax.random.split(key, 2)
        return dict(
            ln1=L.ones((cfg.d_model,), ("embed",)),
            ln1b=L.zeros((cfg.d_model,), ("embed",)),
            attn=L.init_attention(ks[0], cfg),
            ln2=L.ones((cfg.d_model,), ("embed",)),
            ln2b=L.zeros((cfg.d_model,), ("embed",)),
            ffn=L.init_ffn(ks[1], cfg, gelu=True),
        )

    def init_dec_block(key):
        ks = jax.random.split(key, 3)
        return dict(
            ln1=L.ones((cfg.d_model,), ("embed",)),
            ln1b=L.zeros((cfg.d_model,), ("embed",)),
            self_attn=L.init_attention(ks[0], cfg),
            ln2=L.ones((cfg.d_model,), ("embed",)),
            ln2b=L.zeros((cfg.d_model,), ("embed",)),
            cross_attn=L.init_attention(ks[1], cfg),
            ln3=L.ones((cfg.d_model,), ("embed",)),
            ln3b=L.zeros((cfg.d_model,), ("embed",)),
            ffn=L.init_ffn(ks[2], cfg, gelu=True),
        )

    def init(key):
        ks = jax.random.split(key, 4)
        return dict(
            embed=L.init_embedding(ks[0], cfg),
            head=L.init_lm_head(ks[1], cfg),
            enc_blocks=stack_init(init_enc_block, ks[2], cfg.enc_layers),
            dec_blocks=stack_init(init_dec_block, ks[3], cfg.n_layers),
            enc_norm=L.ones((cfg.d_model,), ("embed",)),
            enc_norm_b=L.zeros((cfg.d_model,), ("embed",)),
            final_norm=L.ones((cfg.d_model,), ("embed",)),
            final_norm_b=L.zeros((cfg.d_model,), ("embed",)),
        )

    def lnorm(w, b, x):
        return L.layer_norm(x, w, b)

    def encode(params, frames, rules, mesh):
        S = frames.shape[1]
        x = frames + L.sinusoidal_positions(S, cfg.d_model).astype(frames.dtype)

        def body(carry, lp):
            h = lnorm(lp["ln1"], lp["ln1b"], carry)
            carry = carry + L.attention(lp["attn"], h, cfg, rules, causal=False)
            h = lnorm(lp["ln2"], lp["ln2b"], carry)
            return carry + L.ffn(lp["ffn"], h, rules), None

        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return lnorm(params["enc_norm"], params["enc_norm_b"], x)

    def run_decoder(params, tokens, enc_out, rules, mesh, pos0: int = 0):
        S = tokens.shape[1]
        x = L.embed_lookup(params["embed"], tokens, rules)
        pe = L.sinusoidal_positions(pos0 + S, cfg.d_model)[pos0:].astype(x.dtype)
        x = x + pe

        def body(carry, lp):
            h = lnorm(lp["ln1"], lp["ln1b"], carry)
            carry = carry + L.attention(lp["self_attn"], h, cfg, rules, causal=True)
            h = lnorm(lp["ln2"], lp["ln2b"], carry)
            carry = carry + L.attention(lp["cross_attn"], h, cfg, rules, causal=False, kv_x=enc_out)
            h = lnorm(lp["ln3"], lp["ln3b"], carry)
            return carry + L.ffn(lp["ffn"], h, rules), None

        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        return lnorm(params["final_norm"], params["final_norm_b"], x)

    def loss(params, batch, rules, mesh):
        enc_out = encode(params, batch["frames"], rules, mesh)
        x = run_decoder(params, batch["tokens"], enc_out, rules, mesh)
        logits = L.lm_logits(params["head"], x, rules)
        return L.softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], rules)

    def cache_shape(batch, seq):
        enc_s = max(seq // cfg.enc_frames_div, 64)
        kv = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
        xkv = (cfg.n_layers, batch, enc_s, cfg.n_kv_heads, cfg.head_dim)
        spec = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        return dict(
            k=(kv, jnp.bfloat16, spec), v=(kv, jnp.bfloat16, spec),
            xk=(xkv, jnp.bfloat16, spec), xv=(xkv, jnp.bfloat16, spec),
        )

    def decode(params, cache, tokens, pos, rules, mesh):
        x = L.embed_lookup(params["embed"], tokens, rules)
        pe = L.sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, 0)[None].astype(x.dtype)

        def body(carry, xs):
            lp, ck, cv, xk, xv = xs
            h = lnorm(lp["ln1"], lp["ln1b"], carry)
            a, ck, cv = L.decode_attention(lp["self_attn"], h, ck, cv, pos, cfg, rules, rope=False)
            carry = carry + a
            h = lnorm(lp["ln2"], lp["ln2b"], carry)
            a, _, _ = L.decode_attention(
                lp["cross_attn"], h, xk, xv, xk.shape[1] - 1, cfg, rules, update_cache=False, rope=False
            )
            carry = carry + a
            h = lnorm(lp["ln3"], lp["ln3b"], carry)
            return carry + L.ffn(lp["ffn"], h, rules), (ck, cv)

        x, (cks, cvs) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        x = lnorm(params["final_norm"], params["final_norm_b"], x)
        logits = L.lm_logits(params["head"], x, rules)
        return logits, dict(k=cks, v=cvs, xk=cache["xk"], xv=cache["xv"])

    def prefill(params, batch, rules, mesh):
        enc_out = encode(params, batch["frames"], rules, mesh)
        x = run_decoder(params, batch["tokens"], enc_out, rules, mesh)
        return L.lm_logits(params["head"], x[:, -1:], rules)

    return ModelBundle(cfg, init, loss, prefill, decode, cache_shape)


# ------------------------------------------------------------------ xlstm
def build_xlstm(cfg: ArchConfig) -> ModelBundle:
    unit = cfg.slstm_every  # layers per repeating unit; last one is sLSTM
    assert cfg.n_layers % unit == 0
    n_rep = cfg.n_layers // unit

    def init_unit(key):
        ks = jax.random.split(key, unit)
        p = {}
        for i in range(unit):
            if i == unit - 1:
                p[f"s{i}"] = dict(ln=L.ones((cfg.d_model,), ("embed",)), core=XL.init_slstm(ks[i], cfg))
            else:
                p[f"m{i}"] = dict(ln=L.ones((cfg.d_model,), ("embed",)), core=XL.init_mlstm(ks[i], cfg))
        return p

    def init(key):
        ks = jax.random.split(key, 3)
        return dict(
            embed=L.init_embedding(ks[0], cfg),
            head=L.init_lm_head(ks[1], cfg),
            units=stack_init(init_unit, ks[2], n_rep, "repeat"),
            final_norm=L.ones((cfg.d_model,), ("embed",)),
        )

    def unit_apply(up, x, rules):
        for i in range(unit):
            if i == unit - 1:
                p = up[f"s{i}"]
                x = x + XL.slstm_mixer(p["core"], _norm(p["ln"], x), cfg, rules)
            else:
                p = up[f"m{i}"]
                x = x + XL.mlstm_mixer(p["core"], _norm(p["ln"], x), cfg, rules)
        return x

    def loss(params, batch, rules, mesh):
        x = L.embed_lookup(params["embed"], batch["tokens"], rules)

        def body(carry, up):
            return unit_apply(up, carry, rules), None

        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["units"])
        return _lm_losses(params, x, batch["tokens"], cfg, rules)

    def cache_shape(batch, seq):
        H = cfg.n_heads
        dh = cfg.d_inner // H
        d = cfg.d_model
        return dict(
            C=((n_rep, unit - 1, batch, H, dh, dh), jnp.float32, ("repeat", None, "batch", None, None, None)),
            N=((n_rep, unit - 1, batch, H, dh), jnp.float32, ("repeat", None, "batch", None, None)),
            m=((n_rep, unit - 1, batch, H), jnp.float32, ("repeat", None, "batch", None)),
            sc=((n_rep, batch, d), jnp.float32, ("repeat", "batch", None)),
            sn=((n_rep, batch, d), jnp.float32, ("repeat", "batch", None)),
            sh=((n_rep, batch, d), jnp.float32, ("repeat", "batch", None)),
            sm=((n_rep, batch, d), jnp.float32, ("repeat", "batch", None)),
        )

    def decode(params, cache, tokens, pos, rules, mesh):
        x = L.embed_lookup(params["embed"], tokens, rules)

        def body(carry, xs):
            up, C, N, m, sc, sn, sh, sm = xs
            new_C, new_N, new_m = [], [], []
            for i in range(unit - 1):
                p = up[f"m{i}"]
                y, st = XL.mlstm_decode(
                    p["core"], _norm(p["ln"], carry), dict(C=C[i], N=N[i], m=m[i]), cfg, rules
                )
                carry = carry + y
                new_C.append(st["C"]); new_N.append(st["N"]); new_m.append(st["m"])
            p = up[f"s{unit-1}"]
            y, st = XL.slstm_decode(
                p["core"], _norm(p["ln"], carry), dict(c=sc, n=sn, h=sh, m=sm), cfg, rules
            )
            carry = carry + y
            return carry, (jnp.stack(new_C), jnp.stack(new_N), jnp.stack(new_m),
                           st["c"], st["n"], st["h"], st["m"])

        x, (C, N, m, sc, sn, sh, sm) = jax.lax.scan(
            body, x,
            (params["units"], cache["C"], cache["N"], cache["m"],
             cache["sc"], cache["sn"], cache["sh"], cache["sm"]),
        )
        h = _norm(params["final_norm"], x)
        logits = L.lm_logits(params["head"], h, rules)
        return logits, dict(C=C, N=N, m=m, sc=sc, sn=sn, sh=sh, sm=sm)

    def prefill(params, batch, rules, mesh):
        x = L.embed_lookup(params["embed"], batch["tokens"], rules)

        def body(carry, up):
            return unit_apply(up, carry, rules), None

        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["units"])
        return L.lm_logits(params["head"], _norm(params["final_norm"], x[:, -1:]), rules)

    return ModelBundle(cfg, init, loss, prefill, decode, cache_shape)


# ------------------------------------------------------------------ hybrid
def build_hybrid(cfg: ArchConfig) -> ModelBundle:
    """Jamba: superblock of ``attn_every`` layers, attention at position
    attn_every//2 - 1 (1:7), MoE FFN on odd positions."""
    unit = cfg.attn_every
    assert cfg.n_layers % unit == 0
    n_rep = cfg.n_layers // unit
    attn_pos = unit // 2 - 1  # position 3 of 8

    def is_moe(i):
        return cfg.n_experts and (i % cfg.moe_every == cfg.moe_every - 1)

    def init_unit(key):
        ks = jax.random.split(key, 2 * unit)
        p = {}
        for i in range(unit):
            mix = (
                L.init_attention(ks[2 * i], cfg)
                if i == attn_pos
                else SSM.init_mamba(ks[2 * i], cfg)
            )
            f = MOE.init_moe(ks[2 * i + 1], cfg) if is_moe(i) else L.init_ffn(ks[2 * i + 1], cfg)
            p[f"b{i}"] = dict(
                ln1=L.ones((cfg.d_model,), ("embed",)),
                ln2=L.ones((cfg.d_model,), ("embed",)),
                mix=mix,
                ffn=f,
            )
        return p

    def unit_apply(up, x, rules, mesh):
        for i in range(unit):
            p = up[f"b{i}"]
            h = _norm(p["ln1"], x)
            if i == attn_pos:
                x = x + L.attention(p["mix"], h, cfg, rules)
            else:
                x = x + SSM.mamba_mixer(p["mix"], h, cfg, rules)
            h = _norm(p["ln2"], x)
            if is_moe(i):
                x = x + MOE.moe_ffn(p["ffn"], h, cfg, rules, mesh)
            else:
                x = x + L.ffn(p["ffn"], h, rules)
        return x

    def init(key):
        ks = jax.random.split(key, 3)
        return dict(
            embed=L.init_embedding(ks[0], cfg),
            head=L.init_lm_head(ks[1], cfg),
            units=stack_init(init_unit, ks[2], n_rep, "repeat"),
            final_norm=L.ones((cfg.d_model,), ("embed",)),
        )

    def loss(params, batch, rules, mesh):
        x = L.embed_lookup(params["embed"], batch["tokens"], rules)

        def body(carry, up):
            return unit_apply(up, carry, rules, mesh), None

        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["units"])
        return _lm_losses(params, x, batch["tokens"], cfg, rules)

    def cache_shape(batch, seq):
        n_mamba = unit - 1
        return dict(
            k=((n_rep, batch, seq, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16,
               ("repeat", "batch", "kv_seq", "kv_heads", "head_dim")),
            v=((n_rep, batch, seq, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16,
               ("repeat", "batch", "kv_seq", "kv_heads", "head_dim")),
            h=((n_rep, n_mamba, batch, cfg.d_inner, cfg.d_state), jnp.float32,
               ("repeat", None, "batch", "inner", None)),
            conv=((n_rep, n_mamba, batch, cfg.d_conv - 1, cfg.d_inner), jnp.float32,
                  ("repeat", None, "batch", None, "inner")),
        )

    def decode(params, cache, tokens, pos, rules, mesh):
        x = L.embed_lookup(params["embed"], tokens, rules)

        def body(carry, xs):
            up, ck, cv, hs, convs = xs
            new_h, new_conv = [], []
            mi = 0
            for i in range(unit):
                p = up[f"b{i}"]
                h = _norm(p["ln1"], carry)
                if i == attn_pos:
                    y, ck, cv = L.decode_attention(p["mix"], h, ck, cv, pos, cfg, rules)
                else:
                    y, st = SSM.mamba_decode(
                        p["mix"], h, dict(h=hs[mi], conv=convs[mi]), cfg, rules
                    )
                    new_h.append(st["h"]); new_conv.append(st["conv"])
                    mi += 1
                carry = carry + y
                h = _norm(p["ln2"], carry)
                if is_moe(i):
                    carry = carry + MOE.moe_ffn_dense(p["ffn"], h, cfg, rules)
                else:
                    carry = carry + L.ffn(p["ffn"], h, rules)
            return carry, (ck, cv, jnp.stack(new_h), jnp.stack(new_conv))

        x, (ck, cv, hs, convs) = jax.lax.scan(
            body, x, (params["units"], cache["k"], cache["v"], cache["h"], cache["conv"])
        )
        logits = L.lm_logits(params["head"], _norm(params["final_norm"], x), rules)
        return logits, dict(k=ck, v=cv, h=hs, conv=convs)

    def prefill(params, batch, rules, mesh):
        x = L.embed_lookup(params["embed"], batch["tokens"], rules)

        def body(carry, up):
            return unit_apply(up, carry, rules, mesh), None

        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["units"])
        return L.lm_logits(params["head"], _norm(params["final_norm"], x[:, -1:]), rules)

    return ModelBundle(cfg, init, loss, prefill, decode, cache_shape)


# ---------------------------------------------------------------- factory
def build_model(cfg: ArchConfig) -> ModelBundle:
    if cfg.family in ("dense", "vlm", "moe", "mla_moe"):
        return build_decoder_only(cfg)
    if cfg.family == "encdec":
        return build_encdec(cfg)
    if cfg.family == "xlstm":
        return build_xlstm(cfg)
    if cfg.family == "hybrid":
        return build_hybrid(cfg)
    raise ValueError(f"unknown family {cfg.family}")
