"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory) + recurrent sLSTM.

mLSTM keeps per-head matrix state ``C (dv x dk)``, normalizer ``N (dk)`` and
stabilizer ``m``; the chunkwise form computes intra-chunk interactions with
a decay-masked attention-like quadratic and carries (C, N, m) across chunks
-- the TPU-friendly equivalent of the paper's recurrent formulation.
sLSTM (exponential gating + normalizer + stabilizer states) is inherently
sequential and runs as a ``lax.scan`` over time steps.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.rules import constrain
from .layers import Param, _dtype, make, zeros


# ------------------------------------------------------------------- mLSTM
def init_mlstm(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 6)
    d, H = cfg.d_model, cfg.n_heads
    di = cfg.d_inner
    dh = di // H
    dt = _dtype(cfg)
    return dict(
        up=make(ks[0], (d, 2 * di), ("wembed", "inner"), 1.0, dt),
        wq=make(ks[1], (di, H, dh), ("inner", "heads", "head_dim"), 1.0, dt),
        wk=make(ks[2], (di, H, dh), ("inner", "heads", "head_dim"), 1.0, dt),
        wv=make(ks[3], (di, H, dh), ("inner", "heads", "head_dim"), 1.0, dt),
        w_if=make(ks[4], (di, 2 * H), ("inner", None), 1.0, jnp.float32),
        b_if=zeros((2 * H,), (None,)),
        down=make(ks[5], (di, d), ("inner", "wembed"), 1.0, dt),
    )


def _mlstm_chunk(q, k, v, li, lf, state):
    """One chunk. q,k,v: (B,L,H,dh); li,lf: (B,L,H); state: (C,N,m)."""
    B, L, H, dh = q.shape
    C_prev, N_prev, m_prev = state  # (B,H,dh,dh), (B,H,dh), (B,H)
    F = jnp.cumsum(lf, axis=1)  # (B,L,H) cumulative log-forget
    # intra-chunk decay D[t,tau] = F_t - F_tau + li_tau  (tau <= t)
    Dm = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]  # (B,t,tau,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    Dm = jnp.where(tri[None, :, :, None], Dm, -jnp.inf)
    # stabilizer
    m_intra = jnp.max(Dm, axis=2)  # (B,t,H)
    m_inter = m_prev[:, None, :] + F  # (B,t,H)
    m_t = jnp.maximum(m_intra, m_inter)
    scale = 1.0 / dh**0.5
    s = jnp.einsum("blhd,bmhd->blmh", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    w = s * jnp.exp(Dm - m_t[:, :, None, :])  # (B,t,tau,H)
    h_intra = jnp.einsum("blmh,bmhd->blhd", w, v.astype(jnp.float32))
    n_intra = jnp.einsum("blmh,bmhd->blhd", jnp.exp(Dm - m_t[:, :, None, :]), k.astype(jnp.float32))
    inter_scale = jnp.exp(m_inter - m_t)  # (B,t,H)
    h_inter = jnp.einsum("blhd,bhed->blhe", q.astype(jnp.float32) * scale, C_prev) * inter_scale[..., None]
    n_inter = jnp.einsum("blhd,bhd->blh", q.astype(jnp.float32) * scale, N_prev)[..., None] * 0 + (
        jnp.einsum("blhd,bhd->blh", q.astype(jnp.float32) * scale, N_prev) * inter_scale
    )[..., None]
    h_num = h_intra + h_inter  # (B,t,H,dh)
    qn = jnp.einsum("blhd,blhd->blh", q.astype(jnp.float32) * scale, n_intra) + n_inter[..., 0]
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))[..., None]
    h = h_num / denom
    # carry state to end of chunk
    F_end = F[:, -1:, :]  # (B,1,H)
    m_end = jnp.maximum(m_prev + F_end[:, 0], jnp.max(li + (F_end - F), axis=1))
    decay_out = jnp.exp(li + F_end - F - m_end[:, None, :])  # (B,L,H)
    C_new = jnp.exp(m_prev + F_end[:, 0] - m_end)[:, :, None, None] * C_prev + jnp.einsum(
        "blh,blhe,blhd->bhed", decay_out, v.astype(jnp.float32), k.astype(jnp.float32)
    )
    N_new = jnp.exp(m_prev + F_end[:, 0] - m_end)[:, :, None] * N_prev + jnp.einsum(
        "blh,blhd->bhd", decay_out, k.astype(jnp.float32)
    )
    return h, (C_new, N_new, m_end)


def mlstm_mixer(params: Dict, x: jax.Array, cfg: ArchConfig, rules) -> jax.Array:
    B, S, d = x.shape
    H = cfg.n_heads
    di = cfg.d_inner
    dh = di // H
    xz = x @ params["up"]
    xz = constrain(xz, ("batch", "seq", "inner"), rules)
    xi, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bsd,dhk->bshk", xi, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xi, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xi, params["wv"])
    gates = xi.astype(jnp.float32) @ params["w_if"] + params["b_if"]  # (B,S,2H)
    li = gates[..., :H]  # log input gate (exp gating)
    lf = jax.nn.log_sigmoid(gates[..., H:])
    L = min(cfg.ssm_chunk, S)
    assert S % L == 0
    n = S // L
    resh = lambda a: a.reshape(B, n, L, *a.shape[2:]).swapaxes(0, 1)
    state0 = (
        jnp.zeros((B, H, dh, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )

    def body(state, xs):
        qc, kc, vc, lic, lfc = xs
        h, state = _mlstm_chunk(qc, kc, vc, lic, lfc, state)
        return state, h

    _, hs = jax.lax.scan(body, state0, (resh(q), resh(k), resh(v), resh(li), resh(lf)))
    h = hs.swapaxes(0, 1).reshape(B, S, di)
    y = (h.astype(x.dtype) * jax.nn.silu(z)) @ params["down"]
    return constrain(y, ("batch", "seq", "embed"), rules)


def init_mlstm_state(cfg: ArchConfig, batch: int):
    H = cfg.n_heads
    dh = cfg.d_inner // H
    return dict(
        C=jnp.zeros((batch, H, dh, dh), jnp.float32),
        N=jnp.zeros((batch, H, dh), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
    )


def mlstm_decode(params, x, state, cfg: ArchConfig, rules):
    """Single-step recurrent mLSTM. x: (B,1,d)."""
    B = x.shape[0]
    H = cfg.n_heads
    dh = cfg.d_inner // H
    xz = x[:, 0] @ params["up"]
    xi, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bd,dhk->bhk", xi, params["wq"]).astype(jnp.float32) / dh**0.5
    k = jnp.einsum("bd,dhk->bhk", xi, params["wk"]).astype(jnp.float32)
    v = jnp.einsum("bd,dhk->bhk", xi, params["wv"]).astype(jnp.float32)
    gates = xi.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    li, lf = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
    m_new = jnp.maximum(lf + state["m"], li)
    i_g = jnp.exp(li - m_new)
    f_g = jnp.exp(lf + state["m"] - m_new)
    C = f_g[..., None, None] * state["C"] + i_g[..., None, None] * jnp.einsum("bhe,bhd->bhed", v, k)
    N = f_g[..., None] * state["N"] + i_g[..., None] * k
    qn = jnp.einsum("bhd,bhd->bh", q, N)
    h = jnp.einsum("bhd,bhed->bhe", q, C) / jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, cfg.d_inner).astype(x.dtype)
    y = ((h * jax.nn.silu(z)) @ params["down"])[:, None]
    return constrain(y, ("batch", None, "embed"), rules), dict(C=C, N=N, m=m_new)


# ------------------------------------------------------------------- sLSTM
def init_slstm(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    dt = _dtype(cfg)
    return dict(
        w_in=make(ks[0], (d, 4 * d), ("wembed", "inner"), 1.0, dt),
        w_rec=make(ks[1], (d, 4 * d), ("wembed", "inner"), 1.0, dt),
        b=zeros((4 * d,), ("inner",)),
        down=make(ks[2], (d, d), ("inner", "wembed"), 1.0, dt),
    )


def _slstm_step(params, carry, x_t):
    """carry: (c, n, h, m) each (B, d); x_t: (B, d)."""
    c, n, h, m = carry
    d = x_t.shape[-1]
    pre = (x_t @ params["w_in"] + h.astype(x_t.dtype) @ params["w_rec"]).astype(jnp.float32) + params["b"]
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(fi + m, ii)
    i_g = jnp.exp(ii - m_new)
    f_g = jnp.exp(fi + m - m_new)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_mixer(params: Dict, x: jax.Array, cfg: ArchConfig, rules) -> jax.Array:
    B, S, d = x.shape
    carry = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3)) + (
        jnp.full((B, d), -1e30, jnp.float32),
    )

    def body(c, x_t):
        return _slstm_step(params, c, x_t)

    _, hs = jax.lax.scan(body, carry, x.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    y = h @ params["down"]
    return constrain(y, ("batch", "seq", "embed"), rules)


def init_slstm_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    return dict(
        c=jnp.zeros((batch, d), jnp.float32),
        n=jnp.zeros((batch, d), jnp.float32),
        h=jnp.zeros((batch, d), jnp.float32),
        m=jnp.full((batch, d), -1e30, jnp.float32),
    )


def slstm_decode(params, x, state, cfg: ArchConfig, rules):
    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, h = _slstm_step(params, carry, x[:, 0])
    y = (h.astype(x.dtype) @ params["down"])[:, None]
    new = dict(c=carry[0], n=carry[1], h=carry[2], m=carry[3])
    return constrain(y, ("batch", None, "embed"), rules), new
