"""DeepSeek-V3 Multi-head Latent Attention (MLA).

Train/prefill materializes per-head K/V from the compressed latent (the
FLOP-heavy path); decode uses the *absorbed* formulation so the cache holds
only ``c_kv (kv_lora)`` + ``k_rope (qk_rope)`` per token -- the paper's
cache-compression win (576 dims/token vs 2*128*192 for vanilla MHA).
MLA is still O(S^2) attention: long_500k is skipped for this family.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.rules import constrain
from .layers import Param, _chunked_causal_attn, _dtype, apply_rope, make, ones, rms_norm


def init_mla(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 7)
    d, H = cfg.d_model, cfg.n_heads
    dt = _dtype(cfg)
    return dict(
        wq_a=make(ks[0], (d, cfg.q_lora), ("wembed", "lora"), 1.0, dt),
        q_norm=ones((cfg.q_lora,), ("lora",)),
        wq_b=make(ks[1], (cfg.q_lora, H, cfg.qk_nope + cfg.qk_rope), ("lora", "heads", "head_dim"), 1.0, dt),
        wkv_a=make(ks[2], (d, cfg.kv_lora + cfg.qk_rope), ("wembed", "lora"), 1.0, dt),
        kv_norm=ones((cfg.kv_lora,), ("lora",)),
        wk_b=make(ks[3], (cfg.kv_lora, H, cfg.qk_nope), ("lora", "heads", "head_dim"), 1.0, dt),
        wv_b=make(ks[4], (cfg.kv_lora, H, cfg.v_head), ("lora", "heads", "head_dim"), 1.0, dt),
        wo=make(ks[5], (H, cfg.v_head, d), ("heads", "head_dim", "wembed"), 1.0, dt),
    )


def _project_qkv(params, x, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(x @ params["wq_a"], params["q_norm"])
    q = jnp.einsum("bsl,lhk->bshk", cq, params["wq_b"])
    q_nope, q_rope = q[..., : cfg.qk_nope], q[..., cfg.qk_nope :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ params["wkv_a"]
    c_kv = rms_norm(kv[..., : cfg.kv_lora], params["kv_norm"])
    k_rope = apply_rope(kv[..., None, cfg.kv_lora :], positions, cfg.rope_theta)  # (B,S,1,r)
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(params: Dict, x: jax.Array, cfg: ArchConfig, rules,
                  positions: Optional[jax.Array] = None) -> jax.Array:
    """Train/prefill path: materialize per-head K/V, chunked causal attn."""
    B, S, _ = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope, c_kv, k_rope = _project_qkv(params, x, cfg, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsl,lhv->bshv", c_kv, params["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, cfg.qk_rope))], -1)
    q = constrain(q, ("batch", "seq", "act_heads", "head_dim"), rules)
    k = constrain(k, ("batch", "seq", "act_heads", "head_dim"), rules)
    # pad v head dim to qk dim for the shared flash helper, then slice
    pad = q.shape[-1] - v.shape[-1]
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = _chunked_causal_attn(q, k, vp, cfg.attn_chunk, True, cfg.causal_impl)[..., : cfg.v_head]
    out = constrain(out, ("batch", "seq", "act_heads", "head_dim"), rules)
    y = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
    return constrain(y, ("batch", "seq", "embed"), rules)


def mla_decode(
    params: Dict,
    x: jax.Array,  # (B, 1, d)
    cache_ckv: jax.Array,  # (B, S, kv_lora) -- seq sharded
    cache_kr: jax.Array,  # (B, S, qk_rope)
    pos: jax.Array,
    cfg: ArchConfig,
    rules,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed decode: score via latent space, cache stays compressed."""
    B = x.shape[0]
    H = cfg.n_heads
    q_nope, q_rope, c_new, kr_new = _project_qkv(params, x, cfg, pos[None, None])
    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, c_new.astype(cache_ckv.dtype), (0, pos, 0))
    cache_kr = jax.lax.dynamic_update_slice(cache_kr, kr_new[:, :, 0].astype(cache_kr.dtype), (0, pos, 0))
    # absorb k up-projection into q
    q_abs = jnp.einsum("bhk,lhk->bhl", q_nope[:, 0], params["wk_b"])  # (B, H, kv_lora)
    s = jnp.einsum("bhl,bsl->bhs", q_abs, cache_ckv).astype(jnp.float32)
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32), cache_kr.astype(jnp.float32))
    s = s / (cfg.qk_nope + cfg.qk_rope) ** 0.5
    S = cache_ckv.shape[1]
    s = jnp.where(jnp.arange(S)[None, None, :] <= pos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)  # seq-sharded reductions -> psum
    ctx = jnp.einsum("bhs,bsl->bhl", p.astype(cache_ckv.dtype), cache_ckv)
    out = jnp.einsum("bhl,lhv->bhv", ctx, params["wv_b"])[:, None]  # (B,1,H,v)
    y = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
    return constrain(y, ("batch", None, "embed"), rules), cache_ckv, cache_kr
