"""Pallas TPU kernels: Boolean-kNN frontier distance filtering (DESIGN.md §6).

The distance-bounded descent generalizes the range frontier filter
(``kernels/frontier.py``): instead of an intersect/bitmap boolean, each
(query, frontier-slot) pair needs the *squared min-distance* from the query
point to the slot's MBR, fused with the keyword-bitmap test, so the serving
engine can prune a slot against the query's current k-th best distance in
one VMEM-resident pass. Slots that fail the bitmap AND (or are ``-1``
padding) come back as ``+inf`` -- the natural "never survives a distance
bound" sentinel, mirroring the NEVER_RECT padding of the range path.

Like the range path, two variants share the predicate: ``knn_filter`` on
full-width f32/uint32 planes (A/B baseline and delta-augmented fallback)
and ``knn_filter_narrow`` on int16 rank-coded MBR planes + packed word
planes. The narrow kernel dequantizes the codes to exact f32 via a VMEM
dictionary gather before the distance computation, so the emitted distances
are bit-identical to the f32 kernel's -- the bound-tightening descent and
top-k merges see the same numbers on either path.

Layout notes (TPU): identical tiling to ``frontier_filter`` -- the minor
dimension is the frontier width (BF = 128 lanes by default). The keyword
test is one packed word-plane AND + a single ``any``-reduction over the
word axis per tile (popcount-style); only the (BM, BF) distance/keyword
accumulators stay live.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mbr_sq_dist(px, py, xlo, ylo, xhi, yhi):
    # squared min-distance from point to (closed) MBR: clamp the outside gap
    dx = jnp.maximum(jnp.maximum(xlo - px, px - xhi), 0.0)
    dy = jnp.maximum(jnp.maximum(ylo - py, py - yhi), 0.0)
    return dx * dx + dy * dy


def _knn_kernel(q_pts_ref, q_bm_ref, f_mbrs_ref, f_bm_ref, f_valid_ref, out_ref):
    qp = q_pts_ref[...]  # (BM, 2)
    fm = f_mbrs_ref[...]  # (BM, BF, 4)
    d2 = _mbr_sq_dist(qp[:, 0:1], qp[:, 1:2], fm[:, :, 0], fm[:, :, 1], fm[:, :, 2], fm[:, :, 3])
    qb = q_bm_ref[...]  # (BM, W) uint32
    fb = f_bm_ref[...]  # (BM, BF, W) uint32
    kw = jnp.any((fb & qb[:, None, :]) != 0, axis=-1)  # (BM, BF)
    ok = kw & (f_valid_ref[...] > 0)
    out_ref[...] = jnp.where(ok, d2, jnp.inf).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def knn_filter(
    q_pts: jax.Array,  # (M, 2)
    q_bm: jax.Array,  # (M, W)
    f_mbrs: jax.Array,  # (M, F, 4)
    f_bm: jax.Array,  # (M, F, W)
    f_valid: jax.Array,  # (M, F) int8
    bm: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(M, F) f32 squared MBR min-distances (+inf where the slot is invalid
    or shares no keyword bit). Inputs padded to tile multiples by ops.py."""
    M, F = f_valid.shape
    W = q_bm.shape[1]
    bm = min(bm, M)
    bf = min(bf, F)
    grid = (pl.cdiv(M, bm), pl.cdiv(F, bf))
    return pl.pallas_call(
        _knn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bf, 4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.float32),
        interpret=interpret,
    )(q_pts, q_bm, f_mbrs, f_bm, f_valid)


def _knn_narrow_kernel(
    q_pts_ref, q_bits_ref, f_codes_ref, f_bm_ref, f_valid_ref, dict_x_ref, dict_y_ref, out_ref
):
    qp = q_pts_ref[...]  # (BM, 2) f32
    fc = f_codes_ref[...].astype(jnp.int32)  # (BM, BF, 4) int16 rank codes
    dx = dict_x_ref[...]  # (Dx,) f32
    dy = dict_y_ref[...]  # (Dy,) f32
    d2 = _mbr_sq_dist(
        qp[:, 0:1], qp[:, 1:2], dx[fc[:, :, 0]], dy[fc[:, :, 1]], dx[fc[:, :, 2]], dy[fc[:, :, 3]]
    )
    qb = q_bits_ref[...]  # (BM, Wp) uint32 packed query words
    fb = f_bm_ref[...]  # (BM, BF, Wp) uint32
    kw = jnp.any((fb & qb[:, None, :]) != 0, axis=-1)
    ok = kw & (f_valid_ref[...] > 0)
    out_ref[...] = jnp.where(ok, d2, jnp.inf).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def knn_filter_narrow(
    q_pts: jax.Array,  # (M, 2) f32
    q_bits: jax.Array,  # (M, Wp) uint32 packed query words (ops.pack_query_words)
    f_codes: jax.Array,  # (M, F, 4) int16 MBR rank codes
    f_bm: jax.Array,  # (M, F, Wp) uint32 packed node word planes
    f_valid: jax.Array,  # (M, F) int8
    dict_x: jax.Array,  # (Dx,) f32
    dict_y: jax.Array,  # (Dy,) f32
    bm: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(M, F) f32 squared MBR min-distances, bit-identical to ``knn_filter``
    on the dequantized planes (+inf sentinel semantics unchanged)."""
    M, F = f_valid.shape
    Wp = q_bits.shape[1]
    bm = min(bm, M)
    bf = min(bf, F)
    grid = (pl.cdiv(M, bm), pl.cdiv(F, bf))
    return pl.pallas_call(
        _knn_narrow_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, Wp), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bf, 4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf, Wp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
            pl.BlockSpec(dict_x.shape, lambda i, j: (0,)),
            pl.BlockSpec(dict_y.shape, lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.float32),
        interpret=interpret,
    )(q_pts, q_bits, f_codes, f_bm, f_valid, dict_x, dict_y)
