"""Pallas TPU kernel: Boolean-kNN frontier distance filtering (DESIGN.md §6).

The distance-bounded descent generalizes the range frontier filter
(``kernels/frontier.py``): instead of an intersect/bitmap boolean, each
(query, frontier-slot) pair needs the *squared min-distance* from the query
point to the slot's MBR, fused with the keyword-bitmap test, so the serving
engine can prune a slot against the query's current k-th best distance in
one VMEM-resident pass. Slots that fail the bitmap AND (or are ``-1``
padding) come back as ``+inf`` -- the natural "never survives a distance
bound" sentinel, mirroring the NEVER_RECT padding of the range path.

Layout notes (TPU): identical tiling to ``frontier_filter`` -- the minor
dimension is the frontier width (BF = 128 lanes by default), the bitmap
plane ``(BM, BF, W)`` streams through VMEM one word-plane at a time via the
static W unroll, and only the (BM, BF) distance/keyword accumulators stay
live.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _knn_kernel(q_pts_ref, q_bm_ref, f_mbrs_ref, f_bm_ref, f_valid_ref, out_ref):
    qp = q_pts_ref[...]  # (BM, 2)
    fm = f_mbrs_ref[...]  # (BM, BF, 4)
    px = qp[:, 0:1]
    py = qp[:, 1:2]
    # squared min-distance from point to (closed) MBR: clamp the outside gap
    dx = jnp.maximum(jnp.maximum(fm[:, :, 0] - px, px - fm[:, :, 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(fm[:, :, 1] - py, py - fm[:, :, 3]), 0.0)
    d2 = dx * dx + dy * dy  # (BM, BF)
    qb = q_bm_ref[...]  # (BM, W) uint32
    fb = f_bm_ref[...]  # (BM, BF, W) uint32
    W = qb.shape[1]
    kw = jnp.zeros(d2.shape, dtype=jnp.bool_)
    for w in range(W):  # static unroll over bitmap words (frontier_filter inner loop)
        kw = kw | ((fb[:, :, w] & qb[:, w][:, None]) != 0)
    ok = kw & (f_valid_ref[...] > 0)
    out_ref[...] = jnp.where(ok, d2, jnp.inf).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def knn_filter(
    q_pts: jax.Array,  # (M, 2)
    q_bm: jax.Array,  # (M, W)
    f_mbrs: jax.Array,  # (M, F, 4)
    f_bm: jax.Array,  # (M, F, W)
    f_valid: jax.Array,  # (M, F) int8
    bm: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(M, F) f32 squared MBR min-distances (+inf where the slot is invalid
    or shares no keyword bit). Inputs padded to tile multiples by ops.py."""
    M, F = f_valid.shape
    W = q_bm.shape[1]
    bm = min(bm, M)
    bf = min(bf, F)
    grid = (pl.cdiv(M, bm), pl.cdiv(F, bf))
    return pl.pallas_call(
        _knn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bf, 4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.float32),
        interpret=interpret,
    )(q_pts, q_bm, f_mbrs, f_bm, f_valid)
