"""Pallas TPU kernel: continuous-filter subscription matching (DESIGN.md §8).

The pub-sub subsystem (serve/subscribe.py) inverts the SKR problem: the
*subscriptions* are the indexed set -- a padded power-of-two block of
standing (rect, keyword bitmap) filters -- and every arriving object is a
point query matched against all of them in one cross-product sweep, the
FAST-style continuous-query scenario of ROADMAP item 2.

Predicate per (object, subscription) pair, Boolean semantics identical to
the SKR path: the object's point lies inside the subscription rectangle
(closed; a zero-area rect matches objects exactly at that point) AND the
keyword bitmaps share at least one bit (an empty keyword set matches
nothing, the same contract as an empty SKR query).

The kernel reuses the two bandwidth tricks of the descent kernels:

* **packed object word planes** (PR 7 / ops.pack_query_words): each
  arriving object carries only its nonzero bitmap words -- ``(BN, Wp)``
  ids + values with Wp a static power-of-two bucket -- and the
  subscription-side words are gathered *inside* the kernel from the
  word-major ``(W, BS)`` VMEM tile, so the big operand is ``(BN, Wp, BS)``
  instead of ``(BN, W, BS)``;
* **one-word OR-fold signatures** (PR 9): a per-side 32-bit OR of all
  words; ``(o_sig & s_sig) != 0`` is a necessary condition for any shared
  bit, ANDed in as a register-cheap prefilter (empty slots on either side
  carry signature 0 and are therefore inert -- padding needs no separate
  validity plane).

Grid: ``(cdiv(N, bn), cdiv(S, bs))`` object x subscription tiles; output is
the (N, S) int8 match matrix. The ref twin is ``ref.sub_match_ref``; the
brute-force ground truth (set semantics, no bitmaps at all) is
``core.query.match_subscriptions_bruteforce``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sub_match_kernel(
    o_pts_ref, o_wids_ref, o_bits_ref, o_sig_ref, s_rects_ref, s_bm_ref, s_sig_ref, out_ref
):
    op = o_pts_ref[...]  # (BN, 2) f32 object points
    sr = s_rects_ref[...]  # (BS, 4) f32 subscription rects (NEVER_RECT pads)
    x = op[:, 0:1]  # (BN, 1)
    y = op[:, 1:2]
    inr = (
        (x >= sr[:, 0][None, :])
        & (x <= sr[:, 2][None, :])
        & (y >= sr[:, 1][None, :])
        & (y <= sr[:, 3][None, :])
    )  # (BN, BS) point-in-rect
    osig = o_sig_ref[...]  # (BN, 1) u32 OR-fold object signatures
    ssig = s_sig_ref[...]  # (BS, 1) u32 OR-fold subscription signatures
    sig = (osig & ssig[:, 0][None, :]) != 0  # (BN, BS) shared-bit prefilter
    wid = o_wids_ref[...].astype(jnp.int32)  # (BN, Wp) packed object word ids
    sw = s_bm_ref[...].swapaxes(0, 1)  # (W, BS) word-major subscription tile
    g = sw[wid]  # (BN, Wp, BS) VMEM gather of the objects' words
    kw = jnp.any((g & o_bits_ref[...][:, :, None]) != 0, axis=1)  # (BN, BS)
    out_ref[...] = (inr & sig & kw).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bn", "bs", "interpret"))
def sub_match(
    o_pts: jax.Array,  # (N, 2) f32 arriving object points
    o_wids: jax.Array,  # (N, Wp) int32 packed word ids (ops.pack_query_words)
    o_bits: jax.Array,  # (N, Wp) uint32 packed word values
    o_sig: jax.Array,  # (N, 1) uint32 OR-fold object signatures
    s_rects: jax.Array,  # (S, 4) f32 subscription rects
    s_bm: jax.Array,  # (S, W) uint32 subscription bitmaps
    s_sig: jax.Array,  # (S, 1) uint32 OR-fold subscription signatures
    bn: int = 8,
    bs: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(N, S) int8 match matrix. Inputs padded to tile multiples by ops.py."""
    N = o_pts.shape[0]
    S = s_rects.shape[0]
    Wp = o_wids.shape[1]
    W = s_bm.shape[1]
    bn = min(bn, N)
    bs = min(bs, S)
    grid = (pl.cdiv(N, bn), pl.cdiv(S, bs))
    return pl.pallas_call(
        _sub_match_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, Wp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, Wp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bs, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bs, W), lambda i, j: (j, 0)),
            pl.BlockSpec((bs, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, S), jnp.int8),
        interpret=interpret,
    )(o_pts, o_wids, o_bits, o_sig, s_rects, s_bm, s_sig)
