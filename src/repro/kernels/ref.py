"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests).

These are the *reference semantics*; the kernels must match them bit-exactly
for integer outputs and to float tolerance for the CDF MLP bank.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def skr_filter_ref(
    q_rects: jax.Array,  # (M, 4) f32
    q_bm: jax.Array,  # (M, W) uint32
    n_mbrs: jax.Array,  # (K, 4) f32
    n_bm: jax.Array,  # (K, W) uint32
) -> jax.Array:
    """(M, K) int8: query rect intersects node MBR AND bitmaps share a bit."""
    inter = (
        (q_rects[:, None, 0] <= n_mbrs[None, :, 2])
        & (n_mbrs[None, :, 0] <= q_rects[:, None, 2])
        & (q_rects[:, None, 1] <= n_mbrs[None, :, 3])
        & (n_mbrs[None, :, 1] <= q_rects[:, None, 3])
    )
    kw = jnp.any((q_bm[:, None, :] & n_bm[None, :, :]) != 0, axis=-1)
    return (inter & kw).astype(jnp.int8)


def frontier_filter_ref(
    q_rects: jax.Array,  # (M, 4) f32
    q_bm: jax.Array,  # (M, W) uint32
    f_mbrs: jax.Array,  # (M, F, 4) f32 -- MBRs gathered at each frontier slot
    f_bm: jax.Array,  # (M, F, W) uint32
    f_valid: jax.Array,  # (M, F) int8 (1 = slot holds a real node)
) -> jax.Array:
    """(M, F) int8: frontier slot survives (MBR intersect AND bitmap AND valid).

    Same predicate as ``skr_filter_ref`` but over per-query gathered node
    tiles instead of the full (M, K) cross product -- the sparse-frontier
    half of DESIGN.md §3.
    """
    inter = (
        (q_rects[:, None, 0] <= f_mbrs[:, :, 2])
        & (f_mbrs[:, :, 0] <= q_rects[:, None, 2])
        & (q_rects[:, None, 1] <= f_mbrs[:, :, 3])
        & (f_mbrs[:, :, 1] <= q_rects[:, None, 3])
    )
    kw = jnp.any((f_bm & q_bm[:, None, :]) != 0, axis=-1)
    return (inter & kw & (f_valid > 0)).astype(jnp.int8)


def frontier_filter_narrow_ref(
    q_rects: jax.Array,  # (M, 4) f32
    q_bits: jax.Array,  # (M, Wp) uint32 -- packed nonzero query words
    f_codes: jax.Array,  # (M, F, 4) int16 -- MBR rank codes
    f_bm: jax.Array,  # (M, F, Wp) uint32 -- packed node word planes
    f_valid: jax.Array,  # (M, F) int8
    dict_x: jax.Array,  # (Dx,) f32 sorted distinct x coords
    dict_y: jax.Array,  # (Dy,) f32 sorted distinct y coords
) -> jax.Array:
    """Narrow-plane twin of ``frontier_filter_ref``: dequantize the int16
    rank codes through the per-level coordinate dictionaries (exact -- every
    code indexes the f32 value it was built from), then apply the identical
    intersect/keyword/validity predicate on the packed word planes."""
    fc = f_codes.astype(jnp.int32)
    f_mbrs = jnp.stack(
        [dict_x[fc[:, :, 0]], dict_y[fc[:, :, 1]], dict_x[fc[:, :, 2]], dict_y[fc[:, :, 3]]],
        axis=-1,
    )
    return frontier_filter_ref(q_rects, q_bits, f_mbrs, f_bm, f_valid)


def knn_filter_ref(
    q_pts: jax.Array,  # (M, 2) f32
    q_bm: jax.Array,  # (M, W) uint32
    f_mbrs: jax.Array,  # (M, F, 4) f32 -- MBRs gathered at each frontier slot
    f_bm: jax.Array,  # (M, F, W) uint32
    f_valid: jax.Array,  # (M, F) int8 (1 = slot holds a real node)
) -> jax.Array:
    """(M, F) f32 squared point-to-MBR min-distance; +inf where the slot is
    invalid or its bitmap shares no bit with the query's (the kNN twin of
    ``frontier_filter_ref`` -- DESIGN.md §6)."""
    px = q_pts[:, 0:1]
    py = q_pts[:, 1:2]
    dx = jnp.maximum(jnp.maximum(f_mbrs[:, :, 0] - px, px - f_mbrs[:, :, 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(f_mbrs[:, :, 1] - py, py - f_mbrs[:, :, 3]), 0.0)
    d2 = dx * dx + dy * dy
    kw = jnp.any((f_bm & q_bm[:, None, :]) != 0, axis=-1)
    return jnp.where(kw & (f_valid > 0), d2, jnp.inf).astype(jnp.float32)


def knn_filter_narrow_ref(
    q_pts: jax.Array,  # (M, 2) f32
    q_bits: jax.Array,  # (M, Wp) uint32 -- packed nonzero query words
    f_codes: jax.Array,  # (M, F, 4) int16 -- MBR rank codes
    f_bm: jax.Array,  # (M, F, Wp) uint32 -- packed node word planes
    f_valid: jax.Array,  # (M, F) int8
    dict_x: jax.Array,  # (Dx,) f32
    dict_y: jax.Array,  # (Dy,) f32
) -> jax.Array:
    """Narrow-plane twin of ``knn_filter_ref`` (exact dictionary
    dequantization, then identical distance/keyword semantics)."""
    fc = f_codes.astype(jnp.int32)
    f_mbrs = jnp.stack(
        [dict_x[fc[:, :, 0]], dict_y[fc[:, :, 1]], dict_x[fc[:, :, 2]], dict_y[fc[:, :, 3]]],
        axis=-1,
    )
    return knn_filter_ref(q_pts, q_bits, f_mbrs, f_bm, f_valid)


def sub_match_ref(
    o_pts: jax.Array,  # (N, 2) f32 arriving object points
    o_bm: jax.Array,  # (N, W) uint32 full-width object bitmaps
    s_rects: jax.Array,  # (S, 4) f32 subscription rects
    s_bm: jax.Array,  # (S, W) uint32 subscription bitmaps
) -> jax.Array:
    """(N, S) int8: object point inside sub rect AND bitmaps share a bit.

    Full-width reference for the packed-word + signature ``sub_match``
    kernel (DESIGN.md §8). Padding is inert by construction: a zero bitmap
    on either side fails the keyword test, a NEVER_RECT sub contains no
    point.
    """
    x = o_pts[:, 0:1]
    y = o_pts[:, 1:2]
    inr = (
        (x >= s_rects[:, 0][None, :])
        & (x <= s_rects[:, 2][None, :])
        & (y >= s_rects[:, 1][None, :])
        & (y <= s_rects[:, 3][None, :])
    )
    kw = jnp.any((o_bm[:, None, :] & s_bm[None, :, :]) != 0, axis=-1)
    return (inr & kw).astype(jnp.int8)


def skr_verify_ref(
    q_rects: jax.Array,  # (M, 4) f32
    q_bm: jax.Array,  # (M, W) uint32
    cand_x: jax.Array,  # (M, C) f32
    cand_y: jax.Array,  # (M, C) f32
    cand_bm: jax.Array,  # (M, C, W) uint32
    cand_valid: jax.Array,  # (M, C) int8 (1 = real candidate)
) -> jax.Array:
    """(M, C) int8: candidate is in-rect, keyword-matching, and valid."""
    inr = (
        (cand_x >= q_rects[:, 0:1])
        & (cand_x <= q_rects[:, 2:3])
        & (cand_y >= q_rects[:, 1:2])
        & (cand_y <= q_rects[:, 3:4])
    )
    kw = jnp.any((cand_bm & q_bm[:, None, :]) != 0, axis=-1)
    return (inr & kw & (cand_valid > 0)).astype(jnp.int8)


def fused_verify_ref(
    q_rects: jax.Array,  # (M, 4) f32
    q_bm: jax.Array,  # (M, W) uint32
    top_leaf: jax.Array,  # (M, T) int32 selected leaf ids
    leaf_ok: jax.Array,  # (M, T) int8 (1 = slot holds a selected leaf)
    obj_x: jax.Array,  # (K, OBJ) f32 leaf object bank
    obj_y: jax.Array,  # (K, OBJ) f32
    obj_bm: jax.Array,  # (K, OBJ, W) uint32
    obj_id: jax.Array,  # (K, OBJ) int32, -1 pad
):
    """Reference semantics of the fused leaf gather+verify kernel: gather the
    selected leaves' object blocks, then apply exactly ``skr_verify_ref``.

    Returns ``(ids, kwv)``: ids (M, T*OBJ) i32 -- matching object ids in
    leaf-slot-major candidate order, ``-1`` at non-matches (identical to the
    unfused ``gather -> skr_verify`` pipeline's ordering); kwv (M, T) i32 --
    per-slot counts of keyword-matching valid candidates (the Eq.1
    ``verified`` partial sums).
    """
    M, T = top_leaf.shape
    K, OBJ = obj_x.shape
    safe = jnp.clip(top_leaf, 0, K - 1)
    cx = obj_x[safe].reshape(M, -1)  # (M, T*OBJ)
    cy = obj_y[safe].reshape(M, -1)
    cbm = obj_bm[safe].reshape(M, T * OBJ, -1)
    cid = obj_id[safe].reshape(M, -1)
    cval = (cid >= 0) & jnp.repeat(leaf_ok > 0, OBJ, axis=1)
    match = skr_verify_ref(q_rects, q_bm, cx, cy, cbm, cval.astype(jnp.int8))
    ids = jnp.where(match > 0, cid, -1)
    kw = jnp.any((cbm & q_bm[:, None, :]) != 0, axis=-1)
    kwv = jnp.sum((kw & cval).reshape(M, T, OBJ), axis=2).astype(jnp.int32)
    return ids, kwv


def skr_verify_compact_ref(
    q_rects: jax.Array,  # (M, 4) f32
    q_cbm: jax.Array,  # (M, T, Wl) uint32 leaf-local remapped query words
    q_sig: jax.Array,  # (M, T) uint32 per-(query, slot) signature
    cand_x: jax.Array,  # (M, T*OBJ) f32, leaf-slot-major
    cand_y: jax.Array,  # (M, T*OBJ) f32
    cand_cbm: jax.Array,  # (M, T*OBJ, Wl) uint32 compact candidate bitmaps
    cand_sig: jax.Array,  # (M, T*OBJ) uint32 candidate signatures
    cand_valid: jax.Array,  # (M, T*OBJ) int8
) -> jax.Array:
    """Compact-vocabulary twin of ``skr_verify_ref`` (DESIGN.md §3.5).

    The keyword test is the one-word signature prefilter AND the Wl-word
    any-reduction against the slot's remapped query words. The signature
    test is implied by the word test (an overlapping word always sets a
    shared signature bit), so the match set -- and thus the verified id
    set -- is identical to the full-width predicate.
    """
    M, T = q_sig.shape
    OBJ = cand_x.shape[1] // T
    inr = (
        (cand_x >= q_rects[:, 0:1])
        & (cand_x <= q_rects[:, 2:3])
        & (cand_y >= q_rects[:, 1:2])
        & (cand_y <= q_rects[:, 3:4])
    )
    qc = jnp.repeat(q_cbm, OBJ, axis=1)  # (M, T*OBJ, Wl)
    qs = jnp.repeat(q_sig, OBJ, axis=1)  # (M, T*OBJ)
    sig_hit = (cand_sig & qs) != 0
    kw = sig_hit & jnp.any((cand_cbm & qc) != 0, axis=-1)
    return (inr & kw & (cand_valid > 0)).astype(jnp.int8)


def fused_verify_compact_ref(
    q_rects: jax.Array,  # (M, 4) f32
    q_cbm: jax.Array,  # (M, T, Wl) uint32 leaf-local remapped query words
    q_sig: jax.Array,  # (M, T) uint32
    top_leaf: jax.Array,  # (M, T) int32 selected leaf ids
    leaf_ok: jax.Array,  # (M, T) int8
    obj_x: jax.Array,  # (K, OBJ) f32 leaf object bank
    obj_y: jax.Array,  # (K, OBJ) f32
    obj_cbm: jax.Array,  # (K, OBJ, Wl) uint32 compact bitmap slab
    obj_sig: jax.Array,  # (K, OBJ) uint32 OR-fold signatures
    obj_id: jax.Array,  # (K, OBJ) int32, -1 pad
):
    """Compact-bank twin of ``fused_verify_ref``: gather the selected
    leaves' compact blocks, then apply ``skr_verify_compact_ref``. Same
    (ids, kwv) contract as the full-width reference."""
    M, T = top_leaf.shape
    K, OBJ = obj_x.shape
    safe = jnp.clip(top_leaf, 0, K - 1)
    cx = obj_x[safe].reshape(M, -1)  # (M, T*OBJ)
    cy = obj_y[safe].reshape(M, -1)
    ccbm = obj_cbm[safe].reshape(M, T * OBJ, -1)
    csig = obj_sig[safe].reshape(M, -1)
    cid = obj_id[safe].reshape(M, -1)
    cval = (cid >= 0) & jnp.repeat(leaf_ok > 0, OBJ, axis=1)
    match = skr_verify_compact_ref(
        q_rects, q_cbm, q_sig, cx, cy, ccbm, csig, cval.astype(jnp.int8)
    )
    ids = jnp.where(match > 0, cid, -1)
    sig_hit = (csig & jnp.repeat(q_sig, OBJ, axis=1)) != 0
    kw = sig_hit & jnp.any(
        (ccbm & jnp.repeat(q_cbm, OBJ, axis=1)) != 0, axis=-1
    )
    kwv = jnp.sum((kw & cval).reshape(M, T, OBJ), axis=2).astype(jnp.int32)
    return ids, kwv


def cdf_mlp_ref(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """Evaluate a bank of B CDF MLPs at N points.

    params: w0 (B,1,H) b0 (B,H) w1 (B,H,H) b1 (B,H) w2 (B,H,H) b2 (B,H)
            w3 (B,H,1) b3 (B,1)
    x: (N,) -> out (N, B) in [0,1]
    """
    h = x[:, None, None] * params["w0"][None, :, 0, :] + params["b0"][None]  # (N,B,H)
    h = jax.nn.relu(h)
    h = jnp.einsum("nbh,bhj->nbj", h, params["w1"]) + params["b1"][None]
    h = jax.nn.relu(h)
    h = jnp.einsum("nbh,bhj->nbj", h, params["w2"]) + params["b2"][None]
    h = jax.nn.relu(h)
    out = jnp.einsum("nbh,bho->nbo", h, params["w3"]) + params["b3"][None]
    return jax.nn.sigmoid(out[..., 0])
