"""Pallas TPU kernel: fused CDF-MLP bank forward.

WISK keeps one tiny MLP (1 -> H -> H -> H -> 1, H=16) per high-frequency
keyword and evaluates *all* of them at many coordinates during split
learning. Evaluated naively, the ``(N, B, H)`` hidden activations of the
bank round-trip through HBM between the four layers; this kernel keeps a
(point-tile x model-tile) working set in VMEM and applies all four layers +
activations in one pass, writing only the final ``(N, B)`` CDF plane.

Block sizing: BN x BB x H floats x ~2 live layers; with BN=256, BB=64,
H=16 that's ~2 MB of VMEM -- comfortably under the ~16 MB budget while the
batched (BB,H,H) matmuls are MXU-shaped.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdf_mlp_kernel(x_ref, w0_ref, b0_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, out_ref):
    x = x_ref[...]  # (BN, 1)
    w0 = w0_ref[...]  # (BB, 1, H)
    h = x[:, None, :] * w0[None, :, 0, :] + b0_ref[...][None]  # (BN, BB, H)
    h = jnp.maximum(h, 0.0)
    # batched matmuls over the model dim (dimension_numbers: contract H, batch BB)
    h = jax.lax.dot_general(
        h.swapaxes(0, 1), w1_ref[...], (((2,), (1,)), ((0,), (0,)))
    )  # (BB, BN, H)
    h = jnp.maximum(h + b1_ref[...][:, None, :], 0.0)
    h = jax.lax.dot_general(h, w2_ref[...], (((2,), (1,)), ((0,), (0,))))
    h = jnp.maximum(h + b2_ref[...][:, None, :], 0.0)
    o = jax.lax.dot_general(h, w3_ref[...], (((2,), (1,)), ((0,), (0,))))  # (BB, BN, 1)
    o = o[..., 0] + b3_ref[...][:, 0][:, None]
    out_ref[...] = jax.nn.sigmoid(o).swapaxes(0, 1)  # (BN, BB)


@functools.partial(jax.jit, static_argnames=("bn", "bb", "interpret"))
def cdf_mlp_bank(
    params: Dict[str, jax.Array],
    x: jax.Array,  # (N,)
    bn: int = 256,
    bb: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Evaluate B CDF MLPs at N points -> (N, B)."""
    N = x.shape[0]
    B, _, H = params["w0"].shape
    bn = min(bn, N)
    bb = min(bb, B)
    grid = (pl.cdiv(N, bn), pl.cdiv(B, bb))
    return pl.pallas_call(
        _cdf_mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1, H), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bb, H), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, H, H), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bb, H), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, H, H), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bb, H), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, H, 1), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, B), jnp.float32),
        interpret=interpret,
    )(
        x[:, None],
        params["w0"],
        params["b0"],
        params["w1"],
        params["b1"],
        params["w2"],
        params["b2"],
        params["w3"],
        params["b3"],
    )
