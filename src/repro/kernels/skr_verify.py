"""Pallas TPU kernel: candidate verification (the Eq.1 ``w2`` stage).

After filtering, each query holds a capacity-padded candidate list (gathered
from the leaf inverted files). The kernel verifies in-rectangle membership +
keyword bitmap overlap + validity for a (query-tile x candidate-tile) block
entirely in VMEM. The bitmap plane ``(BM, BC, W)`` is the big operand; the
word axis collapses in one packed ``any``-reduction (popcount-style) so only
``(BM, BC)`` registers accumulate. Candidates re-check in exact f32 here --
this is the stage that guarantees the narrow-plane descent (frontier.py)
cannot change reported ids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _verify_kernel(q_rects_ref, q_bm_ref, cx_ref, cy_ref, cbm_ref, cv_ref, out_ref):
    qr = q_rects_ref[...]  # (BM, 4)
    cx = cx_ref[...]  # (BM, BC)
    cy = cy_ref[...]
    inr = (
        (cx >= qr[:, 0:1])
        & (cx <= qr[:, 2:3])
        & (cy >= qr[:, 1:2])
        & (cy <= qr[:, 3:4])
    )
    qb = q_bm_ref[...]  # (BM, W)
    cb = cbm_ref[...]  # (BM, BC, W)
    # packed word-plane AND + single any-reduction per tile (popcount-style)
    kw = jnp.any((cb & qb[:, None, :]) != 0, axis=-1)  # (BM, BC)
    out_ref[...] = (inr & kw & (cv_ref[...] > 0)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bc", "interpret"))
def skr_verify(
    q_rects: jax.Array,  # (M, 4)
    q_bm: jax.Array,  # (M, W)
    cand_x: jax.Array,  # (M, C)
    cand_y: jax.Array,  # (M, C)
    cand_bm: jax.Array,  # (M, C, W)
    cand_valid: jax.Array,  # (M, C) int8
    bm: int = 8,
    bc: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, C = cand_x.shape
    W = q_bm.shape[1]
    bm = min(bm, M)
    bc = min(bc, C)
    grid = (pl.cdiv(M, bm), pl.cdiv(C, bc))
    return pl.pallas_call(
        _verify_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bc, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, C), jnp.int8),
        interpret=interpret,
    )(q_rects, q_bm, cand_x, cand_y, cand_bm, cand_valid)


def _verify_compact_kernel(
    q_rects_ref, q_cbm_ref, q_sig_ref, cx_ref, cy_ref,
    cbm_ref, csig_ref, cv_ref, out_ref,
):
    qr = q_rects_ref[...]  # (BM, 4)
    cx = cx_ref[...]  # (BM, OBJ)
    cy = cy_ref[...]
    inr = (
        (cx >= qr[:, 0:1])
        & (cx <= qr[:, 2:3])
        & (cy >= qr[:, 1:2])
        & (cy <= qr[:, 3:4])
    )
    qc = q_cbm_ref[...]  # (BM, 1, Wl) -- this slot's remapped query words
    qs = q_sig_ref[...]  # (BM, 1)
    # one-word signature prefilter (implied by the word test -- kw unchanged)
    sig_hit = (csig_ref[...] & qs) != 0  # (BM, OBJ)
    kw = sig_hit & jnp.any((cbm_ref[...] & qc) != 0, axis=-1)  # (BM, OBJ)
    out_ref[...] = (inr & kw & (cv_ref[...] > 0)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def skr_verify_compact(
    q_rects: jax.Array,  # (M, 4)
    q_cbm: jax.Array,  # (M, T, Wl) leaf-local remapped query words
    q_sig: jax.Array,  # (M, T) per-(query, slot) OR-fold signature
    cand_x: jax.Array,  # (M, T*OBJ) leaf-slot-major gathered candidates
    cand_y: jax.Array,  # (M, T*OBJ)
    cand_cbm: jax.Array,  # (M, T*OBJ, Wl) compact candidate bitmaps
    cand_sig: jax.Array,  # (M, T*OBJ) candidate signatures
    cand_valid: jax.Array,  # (M, T*OBJ) int8
    bm: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Compact-vocabulary twin of ``skr_verify`` (DESIGN.md §3.5).

    Candidates arrive leaf-slot-major (T slots of OBJ objects each, the
    fused kernels' ordering) because the query-side words differ PER SLOT:
    each selected leaf has its own vocabulary, so the candidate grid tiles
    over slots -- block ``(BM, OBJ)`` at slot ``j`` pairs with query words
    ``q_cbm[:, j]`` -- instead of skr_verify's flat candidate axis."""
    M, T = q_sig.shape
    Wl = q_cbm.shape[2]
    OBJ = cand_x.shape[1] // T
    bm = min(bm, M)
    grid = (pl.cdiv(M, bm), T)
    return pl.pallas_call(
        _verify_compact_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1, Wl), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
            pl.BlockSpec((bm, OBJ), lambda i, j: (i, j)),
            pl.BlockSpec((bm, OBJ), lambda i, j: (i, j)),
            pl.BlockSpec((bm, OBJ, Wl), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, OBJ), lambda i, j: (i, j)),
            pl.BlockSpec((bm, OBJ), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, OBJ), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, T * OBJ), jnp.int8),
        interpret=interpret,
    )(q_rects, q_cbm, q_sig, cand_x, cand_y, cand_cbm, cand_sig, cand_valid)
