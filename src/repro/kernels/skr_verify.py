"""Pallas TPU kernel: candidate verification (the Eq.1 ``w2`` stage).

After filtering, each query holds a capacity-padded candidate list (gathered
from the leaf inverted files). The kernel verifies in-rectangle membership +
keyword bitmap overlap + validity for a (query-tile x candidate-tile) block
entirely in VMEM. The bitmap plane ``(BM, BC, W)`` is the big operand; the
word axis collapses in one packed ``any``-reduction (popcount-style) so only
``(BM, BC)`` registers accumulate. Candidates re-check in exact f32 here --
this is the stage that guarantees the narrow-plane descent (frontier.py)
cannot change reported ids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _verify_kernel(q_rects_ref, q_bm_ref, cx_ref, cy_ref, cbm_ref, cv_ref, out_ref):
    qr = q_rects_ref[...]  # (BM, 4)
    cx = cx_ref[...]  # (BM, BC)
    cy = cy_ref[...]
    inr = (
        (cx >= qr[:, 0:1])
        & (cx <= qr[:, 2:3])
        & (cy >= qr[:, 1:2])
        & (cy <= qr[:, 3:4])
    )
    qb = q_bm_ref[...]  # (BM, W)
    cb = cbm_ref[...]  # (BM, BC, W)
    # packed word-plane AND + single any-reduction per tile (popcount-style)
    kw = jnp.any((cb & qb[:, None, :]) != 0, axis=-1)  # (BM, BC)
    out_ref[...] = (inr & kw & (cv_ref[...] > 0)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bc", "interpret"))
def skr_verify(
    q_rects: jax.Array,  # (M, 4)
    q_bm: jax.Array,  # (M, W)
    cand_x: jax.Array,  # (M, C)
    cand_y: jax.Array,  # (M, C)
    cand_bm: jax.Array,  # (M, C, W)
    cand_valid: jax.Array,  # (M, C) int8
    bm: int = 8,
    bc: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, C = cand_x.shape
    W = q_bm.shape[1]
    bm = min(bm, M)
    bc = min(bc, C)
    grid = (pl.cdiv(M, bm), pl.cdiv(C, bc))
    return pl.pallas_call(
        _verify_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bc, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, C), jnp.int8),
        interpret=interpret,
    )(q_rects, q_bm, cand_x, cand_y, cand_bm, cand_valid)
