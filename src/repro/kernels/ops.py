"""Jitted public wrappers around the Pallas kernels.

The wrappers pad inputs to tile multiples, pick ``interpret=True`` on CPU
(the container target; kernels execute their Python bodies for validation)
and compiled Mosaic on TPU, and slice outputs back. They are the only entry
points the rest of the framework uses.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .cdf_mlp import cdf_mlp_bank
from .frontier import frontier_filter
from .fused_verify import fused_verify
from .knn_filter import knn_filter
from .skr_filter import skr_filter
from .skr_verify import skr_verify
from . import ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# sentinel rectangle that intersects nothing under the closed-rect predicate
# (xlo > xhi): used for node/query padding here and in serve.plan
NEVER_RECT = (2.0, 2.0, -2.0, -2.0)


def padded_tile_len(n: int, tile: int = 128) -> int:
    """Slots a kernel actually touches for a length-``n`` operand dimension:
    the wrappers below block by ``min(tile, n)`` and pad up to a multiple of
    it. Exposed so cost counters can report padded (true) device work."""
    t = min(tile, max(int(n), 1))
    return -(-int(n) // t) * t


def _pad_dim(a: jax.Array, axis: int, mult: int, fill=0) -> jax.Array:
    size = a.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(a, pads, constant_values=fill)


def filter_pairs(
    q_rects, q_bm, n_mbrs, n_bm, bm: int = 128, bk: int = 128, interpret: Optional[bool] = None
) -> jax.Array:
    """(M, K) int8 relevance via the Pallas filter kernel (padded + sliced)."""
    if interpret is None:
        interpret = _on_cpu()
    M, K = q_rects.shape[0], n_mbrs.shape[0]
    bm_ = min(bm, max(M, 1))
    bk_ = min(bk, max(K, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    # pad node MBRs with never-intersecting rects
    nm = jnp.asarray(n_mbrs, jnp.float32)
    pad_k = -(-K // bk_) * bk_ - K
    if pad_k:
        nm = jnp.concatenate([nm, jnp.tile(jnp.array([NEVER_RECT], jnp.float32), (pad_k, 1))], 0)
    nb = _pad_dim(jnp.asarray(n_bm, jnp.uint32), 0, bk_)
    out = skr_filter(qr, qb, nm, nb, bm=bm_, bk=bk_, interpret=interpret)
    return out[:M, :K]


def filter_frontier(
    q_rects, q_bm, f_mbrs, f_bm, f_valid, bm: int = 8, bf: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, F) int8 frontier-survivor matrix via the Pallas frontier kernel."""
    if interpret is None:
        interpret = _on_cpu()
    M, F = f_valid.shape
    bm_ = min(bm, max(M, 1))
    bf_ = min(bf, max(F, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    fm = _pad_dim(_pad_dim(jnp.asarray(f_mbrs, jnp.float32), 0, bm_), 1, bf_)
    fb = _pad_dim(_pad_dim(jnp.asarray(f_bm, jnp.uint32), 0, bm_), 1, bf_)
    fv = _pad_dim(_pad_dim(jnp.asarray(f_valid, jnp.int8), 0, bm_), 1, bf_)
    out = frontier_filter(qr, qb, fm, fb, fv, bm=bm_, bf=bf_, interpret=interpret)
    return out[:M, :F]


def knn_frontier_dist(
    q_pts, q_bm, f_mbrs, f_bm, f_valid, bm: int = 8, bf: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, F) f32 squared frontier MBR min-distances via the Pallas kNN kernel
    (+inf at invalid / keyword-miss slots, including the padding added here)."""
    if interpret is None:
        interpret = _on_cpu()
    M, F = f_valid.shape
    bm_ = min(bm, max(M, 1))
    bf_ = min(bf, max(F, 1))
    qp = _pad_dim(jnp.asarray(q_pts, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    fm = _pad_dim(_pad_dim(jnp.asarray(f_mbrs, jnp.float32), 0, bm_), 1, bf_)
    fb = _pad_dim(_pad_dim(jnp.asarray(f_bm, jnp.uint32), 0, bm_), 1, bf_)
    fv = _pad_dim(_pad_dim(jnp.asarray(f_valid, jnp.int8), 0, bm_), 1, bf_)
    out = knn_filter(qp, qb, fm, fb, fv, bm=bm_, bf=bf_, interpret=interpret)
    return out[:M, :F]


def verify_candidates(
    q_rects, q_bm, cand_x, cand_y, cand_bm, cand_valid, bm: int = 8, bc: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, C) int8 verified-candidate matrix via the Pallas verify kernel."""
    if interpret is None:
        interpret = _on_cpu()
    M, C = cand_x.shape
    bm_ = min(bm, max(M, 1))
    bc_ = min(bc, max(C, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    cx = _pad_dim(_pad_dim(jnp.asarray(cand_x, jnp.float32), 0, bm_), 1, bc_)
    cy = _pad_dim(_pad_dim(jnp.asarray(cand_y, jnp.float32), 0, bm_), 1, bc_)
    cb = _pad_dim(_pad_dim(jnp.asarray(cand_bm, jnp.uint32), 0, bm_), 1, bc_)
    cv = _pad_dim(_pad_dim(jnp.asarray(cand_valid, jnp.int8), 0, bm_), 1, bc_)
    out = skr_verify(qr, qb, cx, cy, cb, cv, bm=bm_, bc=bc_, interpret=interpret)
    return out[:M, :C]


def fused_gather_verify(
    q_rects, q_bm, top_leaf, leaf_ok, obj_x, obj_y, obj_bm, obj_id,
    bm: int = 8, interpret: Optional[bool] = None,
):
    """Fused leaf gather + verify via the Pallas fused kernel (DESIGN.md §3.5).

    Consumes the frontier descent's selected leaves (``top_leaf``/``leaf_ok``)
    and the snapshot's leaf object bank; the per-query candidate gather
    happens inside the kernel (VMEM), so the ``(M, T*OBJ, W)`` gathered
    bitmap plane never materializes in HBM. Returns ``(ids, kwv)``:
    ids (M, T*OBJ) i32 matching object ids (``-1`` fill, leaf-slot-major --
    bit-identical to the unfused gather -> ``verify_candidates`` ordering)
    and kwv (M, T) i32 per-slot Eq.1 ``verified`` partial counts.
    """
    if interpret is None:
        interpret = _on_cpu()
    M = q_rects.shape[0]
    bm_ = min(bm, max(M, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    tl = _pad_dim(jnp.asarray(top_leaf, jnp.int32), 0, bm_)
    ok = _pad_dim(jnp.asarray(leaf_ok, jnp.int8), 0, bm_)
    ids, kwv = fused_verify(
        qr, qb, tl, ok,
        jnp.asarray(obj_x, jnp.float32), jnp.asarray(obj_y, jnp.float32),
        jnp.asarray(obj_bm, jnp.uint32), jnp.asarray(obj_id, jnp.int32),
        bm=bm_, interpret=interpret,
    )
    return ids[:M], kwv[:M]


def cdf_bank_forward(
    params: Dict[str, jax.Array], x: jax.Array, bn: int = 256, bb: int = 64,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(N, B) CDF values for the whole MLP bank at points x."""
    if interpret is None:
        interpret = _on_cpu()
    N = x.shape[0]
    B = params["w0"].shape[0]
    bn_ = min(bn, max(N, 1))
    bb_ = min(bb, max(B, 1))
    xp = _pad_dim(jnp.asarray(x, jnp.float32), 0, bn_)
    pp = {k: _pad_dim(v, 0, bb_) for k, v in params.items()}
    out = cdf_mlp_bank(pp, xp, bn=bn_, bb=bb_, interpret=interpret)
    return out[:N, :B]


__all__ = [
    "filter_pairs",
    "filter_frontier",
    "fused_gather_verify",
    "knn_frontier_dist",
    "verify_candidates",
    "cdf_bank_forward",
    "ref",
]
