"""Jitted public wrappers around the Pallas kernels.

The wrappers pad inputs to tile multiples, pick ``interpret=True`` on CPU
(the container target; kernels execute their Python bodies for validation)
and compiled Mosaic on TPU, and slice outputs back. They are the only entry
points the rest of the framework uses.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .cdf_mlp import cdf_mlp_bank
from .frontier import frontier_filter, frontier_filter_narrow
from .fused_verify import (
    fused_verify,
    fused_verify_compact,
    fused_verify_prefetch,
    fused_verify_prefetch_compact,
)
from .knn_filter import knn_filter, knn_filter_narrow
from .skr_filter import skr_filter
from .skr_verify import skr_verify, skr_verify_compact
from .sub_match import sub_match
from . import ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# sentinel rectangle that intersects nothing under the closed-rect predicate
# (xlo > xhi): used for node/query padding here and in serve.plan
NEVER_RECT = (2.0, 2.0, -2.0, -2.0)

# Leaf-bank byte budget above which the engine routes fused verification to
# the scalar-prefetched kernel instead of mapping the bank whole into VMEM.
# Half of a ~16 MiB per-core VMEM: leaves headroom for the query tiles, the
# per-slot bitmap slab, and the output blocks. serve.engine._verify_leaves
# applies the rule; fused_gather_verify(variant=...) overrides it.
FUSED_VMEM_BANK_BYTES = 8 * 1024 * 1024


def leaf_bank_bytes(n_leaves: int, obj_per_leaf: int, n_words: int) -> int:
    """Bytes of the fused-verify leaf bank (obj_x/y/id f32+i32 rows plus the
    (K, OBJ, W) u32 bitmap slab) -- the quantity the engine compares against
    ``FUSED_VMEM_BANK_BYTES`` to pick the fused variant."""
    return int(n_leaves) * int(obj_per_leaf) * (3 * 4 + int(n_words) * 4)


def compact_leaf_bank_bytes(
    n_leaves: int, obj_per_leaf: int, n_compact_words: int
) -> int:
    """Bytes of the COMPACT fused-verify leaf bank (DESIGN.md §3.5): the
    obj_x/y/id rows, the one-word u32 signature plane, and the (K, OBJ, Wl)
    leaf-local bitmap slab. This -- not ``leaf_bank_bytes`` -- is what the
    engine prices against ``FUSED_VMEM_BANK_BYTES`` when the snapshot
    carries a compact bank, so far larger indexes stay on the VMEM
    variant."""
    return int(n_leaves) * int(obj_per_leaf) * (
        3 * 4 + 4 + int(n_compact_words) * 4
    )


def remap_query_words(q_bm, leaf_terms, leaves):
    """Remap query bitmaps into the selected leaves' local vocabularies.

    For each (query, slot) pair, gathers the slot's leaf dictionary
    (``leaf_terms[leaf]``: global term id per leaf-local bit, ``-1`` pad;
    serve/snapshot.py:``encode_leaf_vocab``), pulls each dictionary entry's
    bit out of the query's global bitmap, and re-packs them into ``Wl`` u32
    words over leaf-local bit positions. A query term absent from the
    leaf's dictionary contributes no local bit -- exactly the ISSUE's kill
    semantics: no object in that leaf carries the term, so dropping it
    cannot change any match (objects' term sets are subsets of the leaf
    dictionary). Dirty/negative leaf ids are clamp-gathered like the fused
    kernels; their slots are masked downstream by ``leaf_ok``.

    Returns ``(q_cbm (M, T, Wl) u32, q_sig (M, T) u32)`` with ``q_sig`` the
    OR-fold of the remapped words -- a per-(query, slot) kill flag
    (``q_sig == 0`` means nothing in the leaf can match) and the query half
    of the kernels' one-word signature prefilter. Traced (runs inside the
    jitted descent after leaf selection).
    """
    q = jnp.asarray(q_bm, jnp.uint32)
    M, W = q.shape
    K, L = leaf_terms.shape
    Wl = L // 32
    safe = jnp.clip(jnp.asarray(leaves, jnp.int32), 0, K - 1)
    T = safe.shape[1]
    terms = leaf_terms[safe]  # (M, T, L) global term per local bit
    tpos = jnp.clip(terms, 0, 32 * W - 1)
    widx = (tpos >> 5).reshape(M, T * L)
    qw = jnp.take_along_axis(q, widx, axis=1).reshape(M, T, L)
    bits = (qw >> (tpos & 31).astype(jnp.uint32)) & jnp.uint32(1)
    bits = jnp.where(terms >= 0, bits, jnp.uint32(0))  # pad bits are inert
    shifts = jnp.arange(32, dtype=jnp.uint32)
    # distinct powers of two per lane: the sum IS the bitwise OR (exact)
    q_cbm = jnp.sum(
        bits.reshape(M, T, Wl, 32) << shifts, axis=-1, dtype=jnp.uint32
    )
    q_sig = q_cbm[..., 0]
    for w in range(1, Wl):  # static fold; Wl is a small power of two
        q_sig = q_sig | q_cbm[..., w]
    return q_cbm, q_sig


def pack_query_words(q_bm, min_bucket: int = 4):
    """Pack each query bitmap down to its nonzero words (host-side).

    Returns ``(wids, bits)``: word indices (M, Wp) int32 and the word values
    (M, Wp) uint32, with Wp the power-of-two bucket of the batch's max
    nonzero-word count (capped at W). Slots past a query's own count index
    one of its zero words, so their value is 0 and they can never
    contribute a bit -- packing is exact: ``OR_w (bm & q) == OR_p (bits &
    gathered)``. The engine gathers only the ``wids`` word planes per
    frontier slot, shrinking the descent's biggest operand from (M, F, W)
    to (M, F, Wp).

    Host-side on purpose: Wp must be a *static* shape, and the batch's
    bitmaps are concrete before any jitted descent step runs (the sharded
    path packs before ``shard_map`` so every shard agrees on Wp).
    """
    q = np.asarray(q_bm, dtype=np.uint32)
    M, W = q.shape
    nnz = int((q != 0).sum(axis=1).max()) if M else 0
    wp = max(int(nnz), 1)
    # power-of-two bucket (>= min_bucket) to bound distinct jit shapes, as
    # everywhere else in the width discipline; never wider than W itself
    b = max(min_bucket, 1)
    while b < wp:
        b *= 2
    wp = min(b, W)
    # stable argsort of the "is zero" flag keeps nonzero words first, in
    # original word order; zero-word slots carry value 0 and are inert
    order = np.argsort(q == 0, axis=1, kind="stable")
    wids = order[:, :wp].astype(np.int32)
    bits = np.take_along_axis(q, wids, axis=1).astype(np.uint32)
    return jnp.asarray(wids), jnp.asarray(bits)


def padded_tile_len(n: int, tile: int = 128) -> int:
    """Slots a kernel actually touches for a length-``n`` operand dimension:
    the wrappers below block by ``min(tile, n)`` and pad up to a multiple of
    it. Exposed so cost counters can report padded (true) device work."""
    t = min(tile, max(int(n), 1))
    return -(-int(n) // t) * t


def _pad_dim(a: jax.Array, axis: int, mult: int, fill=0) -> jax.Array:
    size = a.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(a, pads, constant_values=fill)


def filter_pairs(
    q_rects, q_bm, n_mbrs, n_bm, bm: int = 128, bk: int = 128, interpret: Optional[bool] = None
) -> jax.Array:
    """(M, K) int8 relevance via the Pallas filter kernel (padded + sliced)."""
    if interpret is None:
        interpret = _on_cpu()
    M, K = q_rects.shape[0], n_mbrs.shape[0]
    bm_ = min(bm, max(M, 1))
    bk_ = min(bk, max(K, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    # pad node MBRs with never-intersecting rects
    nm = jnp.asarray(n_mbrs, jnp.float32)
    pad_k = -(-K // bk_) * bk_ - K
    if pad_k:
        nm = jnp.concatenate([nm, jnp.tile(jnp.array([NEVER_RECT], jnp.float32), (pad_k, 1))], 0)
    nb = _pad_dim(jnp.asarray(n_bm, jnp.uint32), 0, bk_)
    out = skr_filter(qr, qb, nm, nb, bm=bm_, bk=bk_, interpret=interpret)
    return out[:M, :K]


def match_subscriptions(
    obj_pts, obj_bm, sub_rects, sub_bm, sub_sig=None,
    bn: int = 8, bs: int = 128, interpret: Optional[bool] = None,
) -> jax.Array:
    """(N, S) int8 continuous-filter match matrix via the Pallas sub_match
    kernel (padded + sliced; DESIGN.md §8).

    ``obj_pts``/``obj_bm`` are the arriving objects (points + full-width
    bitmaps -- packed to their nonzero words here, the same host-side
    ``pack_query_words`` the descent uses); ``sub_rects``/``sub_bm`` are the
    compiled subscription block. ``sub_sig`` is the per-subscription OR-fold
    signature, recomputed when not supplied. Object padding carries a zero
    bitmap and subscription padding a zero bitmap + NEVER_RECT, so padded
    slots can never match.
    """
    if interpret is None:
        interpret = _on_cpu()
    obj_pts = np.asarray(obj_pts, np.float32).reshape(-1, 2)
    obj_bm = np.asarray(obj_bm, np.uint32)
    N, S = obj_pts.shape[0], np.asarray(sub_rects).shape[0]
    if N == 0 or S == 0:
        return jnp.zeros((N, S), jnp.int8)
    wids, bits = pack_query_words(obj_bm)
    o_sig = np.bitwise_or.reduce(obj_bm, axis=1).reshape(-1, 1)
    if sub_sig is None:
        sub_sig = np.bitwise_or.reduce(np.asarray(sub_bm, np.uint32), axis=1)
    s_sig = np.asarray(sub_sig, np.uint32).reshape(-1, 1)
    bn_ = min(bn, max(N, 1))
    bs_ = min(bs, max(S, 1))
    op = _pad_dim(jnp.asarray(obj_pts), 0, bn_)
    ow = _pad_dim(wids, 0, bn_)
    ob = _pad_dim(bits, 0, bn_)
    osg = _pad_dim(jnp.asarray(o_sig, jnp.uint32), 0, bn_)
    sr = jnp.asarray(sub_rects, jnp.float32)
    pad_s = -(-S // bs_) * bs_ - S
    if pad_s:
        sr = jnp.concatenate(
            [sr, jnp.tile(jnp.array([NEVER_RECT], jnp.float32), (pad_s, 1))], 0
        )
    sb = _pad_dim(jnp.asarray(sub_bm, jnp.uint32), 0, bs_)
    ssg = _pad_dim(jnp.asarray(s_sig, jnp.uint32), 0, bs_)
    out = sub_match(op, ow, ob, osg, sr, sb, ssg, bn=bn_, bs=bs_, interpret=interpret)
    return out[:N, :S]


def filter_frontier(
    q_rects, q_bm, f_mbrs, f_bm, f_valid, bm: int = 8, bf: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, F) int8 frontier-survivor matrix via the Pallas frontier kernel."""
    if interpret is None:
        interpret = _on_cpu()
    M, F = f_valid.shape
    bm_ = min(bm, max(M, 1))
    bf_ = min(bf, max(F, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    fm = _pad_dim(_pad_dim(jnp.asarray(f_mbrs, jnp.float32), 0, bm_), 1, bf_)
    fb = _pad_dim(_pad_dim(jnp.asarray(f_bm, jnp.uint32), 0, bm_), 1, bf_)
    fv = _pad_dim(_pad_dim(jnp.asarray(f_valid, jnp.int8), 0, bm_), 1, bf_)
    out = frontier_filter(qr, qb, fm, fb, fv, bm=bm_, bf=bf_, interpret=interpret)
    return out[:M, :F]


def filter_frontier_narrow(
    q_rects, q_bits, f_codes, f_bm, f_valid, dict_x, dict_y,
    bm: int = 8, bf: int = 128, interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, F) int8 frontier-survivor matrix on the bandwidth-lean planes:
    int16 MBR rank codes (dequantized in-kernel through the per-level
    coordinate dictionaries -- exact) and packed nonzero word planes from
    ``pack_query_words``. Bit-identical survivors to ``filter_frontier`` on
    the corresponding f32/full-width operands."""
    if interpret is None:
        interpret = _on_cpu()
    M, F = f_valid.shape
    bm_ = min(bm, max(M, 1))
    bf_ = min(bf, max(F, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bits, jnp.uint32), 0, bm_)
    fc = _pad_dim(_pad_dim(jnp.asarray(f_codes, jnp.int16), 0, bm_), 1, bf_)
    fb = _pad_dim(_pad_dim(jnp.asarray(f_bm, jnp.uint32), 0, bm_), 1, bf_)
    fv = _pad_dim(_pad_dim(jnp.asarray(f_valid, jnp.int8), 0, bm_), 1, bf_)
    out = frontier_filter_narrow(
        qr, qb, fc, fb, fv,
        jnp.asarray(dict_x, jnp.float32), jnp.asarray(dict_y, jnp.float32),
        bm=bm_, bf=bf_, interpret=interpret,
    )
    return out[:M, :F]


def knn_frontier_dist(
    q_pts, q_bm, f_mbrs, f_bm, f_valid, bm: int = 8, bf: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, F) f32 squared frontier MBR min-distances via the Pallas kNN kernel
    (+inf at invalid / keyword-miss slots, including the padding added here)."""
    if interpret is None:
        interpret = _on_cpu()
    M, F = f_valid.shape
    bm_ = min(bm, max(M, 1))
    bf_ = min(bf, max(F, 1))
    qp = _pad_dim(jnp.asarray(q_pts, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    fm = _pad_dim(_pad_dim(jnp.asarray(f_mbrs, jnp.float32), 0, bm_), 1, bf_)
    fb = _pad_dim(_pad_dim(jnp.asarray(f_bm, jnp.uint32), 0, bm_), 1, bf_)
    fv = _pad_dim(_pad_dim(jnp.asarray(f_valid, jnp.int8), 0, bm_), 1, bf_)
    out = knn_filter(qp, qb, fm, fb, fv, bm=bm_, bf=bf_, interpret=interpret)
    return out[:M, :F]


def knn_frontier_dist_narrow(
    q_pts, q_bits, f_codes, f_bm, f_valid, dict_x, dict_y,
    bm: int = 8, bf: int = 128, interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, F) f32 squared frontier MBR min-distances on the bandwidth-lean
    planes (int16 rank codes + packed word planes); bit-identical distances
    to ``knn_frontier_dist`` on the corresponding f32/full-width operands."""
    if interpret is None:
        interpret = _on_cpu()
    M, F = f_valid.shape
    bm_ = min(bm, max(M, 1))
    bf_ = min(bf, max(F, 1))
    qp = _pad_dim(jnp.asarray(q_pts, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bits, jnp.uint32), 0, bm_)
    fc = _pad_dim(_pad_dim(jnp.asarray(f_codes, jnp.int16), 0, bm_), 1, bf_)
    fb = _pad_dim(_pad_dim(jnp.asarray(f_bm, jnp.uint32), 0, bm_), 1, bf_)
    fv = _pad_dim(_pad_dim(jnp.asarray(f_valid, jnp.int8), 0, bm_), 1, bf_)
    out = knn_filter_narrow(
        qp, qb, fc, fb, fv,
        jnp.asarray(dict_x, jnp.float32), jnp.asarray(dict_y, jnp.float32),
        bm=bm_, bf=bf_, interpret=interpret,
    )
    return out[:M, :F]


def verify_candidates(
    q_rects, q_bm, cand_x, cand_y, cand_bm, cand_valid, bm: int = 8, bc: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, C) int8 verified-candidate matrix via the Pallas verify kernel."""
    if interpret is None:
        interpret = _on_cpu()
    M, C = cand_x.shape
    bm_ = min(bm, max(M, 1))
    bc_ = min(bc, max(C, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    cx = _pad_dim(_pad_dim(jnp.asarray(cand_x, jnp.float32), 0, bm_), 1, bc_)
    cy = _pad_dim(_pad_dim(jnp.asarray(cand_y, jnp.float32), 0, bm_), 1, bc_)
    cb = _pad_dim(_pad_dim(jnp.asarray(cand_bm, jnp.uint32), 0, bm_), 1, bc_)
    cv = _pad_dim(_pad_dim(jnp.asarray(cand_valid, jnp.int8), 0, bm_), 1, bc_)
    out = skr_verify(qr, qb, cx, cy, cb, cv, bm=bm_, bc=bc_, interpret=interpret)
    return out[:M, :C]


def verify_candidates_compact(
    q_rects, q_cbm, q_sig, cand_x, cand_y, cand_cbm, cand_sig, cand_valid,
    bm: int = 8, interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, T*OBJ) int8 verified-candidate matrix on the compact leaf
    vocabulary (DESIGN.md §3.5). Candidates must be leaf-slot-major (T
    slots of OBJ objects) because the remapped query words differ per slot;
    the slot axis is the kernel grid, so no candidate-axis padding is
    needed."""
    if interpret is None:
        interpret = _on_cpu()
    M = cand_x.shape[0]
    bm_ = min(bm, max(M, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qc = _pad_dim(jnp.asarray(q_cbm, jnp.uint32), 0, bm_)
    qs = _pad_dim(jnp.asarray(q_sig, jnp.uint32), 0, bm_)
    cx = _pad_dim(jnp.asarray(cand_x, jnp.float32), 0, bm_)
    cy = _pad_dim(jnp.asarray(cand_y, jnp.float32), 0, bm_)
    cb = _pad_dim(jnp.asarray(cand_cbm, jnp.uint32), 0, bm_)
    cs = _pad_dim(jnp.asarray(cand_sig, jnp.uint32), 0, bm_)
    cv = _pad_dim(jnp.asarray(cand_valid, jnp.int8), 0, bm_)
    out = skr_verify_compact(
        qr, qc, qs, cx, cy, cb, cs, cv, bm=bm_, interpret=interpret
    )
    return out[:M]


def fused_gather_verify(
    q_rects, q_bm, top_leaf, leaf_ok, obj_x, obj_y, obj_bm, obj_id,
    bm: int = 8, interpret: Optional[bool] = None, variant: str = "auto",
):
    """Fused leaf gather + verify via the Pallas fused kernels (DESIGN.md §3.5).

    Consumes the frontier descent's selected leaves (``top_leaf``/``leaf_ok``)
    and the snapshot's leaf object bank; the per-query candidate gather
    happens inside the kernel, so the ``(M, T*OBJ, W)`` gathered bitmap
    plane never materializes in HBM. Returns ``(ids, kwv)``:
    ids (M, T*OBJ) i32 matching object ids (``-1`` fill, leaf-slot-major --
    bit-identical to the unfused gather -> ``verify_candidates`` ordering)
    and kwv (M, T) i32 per-slot Eq.1 ``verified`` partial counts.

    ``variant`` picks the kernel: ``"vmem"`` maps the bank whole into VMEM
    (static-T in-VMEM gathers), ``"prefetch"`` uses the scalar-prefetched
    (M, T) leaf-id grid that DMAs one leaf row per (query, slot) block and
    keeps fusion for banks beyond VMEM, ``"auto"`` compares the bank bytes
    against ``FUSED_VMEM_BANK_BYTES``. Both variants are elementwise
    identical (tests/test_kernels.py).
    """
    if interpret is None:
        interpret = _on_cpu()
    if variant not in ("auto", "vmem", "prefetch"):
        raise ValueError(f"unknown fused-verify variant: {variant!r}")
    if variant == "auto":
        K, OBJ = obj_x.shape
        W = q_bm.shape[1]
        big = leaf_bank_bytes(K, OBJ, W) > FUSED_VMEM_BANK_BYTES
        variant = "prefetch" if big else "vmem"
    M = q_rects.shape[0]
    bm_ = min(bm, max(M, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qb = _pad_dim(jnp.asarray(q_bm, jnp.uint32), 0, bm_)
    tl = _pad_dim(jnp.asarray(top_leaf, jnp.int32), 0, bm_)
    ok = _pad_dim(jnp.asarray(leaf_ok, jnp.int8), 0, bm_)
    bank = (
        jnp.asarray(obj_x, jnp.float32), jnp.asarray(obj_y, jnp.float32),
        jnp.asarray(obj_bm, jnp.uint32), jnp.asarray(obj_id, jnp.int32),
    )
    if variant == "prefetch":
        ids, kwv = fused_verify_prefetch(qr, qb, tl, ok, *bank, interpret=interpret)
    else:
        ids, kwv = fused_verify(qr, qb, tl, ok, *bank, bm=bm_, interpret=interpret)
    return ids[:M], kwv[:M]


def fused_gather_verify_compact(
    q_rects, q_cbm, q_sig, top_leaf, leaf_ok,
    obj_x, obj_y, obj_cbm, obj_sig, obj_id,
    bm: int = 8, interpret: Optional[bool] = None, variant: str = "auto",
):
    """Compact-bank sibling of ``fused_gather_verify`` (DESIGN.md §3.5).

    Takes the per-slot remapped query words from ``remap_query_words``
    instead of the global bitmap, and the snapshot's compact leaf bank
    (``leaf_obj_cbm``/``leaf_obj_sig``). ``variant="auto"`` prices the
    COMPACT bank bytes (``compact_leaf_bank_bytes``) against
    ``FUSED_VMEM_BANK_BYTES`` -- the whole point of the compression is that
    the VMEM variant survives to much larger indexes. Returns the same
    ``(ids, kwv)`` contract, bit-identical to the full-width kernels.
    """
    if interpret is None:
        interpret = _on_cpu()
    if variant not in ("auto", "vmem", "prefetch"):
        raise ValueError(f"unknown fused-verify variant: {variant!r}")
    if variant == "auto":
        K, OBJ = obj_x.shape
        Wl = obj_cbm.shape[2]
        big = compact_leaf_bank_bytes(K, OBJ, Wl) > FUSED_VMEM_BANK_BYTES
        variant = "prefetch" if big else "vmem"
    M = q_rects.shape[0]
    bm_ = min(bm, max(M, 1))
    qr = _pad_dim(jnp.asarray(q_rects, jnp.float32), 0, bm_)
    qc = _pad_dim(jnp.asarray(q_cbm, jnp.uint32), 0, bm_)
    qs = _pad_dim(jnp.asarray(q_sig, jnp.uint32), 0, bm_)
    tl = _pad_dim(jnp.asarray(top_leaf, jnp.int32), 0, bm_)
    ok = _pad_dim(jnp.asarray(leaf_ok, jnp.int8), 0, bm_)
    bank = (
        jnp.asarray(obj_x, jnp.float32), jnp.asarray(obj_y, jnp.float32),
        jnp.asarray(obj_cbm, jnp.uint32), jnp.asarray(obj_sig, jnp.uint32),
        jnp.asarray(obj_id, jnp.int32),
    )
    if variant == "prefetch":
        ids, kwv = fused_verify_prefetch_compact(
            qr, qc, qs, tl, ok, *bank, interpret=interpret
        )
    else:
        ids, kwv = fused_verify_compact(
            qr, qc, qs, tl, ok, *bank, bm=bm_, interpret=interpret
        )
    return ids[:M], kwv[:M]


def cdf_bank_forward(
    params: Dict[str, jax.Array], x: jax.Array, bn: int = 256, bb: int = 64,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(N, B) CDF values for the whole MLP bank at points x."""
    if interpret is None:
        interpret = _on_cpu()
    N = x.shape[0]
    B = params["w0"].shape[0]
    bn_ = min(bn, max(N, 1))
    bb_ = min(bb, max(B, 1))
    xp = _pad_dim(jnp.asarray(x, jnp.float32), 0, bn_)
    pp = {k: _pad_dim(v, 0, bb_) for k, v in params.items()}
    out = cdf_mlp_bank(pp, xp, bn=bn_, bb=bb_, interpret=interpret)
    return out[:N, :B]


__all__ = [
    "FUSED_VMEM_BANK_BYTES",
    "compact_leaf_bank_bytes",
    "filter_pairs",
    "filter_frontier",
    "filter_frontier_narrow",
    "fused_gather_verify",
    "fused_gather_verify_compact",
    "knn_frontier_dist",
    "knn_frontier_dist_narrow",
    "leaf_bank_bytes",
    "match_subscriptions",
    "pack_query_words",
    "remap_query_words",
    "verify_candidates",
    "verify_candidates_compact",
    "cdf_bank_forward",
    "ref",
]
