"""Pallas TPU kernels: fused leaf gather + candidate verification.

The unfused serving hot path bounces the leaf-verification operands through
HBM three times per batch: the frontier kernel writes the (M, F) survivor
matrix, the host-side trace gathers the selected leaves' object blocks into
a dense ``(M, take*OBJ)`` candidate plane -- the bitmap slab alone is
``(M, take*OBJ, W)`` u32, by far the biggest intermediate of a descent --
and ``skr_verify`` streams that plane back in. The fused kernels consume
the survivor-derived leaf selection directly and perform the gather INSIDE
the kernel, so the gathered candidate plane never exists in HBM.

Both variants produce outputs bit-identical to ``gather -> skr_verify``
(same candidate ordering: leaf-slot-major, ``-1`` at non-matches), pinned
by the ref-oracle sweeps in tests/test_kernels.py and the engine-level
fused/unfused parity suite in tests/test_query_parity.py:

* ``ids``  (M, T*OBJ) int32 -- matching object ids, ``-1`` elsewhere;
* ``kwv``  (M, T)     int32 -- per leaf slot, the count of keyword-matching
  valid candidates (the Eq.1 ``verified`` partial sums).

Layout notes (TPU) -- two bank regimes, two kernels:

* ``fused_verify`` (VMEM variant): the object bank is mapped whole into the
  kernel (``(K, OBJ)`` / ``(K, OBJ, W)`` blocks, index map pinned to 0) and
  a static T loop performs in-VMEM row gathers, keeping only one leaf
  slot's ``(BM, OBJ, W)`` bitmap slab live at a time. Right answer when the
  bank fits comfortably in VMEM (small-to-medium single-chip indexes).
* ``fused_verify_prefetch`` (scalar-prefetch variant): the selected leaf-id
  matrix rides in as a *scalar-prefetch* operand
  (``pltpu.PrefetchScalarGridSpec``) and drives the bank BlockSpec index
  maps over a ``(M, T)`` grid, so the pipeline issues exactly one DMA per
  (query, slot) block -- only the selected ``(1, OBJ)`` / ``(1, OBJ, W)``
  leaf rows ever enter VMEM. This keeps the fused path (and its
  one-HBM-pass byte profile) for leaf banks far beyond VMEM, where the
  VMEM variant cannot compile.

Auto-selection lives in ``ops.fused_gather_verify(variant="auto")``, the
default the engine's ``serve/engine.py::_verify_leaves`` passes through: it
compares the bank's byte size (``leaf_bank_bytes``, the ``obj_x/y/bm/id``
rows) against ``ops.FUSED_VMEM_BANK_BYTES`` and picks the VMEM variant
below the cutoff, the prefetch variant above it -- so the engine never
falls back to the unfused HBM round-trip on bank-size grounds (only a live
DeltaBuffer disables fusion). ``variant="vmem"``/``"prefetch"`` force
either side for A/B rows and the beyond-VMEM oracle sweeps.

The keyword test in both kernels is one packed word-plane AND + a single
``any``-reduction over the word axis (popcount-style), matching
skr_verify's restructured inner loop.

Compact-bank twins (DESIGN.md §3.5): ``fused_verify_compact`` /
``fused_verify_prefetch_compact`` verify against the leaf-local vocabulary
slab (``(K, OBJ, Wl)`` with ``Wl << W``; serve/snapshot.py:
``encode_leaf_vocab``). The query side arrives already remapped per
selected leaf (``ops.remap_query_words``): ``q_cbm (M, T, Wl)`` holds each
query's words over slot ``t``'s leaf-local bit ids and ``q_sig (M, T)``
their OR-fold. The keyword test gains a one-word signature prefilter --
``(obj_sig & q_sig) != 0`` AND the word-plane any-reduction -- which is
implied by the word test (a real overlap always sets a shared signature
bit), so outputs stay bit-identical to the full-width kernels while
non-matching objects are decided on one word instead of ``Wl``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_verify_kernel(
    q_rects_ref, q_bm_ref, top_leaf_ref, leaf_ok_ref,
    ox_ref, oy_ref, obm_ref, oid_ref, ids_ref, kwv_ref,
):
    qr = q_rects_ref[...]  # (BM, 4)
    qb = q_bm_ref[...]  # (BM, W) uint32
    tl = top_leaf_ref[...]  # (BM, T) int32
    ok = leaf_ok_ref[...] > 0  # (BM, T)
    ox = ox_ref[...]  # (K, OBJ) -- VMEM-resident bank
    oy = oy_ref[...]
    obm = obm_ref[...]  # (K, OBJ, W)
    oid = oid_ref[...]
    K = ox.shape[0]
    OBJ = ox.shape[1]
    safe = jnp.clip(tl, 0, K - 1)
    for t in range(tl.shape[1]):  # static unroll over selected leaf slots
        leaf = safe[:, t]  # (BM,)
        cx = ox[leaf]  # (BM, OBJ) in-VMEM gather -- never round-trips HBM
        cy = oy[leaf]
        cid = oid[leaf]
        inr = (
            (cx >= qr[:, 0:1])
            & (cx <= qr[:, 2:3])
            & (cy >= qr[:, 1:2])
            & (cy <= qr[:, 3:4])
        )  # (BM, OBJ)
        cbm = obm[leaf]  # (BM, OBJ, W): one slot's bitmap slab live at a time
        kw = jnp.any((cbm & qb[:, None, :]) != 0, axis=-1)  # (BM, OBJ)
        valid = (cid >= 0) & ok[:, t][:, None]
        match = inr & kw & valid
        ids_ref[:, t * OBJ : (t + 1) * OBJ] = jnp.where(match, cid, -1)
        kwv_ref[:, t] = jnp.sum(kw & valid, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def fused_verify(
    q_rects: jax.Array,  # (M, 4) f32
    q_bm: jax.Array,  # (M, W) u32
    top_leaf: jax.Array,  # (M, T) int32 selected leaf ids
    leaf_ok: jax.Array,  # (M, T) int8 (1 = slot holds a selected leaf)
    obj_x: jax.Array,  # (K, OBJ) f32 leaf object bank
    obj_y: jax.Array,  # (K, OBJ) f32
    obj_bm: jax.Array,  # (K, OBJ, W) u32
    obj_id: jax.Array,  # (K, OBJ) int32, -1 pad
    bm: int = 8,
    interpret: bool = False,
):
    """(ids (M, T*OBJ) i32, kwv (M, T) i32): fused gather+verify over the
    VMEM-resident leaf bank. Query rows padded to tile multiples by ops.py."""
    M, T = top_leaf.shape
    K, OBJ = obj_x.shape
    W = q_bm.shape[1]
    bm = min(bm, M)
    grid = (pl.cdiv(M, bm),)
    return pl.pallas_call(
        _fused_verify_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i: (i, 0)),
            pl.BlockSpec((bm, W), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
            pl.BlockSpec((K, OBJ, W), lambda i: (0, 0, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, T * OBJ), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, T * OBJ), jnp.int32),
            jax.ShapeDtypeStruct((M, T), jnp.int32),
        ],
        interpret=interpret,
    )(q_rects, q_bm, top_leaf, leaf_ok, obj_x, obj_y, obj_bm, obj_id)


def _fused_prefetch_kernel(
    tl_ref,  # scalar-prefetch: (M, T) int32 clamped leaf ids
    q_rects_ref, q_bm_ref, leaf_ok_ref, ox_ref, oy_ref, obm_ref, oid_ref,
    ids_ref, kwv_ref,
):
    qr = q_rects_ref[...]  # (1, 4)
    qb = q_bm_ref[...]  # (1, W) uint32
    ok = leaf_ok_ref[...] > 0  # (1, 1)
    cx = ox_ref[...]  # (1, OBJ) -- the one DMA'd leaf row
    cy = oy_ref[...]
    cid = oid_ref[...]
    inr = (
        (cx >= qr[:, 0:1])
        & (cx <= qr[:, 2:3])
        & (cy >= qr[:, 1:2])
        & (cy <= qr[:, 3:4])
    )  # (1, OBJ)
    kw = jnp.any((obm_ref[...] & qb[:, None, :]) != 0, axis=-1)  # (1, OBJ)
    valid = (cid >= 0) & ok
    match = inr & kw & valid
    ids_ref[...] = jnp.where(match, cid, -1)
    kwv_ref[...] = jnp.sum(kw & valid, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_verify_prefetch(
    q_rects: jax.Array,  # (M, 4) f32
    q_bm: jax.Array,  # (M, W) u32
    top_leaf: jax.Array,  # (M, T) int32 selected leaf ids (dirty ids allowed)
    leaf_ok: jax.Array,  # (M, T) int8 (1 = slot holds a selected leaf)
    obj_x: jax.Array,  # (K, OBJ) f32 leaf object bank (HBM-resident)
    obj_y: jax.Array,  # (K, OBJ) f32
    obj_bm: jax.Array,  # (K, OBJ, W) u32
    obj_id: jax.Array,  # (K, OBJ) int32, -1 pad
    interpret: bool = False,
):
    """Scalar-prefetched twin of ``fused_verify`` for banks beyond VMEM.

    The clamped leaf-id matrix is the scalar-prefetch operand; the ``(M, T)``
    grid's bank BlockSpecs index through it, so each grid step DMAs exactly
    the one ``(1, OBJ)`` / ``(1, OBJ, W)`` leaf row that (query, slot) pair
    selected. Elementwise-identical outputs to ``fused_verify`` (same clamp +
    ``leaf_ok``/``cid`` validity semantics)."""
    M, T = top_leaf.shape
    K, OBJ = obj_x.shape
    W = q_bm.shape[1]
    safe = jnp.clip(top_leaf.astype(jnp.int32), 0, K - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M, T),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, t, tl: (i, 0)),
            pl.BlockSpec((1, W), lambda i, t, tl: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, t, tl: (i, t)),
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (tl[i, t], 0)),
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (tl[i, t], 0)),
            pl.BlockSpec((1, OBJ, W), lambda i, t, tl: (tl[i, t], 0, 0)),
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (tl[i, t], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (i, t)),
            pl.BlockSpec((1, 1), lambda i, t, tl: (i, t)),
        ],
    )
    return pl.pallas_call(
        _fused_prefetch_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, T * OBJ), jnp.int32),
            jax.ShapeDtypeStruct((M, T), jnp.int32),
        ],
        interpret=interpret,
    )(safe, q_rects, q_bm, leaf_ok, obj_x, obj_y, obj_bm, obj_id)


# ---------------------------------------------------- compact-bank twins
def _fused_verify_compact_kernel(
    q_rects_ref, q_cbm_ref, q_sig_ref, top_leaf_ref, leaf_ok_ref,
    ox_ref, oy_ref, ocbm_ref, osig_ref, oid_ref, ids_ref, kwv_ref,
):
    qr = q_rects_ref[...]  # (BM, 4)
    qc = q_cbm_ref[...]  # (BM, T, Wl) uint32 -- leaf-local query words
    qs = q_sig_ref[...]  # (BM, T) uint32 -- OR-fold per (query, slot)
    tl = top_leaf_ref[...]  # (BM, T) int32
    ok = leaf_ok_ref[...] > 0  # (BM, T)
    ox = ox_ref[...]  # (K, OBJ) -- VMEM-resident compact bank
    oy = oy_ref[...]
    ocbm = ocbm_ref[...]  # (K, OBJ, Wl)
    osig = osig_ref[...]  # (K, OBJ)
    oid = oid_ref[...]
    K = ox.shape[0]
    OBJ = ox.shape[1]
    safe = jnp.clip(tl, 0, K - 1)
    for t in range(tl.shape[1]):  # static unroll over selected leaf slots
        leaf = safe[:, t]  # (BM,)
        cx = ox[leaf]  # (BM, OBJ)
        cy = oy[leaf]
        cid = oid[leaf]
        inr = (
            (cx >= qr[:, 0:1])
            & (cx <= qr[:, 2:3])
            & (cy >= qr[:, 1:2])
            & (cy <= qr[:, 3:4])
        )  # (BM, OBJ)
        # one-word signature prefilter, then the Wl-word any-reduction;
        # the sig test is implied by the word test, so kw is unchanged
        sig_hit = (osig[leaf] & qs[:, t][:, None]) != 0  # (BM, OBJ)
        cbm = ocbm[leaf]  # (BM, OBJ, Wl)
        kw = sig_hit & jnp.any((cbm & qc[:, t][:, None, :]) != 0, axis=-1)
        valid = (cid >= 0) & ok[:, t][:, None]
        match = inr & kw & valid
        ids_ref[:, t * OBJ : (t + 1) * OBJ] = jnp.where(match, cid, -1)
        kwv_ref[:, t] = jnp.sum(kw & valid, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def fused_verify_compact(
    q_rects: jax.Array,  # (M, 4) f32
    q_cbm: jax.Array,  # (M, T, Wl) u32 leaf-local remapped query words
    q_sig: jax.Array,  # (M, T) u32 per-(query, slot) signature
    top_leaf: jax.Array,  # (M, T) int32 selected leaf ids
    leaf_ok: jax.Array,  # (M, T) int8 (1 = slot holds a selected leaf)
    obj_x: jax.Array,  # (K, OBJ) f32 leaf object bank
    obj_y: jax.Array,  # (K, OBJ) f32
    obj_cbm: jax.Array,  # (K, OBJ, Wl) u32 compact bitmap slab
    obj_sig: jax.Array,  # (K, OBJ) u32 OR-fold signatures
    obj_id: jax.Array,  # (K, OBJ) int32, -1 pad
    bm: int = 8,
    interpret: bool = False,
):
    """Compact-bank twin of ``fused_verify``: identical (ids, kwv) outputs,
    but the bitmap slab is ``Wl`` leaf-local words + a one-word signature
    instead of ``W`` global words. Query rows pre-padded by ops.py."""
    M, T = top_leaf.shape
    K, OBJ = obj_x.shape
    Wl = q_cbm.shape[2]
    bm = min(bm, M)
    grid = (pl.cdiv(M, bm),)
    return pl.pallas_call(
        _fused_verify_compact_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i: (i, 0)),
            pl.BlockSpec((bm, T, Wl), lambda i: (i, 0, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
            pl.BlockSpec((K, OBJ, Wl), lambda i: (0, 0, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, T * OBJ), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, T * OBJ), jnp.int32),
            jax.ShapeDtypeStruct((M, T), jnp.int32),
        ],
        interpret=interpret,
    )(q_rects, q_cbm, q_sig, top_leaf, leaf_ok, obj_x, obj_y, obj_cbm,
      obj_sig, obj_id)


def _fused_prefetch_compact_kernel(
    tl_ref,  # scalar-prefetch: (M, T) int32 clamped leaf ids
    q_rects_ref, q_cbm_ref, q_sig_ref, leaf_ok_ref,
    ox_ref, oy_ref, ocbm_ref, osig_ref, oid_ref,
    ids_ref, kwv_ref,
):
    qr = q_rects_ref[...]  # (1, 4)
    qc = q_cbm_ref[...]  # (1, 1, Wl) uint32
    qs = q_sig_ref[...]  # (1, 1) uint32
    ok = leaf_ok_ref[...] > 0  # (1, 1)
    cx = ox_ref[...]  # (1, OBJ) -- the one DMA'd leaf row
    cy = oy_ref[...]
    cid = oid_ref[...]
    inr = (
        (cx >= qr[:, 0:1])
        & (cx <= qr[:, 2:3])
        & (cy >= qr[:, 1:2])
        & (cy <= qr[:, 3:4])
    )  # (1, OBJ)
    sig_hit = (osig_ref[...] & qs) != 0  # (1, OBJ)
    kw = sig_hit & jnp.any((ocbm_ref[...] & qc[:, 0][:, None, :]) != 0, axis=-1)
    valid = (cid >= 0) & ok
    match = inr & kw & valid
    ids_ref[...] = jnp.where(match, cid, -1)
    kwv_ref[...] = jnp.sum(kw & valid, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_verify_prefetch_compact(
    q_rects: jax.Array,  # (M, 4) f32
    q_cbm: jax.Array,  # (M, T, Wl) u32 leaf-local remapped query words
    q_sig: jax.Array,  # (M, T) u32
    top_leaf: jax.Array,  # (M, T) int32 selected leaf ids (dirty ids allowed)
    leaf_ok: jax.Array,  # (M, T) int8
    obj_x: jax.Array,  # (K, OBJ) f32 leaf object bank (HBM-resident)
    obj_y: jax.Array,  # (K, OBJ) f32
    obj_cbm: jax.Array,  # (K, OBJ, Wl) u32
    obj_sig: jax.Array,  # (K, OBJ) u32
    obj_id: jax.Array,  # (K, OBJ) int32, -1 pad
    interpret: bool = False,
):
    """Compact-bank twin of ``fused_verify_prefetch``: one DMA per
    (query, slot) block over the ``(M, T)`` grid, with the per-slot
    remapped query words riding the same grid."""
    M, T = top_leaf.shape
    K, OBJ = obj_x.shape
    Wl = q_cbm.shape[2]
    safe = jnp.clip(top_leaf.astype(jnp.int32), 0, K - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M, T),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, t, tl: (i, 0)),
            pl.BlockSpec((1, 1, Wl), lambda i, t, tl: (i, t, 0)),
            pl.BlockSpec((1, 1), lambda i, t, tl: (i, t)),
            pl.BlockSpec((1, 1), lambda i, t, tl: (i, t)),
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (tl[i, t], 0)),
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (tl[i, t], 0)),
            pl.BlockSpec((1, OBJ, Wl), lambda i, t, tl: (tl[i, t], 0, 0)),
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (tl[i, t], 0)),
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (tl[i, t], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, OBJ), lambda i, t, tl: (i, t)),
            pl.BlockSpec((1, 1), lambda i, t, tl: (i, t)),
        ],
    )
    return pl.pallas_call(
        _fused_prefetch_compact_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, T * OBJ), jnp.int32),
            jax.ShapeDtypeStruct((M, T), jnp.int32),
        ],
        interpret=interpret,
    )(safe, q_rects, q_cbm, q_sig, leaf_ok, obj_x, obj_y, obj_cbm,
      obj_sig, obj_id)
