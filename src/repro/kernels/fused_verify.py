"""Pallas TPU kernel: fused leaf gather + candidate verification.

The unfused serving hot path bounces the leaf-verification operands through
HBM three times per batch: the frontier kernel writes the (M, F) survivor
matrix, the host-side trace gathers the selected leaves' object blocks into
a dense ``(M, take*OBJ)`` candidate plane -- the bitmap slab alone is
``(M, take*OBJ, W)`` u32, by far the biggest intermediate of a descent --
and ``skr_verify`` streams that plane back in. This kernel consumes the
survivor-derived leaf selection directly and performs the gather INSIDE the
kernel: per query tile it walks the selected leaf slots, pulls each leaf's
object block (``leaf_obj_x/y/bm/id``) out of the VMEM-resident bank, and
verifies it in place, so the gathered candidate plane never exists in HBM.

Outputs are bit-identical to ``gather -> skr_verify`` (same candidate
ordering: leaf-slot-major, ``-1`` at non-matches), pinned by the ref-oracle
sweep in tests/test_kernels.py and the engine-level fused/unfused parity
suite in tests/test_query_parity.py:

* ``ids``  (M, T*OBJ) int32 -- matching object ids, ``-1`` elsewhere;
* ``kwv``  (M, T)     int32 -- per leaf slot, the count of keyword-matching
  valid candidates (the Eq.1 ``verified`` partial sums).

Layout notes (TPU): the object bank is mapped whole into the kernel
(``(K, OBJ)`` / ``(K, OBJ, W)`` blocks, index map pinned to 0), i.e. the
kernel targets indexes whose leaf bank fits VMEM -- the single-chip serving
regime this repo's quick configs exercise. The static T loop keeps only one
leaf slot's ``(BM, OBJ, W)`` bitmap slab live at a time. For banks beyond
VMEM the same kernel body works with a scalar-prefetched leaf-id grid
(one DMA per (query, slot) block); that variant is future work gated on the
scoreboard (EXPERIMENTS.md section Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_verify_kernel(
    q_rects_ref, q_bm_ref, top_leaf_ref, leaf_ok_ref,
    ox_ref, oy_ref, obm_ref, oid_ref, ids_ref, kwv_ref,
):
    qr = q_rects_ref[...]  # (BM, 4)
    qb = q_bm_ref[...]  # (BM, W) uint32
    tl = top_leaf_ref[...]  # (BM, T) int32
    ok = leaf_ok_ref[...] > 0  # (BM, T)
    ox = ox_ref[...]  # (K, OBJ) -- VMEM-resident bank
    oy = oy_ref[...]
    obm = obm_ref[...]  # (K, OBJ, W)
    oid = oid_ref[...]
    K = ox.shape[0]
    OBJ = ox.shape[1]
    W = qb.shape[1]
    safe = jnp.clip(tl, 0, K - 1)
    for t in range(tl.shape[1]):  # static unroll over selected leaf slots
        leaf = safe[:, t]  # (BM,)
        cx = ox[leaf]  # (BM, OBJ) in-VMEM gather -- never round-trips HBM
        cy = oy[leaf]
        cid = oid[leaf]
        inr = (
            (cx >= qr[:, 0:1])
            & (cx <= qr[:, 2:3])
            & (cy >= qr[:, 1:2])
            & (cy <= qr[:, 3:4])
        )  # (BM, OBJ)
        cbm = obm[leaf]  # (BM, OBJ, W): one slot's bitmap slab live at a time
        kw = jnp.zeros(inr.shape, dtype=jnp.bool_)
        for w in range(W):  # skr_verify's static word unroll
            kw = kw | ((cbm[:, :, w] & qb[:, w][:, None]) != 0)
        valid = (cid >= 0) & ok[:, t][:, None]
        match = inr & kw & valid
        ids_ref[:, t * OBJ : (t + 1) * OBJ] = jnp.where(match, cid, -1)
        kwv_ref[:, t] = jnp.sum(kw & valid, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def fused_verify(
    q_rects: jax.Array,  # (M, 4) f32
    q_bm: jax.Array,  # (M, W) u32
    top_leaf: jax.Array,  # (M, T) int32 selected leaf ids
    leaf_ok: jax.Array,  # (M, T) int8 (1 = slot holds a selected leaf)
    obj_x: jax.Array,  # (K, OBJ) f32 leaf object bank
    obj_y: jax.Array,  # (K, OBJ) f32
    obj_bm: jax.Array,  # (K, OBJ, W) u32
    obj_id: jax.Array,  # (K, OBJ) int32, -1 pad
    bm: int = 8,
    interpret: bool = False,
):
    """(ids (M, T*OBJ) i32, kwv (M, T) i32): fused gather+verify over the
    leaf bank. Query rows padded to tile multiples by ops.py."""
    M, T = top_leaf.shape
    K, OBJ = obj_x.shape
    W = q_bm.shape[1]
    bm = min(bm, M)
    grid = (pl.cdiv(M, bm),)
    return pl.pallas_call(
        _fused_verify_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i: (i, 0)),
            pl.BlockSpec((bm, W), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
            pl.BlockSpec((K, OBJ, W), lambda i: (0, 0, 0)),
            pl.BlockSpec((K, OBJ), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, T * OBJ), lambda i: (i, 0)),
            pl.BlockSpec((bm, T), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, T * OBJ), jnp.int32),
            jax.ShapeDtypeStruct((M, T), jnp.int32),
        ],
        interpret=interpret,
    )(q_rects, q_bm, top_leaf, leaf_ok, obj_x, obj_y, obj_bm, obj_id)
