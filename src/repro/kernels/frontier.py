"""Pallas TPU kernels: sparse-frontier node filtering (DESIGN.md §3).

``skr_filter`` scores the full (query x node) cross product -- O(M*K) work
per level no matter how selective the learned hierarchy is. The frontier
kernels instead receive, per query, a *gathered* tile of candidate nodes
(the query's frontier): MBRs ``(BM, BF, 4)``, bitmaps ``(BM, BF, W)`` and a
validity plane for the -1 padding slots, so per-level work is O(M*F) with F
the bucketed frontier width, not the level width.

Two variants share the rectangle-intersect + keyword-AND predicate:

* ``frontier_filter`` -- the full-width f32/uint32 baseline (kept for A/B
  and for the delta-augmented fallback, whose planes are not dictionary
  encoded).
* ``frontier_filter_narrow`` -- the bandwidth-lean descent. MBR planes
  arrive as **int16 rank codes** into per-level sorted coordinate
  dictionaries and are dequantized *inside* the kernel by a VMEM gather,
  reconstructing the exact f32 coordinates (lossless, so the survivor set
  is bit-identical to the f32 path -- strictly stronger than the
  conservative-superset requirement). Bitmaps arrive as **packed word
  planes**: ops.pack_query_words keeps only each query's nonzero bitmap
  words (static bucketed width Wp <= W), and the engine gathers just those
  Wp words per frontier slot, so the biggest descent operand shrinks from
  ``(M, F, W)`` u32 to ``(M, F, Wp)``.

Layout notes (TPU): the minor dimension is the frontier width (BF = 128
lanes by default); the bitmap plane is the big operand. The keyword test is
one packed word-plane AND followed by a single ``any``-reduction over the
word axis (popcount-style) per tile -- the reduction tree lives in
registers, so only the (BM, BF) boolean accumulator is live, same as the
old static W unroll but without W sliced passes over the tile. The
coordinate dictionaries are tiny (<= 2n f32 per axis per level) and are
pinned whole in VMEM across the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _frontier_kernel(q_rects_ref, q_bm_ref, f_mbrs_ref, f_bm_ref, f_valid_ref, out_ref):
    qr = q_rects_ref[...]  # (BM, 4)
    fm = f_mbrs_ref[...]  # (BM, BF, 4)
    inter = (
        (qr[:, 0:1] <= fm[:, :, 2])
        & (fm[:, :, 0] <= qr[:, 2:3])
        & (qr[:, 1:2] <= fm[:, :, 3])
        & (fm[:, :, 1] <= qr[:, 3:4])
    )  # (BM, BF)
    qb = q_bm_ref[...]  # (BM, W) uint32
    fb = f_bm_ref[...]  # (BM, BF, W) uint32
    kw = jnp.any((fb & qb[:, None, :]) != 0, axis=-1)  # (BM, BF)
    out_ref[...] = (inter & kw & (f_valid_ref[...] > 0)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def frontier_filter(
    q_rects: jax.Array,  # (M, 4)
    q_bm: jax.Array,  # (M, W)
    f_mbrs: jax.Array,  # (M, F, 4)
    f_bm: jax.Array,  # (M, F, W)
    f_valid: jax.Array,  # (M, F) int8
    bm: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(M, F) int8 survivor matrix. Inputs padded to tile multiples by ops.py."""
    M, F = f_valid.shape
    W = q_bm.shape[1]
    bm = min(bm, M)
    bf = min(bf, F)
    grid = (pl.cdiv(M, bm), pl.cdiv(F, bf))
    return pl.pallas_call(
        _frontier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bf, 4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.int8),
        interpret=interpret,
    )(q_rects, q_bm, f_mbrs, f_bm, f_valid)


def _frontier_narrow_kernel(
    q_rects_ref, q_bits_ref, f_codes_ref, f_bm_ref, f_valid_ref, dict_x_ref, dict_y_ref, out_ref
):
    qr = q_rects_ref[...]  # (BM, 4) f32 -- queries stay full precision
    fc = f_codes_ref[...].astype(jnp.int32)  # (BM, BF, 4) int16 rank codes
    dx = dict_x_ref[...]  # (Dx,) f32 sorted distinct x coords
    dy = dict_y_ref[...]  # (Dy,) f32 sorted distinct y coords
    xlo = dx[fc[:, :, 0]]  # exact dequantization: VMEM gather, no rounding
    ylo = dy[fc[:, :, 1]]
    xhi = dx[fc[:, :, 2]]
    yhi = dy[fc[:, :, 3]]
    inter = (
        (qr[:, 0:1] <= xhi) & (xlo <= qr[:, 2:3]) & (qr[:, 1:2] <= yhi) & (ylo <= qr[:, 3:4])
    )  # (BM, BF)
    qb = q_bits_ref[...]  # (BM, Wp) uint32 packed nonzero query words
    fb = f_bm_ref[...]  # (BM, BF, Wp) uint32 gathered matching node words
    kw = jnp.any((fb & qb[:, None, :]) != 0, axis=-1)  # (BM, BF)
    out_ref[...] = (inter & kw & (f_valid_ref[...] > 0)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def frontier_filter_narrow(
    q_rects: jax.Array,  # (M, 4) f32
    q_bits: jax.Array,  # (M, Wp) uint32 packed query words (ops.pack_query_words)
    f_codes: jax.Array,  # (M, F, 4) int16 MBR rank codes
    f_bm: jax.Array,  # (M, F, Wp) uint32 packed node word planes
    f_valid: jax.Array,  # (M, F) int8
    dict_x: jax.Array,  # (Dx,) f32
    dict_y: jax.Array,  # (Dy,) f32
    bm: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(M, F) int8 survivor matrix, bit-identical to ``frontier_filter`` on
    the dequantized planes. Inputs padded to tile multiples by ops.py; the
    coordinate dictionaries are pinned whole (index map constant 0)."""
    M, F = f_valid.shape
    Wp = q_bits.shape[1]
    bm = min(bm, M)
    bf = min(bf, F)
    grid = (pl.cdiv(M, bm), pl.cdiv(F, bf))
    return pl.pallas_call(
        _frontier_narrow_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, Wp), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bf, 4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf, Wp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
            pl.BlockSpec(dict_x.shape, lambda i, j: (0,)),
            pl.BlockSpec(dict_y.shape, lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.int8),
        interpret=interpret,
    )(q_rects, q_bits, f_codes, f_bm, f_valid, dict_x, dict_y)
