"""Pallas TPU kernel: sparse-frontier node filtering (DESIGN.md §3).

``skr_filter`` scores the full (query x node) cross product -- O(M*K) work
per level no matter how selective the learned hierarchy is. The frontier
kernel instead receives, per query, a *gathered* tile of candidate nodes
(the query's frontier): MBRs ``(BM, BF, 4)``, bitmaps ``(BM, BF, W)`` and a
validity plane for the -1 padding slots. It reuses the skr_filter inner
loop -- rectangle intersect + unrolled bitmap-word AND -- but over the
frontier tile, so per-level work is O(M*F) with F the bucketed frontier
width, not the level width.

Layout notes (TPU): the minor dimension is the frontier width (BF = 128
lanes by default); the bitmap plane ``(BM, BF, W)`` is the big operand and
streams through VMEM one word-plane at a time via the static W unroll, so
only (BM, BF) boolean accumulators stay live.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _frontier_kernel(q_rects_ref, q_bm_ref, f_mbrs_ref, f_bm_ref, f_valid_ref, out_ref):
    qr = q_rects_ref[...]  # (BM, 4)
    fm = f_mbrs_ref[...]  # (BM, BF, 4)
    inter = (
        (qr[:, 0:1] <= fm[:, :, 2])
        & (fm[:, :, 0] <= qr[:, 2:3])
        & (qr[:, 1:2] <= fm[:, :, 3])
        & (fm[:, :, 1] <= qr[:, 3:4])
    )  # (BM, BF)
    qb = q_bm_ref[...]  # (BM, W) uint32
    fb = f_bm_ref[...]  # (BM, BF, W) uint32
    W = qb.shape[1]
    kw = jnp.zeros(inter.shape, dtype=jnp.bool_)
    for w in range(W):  # static unroll over bitmap words (skr_filter inner loop)
        kw = kw | ((fb[:, :, w] & qb[:, w][:, None]) != 0)
    out_ref[...] = (inter & kw & (f_valid_ref[...] > 0)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def frontier_filter(
    q_rects: jax.Array,  # (M, 4)
    q_bm: jax.Array,  # (M, W)
    f_mbrs: jax.Array,  # (M, F, 4)
    f_bm: jax.Array,  # (M, F, W)
    f_valid: jax.Array,  # (M, F) int8
    bm: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(M, F) int8 survivor matrix. Inputs padded to tile multiples by ops.py."""
    M, F = f_valid.shape
    W = q_bm.shape[1]
    bm = min(bm, M)
    bf = min(bf, F)
    grid = (pl.cdiv(M, bm), pl.cdiv(F, bf))
    return pl.pallas_call(
        _frontier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bf, 4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.int8),
        interpret=interpret,
    )(q_rects, q_bm, f_mbrs, f_bm, f_valid)
