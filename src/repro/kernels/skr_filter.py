"""Pallas TPU kernel: SKR node filtering (the Eq.1 ``w1`` stage).

For a tile of queries and a tile of index nodes, decide in one VMEM-resident
pass whether each (query, node) pair is *relevant*: the query rectangle
intersects the node MBR AND the query keyword bitmap shares >=1 bit with the
node bitmap. This is the hot loop of level-synchronous traversal: on HBM it
touches ``M*4 + M*W + K*4 + K*W`` words and emits ``M*K`` bytes, so blocking
both operands into VMEM and reducing the bitmap-word axis in one packed
``any``-reduction keeps it at one HBM read per operand tile instead of one
per pair. (The node planes here are *shared* across the query tile --
node-major -- so, unlike the frontier kernels, there is no per-query packed
gather to exploit; the full W words stay resident.)

Layout notes (TPU): the minor dimension of the output tile is the node tile
(BK = 128 lanes); rect coordinates ride along as 4-wide minor arrays which
Mosaic pads -- acceptable because they are tiny next to the bitmap planes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _filter_kernel(q_rects_ref, q_bm_ref, n_mbrs_ref, n_bm_ref, out_ref):
    qr = q_rects_ref[...]  # (BM, 4)
    nr = n_mbrs_ref[...]  # (BK, 4)
    inter = (
        (qr[:, 0:1] <= nr[None, :, 2])
        & (nr[None, :, 0] <= qr[:, 2:3])
        & (qr[:, 1:2] <= nr[None, :, 3])
        & (nr[None, :, 1] <= qr[:, 3:4])
    )  # (BM, BK)
    qb = q_bm_ref[...]  # (BM, W) uint32
    nb = n_bm_ref[...]  # (BK, W) uint32
    # packed word-plane AND + single any-reduction per tile (popcount-style)
    kw = jnp.any((qb[:, None, :] & nb[None, :, :]) != 0, axis=-1)  # (BM, BK)
    out_ref[...] = (inter & kw).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def skr_filter(
    q_rects: jax.Array,
    q_bm: jax.Array,
    n_mbrs: jax.Array,
    n_bm: jax.Array,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) int8 relevance matrix. Inputs padded to tile multiples by ops.py."""
    M, K = q_rects.shape[0], n_mbrs.shape[0]
    W = q_bm.shape[1]
    bm = min(bm, M)
    bk = min(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(K, bk))
    return pl.pallas_call(
        _filter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, W), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), jnp.int8),
        interpret=interpret,
    )(q_rects, q_bm, n_mbrs, n_bm)
