"""WISK serving on the production mesh (DESIGN.md §3.4).

Three distribution regimes share this front door:

* **Query-parallel, replicated index** (``serve_sharded`` /
  ``serve_knn_sharded``) -- the default and the throughput-scaling path.
  The ``IndexSnapshot`` pytree is replicated over the mesh with one
  ``device_put`` (``snapshot.replicate``); the query batch is padded to
  per-shard power-of-two buckets and sharded over the data axes; and the
  REAL hierarchical engine -- the frontier SKR descent and the
  distance-bounded kNN descent of serve/engine.py -- runs per shard inside
  ``shard_map``, returning per-query result ids and Eq.1 cost counters
  (identical to the single-device engine, pinned by
  tests/test_sharded_parity.py). Frontier widths cannot block on per-level
  host syncs inside a traced region, so the sharded path runs at
  ``PlanCache.seeded_plan`` widths, cross-shard-maxes the observed per-level
  child counts (``lax.pmax``), and loops grow-and-redescend to the fixed
  point -- lossless for the same reason the §3.2 overflow retry is, and
  sync-free in steady state.

* **Index-parallel, partitioned hierarchy** (``serve_index_sharded`` /
  ``serve_knn_index_sharded``) -- the big-index path. A
  ``PartitionedSnapshot`` (serve/snapshot.py) cuts the root forest into
  balanced shard-local sub-hierarchies placed over the serving mesh's
  ``index`` axis (~1/S of the index bytes per device); each shard runs the
  same engine descent from its masked local root frontier, and per-query
  results are combined by collectives -- an id-union + psum'd Eq.1 counters
  for SKR, a global top-k merge with bound exchange for kNN. Composes with
  query parallelism on the 2D ``(query, index)`` mesh
  (``mesh.make_serving_mesh``); exact id/counter parity with the
  single-device engine is pinned by tests/test_index_sharded_parity.py.

* **Legacy flat fallback** (launch/flat_legacy.py; ``wisk_serve_step`` /
  ``lower_wisk_serve`` re-exported here) -- the retired hierarchy-free
  leaf-sharded scan, kept as the dry-run/roofline lowering surface and the
  A/B floor.

On top of these regimes sits the incremental-maintenance front door
(DESIGN.md §7): ``LiveIndex`` buffers object inserts/deletes in a
``DeltaBuffer`` merged into every descent (routed to the owning shards in
the index-parallel regime via ``delta.partition_delta``), watches workload
drift through the observed Eq.1 counters, and atomically swaps in
warm-start rebuilds as new ``ServingGeneration``s while in-flight batches
finish on the old one. ``LiveIndex`` also fronts the continuous-filter
pub-sub subsystem (DESIGN.md §8, serve/subscribe.py): standing
spatio-textual subscriptions compiled into a device-resident block, every
insert batch matched against it in the same step, notifications drained
exactly once -- subscription state survives generation swaps. Every front
door here is host-side orchestration around the jit-traced engine paths of
serve/engine.py.
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..sharding.compat import shard_map

from ..kernels import ops
from ..serve.delta import DeltaBuffer, DeltaLog, partition_delta
from ..serve.engine import (
    IndexSnapshot,
    _descend_frontier,
    _descend_knn,
    _descend_knn_indexed,
    _local_root_frontier,
    _select_leaves_frontier,
    _select_leaves_indexed,
    _snap_cbank,
    _verify_leaves,
    retrieve,
    retrieve_knn,
    round_up_bucket,
)
from ..serve.plan import (
    ExecutionPlan,
    PlanCache,
    default_plan_cache,
    pad_knn_queries_to_bucket,  # noqa: F401  (re-export: historical home)
    pad_queries_to_bucket,  # noqa: F401  (re-export: historical home)
)
from ..serve.snapshot import PartitionedSnapshot
from ..serve.subscribe import SubscriptionIndex
from ..sharding.rules import default_rules, dp_axes, spec_for
from .mesh import make_host_mesh, make_serving_mesh


# --------------------------------------------------- single-device front door
def serve_batch(
    snap: IndexSnapshot,
    q_rects,
    q_bm,
    max_leaves: int = 32,
    mode: str = "frontier",
    minimum_bucket: int = 8,
    plan_cache: Optional[PlanCache] = None,
    delta: Optional[DeltaBuffer] = None,
    fused: Optional[bool] = None,
    compact: Optional[bool] = None,
):
    """Bucketed front door for the batched SKR engine (host-side wrapper).

    Args:
        snap: the served ``IndexSnapshot``.
        q_rects: (m, 4) f32 query rectangles ``(xlo, ylo, xhi, yhi)``.
        q_bm: (m, W) u32 query keyword bitmaps.
        max_leaves: per-query verification capacity (spill -> ``overflow``).
        mode: ``"frontier"`` (sparse descent) or ``"dense"`` (A/B scan).
        minimum_bucket: smallest power-of-two batch bucket.
        plan_cache: frontier width state (None: per-snapshot default).
        delta: optional ``DeltaBuffer`` of buffered inserts/deletes merged
            on the fly (DESIGN.md §7).
        fused: leaf verification path -- None (default) runs the fused
            gather+verify kernel on the base leaf blocks even with a live
            delta (only the insert-buffer slots take the unfused merge);
            False forces the wholesale unfused baseline (DESIGN.md §3.5).
        compact: leaf verification width -- None (default) verifies on the
            leaf-local compact vocabulary bank when the snapshot carries
            one; False forces the global full-width slab (DESIGN.md §3.5).

    Pads the batch to its power-of-two bucket with inert pad queries, runs
    the jit-traced ``retrieve`` descent, and slices the pads back off the
    per-query outputs. Returns ``retrieve``'s dict (``ids`` (m, C) i32 with
    ``-1`` fill, ``counts``, Eq.1 counters); only the pad/slice runs on
    host.
    """
    rects, bms, m = pad_queries_to_bucket(q_rects, q_bm, minimum_bucket)
    out = retrieve(
        snap, jnp.asarray(rects), jnp.asarray(bms), max_leaves, mode=mode,
        plan_cache=plan_cache, delta=delta, fused=fused, compact=compact,
    )
    per_query = ("ids", "counts", "nodes_checked", "nodes_scanned", "verified", "overflow")
    return {k: (v[:m] if k in per_query else v) for k, v in out.items()}


def serve_knn_batch(
    snap: IndexSnapshot,
    points,
    q_bm,
    k: int,
    minimum_bucket: int = 8,
    plan_cache: Optional[PlanCache] = None,
    delta: Optional[DeltaBuffer] = None,
    knn_dtype: str = "f32",
    compact: Optional[bool] = None,
):
    """Bucketed front door for batched Boolean kNN: pad -> retrieve -> slice.

    Args:
        snap: the served ``IndexSnapshot``.
        points: (m, 2) f32 query points in the unit square.
        q_bm: (m, W) u32 query keyword bitmaps.
        k: neighbors per query -- a *static* argument (each served k
            compiles its own descent; the workload classes of LIST-style
            top-k serving are few and fixed).
        minimum_bucket: smallest power-of-two batch bucket.
        plan_cache: frontier width state (None: per-snapshot default).
        delta: optional ``DeltaBuffer`` merged on the fly (DESIGN.md §7).
        knn_dtype: ``"f32"`` (exact) or ``"bf16"`` -- reduced-precision
            bounded-sweep pruning with a conservative exact-f32 retry; ids
            are always identical to f32 (see ``retrieve_knn``).
        compact: leaf keyword-test width -- None (default) uses the compact
            leaf bank when available; False forces full width (§3.5).

    Returns ``retrieve_knn``'s dict: ``ids``/``dist2`` (m, k) ascending by
    (dist^2, id) with ``-1`` fill, plus Eq.1 counters, pads sliced off.
    Host-side wrapper around the jit-traced descent.
    """
    pts, bms, m = pad_knn_queries_to_bucket(points, q_bm, minimum_bucket)
    out = retrieve_knn(
        snap, jnp.asarray(pts), jnp.asarray(bms), k, plan_cache=plan_cache,
        delta=delta, knn_dtype=knn_dtype, compact=compact,
    )
    per_query = ("ids", "dist2", "nodes_checked", "verified", "leaves_verified", "pruned")
    return {key: (v[:m] if key in per_query else v) for key, v in out.items()}


# ------------------------------- micro-batching + hot-query cache (§3.5)
class HotQueryCache:
    """LRU result cache for repeated ("hot") SKR queries (DESIGN.md §3.5).

    Keys are ``(rect quantized to a 1/quant grid, bitmap bytes)``: real query
    streams repeat popular (region, keyword) probes near-verbatim, and
    quantizing the rectangle folds jittered re-issues of the same probe onto
    one entry. Quantization only affects the KEY -- the cached value is the
    engine's exact output for the first query that produced it, so hits are
    exact for re-issues that quantize identically. ``hits``/``misses``
    counters feed capacity tuning; ``invalidate()`` drops everything and
    must be called whenever served state changes (delta update, generation
    swap) -- ``LiveIndex`` does this automatically.
    """

    def __init__(self, maxsize: int = 1024, quant: float = 4096.0) -> None:
        from collections import OrderedDict

        self.maxsize = int(maxsize)
        self.quant = float(quant)
        self._entries: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def key(self, rect, bm) -> bytes:
        q = np.rint(np.asarray(rect, np.float64) * self.quant).astype(np.int64)
        return q.tobytes() + np.asarray(bm, np.uint32).tobytes()

    def get(self, rect, bm):
        """The cached per-query result dict, or None (counts a hit/miss)."""
        got = self._entries.get(self.key(rect, bm))
        if got is None:
            self.misses += 1
            return None
        self._entries.move_to_end(self.key(rect, bm))
        self.hits += 1
        return got

    def put(self, rect, bm, result) -> None:
        k = self.key(rect, bm)
        self._entries[k] = result
        self._entries.move_to_end(k)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def invalidate(self) -> None:
        """Drop every entry (served state changed: delta update or swap)."""
        self._entries.clear()
        self.invalidations += 1

    def __len__(self) -> int:
        return len(self._entries)


_PER_QUERY_SKR = ("ids", "counts", "nodes_checked", "nodes_scanned", "verified", "overflow")


def serve_batch_cached(
    snap: IndexSnapshot,
    q_rects,
    q_bm,
    cache: HotQueryCache,
    max_leaves: int = 32,
    **serve_kw,
) -> Dict[str, np.ndarray]:
    """``serve_batch`` behind a ``HotQueryCache``: serve only the misses.

    Looks every query up in ``cache``, runs ONE ``serve_batch`` over the
    misses, fills the cache with their per-query rows, and reassembles the
    batch in submission order. Returns ``serve_batch``'s dict plus a
    ``cached`` (m,) bool mask (True = row came from the cache -- callers
    feeding observed-cost telemetry, e.g. the drift monitor, must restrict
    to ``~cached`` rows or hot traffic looks free). ``ids`` rows are padded
    to the batch's widest capacity with ``-1`` (capacity can grow between
    batches as the plan cache learns)."""
    rects = np.asarray(q_rects, np.float32).reshape(-1, 4)
    bms = np.asarray(q_bm, np.uint32).reshape(len(rects), -1)
    m = len(rects)
    entries = [cache.get(rects[i], bms[i]) for i in range(m)]
    cached = np.array([e is not None for e in entries], bool)
    miss = np.flatnonzero(~cached)
    if miss.size:
        out = serve_batch(snap, rects[miss], bms[miss], max_leaves, **serve_kw)
        for j, i in enumerate(miss):
            entry = {k: np.asarray(out[k])[j] for k in _PER_QUERY_SKR}
            cache.put(rects[i], bms[i], entry)
            entries[i] = entry
    width = max((e["ids"].shape[0] for e in entries), default=0)

    def _row(e, k):
        v = e[k]
        if k == "ids" and v.shape[0] < width:
            v = np.concatenate([v, np.full(width - v.shape[0], -1, v.dtype)])
        return v

    result = {k: np.stack([_row(e, k) for e in entries]) for k in _PER_QUERY_SKR}
    result["cached"] = cached
    return result


class MicroBatcher:
    """Deadline-free micro-batching for the SKR front door (DESIGN.md §3.5).

    Coalesces singleton queries into one bucketed ``serve_batch`` dispatch.
    There is NO timer and NO deadline: ``submit`` enqueues and returns a
    ticket; the batch runs when the caller calls ``flush()`` (or
    automatically once ``flush_at`` queries are pending -- the knob). That
    keeps the policy in the caller's event loop, where the repo's serving
    stack keeps all control flow, instead of hiding a latency/throughput
    trade behind a background thread.

    ``result(ticket)`` returns (and drops) one query's row dict, flushing
    first if the ticket is still pending. With a ``cache`` the flush goes
    through ``serve_batch_cached`` and rows carry the ``cached`` flag.
    ``flushes``/``served`` counters expose the achieved batching factor
    (served/flushes -- the scoreboard's micro-batching gain).
    """

    def __init__(
        self,
        snap: IndexSnapshot,
        max_leaves: int = 32,
        flush_at: int = 8,
        cache: Optional[HotQueryCache] = None,
        **serve_kw,
    ) -> None:
        if flush_at < 1:
            raise ValueError(f"flush_at must be >= 1, got {flush_at}")
        self.snap = snap
        self.max_leaves = max_leaves
        self.flush_at = int(flush_at)
        self.cache = cache
        self.serve_kw = serve_kw
        self._pending: list = []  # [(ticket, rect, bm)]
        self._done: dict = {}
        self._next = 0
        self.flushes = 0
        self.served = 0

    @property
    def pending(self) -> int:
        return len(self._pending)

    def submit(self, rect, bm) -> int:
        """Enqueue one query; returns its ticket. Auto-flushes at
        ``flush_at`` pending queries."""
        t = self._next
        self._next += 1
        self._pending.append(
            (t, np.asarray(rect, np.float32).reshape(4),
             np.asarray(bm, np.uint32).reshape(-1))
        )
        if len(self._pending) >= self.flush_at:
            self.flush()
        return t

    def flush(self) -> int:
        """Serve every pending query in one dispatch; returns how many."""
        if not self._pending:
            return 0
        tickets = [t for t, _, _ in self._pending]
        rects = np.stack([r for _, r, _ in self._pending])
        bms = np.stack([b for _, _, b in self._pending])
        self._pending = []
        if self.cache is not None:
            out = serve_batch_cached(
                self.snap, rects, bms, self.cache, self.max_leaves, **self.serve_kw
            )
            keys = _PER_QUERY_SKR + ("cached",)
        else:
            out = serve_batch(self.snap, rects, bms, self.max_leaves, **self.serve_kw)
            keys = _PER_QUERY_SKR
        for j, t in enumerate(tickets):
            self._done[t] = {k: np.asarray(out[k])[j] for k in keys}
        self.flushes += 1
        self.served += len(tickets)
        return len(tickets)

    def result(self, ticket: int) -> Dict[str, np.ndarray]:
        """One query's result row (popped); flushes if still pending."""
        if ticket not in self._done:
            self.flush()
        return self._done.pop(ticket)


# ------------------------------------- query-parallel sharded serving (§3.4)
def default_serving_mesh() -> Mesh:
    """All local devices on the data axis (query-parallel serving)."""
    return make_host_mesh(data=len(jax.devices()), model=1)


def mesh_dp_size(mesh: Mesh) -> int:
    """Number of query shards: the product of the mesh's data axes."""
    dp = dp_axes(mesh)
    return int(np.prod([mesh.shape[a] for a in dp])) if dp else 1


# Replicated-snapshot memo: broadcasting a production-scale index to every
# mesh device is the expensive part of the query-parallel path, so it must
# happen once per (snapshot, mesh), not once per served batch. Weakly keyed
# like plan.default_plan_cache: dropping the snapshot drops its replicas.
_REPLICATED: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _replicated(snap: IndexSnapshot, mesh: Mesh) -> IndexSnapshot:
    per_mesh = _REPLICATED.get(snap)
    if per_mesh is None:
        per_mesh = {}
        _REPLICATED[snap] = per_mesh
    got = per_mesh.get(mesh)
    if got is None:
        got = snap.replicate(mesh)
        per_mesh[mesh] = got
    return got


def _converge_widths(snap: IndexSnapshot, cache: PlanCache, tag: str, run):
    """Shared grow-and-redescend driver of the sharded front doors: descend
    at the cache's seeded widths, max the observed per-level child counts
    across shards, grow the cache, and repeat until nothing overflowed --
    lossless for the same reason the §3.2 overflow retry is (a descent that
    finishes without overflow dropped no children), and convergent because
    widths grow monotonically in power-of-two steps. ``run(widths)`` must
    return a tuple whose LAST element is the pmax'd per-level maxima."""
    n_links = snap.n_levels - 1
    while True:
        widths = cache.seeded_plan(tag, n_links).widths
        out = run(widths)
        maxima = np.asarray(jax.device_get(out[-1]))
        cache.observe(tag, maxima)
        if not n_links or not np.any(maxima > np.asarray(widths)):
            return widths, out


def _shard_queries(mesh: Mesh, *arrays):
    qspec = spec_for(("query", None), default_rules(mesh))
    sharding = NamedSharding(mesh, qspec)
    return tuple(jax.device_put(jnp.asarray(a), sharding) for a in arrays)


def _pmax_needs(needs, dp):
    """Stack per-level observed child-count maxima and max them across the
    query shards: the plan cache must learn widths that fit EVERY shard."""
    if not needs:
        return jnp.zeros((0,), jnp.int32)
    arr = jnp.stack(list(needs)).astype(jnp.int32)
    return jax.lax.pmax(arr, dp) if dp else arr


def _skr_shard_body(
    snap, delta, q_rects, q_bm, wids, bits, *, widths, take, dp, narrow, compact,
):
    """Per-shard SKR serving: the real frontier descent on the local query
    shard against the replicated snapshot (and replicated delta, when one
    is live; no cross-shard collectives except the width-maxima pmax).
    ``narrow`` (static) routes the descent through the bandwidth-lean planes
    using the pre-sharded packed query words (``wids``/``bits`` -- packed
    before ``shard_map`` so every shard agrees on the static Wp).
    ``compact`` (static) controls the leaf-local compact verify bank."""
    plan = ExecutionPlan(tag="skr", widths=widths)
    frontier, surv, nodes_checked, _, needs = _descend_frontier(
        snap, q_rects, q_bm, plan, delta, (wids, bits) if narrow else None
    )
    top_leaf, leaf_ok, overflow = _select_leaves_frontier(
        frontier, surv, take, snap.n_leaves
    )
    ids, counts, kw_scanned = _verify_leaves(
        snap, q_rects, q_bm, top_leaf, leaf_ok, delta, compact=compact
    )
    return ids, counts, nodes_checked, kw_scanned, overflow, _pmax_needs(needs, dp)


@functools.partial(
    jax.jit, static_argnames=("mesh", "widths", "take", "narrow", "compact")
)
def _skr_sharded_exec(
    snap, delta, q_rects, q_bm, wids, bits, mesh, widths, take, narrow, compact,
):
    dp = dp_axes(mesh)
    body = functools.partial(
        _skr_shard_body, widths=widths, take=take, dp=dp, narrow=narrow,
        compact=compact,
    )
    fn = shard_map(
        body,
        mesh=mesh,
        # snapshot + delta replicated (P() prefix; delta=None is an empty
        # pytree, so the same spec covers the no-delta fast path); queries
        # and their packed words sharded on the data axes
        in_specs=(P(), P(), P(dp, None), P(dp, None), P(dp, None), P(dp, None)),
        out_specs=(P(dp, None), P(dp), P(dp), P(dp), P(dp), P()),
        check_vma=False,
    )
    return fn(snap, delta, q_rects, q_bm, wids, bits)


def serve_sharded(
    snap: IndexSnapshot,
    q_rects,
    q_bm,
    max_leaves: int = 32,
    mesh: Optional[Mesh] = None,
    plan_cache: Optional[PlanCache] = None,
    minimum_bucket: int = 8,
    delta: Optional[DeltaBuffer] = None,
    compact: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """Data-parallel SKR serving of the real hierarchical engine.

    Args:
        snap: the served ``IndexSnapshot`` (replicated over ``mesh``).
        q_rects: (m, 4) f32 query rectangles; ``q_bm``: (m, W) u32 bitmaps.
        max_leaves: per-query verification capacity (spill -> ``overflow``).
        mesh: serving mesh (None: all local devices on the data axis).
        plan_cache: frontier width state (None: per-snapshot default).
        minimum_bucket: smallest per-shard power-of-two batch bucket.
        delta: optional ``DeltaBuffer`` of buffered updates, replicated like
            the snapshot and merged per shard (DESIGN.md §7).
        compact: leaf verification width -- None (default) auto-uses the
            compact leaf bank; False forces full width (DESIGN.md §3.5).

    Pads the batch to ``n_shards`` equal power-of-two buckets, replicates the
    snapshot, shard_maps the frontier descent over the mesh's data axes, and
    converges the plan cache by grow-and-redescend (see module docstring).
    Host-side driver around the jit-traced shard_map body. Returns the same
    per-query dict as the single-device ``retrieve`` -- identical ids and
    counters (tests/test_sharded_parity.py).
    """
    mesh = mesh if mesh is not None else default_serving_mesh()
    cache = plan_cache if plan_cache is not None else default_plan_cache(snap)
    rects, bms, m = pad_queries_to_bucket(
        q_rects, q_bm, minimum_bucket, shards=mesh_dp_size(mesh)
    )
    # pack the padded batch's query words before sharding (static Wp shared
    # by every shard; pad rows are all-zero bitmaps, so their words are 0)
    narrow = delta is None and snap.has_narrow_planes
    wids, bits = ops.pack_query_words(bms)
    rects, bms, wids, bits = _shard_queries(mesh, rects, bms, wids, bits)
    snap_r = _replicated(snap, mesh)
    delta_r = _replicated(delta, mesh) if delta is not None else None

    def run(widths):
        leaf_width = widths[-1] if widths else snap.root_width()
        take = min(max_leaves, snap.n_leaves, leaf_width)
        return _skr_sharded_exec(
            snap_r, delta_r, rects, bms, wids, bits, mesh, widths, take, narrow,
            compact,
        )

    widths, out = _converge_widths(snap, cache, "skr", run)
    ids, counts, nodes_checked, kw_scanned, overflow, _ = out
    used = [snap.root_width(), *widths]
    return dict(
        ids=np.asarray(ids)[:m],
        counts=np.asarray(counts)[:m],
        nodes_checked=np.asarray(nodes_checked, np.int64)[:m],
        nodes_scanned=np.full((m,), sum(used), np.int64),
        verified=np.asarray(kw_scanned)[:m],
        overflow=np.asarray(overflow)[:m],
        frontier_widths=np.asarray(used, np.int32),
    )


def _knn_shard_body(
    snap, delta, points, q_bm, wids, bits, *, widths, k, kb, dp, narrow, compact,
):
    """Per-shard Boolean kNN: the real distance-bounded descent on the local
    query shard against the replicated snapshot (and replicated delta).
    ``narrow`` (static) routes the level filters through the bandwidth-lean
    planes with the pre-sharded packed query words; ``compact`` (static)
    controls the compact leaf keyword-test bank."""
    plan = ExecutionPlan(tag="knn", widths=widths)
    result, needs = _descend_knn(
        snap, points, q_bm, k, kb, plan, delta, (wids, bits) if narrow else None,
        cbank=_snap_cbank(snap, compact),
    )
    top_d, top_id, nodes_checked, verified, leaves_verified, pruned, _, _ = result
    fin = jnp.isfinite(top_d[:, :k])
    ids = jnp.where(fin, top_id[:, :k], -1)
    return (
        ids, top_d[:, :k], nodes_checked, verified, leaves_verified, pruned,
        _pmax_needs(needs, dp),
    )


@functools.partial(
    jax.jit, static_argnames=("mesh", "widths", "k", "kb", "narrow", "compact")
)
def _knn_sharded_exec(
    snap, delta, points, q_bm, wids, bits, mesh, widths, k, kb, narrow, compact,
):
    dp = dp_axes(mesh)
    body = functools.partial(
        _knn_shard_body, widths=widths, k=k, kb=kb, dp=dp, narrow=narrow,
        compact=compact,
    )
    fn = shard_map(
        body,
        mesh=mesh,
        # snapshot + delta replicated (P() prefix; None delta = empty pytree)
        in_specs=(P(), P(), P(dp, None), P(dp, None), P(dp, None), P(dp, None)),
        out_specs=(
            P(dp, None), P(dp, None), P(dp), P(dp), P(dp), P(dp), P(),
        ),
        check_vma=False,
    )
    return fn(snap, delta, points, q_bm, wids, bits)


def serve_knn_sharded(
    snap: IndexSnapshot,
    points,
    q_bm,
    k: int,
    mesh: Optional[Mesh] = None,
    plan_cache: Optional[PlanCache] = None,
    minimum_bucket: int = 8,
    min_topk_bucket: int = 8,
    delta: Optional[DeltaBuffer] = None,
    compact: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """Data-parallel Boolean kNN serving of the real bounded descent.

    Args:
        snap: the served ``IndexSnapshot`` (replicated over ``mesh``).
        points: (m, 2) f32 query points; ``q_bm``: (m, W) u32 bitmaps.
        k: neighbors per query (static; each k compiles its own descent).
        mesh: serving mesh (None: all local devices on the data axis).
        plan_cache: frontier width state (None: per-snapshot default).
        minimum_bucket / min_topk_bucket: power-of-two bucket floors for
            the per-shard batch and the on-device top-k buffer.
        delta: optional ``DeltaBuffer`` of buffered updates, replicated like
            the snapshot and merged per shard (DESIGN.md §7).

    Same regime as ``serve_sharded``: replicated snapshot, query batch
    sharded over the data axes, seeded-width descent with grow-and-redescend
    convergence. Host-side driver around the jit-traced shard_map body.
    Identical ids/dist2/counters to ``retrieve_knn``.
    """
    if k <= 0:  # delegate: one source of truth for the degenerate shape
        return retrieve_knn(snap, points, q_bm, k, delta=delta, compact=compact)
    mesh = mesh if mesh is not None else default_serving_mesh()
    cache = plan_cache if plan_cache is not None else default_plan_cache(snap)
    pts, bms, m = pad_knn_queries_to_bucket(
        points, q_bm, minimum_bucket, shards=mesh_dp_size(mesh)
    )
    narrow = delta is None and snap.has_narrow_planes
    wids, bits = ops.pack_query_words(bms)
    pts, bms, wids, bits = _shard_queries(mesh, pts, bms, wids, bits)
    snap_r = _replicated(snap, mesh)
    delta_r = _replicated(delta, mesh) if delta is not None else None
    kb = round_up_bucket(k, min_topk_bucket)

    widths, out = _converge_widths(
        snap, cache, "knn",
        lambda widths: _knn_sharded_exec(
            snap_r, delta_r, pts, bms, wids, bits, mesh, widths, k, kb, narrow,
            compact,
        ),
    )
    ids, dist2, nodes_checked, verified, leaves_verified, pruned, _ = out
    used = [snap.root_width(), *widths]
    return dict(
        ids=np.asarray(ids)[:m],
        dist2=np.asarray(dist2)[:m],
        nodes_checked=np.asarray(nodes_checked, np.int64)[:m],
        verified=np.asarray(verified, np.int64)[:m],
        leaves_verified=np.asarray(leaves_verified, np.int64)[:m],
        pruned=np.asarray(pruned, np.int64)[:m],
        frontier_widths=np.asarray(used, np.int32),
    )


# --------------------------------- index-parallel sharded serving (§3.4)
def mesh_index_size(mesh: Mesh) -> int:
    """Number of index shards: the size of the mesh's ``index`` axis."""
    return int(mesh.shape["index"]) if "index" in mesh.axis_names else 1


def default_index_mesh(n_shards: int) -> Mesh:
    """All local devices as a (query, index) serving mesh with ``n_shards``
    index shards (the remaining factor goes to query parallelism)."""
    n = len(jax.devices())
    if n % n_shards:
        raise ValueError(f"{n} devices not divisible into {n_shards} index shards")
    return make_serving_mesh(query=n // n_shards, index=n_shards)


# Placement memos, mirroring _REPLICATED: sharding a production-scale
# partition (or a delta routed to its shards) must happen once per
# (object, mesh), not once per served batch.
_PLACED: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _placed(psnap: PartitionedSnapshot, mesh: Mesh) -> PartitionedSnapshot:
    per_mesh = _PLACED.get(psnap)
    if per_mesh is None:
        per_mesh = {}
        _PLACED[psnap] = per_mesh
    got = per_mesh.get(mesh)
    if got is None:
        got = psnap.shard(mesh)
        per_mesh[mesh] = got
    return got


# Keyed by the (immutable) DeltaBuffer: every LiveIndex update produces a
# NEW buffer, so a fresh buffer is partitioned -- routed to its owning
# shards -- exactly once, on its first served batch.
_PARTITIONED_DELTA: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _partitioned_delta(delta: DeltaBuffer, psnap: PartitionedSnapshot, mesh: Mesh):
    per_key = _PARTITIONED_DELTA.get(delta)
    if per_key is None:
        per_key = {}
        _PARTITIONED_DELTA[delta] = per_key
    got = per_key.get((mesh, psnap.part))
    if got is None:
        got = jax.device_put(
            partition_delta(delta, psnap.part),
            NamedSharding(mesh, P("index")),
        )
        per_key[(mesh, psnap.part)] = got
    return got


def _converge_widths_indexed(cache: PlanCache, tag: str, n_shards: int, n_links: int, run):
    """Index-sharded twin of ``_converge_widths``: the observed per-level
    child-count maxima come back as an (S, n_links) matrix (each index
    shard's own hierarchy has its own fan-outs), the cache learns per-shard
    sub-tags, and every shard of the next descent traces at the max width
    over shards (``seeded_shard_plan`` -- SPMD needs one static shape)."""
    while True:
        widths = cache.seeded_shard_plan(tag, n_shards, n_links).widths
        out = run(widths)
        maxima = np.asarray(jax.device_get(out[-1])).reshape(n_shards, -1)
        cache.observe_shards(tag, maxima)
        if not n_links or not np.any(maxima.max(axis=0) > np.asarray(widths)):
            return widths, out


def _ix_skr_body(
    psnap, delta, q_rects, q_bm, wids, bits,
    *, widths, take_g, take_loc, n_shards, dp, narrow, compact,
):
    """Per-(query shard, index shard) SKR body: the unchanged engine descent
    on this device's sub-hierarchy from its masked local root frontier, then
    two collectives over ``index`` -- the global smallest-gid leaf selection
    (``_select_leaves_indexed``: one bound exchange + psum'd overflow) and
    the psum of the Eq.1 counters. Result ids stay local (the out_spec
    concatenates the per-shard id unions); counters leave the body already
    global, exactly matching the single-device descent."""
    snap = psnap.local_view()
    M = q_rects.shape[0]
    n_root_local = psnap.level_counts[0, 0]
    plan = ExecutionPlan(tag="skr_ix", widths=widths)
    root = _local_root_frontier(snap.root_width(), n_root_local, M)
    frontier, surv, nodes_checked, _, needs = _descend_frontier(
        snap, q_rects, q_bm, plan, delta, (wids, bits) if narrow else None,
        root=root,
    )
    top_leaf, leaf_ok, overflow = _select_leaves_indexed(
        frontier, surv, psnap.leaf_gid, take_g, take_loc, n_shards, "index"
    )
    ids, counts, kw_scanned = _verify_leaves(
        snap, q_rects, q_bm, top_leaf, leaf_ok, delta, compact=compact
    )
    counts = jax.lax.psum(counts, "index")
    nodes_checked = jax.lax.psum(nodes_checked, "index")
    kw_scanned = jax.lax.psum(kw_scanned, "index")
    needs_all = jax.lax.all_gather(_pmax_needs(needs, dp), "index")  # (S, links)
    return ids, counts, nodes_checked, kw_scanned, overflow, needs_all


@functools.partial(
    jax.jit,
    static_argnames=(
        "mesh", "widths", "take_g", "take_loc", "n_shards", "narrow", "compact",
    ),
)
def _ix_skr_exec(
    psnap, delta, q_rects, q_bm, wids, bits, mesh, widths, take_g, take_loc,
    n_shards, narrow, compact,
):
    dp = dp_axes(mesh)
    body = functools.partial(
        _ix_skr_body, widths=widths, take_g=take_g, take_loc=take_loc,
        n_shards=n_shards, dp=dp, narrow=narrow, compact=compact,
    )
    fn = shard_map(
        body,
        mesh=mesh,
        # partition + routed delta sharded over "index" (single prefix spec
        # over the whole pytree; None delta is an empty pytree); queries and
        # packed words sharded over the data axes, replicated over "index"
        in_specs=(
            P("index"), P("index"), P(dp, None), P(dp, None), P(dp, None), P(dp, None),
        ),
        # ids: concat of the per-shard id unions; counters already psum'd
        out_specs=(P(dp, "index"), P(dp), P(dp), P(dp), P(dp), P()),
        check_vma=False,
    )
    return fn(psnap, delta, q_rects, q_bm, wids, bits)


def serve_index_sharded(
    psnap: PartitionedSnapshot,
    q_rects,
    q_bm,
    max_leaves: int = 32,
    mesh: Optional[Mesh] = None,
    plan_cache: Optional[PlanCache] = None,
    minimum_bucket: int = 8,
    delta: Optional[DeltaBuffer] = None,
    compact: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """Index-parallel SKR serving: the hierarchy itself sharded (§3.4).

    Args:
        psnap: a ``PartitionedSnapshot`` (``PartitionedSnapshot.build``);
            each device holds only its ~1/S slab after placement.
        q_rects: (m, 4) f32 query rectangles; ``q_bm``: (m, W) u32 bitmaps.
        max_leaves: per-query verification capacity (global: the selection
            keeps the ``max_leaves`` smallest-id surviving leaves ACROSS
            shards, exactly like the single-device engine; spill ->
            ``overflow``).
        mesh: a serving mesh with an ``index`` axis of size
            ``psnap.n_shards`` (None: all local devices, query x index).
        plan_cache: frontier width state (None: per-partition default);
            learns per-shard sub-tags (``PlanCache.seeded_shard_plan``).
        minimum_bucket: smallest per-query-shard power-of-two batch bucket.
        delta: optional ``DeltaBuffer`` in the ordinary global layout --
            routed to the owning shards (``delta.partition_delta``, memoized
            per buffer) and merged shard-locally.

    Returns the ``retrieve`` dict: ``counts``/``nodes_checked``/``verified``
    /``overflow`` exactly equal to the single-device engine, ``ids`` the
    same id SET per query (order is shard-concatenation order, not the
    single-device capacity order). ``nodes_scanned`` sums every shard's
    frontier slots -- the only counter that is layout-dependent by design
    (see tests/test_index_sharded_parity.py).
    """
    S = psnap.n_shards
    mesh = mesh if mesh is not None else default_index_mesh(S)
    if mesh_index_size(mesh) != S:
        raise ValueError(
            f"mesh index axis {mesh_index_size(mesh)} != partition shards {S}"
        )
    cache = plan_cache if plan_cache is not None else default_plan_cache(psnap)
    rects, bms, m = pad_queries_to_bucket(
        q_rects, q_bm, minimum_bucket, shards=mesh_dp_size(mesh)
    )
    narrow = delta is None and psnap.has_narrow_planes
    wids, bits = ops.pack_query_words(bms)
    rects, bms, wids, bits = _shard_queries(mesh, rects, bms, wids, bits)
    psnap_s = _placed(psnap, mesh)
    delta_s = _partitioned_delta(delta, psnap, mesh) if delta is not None else None
    n_links = psnap.n_levels - 1

    def run(widths):
        leaf_width = widths[-1] if widths else psnap.local_root_width()
        take_g = min(max_leaves, psnap.n_leaves_global)
        take_loc = min(take_g, leaf_width)
        return _ix_skr_exec(
            psnap_s, delta_s, rects, bms, wids, bits, mesh, widths,
            take_g, take_loc, S, narrow, compact,
        )

    widths, out = _converge_widths_indexed(cache, "skr_ix", S, n_links, run)
    ids, counts, nodes_checked, kw_scanned, overflow, _ = out
    used = [psnap.local_root_width(), *widths]
    return dict(
        ids=np.asarray(ids)[:m],
        counts=np.asarray(counts)[:m],
        nodes_checked=np.asarray(nodes_checked, np.int64)[:m],
        nodes_scanned=np.full((m,), sum(used) * S, np.int64),
        verified=np.asarray(kw_scanned)[:m],
        overflow=np.asarray(overflow)[:m],
        frontier_widths=np.asarray(used, np.int32),
    )


def _ix_knn_body(
    psnap, delta, points, q_bm, wids, bits,
    *, widths, k, kb, n_shards, dp, narrow, compact,
):
    """Per-(query shard, index shard) kNN body: ``_descend_knn_indexed``
    (canonical-probe election, shard-local bounded sweep, global-rank leaf
    phase) plus the counter psums. The top-k buffers leave the descent
    already replicated across shards (the leaf phase ends on a global
    merge), so the out_spec just takes one copy."""
    snap = psnap.local_view()
    n_root_local = psnap.level_counts[0, 0]
    plan = ExecutionPlan(tag="knn_ix", widths=widths)
    result, needs = _descend_knn_indexed(
        snap, psnap.root_gid, psnap.leaf_gid, n_root_local, points, q_bm,
        k, kb, plan, n_shards, "index", delta, (wids, bits) if narrow else None,
        cbank=_snap_cbank(snap, compact),
    )
    top_d, top_id, nodes_checked, verified, leaves_verified, pruned, _ = result
    nodes_checked = jax.lax.psum(nodes_checked, "index")
    verified = jax.lax.psum(verified, "index")
    leaves_verified = jax.lax.psum(leaves_verified, "index")
    pruned = jax.lax.psum(pruned, "index")
    fin = jnp.isfinite(top_d[:, :k])
    ids = jnp.where(fin, top_id[:, :k], -1)
    needs_all = jax.lax.all_gather(_pmax_needs(needs, dp), "index")  # (S, links)
    return (
        ids, top_d[:, :k], nodes_checked, verified, leaves_verified, pruned,
        needs_all,
    )


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "widths", "k", "kb", "n_shards", "narrow", "compact"),
)
def _ix_knn_exec(
    psnap, delta, points, q_bm, wids, bits, mesh, widths, k, kb, n_shards,
    narrow, compact,
):
    dp = dp_axes(mesh)
    body = functools.partial(
        _ix_knn_body, widths=widths, k=k, kb=kb, n_shards=n_shards, dp=dp,
        narrow=narrow, compact=compact,
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P("index"), P("index"), P(dp, None), P(dp, None), P(dp, None), P(dp, None),
        ),
        # top-k buffers are replicated over "index" after the final merge
        out_specs=(
            P(dp, None), P(dp, None), P(dp), P(dp), P(dp), P(dp), P(),
        ),
        check_vma=False,
    )
    return fn(psnap, delta, points, q_bm, wids, bits)


def serve_knn_index_sharded(
    psnap: PartitionedSnapshot,
    points,
    q_bm,
    k: int,
    mesh: Optional[Mesh] = None,
    plan_cache: Optional[PlanCache] = None,
    minimum_bucket: int = 8,
    min_topk_bucket: int = 8,
    delta: Optional[DeltaBuffer] = None,
    compact: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """Index-parallel Boolean kNN serving: the hierarchy itself sharded.

    Same contract as ``serve_knn_sharded`` but over a
    ``PartitionedSnapshot``: ids/dist2 AND every counter except
    ``frontier_widths`` are exactly equal to the single-device
    ``retrieve_knn`` (the bound-exchange collectives in
    ``_descend_knn_indexed`` reproduce the same probe chain, prune
    decisions, and chunked leaf order -- tests/test_index_sharded_parity.py).
    ``delta`` arrives in the global layout and is routed to the owning
    shards. Always exact f32 (``knn_dtype`` is a replicated-path flag).
    """
    if k <= 0:  # delegate: one source of truth for the degenerate shape
        M = int(np.asarray(points).reshape(-1, 2).shape[0])
        z = np.zeros(M, np.int64)
        return dict(
            ids=np.zeros((M, 0), np.int32), dist2=np.zeros((M, 0), np.float32),
            nodes_checked=z, verified=z.copy(), leaves_verified=z.copy(),
            pruned=z.copy(), frontier_widths=np.zeros(0, np.int32),
        )
    S = psnap.n_shards
    mesh = mesh if mesh is not None else default_index_mesh(S)
    if mesh_index_size(mesh) != S:
        raise ValueError(
            f"mesh index axis {mesh_index_size(mesh)} != partition shards {S}"
        )
    cache = plan_cache if plan_cache is not None else default_plan_cache(psnap)
    pts, bms, m = pad_knn_queries_to_bucket(
        points, q_bm, minimum_bucket, shards=mesh_dp_size(mesh)
    )
    narrow = delta is None and psnap.has_narrow_planes
    wids, bits = ops.pack_query_words(bms)
    pts, bms, wids, bits = _shard_queries(mesh, pts, bms, wids, bits)
    psnap_s = _placed(psnap, mesh)
    delta_s = _partitioned_delta(delta, psnap, mesh) if delta is not None else None
    kb = round_up_bucket(k, min_topk_bucket)
    n_links = psnap.n_levels - 1

    widths, out = _converge_widths_indexed(
        cache, "knn_ix", S, n_links,
        lambda widths: _ix_knn_exec(
            psnap_s, delta_s, pts, bms, wids, bits, mesh, widths, k, kb, S,
            narrow, compact,
        ),
    )
    ids, dist2, nodes_checked, verified, leaves_verified, pruned, _ = out
    used = [psnap.local_root_width(), *widths]
    return dict(
        ids=np.asarray(ids)[:m],
        dist2=np.asarray(dist2)[:m],
        nodes_checked=np.asarray(nodes_checked, np.int64)[:m],
        verified=np.asarray(verified, np.int64)[:m],
        leaves_verified=np.asarray(leaves_verified, np.int64)[:m],
        pruned=np.asarray(pruned, np.int64)[:m],
        frontier_widths=np.asarray(used, np.int32),
    )


# ------------------------------- incremental maintenance front door (§7)
@dataclasses.dataclass(frozen=True)
class ServingGeneration:
    """One immutable serving epoch (DESIGN.md §7).

    Everything a request touches -- snapshot, delta log, plan cache, the
    backing dataset and artifacts -- is bundled so replacing a generation is
    ONE reference store (``LiveIndex._gen = new``): an in-flight batch that
    grabbed the old generation keeps serving a consistent view; the next
    batch sees the new one. ``seq`` increments per swap.
    """

    artifacts: object  # core.build.BuildArtifacts
    dataset: object  # core.types.GeoTextDataset
    snapshot: IndexSnapshot
    delta_log: DeltaLog
    plan_cache: PlanCache
    seq: int = 0
    # index-parallel regime: the snapshot's partition, rebuilt per
    # generation (a rebuild re-cuts the fresh hierarchy); None = replicated
    partitioned: Optional[PartitionedSnapshot] = None

    def delta(self) -> Optional[DeltaBuffer]:
        """The live delta, or None when no updates are buffered (the
        executors' zero-overhead fast path)."""
        return self.delta_log.buffer if self.delta_log.n_updates() else None


class LiveIndex:
    """Serving front door that survives live traffic (DESIGN.md §7).

    Ties the incremental subsystem together: object updates land in the
    current generation's ``DeltaLog`` and are merged into every query on
    the fly; a ``DriftMonitor`` watches the observed per-query Eq.1 cost;
    and ``maybe_rebuild()`` reacts to a trip by warm-start rebuilding on
    the recently observed workload and atomically swapping in the fresh
    ``IndexSnapshot`` -- serving never blocks on a rebuild, in-flight
    batches finish on the generation they started on.

    All methods are host-side control plane; the descents they drive are
    the jit-traced engine paths. Single-writer discipline: updates and
    swaps are expected from one maintenance thread; readers may hold
    ``generation`` freely.
    """

    def __init__(
        self,
        dataset,
        workload,
        build_config=None,
        drift_config=None,
        artifacts=None,
        max_recent: int = 512,
        slots_per_leaf: int = 8,
        result_cache: Optional[HotQueryCache] = None,
        index_shards: int = 1,
        index_mesh: Optional[Mesh] = None,
    ) -> None:
        from ..core.build import BuildConfig, build_wisk
        from ..core.drift import DriftMonitor

        self.build_config = build_config or BuildConfig()
        self._slots_per_leaf = slots_per_leaf
        # index-parallel serving (§3.4): partition every generation's
        # snapshot into this many shard-local sub-hierarchies and serve over
        # the (query, index) mesh; updates keep landing in the global-layout
        # DeltaLog and are routed to their owning shards per served batch
        # (memoized per buffer -- see _partitioned_delta)
        self.index_shards = int(index_shards)
        self.index_mesh = index_mesh
        if self.index_mesh is not None and self.index_shards == 1:
            self.index_shards = mesh_index_size(self.index_mesh)
        # hot-query result cache (§3.5): exact results keyed on the current
        # served state, so every state change below must invalidate it
        self.result_cache = result_cache
        if artifacts is None:
            artifacts = build_wisk(dataset, workload, self.build_config)
        self._gen = self._make_generation(artifacts, dataset, seq=0)
        # continuous-filter pub-sub (DESIGN.md §8): the standing-subscription
        # index + notification log live on the front door, NOT on a
        # generation -- subscriptions, queued notifications, and the
        # exactly-once high-water mark (global object ids are monotonic
        # across rebuilds) all survive maybe_rebuild() swaps untouched
        self.subscriptions = SubscriptionIndex(dataset.vocab_size)
        # baseline learned from the warmup window of observed traffic (see
        # core/drift.py: a trained-workload prediction undershoots steady
        # state by the generalization gap)
        self.monitor = DriftMonitor(None, drift_config)
        self.max_recent = max_recent
        self._recent_rects: list = []
        self._recent_bms: list = []
        self.swaps = 0

    def _make_generation(self, artifacts, dataset, seq: int) -> ServingGeneration:
        snapshot = IndexSnapshot.build(artifacts.index, dataset)
        partitioned = (
            PartitionedSnapshot.build(snapshot, self.index_shards)
            if self.index_shards > 1 else None
        )
        return ServingGeneration(
            artifacts=artifacts,
            dataset=dataset,
            snapshot=snapshot,
            delta_log=DeltaLog(artifacts.index, dataset, snapshot, self._slots_per_leaf),
            plan_cache=PlanCache(),
            seq=seq,
            partitioned=partitioned,
        )

    @property
    def generation(self) -> ServingGeneration:
        """The current generation; grab once per batch for a stable view."""
        return self._gen

    # ------------------------------------------------------------- serving
    def _record(self, rects, bms) -> None:
        self._recent_rects.extend(np.asarray(rects, np.float32).reshape(-1, 4))
        self._recent_bms.extend(np.asarray(bms, np.uint32).reshape(len(rects), -1))
        drop = len(self._recent_rects) - self.max_recent
        if drop > 0:
            del self._recent_rects[:drop]
            del self._recent_bms[:drop]

    def serve(self, q_rects, q_bm, max_leaves: int = 32) -> Dict[str, np.ndarray]:
        """Delta-merged SKR batch through the current generation; feeds the
        drift monitor with the observed Eq.1 counters.

        With a ``result_cache`` the batch goes through ``serve_batch_cached``
        and only MISS rows feed the monitor -- cache hits cost nothing, and
        counting them would mask drift in exactly the hot traffic a rebuild
        should follow.

        In the index-parallel regime (``index_shards > 1``) the batch goes
        through ``serve_index_sharded`` over the partitioned snapshot, with
        the live delta routed to its owning shards; the result cache is
        bypassed (counters are identical either way, so the monitor feed is
        unchanged)."""
        gen = self._gen
        if gen.partitioned is not None:
            out = serve_index_sharded(
                gen.partitioned, q_rects, q_bm, max_leaves,
                mesh=self.index_mesh, plan_cache=gen.plan_cache,
                delta=gen.delta(),
            )
            self._record(q_rects, q_bm)
            self.monitor.observe_counters(
                np.asarray(out["nodes_checked"]), np.asarray(out["verified"])
            )
            return out
        if self.result_cache is not None:
            out = serve_batch_cached(
                gen.snapshot, q_rects, q_bm, self.result_cache, max_leaves,
                plan_cache=gen.plan_cache, delta=gen.delta(),
            )
            fresh = ~out["cached"]
        else:
            out = serve_batch(
                gen.snapshot, q_rects, q_bm, max_leaves,
                plan_cache=gen.plan_cache, delta=gen.delta(),
            )
            fresh = slice(None)
        self._record(q_rects, q_bm)
        nc = np.asarray(out["nodes_checked"])[fresh]
        if nc.size:  # an all-hit batch observed no real descents
            self.monitor.observe_counters(nc, np.asarray(out["verified"])[fresh])
        return out

    def serve_knn(self, points, q_bm, k: int) -> Dict[str, np.ndarray]:
        """Delta-merged Boolean kNN batch through the current generation.

        kNN traffic enters the recent-traffic window as zero-area point
        rects, so kNN-driven drift both trips the monitor AND steers the
        rebuild's training workload toward the traffic that tripped it."""
        gen = self._gen
        if gen.partitioned is not None:
            out = serve_knn_index_sharded(
                gen.partitioned, points, q_bm, k,
                mesh=self.index_mesh, plan_cache=gen.plan_cache,
                delta=gen.delta(),
            )
        else:
            out = serve_knn_batch(
                gen.snapshot, points, q_bm, k,
                plan_cache=gen.plan_cache, delta=gen.delta(),
            )
        pts = np.asarray(points, np.float32).reshape(-1, 2)
        self._record(np.concatenate([pts, pts], axis=1), q_bm)
        self.monitor.observe_counters(out["nodes_checked"], out["verified"])
        return out

    # ------------------------------------------------------------- updates
    def insert(self, locs, kw_ids) -> np.ndarray:
        """Buffer new objects into the current generation's delta log;
        visible to the very next query. Returns the assigned global ids.

        In the same step, the arrivals are matched on device against the
        compiled subscription block (DESIGN.md §8): any standing filter they
        satisfy queues an (object_id, subscription_id) notification for
        ``drain_notifications()``."""
        if self.result_cache is not None:
            self.result_cache.invalidate()
        ids = self._gen.delta_log.insert(locs, kw_ids)
        self.subscriptions.match_arrivals(ids, locs, kw_ids=kw_ids)
        return ids

    def delete(self, ids) -> int:
        """Mask objects out of serving immediately; returns #newly deleted.

        Deletion never retracts a queued notification -- the object *did*
        arrive while the matching subscriptions were live (§8 contract)."""
        if self.result_cache is not None:
            self.result_cache.invalidate()
        return self._gen.delta_log.delete(ids)

    # -------------------------------------------- continuous filters (§8)
    def subscribe(self, rect, kw_ids) -> int:
        """Register a standing spatio-textual filter (geofence); returns its
        subscription id. Matches objects inserted from now on: each
        ``insert`` batch is matched on device against the compiled
        subscription block in the same step it enters the delta log."""
        return self.subscriptions.subscribe(rect, kw_ids)

    def unsubscribe(self, sub_id: int) -> bool:
        """Retire a standing filter; already-queued notifications survive."""
        return self.subscriptions.unsubscribe(sub_id)

    def drain_notifications(self) -> np.ndarray:
        """All queued (object_id, subscription_id) notifications, exactly
        once -- across buffer growth, freed-slot reuse, deletes, and
        rebuild swaps (the subscription state lives on the front door, and
        the exactly-once mark rides the monotonic global id space, which a
        swap continues rather than restarts)."""
        return self.subscriptions.drain()

    # ------------------------------------------------------------- rebuild
    def observed_workload(self):
        """The recent-traffic window as a trainable ``Workload``."""
        from ..core.drift import observed_workload

        gen = self._gen
        return observed_workload(
            np.asarray(self._recent_rects, np.float32),
            np.asarray(self._recent_bms, np.uint32),
            gen.dataset.vocab_size,
        )

    def maybe_rebuild(self, force: bool = False, min_observed: int = 16) -> bool:
        """Warm-start rebuild + atomic swap when the drift monitor tripped
        (or ``force``). Returns True when a swap happened.

        The rebuild runs on the *merged* dataset (base + buffered inserts,
        deletes tombstoned) and the recently observed workload; the old
        generation keeps serving until the single reference store at the
        end -- the atomicity contract pinned by
        tests/test_delta_maintenance.py.
        """
        from ..core.build import warm_start_rebuild

        if not (force or self.monitor.triggered):
            return False
        if len(self._recent_rects) < min_observed:
            return False
        gen = self._gen
        merged = gen.delta_log.merged_dataset()
        wl = self.observed_workload()
        artifacts = warm_start_rebuild(
            merged, wl, gen.artifacts, self.build_config,
            assign=gen.delta_log.merged_assignment(),
        )
        new_gen = self._make_generation(artifacts, merged, seq=gen.seq + 1)
        self._gen = new_gen  # THE swap: one reference store
        if self.result_cache is not None:
            self.result_cache.invalidate()  # cached rows belong to the old gen
        self.monitor.rearm()  # back to warmup: re-learn the baseline
        self.swaps += 1
        return True


# ------------------------------------ legacy flat fallback (retired, §3.4)
# The hierarchy-free leaf-sharded scan now lives in launch/flat_legacy.py as
# a documented legacy path (dry-run/roofline surface + A/B floor); these
# re-exports keep historical imports working.
from .flat_legacy import (  # noqa: E402,F401
    OBJ_PER_LEAF as OBJ_PER_LEAF,
    TOP_LEAVES_LOCAL as TOP_LEAVES_LOCAL,
    lower_wisk_serve,
    make_inputs,
    wisk_serve_step,
)
