"""WISK serving on the production mesh: the paper's own dry-run cell.

The batched SKR pipeline distributes queries over the data axes and index
leaves (with their object blocks) over ``model``; each device filters its
local leaves against its local queries, verifies the capacity-bounded
candidates of its best local leaves, and per-query counts are ``psum``-ed
over ``model``. This is exactly the Eq.1 filter/verify split mapped onto
jax-native collectives (DESIGN.md §3). On TPU the two inner loops are the
Pallas kernels; the dry-run lowers the jnp reference math (identical
semantics -- Mosaic kernels cannot target the CPU placeholder backend).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..sharding.compat import shard_map

from ..configs.wisk import WiskServeConfig
from ..kernels.ops import NEVER_RECT
from ..kernels.ref import skr_filter_ref, skr_verify_ref
from ..serve.engine import BatchedWisk, retrieve, retrieve_knn, round_up_bucket
from ..sharding.rules import dp_axes

OBJ_PER_LEAF = 512
TOP_LEAVES_LOCAL = 4


# ------------------------------------------------- batch/frontier bucketing
def pad_queries_to_bucket(q_rects, q_bm, minimum: int = 8):
    """Pad an incoming query batch to its power-of-two bucket.

    The frontier descent (serve.engine) retraces per (batch, frontier-width)
    shape; bucketing the batch dimension here -- like the engine buckets
    frontier widths -- keeps the set of compiled shapes logarithmic in the
    largest batch ever seen. Pad queries use never-intersecting rects and
    empty bitmaps, so they survive no filter and verify nothing.
    """
    q_rects = np.asarray(q_rects, np.float32)
    q_bm = np.asarray(q_bm, np.uint32)
    m = q_rects.shape[0]
    bucket = round_up_bucket(m, minimum)
    if bucket == m:
        return q_rects, q_bm, m
    pad = bucket - m
    rects = np.concatenate(
        [q_rects, np.tile(np.array([NEVER_RECT], np.float32), (pad, 1))], 0
    )
    bms = np.concatenate([q_bm, np.zeros((pad, q_bm.shape[1]), np.uint32)], 0)
    return rects, bms, m


def serve_batch(
    bw: BatchedWisk,
    q_rects,
    q_bm,
    max_leaves: int = 32,
    mode: str = "frontier",
    minimum_bucket: int = 8,
):
    """Bucketed front door for the batched engine: pad -> retrieve -> slice."""
    rects, bms, m = pad_queries_to_bucket(q_rects, q_bm, minimum_bucket)
    out = retrieve(bw, jnp.asarray(rects), jnp.asarray(bms), max_leaves, mode=mode)
    per_query = ("ids", "counts", "nodes_checked", "nodes_scanned", "verified", "overflow")
    return {k: (v[:m] if k in per_query else v) for k, v in out.items()}


def pad_knn_queries_to_bucket(points, q_bm, minimum: int = 8):
    """kNN twin of ``pad_queries_to_bucket``. Pad queries are inert because
    their all-zero bitmap fails the keyword AND, so every frontier slot
    scores +inf -- they verify nothing and return all ``-1`` ids. (The
    out-of-square pad point is only defensive: distance alone would NOT
    exclude a pad query.)"""
    points = np.asarray(points, np.float32)
    q_bm = np.asarray(q_bm, np.uint32)
    m = points.shape[0]
    bucket = round_up_bucket(m, minimum)
    if bucket == m:
        return points, q_bm, m
    pad = bucket - m
    pts = np.concatenate([points, np.full((pad, 2), 2.0, np.float32)], 0)
    bms = np.concatenate([q_bm, np.zeros((pad, q_bm.shape[1]), np.uint32)], 0)
    return pts, bms, m


def serve_knn_batch(
    bw: BatchedWisk,
    points,
    q_bm,
    k: int,
    minimum_bucket: int = 8,
):
    """Bucketed front door for batched Boolean kNN: pad -> retrieve -> slice.

    Batch widths bucket to powers of two exactly like ``serve_batch``; ``k``
    stays a static argument (each served k compiles its own descent, the
    workload classes of LIST-style top-k serving are few and fixed).
    """
    pts, bms, m = pad_knn_queries_to_bucket(points, q_bm, minimum_bucket)
    out = retrieve_knn(bw, jnp.asarray(pts), jnp.asarray(bms), k)
    per_query = ("ids", "dist2", "nodes_checked", "verified", "leaves_verified", "pruned")
    return {key: (v[:m] if key in per_query else v) for key, v in out.items()}


def wisk_serve_step(q_rects, q_bm, leaf_mbrs, leaf_bm, obj_x, obj_y, obj_bm, obj_valid,
                    two_stage: bool = False, stage2_cap: int = 512):
    """Local (per-device) filter + verify; counts psum'd over 'model'.

    q_*: local query shard; leaf_*/obj_*: local leaf shard.

    ``two_stage``: verify in-rectangle membership on the 8-byte (x, y) pairs
    first and gather the 512-byte keyword bitmaps only for the (capacity-
    bounded) spatial survivors -- the memory-roofline hillclimb of
    EXPERIMENTS.md section Perf (bitmap traffic drops ~C/stage2_cap).
    """
    M = q_rects.shape[0]
    rel = skr_filter_ref(q_rects, q_bm, leaf_mbrs, leaf_bm)  # (Mloc, Kloc) int8
    sizes = jnp.sum(obj_valid > 0, axis=1)  # (Kloc,)
    score = rel.astype(jnp.int32) * (1 + sizes[None, :])
    _, top_leaf = jax.lax.top_k(score, TOP_LEAVES_LOCAL)  # (Mloc, L)
    # gather candidate coordinate blocks for each (query, local leaf)
    cx = obj_x[top_leaf].reshape(M, -1)
    cy = obj_y[top_leaf].reshape(M, -1)
    cval = obj_valid[top_leaf].reshape(M, -1)
    # leaves not relevant contribute nothing
    leaf_ok = jnp.take_along_axis(rel, top_leaf, axis=1)  # (Mloc, L)
    cval = cval * jnp.repeat(leaf_ok, OBJ_PER_LEAF, axis=1)

    if two_stage:
        inr = (
            (cx >= q_rects[:, 0:1]) & (cx <= q_rects[:, 2:3])
            & (cy >= q_rects[:, 1:2]) & (cy <= q_rects[:, 3:4])
            & (cval > 0)
        )
        cap = min(stage2_cap, inr.shape[1])
        val2, idx2 = jax.lax.top_k(inr.astype(jnp.int32), cap)  # (Mloc, cap)
        # map surviving candidate slots back to (leaf, slot) for a narrow gather
        leaf_of = jnp.repeat(top_leaf, OBJ_PER_LEAF, axis=1)  # (Mloc, C)
        slot_of = jnp.tile(jnp.arange(OBJ_PER_LEAF), (M, TOP_LEAVES_LOCAL))
        sel_leaf = jnp.take_along_axis(leaf_of, idx2, axis=1)
        sel_slot = jnp.take_along_axis(slot_of, idx2, axis=1)
        cbm2 = obj_bm[sel_leaf, sel_slot]  # (Mloc, cap, W): bitmaps of survivors only
        kw = jnp.any((cbm2 & q_bm[:, None, :]) != 0, axis=-1)
        match = (kw & (val2 > 0)).astype(jnp.int32)
        counts = jnp.sum(match, axis=1)
        overflow = jnp.maximum(jnp.sum(inr.astype(jnp.int32), axis=1) - cap, 0)
        counts = counts + 0 * overflow  # overflow tracked by caller via scanned
    else:
        cbm = obj_bm[top_leaf].reshape(M, -1, q_bm.shape[1])
        match = skr_verify_ref(q_rects, q_bm, cx, cy, cbm, cval)  # (Mloc, C) int8
        counts = jnp.sum(match.astype(jnp.int32), axis=1)
    counts = jax.lax.psum(counts, "model")
    scanned = jax.lax.psum(jnp.sum(rel.astype(jnp.int32), axis=1), "model")
    return counts, scanned


def make_inputs(cfg: WiskServeConfig):
    W = cfg.vocab // 32
    sds = jax.ShapeDtypeStruct
    return dict(
        q_rects=sds((cfg.n_queries, 4), jnp.float32),
        q_bm=sds((cfg.n_queries, W), jnp.uint32),
        leaf_mbrs=sds((cfg.n_nodes, 4), jnp.float32),
        leaf_bm=sds((cfg.n_nodes, W), jnp.uint32),
        obj_x=sds((cfg.n_nodes, OBJ_PER_LEAF), jnp.float32),
        obj_y=sds((cfg.n_nodes, OBJ_PER_LEAF), jnp.float32),
        obj_bm=sds((cfg.n_nodes, OBJ_PER_LEAF, W), jnp.uint32),
        obj_valid=sds((cfg.n_nodes, OBJ_PER_LEAF), jnp.int8),
    )


def lower_wisk_serve(mesh: Mesh, cfg: WiskServeConfig = None, two_stage: bool = False):
    cfg = cfg or WiskServeConfig()
    dp = dp_axes(mesh)
    qspec = P(dp, None)
    lspec = P("model", None)
    in_specs = (qspec, qspec, lspec, lspec, lspec, lspec, P("model", None, None), lspec)
    out_specs = (P(dp), P(dp))

    import functools

    fn = shard_map(
        functools.partial(wisk_serve_step, two_stage=two_stage),
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False,
    )
    inputs = make_inputs(cfg)
    shardings = dict(
        q_rects=NamedSharding(mesh, qspec),
        q_bm=NamedSharding(mesh, qspec),
        leaf_mbrs=NamedSharding(mesh, lspec),
        leaf_bm=NamedSharding(mesh, lspec),
        obj_x=NamedSharding(mesh, lspec),
        obj_y=NamedSharding(mesh, lspec),
        obj_bm=NamedSharding(mesh, P("model", None, None)),
        obj_valid=NamedSharding(mesh, lspec),
    )
    order = list(inputs.keys())
    jitted = jax.jit(
        lambda *args: fn(*args),
        in_shardings=tuple(shardings[k] for k in order),
        out_shardings=(NamedSharding(mesh, P(dp)), NamedSharding(mesh, P(dp))),
    )
    return jitted.lower(*[inputs[k] for k in order])
