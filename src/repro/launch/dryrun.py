import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend init. Everything else in the framework sees the
# normal (1-device) environment; only the dry-run uses 512 placeholders.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we jit the appropriate step function with production
in/out_shardings, lower against ShapeDtypeStructs (no allocation), compile,
and record:
  * memory_analysis()  -- proves the cell fits per-device HBM;
  * cost_analysis()    -- HLO FLOPs / bytes for the roofline;
  * collective bytes   -- parsed from the optimized HLO (per-device shard
    sizes summed per collective opcode).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all            # every cell, subprocess each
  python -m repro.launch.dryrun --arch wisk --shape serve
Options: --multi-pod to use the (2,16,16) mesh, --out DIR for artifacts.
"""
import argparse
import gc
import json
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

import numpy as np

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def parse_collective_bytes(hlo_text: str):
    """Sum per-device result bytes per collective opcode from optimized HLO."""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", stripped)
        if not m:
            continue
        if "-start" in stripped.split("=")[0]:
            pass  # async starts carry the payload type; done ops are aliases
        if "-done" in stripped or "all-reduce-done" in stripped:
            continue
        op = m.group(2)
        total = 0
        for dt, dims in shape_re.findall(m.group(1)):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * DTYPE_BYTES[dt]
        out[op] += total
        counts[op] += 1
    return out, counts


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path, causal_impl: str = None,
             extra_tag: str = "", overrides: str = None) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import get_config
    from ..configs.base import SHAPES, applicable_shapes
    from .mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = dict(arch=arch, shape=shape, mesh=mesh_name, devices=int(np.prod(mesh.devices.shape)))
    t0 = time.time()

    if arch == "wisk":
        from .flat_legacy import lower_wisk_serve

        lowered = lower_wisk_serve(mesh, two_stage=(shape == "serve2"))
        rec["kind"] = "serve"
    else:
        cfg = get_config(arch)
        import dataclasses
        if causal_impl:
            cfg = dataclasses.replace(cfg, causal_impl=causal_impl)
        if overrides:
            merged = dict(cfg.logical_overrides or {})
            for kv in overrides.split(","):
                k, v = kv.split("=")
                if v == "None":
                    merged[k] = None
                elif v == "ALL":  # every mesh axis (pure-DP/ZeRO-3 layouts)
                    merged[k] = ("pod", "data", "model") if multi_pod else ("data", "model")
                else:
                    merged[k] = v
            cfg = dataclasses.replace(cfg, logical_overrides=merged)
        if shape not in applicable_shapes(cfg):
            rec["skipped"] = f"shape {shape} not applicable to {arch} (see DESIGN.md)"
            return rec
        from ..train.step import build_steps
        from ..sharding.rules import dp_axes

        seq, batch, kind = SHAPES[shape]
        rec["kind"] = kind
        steps = build_steps(cfg, mesh)
        sh = lambda spec_tree: steps.shardings(spec_tree)
        repl = NamedSharding(mesh, P())
        dp = dp_axes(mesh)
        n_dp = int(np.prod([mesh.shape[a] for a in dp]))

        if kind == "train":
            state = jax.eval_shape(steps.init_state, jax.random.PRNGKey(0))
            state_sh = sh(steps.state_specs)
            batch_sds, batch_specs = steps.batch_spec(kind, seq, batch)
            batch_sh = sh(batch_specs)
            fn = jax.jit(
                steps.train_step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, repl),
                donate_argnums=(0,),
            )
            lowered = fn.lower(state, batch_sds)
        elif kind == "prefill":
            params = jax.eval_shape(lambda k: steps.init_state(k)["params"], jax.random.PRNGKey(0))
            params_sh = sh(steps.param_specs)
            batch_sds, batch_specs = steps.batch_spec(kind, seq, batch)
            fn = jax.jit(steps.prefill_step, in_shardings=(params_sh, sh(batch_specs)))
            lowered = fn.lower(params, batch_sds)
        else:  # decode
            params = jax.eval_shape(lambda k: steps.init_state(k)["params"], jax.random.PRNGKey(0))
            params_sh = sh(steps.param_specs)
            long_ctx = batch < n_dp
            cache_sds, cache_specs = steps.cache_spec(batch, seq, long_ctx=long_ctx)
            cache_sh = sh(cache_specs)
            tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
            tok_sh = NamedSharding(mesh, P(None, None)) if long_ctx else NamedSharding(mesh, P(dp, None))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(
                steps.decode_step,
                in_shardings=(params_sh, cache_sh, tok_sh, repl),
                out_shardings=(NamedSharding(mesh, P()), cache_sh),
                donate_argnums=(1,),
            )
            lowered = fn.lower(params, cache_sds, tok, pos)

    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = dict(
            argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
            alias_bytes=int(getattr(ma, "alias_size_in_bytes", 0)),
        )
        print("memory_analysis:", rec["memory"])
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    try:
        from ..roofline.hlo_stats import cost_analysis_dict

        ca = cost_analysis_dict(compiled)
        rec["cost"] = {k: float(v) for k, v in ca.items() if np.isscalar(v) and k in (
            "flops", "bytes accessed", "transcendentals", "utilization operand 0 {}",
        )}
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            rec["cost"].get("flops", 0), rec["cost"].get("bytes accessed", 0)))
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}

    try:
        hlo = compiled.as_text()
        coll, counts = parse_collective_bytes(hlo)
        rec["collective_bytes_per_device"] = coll
        rec["collective_counts"] = counts
        rec["collective_total_per_device"] = int(sum(coll.values()))
        print("collectives(B/device):", coll)
        # trip-count-aware correction (while bodies counted once otherwise)
        from ..roofline.hlo_stats import analyze as hlo_analyze

        st = hlo_analyze(hlo)
        rec["hlo_corrected"] = dict(
            dot_flops_per_device=float(st["flops"]),
            collective_bytes_per_device=st["coll"],
            collective_total_per_device=int(st["coll_total"]),
            while_trips=st["while_trips"][:64],
        )
        print("corrected: dot_flops/device=%.3e coll/device=%.3e" % (
            st["flops"], st["coll_total"]))
    except Exception as e:  # pragma: no cover
        rec["collectives_error"] = str(e)

    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}_{shape}_{mesh_name}{extra_tag}.json"
    (out_dir / tag).write_text(json.dumps(rec, indent=1))
    print("PASS", tag)
    return rec


ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--causal-impl", default=None)
    ap.add_argument("--overrides", default=None, help="rule overrides k=None,...")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        from ..configs import ARCH_IDS

        cells = [(a, s) for a in ARCH_IDS + ["wisk"] for s in (ALL_SHAPES if a != "wisk" else ["serve"])]
        failures = []
        for a, s in cells:
            for mp in ([False, True] if True else [False]):
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                f = out_dir / f"{a}_{s}_{mesh_name}.json"
                if f.exists():
                    # single-pod cells feed the roofline: require corrected stats
                    if mp or "hlo_corrected" in f.read_text():
                        print("skip (done)", a, s, mesh_name, flush=True)
                        continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a, "--shape", s,
                       "--out", str(out_dir)] + (["--multi-pod"] if mp else [])
                print(">>>", " ".join(cmd), flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    failures.append((a, s, mp, r.stdout[-2000:] + r.stderr[-2000:]))
                    print("FAIL", a, s, "multi_pod" if mp else "single", flush=True)
                else:
                    print(r.stdout.strip().splitlines()[-1] if r.stdout.strip() else "ok", flush=True)
        print(f"\n{len(failures)} failures")
        for a, s, mp, log in failures:
            print("=" * 80, "\nFAILED:", a, s, mp, "\n", log[-1500:])
        sys.exit(1 if failures else 0)

    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, out_dir,
                       causal_impl=args.causal_impl, extra_tag=args.tag,
                       overrides=args.overrides)
        if args.both_meshes:
            run_cell(args.arch, args.shape, True, out_dir,
                     causal_impl=args.causal_impl, extra_tag=args.tag)
        if "skipped" in rec:
            print("SKIP:", rec["skipped"])
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
