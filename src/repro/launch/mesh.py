"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data", "model").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the real local devices (tests / CPU training)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(*, query: int = 1, index: int = 1):
    """The 2D serving mesh: ``query`` query-parallel replicas x ``index``
    index shards, axes ``("data", "index")``. Requires ``query * index``
    devices. The "data" axis carries the query batch (``dp_axes`` picks it
    up unchanged); the "index" axis carries the ``PartitionedSnapshot``'s
    stacked per-shard rows (sharding/rules.py routes the ``leaf`` logical
    axis to it). ``index=1`` degenerates to the replicated regime's mesh.
    """
    return jax.make_mesh((query, index), ("data", "index"))
