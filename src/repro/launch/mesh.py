"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data", "model").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the real local devices (tests / CPU training)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))
