"""Legacy leaf-sharded FLAT serving fallback (DESIGN.md §3.4, legacy regime).

This was the repo's original big-index story: abandon the hierarchy, shard
the leaf rows (with their object blocks) over the ``model`` mesh axis, have
every device filter its local leaves against replicated queries, and psum
the per-query counts. It is retired from the serving front door -- the
index-sharded regime (``launch/wisk_serve.py:serve_index_sharded``) serves
large indexes WITH the hierarchy at exact parity -- but stays as:

* the dry-run / roofline lowering surface (``launch/dryrun.py`` inspects
  its HLO on abstract shapes without allocating an index), and
* the A/B floor a hierarchical descent must beat (a flat scan touches every
  leaf; the descent touches ``nodes_checked`` of them).

``launch/wisk_serve.py`` re-exports these names, so historical imports
(tests, notebooks) keep working. On TPU the inner loops are the Pallas
kernels; the dry-run lowers the jnp reference math (identical semantics --
Mosaic kernels cannot target the CPU placeholder backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.wisk import WiskServeConfig
from ..kernels.ref import skr_filter_ref, skr_verify_ref
from ..sharding.compat import shard_map
from ..sharding.rules import default_rules, dp_axes, spec_for

OBJ_PER_LEAF = 512
TOP_LEAVES_LOCAL = 4


def wisk_serve_step(q_rects, q_bm, leaf_mbrs, leaf_bm, obj_x, obj_y, obj_bm, obj_valid,
                    two_stage: bool = False, stage2_cap: int = 512):
    """Local (per-device) filter + verify; counts/scanned/overflow psum'd
    over 'model'.

    q_*: local query shard; leaf_*/obj_*: local leaf shard.

    ``two_stage``: verify in-rectangle membership on the 8-byte (x, y) pairs
    first and gather the 512-byte keyword bitmaps only for the (capacity-
    bounded) spatial survivors -- the memory-roofline hillclimb of
    EXPERIMENTS.md section Perf (bitmap traffic drops ~C/stage2_cap).
    ``overflow`` counts the spatial survivors beyond ``stage2_cap`` whose
    matches the capacity bound dropped -- callers must surface it (counts
    are a lower bound whenever it is nonzero).
    """
    M = q_rects.shape[0]
    rel = skr_filter_ref(q_rects, q_bm, leaf_mbrs, leaf_bm)  # (Mloc, Kloc) int8
    sizes = jnp.sum(obj_valid > 0, axis=1)  # (Kloc,)
    score = rel.astype(jnp.int32) * (1 + sizes[None, :])
    _, top_leaf = jax.lax.top_k(score, TOP_LEAVES_LOCAL)  # (Mloc, L)
    # gather candidate coordinate blocks for each (query, local leaf)
    cx = obj_x[top_leaf].reshape(M, -1)
    cy = obj_y[top_leaf].reshape(M, -1)
    cval = obj_valid[top_leaf].reshape(M, -1)
    # leaves not relevant contribute nothing
    leaf_ok = jnp.take_along_axis(rel, top_leaf, axis=1)  # (Mloc, L)
    cval = cval * jnp.repeat(leaf_ok, OBJ_PER_LEAF, axis=1)

    if two_stage:
        inr = (
            (cx >= q_rects[:, 0:1]) & (cx <= q_rects[:, 2:3])
            & (cy >= q_rects[:, 1:2]) & (cy <= q_rects[:, 3:4])
            & (cval > 0)
        )
        cap = min(stage2_cap, inr.shape[1])
        val2, idx2 = jax.lax.top_k(inr.astype(jnp.int32), cap)  # (Mloc, cap)
        # map surviving candidate slots back to (leaf, slot) for a narrow gather
        leaf_of = jnp.repeat(top_leaf, OBJ_PER_LEAF, axis=1)  # (Mloc, C)
        slot_of = jnp.tile(jnp.arange(OBJ_PER_LEAF), (M, TOP_LEAVES_LOCAL))
        sel_leaf = jnp.take_along_axis(leaf_of, idx2, axis=1)
        sel_slot = jnp.take_along_axis(slot_of, idx2, axis=1)
        cbm2 = obj_bm[sel_leaf, sel_slot]  # (Mloc, cap, W): bitmaps of survivors only
        kw = jnp.any((cbm2 & q_bm[:, None, :]) != 0, axis=-1)
        match = (kw & (val2 > 0)).astype(jnp.int32)
        counts = jnp.sum(match, axis=1)
        overflow = jnp.maximum(jnp.sum(inr.astype(jnp.int32), axis=1) - cap, 0)
    else:
        cbm = obj_bm[top_leaf].reshape(M, -1, q_bm.shape[1])
        match = skr_verify_ref(q_rects, q_bm, cx, cy, cbm, cval)  # (Mloc, C) int8
        counts = jnp.sum(match.astype(jnp.int32), axis=1)
        overflow = jnp.zeros_like(counts)
    counts = jax.lax.psum(counts, "model")
    scanned = jax.lax.psum(jnp.sum(rel.astype(jnp.int32), axis=1), "model")
    overflow = jax.lax.psum(overflow, "model")
    return counts, scanned, overflow


def make_inputs(cfg: WiskServeConfig):
    """Abstract ``ShapeDtypeStruct`` inputs of the flat fallback step (for
    ``jit.lower`` dry-runs; host-only, nothing is allocated)."""
    W = cfg.vocab // 32
    sds = jax.ShapeDtypeStruct
    return dict(
        q_rects=sds((cfg.n_queries, 4), jnp.float32),
        q_bm=sds((cfg.n_queries, W), jnp.uint32),
        leaf_mbrs=sds((cfg.n_nodes, 4), jnp.float32),
        leaf_bm=sds((cfg.n_nodes, W), jnp.uint32),
        obj_x=sds((cfg.n_nodes, OBJ_PER_LEAF), jnp.float32),
        obj_y=sds((cfg.n_nodes, OBJ_PER_LEAF), jnp.float32),
        obj_bm=sds((cfg.n_nodes, OBJ_PER_LEAF, W), jnp.uint32),
        obj_valid=sds((cfg.n_nodes, OBJ_PER_LEAF), jnp.int8),
    )


def lower_wisk_serve(mesh: Mesh, cfg: WiskServeConfig = None, two_stage: bool = False):
    """Lower (never execute) the leaf-sharded fallback on ``mesh``: queries
    replicated over 'model', leaves + object blocks sharded, counts/scanned/
    overflow psum'd. Returns the jitted computation's ``Lowered`` handle --
    the dry-run surface for roofline/HLO inspection (host-only)."""
    cfg = cfg or WiskServeConfig()
    rules = default_rules(mesh)
    dp = dp_axes(mesh)
    qspec = spec_for(("query", None), rules)
    lspec = spec_for(("leaf", None), rules)
    ospec = spec_for(("leaf", "obj_slot", "word"), rules)
    in_specs = (qspec, qspec, lspec, lspec, lspec, lspec, ospec, lspec)
    out_specs = (P(dp), P(dp), P(dp))

    fn = shard_map(
        functools.partial(wisk_serve_step, two_stage=two_stage),
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False,
    )
    inputs = make_inputs(cfg)
    shardings = dict(
        q_rects=NamedSharding(mesh, qspec),
        q_bm=NamedSharding(mesh, qspec),
        leaf_mbrs=NamedSharding(mesh, lspec),
        leaf_bm=NamedSharding(mesh, lspec),
        obj_x=NamedSharding(mesh, lspec),
        obj_y=NamedSharding(mesh, lspec),
        obj_bm=NamedSharding(mesh, ospec),
        obj_valid=NamedSharding(mesh, lspec),
    )
    order = list(inputs.keys())
    jitted = jax.jit(
        lambda *args: fn(*args),
        in_shardings=tuple(shardings[k] for k in order),
        out_shardings=tuple(NamedSharding(mesh, P(dp)) for _ in range(3)),
    )
    return jitted.lower(*[inputs[k] for k in order])
