"""Analytic bytes-moved model for the serving descent (DESIGN.md §3.5).

The descent hot path is bandwidth-bound: every stage streams operand planes
whose shapes are static per compiled batch, so bytes moved is a deterministic
integer given (batch size, frontier widths, word counts, leaf-bank geometry).
This module prices the two descent representations and the three leaf-verify
variants so benchmarks/bench_roofline.py can put exact before/after counters
on the scoreboard (tools/bench_compare.py diffs them bit-for-bit -- any
drift is a semantic change, not noise).

Per-stage napkin model (one HBM touch per operand element; reuse inside a
kernel tile is free, re-gathers across levels are not):

Filter stage, one level at frontier width F over M queries
  legacy  M*F*(4*4 + W*4)        f32 MBR plane + full word plane per slot
  narrow  M*F*(4*2 + Wp*4)       int16 rank codes + packed nonzero words,
          + (Dx+Dy)*4            the per-level coordinate dictionaries
                                 (read once; they stay resident across tiles)
  both    + M*(16 + 4*Wq)        the query rects + query word plane
          + M*F                  the int8 survivor mask written back

Leaf verify over M queries x T selected leaves of OBJ padded objects
  unfused   3 * M*T*OBJ*(12+4W)  the candidate bytes are touched three
                                 times: the gather reads the bank rows,
                                 writes the (M, T*OBJ) slab to HBM, and the
                                 verify kernel re-reads the slab
  vmem      ceil(M/bm) * K*OBJ*(12+4W)  whole bank re-streamed per query
                                 block (valid only while the bank fits VMEM)
  prefetch  M*T*OBJ*(12+4W)      one DMA per (query, slot) block -- single
                                 pass, no slab bounce, any bank size
  (the ids/kwv output writes are identical across all three variants and
  excluded from the verify term)

Compact leaf-vocabulary verify (``compact_words=Wl`` > 0, DESIGN.md §3.5):
the per-object word plane shrinks from the global W words to the leaf-local
Wl words plus the one-word OR-fold signature, so every variant's per-object
term becomes 12 + 4 + 4*Wl. The remap of each query's packed word plane
into leaf-local ids adds, once per (query, selected leaf):
  remap     M*T*(32*Wl*4 + (Wl+1)*4)  the leaf's term dictionary row
                                 (32*Wl i32) read in, the remapped plane
                                 (Wl words) + signature (1 word) written out

Modeled milliseconds divide by the roofline's ``HBM_BW`` (analysis.py); the
ratio rows (legacy/narrow) are what the ISSUE's >=2x target is scored on.
All byte counts are exact ints -- keep them that way (scoreboard diffs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from .analysis import HBM_BW

_MBR_F32 = 4 * 4  # four f32 coordinates
_MBR_I16 = 4 * 2  # four int16 rank codes
_WORD = 4  # one uint32 bitmap word
_OBJ_FIXED = 3 * 4  # x, y (f32) + id (i32) per leaf object
_SIG = 4  # one uint32 OR-fold signature per leaf object (compact bank)


def filter_level_bytes(
    m: int,
    width: int,
    n_words: int,
    *,
    narrow: bool = False,
    packed_words: int = 0,
    dict_sizes: Tuple[int, int] = (0, 0),
) -> int:
    """Bytes one filter level moves for ``m`` queries at frontier ``width``.

    ``narrow`` prices the int16-code / packed-word representation:
    ``packed_words`` is the static packed width Wp (ops.pack_query_words)
    and ``dict_sizes`` the level's (Dx, Dy) dictionary lengths. The query
    operands use the same word width as the node planes (full W legacy,
    Wp narrow) and the int8 survivor mask is charged on both."""
    if narrow:
        per_slot = _MBR_I16 + packed_words * _WORD
        q_words = packed_words
        extra = (dict_sizes[0] + dict_sizes[1]) * 4
    else:
        per_slot = _MBR_F32 + n_words * _WORD
        q_words = n_words
        extra = 0
    return m * width * per_slot + m * (16 + q_words * _WORD) + m * width + extra


def remap_bytes(m: int, t: int, compact_words: int) -> int:
    """Bytes the leaf-local query remap moves for ``m`` queries x ``t`` slots.

    Per (query, selected leaf): the leaf's term-dictionary row (32*Wl i32)
    is read and the remapped word plane (Wl u32) plus the one-word signature
    are written (ops.remap_query_words)."""
    return m * t * (32 * compact_words * 4 + (compact_words + 1) * _WORD)


def verify_bytes(
    m: int,
    t: int,
    obj_per_leaf: int,
    n_words: int,
    n_leaves: int,
    variant: str,
    bm: int = 8,
    compact_words: int = 0,
) -> int:
    """Bytes the leaf verify stage moves for ``m`` queries x ``t`` slots.

    ``variant`` is one of ``unfused`` / ``vmem`` / ``prefetch`` (the engine's
    three hot-path variants, DESIGN.md §3.5); ``bm`` is the query block of
    the VMEM-fused kernel. ``compact_words`` > 0 prices the leaf-local
    vocabulary bank instead: Wl-word object planes plus the one-word
    signature, with the per-(query, slot) remap term added on top."""
    if compact_words > 0:
        per_obj = _OBJ_FIXED + _SIG + compact_words * _WORD
        extra = remap_bytes(m, t, compact_words)
    else:
        per_obj = _OBJ_FIXED + n_words * _WORD
        extra = 0
    if variant == "unfused":
        return 3 * m * t * obj_per_leaf * per_obj + extra
    if variant == "vmem":
        blocks = -(-m // bm)
        return blocks * n_leaves * obj_per_leaf * per_obj + extra
    if variant == "prefetch":
        return m * t * obj_per_leaf * per_obj + extra
    raise ValueError(f"unknown verify variant {variant!r}")


def modeled_ms(n_bytes: int) -> float:
    """Bandwidth-bound wall time (ms) for ``n_bytes`` at the roofline HBM
    rate -- a lower bound ranking representations, not a latency predictor
    (the CPU interpret path is compute-bound and far off this line)."""
    return n_bytes / HBM_BW * 1e3


@dataclasses.dataclass(frozen=True)
class DescentBytes:
    """Exact bytes-moved decomposition of one compiled descent batch."""

    filter_bytes: int  # sum over levels of filter_level_bytes
    verify_bytes: int  # the chosen verify variant's bytes
    per_level: Tuple[int, ...]  # the filter term per level, root first

    @property
    def total(self) -> int:
        return self.filter_bytes + self.verify_bytes

    @property
    def total_ms(self) -> float:
        return modeled_ms(self.total)


def descent_bytes(
    m: int,
    widths: Sequence[int],
    n_words: int,
    *,
    narrow: bool = False,
    packed_words: int = 0,
    dict_sizes: Sequence[Tuple[int, int]] = (),
    t: int = 0,
    obj_per_leaf: int = 0,
    n_leaves: int = 0,
    verify_variant: str = "prefetch",
    bm: int = 8,
    compact_words: int = 0,
) -> DescentBytes:
    """Price a whole descent: per-level filter widths + one verify variant.

    ``widths`` are the converged padded frontier widths (engine output
    ``frontier_widths``), root first; ``dict_sizes`` parallels them when
    ``narrow``. ``t=0`` prices a filter-only descent (verify term 0);
    ``compact_words`` > 0 prices the leaf-local compact verify bank."""
    dsz = list(dict_sizes) or [(0, 0)] * len(widths)
    per_level = tuple(
        filter_level_bytes(
            m, int(w), n_words,
            narrow=narrow, packed_words=packed_words, dict_sizes=dsz[i],
        )
        for i, w in enumerate(widths)
    )
    vb = 0
    if t > 0:
        vb = verify_bytes(m, t, obj_per_leaf, n_words, n_leaves, verify_variant,
                          bm, compact_words=compact_words)
    return DescentBytes(sum(per_level), vb, per_level)


def compare(legacy: DescentBytes, narrow: DescentBytes) -> Dict[str, object]:
    """The scoreboard-facing summary of a legacy/narrow descent pair."""
    return {
        "legacy_bytes": legacy.total,
        "narrow_bytes": narrow.total,
        "ratio": legacy.total / max(narrow.total, 1),
        "legacy_ms": legacy.total_ms,
        "narrow_ms": narrow.total_ms,
    }
