"""Trip-count-aware statistics from optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop *body once* (verified in
tests/test_roofline.py), so scanned-layer models under-report FLOPs,
bytes, and collectives by ~the layer count. This module re-derives:

  * per-device matmul FLOPs (every ``dot`` op: 2 * prod(result) * contract),
  * per-device collective bytes by opcode,

by parsing the optimized HLO text into computations, building a symbol
table of instruction shapes, extracting while-loop trip counts from their
condition computations (max integer ``constant(N)``), and DFS-ing from
ENTRY with multipliers: ``body`` computations multiply by the trip count;
fusions/calls/conditionals multiply by 1.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape(text: str) -> Optional[Tuple[str, int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), _shape_elems(m.group(2))


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in DTYPE_BYTES:
            total += _shape_elems(dims) * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]


def cost_analysis_dict(compiled) -> Dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returns a one-element list of per-partition dicts; newer jax
    returns the dict directly. Callers always want the flat dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def split_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip()) if line.strip().endswith("{") else None
        if hdr and ("->" in line):
            cur = Computation(hdr.group(1), [])
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            cur.lines.append(line.strip())
    return comps, entry


def build_symbol_table(comps: Dict[str, Computation]) -> Dict[str, Tuple[str, List[int]]]:
    """instruction name -> (dtype, dims) from its result type."""
    table: Dict[str, Tuple[str, List[int]]] = {}
    for comp in comps.values():
        for line in comp.lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            sm = _SHAPE_RE.search(rest)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
                table[name] = (sm.group(1), dims)
        # parameters: "name = dtype[dims] parameter(i)" handled above
    return table


def trip_count(cond: Computation) -> int:
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


_CALL_RE = re.compile(
    r"(?:condition|body|calls|to_apply|true_computation|false_computation)=%?([\w\.\-]+)"
)
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def dot_flops_line(line: str, table) -> int:
    """FLOPs of a dot instruction: 2 * prod(result dims) * contract size."""
    m = _INSTR_RE.match(line)
    if not m or " dot(" not in line:
        return 0
    rest = m.group(2)
    sm = _SHAPE_RE.search(rest)
    if not sm:
        return 0
    result = _shape_elems(sm.group(2))
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    lhs_dims = _dot_lhs_dims(line, table)
    if lhs_dims is not None and cm:
        for d in cm.group(1).split(","):
            if d != "" and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    return 2 * result * contract


def _dot_lhs_dims(line: str, table) -> Optional[List[int]]:
    """Dims of a dot's lhs operand.

    Current XLA prints typed operands -- ``dot(f32[64,32]{1,0} %a, ...)`` --
    so the lhs shape is read straight off the operand text (naive comma
    splitting breaks on the ``{1,0}`` layout braces). Older untyped operand
    lists -- ``dot(a, b)`` -- fall back to the symbol table.
    """
    ops = re.findall(r"dot\(([^)]*)\)", line)
    if not ops:
        return None
    sm = _SHAPE_RE.search(ops[0])
    if sm:  # typed operand: first shape in the operand list is the lhs type
        return [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    names = re.findall(r"%?([\w\.\-]+)", ops[0].split(",")[0])
    if names and names[-1] in table:
        return table[names[-1]][1]
    return None


def analyze(hlo: str) -> Dict:
    comps, entry = split_computations(hlo)
    table = build_symbol_table(comps)

    # per-computation local stats + edges
    local: Dict[str, Dict] = {}
    for name, comp in comps.items():
        flops = 0
        coll = {c: 0 for c in COLLECTIVES}
        edges: List[Tuple[str, str]] = []  # (callee, kind)
        for line in comp.lines:
            if " dot(" in line:
                flops += dot_flops_line(line, table)
            for c in COLLECTIVES:
                if re.search(rf"\s{c}(-start)?\(", line) and "-done" not in line.split("=")[0]:
                    m = _INSTR_RE.match(line)
                    if m:
                        lhs_type = m.group(2).split(c)[0]
                        coll[c] += _all_shapes_bytes(lhs_type)
            if "while(" in line:
                body = cond = None
                for callee in _CALL_RE.finditer(line):
                    tgt = callee.group(1)
                    key = callee.group(0).split("=")[0]
                    if key == "body":
                        body = tgt
                    elif key == "condition":
                        cond = tgt
                if body:
                    trips = trip_count(comps[cond]) if cond and cond in comps else 1
                    edges.append((body, f"while:{trips}"))
            else:
                for callee in _CALL_RE.finditer(line):
                    key = callee.group(0).split("=")[0]
                    if key in ("calls", "to_apply", "true_computation", "false_computation"):
                        edges.append((callee.group(1), "call"))
                bm = _BRANCH_RE.search(line)
                if bm:
                    for b in bm.group(1).split(","):
                        edges.append((b.strip().lstrip("%"), "call"))
        local[name] = dict(flops=flops, coll=coll, edges=edges)

    # DFS with multipliers (memoized on (comp, multiplier) is wrong for
    # shared comps under different trips -- recompute per path; graphs are
    # small, recursion fine)
    import sys

    sys.setrecursionlimit(10_000)
    total = dict(flops=0, coll={c: 0 for c in COLLECTIVES}, while_trips=[])

    seen_stack = set()

    def walk(name: str, mult: int):
        if name not in local or name in seen_stack:
            return
        seen_stack.add(name)
        st = local[name]
        total["flops"] += st["flops"] * mult
        for c in COLLECTIVES:
            total["coll"][c] += st["coll"][c] * mult
        for callee, kind in st["edges"]:
            if kind.startswith("while:"):
                trips = int(kind.split(":")[1])
                total["while_trips"].append(trips)
                walk(callee, mult * trips)
            else:
                walk(callee, mult)
        seen_stack.discard(name)

    if entry:
        walk(entry, 1)
    total["coll_total"] = int(sum(total["coll"].values()))
    return total
