"""Roofline assembly: three terms per (arch x shape) from dry-run artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Terms (seconds, per step):
  compute    = FLOPs_per_chip / peak_FLOPs
  memory     = HBM_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / ICI_bw

FLOPs_per_chip comes from the trip-count-corrected HLO dot census
(roofline/hlo_stats.py); the raw ``cost_analysis`` value is reported too
(it counts while bodies once -- see tests/test_roofline.py). HBM bytes per
chip are an analytic napkin model (stated inline) because the CPU backend's
byte accounting also ignores trip counts; collective bytes are the
corrected HLO parse. MODEL_FLOPS uses 6*N*D (dense) / 6*N_active*D (MoE);
the MODEL/HLO ratio exposes remat + causal-masking + capacity waste.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

from ..configs import ARCH_IDS, get_config
from ..configs.base import SHAPES


def param_counts(cfg) -> Dict[str, float]:
    """(total_params, active_params_per_token) analytic estimate."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    H = cfg.pad_heads_to or cfg.n_heads
    hd, KV = cfg.head_dim, cfg.n_kv_heads
    emb = V * d * 2  # embed + head
    per_attn = d * H * hd + 2 * d * KV * hd + H * hd * d
    if cfg.use_mla:
        per_attn = (
            d * cfg.q_lora + cfg.q_lora * H * (cfg.qk_nope + cfg.qk_rope)
            + d * (cfg.kv_lora + cfg.qk_rope)
            + cfg.kv_lora * H * (cfg.qk_nope + cfg.v_head)
            + H * cfg.v_head * d
        )
    per_dense_ffn = 3 * d * cfg.d_ff
    fe = cfg.d_expert or cfg.d_ff
    per_expert = 3 * d * fe
    per_shared = 3 * d * fe * cfg.n_shared_experts

    total = emb
    active = emb / max(V, 1) * d / d  # embedding lookup ~ d per token; ignore
    total_active = 0.0
    if cfg.family == "encdec":
        total += cfg.enc_layers * (per_attn + 2 * d * cfg.d_ff)
        total += L * (2 * per_attn + 2 * d * cfg.d_ff)
        total_active = total
    elif cfg.family == "xlstm":
        di = cfg.d_inner
        per_m = d * 2 * di + 3 * di * (di // cfg.n_heads) * cfg.n_heads / max(cfg.n_heads, 1) * cfg.n_heads
        per_m = d * 2 * di + 3 * di * di / cfg.n_heads + di * 2 * cfg.n_heads + di * d
        per_s = 2 * d * 4 * d + d * d
        n_s = L // cfg.slstm_every
        total += (L - n_s) * per_m + n_s * per_s
        total_active = total
    elif cfg.family == "hybrid":
        di = cfg.d_inner
        per_mamba = d * 2 * di + di * (cfg.dt_rank + 2 * cfg.d_state) + cfg.dt_rank * di + di * d
        n_attn = L // cfg.attn_every
        n_moe = L // cfg.moe_every if cfg.n_experts else 0
        n_dense_ffn = L - n_moe
        total += (L - n_attn) * per_mamba + n_attn * per_attn
        total += n_dense_ffn * per_dense_ffn + n_moe * cfg.n_experts * per_expert
        active = total - n_moe * cfg.n_experts * per_expert + n_moe * cfg.moe_topk * per_expert
        total_active = active
    elif cfg.n_experts:
        n_moe = L - cfg.first_dense
        total += L * per_attn + cfg.first_dense * per_dense_ffn
        total += n_moe * (cfg.n_experts * per_expert + per_shared)
        total_active = (
            emb + L * per_attn + cfg.first_dense * per_dense_ffn
            + n_moe * (cfg.moe_topk * per_expert + per_shared)
        )
    else:
        total += L * (per_attn + per_dense_ffn)
        total_active = total
    return dict(total=float(total), active=float(total_active))


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS global per step: 6*N_active*D train, 2*N_active*D prefill,
    2*N_active*B decode (one token per sequence)."""
    seq, batch, kind = SHAPES[shape_name]
    pc = param_counts(cfg)
    n_act = pc["active"]
    tokens = seq * batch
    if kind == "train":
        return 6.0 * n_act * tokens
    if kind == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * batch  # decode: one new token per sequence


def analytic_hbm_bytes(cfg, shape_name: str, n_chips: int) -> float:
    """Per-chip HBM traffic napkin model (stated, conservative):

    train:   3x param-shard reads (fwd + remat-recompute + bwd) + grad write
             + optimizer state read/write + 2 passes over saved activations.
    prefill: 1x param reads + activation write/read once.
    decode:  1x param reads + full KV-cache shard read + O(1) writes.
    """
    seq, batch, kind = SHAPES[shape_name]
    pc = param_counts(cfg)
    p_shard = pc["total"] * 2 / n_chips  # bf16 storage spread over all chips
    d = cfg.d_model
    tokens_local = seq * batch / max(n_chips / 16, 1) / 16  # dp shards only
    if kind == "train":
        opt_mult = 8 if cfg.optimizer == "adamw" else 1  # f32 m+v r/w vs factored
        act = 2 * cfg.n_layers * tokens_local * d * 2  # saved layer inputs, 2 passes
        return 3 * p_shard + p_shard + opt_mult * p_shard * 2 + act
    if kind == "prefill":
        act = 2 * cfg.n_layers * tokens_local * d * 2
        return p_shard + act
    # decode: cache shard dominates
    if cfg.use_mla:
        cache = cfg.n_layers * batch * seq * (cfg.kv_lora + cfg.qk_rope) * 2
    elif cfg.family == "xlstm":
        H = cfg.n_heads
        dh = cfg.d_inner // H
        cache = cfg.n_layers * batch * (H * dh * dh) * 4
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        cache = n_attn * batch * seq * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        cache += (cfg.n_layers - n_attn) * batch * cfg.d_inner * cfg.d_state * 4
    else:
        cache = cfg.n_layers * batch * seq * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    return p_shard + cache / n_chips + pc["active"] * 2 / n_chips


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    raw_cost_flops: float
    note: str = ""


def roofline_from_record(rec: Dict, cfg=None) -> Optional[RooflineRow]:
    if "skipped" in rec or rec.get("arch") == "wisk":
        return None
    arch, shape = rec["arch"], rec["shape"]
    cfg = cfg or get_config(arch)
    chips = rec.get("devices", 256)
    corr = rec.get("hlo_corrected") or {}
    flops_dev = corr.get("dot_flops_per_device", 0.0)
    coll_dev = corr.get("collective_total_per_device", rec.get("collective_total_per_device", 0))
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * chips
    compute = flops_dev / PEAK_FLOPS
    memory = analytic_hbm_bytes(cfg, shape, chips) / HBM_BW
    collective = coll_dev / ICI_BW
    terms = dict(compute=compute, memory=memory, collective=collective)
    bottleneck = max(terms, key=terms.get)
    return RooflineRow(
        arch=arch,
        shape=shape,
        compute_s=compute,
        memory_s=memory,
        collective_s=collective,
        bottleneck=bottleneck,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=(mf / hlo_global) if hlo_global else 0.0,
        raw_cost_flops=rec.get("cost", {}).get("flops", 0.0),
    )


def load_rows(dryrun_dir: str, mesh: str = "pod16x16") -> List[RooflineRow]:
    rows = []
    for f in sorted(Path(dryrun_dir).glob(f"*_{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("mesh") != mesh:
            continue
        row = roofline_from_record(rec)
        if row:
            rows.append(row)
    return rows


def to_markdown(rows: List[RooflineRow]) -> str:
    hdr = (
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | bound | "
        "MODEL_FLOPS | HLO_FLOPS | useful |\n|---|---|---|---|---|---|---|---|---|\n"
    )
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.compute_s*1e3:.2f} | {r.memory_s*1e3:.2f} | "
            f"{r.collective_s*1e3:.2f} | **{r.bottleneck}** | {r.model_flops:.2e} | "
            f"{r.hlo_flops_global:.2e} | {r.useful_ratio:.2f} |\n"
        )
    return "".join(out)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load_rows(args.dir)
    print(to_markdown(rows))


if __name__ == "__main__":
    main()
