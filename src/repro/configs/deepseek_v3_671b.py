"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(routed expert)
vocab=129280, MoE 1 shared + 256 routed top-8, MLA, MTP. [arXiv:2412.19437; hf]

Dense d_ff (first 3 layers) is 18432 per the HF config; routed/shared expert
width (moe_intermediate_size) is 2048. MLA dims from the HF config.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b",
        family="mla_moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,          # dense layers' FFN width
        d_expert=2048,       # routed expert width (assignment's d_ff)
        vocab=129280,
        n_experts=256,
        moe_topk=8,
        n_shared_experts=1,
        first_dense=3,
        use_mla=True,
        q_lora=1536,
        kv_lora=512,
        qk_nope=128,
        qk_rope=64,
        v_head=128,
        head_dim=192,        # qk_nope + qk_rope
        mtp_depth=1,
        optimizer="adafactor",
        rope_theta=10000.0,
    )
