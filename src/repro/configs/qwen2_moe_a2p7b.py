"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H d_ff=1408(expert) vocab=151936,
MoE 4 shared + 60 routed top-4. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5632,        # shared-expert aggregate width (4 x 1408)
        d_expert=1408,
        vocab=151936,
        n_experts=60,
        moe_topk=4,
        n_shared_experts=4,
    )
