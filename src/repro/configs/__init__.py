"""Config registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ArchConfig, SHAPES, applicable_shapes

_MODULES: Dict[str, str] = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "whisper-base": "whisper_base",
    "deepseek-7b": "deepseek_7b",
    "minitron-8b": "minitron_8b",
    "starcoder2-7b": "starcoder2_7b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "xlstm-125m": "xlstm_125m",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
    "wisk": "wisk",
}

ARCH_IDS: List[str] = [k for k in _MODULES if k != "wisk"]


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.config()


__all__ = ["ArchConfig", "SHAPES", "applicable_shapes", "get_config", "ARCH_IDS"]
