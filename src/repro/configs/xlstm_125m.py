"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304, sLSTM + mLSTM
blocks (1 sLSTM per 6 layers). Recurrent state -> sub-quadratic, runs
long_500k. [arXiv:2405.04517]"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m", family="xlstm", n_layers=12, d_model=768,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, slstm_every=6,
        expand=2, subquadratic=True, rope_theta=0.0,
        # 4 heads can't shard 16-way; TP runs on the 1536-wide inner dim.
        logical_overrides={"heads": None, "act_heads": None},
    )
