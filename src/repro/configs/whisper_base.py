"""whisper-base [audio]: enc-dec, 6L encoder + 6L decoder, d_model=512 8H
d_ff=2048 vocab=51865, conv frontend STUB (precomputed frame embeddings).
[arXiv:2212.04356]"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base",
        family="encdec",
        n_layers=6,       # decoder layers
        enc_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=51865,
        enc_frames_div=4,
        rope_theta=0.0,   # whisper uses learned/sinusoidal abs positions
        # 8 heads and an odd vocab (51865) cannot shard 16-way: replicate
        # those dims; TP still applies to the 2048-wide FFN (DESIGN.md).
        logical_overrides={"heads": None, "act_heads": None, "kv_heads": None,
                           "vocab": None, "act_vocab": None},
    )
