"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H d_ff=8192 vocab=32064;
phi3-mini backbone + CLIP frontend (stub: precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32064,
        n_patches=576,   # CLIP ViT-L/14 @ 336px
    )
