"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (src/repro/configs/<id>.py),
with the exact published numbers, plus a ``reduced()`` shrink used by CPU
smoke tests. The dry-run exercises the FULL configs abstractly
(ShapeDtypeStruct only).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class ArchConfig:
    name: str
    family: str  # dense | moe | mla_moe | encdec | vlm | xlstm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    moe_topk: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0  # per-expert FFN width
    capacity_factor: float = 1.25
    moe_every: int = 1  # MoE FFN on layers where (i % moe_every) == moe_every-1
    first_dense: int = 0  # leading dense-FFN layers (deepseek-v3: 3)

    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    mtp_depth: int = 0

    # SSM / hybrid
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    attn_every: int = 0  # jamba: attention layer where (i % attn_every)==attn_every//2
    dt_rank: int = 0

    # xLSTM
    slstm_every: int = 0  # sLSTM on layers where (i % slstm_every)==slstm_every-1

    # enc-dec (whisper) / vlm (phi-3-v)
    enc_layers: int = 0
    enc_frames_div: int = 4  # S_enc = seq // enc_frames_div
    n_patches: int = 0

    rope_theta: float = 10000.0
    pad_heads_to: int = 0  # zero-pad attention heads to a TP multiple (exact)
    logical_overrides: Optional[Dict[str, object]] = None  # per-arch rule patches
    dtype: str = "bfloat16"
    optimizer: str = "adamw"
    remat: str = "full"  # full | none
    causal_impl: str = "masked_scan"  # masked_scan | unrolled_prefix
    attn_chunk: int = 1024
    ssm_chunk: int = 128
    scan_layers: bool = True
    subquadratic: bool = False  # can run long_500k
    has_decode: bool = True
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            self.head_dim = self.d_model // self.n_heads
        if self.dt_rank == 0:
            self.dt_rank = max(1, self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        r = dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.attn_every == 0 else 8),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            moe_topk=min(self.moe_topk, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            d_expert=64 if self.d_expert else 0,
            first_dense=min(self.first_dense, 1),
            q_lora=64 if self.q_lora else 0,
            kv_lora=32 if self.kv_lora else 0,
            qk_nope=32 if self.qk_nope else 0,
            qk_rope=16 if self.qk_rope else 0,
            v_head=32 if self.v_head else 0,
            enc_layers=min(self.enc_layers, 2),
            n_patches=min(self.n_patches, 16),
            d_state=min(self.d_state, 8),
            dt_rank=8,
            attn_chunk=64,
            ssm_chunk=32,
            dtype="float32",
            remat="none",
        )
        if self.attn_every:
            r = dataclasses.replace(r, attn_every=4, n_layers=8, moe_every=2)
        if self.slstm_every:
            r = dataclasses.replace(r, slstm_every=2, n_layers=4)
        return r


# (seq_len, global_batch, kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig):
    """Which of the 4 assigned shapes apply to this arch (see DESIGN.md)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.has_decode:
        out.append("decode_32k")
        if cfg.subquadratic:
            out.append("long_500k")
    return out
