"""The paper's own config: WISK index serving. ``serve_step`` is the batched
SKR query pipeline (filter + verify) over a sharded index; see
launch/dryrun.py for the production-mesh lowering."""
import dataclasses


@dataclasses.dataclass
class WiskServeConfig:
    name: str = "wisk"
    n_queries: int = 4096       # global query batch
    n_nodes: int = 65536        # index nodes at the filtered level
    vocab: int = 4096           # keyword vocabulary (bitmap words = vocab/32)
    candidate_cap: int = 4096   # per-query verification capacity
    levels: int = 3


def config() -> WiskServeConfig:
    return WiskServeConfig()
