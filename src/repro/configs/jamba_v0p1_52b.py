"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
vocab=65536, MoE 16e top-2 (every other layer), Mamba+attn 1:7 interleave
(attention at layer i%8==3). Sub-quadratic outside 4 attn layers; runs
long_500k with attention KV sharded over ("data","model") on sequence.
[arXiv:2403.19887]"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, d_expert=14336, vocab=65536,
        n_experts=16, moe_topk=2, moe_every=2, attn_every=8,
        d_state=16, d_conv=4, expand=2, head_dim=128,
        optimizer="adafactor", subquadratic=True,
    )
