"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, RoPE. [arXiv:2402.19173]"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
        n_heads=36, n_kv_heads=4, d_ff=18432, vocab=49152, head_dim=128,
        # 36 heads don't divide the 16-way model axis: zero-pad to 48
        # (exactly function-preserving; padding stays zero under SGD).
        pad_heads_to=48,
    )
