"""Learned baselines: Flood-T (learned 1-D column layout + inverted files,
the paper's own adaptation of Flood), LSTI (Z-order + learned spline +
postings), and TFI (textual-first: inverted file over a learned per-keyword
1-D spatial index).

Flood-T shares WISK's CDF machinery: the column count/boundaries are chosen
to minimize the Eq.1 cost estimated from the learned CDFs over the training
workload -- but it can only split along ONE dimension, which is exactly the
limitation the paper exploits (Figs. 8-11).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.cost import DEFAULT_W1, DEFAULT_W2, exact_workload_cost
from ..core.index import flat_index
from ..core.types import ClusterSet, GeoTextDataset, WiskIndex, Workload, points_in_rect


def build_floodt(
    dataset: GeoTextDataset,
    workload: Workload,
    candidate_counts: Tuple[int, ...] = (4, 8, 16, 32, 64, 128),
    w1: float = DEFAULT_W1,
    w2: float = DEFAULT_W2,
) -> WiskIndex:
    """Learned single-dimension column layout (Flood-T)."""
    best = None
    # pick the split dimension by query extent anisotropy (narrower query side
    # -> more selective columns along that dim)
    for dim in (0, 1):
        vals = dataset.locs[:, dim]
        for k in candidate_counts:
            if k > dataset.n:
                continue
            qs = np.quantile(vals, np.linspace(0, 1, k + 1)[1:-1])
            assign = np.searchsorted(qs, vals).astype(np.int32)
            clusters = ClusterSet.from_assignment(dataset, assign)
            cost = exact_workload_cost(dataset, clusters, workload, w1, w2).total
            if best is None or cost < best[0]:
                best = (cost, dim, k, assign)
    _, dim, k, assign = best
    clusters = ClusterSet.from_assignment(dataset, assign)
    idx = flat_index(dataset, clusters)
    idx.meta.update(name=f"flood-t(dim={dim},k={k})", dim=dim, k=k)
    return idx


def _zorder(locs: np.ndarray, bits: int = 16) -> np.ndarray:
    xy = np.minimum((locs * (2**bits - 1)).astype(np.int64), 2**bits - 1)
    code = np.zeros(locs.shape[0], dtype=np.int64)
    for b in range(bits):
        code |= ((xy[:, 0] >> b) & 1) << (2 * b)
        code |= ((xy[:, 1] >> b) & 1) << (2 * b + 1)
    return code


def build_lsti(
    dataset: GeoTextDataset, max_error: int = 256
) -> WiskIndex:
    """LSTI analogue: Z-order the objects, fit an error-bounded linear spline
    over the codes (RadixSpline-style greedy), one cluster per spline segment
    with a per-segment inverted file."""
    code = _zorder(dataset.locs)
    order = np.argsort(code)
    # greedy segments of <=max_error points with near-linear code growth
    n = dataset.n
    seg_of = np.zeros(n, dtype=np.int32)
    seg = 0
    start = 0
    cs = code[order]
    for i in range(1, n + 1):
        if i == n or (i - start) >= max_error:
            seg_of[order[start:i]] = seg
            seg += 1
            start = i
    clusters = ClusterSet.from_assignment(dataset, seg_of)
    idx = flat_index(dataset, clusters)
    idx.meta.update(name=f"lsti(err={max_error})")
    return idx


@dataclasses.dataclass
class TFIIndex:
    """Textual-first index: per-keyword Z-ordered object arrays. Queries fetch
    per-keyword candidates by the query rect's Z-range, then verify."""

    kw_ptr: np.ndarray  # (V+1,)
    obj: np.ndarray  # object ids grouped by keyword, z-sorted within keyword
    code: np.ndarray  # z-codes aligned with ``obj``
    dataset_n: int

    def nbytes(self) -> int:
        return self.kw_ptr.nbytes + self.obj.nbytes + self.code.nbytes


def build_tfi(dataset: GeoTextDataset) -> TFIIndex:
    code_all = _zorder(dataset.locs)
    rows, cols = np.nonzero(dataset.kw_ids >= 0)
    kws = dataset.kw_ids[rows, cols]
    order = np.lexsort((code_all[rows], kws))
    kws_s, rows_s = kws[order], rows[order]
    V = dataset.vocab_size
    counts = np.bincount(kws_s, minlength=V)
    kw_ptr = np.zeros(V + 1, dtype=np.int64)
    np.cumsum(counts, out=kw_ptr[1:])
    return TFIIndex(kw_ptr=kw_ptr, obj=rows_s.astype(np.int32), code=code_all[rows_s], dataset_n=dataset.n)


def tfi_query(
    index: TFIIndex, dataset: GeoTextDataset, workload: Workload,
    w1: float = DEFAULT_W1, w2: float = DEFAULT_W2,
):
    """Per query: for each keyword, binary-search the Z-range covering the
    rect, scan candidates, verify spatially. Returns (results, stats)."""
    from ..core.query import QueryStats

    m = workload.m
    nodes = np.zeros(m, dtype=np.int64)
    verified = np.zeros(m, dtype=np.int64)
    results: List[np.ndarray] = []
    bits = 16
    for qi in range(m):
        rect = workload.rects[qi]
        zlo = _zorder(rect[None, 0:2])[0]
        zhi = _zorder(rect[None, 2:4])[0]
        parts = []
        for k in workload.kw_ids[qi]:
            if k < 0:
                continue
            lo, hi = index.kw_ptr[k], index.kw_ptr[k + 1]
            nodes[qi] += 1
            if lo == hi:
                continue
            a = lo + np.searchsorted(index.code[lo:hi], zlo, side="left")
            b = lo + np.searchsorted(index.code[lo:hi], zhi, side="right")
            cand = index.obj[a:b]
            verified[qi] += cand.size
            if cand.size:
                ok = points_in_rect(dataset.locs[cand], rect)
                parts.append(cand[ok])
        results.append(np.unique(np.concatenate(parts)) if parts else np.zeros(0, np.int32))
    cost = w1 * nodes.astype(np.float64) + w2 * verified.astype(np.float64)
    return QueryStats(nodes_accessed=nodes, verified=verified, results=results, cost=cost)
