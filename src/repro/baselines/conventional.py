"""Conventional (data-driven) baselines: uniform grid + inverted files
(SFC-Quad analogue), STR-packed R-tree + inverted files (R*-IF / SFI
analogue), and CDIR-style agglomerative packing over given bottom clusters
(used for the Fig. 17 packing ablation).

All baselines reuse the WiskIndex container so query execution and size
accounting are identical across indexes -- only the *layout* differs, which
is exactly the paper's experimental control.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from ..core.index import assemble_index, flat_index
from ..core.packing import HierarchyResult
from ..core.types import ClusterSet, GeoTextDataset, WiskIndex, Workload


def build_grid_index(dataset: GeoTextDataset, cells_per_dim: int = 8) -> WiskIndex:
    """Uniform grid + per-cell inverted file (data-agnostic; SFC-Quad-like)."""
    g = cells_per_dim
    ij = np.minimum((dataset.locs * g).astype(np.int32), g - 1)
    assign = ij[:, 0] * g + ij[:, 1]
    # compact non-empty cells
    used, assign = np.unique(assign, return_inverse=True)
    clusters = ClusterSet.from_assignment(dataset, assign.astype(np.int32))
    idx = flat_index(dataset, clusters)
    idx.meta["name"] = f"grid{g}"
    return idx


def _str_pack(mbrs: np.ndarray, fanout: int) -> np.ndarray:
    """STR packing of rectangles into groups of ``fanout`` -> parent ids."""
    n = mbrs.shape[0]
    n_groups = max(1, -(-n // fanout))
    s = int(np.ceil(np.sqrt(n_groups)))
    cx = (mbrs[:, 0] + mbrs[:, 2]) / 2
    cy = (mbrs[:, 1] + mbrs[:, 3]) / 2
    parent = np.zeros(n, dtype=np.int32)
    order_x = np.argsort(cx, kind="stable")
    slice_size = -(-n // s)
    gid = 0
    for si in range(s):
        sl = order_x[si * slice_size : (si + 1) * slice_size]
        if sl.size == 0:
            continue
        sl = sl[np.argsort(cy[sl], kind="stable")]
        for off in range(0, sl.size, fanout):
            parent[sl[off : off + fanout]] = gid
            gid += 1
    return parent


def build_str_rtree(
    dataset: GeoTextDataset, leaf_size: int = 128, fanout: int = 8
) -> WiskIndex:
    """STR bulk-loaded R-tree with a per-leaf inverted file (data-driven)."""
    n = dataset.n
    n_leaves = max(1, -(-n // leaf_size))
    s = int(np.ceil(np.sqrt(n_leaves)))
    order_x = np.argsort(dataset.locs[:, 0], kind="stable")
    assign = np.zeros(n, dtype=np.int32)
    slice_size = -(-n // s)
    leaf = 0
    for si in range(s):
        sl = order_x[si * slice_size : (si + 1) * slice_size]
        if sl.size == 0:
            continue
        sl = sl[np.argsort(dataset.locs[sl, 1], kind="stable")]
        for off in range(0, sl.size, leaf_size):
            assign[sl[off : off + leaf_size]] = leaf
            leaf += 1
    clusters = ClusterSet.from_assignment(dataset, assign)
    # pack upper levels with STR until narrow
    parents: List[np.ndarray] = []
    mbrs = clusters.mbrs
    while mbrs.shape[0] > fanout:
        p = _str_pack(mbrs, fanout)
        parents.append(p)
        n_up = int(p.max()) + 1
        up = np.zeros((n_up, 4), dtype=np.float32)
        for u in range(n_up):
            sel = mbrs[p == u]
            up[u] = (sel[:, 0].min(), sel[:, 1].min(), sel[:, 2].max(), sel[:, 3].max())
        mbrs = up
    hier = HierarchyResult(parents=parents, level_labels=[], packs=[])
    idx = assemble_index(dataset, clusters, hier, meta={"name": "str-rtree"})
    return idx


def cdir_pack_hierarchy(
    clusters: ClusterSet, alpha: float = 0.5, fanout: int = 8
) -> HierarchyResult:
    """CDIR-tree-style packing of bottom clusters: greedy grouping by the
    weighted spatio-textual distance alpha*spatial + (1-alpha)*(1-jaccard).
    This is the Fig. 17 comparison target for the RL packing."""
    parents: List[np.ndarray] = []
    mbrs = clusters.mbrs.copy()
    bms = clusters.bitmaps.copy()

    def popcount(a):
        return np.unpackbits(a.view(np.uint8), axis=-1).sum(-1)

    while mbrs.shape[0] > fanout:
        n = mbrs.shape[0]
        cx = (mbrs[:, 0] + mbrs[:, 2]) / 2
        cy = (mbrs[:, 1] + mbrs[:, 3]) / 2
        sp = np.sqrt((cx[:, None] - cx[None, :]) ** 2 + (cy[:, None] - cy[None, :]) ** 2)
        sp = sp / max(sp.max(), 1e-9)
        inter = popcount(bms[:, None, :] & bms[None, :, :]).astype(np.float64)
        union = popcount(bms[:, None, :] | bms[None, :, :]).astype(np.float64)
        jac = inter / np.maximum(union, 1.0)
        dist = alpha * sp + (1 - alpha) * (1.0 - jac)
        np.fill_diagonal(dist, np.inf)
        parent = np.full(n, -1, dtype=np.int32)
        gid = 0
        order = np.argsort(cx, kind="stable")
        for i in order:
            if parent[i] >= 0:
                continue
            parent[i] = gid
            # take the fanout-1 nearest unassigned
            cand = np.argsort(dist[i], kind="stable")
            taken = 1
            for j in cand:
                if taken >= fanout:
                    break
                if parent[j] < 0:
                    parent[j] = gid
                    taken += 1
            gid += 1
        parents.append(parent)
        n_up = gid
        up_m = np.zeros((n_up, 4), dtype=np.float32)
        up_b = np.zeros((n_up, bms.shape[1]), dtype=np.uint32)
        for u in range(n_up):
            sel = parent == u
            mm = mbrs[sel]
            up_m[u] = (mm[:, 0].min(), mm[:, 1].min(), mm[:, 2].max(), mm[:, 3].max())
            up_b[u] = np.bitwise_or.reduce(bms[sel], axis=0)
        mbrs, bms = up_m, up_b
    return HierarchyResult(parents=parents, level_labels=[], packs=[])


def build_cdir_over_clusters(dataset: GeoTextDataset, clusters: ClusterSet, alpha: float = 0.5) -> WiskIndex:
    hier = cdir_pack_hierarchy(clusters, alpha=alpha)
    return assemble_index(dataset, clusters, hier, meta={"name": f"cdir-pack(a={alpha})"})
