"""Straggler detection + mitigation policy.

On a real pod each host reports per-step wall time; the monitor keeps an
EMA + EMVar per host and flags hosts whose step time exceeds
``mean + k * std`` for ``patience`` consecutive steps. The training loop
consults the policy each step: flagged hosts trigger either a re-dispatch
recommendation (synchronous mode) or stale-gradient dropping (async DP).
On CPU we unit-test the detector with injected delays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    alpha: float = 0.2  # EMA coefficient
    k_sigma: float = 3.0
    patience: int = 3
    min_steps: int = 8  # warmup before flagging


class StragglerMonitor:
    def __init__(self, n_hosts: int, cfg: Optional[StragglerConfig] = None):
        self.cfg = cfg or StragglerConfig()
        self.n = n_hosts
        self.ema = np.zeros(n_hosts)
        self.emvar = np.zeros(n_hosts)
        self.count = 0
        self.breach = np.zeros(n_hosts, dtype=np.int64)

    def observe(self, step_times: np.ndarray) -> List[int]:
        """step_times: (n_hosts,) seconds. Returns flagged host ids."""
        a = self.cfg.alpha
        if self.count == 0:
            self.ema = step_times.astype(float).copy()
        else:
            delta = step_times - self.ema
            self.ema += a * delta
            self.emvar = (1 - a) * (self.emvar + a * delta**2)
        self.count += 1
        if self.count < self.cfg.min_steps:
            return []
        fleet_mean = float(np.median(self.ema))
        fleet_std = float(np.sqrt(np.median(self.emvar) + 1e-12))
        slow = step_times > fleet_mean + self.cfg.k_sigma * max(fleet_std, 0.02 * fleet_mean)
        self.breach = np.where(slow, self.breach + 1, 0)
        return [int(i) for i in np.nonzero(self.breach >= self.cfg.patience)[0]]

    def fleet_step_time(self) -> float:
        return float(np.max(self.ema)) if self.count else 0.0


@dataclasses.dataclass
class MitigationPlan:
    flagged_hosts: List[int]
    action: str  # "none" | "redispatch" | "drop_stale"

    @staticmethod
    def decide(flagged: List[int], async_dp: bool) -> "MitigationPlan":
        if not flagged:
            return MitigationPlan([], "none")
        return MitigationPlan(flagged, "drop_stale" if async_dp else "redispatch")
