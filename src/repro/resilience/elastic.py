"""Elastic scaling: recompute the mesh from surviving devices and re-shard.

``plan_remesh(n_devices)`` picks the largest (data, model) grid that fits
the survivor count while preserving the model-parallel degree where
possible (changing TP degree would change expert/head shard divisibility);
the checkpoint layer then restores the latest step with the new shardings
(ckpt/checkpoint.py::restore). The deterministic data pipeline skips to
``global_step * global_batch`` examples so restarts are bitwise-consistent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh


@dataclasses.dataclass
class RemeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_devices: int

    def make_mesh(self) -> Mesh:
        return jax.make_mesh(self.shape, self.axes)


def plan_remesh(n_devices: int, prefer_model: int = 16) -> RemeshPlan:
    """Largest usable (data, model) grid <= n_devices, keeping model degree
    at the largest power-of-two divisor <= prefer_model."""
    best = (None, None)
    m = prefer_model
    while m >= 1:
        if n_devices >= m:
            drop = n_devices - (n_devices // m) * m
            if best[0] is None or drop < best[0]:
                best = (drop, m)
        m //= 2
    model = best[1] or 1
    data = n_devices // model
    # drop ragged remainder devices (they rejoin at next restart)
    used = data * model
    return RemeshPlan(shape=(data, model), axes=("data", "model"), dropped_devices=n_devices - used)


def data_skip_offset(global_step: int, global_batch: int) -> int:
    """Deterministic pipeline fast-forward for restart."""
    return global_step * global_batch
