"""Version-tolerant shims over moving jax APIs.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and renamed ``check_rep`` -> ``check_vma``) across jax releases. Callers in
this repo use the modern spelling (``jax.shard_map`` semantics with
``check_vma=``); this module makes that spelling work on older jax (0.4.x)
by falling back to the experimental module and translating the kwarg.
"""
from __future__ import annotations

import functools
from typing import Any

try:  # jax >= 0.6 style
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _KWARG = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _KWARG = "check_rep"


@functools.wraps(_shard_map)
def shard_map(*args: Any, **kwargs: Any):
    if "check_vma" in kwargs and _KWARG != "check_vma":
        kwargs[_KWARG] = kwargs.pop("check_vma")
    return _shard_map(*args, **kwargs)


__all__ = ["shard_map"]
