"""Logical-axis sharding rules (MaxText-style).

Every parameter / activation dimension carries a logical name; a rules table
maps logical names to mesh axes. Weights use FSDP-flavored names (``wembed``)
so storage shards over the data axes while activations stay unsharded on the
same dimension (GSPMD inserts the per-layer all-gathers under ``lax.scan``,
giving ZeRO-3 semantics).

The production meshes (launch/mesh.py) are ``("data","model")`` single-pod
and ``("pod","data","model")`` multi-pod; ``dp_axes(mesh)`` returns the data
axes present, so the same rules serve both.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def default_rules(mesh: Mesh) -> Dict[str, Axis]:
    dp = dp_axes(mesh)
    return {
        # activations
        "batch": dp,
        "seq": None,
        "embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_experts": "model",
        "kv_seq": "model",  # decode KV caches: sequence over model (flash-decoding)
        "kv_seq_all": dp + ("model",),  # long-context decode: sequence over everything
        # weights
        "wembed": dp,  # FSDP storage axis
        "heads": "model",
        "kv_heads": None,  # GQA kv heads often < |model|; replicate (see DESIGN.md)
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "layers": None,
        "lora": None,
        "state": None,
        "inner": "model",  # mamba d_inner / xlstm inner: channel TP
        "conv": None,
        "repeat": None,
        # WISK serving (launch/wisk_serve.py, DESIGN.md §3.4) -- three
        # regimes share these names:
        #  * replicated: queries shard over the data axes, the whole
        #    IndexSnapshot replicates (P() -- no logical axis in play);
        #  * index-sharded: a serving mesh carries an "index" axis and the
        #    PartitionedSnapshot's stacked per-shard rows (subtree nodes,
        #    leaves, object blocks, delta buffers) shard their leading dim
        #    over it -- "leaf" resolves to "index" on such meshes;
        #  * legacy flat (launch/flat_legacy.py): the hierarchy-free
        #    fallback distributes leaf rows over "model" on the training-
        #    style meshes, which have no "index" axis.
        "query": dp,
        "leaf": "index" if "index" in mesh.axis_names else "model",
        "word": None,  # keyword bitmap words stay unsharded
        "obj_slot": None,  # per-leaf object blocks ride their leaf's shard
    }


def spec_for(names: Sequence[Optional[str]], rules: Dict[str, Axis]) -> P:
    parts = []
    for n in names:
        if n is None:
            parts.append(None)
        else:
            ax = rules.get(n)
            parts.append(ax if ax is None or isinstance(ax, str) or isinstance(ax, tuple) else None)
    # normalize empty tuples to None
    parts = [None if (isinstance(p, tuple) and len(p) == 0) else p for p in parts]
    return P(*parts)


def named_sharding(mesh: Mesh, names: Sequence[Optional[str]], rules: Optional[Dict[str, Axis]] = None) -> NamedSharding:
    rules = rules or default_rules(mesh)
    return NamedSharding(mesh, spec_for(names, rules))


def constrain(x: jax.Array, names: Sequence[Optional[str]], rules: Dict[str, Axis]) -> jax.Array:
    """with_sharding_constraint by logical names.

    ``rules["__mesh__"]`` (set by the step builder) turns the spec into a
    NamedSharding -- a bare PartitionSpec needs an ambient mesh and silently
    failing there would leave activations unconstrained (GSPMD then
    propagates weight shardings into activations; see EXPERIMENTS.md §Perf
    iteration 0, which measured exactly that).
    """
    mesh = rules.get("__mesh__") if isinstance(rules, dict) else None
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec_for(names, rules)))


def tree_shardings(mesh: Mesh, tree_names: Any, rules: Optional[Dict[str, Axis]] = None):
    """Map a pytree of logical-name tuples to NamedShardings."""
    rules = rules or default_rules(mesh)
    return jax.tree.map(
        lambda names: named_sharding(mesh, names, rules),
        tree_names,
        is_leaf=lambda t: isinstance(t, tuple) and all(isinstance(x, (str, type(None))) for x in t),
    )
