"""Pure-JAX optimizers (no external deps): AdamW, Adafactor, SGD + schedules.

Every optimizer is a pair of pytree-level functions::

    state = init(params)
    updates, state = update(grads, state, params, lr, step)

States are plain pytrees so they checkpoint/re-shard like parameters.
Adafactor keeps factored second moments (row/col) for >=2-D leaves -- the
production choice for very large configs on 16 GB v5e HBM (see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


# ----------------------------------------------------------------- schedules
def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = base_lr * jnp.minimum((step + 1.0) / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return fn


# --------------------------------------------------------------------- adamw
class AdamWState(NamedTuple):
    m: Any
    v: Any


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, wd: float = 0.1):
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(m=jax.tree.map(f32, params), v=jax.tree.map(f32, params))

    def update(grads, state, params, lr, step):
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.v, grads)
        def upd(m_, v_, p_):
            mh = m_ / (1 - b1**t)
            vh = v_ / (1 - b2**t)
            return (-lr * (mh / (jnp.sqrt(vh) + eps) + wd * p_.astype(jnp.float32))).astype(p_.dtype)
        return jax.tree.map(upd, m, v, params), AdamWState(m, v)

    return init, update


# ----------------------------------------------------------------- adafactor
class AdafactorState(NamedTuple):
    vr: Any  # row factors (or full v for <2D leaves)
    vc: Any  # col factors (zeros() sentinel for <2D leaves)


def adafactor(eps: float = 1e-30, clip_thresh: float = 1.0, decay_pow: float = 0.8):
    """Factored second-moment optimizer (Shazeer & Stern) without momentum."""

    def init(params):
        def vr_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return AdafactorState(vr=jax.tree.map(vr_init, params), vc=jax.tree.map(vc_init, params))

    def update(grads, state, params, lr, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay_pow)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr_n = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc_n = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr_n, axis=-1, keepdims=True), eps)
                pre = (vr_n / denom)[..., None] * vc_n[..., None, :]
                u = g / jnp.sqrt(pre + eps)
            else:
                vr_n = beta * vr + (1 - beta) * g2
                vc_n = vc
                u = g / jnp.sqrt(vr_n + eps)
            # update clipping (RMS <= clip_thresh)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            return (-lr * u).astype(p.dtype), vr_n, vc_n

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_vr = tdef.flatten_up_to(state.vr)
        flat_vc = tdef.flatten_up_to(state.vc)
        outs = [upd(g, vr, vc, p) for g, vr, vc, p in zip(flat_g, flat_vr, flat_vc, flat_p)]
        updates = tdef.unflatten([o[0] for o in outs])
        vr = tdef.unflatten([o[1] for o in outs])
        vc = tdef.unflatten([o[2] for o in outs])
        return updates, AdafactorState(vr, vc)

    return init, update


# ----------------------------------------------------------------------- sgd
class SGDState(NamedTuple):
    mom: Any


def sgd(momentum: float = 0.9):
    def init(params):
        return SGDState(mom=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(grads, state, params, lr, step):
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32), state.mom, grads)
        return jax.tree.map(lambda m, p: (-lr * m).astype(p.dtype), mom, params), SGDState(mom)

    return init, update


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}


def get_optimizer(name: str):
    return OPTIMIZERS[name]()
