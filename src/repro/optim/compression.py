"""Gradient compression for the DP all-reduce: top-k sparsification with
error feedback, and int8 quantization with per-tensor scale.

Both are *transforms around the gradient tree* applied before the data-
parallel reduction; error feedback accumulates what compression dropped so
the scheme stays convergent (contraction property -- tested in
tests/test_compression.py with hypothesis).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # same structure as grads


def ef_init(grads_template: Any) -> EFState:
    return EFState(residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template))


def topk_compress(g: jax.Array, frac: float) -> jax.Array:
    """Keep the top-|frac| fraction of entries by magnitude (rest zeroed)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g.shape)


def topk_with_error_feedback(grads: Any, ef: EFState, frac: float = 0.1) -> Tuple[Any, EFState]:
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        comp = topk_compress(acc, frac)
        return comp.astype(g.dtype), acc - comp

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = tdef.unflatten([o[0] for o in outs])
    res = tdef.unflatten([o[1] for o in outs])
    return comp, EFState(residual=res)


def int8_quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_tree_roundtrip(grads: Any) -> Any:
    """Quantize->dequantize every leaf (what the compressed all-reduce sees)."""

    def one(g):
        q, s = int8_quantize(g)
        return int8_dequantize(q, s, g.dtype)

    return jax.tree.map(one, grads)
