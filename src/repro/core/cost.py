"""WISK cost model (paper Eq. 1) and exact cost accounting.

``C(q) = w1 * |G| + w2 * sum_{c in G_q} |O_c|``

where ``G`` is the cluster set, ``G_q`` the clusters that intersect ``q.area``
and share a keyword with ``q.keys``, and ``|O_c|`` the number of objects in
``c`` containing >=1 query keyword (the inverted file fetches postings for the
query keywords over the whole cluster, then filters spatially -- so the count
is keyword-conditioned but *not* spatially restricted).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .types import ClusterSet, GeoTextDataset, Workload, bitmap_intersects, points_in_rect, rects_intersect

DEFAULT_W1 = 0.1  # stage-1 (filter) weight, paper §7.1
DEFAULT_W2 = 1.0  # stage-2 (verify) weight


@dataclasses.dataclass
class CostBreakdown:
    filter_checks: int  # total (query, cluster) filter tests
    verified_objects: int  # total keyword-matching objects scanned in relevant clusters
    total: float
    per_query: np.ndarray  # (m,) float64


def object_query_match(
    dataset: GeoTextDataset, workload: Workload, chunk: int = 262_144
) -> np.ndarray:
    """(m, n) bool: object shares >=1 keyword with the query (no spatial test)."""
    m, n = workload.m, dataset.n
    out = np.zeros((m, n), dtype=bool)
    qbm = workload.kw_bitmap[:, None, :]
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        out[:, lo:hi] = np.any(qbm & dataset.kw_bitmap[None, lo:hi, :], axis=-1)
    return out


def exact_workload_cost(
    dataset: GeoTextDataset,
    clusters: ClusterSet,
    workload: Workload,
    w1: float = DEFAULT_W1,
    w2: float = DEFAULT_W2,
    kw_match: Optional[np.ndarray] = None,
) -> CostBreakdown:
    """Exact Eq. 1 cost of running ``workload`` over the flat cluster set."""
    m, k = workload.m, clusters.k
    if kw_match is None:
        kw_match = object_query_match(dataset, workload)
    # (m, k): cluster relevant to query
    inter = rects_intersect(workload.rects[:, None, :], clusters.mbrs[None, :, :])
    kwc = np.any(
        workload.kw_bitmap[:, None, :] & clusters.bitmaps[None, :, :] != 0, axis=-1
    )
    relevant = inter & kwc
    # per-cluster keyword-matching object counts per query: sum kw_match over members
    # membership matrix via assignment
    per_query = np.full(m, w1 * k, dtype=np.float64)
    verified = 0
    # counts[c] for each query: segment-sum kw_match by cluster assignment
    assign = clusters.assign
    for qi in range(m):
        match_counts = np.bincount(assign[kw_match[qi]], minlength=k)
        v = int(match_counts[relevant[qi]].sum())
        verified += v
        per_query[qi] += w2 * v
    return CostBreakdown(
        filter_checks=m * k,
        verified_objects=verified,
        total=float(per_query.sum()),
        per_query=per_query,
    )


def exact_query_results(
    dataset: GeoTextDataset, workload: Workload, kw_match: Optional[np.ndarray] = None
) -> np.ndarray:
    """(m,) int64 ground-truth result counts (for correctness tests)."""
    if kw_match is None:
        kw_match = object_query_match(dataset, workload)
    inr = (
        (dataset.locs[None, :, 0] >= workload.rects[:, None, 0])
        & (dataset.locs[None, :, 0] <= workload.rects[:, None, 2])
        & (dataset.locs[None, :, 1] >= workload.rects[:, None, 1])
        & (dataset.locs[None, :, 1] <= workload.rects[:, None, 3])
    )
    return np.sum(kw_match & inr, axis=1).astype(np.int64)


def exact_query_result_ids(dataset: GeoTextDataset, rect: np.ndarray, kw_bitmap: np.ndarray) -> np.ndarray:
    """Ground truth ids for a single query (host reference)."""
    match = np.any(dataset.kw_bitmap & kw_bitmap[None, :], axis=-1)
    inr = points_in_rect(dataset.locs, rect)
    return np.nonzero(match & inr)[0].astype(np.int32)
