"""Bottom cluster generation (paper Alg. 2): recursive space splitting where
each split value is *learned* with SGD on the differentiable surrogate cost
(paper Eq. 4):

    L_q(v) = sigma(3(v - q_lo)) * |O1(q)|  +  sigma(3(q_hi - v)) * |O2(q)|

``|O1|/|O2|`` are CDF-bank estimates of keyword-matching objects in the two
sub-spaces (keyword-conditioned over the *whole* sub-space rectangle, per the
cost model). The split of a (sub-)space is accepted when the estimated
verification saving beats the added filtering cost:

    C_s - w2 * best.cost > w1 * |W|      (Alg. 2, line 10)

The optimizer runs multi-restart Adam on both dimensions at once inside one
jitted function; queries are padded to a fixed width per call site bucket to
bound recompilation.

Two execution strategies drive the split recursion (DESIGN.md §5):

* ``mode="batched"`` (default) -- frontier-parallel rounds: every currently
  splittable subspace is learned in one ``vmap``-over-subspaces dispatch per
  (n_subspaces, query_pad) power-of-two bucket, so device calls scale with
  tree *depth*, not node *count*. Accept/split bookkeeping replays the
  sequential priority-heap walk on host, so the learned cluster set is
  identical to the sequential mode's (tests/test_build_parity.py).
* ``mode="sequential"`` -- the original heap loop (one jitted ``_learn_split``
  per subspace), kept for A/B benchmarking and parity testing.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .cdf import CDFBank, est_count_rect
from .cost import DEFAULT_W1, DEFAULT_W2
from .query import round_up_bucket
from .types import ClusterSet, GeoTextDataset, Workload, rects_intersect


@dataclasses.dataclass
class PartitionConfig:
    w1: float = DEFAULT_W1
    w2: float = DEFAULT_W2
    n_restarts: int = 4
    n_steps: int = 120
    lr: float = 0.03
    min_queries: int = 1  # stop splitting below this many intersecting queries
    min_objects: int = 8
    max_clusters: int = 512
    sigmoid_beta: float = 3.0  # paper uses sigma(3x)
    # The paper's sigma(3x) presumes coordinate deltas >> 1; in the unit square
    # we sharpen the relaxation by this factor during SGD (see DESIGN.md). The
    # accept/reject decision always uses hard indicators at the learned value.
    indicator_scale: float = 64.0
    consistent_init_cost: bool = True  # see DESIGN.md: keyword-conditioned C_s
    query_pad: int = 64  # pad workload slices to multiples of this
    # batched mode: cap the vmapped subspace batch per dispatch so the set of
    # compiled (B, Q) shapes stays small and each compile stays cheap
    max_split_batch: int = 16


def _pad_to(a: np.ndarray, size: int, fill) -> np.ndarray:
    if a.shape[0] >= size:
        return a[:size]
    pad = [(0, size - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad, constant_values=fill)


def _learn_split_impl(
    bank_tables: Dict[str, jax.Array],
    nn_params,
    space: jax.Array,  # (4,)
    q_rects: jax.Array,  # (Q, 4) padded
    q_entries: jax.Array,  # (Q, E) int32 padded -1
    q_signs: jax.Array,  # (Q, E) float32
    q_valid: jax.Array,  # (Q,) bool
    lr: float,
    n_steps: int,
    n_restarts: int,
    beta: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (best_cost (2,), best_value (2,), base_cost ()) for dims x,y.

    base_cost = estimated keyword-matching objects summed over valid queries
    for the *unsplit* space (used for the consistent init-cost mode).
    """
    xlo, ylo, xhi, yhi = space[0], space[1], space[2], space[3]

    def est_queries(rect):  # (4,) -> (Q,) counts in rect for each query
        def one(entries, signs):
            c = est_count_rect(bank_tables, nn_params, entries, rect)
            return jnp.sum(jnp.maximum(c, 0.0) * signs)

        cnt = jax.vmap(one)(q_entries, q_signs)
        return jnp.maximum(cnt, 0.0)

    base = jnp.sum(jnp.where(q_valid, est_queries(space), 0.0))

    def loss_dim(v, dim, hard):
        # sub-space rects
        left = jnp.where(dim == 0, jnp.array([xlo, ylo, 0.0, yhi]), jnp.array([xlo, ylo, xhi, 0.0]))
        left = left.at[2 + dim].set(v)
        right = jnp.where(dim == 0, jnp.array([0.0, ylo, xhi, yhi]), jnp.array([xlo, 0.0, xhi, yhi]))
        right = right.at[dim].set(v)
        o1 = est_queries(left)
        o2 = est_queries(right)
        qlo = q_rects[:, dim]
        qhi = q_rects[:, 2 + dim]
        if hard:
            s1 = (v >= qlo).astype(jnp.float32)
            s2 = (qhi >= v).astype(jnp.float32)
        else:
            s1 = jax.nn.sigmoid(beta * (v - qlo))
            s2 = jax.nn.sigmoid(beta * (qhi - v))
        per_q = s1 * o1 + s2 * o2
        return jnp.sum(jnp.where(q_valid, per_q, 0.0))

    lo = jnp.stack([xlo, ylo])
    hi = jnp.stack([xhi, yhi])
    span = hi - lo

    def optimize(dim):
        inits = lo[dim] + span[dim] * (jnp.arange(n_restarts) + 1.0) / (n_restarts + 1.0)

        def run_one(v0):
            def step(carry, _):
                v, m, u, t = carry
                l, g = jax.value_and_grad(lambda vv: loss_dim(vv, dim, False))(v)
                m = 0.9 * m + 0.1 * g
                u = 0.999 * u + 0.001 * g * g
                mhat = m / (1 - 0.9 ** (t + 1))
                uhat = u / (1 - 0.999 ** (t + 1))
                v = v - lr * span[dim] * mhat / (jnp.sqrt(uhat) + 1e-8)
                v = jnp.clip(v, lo[dim] + 1e-6, hi[dim] - 1e-6)
                return (v, m, u, t + 1), l

            (v, _, _, _), _ = jax.lax.scan(step, (v0, 0.0, 0.0, 0), None, length=n_steps)
            # decision cost with hard indicators (see PartitionConfig docstring)
            return v, loss_dim(v, dim, True)

        vs, ls = jax.vmap(run_one)(inits)
        j = jnp.argmin(ls)
        return ls[j], vs[j]

    c0, v0 = optimize(0)
    c1, v1 = optimize(1)
    return jnp.stack([c0, c1]), jnp.stack([v0, v1]), base


@functools.partial(jax.jit, static_argnames=("n_steps", "n_restarts", "beta"))
def _learn_split(
    bank_tables,
    nn_params,
    space,
    q_rects,
    q_entries,
    q_signs,
    q_valid,
    lr: float = 0.03,
    n_steps: int = 120,
    n_restarts: int = 4,
    beta: float = 3.0,
):
    """One-subspace jitted entry point (sequential mode)."""
    return _learn_split_impl(
        bank_tables, nn_params, space, q_rects, q_entries, q_signs, q_valid, lr, n_steps, n_restarts, beta
    )


@functools.partial(jax.jit, static_argnames=("n_steps", "n_restarts", "beta"))
def _learn_split_batched(
    bank_tables,
    nn_params,
    spaces,  # (B, 4)
    q_rects,  # (B, Q, 4)
    q_entries,  # (B, Q, E)
    q_signs,  # (B, Q, E)
    q_valid,  # (B, Q)
    lr: float = 0.03,
    n_steps: int = 120,
    n_restarts: int = 4,
    beta: float = 3.0,
):
    """vmap-over-subspaces twin of ``_learn_split``: one dispatch learns the
    split of every subspace in the round's bucket (DESIGN.md §5). Padded
    subspaces carry all-False ``q_valid`` rows, so their loss (and Adam
    trajectory) is identically zero and they are discarded on host."""

    def one(space, qr, qe, qs, qv):
        return _learn_split_impl(bank_tables, nn_params, space, qr, qe, qs, qv, lr, n_steps, n_restarts, beta)

    return jax.vmap(one)(spaces, q_rects, q_entries, q_signs, q_valid)


@dataclasses.dataclass
class _SubSpace:
    rect: np.ndarray  # (4,)
    obj_ids: np.ndarray
    query_ids: np.ndarray


@dataclasses.dataclass
class PartitionResult:
    clusters: ClusterSet
    n_splits: int
    n_sgd_calls: int  # split-learning problem instances solved
    history: List[Dict]
    # execution-strategy counters (DESIGN.md §5): rounds of frontier-parallel
    # processing and actual jitted device dispatches issued. In sequential
    # mode n_dispatches == n_sgd_calls (one call per subspace) and n_rounds
    # degenerates to the same count.
    n_rounds: int = 0
    n_dispatches: int = 0
    mode: str = "sequential"


def _pad_queries(workload: Workload, q_entries, q_signs, s: _SubSpace, Q: int):
    """Pad one subspace's query slice to width Q (validity-masked)."""
    nq = s.query_ids.size
    qr = _pad_to(workload.rects[s.query_ids], Q, 0.0)
    qe = _pad_to(q_entries[s.query_ids], Q, -1)
    qs = _pad_to(q_signs[s.query_ids], Q, 0.0)
    qv = np.zeros(Q, dtype=bool)
    qv[: min(nq, Q)] = True
    return qr, qe, qs, qv


def _split_children(
    dataset: GeoTextDataset, workload: Workload, s: _SubSpace, d: int, val: float
) -> Optional[Tuple[_SubSpace, _SubSpace]]:
    """Materialize the two children of an accepted split, or None when one
    side would be empty (the subspace is finalized instead, per Alg. 2)."""
    locs = dataset.locs[s.obj_ids]
    left_mask = locs[:, d] <= val
    lids, rids = s.obj_ids[left_mask], s.obj_ids[~left_mask]
    if not (lids.size and rids.size):
        return None
    lrect = s.rect.copy()
    lrect[2 + d] = val
    rrect = s.rect.copy()
    rrect[d] = val
    qrects = workload.rects[s.query_ids]
    lq = s.query_ids[rects_intersect(qrects, lrect[None, :]).astype(bool).reshape(-1)]
    rq = s.query_ids[rects_intersect(qrects, rrect[None, :]).astype(bool).reshape(-1)]
    return _SubSpace(lrect, lids, lq), _SubSpace(rrect, rids, rq)


def _decide(cfg: PartitionConfig, m: int, costs, values, base, nq: int, no: int):
    """Alg. 2 line 10 accept test on one learned result; returns history row."""
    d = int(np.argmin(costs))
    best_cost, best_val = float(costs[d]), float(values[d])
    if cfg.consistent_init_cost:
        c_s = cfg.w2 * float(base)
    else:
        c_s = cfg.w2 * no * nq  # paper-literal |O_s| * |W_s| * w2
    gain = c_s - cfg.w2 * best_cost
    loss = cfg.w1 * m
    return d, best_val, gain, loss


def _root_subspace(dataset: GeoTextDataset, m: int) -> _SubSpace:
    space0 = np.array([0.0, 0.0, 1.0, 1.0], dtype=np.float32)
    # shrink to data MBR
    if dataset.n:
        space0 = np.array(
            [
                dataset.locs[:, 0].min(),
                dataset.locs[:, 1].min(),
                dataset.locs[:, 0].max(),
                dataset.locs[:, 1].max(),
            ],
            dtype=np.float32,
        )
    return _SubSpace(space0, np.arange(dataset.n), np.arange(m))


def _finalize(dataset: GeoTextDataset, final: List[_SubSpace]) -> ClusterSet:
    assign = np.zeros(dataset.n, dtype=np.int32)
    keep = [s for s in final if s.obj_ids.size > 0]
    for ci, s in enumerate(keep):
        assign[s.obj_ids] = ci
    return ClusterSet.from_assignment(dataset, assign)


def generate_bottom_clusters(
    dataset: GeoTextDataset,
    workload: Workload,
    bank: CDFBank,
    q_entries: np.ndarray,
    q_signs: np.ndarray,
    config: Optional[PartitionConfig] = None,
    mode: str = "batched",
) -> PartitionResult:
    """Alg. 2: returns the learned flat partition (bottom clusters).

    ``mode="batched"`` runs frontier-parallel rounds (device dispatches scale
    with tree depth); ``mode="sequential"`` is the original one-subspace-per-
    call heap loop (DESIGN.md §5). The batched mode replays the sequential
    heap walk over batch-learned decisions, so both modes accept/reject
    identical splits and produce the identical cluster set -- including when
    the ``max_clusters`` budget binds (tests/test_build_parity.py).
    """
    cfg = config or PartitionConfig()
    if mode == "sequential":
        return _generate_sequential(dataset, workload, bank, q_entries, q_signs, cfg)
    if mode == "batched":
        return _generate_batched(dataset, workload, bank, q_entries, q_signs, cfg)
    raise ValueError(f"unknown partition mode {mode!r}")


def _generate_sequential(
    dataset: GeoTextDataset,
    workload: Workload,
    bank: CDFBank,
    q_entries: np.ndarray,
    q_signs: np.ndarray,
    cfg: PartitionConfig,
) -> PartitionResult:
    root = _root_subspace(dataset, workload.m)
    final, n_splits, n_sgd, history = _walk_sequential(
        dataset, workload, bank, q_entries, q_signs, cfg, root
    )
    clusters = _finalize(dataset, final)
    return PartitionResult(
        clusters=clusters,
        n_splits=n_splits,
        n_sgd_calls=n_sgd,
        history=history,
        n_rounds=n_sgd,
        n_dispatches=n_sgd,
        mode="sequential",
    )


def _walk_sequential(
    dataset: GeoTextDataset,
    workload: Workload,
    bank: CDFBank,
    q_entries: np.ndarray,
    q_signs: np.ndarray,
    cfg: PartitionConfig,
    root: _SubSpace,
) -> Tuple[List[_SubSpace], int, int, List[Dict]]:
    """The Alg. 2 heap walk from an arbitrary root subspace. Returns the
    final (un-finalized) subspaces so callers can either build a full
    ``ClusterSet`` (``generate_bottom_clusters``) or splice the result into
    an existing partition (``refine_partition``)."""
    tables = bank.jax_tables()
    nn_params = bank.nn_params
    m = workload.m

    heap: List[Tuple[int, int, _SubSpace]] = []
    counter = 0
    heapq.heappush(heap, (-root.query_ids.size, counter, root))
    final: List[_SubSpace] = []
    n_splits = 0
    n_sgd = 0
    history: List[Dict] = []

    while heap:
        _, _, s = heapq.heappop(heap)
        nq, no = s.query_ids.size, s.obj_ids.size
        done = (
            nq < cfg.min_queries
            or no <= cfg.min_objects
            or len(final) + len(heap) + 1 >= cfg.max_clusters
        )
        if not done:
            Q = int(np.ceil(max(nq, 1) / cfg.query_pad) * cfg.query_pad)
            qr, qe, qs, qv = _pad_queries(workload, q_entries, q_signs, s, Q)
            costs, values, base = _learn_split(
                tables,
                nn_params,
                jnp.asarray(s.rect),
                jnp.asarray(qr),
                jnp.asarray(qe),
                jnp.asarray(qs),
                jnp.asarray(qv),
                lr=cfg.lr,
                n_steps=cfg.n_steps,
                n_restarts=cfg.n_restarts,
                beta=cfg.sigmoid_beta * cfg.indicator_scale,
            )
            n_sgd += 1
            d, best_val, gain, loss = _decide(
                cfg, m, np.asarray(costs), np.asarray(values), base, nq, no
            )
            history.append(
                dict(rect=s.rect.tolist(), nq=nq, no=no, dim=d, val=best_val, gain=gain, loss=loss)
            )
            if gain > loss:
                children = _split_children(dataset, workload, s, d, best_val)
                if children is not None:
                    n_splits += 1
                    for child in children:
                        counter += 1
                        heapq.heappush(heap, (-child.query_ids.size, counter, child))
                    continue
        final.append(s)

    return final, n_splits, n_sgd, history


def _learn_frontier(
    workload: Workload,
    q_entries: np.ndarray,
    q_signs: np.ndarray,
    cfg: PartitionConfig,
    tables,
    nn_params,
    batch: List[_SubSpace],
) -> Tuple[Dict[int, Tuple[np.ndarray, np.ndarray, float]], int]:
    """Learn every subspace in ``batch`` with vmapped dispatches over
    power-of-two (n_subspaces, query_pad) buckets (DESIGN.md §5). Returns
    ``{id(subspace): (costs, values, base)}`` plus the dispatch count."""
    E = q_entries.shape[1]
    results: Dict[int, Tuple[np.ndarray, np.ndarray, float]] = {}
    n_dispatches = 0
    by_q: Dict[int, List[_SubSpace]] = {}
    for s in batch:
        Q = round_up_bucket(max(int(s.query_ids.size), 1), cfg.query_pad)
        by_q.setdefault(Q, []).append(s)
    for Q, group in sorted(by_q.items()):
        for lo_i in range(0, len(group), cfg.max_split_batch):
            chunk = group[lo_i : lo_i + cfg.max_split_batch]
            B = round_up_bucket(len(chunk), 1)
            spaces = np.zeros((B, 4), np.float32)
            spaces[:, 2:] = 1.0  # inert unit-square pad subspaces
            qr = np.zeros((B, Q, 4), np.float32)
            qe = np.full((B, Q, E), -1, np.int32)
            qs = np.zeros((B, Q, E), np.float32)
            qv = np.zeros((B, Q), bool)
            for bi, s in enumerate(chunk):
                spaces[bi] = s.rect
                qr[bi], qe[bi], qs[bi], qv[bi] = _pad_queries(workload, q_entries, q_signs, s, Q)
            costs_b, values_b, base_b = _learn_split_batched(
                tables,
                nn_params,
                jnp.asarray(spaces),
                jnp.asarray(qr),
                jnp.asarray(qe),
                jnp.asarray(qs),
                jnp.asarray(qv),
                lr=cfg.lr,
                n_steps=cfg.n_steps,
                n_restarts=cfg.n_restarts,
                beta=cfg.sigmoid_beta * cfg.indicator_scale,
            )
            n_dispatches += 1
            costs_b = np.asarray(costs_b)
            values_b = np.asarray(values_b)
            base_b = np.asarray(base_b)
            for bi, s in enumerate(chunk):
                results[id(s)] = (costs_b[bi], values_b[bi], float(base_b[bi]))
    return results, n_dispatches


def _generate_batched(
    dataset: GeoTextDataset,
    workload: Workload,
    bank: CDFBank,
    q_entries: np.ndarray,
    q_signs: np.ndarray,
    cfg: PartitionConfig,
) -> PartitionResult:
    """Frontier-parallel Alg. 2 (DESIGN.md §5).

    Each round learns the splits of *all* currently splittable heap
    residents in vmapped power-of-two buckets, then replays the sequential
    priority-heap walk over the learned decisions -- identical pop order,
    identical pop-time ``max_clusters`` check -- so the accepted cluster set
    matches the sequential mode exactly (even under a binding budget), while
    device dispatches scale with the walk's blocking depth (~tree depth)
    instead of node count.
    """
    root = _root_subspace(dataset, workload.m)
    final, n_splits, n_sgd, history, n_rounds, n_dispatches = _walk_batched(
        dataset, workload, bank, q_entries, q_signs, cfg, root
    )
    clusters = _finalize(dataset, final)
    return PartitionResult(
        clusters=clusters,
        n_splits=n_splits,
        n_sgd_calls=n_sgd,
        history=history,
        n_rounds=n_rounds,
        n_dispatches=n_dispatches,
        mode="batched",
    )


def _walk_batched(
    dataset: GeoTextDataset,
    workload: Workload,
    bank: CDFBank,
    q_entries: np.ndarray,
    q_signs: np.ndarray,
    cfg: PartitionConfig,
    root: _SubSpace,
) -> Tuple[List[_SubSpace], int, int, List[Dict], int, int]:
    """Frontier-parallel Alg. 2 walk from an arbitrary root subspace (the
    batched twin of ``_walk_sequential``; same replay-parity contract)."""
    tables = bank.jax_tables()
    nn_params = bank.nn_params
    m = workload.m

    heap: List[Tuple[int, int, _SubSpace]] = []
    counter = 0
    heapq.heappush(heap, (-root.query_ids.size, counter, root))
    final: List[_SubSpace] = []
    decided: Dict[int, Tuple[np.ndarray, np.ndarray, float]] = {}
    n_splits = 0
    n_sgd = 0
    n_rounds = 0
    n_dispatches = 0
    history: List[Dict] = []

    while heap:
        # ---- learning round: every undecided, non-size-terminal resident.
        # (Residents the budget later finalizes are learned speculatively;
        # that waste is bounded by one heap's width.)
        batch = [
            s
            for (_, _, s) in heap
            if id(s) not in decided
            and s.query_ids.size >= cfg.min_queries
            and s.obj_ids.size > cfg.min_objects
        ]
        if batch:
            n_rounds += 1
            n_sgd += len(batch)
            results, nd = _learn_frontier(
                workload, q_entries, q_signs, cfg, tables, nn_params, batch
            )
            decided.update(results)
            n_dispatches += nd

        # ---- replay the sequential heap walk until an unlearned child
        # reaches the top (next round) or the heap drains
        progressed = False
        while heap:
            _, _, s = heap[0]
            nq, no = s.query_ids.size, s.obj_ids.size
            # pop-time check identical to the sequential loop's (the peeked
            # node is still in the heap, hence no +1 here)
            terminal = (
                nq < cfg.min_queries
                or no <= cfg.min_objects
                or len(final) + len(heap) >= cfg.max_clusters
            )
            if not terminal and id(s) not in decided:
                break
            heapq.heappop(heap)
            progressed = True
            if terminal:
                # drop any speculative decision: keeps the id()-keyed cache
                # covering live heap residents only (no stale-id hazard)
                decided.pop(id(s), None)
                final.append(s)
                continue
            costs, values, base = decided.pop(id(s))
            d, best_val, gain, loss = _decide(cfg, m, costs, values, base, nq, no)
            history.append(
                dict(rect=s.rect.tolist(), nq=nq, no=no, dim=d, val=best_val, gain=gain, loss=loss)
            )
            children = _split_children(dataset, workload, s, d, best_val) if gain > loss else None
            if children is None:
                final.append(s)
            else:
                n_splits += 1
                for child in children:
                    counter += 1
                    heapq.heappush(heap, (-child.query_ids.size, counter, child))
        if heap and not progressed and not batch:  # defensive: cannot happen
            _, _, s = heapq.heappop(heap)
            final.append(s)

    return final, n_splits, n_sgd, history, n_rounds, n_dispatches


# ----------------------------------------------- warm-start partial refinement
@dataclasses.dataclass
class RefineResult:
    """A partition spliced from kept clusters + re-learned subspaces.

    ``source[c]`` is the previous cluster each new cluster came from
    (identity for kept clusters) -- the mapping the warm-start hierarchy
    graft uses to inherit parent slots (core/build.py:warm_start_rebuild).
    """

    clusters: ClusterSet
    source: np.ndarray  # (k_new,) int32 previous-cluster id per new cluster
    n_refined: int  # regressed leaves re-learned
    n_kept: int  # clusters kept verbatim
    n_splits: int
    n_sgd_calls: int
    n_dispatches: int


def refine_partition(
    dataset: GeoTextDataset,
    workload: Workload,
    bank: CDFBank,
    q_entries: np.ndarray,
    q_signs: np.ndarray,
    prev: ClusterSet,
    regressed: np.ndarray,
    config: Optional[PartitionConfig] = None,
    mode: str = "batched",
) -> RefineResult:
    """Re-learn the splits of the ``regressed`` leaves only (DESIGN.md §7).

    Every non-regressed cluster of ``prev`` is kept verbatim; each
    regressed leaf becomes the root of its own Alg. 2 walk (rect = leaf
    MBR, objects = members, queries = the new workload's queries that
    intersect it and share a keyword) with an equal share of the remaining
    ``max_clusters`` budget. The result is the warm-start rebuild's bottom
    partition: identical learned splits where the workload did not move,
    fresh ones where it did.
    """
    cfg = config or PartitionConfig()
    regressed = np.asarray(regressed, bool)
    k_prev = prev.k
    keep = np.nonzero(~regressed)[0]
    refine = np.nonzero(regressed)[0]
    budget_left = max(cfg.max_clusters - keep.size, 2 * refine.size)
    per_leaf_budget = max(2, budget_left // max(refine.size, 1))

    n_splits = n_sgd = n_disp = 0
    assign = np.full(dataset.n, -1, np.int64)
    source: List[int] = []
    next_id = 0
    for c in keep:
        ids = prev.order[prev.offsets[c] : prev.offsets[c + 1]]
        assign[ids] = next_id
        source.append(int(c))
        next_id += 1
    for c in refine:
        obj_ids = prev.order[prev.offsets[c] : prev.offsets[c + 1]].astype(np.int64)
        rect = prev.mbrs[c].copy()
        qsel = (
            rects_intersect(workload.rects, rect[None, :]).reshape(-1)
            & np.any(workload.kw_bitmap & prev.bitmaps[c][None, :] != 0, axis=-1)
        )
        root = _SubSpace(rect, obj_ids, np.nonzero(qsel)[0])
        sub_cfg = dataclasses.replace(cfg, max_clusters=per_leaf_budget)
        if mode == "sequential":
            final, ns, nq, _ = _walk_sequential(
                dataset, workload, bank, q_entries, q_signs, sub_cfg, root
            )
            nd = nq
        else:
            final, ns, nq, _, _, nd = _walk_batched(
                dataset, workload, bank, q_entries, q_signs, sub_cfg, root
            )
        n_splits += ns
        n_sgd += nq
        n_disp += nd
        for s in final:
            if s.obj_ids.size == 0:
                continue
            assign[s.obj_ids] = next_id
            source.append(int(c))
            next_id += 1
    clusters = ClusterSet.from_assignment(dataset, assign.astype(np.int32))
    return RefineResult(
        clusters=clusters,
        source=np.asarray(source, np.int32),
        n_refined=int(refine.size),
        n_kept=int(keep.size),
        n_splits=n_splits,
        n_sgd_calls=n_sgd,
        n_dispatches=n_disp,
    )
