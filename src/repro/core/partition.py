"""Bottom cluster generation (paper Alg. 2): recursive space splitting where
each split value is *learned* with SGD on the differentiable surrogate cost
(paper Eq. 4):

    L_q(v) = sigma(3(v - q_lo)) * |O1(q)|  +  sigma(3(q_hi - v)) * |O2(q)|

``|O1|/|O2|`` are CDF-bank estimates of keyword-matching objects in the two
sub-spaces (keyword-conditioned over the *whole* sub-space rectangle, per the
cost model). The split of a (sub-)space is accepted when the estimated
verification saving beats the added filtering cost:

    C_s - w2 * best.cost > w1 * |W|      (Alg. 2, line 10)

The optimizer runs multi-restart Adam on both dimensions at once inside one
jitted function; queries are padded to a fixed width per call site bucket to
bound recompilation.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .cdf import CDFBank, est_count_rect
from .cost import DEFAULT_W1, DEFAULT_W2
from .types import ClusterSet, GeoTextDataset, Workload, rects_intersect


@dataclasses.dataclass
class PartitionConfig:
    w1: float = DEFAULT_W1
    w2: float = DEFAULT_W2
    n_restarts: int = 4
    n_steps: int = 120
    lr: float = 0.03
    min_queries: int = 1  # stop splitting below this many intersecting queries
    min_objects: int = 8
    max_clusters: int = 512
    sigmoid_beta: float = 3.0  # paper uses sigma(3x)
    # The paper's sigma(3x) presumes coordinate deltas >> 1; in the unit square
    # we sharpen the relaxation by this factor during SGD (see DESIGN.md). The
    # accept/reject decision always uses hard indicators at the learned value.
    indicator_scale: float = 64.0
    consistent_init_cost: bool = True  # see DESIGN.md: keyword-conditioned C_s
    query_pad: int = 64  # pad workload slices to multiples of this


def _pad_to(a: np.ndarray, size: int, fill) -> np.ndarray:
    if a.shape[0] >= size:
        return a[:size]
    pad = [(0, size - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("n_steps", "n_restarts", "beta"))
def _learn_split(
    bank_tables: Dict[str, jax.Array],
    nn_params,
    space: jax.Array,  # (4,)
    q_rects: jax.Array,  # (Q, 4) padded
    q_entries: jax.Array,  # (Q, E) int32 padded -1
    q_signs: jax.Array,  # (Q, E) float32
    q_valid: jax.Array,  # (Q,) bool
    lr: float = 0.03,
    n_steps: int = 120,
    n_restarts: int = 4,
    beta: float = 3.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (best_cost (2,), best_value (2,), base_cost ()) for dims x,y.

    base_cost = estimated keyword-matching objects summed over valid queries
    for the *unsplit* space (used for the consistent init-cost mode).
    """
    xlo, ylo, xhi, yhi = space[0], space[1], space[2], space[3]

    def est_queries(rect):  # (4,) -> (Q,) counts in rect for each query
        def one(entries, signs):
            c = est_count_rect(bank_tables, nn_params, entries, rect)
            return jnp.sum(jnp.maximum(c, 0.0) * signs)

        cnt = jax.vmap(one)(q_entries, q_signs)
        return jnp.maximum(cnt, 0.0)

    base = jnp.sum(jnp.where(q_valid, est_queries(space), 0.0))

    def loss_dim(v, dim, hard):
        # sub-space rects
        left = jnp.where(dim == 0, jnp.array([xlo, ylo, 0.0, yhi]), jnp.array([xlo, ylo, xhi, 0.0]))
        left = left.at[2 + dim].set(v)
        right = jnp.where(dim == 0, jnp.array([0.0, ylo, xhi, yhi]), jnp.array([xlo, 0.0, xhi, yhi]))
        right = right.at[dim].set(v)
        o1 = est_queries(left)
        o2 = est_queries(right)
        qlo = q_rects[:, dim]
        qhi = q_rects[:, 2 + dim]
        if hard:
            s1 = (v >= qlo).astype(jnp.float32)
            s2 = (qhi >= v).astype(jnp.float32)
        else:
            s1 = jax.nn.sigmoid(beta * (v - qlo))
            s2 = jax.nn.sigmoid(beta * (qhi - v))
        per_q = s1 * o1 + s2 * o2
        return jnp.sum(jnp.where(q_valid, per_q, 0.0))

    lo = jnp.stack([xlo, ylo])
    hi = jnp.stack([xhi, yhi])
    span = hi - lo

    def optimize(dim):
        inits = lo[dim] + span[dim] * (jnp.arange(n_restarts) + 1.0) / (n_restarts + 1.0)

        def run_one(v0):
            def step(carry, _):
                v, m, u, t = carry
                l, g = jax.value_and_grad(lambda vv: loss_dim(vv, dim, False))(v)
                m = 0.9 * m + 0.1 * g
                u = 0.999 * u + 0.001 * g * g
                mhat = m / (1 - 0.9 ** (t + 1))
                uhat = u / (1 - 0.999 ** (t + 1))
                v = v - lr * span[dim] * mhat / (jnp.sqrt(uhat) + 1e-8)
                v = jnp.clip(v, lo[dim] + 1e-6, hi[dim] - 1e-6)
                return (v, m, u, t + 1), l

            (v, _, _, _), _ = jax.lax.scan(step, (v0, 0.0, 0.0, 0), None, length=n_steps)
            # decision cost with hard indicators (see PartitionConfig docstring)
            return v, loss_dim(v, dim, True)

        vs, ls = jax.vmap(run_one)(inits)
        j = jnp.argmin(ls)
        return ls[j], vs[j]

    c0, v0 = optimize(0)
    c1, v1 = optimize(1)
    return jnp.stack([c0, c1]), jnp.stack([v0, v1]), base


@dataclasses.dataclass
class _SubSpace:
    rect: np.ndarray  # (4,)
    obj_ids: np.ndarray
    query_ids: np.ndarray


@dataclasses.dataclass
class PartitionResult:
    clusters: ClusterSet
    n_splits: int
    n_sgd_calls: int
    history: List[Dict]


def generate_bottom_clusters(
    dataset: GeoTextDataset,
    workload: Workload,
    bank: CDFBank,
    q_entries: np.ndarray,
    q_signs: np.ndarray,
    config: Optional[PartitionConfig] = None,
) -> PartitionResult:
    """Alg. 2: returns the learned flat partition (bottom clusters)."""
    cfg = config or PartitionConfig()
    tables = bank.jax_tables()
    nn_params = bank.nn_params

    m = workload.m
    space0 = np.array([0.0, 0.0, 1.0, 1.0], dtype=np.float32)
    # shrink to data MBR
    if dataset.n:
        space0 = np.array(
            [
                dataset.locs[:, 0].min(),
                dataset.locs[:, 1].min(),
                dataset.locs[:, 0].max(),
                dataset.locs[:, 1].max(),
            ],
            dtype=np.float32,
        )
    root = _SubSpace(space0, np.arange(dataset.n), np.arange(m))

    heap: List[Tuple[int, int, _SubSpace]] = []
    counter = 0
    heapq.heappush(heap, (-root.query_ids.size, counter, root))
    final: List[_SubSpace] = []
    n_splits = 0
    n_sgd = 0
    history: List[Dict] = []

    while heap:
        _, _, s = heapq.heappop(heap)
        nq, no = s.query_ids.size, s.obj_ids.size
        done = (
            nq < cfg.min_queries
            or no <= cfg.min_objects
            or len(final) + len(heap) + 1 >= cfg.max_clusters
        )
        if not done:
            Q = int(np.ceil(max(nq, 1) / cfg.query_pad) * cfg.query_pad)
            qr = _pad_to(workload.rects[s.query_ids], Q, 0.0)
            qe = _pad_to(q_entries[s.query_ids], Q, -1)
            qs = _pad_to(q_signs[s.query_ids], Q, 0.0)
            qv = np.zeros(Q, dtype=bool)
            qv[: min(nq, Q)] = True
            costs, values, base = _learn_split(
                tables,
                nn_params,
                jnp.asarray(s.rect),
                jnp.asarray(qr),
                jnp.asarray(qe),
                jnp.asarray(qs),
                jnp.asarray(qv),
                lr=cfg.lr,
                n_steps=cfg.n_steps,
                n_restarts=cfg.n_restarts,
                beta=cfg.sigmoid_beta * cfg.indicator_scale,
            )
            n_sgd += 1
            costs = np.asarray(costs)
            values = np.asarray(values)
            d = int(np.argmin(costs))
            best_cost, best_val = float(costs[d]), float(values[d])
            if cfg.consistent_init_cost:
                c_s = cfg.w2 * float(base)
            else:
                c_s = cfg.w2 * no * nq  # paper-literal |O_s| * |W_s| * w2
            gain = c_s - cfg.w2 * best_cost
            loss = cfg.w1 * m
            history.append(
                dict(rect=s.rect.tolist(), nq=nq, no=no, dim=d, val=best_val, gain=gain, loss=loss)
            )
            if gain > loss:
                # split
                locs = dataset.locs[s.obj_ids]
                left_mask = locs[:, d] <= best_val
                lids, rids = s.obj_ids[left_mask], s.obj_ids[~left_mask]
                if lids.size and rids.size:
                    lrect = s.rect.copy()
                    lrect[2 + d] = best_val
                    rrect = s.rect.copy()
                    rrect[d] = best_val
                    qrects = workload.rects[s.query_ids]
                    lq = s.query_ids[
                        rects_intersect(qrects, lrect[None, :]).astype(bool).reshape(-1)
                    ]
                    rq = s.query_ids[
                        rects_intersect(qrects, rrect[None, :]).astype(bool).reshape(-1)
                    ]
                    n_splits += 1
                    for rect, oids, qids in ((lrect, lids, lq), (rrect, rids, rq)):
                        counter += 1
                        heapq.heappush(heap, (-qids.size, counter, _SubSpace(rect, oids, qids)))
                    continue
        final.append(s)

    assign = np.zeros(dataset.n, dtype=np.int32)
    keep = [s for s in final if s.obj_ids.size > 0]
    for ci, s in enumerate(keep):
        assign[s.obj_ids] = ci
    clusters = ClusterSet.from_assignment(dataset, assign)
    return PartitionResult(clusters=clusters, n_splits=n_splits, n_sgd_calls=n_sgd, history=history)
