"""Keyword-conditioned CDF models (paper §4.3.1 + §6 "Choice of CDF models").

Per keyword ``k`` and spatial dimension ``d`` we model the marginal CDF
``F_k^d`` of the locations of objects containing ``k``. Keywords are
stratified by frequency (thresholds are *fractions of the dataset size*,
matching the paper's percentage bands):

* high   (freq ratio >= ``high_thresh``):  4-layer MLP (1->16->16->16->1),
  ReLU hidden, sigmoid head, trained with MSE on empirical quantiles --
  trained for *all* high keywords at once via ``vmap`` (a bank of MLPs).
* medium (``low_thresh`` <= ratio < ``high_thresh``): Gaussian CDF with
  moment-matched (mu, sigma).
* low    (< ``low_thresh``): ignored (estimate 0), per the paper.

The bank also hosts *frequent itemset* entries (appended virtual keywords)
so multi-keyword queries can be corrected by inclusion-exclusion (§6).

Everything is stored as stacked arrays so count estimation is a single
vectorized function usable inside jitted split-learning losses.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .types import GeoTextDataset

CLASS_LOW, CLASS_MED, CLASS_HIGH = 0, 1, 2


def mlp_init(key: jax.Array, widths: Sequence[int]) -> Dict[str, jax.Array]:
    """Initialize one CDF MLP; widths e.g. (1, 16, 16, 16, 1)."""
    params = {}
    keys = jax.random.split(key, len(widths) - 1)
    for i, (fan_in, fan_out) in enumerate(zip(widths[:-1], widths[1:])):
        params[f"w{i}"] = jax.random.normal(keys[i], (fan_in, fan_out)) * jnp.sqrt(2.0 / fan_in)
        params[f"b{i}"] = jnp.zeros((fan_out,))
    return params


def mlp_apply(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """x: (..., 1) -> (...,) in [0,1]."""
    h = x
    n_layers = len(params) // 2
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return jax.nn.sigmoid(h[..., 0])


def _empirical_quantiles(values: np.ndarray, n_points: int) -> Tuple[np.ndarray, np.ndarray]:
    """Return (x, F(x)) pairs: quantile coordinates and CDF targets."""
    v = np.sort(values)
    # targets: mid-rank CDF, plus anchors at domain edges
    qs = (np.arange(n_points) + 0.5) / n_points
    xs = np.quantile(v, qs)
    xs = np.concatenate([[0.0], xs, [1.0]])
    ys = np.concatenate([[0.0], qs, [1.0]])
    return xs.astype(np.float32), ys.astype(np.float32)


@dataclasses.dataclass
class CDFBank:
    """Stacked CDF models over ``n_entries = V + n_itemsets`` entries.

    cls:      (E,) int8 class per entry
    count:    (E,) float32 #objects containing the entry's keyword(-set)
    gauss:    (E, 2, 2) float32 (mu, sigma) per dim (valid where cls==MED)
    nn_slot:  (E,) int32 slot into the stacked NN params, -1 if none
    nn_params: pytree of arrays with leading dim = n_high (valid where cls==HIGH)
    """

    cls: np.ndarray
    count: np.ndarray
    gauss: np.ndarray
    nn_slot: np.ndarray
    nn_params: Optional[Dict[str, jax.Array]]
    vocab_size: int
    train_loss: float = 0.0

    @property
    def n_entries(self) -> int:
        return int(self.cls.shape[0])

    def jax_tables(self) -> Dict[str, jax.Array]:
        """Device-friendly views used by estimators inside jit."""
        return dict(
            cls=jnp.asarray(self.cls, jnp.int32),
            count=jnp.asarray(self.count, jnp.float32),
            gauss=jnp.asarray(self.gauss, jnp.float32),
            nn_slot=jnp.asarray(self.nn_slot, jnp.int32),
        )


@functools.partial(jax.jit, static_argnames=("n_steps",))
def _train_mlp_bank(
    params: Dict[str, jax.Array],
    xs: jax.Array,  # (B, P) quantile coords per model
    ys: jax.Array,  # (B, P) cdf targets
    lr: float = 0.05,
    n_steps: int = 300,
) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Adam on MSE, vmapped over the bank dimension B."""

    def loss_fn(p, x, y):
        pred = jax.vmap(lambda pi, xi: mlp_apply(pi, xi[:, None]))(p, x)
        return jnp.mean((pred - y) ** 2)

    # Adam state
    m0 = jax.tree.map(jnp.zeros_like, params)
    v0 = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        p, m, v = carry
        l, g = jax.value_and_grad(loss_fn)(p, xs, ys)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1
        mhat = jax.tree.map(lambda a: a / (1 - 0.9**t), m)
        vhat = jax.tree.map(lambda a: a / (1 - 0.999**t), v)
        p = jax.tree.map(lambda a, mh, vh: a - lr * mh / (jnp.sqrt(vh) + 1e-8), p, mhat, vhat)
        return (p, m, v), l

    (params, _, _), losses = jax.lax.scan(step, (params, m0, v0), jnp.arange(n_steps))
    return params, losses[-1]


def build_cdf_bank(
    dataset: GeoTextDataset,
    itemsets: Optional[List[Tuple[int, ...]]] = None,
    itemset_members: Optional[List[np.ndarray]] = None,
    high_thresh: float = 0.001,  # >=0.1% of objects -> NN (paper: >=0.1%)
    low_thresh: float = 0.00001,  # <0.001% -> ignored
    n_points: int = 128,
    n_steps: int = 300,
    hidden: int = 16,
    n_hidden_layers: int = 2,
    seed: int = 0,
    force_class: Optional[str] = None,  # "gauss" | "nn" for the ablation
) -> CDFBank:
    """Fit the stratified CDF bank for all keywords (+ frequent itemsets).

    ``itemsets`` are tuples of keyword ids; ``itemset_members[i]`` are the
    object ids containing *all* keywords of itemset i (from the miner).
    """
    V = dataset.vocab_size
    itemsets = itemsets or []
    itemset_members = itemset_members or []
    E = V + len(itemsets)
    n = max(dataset.n, 1)

    # member object lists per entry
    member_lists: List[np.ndarray] = [None] * E  # type: ignore
    rows, cols = np.nonzero(dataset.kw_ids >= 0)
    ids = dataset.kw_ids[rows, cols]
    order = np.argsort(ids, kind="stable")
    ids_s, rows_s = ids[order], rows[order]
    uk, start = np.unique(ids_s, return_index=True)
    bounds = np.append(start, ids_s.size)
    for j, k in enumerate(uk):
        member_lists[int(k)] = rows_s[bounds[j] : bounds[j + 1]]
    for i, mem in enumerate(itemset_members):
        member_lists[V + i] = np.asarray(mem, dtype=np.int64)

    counts = np.array([0 if m is None else m.size for m in member_lists], dtype=np.float32)
    ratio = counts / n
    cls = np.full(E, CLASS_LOW, dtype=np.int8)
    cls[(ratio >= low_thresh) & (counts >= 2)] = CLASS_MED
    cls[(ratio >= high_thresh) & (counts >= 4)] = CLASS_HIGH
    if force_class == "gauss":
        cls[cls == CLASS_HIGH] = CLASS_MED
    elif force_class == "nn":
        cls[(cls == CLASS_MED) & (counts >= 4)] = CLASS_HIGH

    gauss = np.zeros((E, 2, 2), dtype=np.float32)
    gauss[:, 1, :] = 1.0  # sd row defaults to 1 (safe for unfitted entries)
    nn_slot = np.full(E, -1, dtype=np.int32)

    high_ids = np.nonzero(cls == CLASS_HIGH)[0]
    med_ids = np.nonzero(cls == CLASS_MED)[0]

    for e in med_ids:
        pts = dataset.locs[member_lists[e]]
        mu = pts.mean(axis=0)
        sd = pts.std(axis=0) + 1e-4
        gauss[e, 0] = mu
        gauss[e, 1] = sd

    nn_params = None
    final_loss = 0.0
    if high_ids.size:
        nn_slot[high_ids] = np.arange(high_ids.size, dtype=np.int32)
        # build quantile training data: (n_high*2, P) -- x and y dims interleaved
        P = n_points
        xs = np.zeros((high_ids.size, 2, P + 2), dtype=np.float32)
        ys = np.zeros((high_ids.size, 2, P + 2), dtype=np.float32)
        for j, e in enumerate(high_ids):
            pts = dataset.locs[member_lists[e]]
            for d in range(2):
                xs[j, d], ys[j, d] = _empirical_quantiles(pts[:, d], P)
        widths = (1,) + (hidden,) * n_hidden_layers + (1,)
        key = jax.random.PRNGKey(seed)
        base = mlp_init(key, widths)
        B = high_ids.size * 2
        params = jax.tree.map(lambda a: jnp.broadcast_to(a, (B,) + a.shape).copy(), base)
        # per-model jitter so models are not identical
        keys = jax.random.split(key, B)
        jitter = jax.vmap(lambda k: mlp_init(k, widths))(keys)
        params = jax.tree.map(lambda a, b: a * 0.0 + b, params, jitter)
        params, loss = _train_mlp_bank(
            params, jnp.asarray(xs.reshape(B, -1)), jnp.asarray(ys.reshape(B, -1)), n_steps=n_steps
        )
        nn_params = params
        final_loss = float(loss)

    return CDFBank(
        cls=cls,
        count=counts,
        gauss=gauss,
        nn_slot=nn_slot,
        nn_params=nn_params,
        vocab_size=V,
        train_loss=final_loss,
    )


def _gauss_cdf(x: jax.Array, mu: jax.Array, sd: jax.Array) -> jax.Array:
    sd = jnp.maximum(sd, 1e-5)  # guard: sd=0 would make erf'(inf) NaN-poison grads
    return 0.5 * (1.0 + jax.lax.erf((x - mu) / (sd * jnp.sqrt(2.0))))


def eval_cdf(
    bank_tables: Dict[str, jax.Array],
    nn_params: Optional[Dict[str, jax.Array]],
    entry_ids: jax.Array,  # (B,) int32 entries (keywords or itemset slots), -1 = invalid
    x: jax.Array,  # (B,) coordinates
    dim: int,  # 0 = x, 1 = y
) -> jax.Array:
    """F_e^dim(x) per entry. Invalid/low entries return 0 contribution later
    (the *count* estimator multiplies by entry count which is 0-masked)."""
    eids = jnp.maximum(entry_ids, 0)
    cls = bank_tables["cls"][eids]
    mu = bank_tables["gauss"][eids, 0, dim]
    sd = bank_tables["gauss"][eids, 1, dim]
    g = _gauss_cdf(x, mu, sd)
    if nn_params is not None:
        slot = jnp.maximum(bank_tables["nn_slot"][eids], 0) * 2 + dim
        p = jax.tree.map(lambda a: a[slot], nn_params)
        nn = jax.vmap(lambda pi, xi: mlp_apply(pi, xi[None, None])[0])(p, x)
        out = jnp.where(cls == CLASS_HIGH, nn, g)
    else:
        out = g
    # clamp to [0,1] and enforce boundary behaviour
    out = jnp.clip(out, 0.0, 1.0)
    return jnp.where(entry_ids < 0, 0.0, out)


def est_count_rect(
    bank_tables: Dict[str, jax.Array],
    nn_params: Optional[Dict[str, jax.Array]],
    entry_ids: jax.Array,  # (B,)
    rect: jax.Array,  # (B, 4) or (4,)
) -> jax.Array:
    """Estimated #objects containing entry e inside rect (Lemma 4.2):
    n_e * (Fx(xu)-Fx(xl)) * (Fy(yu)-Fy(yl)). Low-class entries contribute 0.
    """
    rect = jnp.broadcast_to(rect, entry_ids.shape + (4,))
    eids = jnp.maximum(entry_ids, 0)
    cnt = bank_tables["count"][eids]
    cls = bank_tables["cls"][eids]
    fx = eval_cdf(bank_tables, nn_params, entry_ids, rect[..., 2], 0) - eval_cdf(
        bank_tables, nn_params, entry_ids, rect[..., 0], 0
    )
    fy = eval_cdf(bank_tables, nn_params, entry_ids, rect[..., 3], 1) - eval_cdf(
        bank_tables, nn_params, entry_ids, rect[..., 1], 1
    )
    est = cnt * jnp.clip(fx, 0.0, 1.0) * jnp.clip(fy, 0.0, 1.0)
    valid = (entry_ids >= 0) & (cls != CLASS_LOW)
    return jnp.where(valid, est, 0.0)
