"""Deep Q-Network (paper §5.2/§5.3) in pure JAX.

Policy / target networks with soft updates (Eq. 7, tau=0.001), experience
replay (capacity 256 per §7.1), epsilon-greedy with decay, duplicate-action
masking, and SmoothL1 (sum reduction) loss per §7.6.4 on the TD target
(Eq. 6). The replay buffer and the train step are jitted; the environment
loop lives in ``packing.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def q_init(key: jax.Array, state_dim: int, n_actions: int, hidden: int = 64) -> Dict:
    """3-layer MLP (paper: 3 layers, 64 hidden units)."""
    k1, k2, k3 = jax.random.split(key, 3)

    def dense(k, i, o):
        return dict(w=jax.random.normal(k, (i, o)) * jnp.sqrt(2.0 / i), b=jnp.zeros((o,)))

    return dict(l0=dense(k1, state_dim, hidden), l1=dense(k2, hidden, hidden), l2=dense(k3, hidden, n_actions))


def q_apply(params: Dict, s: jax.Array) -> jax.Array:
    h = jax.nn.relu(s @ params["l0"]["w"] + params["l0"]["b"])
    h = jax.nn.relu(h @ params["l1"]["w"] + params["l1"]["b"])
    return h @ params["l2"]["w"] + params["l2"]["b"]


class Replay(NamedTuple):
    s: jax.Array  # (C, D)
    a: jax.Array  # (C,)
    r: jax.Array  # (C,)
    s2: jax.Array  # (C, D)
    mask2: jax.Array  # (C, A) action mask at s2
    done: jax.Array  # (C,)
    ptr: jax.Array  # ()
    size: jax.Array  # ()


def replay_init(capacity: int, state_dim: int, n_actions: int) -> Replay:
    return Replay(
        s=jnp.zeros((capacity, state_dim)),
        a=jnp.zeros((capacity,), jnp.int32),
        r=jnp.zeros((capacity,)),
        s2=jnp.zeros((capacity, state_dim)),
        mask2=jnp.zeros((capacity, n_actions), bool),
        done=jnp.zeros((capacity,), bool),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


@jax.jit
def replay_add(buf: Replay, s, a, r, s2, mask2, done) -> Replay:
    i = buf.ptr
    cap = buf.s.shape[0]
    return Replay(
        s=buf.s.at[i].set(s),
        a=buf.a.at[i].set(a),
        r=buf.r.at[i].set(r),
        s2=buf.s2.at[i].set(s2),
        mask2=buf.mask2.at[i].set(mask2),
        done=buf.done.at[i].set(done),
        ptr=(i + 1) % cap,
        size=jnp.minimum(buf.size + 1, cap),
    )


def smooth_l1(x: jax.Array) -> jax.Array:
    ax = jnp.abs(x)
    return jnp.where(ax < 1.0, 0.5 * x * x, ax - 0.5)


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    gamma: float = 0.99
    tau: float = 0.001
    lr: float = 1e-3
    batch_size: int = 32
    capacity: int = 256
    hidden: int = 64
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay: float = 0.98  # per-episode multiplicative decay


class TrainState(NamedTuple):
    params: Dict
    target: Dict
    opt_m: Dict
    opt_v: Dict
    step: jax.Array


def train_state_init(key: jax.Array, state_dim: int, n_actions: int, cfg: DQNConfig) -> TrainState:
    p = q_init(key, state_dim, n_actions, cfg.hidden)
    return TrainState(
        params=p,
        target=jax.tree.map(jnp.copy, p),
        opt_m=jax.tree.map(jnp.zeros_like, p),
        opt_v=jax.tree.map(jnp.zeros_like, p),
        step=jnp.zeros((), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def dqn_train_step(ts: TrainState, buf: Replay, key: jax.Array, cfg: DQNConfig) -> Tuple[TrainState, jax.Array]:
    """One gradient step on a replay batch (Eq. 6 with SmoothL1-sum)."""
    idx = jax.random.randint(key, (cfg.batch_size,), 0, jnp.maximum(buf.size, 1))
    s, a, r, s2, m2, dn = (buf.s[idx], buf.a[idx], buf.r[idx], buf.s2[idx], buf.mask2[idx], buf.done[idx])

    q_next = q_apply(ts.target, s2)
    q_next = jnp.where(m2, q_next, -jnp.inf)
    max_next = jnp.max(q_next, axis=-1)
    max_next = jnp.where(jnp.isfinite(max_next), max_next, 0.0)
    tgt = r + cfg.gamma * jnp.where(dn, 0.0, max_next)

    def loss_fn(p):
        q = q_apply(p, s)
        qa = jnp.take_along_axis(q, a[:, None], axis=-1)[:, 0]
        return jnp.sum(smooth_l1(qa - jax.lax.stop_gradient(tgt)))

    loss, g = jax.value_and_grad(loss_fn)(ts.params)
    t = ts.step + 1
    m = jax.tree.map(lambda a_, b_: 0.9 * a_ + 0.1 * b_, ts.opt_m, g)
    v = jax.tree.map(lambda a_, b_: 0.999 * a_ + 0.001 * b_ * b_, ts.opt_v, g)
    params = jax.tree.map(
        lambda p_, m_, v_: p_
        - cfg.lr * (m_ / (1 - 0.9 ** t)) / (jnp.sqrt(v_ / (1 - 0.999 ** t)) + 1e-8),
        ts.params,
        m,
        v,
    )
    # soft target update (Eq. 7)
    target = jax.tree.map(lambda tp, pp: (1 - cfg.tau) * tp + cfg.tau * pp, ts.target, params)
    return TrainState(params, target, m, v, t), loss


@jax.jit
def greedy_action(params: Dict, s: jax.Array, mask: jax.Array) -> jax.Array:
    q = q_apply(params, s)
    return jnp.argmax(jnp.where(mask, q, -jnp.inf))


def masked_random_action(key: jax.Array, mask: jax.Array) -> jax.Array:
    """Uniform action over the True entries of ``mask`` (scan/cond-safe).

    Draws j ~ U[0, #valid) with ``key`` and returns the j-th valid slot --
    the traced twin of the host path's ``valid[randint(key, 0, len(valid))]``
    (same key, same draw, same action), which is what makes the scan-compiled
    packing rollout RNG-parity-exact with the Python episode loop
    (DESIGN.md §5; tests/test_build_parity.py).
    """
    nvalid = jnp.sum(mask.astype(jnp.int32))
    j = jax.random.randint(key, (), 0, jnp.maximum(nvalid, 1))
    return jnp.argmax((jnp.cumsum(mask.astype(jnp.int32)) - 1 == j) & mask).astype(jnp.int32)


def train_step_if_ready(
    ts: TrainState, buf: Replay, key: jax.Array, cfg: DQNConfig
) -> Tuple[TrainState, jax.Array, jax.Array]:
    """``dqn_train_step`` gated on replay occupancy, usable inside lax.scan.

    Mirrors the host loop's ``if buf.size >= batch_size: train`` without the
    per-step device->host size sync. Returns (ts, loss, trained?); when the
    buffer is not warm yet the state passes through and loss is 0.
    """
    ready = buf.size >= cfg.batch_size
    ts2, loss = jax.lax.cond(
        ready,
        lambda: dqn_train_step(ts, buf, key, cfg),
        lambda: (ts, jnp.float32(0.0)),
    )
    return ts2, loss, ready
