"""Bottom-up packing with reinforcement learning (paper §5 + Alg. 3).

One level of packing is an MDP: N bottom nodes are inserted sequentially
into at most N upper-node slots. The state is the paper's
``(m+1)*N + m``-vector: for each upper slot its m-dim query-label bitmap and
a child count, plus the label of the incoming bottom node. Reward (Eq. 5) is
the reduction in the average number of accessed nodes per query. Duplicated
empty-slot actions are masked (§6 "Action mask in RL").

Accelerations from §6 are implemented here too: stratified sampling of the
training queries (``data/workloads.py``) and spectral-clustering grouping of
bottom clusters before packing.

Two rollout strategies drive each episode (DESIGN.md §5):

* ``mode="batched"`` (default) -- the episode loop is a single
  ``jax.lax.scan`` with the env state (upper-slot label bitmaps, counts,
  step index) as jnp arrays: epsilon-greedy action selection, duplicate-slot
  masking, the Eq. 5 reward, replay insertion, and the conditional
  ``dqn_train_step`` all run inside the scan body -- one device dispatch per
  episode instead of ~4 per env step. ``PackingConfig.parallel_episodes``
  additionally vmaps exploration episodes per epoch.
* ``mode="sequential"`` -- the original Python-loop episode with per-step
  host syncs, kept for A/B benchmarking; the scan rollout reproduces it
  exactly under matched RNG streams (tests/test_build_parity.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .dqn import (
    DQNConfig,
    TrainState,
    dqn_train_step,
    greedy_action,
    masked_random_action,
    q_apply,
    replay_add,
    replay_init,
    train_state_init,
    train_step_if_ready,
)


@dataclasses.dataclass
class PackingConfig:
    dqn: DQNConfig = dataclasses.field(default_factory=DQNConfig)
    epochs: int = 24
    max_label_queries: int = 48  # m used for state encoding (stratified-sampled)
    min_nodes: int = 3  # stop building levels at or below this width
    max_levels: int = 6
    action_mask: bool = True
    spectral_ratio: float = 1.0  # <1.0 groups bottom clusters first (accel §6)
    # batched mode only: >1 vmaps this many exploration episodes per epoch
    # (transitions are absorbed episode-major afterwards, so the training
    # schedule differs from the sequential one-episode-at-a-time loop)
    parallel_episodes: int = 1
    seed: int = 0


class _Env:
    """One-level packing environment (numpy; tiny state spaces)."""

    def __init__(self, labels: np.ndarray, use_mask: bool):
        self.labels = labels.astype(bool)  # (N, m)
        self.N, self.m = labels.shape
        self.use_mask = use_mask
        self.reset()

    def reset(self) -> np.ndarray:
        self.upper = np.zeros((self.N, self.m), dtype=bool)
        self.counts = np.zeros(self.N, dtype=np.int64)
        self.t = 0
        return self.state()

    def state(self) -> np.ndarray:
        nxt = self.labels[self.t] if self.t < self.N else np.zeros(self.m, bool)
        per_upper = np.concatenate(
            [self.upper.astype(np.float32), (self.counts[:, None] > 0).astype(np.float32)], axis=1
        )
        return np.concatenate([per_upper.reshape(-1), nxt.astype(np.float32)])

    def mask(self) -> np.ndarray:
        if not self.use_mask:
            return np.ones(self.N, dtype=bool)
        m = self.counts > 0
        empties = np.nonzero(~m)[0]
        if empties.size:
            m[empties[0]] = True  # expose exactly one empty slot
        return m

    def avg_accesses(self) -> float:
        """Average #upper nodes a query must traverse into (labeled, nonempty)."""
        if self.m == 0:
            return 0.0
        act = self.upper[self.counts > 0]
        if act.size == 0:
            return 0.0
        return float(act.sum(axis=0).mean())

    def step(self, a: int) -> Tuple[np.ndarray, float, bool]:
        before = self.avg_accesses()
        self.upper[a] |= self.labels[self.t]
        self.counts[a] += 1
        self.t += 1
        after = self.avg_accesses()
        done = self.t >= self.N
        return self.state(), before - after, done


def _run_episode(env: _Env, ts: TrainState, buf, key, eps: float, cfg: PackingConfig, train: bool):
    """Play one packing episode with the original per-step host loop.

    Returns (assignment, sum_rewards, buf, ts, losses, n_dispatches) where
    ``n_dispatches`` counts the jitted device calls issued (uniform draw,
    action selection, replay insertion, train step) -- the quantity the
    scan-compiled rollout collapses to 1 per episode (DESIGN.md §5).
    """
    s = env.reset()
    assign = np.zeros(env.N, dtype=np.int32)
    total_r = 0.0
    losses = []
    n_disp = 0
    for t in range(env.N):
        mask = env.mask()
        key, k1, k2, k3 = jax.random.split(key, 4)
        if train and float(jax.random.uniform(k1)) < eps:
            valid = np.nonzero(mask)[0]
            a = int(valid[int(jax.random.randint(k2, (), 0, valid.size))])
            n_disp += 2  # uniform + randint
        else:
            a = int(greedy_action(ts.params, jnp.asarray(s), jnp.asarray(mask)))
            n_disp += 2 if train else 1  # uniform (train only) + greedy
        s2, r, done = env.step(a)
        assign[t] = a
        total_r += r
        if train:
            mask2 = env.mask() if not done else np.zeros(env.N, bool)
            buf = replay_add(
                buf,
                jnp.asarray(s),
                jnp.int32(a),
                jnp.float32(r),
                jnp.asarray(s2),
                jnp.asarray(mask2),
                jnp.bool_(done),
            )
            n_disp += 1
            if int(buf.size) >= cfg.dqn.batch_size:
                ts, loss = dqn_train_step(ts, buf, k3, cfg.dqn)
                losses.append(float(loss))
                n_disp += 1
        s = s2
    return assign, total_r, buf, ts, losses, n_disp


# ------------------------------------------------- scan-compiled rollout path
def _env_math(labels: jnp.ndarray, use_mask: bool):
    """Traced twins of _Env.state/.mask/.avg_accesses over jnp env state,
    plus the shared epsilon-greedy transition both rollout paths scan over
    (one step body -- a fix to masking/reward/key order fixes both)."""
    N, m = labels.shape
    denom = jnp.float32(max(m, 1))

    def state_vec(upper, counts, t):
        nxt = jnp.where(t < N, labels[jnp.minimum(t, N - 1)], jnp.zeros((m,), bool))
        per_upper = jnp.concatenate(
            [upper.astype(jnp.float32), (counts > 0)[:, None].astype(jnp.float32)], axis=1
        )
        return jnp.concatenate([per_upper.reshape(-1), nxt.astype(jnp.float32)])

    def mask_of(counts):
        used = counts > 0
        if not use_mask:
            return jnp.ones((N,), bool)
        has_empty = jnp.any(~used)
        first_empty = jnp.argmax(~used)  # expose exactly one empty slot
        return used.at[first_empty].set(used[first_empty] | has_empty)

    def access_count(upper, counts):
        # integer numerator of avg_accesses: reward = (before-after)/m exactly
        return jnp.sum(jnp.where((counts > 0)[:, None], upper, False).astype(jnp.int32))

    def transition(params, upper, counts, t, k1, k2, eps, explore: bool):
        """One env step: act (epsilon-greedy when ``explore``), apply, score.
        Returns (s, a, r, upper2, counts2, s2, mask2, done); unused outputs
        are dead-code-eliminated by XLA in the eval rollout."""
        s = state_vec(upper, counts, t)
        msk = mask_of(counts)
        a = jnp.argmax(jnp.where(msk, q_apply(params, s), -jnp.inf)).astype(jnp.int32)
        if explore:
            take_random = jax.random.uniform(k1) < eps
            a = jnp.where(take_random, masked_random_action(k2, msk), a)
        before = access_count(upper, counts)
        upper2 = upper.at[a].set(upper[a] | labels[t])
        counts2 = counts.at[a].add(1)
        after = access_count(upper2, counts2)
        r = (before - after).astype(jnp.float32) / denom
        done = t + 1 >= N
        s2 = state_vec(upper2, counts2, t + 1)
        mask2 = jnp.where(done, jnp.zeros((N,), bool), mask_of(counts2))
        return s, a, r, upper2, counts2, s2, mask2, done

    return N, m, transition


@functools.partial(jax.jit, static_argnames=("cfg", "train", "use_mask"))
def _rollout_episode(
    labels: jnp.ndarray,  # (N, m) bool
    ts: TrainState,
    buf,
    key: jax.Array,
    eps,
    cfg: DQNConfig,
    train: bool,
    use_mask: bool,
):
    """One packing episode as a single lax.scan (DESIGN.md §5).

    Per step: epsilon-greedy action (same key-split order as _run_episode,
    so the RNG streams match bit-for-bit), duplicate-slot masking, the Eq. 5
    access-delta reward, replay insertion, and the occupancy-gated
    dqn_train_step -- all inside the scan body. Returns
    (actions, rewards, buf, ts, losses, trained) with per-step arrays.
    """
    N, m, transition = _env_math(labels, use_mask)

    def step(carry, t):
        upper, counts, key, buf, ts = carry
        key, k1, k2, k3 = jax.random.split(key, 4)
        s, a, r, upper2, counts2, s2, mask2, done = transition(
            ts.params, upper, counts, t, k1, k2, eps, train
        )
        loss = jnp.float32(0.0)
        trained = jnp.bool_(False)
        if train:
            buf = replay_add(buf, s, a, r, s2, mask2, done)
            ts, loss, trained = train_step_if_ready(ts, buf, k3, cfg)
        return (upper2, counts2, key, buf, ts), (a, r, loss, trained)

    carry0 = (jnp.zeros((N, m), bool), jnp.zeros((N,), jnp.int32), key, buf, ts)
    (_, _, _, buf, ts), (acts, rewards, losses, trained) = jax.lax.scan(
        step, carry0, jnp.arange(N)
    )
    return acts, rewards, buf, ts, losses, trained


@functools.partial(jax.jit, static_argnames=("use_mask",))
def _rollout_collect(labels: jnp.ndarray, params: Dict, keys: jax.Array, eps, use_mask: bool):
    """vmapped parallel exploration (PackingConfig.parallel_episodes > 1):
    each key plays one epsilon-greedy episode against frozen ``params`` and
    returns its transitions; training happens afterwards in
    ``_absorb_and_train`` (an intentionally different schedule from the
    sequential loop -- more exploration per parameter refresh)."""
    N, m, transition = _env_math(labels, use_mask)

    def one(key):
        def step(carry, t):
            upper, counts, key = carry
            key, k1, k2, _ = jax.random.split(key, 4)
            s, a, r, upper2, counts2, s2, mask2, done = transition(
                params, upper, counts, t, k1, k2, eps, True
            )
            return (upper2, counts2, key), (s, a, r, s2, mask2, done)

        carry0 = (jnp.zeros((N, m), bool), jnp.zeros((N,), jnp.int32), key)
        _, trans = jax.lax.scan(step, carry0, jnp.arange(N))
        return trans, jnp.sum(trans[2])

    return jax.vmap(one)(keys)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _absorb_and_train(ts: TrainState, buf, trans, key: jax.Array, cfg: DQNConfig):
    """Insert collected transitions episode-major and run the occupancy-gated
    train step after each insertion (one dispatch for the whole epoch)."""

    def step(carry, x):
        ts, buf, key = carry
        s, a, r, s2, m2, dn = x
        buf = replay_add(buf, s, a, r, s2, m2, dn)
        key, k = jax.random.split(key)
        ts, loss, trained = train_step_if_ready(ts, buf, k, cfg)
        return (ts, buf, key), (loss, trained)

    (ts, buf, _), (losses, trained) = jax.lax.scan(step, (ts, buf, key), trans)
    return ts, buf, losses, trained


@dataclasses.dataclass
class LevelPackResult:
    assign: np.ndarray  # (N,) upper slot per bottom node
    n_upper: int
    sum_rewards: float
    losses: List[float]
    reward_curve: List[float]
    n_dispatches: int = 0  # jitted device calls issued for this level
    n_env_steps: int = 0  # env transitions played (incl. parallel episodes)
    mode: str = "sequential"


def pack_one_level(
    labels: np.ndarray, cfg: PackingConfig, seed: int = 0, mode: str = "batched"
) -> LevelPackResult:
    """Train a DQN for one level and return the greedy packing.

    ``mode="batched"`` compiles each episode into one lax.scan dispatch;
    ``mode="sequential"`` is the original per-step host loop (DESIGN.md §5).
    Both share the RNG stream layout, so matched seeds yield matched episodes
    (tests/test_build_parity.py).
    """
    if mode == "sequential":
        return _pack_one_level_sequential(labels, cfg, seed)
    if mode == "batched":
        return _pack_one_level_batched(labels, cfg, seed)
    raise ValueError(f"unknown packing mode {mode!r}")


def _compact_assign(assign: np.ndarray) -> Tuple[np.ndarray, int]:
    used = np.unique(assign)
    remap = {int(u): i for i, u in enumerate(used)}
    return np.array([remap[int(a)] for a in assign], dtype=np.int32), len(used)


def _pack_one_level_sequential(labels: np.ndarray, cfg: PackingConfig, seed: int) -> LevelPackResult:
    N, m = labels.shape
    env = _Env(labels, cfg.action_mask)
    state_dim = (m + 1) * N + m
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    ts = train_state_init(k0, state_dim, N, cfg.dqn)
    buf = replay_init(cfg.dqn.capacity, state_dim, N)
    eps = cfg.dqn.eps_start
    losses: List[float] = []
    curve: List[float] = []
    n_disp = 0
    for ep in range(cfg.epochs):
        key, k = jax.random.split(key)
        _, total_r, buf, ts, ls, d = _run_episode(env, ts, buf, k, eps, cfg, train=True)
        losses.extend(ls)
        curve.append(total_r)
        n_disp += d
        eps = max(cfg.dqn.eps_end, eps * cfg.dqn.eps_decay)
    key, k = jax.random.split(key)
    assign, total_r, _, _, _, d = _run_episode(env, ts, buf, k, 0.0, cfg, train=False)
    n_disp += d
    assign, n_upper = _compact_assign(assign)
    return LevelPackResult(
        assign, n_upper, total_r, losses, curve,
        n_dispatches=n_disp, n_env_steps=(cfg.epochs + 1) * N, mode="sequential",
    )


def _pack_one_level_batched(labels: np.ndarray, cfg: PackingConfig, seed: int) -> LevelPackResult:
    N, m = labels.shape
    labels_j = jnp.asarray(labels.astype(bool))
    state_dim = (m + 1) * N + m
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    ts = train_state_init(k0, state_dim, N, cfg.dqn)
    buf = replay_init(cfg.dqn.capacity, state_dim, N)
    eps = cfg.dqn.eps_start
    losses: List[float] = []
    curve: List[float] = []
    n_disp = 0
    n_env = 0
    P = max(1, int(cfg.parallel_episodes))
    for ep in range(cfg.epochs):
        key, k = jax.random.split(key)
        if P == 1:
            _, rewards, buf, ts, ls, trained = _rollout_episode(
                labels_j, ts, buf, k, eps, cfg.dqn, True, cfg.action_mask
            )
            n_disp += 1
            n_env += N
            curve.append(float(jnp.sum(rewards)))
        else:
            ks = jax.random.split(k, P)
            trans, totals = _rollout_collect(labels_j, ts.params, ks, eps, cfg.action_mask)
            flat = jax.tree.map(lambda x: x.reshape((P * N,) + x.shape[2:]), trans)
            key, k2 = jax.random.split(key)
            ts, buf, ls, trained = _absorb_and_train(ts, buf, flat, k2, cfg.dqn)
            n_disp += 2
            n_env += P * N
            curve.extend(np.asarray(totals, dtype=np.float64).tolist())
        ls_np, tr_np = np.asarray(ls), np.asarray(trained)
        losses.extend(ls_np[tr_np].tolist())
        eps = max(cfg.dqn.eps_end, eps * cfg.dqn.eps_decay)
    key, k = jax.random.split(key)
    acts, rewards, _, _, _, _ = _rollout_episode(
        labels_j, ts, buf, k, 0.0, cfg.dqn, False, cfg.action_mask
    )
    n_disp += 1
    n_env += N
    assign, n_upper = _compact_assign(np.asarray(acts))
    return LevelPackResult(
        assign, n_upper, float(jnp.sum(rewards)), losses, curve,
        n_dispatches=n_disp, n_env_steps=n_env, mode="batched",
    )


def spectral_group(mbrs: np.ndarray, n_groups: int, seed: int = 0) -> np.ndarray:
    """Spectral clustering on MBR corner features (§6 accel). Returns group ids."""
    n = mbrs.shape[0]
    n_groups = max(1, min(n_groups, n))
    if n_groups >= n:
        return np.arange(n, dtype=np.int32)
    feats = mbrs.astype(np.float64)
    d2 = ((feats[:, None, :] - feats[None, :, :]) ** 2).sum(-1)
    sigma2 = np.median(d2[d2 > 0]) + 1e-12 if np.any(d2 > 0) else 1.0
    A = np.exp(-d2 / sigma2)
    np.fill_diagonal(A, 0.0)
    deg = A.sum(1)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1e-12))
    L = np.eye(n) - dinv[:, None] * A * dinv[None, :]
    vals, vecs = np.linalg.eigh(L)
    U = vecs[:, :n_groups]
    U = U / (np.linalg.norm(U, axis=1, keepdims=True) + 1e-12)
    # k-means
    rng = np.random.default_rng(seed)
    centers = U[rng.choice(n, n_groups, replace=False)]
    lab = np.zeros(n, dtype=np.int32)
    for _ in range(25):
        dist = ((U[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        new = dist.argmin(1).astype(np.int32)
        if np.array_equal(new, lab):
            break
        lab = new
        for g in range(n_groups):
            sel = lab == g
            if sel.any():
                centers[g] = U[sel].mean(0)
    # compact
    used = np.unique(lab)
    remap = {int(u): i for i, u in enumerate(used)}
    return np.array([remap[int(x)] for x in lab], dtype=np.int32)


@dataclasses.dataclass
class HierarchyResult:
    parents: List[np.ndarray]  # per built level: parent slot of each lower node
    level_labels: List[np.ndarray]
    packs: List[LevelPackResult]
    n_dispatches: int = 0  # summed over packed levels
    n_env_steps: int = 0


def build_hierarchy(
    bottom_labels: np.ndarray,  # (K, m) bool: bottom cluster x sampled-query label
    bottom_mbrs: np.ndarray,
    cfg: Optional[PackingConfig] = None,
    mode: str = "batched",
) -> HierarchyResult:
    """Pack levels bottom-up until few nodes remain or packing stops helping."""
    cfg = cfg or PackingConfig()
    labels = bottom_labels.astype(bool)
    parents: List[np.ndarray] = []
    packs: List[LevelPackResult] = []
    level_labels: List[np.ndarray] = [labels]

    # optional grouping acceleration on the widest (first) level
    if cfg.spectral_ratio < 1.0 and labels.shape[0] > 8:
        n_groups = max(2, int(np.ceil(labels.shape[0] * cfg.spectral_ratio)))
        gids = spectral_group(bottom_mbrs, n_groups, cfg.seed)
        parents.append(gids)
        ng = gids.max() + 1
        glabels = np.zeros((ng, labels.shape[1]), dtype=bool)
        for i, g in enumerate(gids):
            glabels[g] |= labels[i]
        labels = glabels
        level_labels.append(labels)
        packs.append(LevelPackResult(gids, int(ng), 0.0, [], [], mode=mode))

    seed = cfg.seed
    for lvl in range(cfg.max_levels):
        N = labels.shape[0]
        if N <= cfg.min_nodes:
            break
        res = pack_one_level(labels, cfg, seed=seed + lvl + 1, mode=mode)
        if res.n_upper >= N or res.sum_rewards <= -float(N):
            break  # packing stopped reducing accesses (paper's -N termination)
        parents.append(res.assign)
        packs.append(res)
        new_labels = np.zeros((res.n_upper, labels.shape[1]), dtype=bool)
        for i, a in enumerate(res.assign):
            new_labels[a] |= labels[i]
        labels = new_labels
        level_labels.append(labels)
    return HierarchyResult(
        parents=parents,
        level_labels=level_labels,
        packs=packs,
        n_dispatches=sum(p.n_dispatches for p in packs),
        n_env_steps=sum(p.n_env_steps for p in packs),
    )
