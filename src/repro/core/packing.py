"""Bottom-up packing with reinforcement learning (paper §5 + Alg. 3).

One level of packing is an MDP: N bottom nodes are inserted sequentially
into at most N upper-node slots. The state is the paper's
``(m+1)*N + m``-vector: for each upper slot its m-dim query-label bitmap and
a child count, plus the label of the incoming bottom node. Reward (Eq. 5) is
the reduction in the average number of accessed nodes per query. Duplicated
empty-slot actions are masked (§6 "Action mask in RL").

Accelerations from §6 are implemented here too: stratified sampling of the
training queries (``data/workloads.py``) and spectral-clustering grouping of
bottom clusters before packing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .dqn import (
    DQNConfig,
    TrainState,
    dqn_train_step,
    greedy_action,
    q_apply,
    replay_add,
    replay_init,
    train_state_init,
)


@dataclasses.dataclass
class PackingConfig:
    dqn: DQNConfig = dataclasses.field(default_factory=DQNConfig)
    epochs: int = 24
    max_label_queries: int = 48  # m used for state encoding (stratified-sampled)
    min_nodes: int = 3  # stop building levels at or below this width
    max_levels: int = 6
    action_mask: bool = True
    spectral_ratio: float = 1.0  # <1.0 groups bottom clusters first (accel §6)
    seed: int = 0


class _Env:
    """One-level packing environment (numpy; tiny state spaces)."""

    def __init__(self, labels: np.ndarray, use_mask: bool):
        self.labels = labels.astype(bool)  # (N, m)
        self.N, self.m = labels.shape
        self.use_mask = use_mask
        self.reset()

    def reset(self) -> np.ndarray:
        self.upper = np.zeros((self.N, self.m), dtype=bool)
        self.counts = np.zeros(self.N, dtype=np.int64)
        self.t = 0
        return self.state()

    def state(self) -> np.ndarray:
        nxt = self.labels[self.t] if self.t < self.N else np.zeros(self.m, bool)
        per_upper = np.concatenate(
            [self.upper.astype(np.float32), (self.counts[:, None] > 0).astype(np.float32)], axis=1
        )
        return np.concatenate([per_upper.reshape(-1), nxt.astype(np.float32)])

    def mask(self) -> np.ndarray:
        if not self.use_mask:
            return np.ones(self.N, dtype=bool)
        m = self.counts > 0
        empties = np.nonzero(~m)[0]
        if empties.size:
            m[empties[0]] = True  # expose exactly one empty slot
        return m

    def avg_accesses(self) -> float:
        """Average #upper nodes a query must traverse into (labeled, nonempty)."""
        if self.m == 0:
            return 0.0
        act = self.upper[self.counts > 0]
        if act.size == 0:
            return 0.0
        return float(act.sum(axis=0).mean())

    def step(self, a: int) -> Tuple[np.ndarray, float, bool]:
        before = self.avg_accesses()
        self.upper[a] |= self.labels[self.t]
        self.counts[a] += 1
        self.t += 1
        after = self.avg_accesses()
        done = self.t >= self.N
        return self.state(), before - after, done

    def assignment(self) -> np.ndarray:
        raise NotImplementedError


def _run_episode(env: _Env, ts: TrainState, buf, key, eps: float, cfg: PackingConfig, train: bool):
    """Play one packing episode; returns (assignment, sum_rewards, buf, ts, losses)."""
    s = env.reset()
    assign = np.zeros(env.N, dtype=np.int32)
    total_r = 0.0
    losses = []
    for t in range(env.N):
        mask = env.mask()
        key, k1, k2, k3 = jax.random.split(key, 4)
        if train and float(jax.random.uniform(k1)) < eps:
            valid = np.nonzero(mask)[0]
            a = int(valid[int(jax.random.randint(k2, (), 0, valid.size))])
        else:
            a = int(greedy_action(ts.params, jnp.asarray(s), jnp.asarray(mask)))
        s2, r, done = env.step(a)
        assign[t] = a
        total_r += r
        if train:
            mask2 = env.mask() if not done else np.zeros(env.N, bool)
            buf = replay_add(
                buf,
                jnp.asarray(s),
                jnp.int32(a),
                jnp.float32(r),
                jnp.asarray(s2),
                jnp.asarray(mask2),
                jnp.bool_(done),
            )
            if int(buf.size) >= cfg.dqn.batch_size:
                ts, loss = dqn_train_step(ts, buf, k3, cfg.dqn)
                losses.append(float(loss))
        s = s2
    return assign, total_r, buf, ts, losses


@dataclasses.dataclass
class LevelPackResult:
    assign: np.ndarray  # (N,) upper slot per bottom node
    n_upper: int
    sum_rewards: float
    losses: List[float]
    reward_curve: List[float]


def pack_one_level(
    labels: np.ndarray, cfg: PackingConfig, seed: int = 0
) -> LevelPackResult:
    """Train a DQN for one level and return the greedy packing."""
    N, m = labels.shape
    env = _Env(labels, cfg.action_mask)
    state_dim = (m + 1) * N + m
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    ts = train_state_init(k0, state_dim, N, cfg.dqn)
    buf = replay_init(cfg.dqn.capacity, state_dim, N)
    eps = cfg.dqn.eps_start
    losses: List[float] = []
    curve: List[float] = []
    for ep in range(cfg.epochs):
        key, k = jax.random.split(key)
        _, total_r, buf, ts, ls = _run_episode(env, ts, buf, k, eps, cfg, train=True)
        losses.extend(ls)
        curve.append(total_r)
        eps = max(cfg.dqn.eps_end, eps * cfg.dqn.eps_decay)
    key, k = jax.random.split(key)
    assign, total_r, _, _, _ = _run_episode(env, ts, buf, k, 0.0, cfg, train=False)
    # compact slot ids
    used = np.unique(assign)
    remap = {int(u): i for i, u in enumerate(used)}
    assign = np.array([remap[int(a)] for a in assign], dtype=np.int32)
    return LevelPackResult(assign, len(used), total_r, losses, curve)


def spectral_group(mbrs: np.ndarray, n_groups: int, seed: int = 0) -> np.ndarray:
    """Spectral clustering on MBR corner features (§6 accel). Returns group ids."""
    n = mbrs.shape[0]
    n_groups = max(1, min(n_groups, n))
    if n_groups >= n:
        return np.arange(n, dtype=np.int32)
    feats = mbrs.astype(np.float64)
    d2 = ((feats[:, None, :] - feats[None, :, :]) ** 2).sum(-1)
    sigma2 = np.median(d2[d2 > 0]) + 1e-12 if np.any(d2 > 0) else 1.0
    A = np.exp(-d2 / sigma2)
    np.fill_diagonal(A, 0.0)
    deg = A.sum(1)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1e-12))
    L = np.eye(n) - dinv[:, None] * A * dinv[None, :]
    vals, vecs = np.linalg.eigh(L)
    U = vecs[:, :n_groups]
    U = U / (np.linalg.norm(U, axis=1, keepdims=True) + 1e-12)
    # k-means
    rng = np.random.default_rng(seed)
    centers = U[rng.choice(n, n_groups, replace=False)]
    lab = np.zeros(n, dtype=np.int32)
    for _ in range(25):
        dist = ((U[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        new = dist.argmin(1).astype(np.int32)
        if np.array_equal(new, lab):
            break
        lab = new
        for g in range(n_groups):
            sel = lab == g
            if sel.any():
                centers[g] = U[sel].mean(0)
    # compact
    used = np.unique(lab)
    remap = {int(u): i for i, u in enumerate(used)}
    return np.array([remap[int(x)] for x in lab], dtype=np.int32)


@dataclasses.dataclass
class HierarchyResult:
    parents: List[np.ndarray]  # per built level: parent slot of each lower node
    level_labels: List[np.ndarray]
    packs: List[LevelPackResult]


def build_hierarchy(
    bottom_labels: np.ndarray,  # (K, m) bool: bottom cluster x sampled-query label
    bottom_mbrs: np.ndarray,
    cfg: Optional[PackingConfig] = None,
) -> HierarchyResult:
    """Pack levels bottom-up until few nodes remain or packing stops helping."""
    cfg = cfg or PackingConfig()
    labels = bottom_labels.astype(bool)
    parents: List[np.ndarray] = []
    packs: List[LevelPackResult] = []
    level_labels: List[np.ndarray] = [labels]

    # optional grouping acceleration on the widest (first) level
    if cfg.spectral_ratio < 1.0 and labels.shape[0] > 8:
        n_groups = max(2, int(np.ceil(labels.shape[0] * cfg.spectral_ratio)))
        gids = spectral_group(bottom_mbrs, n_groups, cfg.seed)
        parents.append(gids)
        ng = gids.max() + 1
        glabels = np.zeros((ng, labels.shape[1]), dtype=bool)
        for i, g in enumerate(gids):
            glabels[g] |= labels[i]
        labels = glabels
        level_labels.append(labels)
        packs.append(LevelPackResult(gids, int(ng), 0.0, [], []))

    seed = cfg.seed
    for lvl in range(cfg.max_levels):
        N = labels.shape[0]
        if N <= cfg.min_nodes:
            break
        res = pack_one_level(labels, cfg, seed=seed + lvl + 1)
        if res.n_upper >= N or res.sum_rewards <= -float(N):
            break  # packing stopped reducing accesses (paper's -N termination)
        parents.append(res.assign)
        packs.append(res)
        new_labels = np.zeros((res.n_upper, labels.shape[1]), dtype=bool)
        for i, a in enumerate(res.assign):
            new_labels[a] |= labels[i]
        labels = new_labels
        level_labels.append(labels)
    return HierarchyResult(parents=parents, level_labels=level_labels, packs=packs)
