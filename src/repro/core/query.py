"""SKR query processing over a WiskIndex (paper §3 "Query processing").

Three execution paths:

* ``execute_serial`` -- the paper-faithful traversal: breadth-first descent,
  per-node MBR + bitmap checks, inverted-file verification at leaves. This
  is the host reference used for wall-clock comparisons against baselines
  and for correctness ground truth of the other paths.
* ``execute_level_sync`` -- vectorized (numpy) level-synchronous traversal:
  an (M, n_level) active mask descends the levels. Mirrors the TPU execution
  strategy (see DESIGN.md §3); used to validate the JAX/Pallas serving path.
* ``knn_query`` -- Boolean kNN (paper appendix A): serial best-first search,
  ground truth for the kNN serving paths. Ties at equal distance break by
  smallest object id -- the convention shared by every kNN path (DESIGN.md
  §6), so the returned k-set is independent of traversal order.
* ``knn_level_sync`` -- vectorized (numpy) distance-bounded kNN: kw-filtered
  level descent, then per-query leaf sweeps in ascending MBR min-distance
  order, pruned against the running k-th best. Mirrors the device
  ``serve.engine.retrieve_knn`` descent.

All paths return per-query result ids plus Eq.1-style cost counters.
Distances are computed in float32 throughout, matching the device paths so
equal-distance ties (identical coordinates) resolve identically everywhere;
XLA's FMA fusion may still drift distinct distances by 1 ULP, which the
lexicographic (dist, id) ordering tolerates.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Tuple

import numpy as np

from .cost import DEFAULT_W1, DEFAULT_W2
from .types import GeoTextDataset, Workload, WiskIndex, points_in_rect


@dataclasses.dataclass
class QueryStats:
    nodes_accessed: np.ndarray  # (m,) int64 -- nodes whose MBR/bitmap were checked
    verified: np.ndarray  # (m,) int64 -- objects fetched from inverted files
    results: List[np.ndarray]  # per-query object ids
    cost: np.ndarray  # (m,) float64 Eq.1-style cost

    @property
    def total_cost(self) -> float:
        return float(self.cost.sum())


# --------------------------------------- continuous-filter matching ground truth
def match_subscriptions_bruteforce(
    obj_locs: np.ndarray,  # (N, 2) f32 arriving object points
    obj_kw_ids: np.ndarray,  # (N, max_kw) i32 keyword id lists, -1 padded
    sub_rects: np.ndarray,  # (S, 4) f32 subscription rects
    sub_kw_ids,  # length-S sequence of keyword id lists (-1s ignored)
) -> np.ndarray:
    """(N, S) bool ground-truth continuous-filter match matrix.

    The brute-force host oracle for the pub-sub subsystem (DESIGN.md §8):
    pure set semantics -- object keywords as python sets, closed-rect
    containment per pair -- with none of the bitmap/packing/signature
    machinery the device path uses, so a shared-representation bug cannot
    hide. Empty keyword sets (either side) match nothing, the same Boolean
    contract as an empty SKR query.
    """
    obj_locs = np.asarray(obj_locs, np.float32).reshape(-1, 2)
    obj_kw_ids = np.asarray(obj_kw_ids, np.int64).reshape(obj_locs.shape[0], -1)
    sub_rects = np.asarray(sub_rects, np.float32).reshape(-1, 4)
    out = np.zeros((obj_locs.shape[0], sub_rects.shape[0]), bool)
    osets = [set(int(t) for t in row if t >= 0) for row in obj_kw_ids]
    for s, rect in enumerate(sub_rects):
        kset = set(int(t) for t in np.atleast_1d(np.asarray(sub_kw_ids[s])) if t >= 0)
        if not kset:
            continue
        for i, (x, y) in enumerate(obj_locs):
            if rect[0] <= x <= rect[2] and rect[1] <= y <= rect[3] and osets[i] & kset:
                out[i, s] = True
    return out


class SubscriptionOracle:
    """Ground-truth replay of a continuous-query event schedule (§8).

    The host twin of ``serve.subscribe.SubscriptionIndex``: the same event
    API (subscribe / unsubscribe / arrivals / drain) driven entirely by
    ``match_subscriptions_bruteforce``, with the same id-assignment scheme
    (dense monotonic subscription ids) so notification streams compare
    verbatim. Stream semantics: a subscription sees exactly the objects
    that arrive while it is live -- no retroactive delivery, no delivery
    after unsubscribe, and deleting an object never retracts an already
    emitted notification. Notifications are (object_id, subscription_id)
    pairs in canonical (object id, subscription id) order per arrival
    batch; ``drain()`` empties the queue (exactly-once)."""

    def __init__(self) -> None:
        self._subs = {}  # sub_id -> (rect, kw_ids)
        self._next_sub = 0
        self._pending: List[Tuple[int, int]] = []
        self.emitted_total = 0
        self.matched_total = 0

    def subscribe(self, rect, kw_ids) -> int:
        sid = self._next_sub
        self._next_sub += 1
        self._subs[sid] = (
            np.asarray(rect, np.float32).reshape(4),
            np.asarray(kw_ids, np.int64).reshape(-1),
        )
        return sid

    def unsubscribe(self, sub_id: int) -> bool:
        return self._subs.pop(int(sub_id), None) is not None

    def arrive(self, ids, locs, kw_ids) -> int:
        """Match one arrival batch against the live subscriptions; queue the
        resulting notifications. Returns how many were queued."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size == 0 or not self._subs:
            return 0
        sids = sorted(self._subs)
        mat = match_subscriptions_bruteforce(
            locs, kw_ids,
            np.stack([self._subs[s][0] for s in sids]),
            [self._subs[s][1] for s in sids],
        )
        order = np.argsort(ids, kind="stable")
        n0 = len(self._pending)
        for i in order:
            for j in np.nonzero(mat[i])[0]:
                self._pending.append((int(ids[i]), int(sids[j])))
        n_new = len(self._pending) - n0
        self.matched_total += n_new
        return n_new

    def drain(self) -> np.ndarray:
        out = np.asarray(self._pending, np.int64).reshape(-1, 2)
        self._pending = []
        self.emitted_total += out.shape[0]
        return out


# ------------------------------------------------------- CSR / frontier helpers
def round_up_bucket(n: int, minimum: int = 8) -> int:
    """Next power-of-two >= n (>= minimum): the shared width-bucket discipline.

    Bucketing dynamic widths to powers of two bounds the number of distinct
    shapes any jitted step ever sees (log2 of the largest width), so
    recompiles stay O(log(width)) for the lifetime of the process. Used by
    the serving frontier/batch buckets (serve.engine, launch.wisk_serve) and
    by the batched construction pipeline's (n_subspaces, query_pad) buckets
    (core.partition; DESIGN.md §3 and §5).
    """
    n = max(int(n), 1)
    b = int(minimum)
    while b < n:
        b <<= 1
    return b


def sharded_bucket(m: int, shards: int, minimum: int = 8) -> int:
    """Padded batch size for ``m`` queries split evenly over ``shards``
    devices: each per-device shard is a power-of-two bucket, so the data-
    parallel serving path (launch.wisk_serve.serve_sharded) retraces with the
    same log-bounded shape discipline as the single-device engine. With
    ``shards=1`` this degenerates to ``round_up_bucket``."""
    shards = max(int(shards), 1)
    per_shard = -(-max(int(m), 1) // shards)
    return shards * round_up_bucket(per_shard, minimum)


def padded_child_table(level) -> np.ndarray:
    """(n, max_fanout) int32 child table from a level's CSR, padded with -1.

    The hierarchy is a tree (every lower node has exactly one parent), so the
    rows are disjoint: gathering the rows of a query's surviving frontier
    yields the next frontier with no duplicates. Shared by the numpy
    level-sync path and the device frontier descent (serve.engine).
    """
    cached = getattr(level, "_padded_child_table", None)
    if cached is not None:
        return cached
    counts = np.diff(level.child_ptr)
    fanout = int(counts.max()) if counts.size else 0
    table = np.full((level.n, max(fanout, 1)), -1, dtype=np.int32)
    for u in range(level.n):
        ch = level.child[level.child_ptr[u] : level.child_ptr[u + 1]]
        table[u, : ch.size] = ch
    try:  # memoize on the level (a pure function of its static CSR)
        level._padded_child_table = table
    except AttributeError:  # plain classes/namedtuples without __dict__
        pass
    return table


def propagate_hits(hit: np.ndarray, child_table: np.ndarray, n_down: int) -> np.ndarray:
    """(m, n_up) bool hits -> (m, n_down) bool active-children mask.

    Dense reference for CSR frontier expansion: a child is active iff its
    (unique) parent hit. Equivalent to ``hit @ adjacency > 0`` with the dense
    (n_up, n_down) 0/1 matrix -- the property test in tests/test_properties.py
    pins that equivalence.
    """
    m = hit.shape[0]
    nxt = np.zeros((m, n_down), dtype=bool)
    for f in range(child_table.shape[1]):
        col = child_table[:, f]
        valid = col >= 0
        if valid.any():
            nxt[:, col[valid]] |= hit[:, valid]
    return nxt


def _node_match(level, rect, qbm) -> np.ndarray:
    mb = level.mbrs
    inter = (mb[:, 0] <= rect[2]) & (rect[0] <= mb[:, 2]) & (mb[:, 1] <= rect[3]) & (rect[1] <= mb[:, 3])
    kw = np.any(level.bitmaps & qbm[None, :], axis=1)
    return inter & kw


def _verify_leaf(
    index: WiskIndex, dataset: GeoTextDataset, leaf_id: int, rect, q_kws
) -> Tuple[np.ndarray, int]:
    """Inverted-file verification: postings for query keywords -> spatial filter."""
    inv = index.inv
    lo, hi = inv.kw_ptr[leaf_id], inv.kw_ptr[leaf_id + 1]
    kws = inv.kw[lo:hi]
    cand: List[np.ndarray] = []
    for k in q_kws:
        j = np.searchsorted(kws, k)
        if j < kws.size and kws[j] == k:
            row = lo + j
            cand.append(inv.obj[inv.obj_ptr[row] : inv.obj_ptr[row + 1]])
    if not cand:
        return np.zeros(0, dtype=np.int32), 0
    ids = np.unique(np.concatenate(cand))
    ok = points_in_rect(dataset.locs[ids], rect)
    return ids[ok].astype(np.int32), int(ids.size)


def execute_serial(
    index: WiskIndex,
    dataset: GeoTextDataset,
    workload: Workload,
    w1: float = DEFAULT_W1,
    w2: float = DEFAULT_W2,
) -> QueryStats:
    m = workload.m
    nodes = np.zeros(m, dtype=np.int64)
    verified = np.zeros(m, dtype=np.int64)
    results: List[np.ndarray] = []
    for qi in range(m):
        rect = workload.rects[qi]
        qbm = workload.kw_bitmap[qi]
        q_kws = [int(k) for k in workload.kw_ids[qi] if k >= 0]
        # root level: check every node
        active = np.arange(index.levels[0].n)
        res_parts: List[np.ndarray] = []
        for li, level in enumerate(index.levels):
            nodes[qi] += active.size
            match = _node_match(level, rect, qbm)
            hit = active[match[active]]
            if li == len(index.levels) - 1:
                for leaf in hit:
                    ids, nv = _verify_leaf(index, dataset, int(leaf), rect, q_kws)
                    verified[qi] += nv
                    if ids.size:
                        res_parts.append(ids)
                break
            # expand children of hits
            if hit.size:
                nxt = np.concatenate(
                    [level.child[level.child_ptr[h] : level.child_ptr[h + 1]] for h in hit]
                )
            else:
                nxt = np.zeros(0, dtype=np.int32)
            active = nxt
            if active.size == 0:
                break
        results.append(
            np.unique(np.concatenate(res_parts)) if res_parts else np.zeros(0, dtype=np.int32)
        )
    cost = w1 * nodes.astype(np.float64) + w2 * verified.astype(np.float64)
    return QueryStats(nodes_accessed=nodes, verified=verified, results=results, cost=cost)


def execute_level_sync(
    index: WiskIndex,
    dataset: GeoTextDataset,
    workload: Workload,
    w1: float = DEFAULT_W1,
    w2: float = DEFAULT_W2,
) -> QueryStats:
    """Vectorized traversal with (M, n_level) masks (the TPU execution shape)."""
    m = workload.m
    nodes = np.zeros(m, dtype=np.int64)
    active = np.ones((m, index.levels[0].n), dtype=bool)
    for li, level in enumerate(index.levels):
        mb = level.mbrs
        inter = (
            (mb[None, :, 0] <= workload.rects[:, None, 2])
            & (workload.rects[:, None, 0] <= mb[None, :, 2])
            & (mb[None, :, 1] <= workload.rects[:, None, 3])
            & (workload.rects[:, None, 1] <= mb[None, :, 3])
        )
        kw = np.any(level.bitmaps[None, :, :] & workload.kw_bitmap[:, None, :], axis=2)
        nodes += active.sum(axis=1)
        hit = active & inter & kw
        if li == len(index.levels) - 1:
            leaf_hit = hit
            break
        # propagate to children (CSR frontier expansion, dense-mask form)
        active = propagate_hits(hit, padded_child_table(level), index.levels[li + 1].n)
    # leaf verification (vectorized per leaf)
    verified = np.zeros(m, dtype=np.int64)
    results: List[List[np.ndarray]] = [[] for _ in range(m)]
    clusters = index.clusters
    kwm_cache: dict = {}
    for leaf in range(index.levels[-1].n):
        qs = np.nonzero(leaf_hit[:, leaf])[0]
        if qs.size == 0:
            continue
        ids = clusters.order[clusters.offsets[leaf] : clusters.offsets[leaf + 1]]
        bm = dataset.kw_bitmap[ids]
        locs = dataset.locs[ids]
        for qi in qs:
            match = np.any(bm & workload.kw_bitmap[qi][None, :], axis=1)
            verified[qi] += int(match.sum())
            sel = ids[match & points_in_rect(locs, workload.rects[qi])]
            if sel.size:
                results[qi].append(sel)
    res = [
        np.unique(np.concatenate(r)) if r else np.zeros(0, dtype=np.int32) for r in results
    ]
    cost = w1 * nodes.astype(np.float64) + w2 * verified.astype(np.float64)
    return QueryStats(nodes_accessed=nodes, verified=verified, results=res, cost=cost)


@dataclasses.dataclass
class KnnResult:
    """One query's Boolean kNN answer plus Eq.1-style cost counters.

    ids/dist2 are sorted ascending by (distance, object id) -- the shared
    tie-break convention of every kNN path (DESIGN.md §6). ``ids`` may hold
    fewer than k entries when fewer objects match the query keywords.
    """

    ids: np.ndarray  # (k',) int32, k' <= k
    dist2: np.ndarray  # (k',) float32 squared distances
    nodes_accessed: int  # nodes popped & examined (MBR dist / bitmap checked)
    verified: int  # keyword-matching objects whose distance was computed


def _mbr_dist2_f32(mbrs: np.ndarray, point: np.ndarray) -> np.ndarray:
    """Squared point-to-MBR min-distance, float32 (matches the device kernel
    op-for-op so cross-path distance ties resolve identically)."""
    mbrs = np.asarray(mbrs, np.float32)
    point = np.asarray(point, np.float32)
    dx = np.maximum(np.maximum(mbrs[..., 0] - point[..., 0], point[..., 0] - mbrs[..., 2]), 0.0)
    dy = np.maximum(np.maximum(mbrs[..., 1] - point[..., 1], point[..., 1] - mbrs[..., 3]), 0.0)
    return (dx * dx + dy * dy).astype(np.float32)


def knn_query(
    index: WiskIndex,
    dataset: GeoTextDataset,
    point: np.ndarray,
    kw_bitmap: np.ndarray,
    k: int,
) -> KnnResult:
    """Boolean kNN (appendix A): best-first search over the hierarchy.

    Equal-distance objects break ties by smallest object id, so the returned
    k-set is a pure function of (dataset, query) -- independent of heap
    insertion / traversal order -- and matches the serving paths exactly.
    """
    empty = KnnResult(
        ids=np.zeros(0, np.int32), dist2=np.zeros(0, np.float32), nodes_accessed=0, verified=0
    )
    if k <= 0 or not np.any(kw_bitmap):
        return empty
    point = np.asarray(point, np.float32)
    heap: List[Tuple[float, int, int, int]] = []  # (dist, tie, level, node)
    tie = 0
    root_d = _mbr_dist2_f32(index.levels[0].mbrs, point)
    for u in range(index.levels[0].n):
        heapq.heappush(heap, (float(root_d[u]), tie, 0, u))
        tie += 1
    # selected objects: a max-heap on (-dist, -oid); its root is the entry to
    # evict -- the lexicographically largest (dist, oid), so equal-distance
    # ties evict the largest id first (smallest-id-wins convention)
    out: List[Tuple[float, int]] = []
    nodes = 0
    verified = 0
    clusters = index.clusters
    while heap:
        d, _, li, u = heapq.heappop(heap)
        # strict bound: a node at exactly the k-th distance may still hold an
        # equal-distance object with a smaller id, so only d > bound stops
        if len(out) >= k and d > -out[0][0]:
            break
        nodes += 1
        level = index.levels[li]
        if not np.any(level.bitmaps[u] & kw_bitmap):
            continue
        if li == len(index.levels) - 1:
            ids = clusters.order[clusters.offsets[u] : clusters.offsets[u + 1]]
            match = np.any(dataset.kw_bitmap[ids] & kw_bitmap[None, :], axis=1)
            sel = ids[match]
            verified += int(sel.size)
            dx = dataset.locs[sel, 0] - point[0]
            dy = dataset.locs[sel, 1] - point[1]
            dd_all = (dx * dx + dy * dy).astype(np.float32)
            for oid, dd in zip(sel, dd_all):
                key = (-float(dd), -int(oid))
                if len(out) < k:
                    heapq.heappush(out, key)
                elif key > out[0]:  # (dd, oid) < worst (dist, oid) kept
                    heapq.heapreplace(out, key)
        else:
            ch = level.child[level.child_ptr[u] : level.child_ptr[u + 1]]
            ch_d = _mbr_dist2_f32(index.levels[li + 1].mbrs[ch], point)
            for c, cd in zip(ch, ch_d):
                heapq.heappush(heap, (float(cd), tie, li + 1, int(c)))
                tie += 1
    out.sort(key=lambda t: (-t[0], -t[1]))  # ascending (dist, oid)
    return KnnResult(
        ids=np.array([-oid for _, oid in out], dtype=np.int32),
        dist2=np.array([-dd for dd, _ in out], dtype=np.float32),
        nodes_accessed=nodes,
        verified=verified,
    )


def knn_level_sync(
    index: WiskIndex,
    dataset: GeoTextDataset,
    points: np.ndarray,
    kw_bitmaps: np.ndarray,
    k: int,
) -> dict:
    """Vectorized distance-bounded Boolean kNN -- the host mirror of the
    device descent (``serve.engine.retrieve_knn``, DESIGN.md §6).

    Descends the levels with keyword-only masks (kNN has no rectangle), then
    sweeps each query's surviving leaves in ascending squared MBR
    min-distance, maintaining the k best (dist, id) pairs and stopping as
    soon as the next leaf's min-distance exceeds the current k-th best.
    Returns a dict shaped like ``retrieve_knn``'s (ids padded with -1).
    """
    m = int(np.asarray(points).shape[0])
    points = np.asarray(points, np.float32)
    kw_bitmaps = np.asarray(kw_bitmaps, np.uint32)
    out = dict(
        ids=np.full((m, max(k, 0)), -1, np.int32),
        dist2=np.full((m, max(k, 0)), np.inf, np.float32),
        nodes_checked=np.zeros(m, np.int64),
        verified=np.zeros(m, np.int64),
        leaves_verified=np.zeros(m, np.int64),
        pruned=np.zeros(m, np.int64),
    )
    if k <= 0:
        return out
    # keyword-filtered level descent (an object's keywords are contained in
    # every ancestor bitmap, so this never prunes a leaf holding a match)
    active = np.ones((m, index.levels[0].n), dtype=bool)
    for li, level in enumerate(index.levels):
        out["nodes_checked"] += active.sum(axis=1)
        kw = np.any(level.bitmaps[None, :, :] & kw_bitmaps[:, None, :], axis=2)
        hit = active & kw
        if li == len(index.levels) - 1:
            leaf_hit = hit
            break
        active = propagate_hits(hit, padded_child_table(level), index.levels[li + 1].n)
    leaves = index.levels[-1]
    d2 = np.where(leaf_hit, _mbr_dist2_f32(leaves.mbrs[None, :, :], points[:, None, :]), np.inf)
    clusters = index.clusters
    id_sentinel = np.int64(np.iinfo(np.int32).max)
    for qi in range(m):
        order = np.argsort(d2[qi], kind="stable")  # ties: smallest leaf id first
        best_d = np.full(k, np.inf, np.float32)
        best_id = np.full(k, id_sentinel, np.int64)
        for pos, leaf in enumerate(order):
            dq = d2[qi, leaf]
            if not np.isfinite(dq):
                break
            if dq > best_d[k - 1]:
                out["pruned"][qi] += int(np.isfinite(d2[qi, order[pos:]]).sum())
                break
            ids = clusters.order[clusters.offsets[leaf] : clusters.offsets[leaf + 1]]
            kwm = np.any(dataset.kw_bitmap[ids] & kw_bitmaps[qi][None, :], axis=1)
            sel = ids[kwm]
            out["leaves_verified"][qi] += 1
            out["verified"][qi] += int(sel.size)
            if sel.size:
                dx = dataset.locs[sel, 0] - points[qi, 0]
                dy = dataset.locs[sel, 1] - points[qi, 1]
                od = (dx * dx + dy * dy).astype(np.float32)
                alld = np.concatenate([best_d, od])
                allid = np.concatenate([best_id, sel.astype(np.int64)])
                keep = np.lexsort((allid, alld))[:k]
                best_d, best_id = alld[keep], allid[keep]
        fin = np.isfinite(best_d)
        out["ids"][qi] = np.where(fin, best_id, -1).astype(np.int32)
        out["dist2"][qi] = best_d
    return out
