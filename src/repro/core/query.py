"""SKR query processing over a WiskIndex (paper §3 "Query processing").

Three execution paths:

* ``execute_serial`` -- the paper-faithful traversal: breadth-first descent,
  per-node MBR + bitmap checks, inverted-file verification at leaves. This
  is the host reference used for wall-clock comparisons against baselines
  and for correctness ground truth of the other paths.
* ``execute_level_sync`` -- vectorized (numpy) level-synchronous traversal:
  an (M, n_level) active mask descends the levels. Mirrors the TPU execution
  strategy (see DESIGN.md §3); used to validate the JAX/Pallas serving path.
* kNN (Boolean kNN, paper appendix A): best-first search.

All paths return per-query result ids plus Eq.1-style cost counters.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Tuple

import numpy as np

from .cost import DEFAULT_W1, DEFAULT_W2
from .types import GeoTextDataset, Workload, WiskIndex, points_in_rect


@dataclasses.dataclass
class QueryStats:
    nodes_accessed: np.ndarray  # (m,) int64 -- nodes whose MBR/bitmap were checked
    verified: np.ndarray  # (m,) int64 -- objects fetched from inverted files
    results: List[np.ndarray]  # per-query object ids
    cost: np.ndarray  # (m,) float64 Eq.1-style cost

    @property
    def total_cost(self) -> float:
        return float(self.cost.sum())


# ------------------------------------------------------- CSR / frontier helpers
def round_up_bucket(n: int, minimum: int = 8) -> int:
    """Next power-of-two >= n (>= minimum): the shared width-bucket discipline.

    Bucketing dynamic widths to powers of two bounds the number of distinct
    shapes any jitted step ever sees (log2 of the largest width), so
    recompiles stay O(log(width)) for the lifetime of the process. Used by
    the serving frontier/batch buckets (serve.engine, launch.wisk_serve) and
    by the batched construction pipeline's (n_subspaces, query_pad) buckets
    (core.partition; DESIGN.md §3 and §5).
    """
    n = max(int(n), 1)
    b = int(minimum)
    while b < n:
        b <<= 1
    return b


def padded_child_table(level) -> np.ndarray:
    """(n, max_fanout) int32 child table from a level's CSR, padded with -1.

    The hierarchy is a tree (every lower node has exactly one parent), so the
    rows are disjoint: gathering the rows of a query's surviving frontier
    yields the next frontier with no duplicates. Shared by the numpy
    level-sync path and the device frontier descent (serve.engine).
    """
    cached = getattr(level, "_padded_child_table", None)
    if cached is not None:
        return cached
    counts = np.diff(level.child_ptr)
    fanout = int(counts.max()) if counts.size else 0
    table = np.full((level.n, max(fanout, 1)), -1, dtype=np.int32)
    for u in range(level.n):
        ch = level.child[level.child_ptr[u] : level.child_ptr[u + 1]]
        table[u, : ch.size] = ch
    try:  # memoize on the level (a pure function of its static CSR)
        level._padded_child_table = table
    except AttributeError:  # plain classes/namedtuples without __dict__
        pass
    return table


def propagate_hits(hit: np.ndarray, child_table: np.ndarray, n_down: int) -> np.ndarray:
    """(m, n_up) bool hits -> (m, n_down) bool active-children mask.

    Dense reference for CSR frontier expansion: a child is active iff its
    (unique) parent hit. Equivalent to ``hit @ adjacency > 0`` with the dense
    (n_up, n_down) 0/1 matrix -- the property test in tests/test_properties.py
    pins that equivalence.
    """
    m = hit.shape[0]
    nxt = np.zeros((m, n_down), dtype=bool)
    for f in range(child_table.shape[1]):
        col = child_table[:, f]
        valid = col >= 0
        if valid.any():
            nxt[:, col[valid]] |= hit[:, valid]
    return nxt


def _node_match(level, rect, qbm) -> np.ndarray:
    mb = level.mbrs
    inter = (mb[:, 0] <= rect[2]) & (rect[0] <= mb[:, 2]) & (mb[:, 1] <= rect[3]) & (rect[1] <= mb[:, 3])
    kw = np.any(level.bitmaps & qbm[None, :], axis=1)
    return inter & kw


def _verify_leaf(
    index: WiskIndex, dataset: GeoTextDataset, leaf_id: int, rect, q_kws
) -> Tuple[np.ndarray, int]:
    """Inverted-file verification: postings for query keywords -> spatial filter."""
    inv = index.inv
    lo, hi = inv.kw_ptr[leaf_id], inv.kw_ptr[leaf_id + 1]
    kws = inv.kw[lo:hi]
    cand: List[np.ndarray] = []
    for k in q_kws:
        j = np.searchsorted(kws, k)
        if j < kws.size and kws[j] == k:
            row = lo + j
            cand.append(inv.obj[inv.obj_ptr[row] : inv.obj_ptr[row + 1]])
    if not cand:
        return np.zeros(0, dtype=np.int32), 0
    ids = np.unique(np.concatenate(cand))
    ok = points_in_rect(dataset.locs[ids], rect)
    return ids[ok].astype(np.int32), int(ids.size)


def execute_serial(
    index: WiskIndex,
    dataset: GeoTextDataset,
    workload: Workload,
    w1: float = DEFAULT_W1,
    w2: float = DEFAULT_W2,
) -> QueryStats:
    m = workload.m
    nodes = np.zeros(m, dtype=np.int64)
    verified = np.zeros(m, dtype=np.int64)
    results: List[np.ndarray] = []
    for qi in range(m):
        rect = workload.rects[qi]
        qbm = workload.kw_bitmap[qi]
        q_kws = [int(k) for k in workload.kw_ids[qi] if k >= 0]
        # root level: check every node
        active = np.arange(index.levels[0].n)
        res_parts: List[np.ndarray] = []
        for li, level in enumerate(index.levels):
            nodes[qi] += active.size
            match = _node_match(level, rect, qbm)
            hit = active[match[active]]
            if li == len(index.levels) - 1:
                for leaf in hit:
                    ids, nv = _verify_leaf(index, dataset, int(leaf), rect, q_kws)
                    verified[qi] += nv
                    if ids.size:
                        res_parts.append(ids)
                break
            # expand children of hits
            if hit.size:
                nxt = np.concatenate(
                    [level.child[level.child_ptr[h] : level.child_ptr[h + 1]] for h in hit]
                )
            else:
                nxt = np.zeros(0, dtype=np.int32)
            active = nxt
            if active.size == 0:
                for _ in range(li + 1, len(index.levels)):
                    pass
                break
        results.append(
            np.unique(np.concatenate(res_parts)) if res_parts else np.zeros(0, dtype=np.int32)
        )
    cost = w1 * nodes.astype(np.float64) + w2 * verified.astype(np.float64)
    return QueryStats(nodes_accessed=nodes, verified=verified, results=results, cost=cost)


def execute_level_sync(
    index: WiskIndex,
    dataset: GeoTextDataset,
    workload: Workload,
    w1: float = DEFAULT_W1,
    w2: float = DEFAULT_W2,
) -> QueryStats:
    """Vectorized traversal with (M, n_level) masks (the TPU execution shape)."""
    m = workload.m
    nodes = np.zeros(m, dtype=np.int64)
    active = np.ones((m, index.levels[0].n), dtype=bool)
    for li, level in enumerate(index.levels):
        mb = level.mbrs
        inter = (
            (mb[None, :, 0] <= workload.rects[:, None, 2])
            & (workload.rects[:, None, 0] <= mb[None, :, 2])
            & (mb[None, :, 1] <= workload.rects[:, None, 3])
            & (workload.rects[:, None, 1] <= mb[None, :, 3])
        )
        kw = np.any(level.bitmaps[None, :, :] & workload.kw_bitmap[:, None, :], axis=2)
        nodes += active.sum(axis=1)
        hit = active & inter & kw
        if li == len(index.levels) - 1:
            leaf_hit = hit
            break
        # propagate to children (CSR frontier expansion, dense-mask form)
        active = propagate_hits(hit, padded_child_table(level), index.levels[li + 1].n)
    # leaf verification (vectorized per leaf)
    verified = np.zeros(m, dtype=np.int64)
    results: List[List[np.ndarray]] = [[] for _ in range(m)]
    clusters = index.clusters
    kwm_cache: dict = {}
    for leaf in range(index.levels[-1].n):
        qs = np.nonzero(leaf_hit[:, leaf])[0]
        if qs.size == 0:
            continue
        ids = clusters.order[clusters.offsets[leaf] : clusters.offsets[leaf + 1]]
        bm = dataset.kw_bitmap[ids]
        locs = dataset.locs[ids]
        for qi in qs:
            match = np.any(bm & workload.kw_bitmap[qi][None, :], axis=1)
            verified[qi] += int(match.sum())
            sel = ids[match & points_in_rect(locs, workload.rects[qi])]
            if sel.size:
                results[qi].append(sel)
    res = [
        np.unique(np.concatenate(r)) if r else np.zeros(0, dtype=np.int32) for r in results
    ]
    cost = w1 * nodes.astype(np.float64) + w2 * verified.astype(np.float64)
    return QueryStats(nodes_accessed=nodes, verified=verified, results=res, cost=cost)


def knn_query(
    index: WiskIndex,
    dataset: GeoTextDataset,
    point: np.ndarray,
    kw_bitmap: np.ndarray,
    k: int,
) -> np.ndarray:
    """Boolean kNN (appendix A): best-first search over the hierarchy."""

    def mbr_dist2(mb):
        dx = np.maximum(np.maximum(mb[0] - point[0], point[0] - mb[2]), 0.0)
        dy = np.maximum(np.maximum(mb[1] - point[1], point[1] - mb[3]), 0.0)
        return dx * dx + dy * dy

    heap: List[Tuple[float, int, int, int]] = []  # (dist, tie, level, node)
    tie = 0
    for u in range(index.levels[0].n):
        heapq.heappush(heap, (float(mbr_dist2(index.levels[0].mbrs[u])), tie, 0, u))
        tie += 1
    out: List[Tuple[float, int]] = []  # max-heap by -dist of selected objects
    clusters = index.clusters
    while heap:
        d, _, li, u = heapq.heappop(heap)
        if len(out) >= k and d >= -out[0][0]:
            break
        level = index.levels[li]
        if not np.any(level.bitmaps[u] & kw_bitmap):
            continue
        if li == len(index.levels) - 1:
            ids = clusters.order[clusters.offsets[u] : clusters.offsets[u + 1]]
            match = np.any(dataset.kw_bitmap[ids] & kw_bitmap[None, :], axis=1)
            for oid in ids[match]:
                dd = float(((dataset.locs[oid] - point) ** 2).sum())
                if len(out) < k:
                    heapq.heappush(out, (-dd, int(oid)))
                elif dd < -out[0][0]:
                    heapq.heapreplace(out, (-dd, int(oid)))
        else:
            for c in level.child[level.child_ptr[u] : level.child_ptr[u + 1]]:
                heapq.heappush(
                    heap, (float(mbr_dist2(index.levels[li + 1].mbrs[c])), tie, li + 1, int(c))
                )
                tie += 1
    out.sort(key=lambda t: -t[0])
    return np.array([oid for _, oid in out], dtype=np.int32)
