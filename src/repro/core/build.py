"""WISK construction (paper Alg. 1): end-to-end orchestration.

Step 1: mine frequent itemsets, fit the CDF bank, learn the bottom clusters
        with SGD split learning (Alg. 2).
Step 2: label bottom clusters with (sampled) training queries and pack them
        level by level with the DQN (Alg. 3).

``accelerated=True`` enables the §6 accelerations: stratified query sampling
(default 30%) and spectral-clustering grouping of bottom clusters (default
20% ratio), matching the "Accelerated WISK" row of Table 4.

``construction`` selects the execution strategy for both learned phases
(DESIGN.md §5): ``"batched"`` (default) runs frontier-parallel split
learning and scan-compiled RL packing (device dispatches scale with tree
depth + episode count); ``"sequential"`` keeps the original per-subspace /
per-env-step host loops for A/B. Per-phase timings plus round/dispatch
counters land in ``BuildArtifacts.timings`` / ``.counters``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from .cdf import CDFBank, build_cdf_bank
from .index import assemble_index
from .itemsets import expand_queries, mine_frequent_itemsets
from .packing import HierarchyResult, PackingConfig, build_hierarchy
from .partition import (
    PartitionConfig,
    PartitionResult,
    generate_bottom_clusters,
    refine_partition,
)
from .types import ClusterSet, GeoTextDataset, Workload, WiskIndex, rects_intersect


@dataclasses.dataclass
class BuildConfig:
    partition: PartitionConfig = dataclasses.field(default_factory=PartitionConfig)
    packing: PackingConfig = dataclasses.field(default_factory=PackingConfig)
    use_itemsets: bool = True
    itemset_min_support: float = 1e-5  # paper §7.6.3: 0.01 per-mille
    itemset_max_size: int = 3
    cdf_force_class: Optional[str] = None  # None | "gauss" | "nn" (Fig. 19 ablation)
    cdf_high_thresh: float = 0.001
    cdf_low_thresh: float = 0.00001
    cdf_train_steps: int = 300
    accelerated: bool = False
    sample_ratio: float = 0.3  # query sampling for training (Fig. 13a)
    cluster_ratio: float = 0.2  # spectral grouping ratio (Fig. 13b)
    build_hierarchy: bool = True
    construction: str = "batched"  # "batched" | "sequential" (DESIGN.md §5)
    seed: int = 0


@dataclasses.dataclass
class BuildArtifacts:
    index: WiskIndex
    bank: CDFBank
    partition: PartitionResult
    hierarchy: Optional[HierarchyResult]
    timings: Dict[str, float]
    # execution-strategy counters (DESIGN.md §5): device dispatches / rounds
    # per learned phase, for the batched-vs-sequential A/B
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)
    # reuse state for warm-start rebuilds (DESIGN.md §7): the mined itemsets
    # (so expand_queries need not re-mine) and the workload the layout was
    # trained on (the drift baseline the regressed-leaf detection compares
    # observed traffic against)
    itemsets: list = dataclasses.field(default_factory=list)
    train_workload: Optional[Workload] = None


def cluster_query_labels(index_or_clusters, workload: Workload) -> np.ndarray:
    """(K, m) bool: cluster intersects query rect AND shares a keyword."""
    clusters = index_or_clusters
    inter = rects_intersect(clusters.mbrs[:, None, :], workload.rects[None, :, :])
    kw = np.any(
        clusters.bitmaps[:, None, :] & workload.kw_bitmap[None, :, :] != 0, axis=-1
    )
    return inter & kw


def build_wisk(
    dataset: GeoTextDataset,
    workload: Workload,
    config: Optional[BuildConfig] = None,
) -> BuildArtifacts:
    cfg = config or BuildConfig()
    rng = np.random.default_rng(cfg.seed)
    timings: Dict[str, float] = {}

    train_wl = workload
    if cfg.accelerated and workload.m > 8:
        from ..data.workloads import stratified_sample

        idx = stratified_sample(workload, cfg.sample_ratio, seed=cfg.seed)
        train_wl = workload.subset(idx)

    t0 = time.perf_counter()
    itemsets, members = ([], [])
    if cfg.use_itemsets:
        itemsets, members = mine_frequent_itemsets(
            dataset, min_support=cfg.itemset_min_support, max_size=cfg.itemset_max_size
        )
    timings["itemset_mining"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    bank = build_cdf_bank(
        dataset,
        itemsets=itemsets,
        itemset_members=members,
        high_thresh=cfg.cdf_high_thresh,
        low_thresh=cfg.cdf_low_thresh,
        n_steps=cfg.cdf_train_steps,
        seed=cfg.seed,
        force_class=cfg.cdf_force_class,
    )
    timings["cdf_training"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    q_entries, q_signs = expand_queries(
        train_wl, itemsets, dataset.vocab_size, use_itemsets=cfg.use_itemsets
    )
    part = generate_bottom_clusters(
        dataset, train_wl, bank, q_entries, q_signs, cfg.partition, mode=cfg.construction
    )
    timings["partitioning"] = time.perf_counter() - t0

    hierarchy = None
    if cfg.build_hierarchy and part.clusters.k > cfg.packing.min_nodes:
        t0 = time.perf_counter()
        # label clusters with (sampled) queries for the packing state
        mq = min(cfg.packing.max_label_queries, train_wl.m)
        sel = rng.choice(train_wl.m, size=mq, replace=False) if train_wl.m > mq else np.arange(train_wl.m)
        lbl_wl = train_wl.subset(np.sort(sel))
        labels = cluster_query_labels(part.clusters, lbl_wl)
        pk = cfg.packing
        if cfg.accelerated:
            pk = dataclasses.replace(pk, spectral_ratio=cfg.cluster_ratio)
        hierarchy = build_hierarchy(labels, part.clusters.mbrs, pk, mode=cfg.construction)
        timings["packing"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    index = assemble_index(
        dataset,
        part.clusters,
        hierarchy,
        meta=dict(
            n_clusters=part.clusters.k,
            n_itemsets=len(itemsets),
            accelerated=cfg.accelerated,
            cdf_loss=bank.train_loss,
        ),
    )
    timings["assembly"] = time.perf_counter() - t0
    timings["total"] = sum(timings.values())
    counters = dict(
        partition_rounds=part.n_rounds,
        partition_dispatches=part.n_dispatches,
        partition_problems=part.n_sgd_calls,
        packing_dispatches=hierarchy.n_dispatches if hierarchy else 0,
        packing_env_steps=hierarchy.n_env_steps if hierarchy else 0,
        construction_dispatches=part.n_dispatches + (hierarchy.n_dispatches if hierarchy else 0),
    )
    return BuildArtifacts(
        index=index,
        bank=bank,
        partition=part,
        hierarchy=hierarchy,
        timings=timings,
        counters=counters,
        itemsets=itemsets,
        train_workload=train_wl,
    )


def warm_start_rebuild(
    dataset: GeoTextDataset,
    workload: Workload,
    prev: BuildArtifacts,
    config: Optional[BuildConfig] = None,
    regressed: Optional[np.ndarray] = None,
    regress_ratio: float = 1.5,
    assign: Optional[np.ndarray] = None,
) -> BuildArtifacts:
    """Drift-triggered partial rebuild (DESIGN.md §7).

    Instead of re-running the full Alg. 1 pipeline, reuse everything the
    shift did not invalidate:

    * the **CDF bank and mined itemsets** are pure functions of the dataset
      -- reused verbatim (when ``dataset`` grew via buffered inserts the
      bank is a slightly stale estimator of the grown collection; the
      accept/reject decisions it drives remain sound because both sides of
      Alg. 2 line 10 use the same estimates);
    * the **bottom partition** is re-learned only for leaves whose per-leaf
      Eq.1 verification cost regressed under the observed workload
      (``regressed``: explicit bool mask, or detected by comparing
      ``core.drift.leaf_cost_profile`` between ``prev.train_workload`` and
      ``workload`` at ``regress_ratio``); all other clusters keep their
      learned splits (``core.partition.refine_partition``);
    * the **hierarchy is grafted**, not re-trained: new sub-clusters
      inherit the parent slot of the leaf they refined, upper levels keep
      the DQN-learned packing verbatim, and ``assemble_index`` recomputes
      level MBRs/bitmaps bottom-up. No RL episodes run at all.

    Args:
        dataset: the (possibly grown/tombstoned) object collection -- e.g.
            ``DeltaLog.merged_dataset()``.
        workload: the observed (post-shift) workload to adapt to.
        prev: the artifacts of the build being refreshed.
        config: build config for the refinement (None: ``BuildConfig()``).
        regressed: optional (K,) bool mask of leaves to re-split.
        regress_ratio: detection threshold when ``regressed`` is None.
        assign: (dataset.n,) cluster assignment extending ``prev``'s
            partition over ``dataset`` (required when the dataset grew;
            ``DeltaLog.merged_assignment()`` provides it).

    Returns fresh ``BuildArtifacts`` whose ``counters`` record how much was
    reused (``refined_leaves`` / ``kept_clusters``); ``timings["total"]``
    is the warm build's cost -- the quantity ``bench_dynamic --quick``
    asserts is below the cold rebuild's.
    """
    from .drift import leaf_cost_profile, regressed_leaves

    cfg = config or BuildConfig()
    timings: Dict[str, float] = {}

    t0 = time.perf_counter()
    if assign is None:
        assign = prev.partition.clusters.assign
    if assign.shape[0] != dataset.n:
        raise ValueError(
            f"assignment covers {assign.shape[0]} objects, dataset has {dataset.n}; "
            "pass DeltaLog.merged_assignment() when rebuilding over a grown dataset"
        )
    clusters0 = ClusterSet.from_assignment(dataset, np.asarray(assign, np.int32))
    if regressed is None:
        if prev.train_workload is None:
            raise ValueError("prev.train_workload missing; pass regressed explicitly")
        trained_prof = leaf_cost_profile(dataset, clusters0, prev.train_workload)
        observed_prof = leaf_cost_profile(dataset, clusters0, workload)
        regressed = regressed_leaves(trained_prof, observed_prof, ratio=regress_ratio)
    timings["drift_localization"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    q_entries, q_signs = expand_queries(
        workload, prev.itemsets, dataset.vocab_size, use_itemsets=cfg.use_itemsets
    )
    refined = refine_partition(
        dataset, workload, prev.bank, q_entries, q_signs,
        clusters0, regressed, cfg.partition, mode=cfg.construction,
    )
    timings["partitioning"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    hierarchy = graft_hierarchy(prev.hierarchy, refined.source)
    index = assemble_index(
        dataset,
        refined.clusters,
        hierarchy,
        meta=dict(
            n_clusters=refined.clusters.k,
            warm_start=True,
            refined_leaves=refined.n_refined,
            kept_clusters=refined.n_kept,
        ),
    )
    timings["assembly"] = time.perf_counter() - t0
    timings["total"] = sum(timings.values())
    counters = dict(
        refined_leaves=refined.n_refined,
        kept_clusters=refined.n_kept,
        partition_problems=refined.n_sgd_calls,
        partition_dispatches=refined.n_dispatches,
        packing_dispatches=0,  # the graft reuses the learned packing
        construction_dispatches=refined.n_dispatches,
    )
    part = PartitionResult(
        clusters=refined.clusters,
        n_splits=refined.n_splits,
        n_sgd_calls=refined.n_sgd_calls,
        history=[],
        n_rounds=0,
        n_dispatches=refined.n_dispatches,
        mode=cfg.construction,
    )
    return BuildArtifacts(
        index=index,
        bank=prev.bank,
        partition=part,
        hierarchy=hierarchy,
        timings=timings,
        counters=counters,
        itemsets=prev.itemsets,
        train_workload=workload,
    )


def graft_hierarchy(
    prev: Optional[HierarchyResult], source: np.ndarray
) -> Optional[HierarchyResult]:
    """Reuse a learned hierarchy across a partial re-partition.

    ``source[c]`` names the previous bottom cluster each new cluster came
    from; every new cluster inherits that leaf's parent slot in the first
    packed level, and all upper levels keep their DQN-learned assignment
    verbatim (``assemble_index`` recomputes the level MBRs/bitmaps, so the
    grafted nodes stay consistent). Refining a leaf therefore only fans out
    its own parent -- the rest of the learned packing is untouched.
    """
    if prev is None or not prev.parents:
        return None
    new_p0 = prev.parents[0][np.asarray(source, np.int64)].astype(np.int32)
    return HierarchyResult(
        parents=[new_p0, *prev.parents[1:]],
        level_labels=[],
        packs=[],
        n_dispatches=0,
        n_env_steps=0,
    )
