"""Core data types for WISK: geo-textual datasets, workloads, clusters, index.

Everything is stored as dense, fixed-shape arrays so the structures are
jit/pjit friendly. Keyword sets are represented twice:

* ``kw_ids``  -- ``(n, max_kw) int32`` padded with ``-1`` (exact sets, used by
  host-side construction and the serial reference query path), and
* ``kw_bitmap`` -- ``(n, words) uint32`` bitmaps over the vocabulary (used by
  the vectorized / Pallas filtering and verification paths).

Coordinates live in the unit square ``[0,1]^2``; rectangles are
``(xlo, ylo, xhi, yhi)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

Array = Any  # np.ndarray or jax.Array


def bitmap_words(vocab_size: int) -> int:
    return (vocab_size + 31) // 32


def ids_to_bitmap(kw_ids: np.ndarray, vocab_size: int) -> np.ndarray:
    """Convert padded id lists ``(n, k)`` (pad=-1) to uint32 bitmaps ``(n, W)``."""
    n = kw_ids.shape[0]
    W = bitmap_words(vocab_size)
    bm = np.zeros((n, W), dtype=np.uint32)
    rows, cols = np.nonzero(kw_ids >= 0)
    ids = kw_ids[rows, cols].astype(np.int64)
    np.bitwise_or.at(bm, (rows, ids // 32), (np.uint32(1) << (ids % 32).astype(np.uint32)))
    return bm


def bitmap_intersects(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Rowwise: does bitmap a[i] share any bit with b[i]? Shapes broadcast."""
    return np.any((a & b) != 0, axis=-1)


@dataclasses.dataclass
class GeoTextDataset:
    """A geo-textual object collection.

    locs:      (n, 2) float32 in [0,1]^2
    kw_ids:    (n, max_kw) int32, padded with -1
    kw_bitmap: (n, W) uint32
    kw_freq:   (V,) int64 -- #objects containing each keyword
    """

    locs: np.ndarray
    kw_ids: np.ndarray
    kw_bitmap: np.ndarray
    vocab_size: int
    kw_freq: np.ndarray

    @property
    def n(self) -> int:
        return int(self.locs.shape[0])

    @property
    def words(self) -> int:
        return int(self.kw_bitmap.shape[1])

    @staticmethod
    def from_ids(locs: np.ndarray, kw_ids: np.ndarray, vocab_size: int) -> "GeoTextDataset":
        locs = np.asarray(locs, dtype=np.float32)
        kw_ids = np.asarray(kw_ids, dtype=np.int32)
        bm = ids_to_bitmap(kw_ids, vocab_size)
        flat = kw_ids[kw_ids >= 0]
        freq = np.bincount(flat, minlength=vocab_size).astype(np.int64)
        return GeoTextDataset(locs, kw_ids, bm, vocab_size, freq)

    def subset(self, idx: np.ndarray) -> "GeoTextDataset":
        return GeoTextDataset(
            self.locs[idx], self.kw_ids[idx], self.kw_bitmap[idx], self.vocab_size, self.kw_freq
        )


@dataclasses.dataclass
class Workload:
    """A batch of SKR queries.

    rects:     (m, 4) float32 (xlo, ylo, xhi, yhi)
    kw_ids:    (m, max_qk) int32 padded -1
    kw_bitmap: (m, W) uint32
    """

    rects: np.ndarray
    kw_ids: np.ndarray
    kw_bitmap: np.ndarray
    vocab_size: int

    @property
    def m(self) -> int:
        return int(self.rects.shape[0])

    @staticmethod
    def from_ids(rects: np.ndarray, kw_ids: np.ndarray, vocab_size: int) -> "Workload":
        rects = np.asarray(rects, dtype=np.float32)
        kw_ids = np.asarray(kw_ids, dtype=np.int32)
        return Workload(rects, kw_ids, ids_to_bitmap(kw_ids, vocab_size), vocab_size)

    def subset(self, idx: np.ndarray) -> "Workload":
        return Workload(self.rects[idx], self.kw_ids[idx], self.kw_bitmap[idx], self.vocab_size)

    def concat(self, other: "Workload") -> "Workload":
        assert self.vocab_size == other.vocab_size
        k = max(self.kw_ids.shape[1], other.kw_ids.shape[1])

        def pad(a):
            return np.pad(a, ((0, 0), (0, k - a.shape[1])), constant_values=-1)

        return Workload(
            np.concatenate([self.rects, other.rects], 0),
            np.concatenate([pad(self.kw_ids), pad(other.kw_ids)], 0),
            np.concatenate([self.kw_bitmap, other.kw_bitmap], 0),
            self.vocab_size,
        )


def rects_intersect(rects_a: np.ndarray, rects_b: np.ndarray) -> np.ndarray:
    """Pairwise-broadcast rectangle intersection test (closed rectangles)."""
    axlo, aylo, axhi, ayhi = (rects_a[..., i] for i in range(4))
    bxlo, bylo, bxhi, byhi = (rects_b[..., i] for i in range(4))
    return (axlo <= bxhi) & (bxlo <= axhi) & (aylo <= byhi) & (bylo <= ayhi)


def points_in_rect(locs: np.ndarray, rect: np.ndarray) -> np.ndarray:
    return (
        (locs[..., 0] >= rect[..., 0])
        & (locs[..., 0] <= rect[..., 2])
        & (locs[..., 1] >= rect[..., 1])
        & (locs[..., 1] <= rect[..., 3])
    )


@dataclasses.dataclass
class ClusterSet:
    """A flat partition of the dataset into k clusters (WISK bottom clusters).

    assign:  (n,) int32 cluster id per object
    order:   (n,) int32 object ids sorted by cluster (CSR payload)
    offsets: (k+1,) int64 CSR offsets into ``order``
    mbrs:    (k, 4) float32 MBR of member objects
    bitmaps: (k, W) uint32 OR of member bitmaps
    """

    assign: np.ndarray
    order: np.ndarray
    offsets: np.ndarray
    mbrs: np.ndarray
    bitmaps: np.ndarray

    @property
    def k(self) -> int:
        return int(self.mbrs.shape[0])

    @staticmethod
    def from_assignment(dataset: GeoTextDataset, assign: np.ndarray) -> "ClusterSet":
        assign = np.asarray(assign, dtype=np.int32)
        k = int(assign.max()) + 1 if assign.size else 0
        order = np.argsort(assign, kind="stable").astype(np.int32)
        counts = np.bincount(assign, minlength=k)
        offsets = np.zeros(k + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        mbrs = np.zeros((k, 4), dtype=np.float32)
        W = dataset.words
        bitmaps = np.zeros((k, W), dtype=np.uint32)
        locs = dataset.locs
        for c in range(k):
            ids = order[offsets[c] : offsets[c + 1]]
            if ids.size == 0:
                mbrs[c] = (1.0, 1.0, 0.0, 0.0)  # empty (never intersects)
                continue
            pl = locs[ids]
            mbrs[c] = (pl[:, 0].min(), pl[:, 1].min(), pl[:, 0].max(), pl[:, 1].max())
            bitmaps[c] = np.bitwise_or.reduce(dataset.kw_bitmap[ids], axis=0)
        return ClusterSet(assign, order, offsets, mbrs, bitmaps)

    def sizes(self) -> np.ndarray:
        return np.diff(self.offsets)


@dataclasses.dataclass
class InvertedFile:
    """CSR inverted file per (cluster, keyword): cluster-local postings.

    For cluster c: keywords ``kw[kw_ptr[c]:kw_ptr[c+1]]`` sorted ascending,
    keyword j's postings are ``obj[obj_ptr[kw_ptr[c]+j] : obj_ptr[kw_ptr[c]+j+1]]``
    (global object ids).
    """

    kw_ptr: np.ndarray  # (k+1,) int64
    kw: np.ndarray  # (nnz_kw,) int32
    obj_ptr: np.ndarray  # (nnz_kw+1,) int64
    obj: np.ndarray  # (nnz_post,) int32

    @staticmethod
    def build(dataset: GeoTextDataset, clusters: ClusterSet) -> "InvertedFile":
        k = clusters.k
        kw_ptr = np.zeros(k + 1, dtype=np.int64)
        kws: List[np.ndarray] = []
        obj_lists: List[np.ndarray] = []
        obj_counts: List[int] = []
        for c in range(k):
            ids = clusters.order[clusters.offsets[c] : clusters.offsets[c + 1]]
            if ids.size:
                pairs_obj = np.repeat(ids, np.sum(dataset.kw_ids[ids] >= 0, axis=1))
                pairs_kw = dataset.kw_ids[ids][dataset.kw_ids[ids] >= 0]
                srt = np.argsort(pairs_kw, kind="stable")
                pairs_kw, pairs_obj = pairs_kw[srt], pairs_obj[srt]
                uk, start = np.unique(pairs_kw, return_index=True)
                counts = np.diff(np.append(start, pairs_kw.size))
                kws.append(uk.astype(np.int32))
                for s, cnt in zip(start, counts):
                    obj_lists.append(pairs_obj[s : s + cnt].astype(np.int32))
                    obj_counts.append(int(cnt))
                kw_ptr[c + 1] = kw_ptr[c] + uk.size
            else:
                kw_ptr[c + 1] = kw_ptr[c]
        kw = np.concatenate(kws) if kws else np.zeros(0, dtype=np.int32)
        obj_ptr = np.zeros(kw.size + 1, dtype=np.int64)
        np.cumsum(np.asarray(obj_counts, dtype=np.int64), out=obj_ptr[1:]) if obj_counts else None
        obj = np.concatenate(obj_lists) if obj_lists else np.zeros(0, dtype=np.int32)
        return InvertedFile(kw_ptr, kw, obj_ptr, obj)

    def nbytes(self) -> int:
        return self.kw_ptr.nbytes + self.kw.nbytes + self.obj_ptr.nbytes + self.obj.nbytes


@dataclasses.dataclass
class Level:
    """One level of the WISK hierarchy (dense arrays over nodes).

    ``child_ptr/child`` give the CSR of children in the level below
    (leaf level: children index bottom clusters == themselves).
    """

    mbrs: np.ndarray  # (n, 4) float32
    bitmaps: np.ndarray  # (n, W) uint32
    child_ptr: np.ndarray  # (n+1,) int64
    child: np.ndarray  # (nnz,) int32

    @property
    def n(self) -> int:
        return int(self.mbrs.shape[0])


@dataclasses.dataclass
class WiskIndex:
    """The assembled index: levels[0] is the root level, levels[-1] the leaves
    (bottom clusters); ``inv`` is the leaf-level inverted file."""

    levels: List[Level]
    clusters: ClusterSet
    inv: InvertedFile
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def height(self) -> int:
        return len(self.levels)

    def num_nodes(self) -> int:
        return sum(l.n for l in self.levels)

    def nbytes(self) -> int:
        total = self.inv.nbytes()
        for l in self.levels:
            total += l.mbrs.nbytes + l.bitmaps.nbytes + l.child_ptr.nbytes + l.child.nbytes
        total += self.clusters.offsets.nbytes + self.clusters.order.nbytes
        return total
