"""Workload-drift detection for incremental index maintenance (DESIGN.md §7).

WISK's layout is learned *for a workload* (paper §7.5): when the query
distribution shifts, the trained partition stops matching where queries
actually land and the observed Eq.1 cost regresses. This module is the
serving-side monitor that notices:

* ``DriftMonitor`` tracks an EWMA of the observed per-query Eq.1 cost
  (``w1 * nodes_checked + w2 * verified`` -- exactly the counters every
  serving path already returns) against a baseline, and trips once the
  ratio crosses a threshold. The baseline is learned from the warmup
  window of *observed* traffic by default (a trained-workload prediction
  such as ``index_cost_baseline`` systematically undershoots steady state
  -- training queries are what the layout was optimized for -- so
  comparing against it would trip on the generalization gap alone). State
  machine::

      warmup --(min_queries observed; baseline = their mean)--> armed
      --(ewma > threshold * baseline)--> triggered --rearm()--> warmup

  ``triggered`` is sticky: it stays set until ``rearm()`` so the rebuild
  driver (launch/wisk_serve.py:LiveIndex.maybe_rebuild) can act on its own
  schedule; ``rearm()`` re-enters warmup, which doubles as the post-swap
  cooldown. Same-distribution noise does not trip the monitor: the EWMA of
  a resampled workload stays near the warmup baseline
  (tests/test_delta_maintenance.py).

* ``leaf_cost_profile`` / ``regressed_leaves`` localize the damage: the
  per-leaf share of the workload's Eq.1 verification cost, compared between
  the trained and the observed workload. Only leaves whose share regressed
  are re-split by the warm-start rebuild (core/build.py:
  warm_start_rebuild); everything else keeps its learned partition.

Everything here is host-only numpy -- drift tracking is serving control
plane, not descent work.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .cost import DEFAULT_W1, DEFAULT_W2, object_query_match
from .query import execute_level_sync
from .types import ClusterSet, GeoTextDataset, Workload, WiskIndex, ids_to_bitmap, rects_intersect


@dataclasses.dataclass
class DriftConfig:
    """Knobs of the drift state machine.

    alpha:       EWMA smoothing per observed query (higher = faster react).
    threshold:   trigger when ``ewma > threshold * baseline``.
    min_queries: warmup window: queries observed before arming; when the
                 baseline is learned, it is their mean cost.
    w1/w2:       Eq.1 weights (must match the serving cost accounting).
    """

    alpha: float = 0.05
    threshold: float = 1.5
    min_queries: int = 32
    w1: float = DEFAULT_W1
    w2: float = DEFAULT_W2


class DriftMonitor:
    """EWMA drift tracker over per-query Eq.1 costs (host-only).

    Args:
        baseline: expected per-query Eq.1 cost, or None (default) to learn
            it as the mean cost of the warmup window -- the robust choice,
            see the module docstring.
        config: ``DriftConfig`` (None = defaults).

    Feed it with ``observe(costs)`` (per-query cost array) or
    ``observe_counters(nodes_checked, verified)`` (raw serving counters).
    Read ``state`` / ``ratio`` / ``triggered``; call ``rearm()`` after a
    rebuild swap.
    """

    def __init__(
        self, baseline: Optional[float] = None, config: Optional[DriftConfig] = None
    ) -> None:
        self.config = config or DriftConfig()
        self.baseline: Optional[float] = None if baseline is None else float(baseline)
        self.ewma: float = 0.0 if baseline is None else float(baseline)
        self.n_observed = 0
        self.state = "warmup" if baseline is None else "armed"
        self._warm_costs: List[float] = []
        self.history: List[float] = []  # EWMA after each observe() batch

    @property
    def ratio(self) -> float:
        """Observed EWMA cost relative to the baseline (0 during warmup)."""
        if self.baseline is None:
            return 0.0
        return self.ewma / max(self.baseline, 1e-9)

    @property
    def triggered(self) -> bool:
        return self.state == "triggered"

    def observe_counters(self, nodes_checked, verified) -> None:
        """Absorb raw serving counters (the dicts every execution path
        returns carry both)."""
        nodes = np.asarray(nodes_checked, np.float64)
        ver = np.asarray(verified, np.float64)
        self.observe(self.config.w1 * nodes + self.config.w2 * ver)

    def observe(self, costs) -> None:
        """Absorb a batch of per-query Eq.1 costs and advance the state
        machine. Pad queries must be sliced off by the caller (the front
        doors already do)."""
        costs = np.atleast_1d(np.asarray(costs, np.float64))
        if costs.size == 0:
            return
        self.n_observed += costs.size
        if self.state == "warmup":
            self._warm_costs.extend(float(c) for c in costs)
            if len(self._warm_costs) >= self.config.min_queries:
                if self.baseline is None:
                    self.baseline = float(np.mean(self._warm_costs))
                self.ewma = self.baseline
                self._warm_costs = []
                self.state = "armed"
            self.history.append(self.ewma)
            return
        a = self.config.alpha
        for c in costs:
            self.ewma = (1.0 - a) * self.ewma + a * float(c)
        self.history.append(self.ewma)
        if self.state == "armed" and self.ewma > self.config.threshold * self.baseline:
            self.state = "triggered"

    def rearm(self, baseline: Optional[float] = None) -> None:
        """Reset after a rebuild swap: back to warmup (which doubles as the
        cooldown -- nothing can trigger until a fresh baseline window is
        observed on the new index). Pass ``baseline`` to pin it instead of
        re-learning it from the warmup window."""
        self.baseline = None if baseline is None else float(baseline)
        self.ewma = 0.0 if baseline is None else float(baseline)
        self._warm_costs = []
        self.state = "warmup" if baseline is None else "armed"


def index_cost_baseline(
    index: WiskIndex,
    dataset: GeoTextDataset,
    workload: Workload,
    w1: float = DEFAULT_W1,
    w2: float = DEFAULT_W2,
) -> float:
    """Mean per-query Eq.1 cost of ``workload`` on ``index`` -- the trained
    baseline a ``DriftMonitor`` compares serving traffic against. Uses the
    vectorized host traversal (its counters equal the device engine's)."""
    st = execute_level_sync(index, dataset, workload, w1=w1, w2=w2)
    return float(st.cost.mean())


def leaf_cost_profile(
    dataset: GeoTextDataset,
    clusters: ClusterSet,
    workload: Workload,
    w2: float = DEFAULT_W2,
) -> np.ndarray:
    """(K,) mean per-query Eq.1 *verification* cost attributed to each leaf.

    For leaf ``c``: ``w2 / m * sum_{q relevant to c} |O_c(q)|`` with
    ``|O_c(q)|`` the keyword-matching members (the paper's verification
    term, cluster-local). This is the per-leaf decomposition of
    ``cost.exact_workload_cost``'s w2 term; comparing profiles between the
    trained and observed workloads localizes a drift to the leaves that
    actually regressed."""
    m, k = workload.m, clusters.k
    if m == 0:
        return np.zeros(k, np.float64)
    kw_match = object_query_match(dataset, workload)
    inter = rects_intersect(workload.rects[:, None, :], clusters.mbrs[None, :, :])
    kwc = np.any(
        workload.kw_bitmap[:, None, :] & clusters.bitmaps[None, :, :] != 0, axis=-1
    )
    relevant = inter & kwc  # (m, k)
    prof = np.zeros(k, np.float64)
    assign = clusters.assign
    for qi in range(m):
        counts = np.bincount(assign[kw_match[qi]], minlength=k).astype(np.float64)
        prof += np.where(relevant[qi], counts, 0.0)
    return w2 * prof / m


def regressed_leaves(
    trained_profile: np.ndarray,
    observed_profile: np.ndarray,
    ratio: float = 1.5,
    min_cost: float = 1.0,
) -> np.ndarray:
    """(K,) bool: leaves whose observed verification cost regressed.

    A leaf regresses when its observed per-query cost exceeds ``ratio``
    times its trained cost AND is material (``> min_cost``), so leaves that
    were already expensive under the trained workload (the optimizer chose
    not to split them further) and leaves with negligible traffic are left
    alone. The warm-start rebuild re-splits exactly these leaves."""
    trained = np.asarray(trained_profile, np.float64)
    observed = np.asarray(observed_profile, np.float64)
    return (observed > ratio * trained) & (observed > min_cost)


def observed_workload(rects, kw_bitmaps, vocab_size: int) -> Workload:
    """Reconstruct a trainable ``Workload`` from the (rects, bitmap) form
    the serving front doors receive -- keyword ids are recovered from the
    set bits, so the drift-triggered rebuild can train on exactly the
    traffic that tripped the monitor."""
    rects = np.asarray(rects, np.float32).reshape(-1, 4)
    bms = np.asarray(kw_bitmaps, np.uint32).reshape(rects.shape[0], -1)
    per_q: List[np.ndarray] = []
    for row in bms:
        bits = np.unpackbits(row.view(np.uint8), bitorder="little")
        per_q.append(np.nonzero(bits[:vocab_size])[0].astype(np.int32))
    max_kw = max((p.size for p in per_q), default=1) or 1
    kw_ids = np.full((rects.shape[0], max_kw), -1, np.int32)
    for i, p in enumerate(per_q):
        kw_ids[i, : p.size] = p
    return Workload(rects, kw_ids, ids_to_bitmap(kw_ids, vocab_size), vocab_size)
