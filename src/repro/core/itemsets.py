"""Frequent keyword itemset mining + multi-keyword count correction (§6).

The paper mines frequent keyword sets (FP-Tree) and learns CDF models for
them so that multi-keyword queries do not over-count objects containing
several query keywords. At our (synthetic, laptop-scale) vocabulary sizes a
vectorized Apriori over the object-keyword incidence produces identical
output (all itemsets with support >= min_support); we mine up to
``max_size`` and correct estimates by truncated inclusion-exclusion:

    |O(q)| ~= sum_k |O_k ∩ rect|  -  sum_{(a,b) ⊆ q, (a,b) frequent} |O_ab ∩ rect|

Higher-order frequent itemsets are still mined and exposed (the bank learns
their CDFs; benchmarks report their effect) but the default correction uses
pairs, which removes the bulk of the redundancy (Fig. 20's mechanism).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .types import GeoTextDataset, Workload


def mine_frequent_itemsets(
    dataset: GeoTextDataset,
    min_support: float = 1e-5,
    max_size: int = 3,
    max_itemsets: int = 4096,
) -> Tuple[List[Tuple[int, ...]], List[np.ndarray]]:
    """Apriori over the keyword incidence. Returns (itemsets, member object ids)
    for itemsets of size >= 2 (singletons are the base CDF entries)."""
    n = dataset.n
    min_count = max(2, int(np.ceil(min_support * n)))

    # keyword -> member rows (sorted)
    rows, cols = np.nonzero(dataset.kw_ids >= 0)
    ids = dataset.kw_ids[rows, cols]
    order = np.argsort(ids, kind="stable")
    ids_s, rows_s = ids[order], rows[order]
    uk, start = np.unique(ids_s, return_index=True)
    bounds = np.append(start, ids_s.size)
    members: Dict[Tuple[int, ...], np.ndarray] = {}
    frequent_1 = []
    for j, k in enumerate(uk):
        mem = np.sort(rows_s[bounds[j] : bounds[j + 1]])
        if mem.size >= min_count:
            frequent_1.append(int(k))
            members[(int(k),)] = mem

    itemsets: List[Tuple[int, ...]] = []
    out_members: List[np.ndarray] = []
    prev_level: List[Tuple[int, ...]] = [(k,) for k in frequent_1]
    for size in range(2, max_size + 1):
        cur: List[Tuple[int, ...]] = []
        prev_set = set(prev_level)
        # candidate generation: join prev-level sets sharing a (size-2)-prefix
        for i in range(len(prev_level)):
            for j in range(i + 1, len(prev_level)):
                a, b = prev_level[i], prev_level[j]
                if a[:-1] != b[:-1]:
                    continue
                cand = tuple(sorted(set(a) | set(b)))
                if len(cand) != size or cand in members:
                    continue
                # prune: all (size-1)-subsets must be frequent
                ok = all(cand[:t] + cand[t + 1 :] in prev_set for t in range(size))
                if not ok:
                    continue
                inter = np.intersect1d(members[a], members[b], assume_unique=True)
                if inter.size >= min_count:
                    members[cand] = inter
                    cur.append(cand)
                    itemsets.append(cand)
                    out_members.append(inter)
                    if len(itemsets) >= max_itemsets:
                        return itemsets, out_members
        prev_level = cur
        if not cur:
            break
    return itemsets, out_members


def expand_queries(
    workload: Workload,
    itemsets: List[Tuple[int, ...]],
    vocab_size: int,
    use_itemsets: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-query CDF-entry expansion with inclusion-exclusion signs.

    Returns (entries (m, E) int32 padded -1, signs (m, E) float32). Entry ids
    >= vocab_size refer to itemset slots (vocab_size + itemset_index).
    """
    pair_index: Dict[Tuple[int, int], int] = {}
    if use_itemsets:
        for idx, s in enumerate(itemsets):
            if len(s) == 2:
                pair_index[(s[0], s[1])] = vocab_size + idx

    m = workload.m
    ent_rows: List[List[int]] = []
    sign_rows: List[List[float]] = []
    for qi in range(m):
        kws = [int(k) for k in workload.kw_ids[qi] if k >= 0]
        ents = list(kws)
        sgns = [1.0] * len(kws)
        if use_itemsets:
            for i in range(len(kws)):
                for j in range(i + 1, len(kws)):
                    a, b = sorted((kws[i], kws[j]))
                    slot = pair_index.get((a, b))
                    if slot is not None:
                        ents.append(slot)
                        sgns.append(-1.0)
        ent_rows.append(ents)
        sign_rows.append(sgns)
    E = max(1, max(len(r) for r in ent_rows) if ent_rows else 1)
    entries = np.full((m, E), -1, dtype=np.int32)
    signs = np.zeros((m, E), dtype=np.float32)
    for qi, (er, sr) in enumerate(zip(ent_rows, sign_rows)):
        entries[qi, : len(er)] = er
        signs[qi, : len(sr)] = sr
    return entries, signs
