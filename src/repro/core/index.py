"""WISK index assembly: bottom clusters + learned hierarchy -> dense levels.

``levels[0]`` is the top (root) level; ``levels[-1]`` the leaf level whose
nodes are exactly the bottom clusters (leaf ``child`` CSR maps to cluster
ids). Non-leaf nodes carry an MBR and a keyword *bitmap* (paper Fig. 4: the
non-leaf textual summary is a bitmap; leaves use inverted files).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .packing import HierarchyResult
from .types import ClusterSet, GeoTextDataset, InvertedFile, Level, WiskIndex


def _group_level(
    lower_mbrs: np.ndarray, lower_bitmaps: np.ndarray, parent: np.ndarray
) -> Level:
    n_up = int(parent.max()) + 1 if parent.size else 0
    order = np.argsort(parent, kind="stable").astype(np.int32)
    counts = np.bincount(parent, minlength=n_up)
    ptr = np.zeros(n_up + 1, dtype=np.int64)
    np.cumsum(counts, out=ptr[1:])
    mbrs = np.zeros((n_up, 4), dtype=np.float32)
    bitmaps = np.zeros((n_up, lower_bitmaps.shape[1]), dtype=np.uint32)
    for u in range(n_up):
        ch = order[ptr[u] : ptr[u + 1]]
        mb = lower_mbrs[ch]
        mbrs[u] = (mb[:, 0].min(), mb[:, 1].min(), mb[:, 2].max(), mb[:, 3].max())
        bitmaps[u] = np.bitwise_or.reduce(lower_bitmaps[ch], axis=0)
    return Level(mbrs=mbrs, bitmaps=bitmaps, child_ptr=ptr, child=order)


def assemble_index(
    dataset: GeoTextDataset,
    clusters: ClusterSet,
    hierarchy: Optional[HierarchyResult] = None,
    meta: Optional[dict] = None,
) -> WiskIndex:
    inv = InvertedFile.build(dataset, clusters)
    k = clusters.k
    leaf = Level(
        mbrs=clusters.mbrs,
        bitmaps=clusters.bitmaps,
        child_ptr=np.arange(k + 1, dtype=np.int64),
        child=np.arange(k, dtype=np.int32),
    )
    levels: List[Level] = [leaf]
    if hierarchy is not None:
        cur_mbrs, cur_bm = clusters.mbrs, clusters.bitmaps
        for parent in hierarchy.parents:
            lvl = _group_level(cur_mbrs, cur_bm, parent)
            levels.append(lvl)
            cur_mbrs, cur_bm = lvl.mbrs, lvl.bitmaps
    levels.reverse()  # root first
    return WiskIndex(levels=levels, clusters=clusters, inv=inv, meta=meta or {})


def flat_index(dataset: GeoTextDataset, clusters: ClusterSet) -> WiskIndex:
    """A one-level index (no hierarchy) over the given clusters."""
    return assemble_index(dataset, clusters, hierarchy=None, meta={"flat": True})
