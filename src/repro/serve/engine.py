"""Executor layer: batched WISK retrieval over an ``IndexSnapshot``.

The serving stack is four explicit layers (DESIGN.md §3.4, §7):

* **snapshot** (serve/snapshot.py) -- the immutable pytree of device-resident
  index arrays,
* **plan** (serve/plan.py) -- batch bucketing plus the monotone frontier
  width cache, handed to descents as per-call ``ExecutionPlan``s,
* **delta** (serve/delta.py) -- optional device-resident insert/delete
  buffers merged into every descent (DESIGN.md §7),
* **executors** (this module) -- the jitted descent/verify pipelines that
  consume ``(snapshot, plan, delta)`` and return exact results + Eq.1
  counters.

Two range-query traversal modes share the leaf verification stage:

* ``mode="frontier"`` (default) -- sparse frontier descent: each query
  carries a padded int32 frontier of candidate node ids; per level the
  Pallas frontier kernel filters the gathered frontier tile (MBR intersect
  + bitmap AND) and survivors' children are expanded through device-resident
  CSR child arrays into the next frontier, compacted with a prefix-sum
  scatter. Per-level work is O(M * frontier_width), so the learned
  hierarchy's pruning shows up as wall-clock, not just as a counter.
* ``mode="dense"`` -- the original level-synchronous path kept for A/B
  benchmarking: an (M, n_level) active mask and dense (n_up, n_down) int8
  child matrices; per-level work is O(M * n_level) regardless of
  selectivity.

Frontier expansion widths come from the caller's ``PlanCache`` (default: a
per-snapshot cache, ``plan.default_plan_cache``): the descent runs at cached
per-level widths and fetches every level's actual child-count maximum in ONE
batched device->host sync at the end; if any level overflowed its cached
width the (rare, at most log2(level width) times ever) lossless retry
re-descends with exact per-level syncs and grows the cache. Steady state
therefore has no per-level blocking syncs (DESIGN.md §3.2).

``retrieve_knn`` is the third execution path (DESIGN.md §6): Boolean kNN as
a distance-bounded frontier descent. Each query carries a padded on-device
top-k buffer of (dist^2, object id) pairs; a beam-1 probe descent seeds the
buffer, the bounded sweep prunes frontier nodes whose squared MBR
min-distance (Pallas ``knn_filter`` kernel) exceeds the current k-th best
before expansion, and surviving leaves are verified in ascending
min-distance chunks inside one ``lax.scan``, re-tightening the bound after
every chunk until the remaining leaves are bounded out.

All modes return exact results (validated against core.query in
tests/test_query_parity.py and tests/test_knn_parity.py) plus Eq.1-style
cost counters:

* ``nodes_checked`` -- nodes whose MBR/bitmap were examined for the query
  (frontier-resident nodes only; matches ``execute_serial``'s
  ``nodes_accessed``),
* ``nodes_scanned`` -- slots the kernels actually touched (padded frontier
  widths, or full level widths in dense mode) -- the honest device-work
  measure the benchmark compares,
* ``verified``/``overflow`` -- Eq.1 verification cost and ``max_leaves``
  spill accounting (kNN: ``verified``/``leaves_verified``/``pruned``).

Bandwidth-lean descent (DESIGN.md §3.5): when the snapshot carries narrow
planes (int16 rank-coded shadow MBRs + coordinate dictionaries,
serve/snapshot.py:encode_mbr_planes) and no delta is live, the frontier and
kNN level filters run on those planes plus per-query *packed* bitmap words
(ops.pack_query_words), moving ~F*8 + F*Wp*4 bytes per (query, level)
instead of F*16 + F*W*4. Dequantization happens inside the kernels via the
dictionaries, so survivors/distances are bit-identical to the f32 path --
the ``quantized`` knob on ``retrieve``/``retrieve_knn`` exists only for A/B.

Incremental serving (DESIGN.md §7): every executor takes an optional
``delta`` (serve/delta.py:DeltaBuffer). When present, descents filter
against the delta's *augmented* per-level MBR/bitmap arrays (widened by
buffered inserts, so no level can prune a node whose subtree holds a
buffered match), the verify stages check each selected leaf's insert-buffer
slots alongside its snapshot object block, and deleted objects are masked
out of verification and the kNN top-k merge. ``delta=None`` (an empty
pytree) is the static fast path -- zero merge overhead.

The data-parallel distributed front doors (``serve_sharded`` /
``serve_knn_sharded``) live in launch/wisk_serve.py; they shard_map the
same per-level steps over the mesh's data axes with the snapshot (and any
delta) replicated.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

# round_up_bucket lives in core.query so construction (core.partition) can
# share the exact same bucket discipline; re-exported here for callers
# (launch.wisk_serve, tests) that address it through the serving engine.
from ..core.query import round_up_bucket  # noqa: F401
from ..core.types import Workload
from ..kernels import ops
from .delta import DeltaBuffer
from .plan import ExecutionPlan, PlanCache, default_plan_cache
from .snapshot import IndexSnapshot  # noqa: F401  (re-export)


def _level_arrays(snap: IndexSnapshot, delta: Optional[DeltaBuffer], li: int):
    """The (mbrs, bitmaps) a descent filters level ``li`` against: the
    delta's insert-widened arrays when a delta is live, else the frozen
    snapshot arrays."""
    if delta is not None:
        return delta.aug_mbrs[li], delta.aug_bms[li]
    return snap.level_mbrs[li], snap.level_bms[li]


def _narrow_words(q_bm, delta, snap: IndexSnapshot, quantized: Optional[bool]):
    """The packed query words driving the bandwidth-lean descent, or None.

    ``quantized=None`` (auto) packs whenever the snapshot carries narrow
    planes and no delta is live (a live delta's insert-widened MBRs are not
    in the snapshot's coordinate dictionaries, so the descent falls back to
    the f32 planes -- DESIGN.md §3.5). ``quantized=False`` forces the f32
    full-width A/B baseline. Host-side: Wp must be a static shape.
    """
    if quantized is False or delta is not None or not snap.has_narrow_planes:
        return None
    return ops.pack_query_words(np.asarray(q_bm))


# ------------------------------------------------------------ frontier steps
@jax.jit
def _filter_frontier_level(mbrs, bms, q_rects, q_bm, frontier):
    """Gather frontier node tiles and run the Pallas frontier kernel."""
    valid = frontier >= 0
    safe = jnp.clip(frontier, 0, mbrs.shape[0] - 1)
    surv = ops.filter_frontier(q_rects, q_bm, mbrs[safe], bms[safe], valid.astype(jnp.int8))
    return surv, jnp.sum(valid, axis=1).astype(jnp.int32)


@jax.jit
def _filter_frontier_level_narrow(codes, bms, dict_x, dict_y, q_rects, wids, bits, frontier):
    """Bandwidth-lean twin of ``_filter_frontier_level``: gathers int16 MBR
    rank codes and only the query's packed bitmap word planes (the (M, F, W)
    slab shrinks to (M, F, Wp)), then runs the narrow Pallas kernel --
    bit-identical survivors (tests/test_query_parity.py)."""
    valid = frontier >= 0
    safe = jnp.clip(frontier, 0, codes.shape[0] - 1)
    f_bm = bms[safe[:, :, None], wids[:, None, :]]  # (M, F, Wp)
    surv = ops.filter_frontier_narrow(
        q_rects, bits, codes[safe], f_bm, valid.astype(jnp.int8), dict_x, dict_y
    )
    return surv, jnp.sum(valid, axis=1).astype(jnp.int32)


@jax.jit
def _frontier_child_counts(child_counts, frontier, surv):
    """Per-query number of children the surviving frontier will expand to."""
    safe = jnp.clip(frontier, 0, child_counts.shape[0] - 1)
    return jnp.sum(jnp.where(surv > 0, child_counts[safe], 0), axis=1)


@functools.partial(jax.jit, static_argnames=("f_next",))
def _expand_frontier(child_table, frontier, surv, f_next: int):
    """CSR gather of survivors' children + prefix-sum compaction.

    The hierarchy is a tree, so gathered child rows are disjoint and the
    compacted frontier has no duplicates. ``f_next`` must be >= the max
    per-query child count (guaranteed by the caller's planning), so the
    descent is lossless.
    """
    M, F = frontier.shape
    safe = jnp.clip(frontier, 0, child_table.shape[0] - 1)
    cand = jnp.where((surv > 0)[:, :, None], child_table[safe], -1).reshape(M, -1)
    validc = cand >= 0
    pos = jnp.cumsum(validc, axis=1) - 1
    pos = jnp.where(validc & (pos < f_next), pos, f_next)  # f_next = trash slot
    nxt = jnp.full((M, f_next + 1), -1, jnp.int32)
    nxt = nxt.at[jnp.arange(M)[:, None], pos].set(cand, mode="drop")
    return nxt[:, :f_next]


@functools.partial(jax.jit, static_argnames=("take", "n_leaf"))
def _select_leaves_frontier(frontier, surv, take: int, n_leaf: int):
    """Up to ``take`` surviving leaves per query, smallest leaf id first.

    Keying top-k by ``n_leaf - leaf_id`` reproduces the dense path's
    tie-break (top_k prefers lower indices), so dense and frontier modes
    drop the *same* leaves under ``max_leaves`` overflow.
    """
    key = jnp.where(surv > 0, n_leaf - frontier, 0)
    val, _ = jax.lax.top_k(key, take)
    leaf_ok = val > 0
    top_leaf = jnp.where(leaf_ok, n_leaf - val, 0)
    overflow = jnp.maximum(jnp.sum((surv > 0).astype(jnp.int32), axis=1) - take, 0)
    return top_leaf, leaf_ok, overflow


# -------------------------------------- index-sharded collectives (DESIGN §3.4)
def _gather_cat(x, index_axis: str):
    """all_gather over the ``index`` mesh axis, shards concatenated along
    axis 1: the (M, F) per-shard view becomes the (M, S*F) global view.
    Traced inside shard_map bodies only."""
    g = jax.lax.all_gather(x, index_axis)  # (S, M, ...)
    return jnp.moveaxis(g, 0, 1).reshape(x.shape[0], -1)


def _select_leaves_indexed(
    frontier, surv, leaf_gid, take_g: int, take_loc: int, n_shards: int,
    index_axis: str,
):
    """Index-sharded twin of ``_select_leaves_frontier``: keep the globally
    ``take_g`` smallest-GLOBAL-id surviving leaves, exactly matching the
    single-device selection (and therefore its ``overflow`` drops).

    One bound exchange: each shard gathers its ``take_loc`` smallest
    surviving global leaf ids, the all-gathered (S*take_loc) candidates are
    sorted, and the ``take_g``-th smallest becomes the keep threshold. A
    shard can contribute at most ``take_loc`` (>= its survivor count, the
    caller passes its leaf frontier width) of the global winners, so the
    threshold is exact. ``overflow`` is the psum'd global survivor count
    beyond ``take_g`` -- identical per query to the single-device counter.
    """
    K = leaf_gid.shape[0]
    ok = (surv > 0) & (frontier >= 0)
    gid = jnp.where(ok, leaf_gid[jnp.clip(frontier, 0, K - 1)], _ID_SENTINEL)
    neg, _ = jax.lax.top_k(_ID_SENTINEL - gid, take_loc)
    small = _ID_SENTINEL - neg  # ascending local minima, sentinel-padded
    g = jax.lax.all_gather(small, index_axis)  # (S, M, take_loc)
    g = jnp.sort(jnp.moveaxis(g, 0, 1).reshape(small.shape[0], -1), axis=1)
    thr = g[:, min(take_g, n_shards * take_loc) - 1]
    keep = (ok & (gid <= thr[:, None])).astype(jnp.int8)
    top_leaf, leaf_ok, _ = _select_leaves_frontier(frontier, keep, take_loc, K)
    total = jax.lax.psum(jnp.sum(ok.astype(jnp.int32), axis=1), index_axis)
    overflow = jnp.maximum(total - take_g, 0)
    return top_leaf, leaf_ok, overflow


def _snap_cbank(snap: IndexSnapshot, compact: Optional[bool]):
    """The snapshot's compact leaf bank as a ``(leaf_terms, obj_cbm,
    obj_sig)`` triple, or None. ``compact=None`` (auto) uses it whenever the
    snapshot carries one; ``False`` forces the full-width A/B baseline."""
    if compact is False or not snap.has_compact_bank:
        return None
    return (snap.leaf_terms, snap.leaf_obj_cbm, snap.leaf_obj_sig)


def _verify_delta_slots(q_rects, q_bm, top_leaf, leaf_ok, delta, q_cbm, q_sig):
    """Verify the selected leaves' delta insert-buffer slots (DESIGN.md §7).

    The fused-with-delta merge (below): the fused kernel covers the base
    leaf blocks only, so the buffered inserts are gathered and verified
    here, through the compact kernel when the delta carries remapped slot
    bitmaps (``ins_cbm``; exact -- DeltaLog drops them the moment any
    buffered term falls outside its leaf's dictionary) and through the
    full-width ``verify_candidates`` otherwise. Returns ``(ids, counts,
    kw_scanned)`` for the delta slots alone.
    """
    M = q_rects.shape[0]
    B = delta.slots_per_leaf
    ix = delta.ins_x[top_leaf].reshape(M, -1)
    iy = delta.ins_y[top_leaf].reshape(M, -1)
    iid = delta.ins_id[top_leaf].reshape(M, -1)
    ival = (iid >= 0) & jnp.repeat(leaf_ok, B, axis=1)
    if q_cbm is not None and delta.ins_cbm is not None:
        Wl = delta.ins_cbm.shape[2]
        icbm = delta.ins_cbm[top_leaf].reshape(M, -1, Wl)
        isig = delta.ins_sig[top_leaf].reshape(M, -1)
        match = ops.verify_candidates_compact(
            q_rects, q_cbm, q_sig, ix, iy, icbm, isig, ival.astype(jnp.int8)
        )
        kw = ((isig & jnp.repeat(q_sig, B, axis=1)) != 0) & jnp.any(
            (icbm & jnp.repeat(q_cbm, B, axis=1)) != 0, axis=-1
        )
    else:
        ibm = delta.ins_bm[top_leaf].reshape(M, -1, q_bm.shape[1])
        match = ops.verify_candidates(
            q_rects, q_bm, ix, iy, ibm, ival.astype(jnp.int8)
        )
        kw = jnp.any(ibm & q_bm[:, None, :] != 0, axis=-1)
    ids = jnp.where(match > 0, iid, -1)
    counts = jnp.sum(match.astype(jnp.int32), axis=1)
    kw_scanned = jnp.sum(kw & ival, axis=1)
    return ids, counts, kw_scanned


def _verify_leaves(
    snap: IndexSnapshot, q_rects, q_bm, top_leaf, leaf_ok, delta=None, fused=None,
    fused_variant: Optional[str] = None, compact: Optional[bool] = None,
):
    """Capacity-bounded verification of the selected leaves (shared by modes).

    ``fused=None`` (auto) now ALWAYS routes the base leaf blocks through the
    fused gather+verify Pallas kernels (DESIGN.md §3.5): the selected
    leaves' object blocks are gathered and verified inside one kernel, so
    the ``(M, T*OBJ, W)`` candidate bitmap plane never round-trips HBM
    between the gather and ``skr_verify``. With a live ``delta`` the fused
    kernel sees an id bank masked by ``base_alive`` (deleted objects behave
    exactly like pad slots) and only the delta's insert-buffer slots go
    through the unfused ``_verify_delta_slots`` merge -- candidate order
    stays [base blocks, delta slots], identical to the wholesale unfused
    pipeline. ``fused=False`` forces that unfused pipeline (the A/B
    baseline); every combination returns identical ids/counters
    (tests/test_query_parity.py).

    ``compact=None`` (auto) verifies on the snapshot's leaf-local compact
    bank when it exists (``has_compact_bank``): queries are remapped into
    each selected leaf's vocabulary (``ops.remap_query_words``) and the
    kernels test a one-word signature before the ``Wl``-word plane --
    bit-identical ids and Eq.1 counters, ~W/Wl fewer verify bytes.
    ``compact=False`` forces the full-width slab.

    ``fused_variant`` picks the fused kernel: None (auto) compares the leaf
    bank's bytes (compact bytes when the compact bank is in play) against
    ``ops.FUSED_VMEM_BANK_BYTES`` -- the VMEM-resident kernel below the
    cutoff, the scalar-prefetched (M, T)-grid kernel above it -- so banks
    beyond VMEM keep the fused path instead of falling back to the unfused
    HBM round-trip. ``"vmem"``/``"prefetch"`` force a kernel (A/B rows,
    beyond-VMEM tests).
    """
    if fused is None:
        fused = True
    cbank = _snap_cbank(snap, compact)
    q_cbm = q_sig = None
    if cbank is not None:
        q_cbm, q_sig = ops.remap_query_words(q_bm, cbank[0], top_leaf)
    variant = fused_variant if fused_variant is not None else "auto"
    if fused:
        base_id = snap.leaf_obj_id
        if delta is not None:
            # deleted objects become pad slots for the fused base pass
            base_id = jnp.where(delta.base_alive > 0, snap.leaf_obj_id, -1)
        if cbank is not None:
            ids, kwv = ops.fused_gather_verify_compact(
                q_rects, q_cbm, q_sig, top_leaf, leaf_ok.astype(jnp.int8),
                snap.leaf_obj_x, snap.leaf_obj_y, cbank[1], cbank[2], base_id,
                variant=variant,
            )
        else:
            ids, kwv = ops.fused_gather_verify(
                q_rects, q_bm, top_leaf, leaf_ok.astype(jnp.int8),
                snap.leaf_obj_x, snap.leaf_obj_y, snap.leaf_obj_bm, base_id,
                variant=variant,
            )
        counts = jnp.sum((ids >= 0).astype(jnp.int32), axis=1)
        kw_scanned = jnp.sum(kwv, axis=1)
        if delta is not None:
            d_ids, d_counts, d_kw = _verify_delta_slots(
                q_rects, q_bm, top_leaf, leaf_ok, delta, q_cbm, q_sig
            )
            ids = jnp.concatenate([ids, d_ids], axis=1)
            counts = counts + d_counts
            kw_scanned = kw_scanned + d_kw
        return ids, counts, kw_scanned
    M = q_rects.shape[0]
    cx = snap.leaf_obj_x[top_leaf].reshape(M, -1)
    cy = snap.leaf_obj_y[top_leaf].reshape(M, -1)
    cid = snap.leaf_obj_id[top_leaf].reshape(M, -1)
    cval = (cid >= 0) & jnp.repeat(leaf_ok, snap.obj_per_leaf, axis=1)
    if delta is not None:
        alive = delta.base_alive[top_leaf].reshape(M, -1)
        cval = cval & (alive > 0)
    if cbank is not None:
        OBJ = snap.obj_per_leaf
        Wl = cbank[1].shape[2]
        ccbm = cbank[1][top_leaf].reshape(M, -1, Wl)
        csig = cbank[2][top_leaf].reshape(M, -1)
        match = ops.verify_candidates_compact(
            q_rects, q_cbm, q_sig, cx, cy, ccbm, csig, cval.astype(jnp.int8)
        )
        kw = ((csig & jnp.repeat(q_sig, OBJ, axis=1)) != 0) & jnp.any(
            (ccbm & jnp.repeat(q_cbm, OBJ, axis=1)) != 0, axis=-1
        )
        counts = jnp.sum(match.astype(jnp.int32), axis=1)
        kw_scanned = jnp.sum(kw & cval, axis=1)
        ids = jnp.where(match > 0, cid, -1)
        if delta is not None:
            d_ids, d_counts, d_kw = _verify_delta_slots(
                q_rects, q_bm, top_leaf, leaf_ok, delta, q_cbm, q_sig
            )
            ids = jnp.concatenate([ids, d_ids], axis=1)
            counts = counts + d_counts
            kw_scanned = kw_scanned + d_kw
        return ids, counts, kw_scanned
    cbm = snap.leaf_obj_bm[top_leaf].reshape(M, -1, q_bm.shape[1])
    if delta is not None:
        B = delta.slots_per_leaf
        ix = delta.ins_x[top_leaf].reshape(M, -1)
        iy = delta.ins_y[top_leaf].reshape(M, -1)
        ibm = delta.ins_bm[top_leaf].reshape(M, -1, q_bm.shape[1])
        iid = delta.ins_id[top_leaf].reshape(M, -1)
        ival = (iid >= 0) & jnp.repeat(leaf_ok, B, axis=1)
        cx = jnp.concatenate([cx, ix], axis=1)
        cy = jnp.concatenate([cy, iy], axis=1)
        cbm = jnp.concatenate([cbm, ibm], axis=1)
        cid = jnp.concatenate([cid, iid], axis=1)
        cval = jnp.concatenate([cval, ival], axis=1)
    match = ops.verify_candidates(q_rects, q_bm, cx, cy, cbm, cval.astype(jnp.int8))
    counts = jnp.sum(match.astype(jnp.int32), axis=1)
    # keyword-matching candidates scanned (Eq.1 verification cost)
    kw_scanned = jnp.sum(
        (jnp.any(cbm & q_bm[:, None, :] != 0, axis=-1) & cval), axis=1
    )
    ids = jnp.where(match > 0, cid, -1)
    return ids, counts, kw_scanned


def _root_frontier(snap: IndexSnapshot, M: int) -> jnp.ndarray:
    n_root = int(snap.level_mbrs[0].shape[0])
    root = np.full((snap.root_width(),), -1, np.int32)
    root[:n_root] = np.arange(n_root, dtype=np.int32)
    return jnp.tile(jnp.asarray(root)[None, :], (M, 1))


def _local_root_frontier(width: int, n_root_local, M: int) -> jnp.ndarray:
    """Shard-local root frontier for the index-sharded descent: the first
    ``n_root_local`` (a per-shard device scalar -- shards own different
    numbers of root subtrees) slots hold local root ids, the rest are ``-1``
    pads. Masking by the REAL local count keeps psum'd ``nodes_checked``
    exactly equal to the single-device root scan."""
    slot = jnp.arange(width, dtype=jnp.int32)
    root = jnp.where(slot < n_root_local, slot, -1)
    return jnp.tile(root[None, :], (M, 1))


def _descend_frontier(
    snap: IndexSnapshot, q_rects, q_bm, plan: ExecutionPlan, delta=None, words=None,
    root=None,
):
    """Shared range-query frontier descent.

    ``plan.widths=None``: exact mode -- bucket each next frontier on the
    batch's actual occupancy, one blocking host sync per level (first descent
    and overflow retries). ``plan.widths=(...)``: cached mode -- no per-level
    syncs; per-level child-count maxima are returned as device scalars for
    the caller's single batched overflow check. ``delta`` swaps in the
    insert-widened level arrays (DESIGN.md §7). ``words`` (the
    ``(wids, bits)`` pair from ``ops.pack_query_words``) switches the level
    filters to the bandwidth-lean narrow planes -- int16 MBR rank codes and
    packed bitmap word planes, bit-identical survivors (DESIGN.md §3.5);
    requires ``snap.has_narrow_planes`` and no live delta. ``root`` overrides
    the level-0 frontier -- the index-sharded path starts each shard from its
    masked local root frontier (``_local_root_frontier``) instead of the full
    forest.
    """
    M = q_rects.shape[0]
    narrow = words is not None and delta is None and snap.has_narrow_planes
    frontier = root if root is not None else _root_frontier(snap, M)
    nodes_checked = jnp.zeros((M,), jnp.int32)
    used: List[int] = []
    needs: List = []
    surv = None
    for li in range(snap.n_levels):
        used.append(int(frontier.shape[1]))
        if narrow:
            surv, n_valid = _filter_frontier_level_narrow(
                snap.level_mbr_codes[li], snap.level_bms[li],
                snap.level_dict_x[li], snap.level_dict_y[li],
                q_rects, words[0], words[1], frontier,
            )
        else:
            mbrs, bms = _level_arrays(snap, delta, li)
            surv, n_valid = _filter_frontier_level(mbrs, bms, q_rects, q_bm, frontier)
        nodes_checked = nodes_checked + n_valid
        if li < snap.n_levels - 1:
            need = _frontier_child_counts(snap.child_counts[li], frontier, surv)
            f_next = plan.pick_width(need, li, needs)
            frontier = _expand_frontier(snap.child_table[li], frontier, surv, f_next)
    return frontier, surv, nodes_checked, used, needs


def _retrieve_frontier(
    snap: IndexSnapshot,
    q_rects: jnp.ndarray,
    q_bm: jnp.ndarray,
    max_leaves: int,
    cache: PlanCache,
    delta=None,
    fused=None,
    words=None,
    fused_variant: Optional[str] = None,
    compact: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    M = q_rects.shape[0]
    plan = cache.plan("skr", snap.n_levels - 1)
    descend = lambda p: _descend_frontier(snap, q_rects, q_bm, p, delta, words)
    out = descend(plan)
    retried = cache.check_and_retry(plan, out[-1], descend)
    frontier, surv, nodes_checked, used, _ = retried or out

    n_leaf = snap.n_leaves
    take = min(max_leaves, n_leaf, int(frontier.shape[1]))
    top_leaf, leaf_ok, overflow = _select_leaves_frontier(frontier, surv, take, n_leaf)
    ids, counts, kw_scanned = _verify_leaves(
        snap, q_rects, q_bm, top_leaf, leaf_ok, delta, fused, fused_variant, compact
    )
    return dict(
        ids=np.asarray(ids),
        counts=np.asarray(counts),
        nodes_checked=np.asarray(nodes_checked, np.int64),
        nodes_scanned=np.full((M,), sum(used), np.int64),
        verified=np.asarray(kw_scanned),
        overflow=np.asarray(overflow),
        frontier_widths=np.asarray(used, np.int32),
    )


# ------------------------------------------------------- kNN (Boolean, §6)
_ID_SENTINEL = np.int32(np.iinfo(np.int32).max)

# bf16 carries an 8-bit mantissa: rounding a finite f32 distance to bf16
# perturbs it by at most 2^-9 relative. The retry guard below divides by a
# 2^-6 margin -- comfortably conservative -- to lower-bound what the true
# f32 distance of a bf16-pruned node could have been.
_BF16_RISK_TOL = 2.0 ** -6


def _quantize_dist(d, knn_dtype: str):
    """Model reduced-precision distance math in the bounded sweep: round the
    kernel's f32 squared distances to bf16 (``knn_dtype="bf16"``). On TPU
    the cast moves into the kernel (halving the distance-plane bytes); the
    rounding here is the same numerics, so the retry contract is identical.
    """
    if knn_dtype == "bf16":
        return d.astype(jnp.bfloat16).astype(jnp.float32)
    return d


def _merge_topk(top_d, top_id, cand_d, cand_id, kb: int):
    """Merge candidates into the padded top-k buffer: lexicographic sort on
    (dist^2, object id) keeps equal-distance ties smallest-id-first -- the
    convention shared with the host paths (core.query)."""
    d_all = jnp.concatenate([top_d, cand_d], axis=1)
    id_all = jnp.concatenate([top_id, cand_id], axis=1)
    d_s, id_s = jax.lax.sort((d_all, id_all), dimension=1, num_keys=2)
    return d_s[:, :kb], id_s[:, :kb]


@jax.jit
def _knn_dist_level(mbrs, bms, points, q_bm, frontier):
    """Gather frontier node tiles and run the Pallas kNN distance kernel."""
    valid = frontier >= 0
    safe = jnp.clip(frontier, 0, mbrs.shape[0] - 1)
    d = ops.knn_frontier_dist(points, q_bm, mbrs[safe], bms[safe], valid.astype(jnp.int8))
    return d, jnp.sum(valid, axis=1).astype(jnp.int32)


@jax.jit
def _knn_dist_level_narrow(codes, bms, dict_x, dict_y, points, wids, bits, frontier):
    """Bandwidth-lean twin of ``_knn_dist_level`` (int16 rank codes +
    packed word planes; bit-identical distances)."""
    valid = frontier >= 0
    safe = jnp.clip(frontier, 0, codes.shape[0] - 1)
    f_bm = bms[safe[:, :, None], wids[:, None, :]]  # (M, F, Wp)
    d = ops.knn_frontier_dist_narrow(
        points, bits, codes[safe], f_bm, valid.astype(jnp.int8), dict_x, dict_y
    )
    return d, jnp.sum(valid, axis=1).astype(jnp.int32)


@jax.jit
def _probe_children(child_table, cur):
    safe = jnp.clip(cur, 0, child_table.shape[0] - 1)
    return jnp.where(cur[:, None] >= 0, child_table[safe], -1)


@jax.jit
def _probe_select(d, cand):
    best = jnp.argmin(d, axis=1)  # ties: lowest slot == smallest node id
    bd = jnp.take_along_axis(d, best[:, None], axis=1)[:, 0]
    nxt = jnp.take_along_axis(cand, best[:, None], axis=1)[:, 0]
    return jnp.where(jnp.isfinite(bd), nxt, -1)


def _chunk_kw(q_bm, obj_bm, delta, cbank, leaves2d):
    """Keyword-overlap of each query against gathered leaf blocks.

    ``leaves2d`` is ``(M, T)`` leaf ids (clipped in here; invalid slots are
    masked by the callers' validity logic). Returns ``(kw_base (M, T, OBJ),
    kw_ins (M, T, B) or None)``. With ``cbank=(leaf_terms, obj_cbm,
    obj_sig)`` the test runs on the leaf-local compact plane -- queries are
    remapped once per (query, leaf slot) and a one-word signature gates the
    ``Wl``-word AND -- bit-identical to the full-width test (DESIGN.md
    §3.5). Delta insert slots use the delta's remapped ``ins_cbm`` when it
    carries one (exact: DeltaLog drops it on any out-of-dictionary term)
    and the full-width ``ins_bm`` otherwise.
    """
    if cbank is None:
        K = obj_bm.shape[0]
        safe = jnp.clip(leaves2d, 0, K - 1)
        kw = jnp.any((obj_bm[safe] & q_bm[:, None, None, :]) != 0, axis=-1)
        ikw = None
        if delta is not None:
            ikw = jnp.any(
                (delta.ins_bm[safe] & q_bm[:, None, None, :]) != 0, axis=-1
            )
        return kw, ikw
    leaf_terms, obj_cbm, obj_sig = cbank
    K = obj_cbm.shape[0]
    safe = jnp.clip(leaves2d, 0, K - 1)
    q_cbm, q_sig = ops.remap_query_words(q_bm, leaf_terms, leaves2d)
    sig_hit = (obj_sig[safe] & q_sig[:, :, None]) != 0
    kw = sig_hit & jnp.any((obj_cbm[safe] & q_cbm[:, :, None, :]) != 0, axis=-1)
    ikw = None
    if delta is not None:
        if delta.ins_cbm is not None:
            isig_hit = (delta.ins_sig[safe] & q_sig[:, :, None]) != 0
            ikw = isig_hit & jnp.any(
                (delta.ins_cbm[safe] & q_cbm[:, :, None, :]) != 0, axis=-1
            )
        else:
            ikw = jnp.any(
                (delta.ins_bm[safe] & q_bm[:, None, None, :]) != 0, axis=-1
            )
    return kw, ikw


@functools.partial(jax.jit, static_argnames=("kb",))
def _knn_probe_verify(
    points, q_bm, obj_x, obj_y, obj_bm, obj_id, leaf, top_d, top_id, kb: int,
    delta=None, cbank=None,
):
    """Verify the probe leaf's object block and seed the top-k buffer.

    With a live ``delta``, the probe leaf's insert-buffer slots join the
    candidate set and deleted snapshot objects are masked (a deleted object
    must not occupy a top-k slot or tighten the bound). ``cbank`` routes the
    keyword test through the compact leaf bank (``_chunk_kw``)."""
    safe = jnp.clip(leaf, 0, obj_x.shape[0] - 1)
    ox, oy = obj_x[safe], obj_y[safe]  # (M, OBJ)
    oid = obj_id[safe]
    kw2, ikw2 = _chunk_kw(q_bm, obj_bm, delta, cbank, safe[:, None])
    kw = kw2[:, 0]  # (M, OBJ)
    base_ok = oid >= 0
    if delta is not None:
        base_ok = base_ok & (delta.base_alive[safe] > 0)
        ox = jnp.concatenate([ox, delta.ins_x[safe]], axis=1)
        oy = jnp.concatenate([oy, delta.ins_y[safe]], axis=1)
        oid = jnp.concatenate([oid, delta.ins_id[safe]], axis=1)
        kw = jnp.concatenate([kw, ikw2[:, 0]], axis=1)
        base_ok = jnp.concatenate([base_ok, delta.ins_id[safe] >= 0], axis=1)
    dx = ox - points[:, 0:1]
    dy = oy - points[:, 1:2]
    od2 = dx * dx + dy * dy
    valid = base_ok & kw & (leaf >= 0)[:, None]
    cd = jnp.where(valid, od2, jnp.inf)
    cid = jnp.where(valid, oid, _ID_SENTINEL)
    top_d, top_id = _merge_topk(top_d, top_id, cd, cid, kb)
    return top_d, top_id, jnp.sum(valid, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def _bound_prune(d, top_d, k: int):
    """Frontier slots that survive the current k-th-best bound. ``<=`` keeps
    nodes at exactly the bound: they may hold an equal-distance object with
    a smaller id (the tie-break can still swap it in)."""
    bound = top_d[:, k - 1]
    alive = jnp.isfinite(d) & (d <= bound[:, None])
    pruned = jnp.sum(jnp.isfinite(d) & ~alive, axis=1).astype(jnp.int32)
    return alive.astype(jnp.int8), pruned


@functools.partial(jax.jit, static_argnames=("k", "kb", "ch"))
def _knn_leaf_phase(
    points, q_bm, leaf_d, frontier, probe_leaf,
    obj_x, obj_y, obj_bm, obj_id, top_d, top_id, k: int, kb: int, ch: int,
    delta=None, cbank=None,
):
    """Distance-ordered chunked leaf verification in one lax.scan.

    Leaves are sorted ascending by (min-dist, leaf id); each chunk of ``ch``
    leaves is re-checked against the bound as tightened by every previous
    chunk, so later (farther) chunks are usually bounded out entirely. The
    probe leaf is masked to +inf -- its objects are already in the buffer.
    With a live ``delta``, every chunk leaf's insert-buffer slots are
    verified alongside its snapshot block and deleted objects are masked
    out of the top-k merge.

    Also returns ``rm``, the per-query minimum over bounded-out chunk slots
    of ``dc * (1 - _BF16_RISK_TOL)`` -- the bf16 retry guard's conservative
    lower bound on what a pruned leaf could still contain (inf under f32
    serving or when nothing was pruned; see ``retrieve_knn``'s ``knn_dtype``).
    """
    M, F = leaf_d.shape
    d = jnp.where(frontier == probe_leaf[:, None], jnp.inf, leaf_d)
    d_s, leaf_s = jax.lax.sort((d, frontier), dimension=1, num_keys=2)
    nch = F // ch  # callers pick ch dividing F (power-of-two bucket widths)
    d_ch = jnp.moveaxis(d_s.reshape(M, nch, ch), 1, 0)
    l_ch = jnp.moveaxis(leaf_s.reshape(M, nch, ch), 1, 0)

    def step(carry, inp):
        top_d, top_id, lv, ver, pr, rm = carry
        dc, lc = inp  # (M, ch)
        bound = top_d[:, k - 1]
        active = jnp.isfinite(dc) & (dc <= bound[:, None])
        safe = jnp.clip(lc, 0, obj_x.shape[0] - 1)
        ox, oy = obj_x[safe], obj_y[safe]  # (M, ch, OBJ)
        oid = obj_id[safe]
        kw, ikw = _chunk_kw(q_bm, obj_bm, delta, cbank, safe)
        base_ok = oid >= 0
        if delta is not None:
            base_ok = base_ok & (delta.base_alive[safe] > 0)
            ox = jnp.concatenate([ox, delta.ins_x[safe]], axis=2)
            oy = jnp.concatenate([oy, delta.ins_y[safe]], axis=2)
            oid = jnp.concatenate([oid, delta.ins_id[safe]], axis=2)
            kw = jnp.concatenate([kw, ikw], axis=2)
            base_ok = jnp.concatenate([base_ok, delta.ins_id[safe] >= 0], axis=2)
        dx = ox - points[:, 0][:, None, None]
        dy = oy - points[:, 1][:, None, None]
        od2 = dx * dx + dy * dy
        valid = base_ok & kw & active[:, :, None]
        cd = jnp.where(valid, od2, jnp.inf).reshape(M, -1)
        cid = jnp.where(valid, oid, _ID_SENTINEL).reshape(M, -1)
        top_d2, top_id2 = _merge_topk(top_d, top_id, cd, cid, kb)
        lv = lv + jnp.sum(active, axis=1).astype(jnp.int32)
        ver = ver + jnp.sum(valid, axis=(1, 2)).astype(jnp.int32)
        pr = pr + jnp.sum(jnp.isfinite(dc) & ~active, axis=1).astype(jnp.int32)
        lower = jnp.where(
            jnp.isfinite(dc) & ~active, dc * (1.0 - _BF16_RISK_TOL), jnp.inf
        )
        rm = jnp.minimum(rm, jnp.min(lower, axis=1))
        return (top_d2, top_id2, lv, ver, pr, rm), None

    z = jnp.zeros((M,), jnp.int32)
    rm0 = jnp.full((M,), jnp.inf, jnp.float32)
    (top_d, top_id, lv, ver, pr, rm), _ = jax.lax.scan(
        step, (top_d, top_id, z, z, z, rm0), (d_ch, l_ch)
    )
    return top_d, top_id, lv, ver, pr, rm


def _descend_knn(
    snap: IndexSnapshot, points, q_bm, k: int, kb: int, plan: ExecutionPlan, delta=None,
    words=None, knn_dtype: str = "f32", cbank=None,
):
    """Distance-bounded kNN descent (probe -> bounded sweep -> leaf chunks).

    Width discipline is identical to ``_descend_frontier``: exact mode syncs
    per level, cached mode runs sync-free and returns device maxima for the
    caller's batched overflow check. ``delta`` swaps in the insert-widened
    level arrays and merges buffered inserts / masks deletes in the verify
    stages (DESIGN.md §7). ``words`` switches the probe and sweep level
    filters to the bandwidth-lean narrow planes (bit-identical distances;
    leaf scoring stays on the exact f32 object bank either way).

    ``knn_dtype="bf16"`` rounds the bounded sweep's node distances to bf16
    before pruning and tracks ``risk`` -- the minimum conservative lower
    bound over everything pruned; the caller retries in exact f32 whenever
    ``risk`` reaches the final bound (``retrieve_knn``). Object distances in
    the verify stages stay exact f32 either way, so a descent whose risk
    stays above the final bound is already id-exact.
    """
    M = int(points.shape[0])
    L = snap.n_levels
    narrow = words is not None and delta is None and snap.has_narrow_planes

    def dist_level(li, fr):
        if narrow:
            return _knn_dist_level_narrow(
                snap.level_mbr_codes[li], snap.level_bms[li],
                snap.level_dict_x[li], snap.level_dict_y[li],
                points, words[0], words[1], fr,
            )
        mbrs, bms = _level_arrays(snap, delta, li)
        return _knn_dist_level(mbrs, bms, points, q_bm, fr)

    top_d = jnp.full((M, kb), jnp.inf, jnp.float32)
    top_id = jnp.full((M, kb), _ID_SENTINEL, jnp.int32)
    nodes_checked = jnp.zeros((M,), jnp.int32)
    pruned = jnp.zeros((M,), jnp.int32)

    # probe: beam-1 greedy descent to a leaf seeds the buffer, so the sweep
    # below starts with a finite bound and can prune before expansion
    cand = _root_frontier(snap, M)
    cur = None
    for li in range(L):
        if li > 0:
            cand = _probe_children(snap.child_table[li - 1], cur)
        d, nv = dist_level(li, cand)
        nodes_checked = nodes_checked + nv
        cur = _probe_select(d, cand)
    probe_leaf = cur
    top_d, top_id, ver0 = _knn_probe_verify(
        points, q_bm, snap.leaf_obj_x, snap.leaf_obj_y, snap.leaf_obj_bm, snap.leaf_obj_id,
        probe_leaf, top_d, top_id, kb, delta, cbank,
    )
    verified = ver0
    leaves_verified = (probe_leaf >= 0).astype(jnp.int32)

    # bounded sweep: full frontier descent, pruning against the k-th best
    frontier = _root_frontier(snap, M)
    used: List[int] = []
    needs: List = []
    leaf_d = None
    risk_min = jnp.full((M,), jnp.inf, jnp.float32)
    for li in range(L):
        used.append(int(frontier.shape[1]))
        d, nv = dist_level(li, frontier)
        d = _quantize_dist(d, knn_dtype)
        nodes_checked = nodes_checked + nv
        if li < L - 1:
            alive, pr = _bound_prune(d, top_d, k)
            pruned = pruned + pr
            lower = jnp.where(
                jnp.isfinite(d) & ~(alive > 0), d * (1.0 - _BF16_RISK_TOL), jnp.inf
            )
            risk_min = jnp.minimum(risk_min, jnp.min(lower, axis=1))
            need = _frontier_child_counts(snap.child_counts[li], frontier, alive)
            f_next = plan.pick_width(need, li, needs)
            frontier = _expand_frontier(snap.child_table[li], frontier, alive, f_next)
        else:
            leaf_d = d

    F = int(frontier.shape[1])
    ch = 4 if F % 4 == 0 else 1
    top_d, top_id, lv, ver, pr, rm = _knn_leaf_phase(
        points, q_bm, leaf_d, frontier, probe_leaf,
        snap.leaf_obj_x, snap.leaf_obj_y, snap.leaf_obj_bm, snap.leaf_obj_id,
        top_d, top_id, k, kb, ch, delta, cbank,
    )
    result = (
        top_d, top_id, nodes_checked, verified + ver,
        leaves_verified + lv, pruned + pr, used,
        jnp.minimum(risk_min, rm),
    )
    return result, needs


def _knn_leaf_phase_indexed(
    points, q_bm, leaf_d, frontier, probe_leaf, leaf_gid,
    obj_x, obj_y, obj_bm, obj_id, top_d, top_id, k: int, kb: int, ch: int,
    n_shards: int, index_axis: str, delta=None, cbank=None,
):
    """Index-sharded twin of ``_knn_leaf_phase`` (shard_map bodies only).

    Parity with the single-device leaf phase needs the *global* ascending
    (min-dist, global leaf id) chunk order, because each chunk's bound is
    tightened by every previous chunk. Each shard ranks its local leaves
    against the all-gathered global (dist, gid) key set and scatters them
    into their global-rank slots; slots owned by other shards stay
    ``(inf, -1)`` locally, so every shard walks the same global chunk
    sequence with exactly its own leaves materialized. After each chunk the
    shards exchange their local top-kb candidates and merge into a shared
    buffer -- the truncation is lossless (a chunk contributes at most kb of
    the new top-kb) -- so the bound sequence, and therefore which leaves get
    verified vs bounded out, is identical to the single-device scan.
    Counters are per-shard (each real leaf counted only by its owner); the
    caller psums them over ``index_axis``.
    """
    M, F = leaf_d.shape
    K = obj_x.shape[0]
    d = jnp.where(frontier == probe_leaf[:, None], jnp.inf, leaf_d)
    gid = jnp.where(frontier >= 0, leaf_gid[jnp.clip(frontier, 0, K - 1)], _ID_SENTINEL)
    gid = jnp.where(jnp.isfinite(d), gid, _ID_SENTINEL)
    d_s, gid_s, leaf_s = jax.lax.sort((d, gid, frontier), dimension=1, num_keys=2)

    # global rank of each local leaf under the (dist, gid) total order
    T = n_shards * F
    gd = _gather_cat(d_s, index_axis)  # (M, T)
    gg = _gather_cat(gid_s, index_axis)
    less = (gd[:, None, :] < d_s[:, :, None]) | (
        (gd[:, None, :] == d_s[:, :, None]) & (gg[:, None, :] < gid_s[:, :, None])
    )
    rank = jnp.sum(less, axis=2).astype(jnp.int32)  # (M, F)

    nch = -(-T // ch)
    rows = jnp.arange(M, dtype=jnp.int32)[:, None]
    fin = jnp.isfinite(d_s)
    tgt = jnp.where(fin, rank, nch * ch)  # pads land in the dump slot
    buf_d = jnp.full((M, nch * ch + 1), jnp.inf, jnp.float32)
    buf_l = jnp.full((M, nch * ch + 1), -1, jnp.int32)
    buf_d = buf_d.at[rows, tgt].set(jnp.where(fin, d_s, jnp.inf))
    buf_l = buf_l.at[rows, tgt].set(jnp.where(fin, leaf_s, -1))
    d_ch = jnp.moveaxis(buf_d[:, : nch * ch].reshape(M, nch, ch), 1, 0)
    l_ch = jnp.moveaxis(buf_l[:, : nch * ch].reshape(M, nch, ch), 1, 0)

    def step(carry, inp):
        top_d, top_id, lv, ver, pr, rm = carry
        dc, lc = inp  # (M, ch)
        bound = top_d[:, k - 1]
        active = jnp.isfinite(dc) & (dc <= bound[:, None])
        safe = jnp.clip(lc, 0, K - 1)
        ox, oy = obj_x[safe], obj_y[safe]  # (M, ch, OBJ)
        oid = obj_id[safe]
        kw, ikw = _chunk_kw(q_bm, obj_bm, delta, cbank, safe)
        base_ok = oid >= 0
        if delta is not None:
            base_ok = base_ok & (delta.base_alive[safe] > 0)
            ox = jnp.concatenate([ox, delta.ins_x[safe]], axis=2)
            oy = jnp.concatenate([oy, delta.ins_y[safe]], axis=2)
            oid = jnp.concatenate([oid, delta.ins_id[safe]], axis=2)
            kw = jnp.concatenate([kw, ikw], axis=2)
            base_ok = jnp.concatenate([base_ok, delta.ins_id[safe] >= 0], axis=2)
        dx = ox - points[:, 0][:, None, None]
        dy = oy - points[:, 1][:, None, None]
        od2 = dx * dx + dy * dy
        valid = base_ok & kw & active[:, :, None]
        cd = jnp.where(valid, od2, jnp.inf).reshape(M, -1)
        cid = jnp.where(valid, oid, _ID_SENTINEL).reshape(M, -1)
        loc_d = jnp.full((M, kb), jnp.inf, jnp.float32)
        loc_id = jnp.full((M, kb), _ID_SENTINEL, jnp.int32)
        loc_d, loc_id = _merge_topk(loc_d, loc_id, cd, cid, kb)
        g_d = _gather_cat(loc_d, index_axis)  # (M, S*kb)
        g_id = _gather_cat(loc_id, index_axis)
        top_d2, top_id2 = _merge_topk(top_d, top_id, g_d, g_id, kb)
        lv = lv + jnp.sum(active, axis=1).astype(jnp.int32)
        ver = ver + jnp.sum(valid, axis=(1, 2)).astype(jnp.int32)
        pr = pr + jnp.sum(jnp.isfinite(dc) & ~active, axis=1).astype(jnp.int32)
        lower = jnp.where(
            jnp.isfinite(dc) & ~active, dc * (1.0 - _BF16_RISK_TOL), jnp.inf
        )
        rm = jnp.minimum(rm, jnp.min(lower, axis=1))
        return (top_d2, top_id2, lv, ver, pr, rm), None

    z = jnp.zeros((M,), jnp.int32)
    rm0 = jnp.full((M,), jnp.inf, jnp.float32)
    (top_d, top_id, lv, ver, pr, rm), _ = jax.lax.scan(
        step, (top_d, top_id, z, z, z, rm0), (d_ch, l_ch)
    )
    return top_d, top_id, lv, ver, pr, rm


def _descend_knn_indexed(
    snap: IndexSnapshot, root_gid, leaf_gid, n_root_local, points, q_bm,
    k: int, kb: int, plan: ExecutionPlan, n_shards: int, index_axis: str,
    delta=None, words=None, cbank=None,
):
    """Index-sharded kNN descent (shard_map bodies only; DESIGN.md §3.4).

    ``snap`` is a shard's ``PartitionedSnapshot.local_view()``;
    ``root_gid``/``leaf_gid`` map local slots to global ids and
    ``n_root_local`` is the shard's real root count. Three collective
    exchanges keep exact parity with ``_descend_knn``:

    1. *Probe*: every shard scans its local roots (their psum'd count equals
       the global root scan), then the shards exchange their best
       ``(dist, root gid)`` -- the lexicographic minimum picks the one
       *canonical* shard whose greedy chain matches the single-device
       probe's smallest-id argmin tie-break. Only the canonical shard counts
       sub-root probe levels and verifies its probe leaf; the seeded top-k
       buffer is then shared via an all-gather + sort.
    2. *Sweep*: purely shard-local -- the bound is static during the sweep,
       so per-node prune decisions match the single-device sweep and the
       counters psum exactly.
    3. *Leaf phase*: ``_knn_leaf_phase_indexed`` walks the global
       (dist, gid)-ordered chunk sequence with a shared bound.

    Always exact f32 (``knn_dtype`` stays a single-device/replicated-path
    flag). Returns the 7-tuple result (no risk) plus per-shard ``needs``.
    """
    M = int(points.shape[0])
    L = snap.n_levels
    narrow = words is not None and delta is None and snap.has_narrow_planes

    def dist_level(li, fr):
        if narrow:
            return _knn_dist_level_narrow(
                snap.level_mbr_codes[li], snap.level_bms[li],
                snap.level_dict_x[li], snap.level_dict_y[li],
                points, words[0], words[1], fr,
            )
        mbrs, bms = _level_arrays(snap, delta, li)
        return _knn_dist_level(mbrs, bms, points, q_bm, fr)

    top_d = jnp.full((M, kb), jnp.inf, jnp.float32)
    top_id = jnp.full((M, kb), _ID_SENTINEL, jnp.int32)
    nodes_checked = jnp.zeros((M,), jnp.int32)
    pruned = jnp.zeros((M,), jnp.int32)

    # probe: local root scan, then one (dist, gid) exchange elects the
    # canonical shard that owns the single-device greedy chain
    cand = _local_root_frontier(snap.root_width(), n_root_local, M)
    d0, nv0 = dist_level(0, cand)
    nodes_checked = nodes_checked + nv0
    best = jnp.argmin(d0, axis=1)  # ties: lowest slot == smallest gid
    bd = jnp.take_along_axis(d0, best[:, None], axis=1)[:, 0]
    bslot = jnp.take_along_axis(cand, best[:, None], axis=1)[:, 0]
    bgid = jnp.where(
        (bslot >= 0) & jnp.isfinite(bd),
        root_gid[jnp.clip(bslot, 0, root_gid.shape[0] - 1)], _ID_SENTINEL,
    )
    g_bd = jax.lax.all_gather(jnp.where(jnp.isfinite(bd), bd, jnp.inf), index_axis)
    g_bg = jax.lax.all_gather(bgid, index_axis)  # (S, M)
    wd, wg = jax.lax.sort((g_bd, g_bg), dimension=0, num_keys=2)
    win_d, win_gid = wd[0], wg[0]
    canonical = jnp.isfinite(bd) & (bd == win_d) & (bgid == win_gid)
    cur = jnp.where(jnp.isfinite(bd), bslot, -1)
    for li in range(1, L):
        cand = _probe_children(snap.child_table[li - 1], cur)
        d, nv = dist_level(li, cand)
        nodes_checked = nodes_checked + jnp.where(canonical, nv, 0)
        cur = _probe_select(d, cand)
    probe_leaf = jnp.where(canonical, cur, -1)
    top_d, top_id, ver0 = _knn_probe_verify(
        points, q_bm, snap.leaf_obj_x, snap.leaf_obj_y, snap.leaf_obj_bm,
        snap.leaf_obj_id, probe_leaf, top_d, top_id, kb, delta, cbank,
    )
    verified = ver0
    leaves_verified = (probe_leaf >= 0).astype(jnp.int32)
    # share the canonical shard's seed so every shard sweeps the same bound
    g_d = _gather_cat(top_d, index_axis)
    g_id = _gather_cat(top_id, index_axis)
    d_sh, id_sh = jax.lax.sort((g_d, g_id), dimension=1, num_keys=2)
    top_d, top_id = d_sh[:, :kb], id_sh[:, :kb]

    # bounded sweep: shard-local (the bound is static until the leaf phase)
    frontier = _local_root_frontier(snap.root_width(), n_root_local, M)
    used: List[int] = []
    needs: List = []
    leaf_d = None
    for li in range(L):
        used.append(int(frontier.shape[1]))
        d, nv = dist_level(li, frontier)
        nodes_checked = nodes_checked + nv
        if li < L - 1:
            alive, pr = _bound_prune(d, top_d, k)
            pruned = pruned + pr
            need = _frontier_child_counts(snap.child_counts[li], frontier, alive)
            f_next = plan.pick_width(need, li, needs)
            frontier = _expand_frontier(snap.child_table[li], frontier, alive, f_next)
        else:
            leaf_d = d

    F = int(frontier.shape[1])
    ch = 4 if F % 4 == 0 else 1
    top_d, top_id, lv, ver, pr, _ = _knn_leaf_phase_indexed(
        points, q_bm, leaf_d, frontier, probe_leaf, leaf_gid,
        snap.leaf_obj_x, snap.leaf_obj_y, snap.leaf_obj_bm, snap.leaf_obj_id,
        top_d, top_id, k, kb, ch, n_shards, index_axis, delta, cbank,
    )
    result = (
        top_d, top_id, nodes_checked, verified + ver,
        leaves_verified + lv, pruned + pr, used,
    )
    return result, needs


def retrieve_knn(
    snap: IndexSnapshot,
    points,
    q_bm,
    k: int,
    min_topk_bucket: int = 8,
    plan_cache: Optional[PlanCache] = None,
    delta: Optional[DeltaBuffer] = None,
    quantized: Optional[bool] = None,
    knn_dtype: str = "f32",
    compact: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """Batched Boolean kNN over the device-resident index (DESIGN.md §6).

    Returns per-query ``ids``/``dist2`` of the exact k nearest keyword-
    matching objects (ascending (dist^2, id); ``-1``-padded when fewer than
    k objects match) plus cost counters: ``nodes_checked``, ``verified``
    (kw-matching objects scored), ``leaves_verified`` (leaf blocks
    verified), and ``pruned`` (kw-matching frontier slots bounded out).
    ``delta`` merges buffered inserts/deletes on the fly (DESIGN.md §7).
    ``quantized=None`` (auto) descends on the snapshot's narrow planes when
    available and no delta is live; ``False`` forces the f32 full-width A/B
    baseline. ``compact=None`` (auto) runs every leaf keyword test on the
    leaf-local compact bank when the snapshot carries one (signature
    prefilter + ``Wl``-word plane; distance math untouched); ``False``
    forces the full-width slab. Results are bit-identical every way
    (DESIGN.md §3.5).

    ``knn_dtype="bf16"`` runs the bounded sweep's node-distance pruning in
    bf16 (ROADMAP item 5). Object distances stay exact f32, so the result
    differs from f32 only when a node was pruned on a rounded-down distance
    that an exact sweep would have expanded; the descent tracks a
    conservative ``risk`` lower bound over everything pruned and retries the
    whole batch in exact f32 whenever that risk reaches the final k-th
    bound. The output dict gains ``knn_dtype_retried`` and ids are always
    identical to the f32 path.
    """
    if knn_dtype not in ("f32", "bf16"):
        raise ValueError(f"knn_dtype must be 'f32' or 'bf16', got {knn_dtype!r}")
    points = jnp.asarray(points, jnp.float32)
    q_bm = jnp.asarray(q_bm, jnp.uint32)
    M = int(points.shape[0])
    if k <= 0:
        z = np.zeros(M, np.int64)
        return dict(
            ids=np.zeros((M, 0), np.int32), dist2=np.zeros((M, 0), np.float32),
            nodes_checked=z, verified=z.copy(), leaves_verified=z.copy(),
            pruned=z.copy(), frontier_widths=np.zeros(0, np.int32),
        )
    kb = round_up_bucket(k, min_topk_bucket)
    cache = plan_cache if plan_cache is not None else default_plan_cache(snap)
    words = _narrow_words(q_bm, delta, snap, quantized)
    cbank = _snap_cbank(snap, compact)
    plan = cache.plan("knn", snap.n_levels - 1)
    descend = lambda p: _descend_knn(
        snap, points, q_bm, k, kb, p, delta, words, knn_dtype=knn_dtype, cbank=cbank
    )
    out = descend(plan)
    retried = cache.check_and_retry(plan, out[-1], descend)
    (top_d, top_id, nodes_checked, verified, leaves_verified,
     pruned, used, risk) = (retried or out)[0]
    if knn_dtype == "bf16":
        bound = np.asarray(top_d[:, k - 1])
        risk_np = np.asarray(risk)
        if bool(np.any(np.isfinite(risk_np) & (risk_np <= bound))):
            exact = retrieve_knn(
                snap, points, q_bm, k, min_topk_bucket=min_topk_bucket,
                plan_cache=cache, delta=delta, quantized=quantized,
                knn_dtype="f32", compact=compact,
            )
            exact["knn_dtype_retried"] = True
            return exact
    fin = jnp.isfinite(top_d[:, :k])
    ids = jnp.where(fin, top_id[:, :k], -1)
    result = dict(
        ids=np.asarray(ids),
        dist2=np.asarray(top_d[:, :k]),
        nodes_checked=np.asarray(nodes_checked, np.int64),
        verified=np.asarray(verified, np.int64),
        leaves_verified=np.asarray(leaves_verified, np.int64),
        pruned=np.asarray(pruned, np.int64),
        frontier_widths=np.asarray(used, np.int32),
    )
    if knn_dtype == "bf16":
        result["knn_dtype_retried"] = False
    return result


# --------------------------------------------------------------- dense path
def _retrieve_dense(
    snap: IndexSnapshot, q_rects: jnp.ndarray, q_bm: jnp.ndarray, max_leaves: int,
    delta=None, fused=None, compact: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    if len(snap.child_matrix) != len(snap.level_mbrs) - 1:
        raise ValueError("dense mode needs IndexSnapshot.build(..., dense=True)")
    M = q_rects.shape[0]
    active = jnp.ones((M, snap.level_mbrs[0].shape[0]), jnp.int8)
    nodes_checked = jnp.zeros((M,), jnp.int32)
    for li in range(len(snap.level_mbrs)):
        mbrs, bms = _level_arrays(snap, delta, li)
        rel = ops.filter_pairs(q_rects, q_bm, mbrs, bms)
        nodes_checked = nodes_checked + jnp.sum(active > 0, axis=1)
        hit = (rel > 0) & (active > 0)
        if li < len(snap.level_mbrs) - 1:
            active = (hit.astype(jnp.int8) @ snap.child_matrix[li] > 0).astype(jnp.int8)
        else:
            leaf_hit = hit
    # pick up to max_leaves relevant leaves per query (lowest leaf id first)
    score = leaf_hit.astype(jnp.int32)
    take = min(max_leaves, score.shape[1])
    top_val, top_leaf = jax.lax.top_k(score, take)  # (M, L)
    leaf_ok = top_val > 0
    overflow = jnp.maximum(jnp.sum(score, axis=1) - take, 0)
    ids, counts, kw_scanned = _verify_leaves(
        snap, q_rects, q_bm, top_leaf, leaf_ok, delta, fused, compact=compact
    )
    return dict(
        ids=np.asarray(ids),
        counts=np.asarray(counts),
        nodes_checked=np.asarray(nodes_checked, np.int64),
        # padded (tile-aligned) widths filter_pairs actually scores, so the
        # A/B metric stays symmetric with the frontier path (whose power-of-
        # two buckets are already tile-exact)
        nodes_scanned=np.full(
            (M,),
            sum(ops.padded_tile_len(int(l.shape[0])) for l in snap.level_mbrs),
            np.int64,
        ),
        verified=np.asarray(kw_scanned),
        overflow=np.asarray(overflow),
    )


def retrieve(
    snap: IndexSnapshot,
    q_rects: jnp.ndarray,
    q_bm: jnp.ndarray,
    max_leaves: int = 32,
    mode: str = "frontier",
    plan_cache: Optional[PlanCache] = None,
    delta: Optional[DeltaBuffer] = None,
    fused: Optional[bool] = None,
    quantized: Optional[bool] = None,
    fused_variant: Optional[str] = None,
    compact: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """Batched SKR retrieval. Exact as long as <= max_leaves leaves are
    relevant per query (the spill is counted in ``overflow``).

    ``mode="frontier"`` is the sparse descent; ``mode="dense"`` the original
    full-level scan (kept for A/B benchmarking). ``plan_cache`` carries the
    frontier width state across calls; None uses the per-snapshot default.
    ``delta`` merges buffered inserts/deletes on the fly (DESIGN.md §7).
    ``fused`` picks the leaf verification pipeline (DESIGN.md §3.5): None
    (auto) uses the fused gather+verify kernels on the base leaf blocks --
    with a live delta only the insert-buffer slots take the unfused merge;
    False forces the wholesale unfused A/B baseline. ``fused_variant``
    further picks the fused kernel (None auto-selects by leaf-bank bytes vs
    ``ops.FUSED_VMEM_BANK_BYTES``; ``"vmem"``/``"prefetch"`` force one).
    ``quantized`` controls the bandwidth-lean frontier descent (DESIGN.md
    §3.5): None (auto) uses the snapshot's int16 shadow MBR planes + packed
    bitmap words when available and no delta is live; False forces the f32
    full-width baseline. ``compact`` controls leaf verification width
    (DESIGN.md §3.5): None (auto) verifies on the leaf-local compact
    vocabulary bank (remapped query words + one-word signature prefilter)
    whenever the snapshot carries one; False forces the global full-width
    slab. Every combination is id- and counter-exact.
    """
    q_rects = jnp.asarray(q_rects, jnp.float32)
    q_bm = jnp.asarray(q_bm, jnp.uint32)
    if mode == "frontier":
        cache = plan_cache if plan_cache is not None else default_plan_cache(snap)
        words = _narrow_words(q_bm, delta, snap, quantized)
        return _retrieve_frontier(
            snap, q_rects, q_bm, max_leaves, cache, delta, fused, words,
            fused_variant, compact,
        )
    if mode == "dense":
        # the dense A/B path scores full levels against full-width planes by
        # design; the narrow planes only accelerate the frontier descent
        return _retrieve_dense(snap, q_rects, q_bm, max_leaves, delta, fused, compact)
    raise ValueError(f"unknown retrieve mode {mode!r}")


def retrieve_workload(
    snap: IndexSnapshot,
    workload: Workload,
    max_leaves: int = 32,
    mode: str = "frontier",
    plan_cache: Optional[PlanCache] = None,
    delta: Optional[DeltaBuffer] = None,
    fused: Optional[bool] = None,
    quantized: Optional[bool] = None,
    fused_variant: Optional[str] = None,
    compact: Optional[bool] = None,
):
    return retrieve(
        snap,
        jnp.asarray(workload.rects),
        jnp.asarray(workload.kw_bitmap),
        max_leaves,
        mode=mode,
        plan_cache=plan_cache,
        delta=delta,
        fused=fused,
        quantized=quantized,
        fused_variant=fused_variant,
        compact=compact,
    )
