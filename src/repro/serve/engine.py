"""Serving engine: WISK retrieval front-end + batched LM decode.

The WISK half is the TPU-execution path of the paper (level-synchronous
filter via the Pallas kernels, capacity-bounded verification); the LM half
is a simple batched greedy decoder over any arch bundle. ``retrieve()``
returns exact SKR results (validated against core.query in tests) plus the
Eq.1-style cost counters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.types import GeoTextDataset, WiskIndex, Workload
from ..kernels import ops


@dataclasses.dataclass
class BatchedWisk:
    """Device-resident arrays for batched query execution over a WiskIndex."""

    level_mbrs: List[jnp.ndarray]
    level_bms: List[jnp.ndarray]
    child_matrix: List[jnp.ndarray]  # (n_up, n_down) int8 adjacency per level
    leaf_obj_x: jnp.ndarray  # (K, OBJ) padded per-leaf object blocks
    leaf_obj_y: jnp.ndarray
    leaf_obj_bm: jnp.ndarray  # (K, OBJ, W)
    leaf_obj_id: jnp.ndarray  # (K, OBJ) int32, -1 pad
    obj_per_leaf: int

    @staticmethod
    def build(index: WiskIndex, dataset: GeoTextDataset) -> "BatchedWisk":
        mbrs = [jnp.asarray(l.mbrs) for l in index.levels]
        bms = [jnp.asarray(l.bitmaps) for l in index.levels]
        child = []
        for li in range(len(index.levels) - 1):
            l = index.levels[li]
            n_down = index.levels[li + 1].n
            m = np.zeros((l.n, n_down), dtype=np.int8)
            for u in range(l.n):
                m[u, l.child[l.child_ptr[u] : l.child_ptr[u + 1]]] = 1
            child.append(jnp.asarray(m))
        clusters = index.clusters
        sizes = np.diff(clusters.offsets)
        OBJ = int(max(8, 1 << int(np.ceil(np.log2(max(sizes.max(), 1))))))
        K = clusters.k
        W = dataset.words
        ox = np.zeros((K, OBJ), np.float32)
        oy = np.zeros((K, OBJ), np.float32)
        obm = np.zeros((K, OBJ, W), np.uint32)
        oid = np.full((K, OBJ), -1, np.int32)
        for c in range(K):
            ids = clusters.order[clusters.offsets[c] : clusters.offsets[c + 1]]
            ox[c, : ids.size] = dataset.locs[ids, 0]
            oy[c, : ids.size] = dataset.locs[ids, 1]
            obm[c, : ids.size] = dataset.kw_bitmap[ids]
            oid[c, : ids.size] = ids
        return BatchedWisk(
            level_mbrs=mbrs,
            level_bms=bms,
            child_matrix=child,
            leaf_obj_x=jnp.asarray(ox),
            leaf_obj_y=jnp.asarray(oy),
            leaf_obj_bm=jnp.asarray(obm),
            leaf_obj_id=jnp.asarray(oid),
            obj_per_leaf=OBJ,
        )


def retrieve(
    bw: BatchedWisk,
    q_rects: jnp.ndarray,
    q_bm: jnp.ndarray,
    max_leaves: int = 32,
) -> Dict[str, np.ndarray]:
    """Level-synchronous traversal + capacity-bounded verification.

    Returns result ids (padded -1), counts, and cost counters. Exact as long
    as <= max_leaves leaves are relevant per query (overflow is counted).
    """
    M = q_rects.shape[0]
    active = jnp.ones((M, bw.level_mbrs[0].shape[0]), jnp.int8)
    nodes_checked = jnp.zeros((M,), jnp.int64)
    for li in range(len(bw.level_mbrs)):
        rel = ops.filter_pairs(q_rects, q_bm, bw.level_mbrs[li], bw.level_bms[li])
        nodes_checked = nodes_checked + jnp.sum(active > 0, axis=1)
        hit = (rel > 0) & (active > 0)
        if li < len(bw.level_mbrs) - 1:
            active = (hit.astype(jnp.int8) @ bw.child_matrix[li] > 0).astype(jnp.int8)
        else:
            leaf_hit = hit
    # pick up to max_leaves relevant leaves per query
    score = leaf_hit.astype(jnp.int32)
    take = min(max_leaves, score.shape[1])
    top_val, top_leaf = jax.lax.top_k(score, take)  # (M, L)
    leaf_ok = top_val > 0
    overflow = jnp.maximum(jnp.sum(score, axis=1) - take, 0)
    # gather candidate blocks
    cx = bw.leaf_obj_x[top_leaf].reshape(M, -1)
    cy = bw.leaf_obj_y[top_leaf].reshape(M, -1)
    cbm = bw.leaf_obj_bm[top_leaf].reshape(M, -1, q_bm.shape[1])
    cid = bw.leaf_obj_id[top_leaf].reshape(M, -1)
    cval = (cid >= 0) & jnp.repeat(leaf_ok, bw.obj_per_leaf, axis=1)
    match = ops.verify_candidates(q_rects, q_bm, cx, cy, cbm, cval.astype(jnp.int8))
    counts = jnp.sum(match.astype(jnp.int32), axis=1)
    # keyword-matching candidates scanned (Eq.1 verification cost)
    kw_scanned = jnp.sum(
        (jnp.any(cbm & q_bm[:, None, :] != 0, axis=-1) & cval), axis=1
    )
    ids = jnp.where(match > 0, cid, -1)
    return dict(
        ids=np.asarray(ids),
        counts=np.asarray(counts),
        nodes_checked=np.asarray(nodes_checked),
        verified=np.asarray(kw_scanned),
        overflow=np.asarray(overflow),
    )


def retrieve_workload(bw: BatchedWisk, workload: Workload, max_leaves: int = 32):
    return retrieve(
        bw, jnp.asarray(workload.rects), jnp.asarray(workload.kw_bitmap), max_leaves
    )


# --------------------------------------------------------------- LM decode
def greedy_generate(steps, params, cache, prompt_tokens: jnp.ndarray, n_new: int, start_pos: int):
    """Batched greedy decode loop driving steps.decode_step."""
    decode = jax.jit(steps.decode_step)
    tok = prompt_tokens[:, -1:]
    out = []
    pos = start_pos
    for _ in range(n_new):
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1), cache
