"""Serving engine: WISK retrieval front-end + batched LM decode.

The WISK half is the TPU-execution path of the paper (DESIGN.md §3). Two
traversal modes share the leaf verification stage:

* ``mode="frontier"`` (default) -- sparse frontier descent: each query
  carries a padded int32 frontier of candidate node ids; per level the
  Pallas frontier kernel filters the gathered frontier tile (MBR intersect
  + bitmap AND) and survivors' children are expanded through device-resident
  CSR child arrays into the next frontier, compacted with a prefix-sum
  scatter. Per-level work is O(M * frontier_width), so the learned
  hierarchy's pruning shows up as wall-clock, not just as a counter.
* ``mode="dense"`` -- the original level-synchronous path kept for A/B
  benchmarking: an (M, n_level) active mask and dense (n_up, n_down) int8
  child matrices; per-level work is O(M * n_level) regardless of
  selectivity.

Both modes return exact SKR results (validated against core.query in
tests/test_query_parity.py) plus Eq.1-style cost counters:

* ``nodes_checked`` -- nodes whose MBR/bitmap were examined for the query
  (frontier-resident nodes only; matches ``execute_serial``'s
  ``nodes_accessed``),
* ``nodes_scanned`` -- slots the kernels actually touched (padded frontier
  widths, or full level widths in dense mode) -- the honest device-work
  measure the benchmark compares,
* ``verified``/``overflow`` -- Eq.1 verification cost and ``max_leaves``
  spill accounting.

The LM half is a simple batched greedy decoder over any arch bundle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

# round_up_bucket moved to core.query so construction (core.partition) can
# share the exact same bucket discipline; re-exported here for callers
# (launch.wisk_serve, tests) that address it through the serving engine.
from ..core.query import padded_child_table, round_up_bucket  # noqa: F401
from ..core.types import GeoTextDataset, WiskIndex, Workload
from ..kernels import ops


@dataclasses.dataclass
class BatchedWisk:
    """Device-resident arrays for batched query execution over a WiskIndex."""

    level_mbrs: List[jnp.ndarray]
    level_bms: List[jnp.ndarray]
    # CSR children per non-leaf level, padded-table form (frontier path)
    child_table: List[jnp.ndarray]  # (n_up, max_fanout) int32, -1 padded
    child_counts: List[jnp.ndarray]  # (n_up,) int32
    # dense adjacency per non-leaf level (A/B dense path; [] if not built)
    child_matrix: List[jnp.ndarray]  # (n_up, n_down) int8
    leaf_obj_x: jnp.ndarray  # (K, OBJ) padded per-leaf object blocks
    leaf_obj_y: jnp.ndarray
    leaf_obj_bm: jnp.ndarray  # (K, OBJ, W)
    leaf_obj_id: jnp.ndarray  # (K, OBJ) int32, -1 pad
    obj_per_leaf: int

    @property
    def n_levels(self) -> int:
        return len(self.level_mbrs)

    @property
    def n_leaves(self) -> int:
        return int(self.level_mbrs[-1].shape[0])

    @staticmethod
    def build(index: WiskIndex, dataset: GeoTextDataset, dense: bool = False) -> "BatchedWisk":
        """``dense=True`` additionally materializes the O(n_up * n_down)
        child matrices the A/B ``mode="dense"`` path needs; the default
        frontier path only builds the CSR arrays."""
        mbrs = [jnp.asarray(l.mbrs) for l in index.levels]
        bms = [jnp.asarray(l.bitmaps) for l in index.levels]
        child_table, child_counts, child_matrix = [], [], []
        for li in range(len(index.levels) - 1):
            l = index.levels[li]
            child_table.append(jnp.asarray(padded_child_table(l)))
            child_counts.append(jnp.asarray(np.diff(l.child_ptr), jnp.int32))
            if dense:
                n_down = index.levels[li + 1].n
                m = np.zeros((l.n, n_down), dtype=np.int8)
                for u in range(l.n):
                    m[u, l.child[l.child_ptr[u] : l.child_ptr[u + 1]]] = 1
                child_matrix.append(jnp.asarray(m))
        clusters = index.clusters
        sizes = np.diff(clusters.offsets)
        OBJ = round_up_bucket(int(sizes.max()))
        K = clusters.k
        W = dataset.words
        ox = np.zeros((K, OBJ), np.float32)
        oy = np.zeros((K, OBJ), np.float32)
        obm = np.zeros((K, OBJ, W), np.uint32)
        oid = np.full((K, OBJ), -1, np.int32)
        for c in range(K):
            ids = clusters.order[clusters.offsets[c] : clusters.offsets[c + 1]]
            ox[c, : ids.size] = dataset.locs[ids, 0]
            oy[c, : ids.size] = dataset.locs[ids, 1]
            obm[c, : ids.size] = dataset.kw_bitmap[ids]
            oid[c, : ids.size] = ids
        return BatchedWisk(
            level_mbrs=mbrs,
            level_bms=bms,
            child_table=child_table,
            child_counts=child_counts,
            child_matrix=child_matrix,
            leaf_obj_x=jnp.asarray(ox),
            leaf_obj_y=jnp.asarray(oy),
            leaf_obj_bm=jnp.asarray(obm),
            leaf_obj_id=jnp.asarray(oid),
            obj_per_leaf=OBJ,
        )


# ------------------------------------------------------------ frontier steps
@jax.jit
def _filter_frontier_level(mbrs, bms, q_rects, q_bm, frontier):
    """Gather frontier node tiles and run the Pallas frontier kernel."""
    valid = frontier >= 0
    safe = jnp.clip(frontier, 0, mbrs.shape[0] - 1)
    surv = ops.filter_frontier(q_rects, q_bm, mbrs[safe], bms[safe], valid.astype(jnp.int8))
    return surv, jnp.sum(valid, axis=1).astype(jnp.int32)


@jax.jit
def _frontier_child_counts(child_counts, frontier, surv):
    """Per-query number of children the surviving frontier will expand to."""
    safe = jnp.clip(frontier, 0, child_counts.shape[0] - 1)
    return jnp.sum(jnp.where(surv > 0, child_counts[safe], 0), axis=1)


@functools.partial(jax.jit, static_argnames=("f_next",))
def _expand_frontier(child_table, frontier, surv, f_next: int):
    """CSR gather of survivors' children + prefix-sum compaction.

    The hierarchy is a tree, so gathered child rows are disjoint and the
    compacted frontier has no duplicates. ``f_next`` must be >= the max
    per-query child count (guaranteed by the caller's bucketing), so the
    descent is lossless.
    """
    M, F = frontier.shape
    safe = jnp.clip(frontier, 0, child_table.shape[0] - 1)
    cand = jnp.where((surv > 0)[:, :, None], child_table[safe], -1).reshape(M, -1)
    validc = cand >= 0
    pos = jnp.cumsum(validc, axis=1) - 1
    pos = jnp.where(validc & (pos < f_next), pos, f_next)  # f_next = trash slot
    nxt = jnp.full((M, f_next + 1), -1, jnp.int32)
    nxt = nxt.at[jnp.arange(M)[:, None], pos].set(cand, mode="drop")
    return nxt[:, :f_next]


@functools.partial(jax.jit, static_argnames=("take", "n_leaf"))
def _select_leaves_frontier(frontier, surv, take: int, n_leaf: int):
    """Up to ``take`` surviving leaves per query, smallest leaf id first.

    Keying top-k by ``n_leaf - leaf_id`` reproduces the dense path's
    tie-break (top_k prefers lower indices), so dense and frontier modes
    drop the *same* leaves under ``max_leaves`` overflow.
    """
    key = jnp.where(surv > 0, n_leaf - frontier, 0)
    val, _ = jax.lax.top_k(key, take)
    leaf_ok = val > 0
    top_leaf = jnp.where(leaf_ok, n_leaf - val, 0)
    overflow = jnp.maximum(jnp.sum((surv > 0).astype(jnp.int32), axis=1) - take, 0)
    return top_leaf, leaf_ok, overflow


def _verify_leaves(bw: BatchedWisk, q_rects, q_bm, top_leaf, leaf_ok):
    """Capacity-bounded verification of the selected leaves (shared by modes)."""
    M = q_rects.shape[0]
    cx = bw.leaf_obj_x[top_leaf].reshape(M, -1)
    cy = bw.leaf_obj_y[top_leaf].reshape(M, -1)
    cbm = bw.leaf_obj_bm[top_leaf].reshape(M, -1, q_bm.shape[1])
    cid = bw.leaf_obj_id[top_leaf].reshape(M, -1)
    cval = (cid >= 0) & jnp.repeat(leaf_ok, bw.obj_per_leaf, axis=1)
    match = ops.verify_candidates(q_rects, q_bm, cx, cy, cbm, cval.astype(jnp.int8))
    counts = jnp.sum(match.astype(jnp.int32), axis=1)
    # keyword-matching candidates scanned (Eq.1 verification cost)
    kw_scanned = jnp.sum(
        (jnp.any(cbm & q_bm[:, None, :] != 0, axis=-1) & cval), axis=1
    )
    ids = jnp.where(match > 0, cid, -1)
    return ids, counts, kw_scanned


def _retrieve_frontier(
    bw: BatchedWisk, q_rects: jnp.ndarray, q_bm: jnp.ndarray, max_leaves: int
) -> Dict[str, np.ndarray]:
    M = q_rects.shape[0]
    n_root = int(bw.level_mbrs[0].shape[0])
    width = round_up_bucket(n_root)
    root = np.full((width,), -1, np.int32)
    root[:n_root] = np.arange(n_root, dtype=np.int32)
    frontier = jnp.tile(jnp.asarray(root)[None, :], (M, 1))

    nodes_checked = jnp.zeros((M,), jnp.int32)
    widths: List[int] = []
    surv = None
    for li in range(bw.n_levels):
        widths.append(int(frontier.shape[1]))
        surv, n_valid = _filter_frontier_level(
            bw.level_mbrs[li], bw.level_bms[li], q_rects, q_bm, frontier
        )
        nodes_checked = nodes_checked + n_valid
        if li < bw.n_levels - 1:
            # bucket the next frontier width on the batch's actual occupancy
            need = _frontier_child_counts(bw.child_counts[li], frontier, surv)
            f_next = round_up_bucket(int(jnp.max(need)))
            frontier = _expand_frontier(bw.child_table[li], frontier, surv, f_next)

    n_leaf = bw.n_leaves
    take = min(max_leaves, n_leaf, int(frontier.shape[1]))
    top_leaf, leaf_ok, overflow = _select_leaves_frontier(frontier, surv, take, n_leaf)
    ids, counts, kw_scanned = _verify_leaves(bw, q_rects, q_bm, top_leaf, leaf_ok)
    return dict(
        ids=np.asarray(ids),
        counts=np.asarray(counts),
        nodes_checked=np.asarray(nodes_checked, np.int64),
        nodes_scanned=np.full((M,), sum(widths), np.int64),
        verified=np.asarray(kw_scanned),
        overflow=np.asarray(overflow),
        frontier_widths=np.asarray(widths, np.int32),
    )


# --------------------------------------------------------------- dense path
def _retrieve_dense(
    bw: BatchedWisk, q_rects: jnp.ndarray, q_bm: jnp.ndarray, max_leaves: int
) -> Dict[str, np.ndarray]:
    if len(bw.child_matrix) != len(bw.level_mbrs) - 1:
        raise ValueError("dense mode needs BatchedWisk.build(..., dense=True)")
    M = q_rects.shape[0]
    active = jnp.ones((M, bw.level_mbrs[0].shape[0]), jnp.int8)
    nodes_checked = jnp.zeros((M,), jnp.int32)
    for li in range(len(bw.level_mbrs)):
        rel = ops.filter_pairs(q_rects, q_bm, bw.level_mbrs[li], bw.level_bms[li])
        nodes_checked = nodes_checked + jnp.sum(active > 0, axis=1)
        hit = (rel > 0) & (active > 0)
        if li < len(bw.level_mbrs) - 1:
            active = (hit.astype(jnp.int8) @ bw.child_matrix[li] > 0).astype(jnp.int8)
        else:
            leaf_hit = hit
    # pick up to max_leaves relevant leaves per query (lowest leaf id first)
    score = leaf_hit.astype(jnp.int32)
    take = min(max_leaves, score.shape[1])
    top_val, top_leaf = jax.lax.top_k(score, take)  # (M, L)
    leaf_ok = top_val > 0
    overflow = jnp.maximum(jnp.sum(score, axis=1) - take, 0)
    ids, counts, kw_scanned = _verify_leaves(bw, q_rects, q_bm, top_leaf, leaf_ok)
    return dict(
        ids=np.asarray(ids),
        counts=np.asarray(counts),
        nodes_checked=np.asarray(nodes_checked, np.int64),
        # padded (tile-aligned) widths filter_pairs actually scores, so the
        # A/B metric stays symmetric with the frontier path (whose power-of-
        # two buckets are already tile-exact)
        nodes_scanned=np.full(
            (M,),
            sum(ops.padded_tile_len(int(l.shape[0])) for l in bw.level_mbrs),
            np.int64,
        ),
        verified=np.asarray(kw_scanned),
        overflow=np.asarray(overflow),
    )


def retrieve(
    bw: BatchedWisk,
    q_rects: jnp.ndarray,
    q_bm: jnp.ndarray,
    max_leaves: int = 32,
    mode: str = "frontier",
) -> Dict[str, np.ndarray]:
    """Batched SKR retrieval. Exact as long as <= max_leaves leaves are
    relevant per query (the spill is counted in ``overflow``).

    ``mode="frontier"`` is the sparse descent; ``mode="dense"`` the original
    full-level scan (kept for A/B benchmarking).
    """
    q_rects = jnp.asarray(q_rects, jnp.float32)
    q_bm = jnp.asarray(q_bm, jnp.uint32)
    if mode == "frontier":
        return _retrieve_frontier(bw, q_rects, q_bm, max_leaves)
    if mode == "dense":
        return _retrieve_dense(bw, q_rects, q_bm, max_leaves)
    raise ValueError(f"unknown retrieve mode {mode!r}")


def retrieve_workload(
    bw: BatchedWisk, workload: Workload, max_leaves: int = 32, mode: str = "frontier"
):
    return retrieve(
        bw,
        jnp.asarray(workload.rects),
        jnp.asarray(workload.kw_bitmap),
        max_leaves,
        mode=mode,
    )


# --------------------------------------------------------------- LM decode
def greedy_generate(steps, params, cache, prompt_tokens: jnp.ndarray, n_new: int, start_pos: int):
    """Batched greedy decode loop driving steps.decode_step."""
    decode = jax.jit(steps.decode_step)
    tok = prompt_tokens[:, -1:]
    out = []
    pos = start_pos
    for _ in range(n_new):
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1), cache
