"""Snapshot layer: the device-resident WISK index as an immutable pytree.

``IndexSnapshot`` holds every array the batched executors (serve/engine.py)
touch -- per-level MBRs and keyword bitmaps, CSR child tables, the optional
dense adjacency matrices, and the padded per-leaf object blocks. It is
registered as a JAX pytree with the arrays as leaves and the static layout
(``obj_per_leaf``) as aux data, so a whole index can be

* ``jax.device_put`` with one ``NamedSharding`` (``snapshot.replicate(mesh)``
  broadcasts it to every device of a serving mesh), and
* passed through ``jit`` / ``shard_map`` as a SINGLE argument -- the
  query-parallel distributed path (launch/wisk_serve.py:serve_sharded) maps
  it with a one-element ``P()`` prefix spec instead of eight per-array specs.

Mutability policy (DESIGN.md §3.4): the snapshot is frozen. Serving *state*
(the monotone frontier width cache) lives in ``serve/plan.py``'s
``PlanCache`` so the same snapshot can be served concurrently by executors
with independent (or shared) planning state, and *object updates* live in
``serve/delta.py``'s ``DeltaBuffer`` (DESIGN.md §7) so the snapshot never
mutates -- adapting to updates or drift always swaps in a freshly built
snapshot atomically (launch/wisk_serve.py:LiveIndex).

Host-only vs traced: ``IndexSnapshot.build`` and ``.replicate`` run on
host; the snapshot's arrays are consumed inside jit-traced descents.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

import jax
import jax.numpy as jnp

from ..core.query import padded_child_table, round_up_bucket
from ..core.types import GeoTextDataset, WiskIndex


# int16 code capacity per coordinate dictionary: levels whose distinct
# coordinate count exceeds this are served on the f32 planes instead
NARROW_DICT_MAX = 32767


def encode_mbr_planes(level_mbrs):
    """Rank-encode per-level MBR planes into int16 codes + f32 dictionaries.

    Per level, the x dictionary is the sorted distinct set of {xlo, xhi}
    values (y likewise) and each MBR coordinate is replaced by its rank --
    ``dict[code]`` reconstructs the exact f32 value, so descending on the
    codes is lossless (the "never prunes a node the f32 descent keeps"
    guarantee holds with equality). Returns ``(codes, dicts_x, dicts_y)``
    as parallel per-level lists, or three empty lists when any level's
    dictionary would overflow the int16 code space (``NARROW_DICT_MAX``).
    Host-only (snapshot construction time).
    """
    codes, dicts_x, dicts_y = [], [], []
    for m in level_mbrs:
        m = np.asarray(m, np.float32)
        dx = np.unique(m[:, [0, 2]])
        dy = np.unique(m[:, [1, 3]])
        if dx.size > NARROW_DICT_MAX or dy.size > NARROW_DICT_MAX:
            return [], [], []
        c = np.stack(
            [
                np.searchsorted(dx, m[:, 0]),
                np.searchsorted(dy, m[:, 1]),
                np.searchsorted(dx, m[:, 2]),
                np.searchsorted(dy, m[:, 3]),
            ],
            axis=1,
        ).astype(np.int16)
        codes.append(jnp.asarray(c))
        dicts_x.append(jnp.asarray(dx.astype(np.float32)))
        dicts_y.append(jnp.asarray(dy.astype(np.float32)))
    return codes, dicts_x, dicts_y


@dataclasses.dataclass(frozen=True, eq=False)
class IndexSnapshot:
    """Immutable device-resident arrays for batched serving over a WiskIndex.

    All array fields are pytree leaves; ``obj_per_leaf`` is static aux data
    (it is a compiled-shape parameter, not traced data).
    """

    level_mbrs: List[jnp.ndarray]  # per level: (n, 4) f32
    level_bms: List[jnp.ndarray]  # per level: (n, W) u32
    # CSR children per non-leaf level, padded-table form (frontier path)
    child_table: List[jnp.ndarray]  # (n_up, max_fanout) int32, -1 padded
    child_counts: List[jnp.ndarray]  # (n_up,) int32
    # dense adjacency per non-leaf level (A/B dense path; [] if not built)
    child_matrix: List[jnp.ndarray]  # (n_up, n_down) int8
    leaf_obj_x: jnp.ndarray  # (K, OBJ) padded per-leaf object blocks
    leaf_obj_y: jnp.ndarray
    leaf_obj_bm: jnp.ndarray  # (K, OBJ, W)
    leaf_obj_id: jnp.ndarray  # (K, OBJ) int32, -1 pad
    obj_per_leaf: int
    # Bandwidth-lean shadow MBR planes (DESIGN.md §3.5): per level, int16
    # rank codes into the sorted distinct-coordinate dictionaries below.
    # Lossless -- dict[code] reconstructs the exact f32 coordinate -- so the
    # narrow descent's survivor set is bit-identical to the f32 planes'.
    # Empty lists when a level's dictionary would overflow int16 (the engine
    # then descends on the f32 planes).
    level_mbr_codes: List[jnp.ndarray] = dataclasses.field(default_factory=list)  # (n, 4) i16
    level_dict_x: List[jnp.ndarray] = dataclasses.field(default_factory=list)  # (Dx,) f32
    level_dict_y: List[jnp.ndarray] = dataclasses.field(default_factory=list)  # (Dy,) f32

    @property
    def n_levels(self) -> int:
        return len(self.level_mbrs)

    @property
    def n_leaves(self) -> int:
        return int(self.level_mbrs[-1].shape[0])

    @property
    def n_words(self) -> int:
        return int(self.level_bms[0].shape[1])

    @property
    def has_narrow_planes(self) -> bool:
        """True when every level carries int16 shadow MBR codes (the
        bandwidth-lean descent of DESIGN.md §3.5 is available)."""
        return len(self.level_mbr_codes) == len(self.level_mbrs) > 0

    def root_width(self) -> int:
        """Bucketed width of the root frontier (static)."""
        return round_up_bucket(int(self.level_mbrs[0].shape[0]))

    def replicate(self, mesh) -> "IndexSnapshot":
        """The snapshot fully replicated over ``mesh`` (one device_put of the
        whole pytree with a single ``P()`` NamedSharding)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(self, NamedSharding(mesh, P()))

    @staticmethod
    def build(
        index: WiskIndex, dataset: GeoTextDataset, dense: bool = False
    ) -> "IndexSnapshot":
        """Freeze a host-side ``WiskIndex`` into the device-resident pytree
        (host-only; the returned snapshot's arrays feed jit-traced descents).

        Args:
            index: the assembled index (``core.index.assemble_index``).
            dataset: the object collection backing the leaf blocks.
            dense: additionally materialize the O(n_up * n_down) child
                matrices the A/B ``mode="dense"`` path needs; the default
                frontier path only builds the CSR arrays.

        Returns:
            An ``IndexSnapshot`` whose leaf object blocks are padded to the
            power-of-two bucket of the largest cluster (``obj_per_leaf``),
            object ids ``-1``-padded.
        """
        mbrs = [jnp.asarray(l.mbrs) for l in index.levels]
        bms = [jnp.asarray(l.bitmaps) for l in index.levels]
        child_table, child_counts, child_matrix = [], [], []
        for li in range(len(index.levels) - 1):
            l = index.levels[li]
            child_table.append(jnp.asarray(padded_child_table(l)))
            child_counts.append(jnp.asarray(np.diff(l.child_ptr), jnp.int32))
            if dense:
                n_down = index.levels[li + 1].n
                m = np.zeros((l.n, n_down), dtype=np.int8)
                for u in range(l.n):
                    m[u, l.child[l.child_ptr[u] : l.child_ptr[u + 1]]] = 1
                child_matrix.append(jnp.asarray(m))
        clusters = index.clusters
        sizes = np.diff(clusters.offsets)
        OBJ = round_up_bucket(int(sizes.max()))
        K = clusters.k
        W = dataset.words
        ox = np.zeros((K, OBJ), np.float32)
        oy = np.zeros((K, OBJ), np.float32)
        obm = np.zeros((K, OBJ, W), np.uint32)
        oid = np.full((K, OBJ), -1, np.int32)
        for c in range(K):
            ids = clusters.order[clusters.offsets[c] : clusters.offsets[c + 1]]
            ox[c, : ids.size] = dataset.locs[ids, 0]
            oy[c, : ids.size] = dataset.locs[ids, 1]
            obm[c, : ids.size] = dataset.kw_bitmap[ids]
            oid[c, : ids.size] = ids
        codes, dicts_x, dicts_y = encode_mbr_planes([l.mbrs for l in index.levels])
        return IndexSnapshot(
            level_mbrs=mbrs,
            level_bms=bms,
            child_table=child_table,
            child_counts=child_counts,
            child_matrix=child_matrix,
            leaf_obj_x=jnp.asarray(ox),
            leaf_obj_y=jnp.asarray(oy),
            leaf_obj_bm=jnp.asarray(obm),
            leaf_obj_id=jnp.asarray(oid),
            obj_per_leaf=OBJ,
            level_mbr_codes=codes,
            level_dict_x=dicts_x,
            level_dict_y=dicts_y,
        )


_ARRAY_FIELDS = (
    "level_mbrs",
    "level_bms",
    "child_table",
    "child_counts",
    "child_matrix",
    "leaf_obj_x",
    "leaf_obj_y",
    "leaf_obj_bm",
    "leaf_obj_id",
    "level_mbr_codes",
    "level_dict_x",
    "level_dict_y",
)


def _snapshot_flatten(s: IndexSnapshot):
    return tuple(getattr(s, f) for f in _ARRAY_FIELDS), (s.obj_per_leaf,)


def _snapshot_unflatten(aux, children) -> IndexSnapshot:
    kw = dict(zip(_ARRAY_FIELDS, children))
    return IndexSnapshot(obj_per_leaf=aux[0], **kw)


jax.tree_util.register_pytree_node(
    IndexSnapshot, _snapshot_flatten, _snapshot_unflatten
)
