"""Snapshot layer: the device-resident WISK index as an immutable pytree.

``IndexSnapshot`` holds every array the batched executors (serve/engine.py)
touch -- per-level MBRs and keyword bitmaps, CSR child tables, the optional
dense adjacency matrices, and the padded per-leaf object blocks. It is
registered as a JAX pytree with the arrays as leaves and the static layout
(``obj_per_leaf``) as aux data, so a whole index can be

* ``jax.device_put`` with one ``NamedSharding`` (``snapshot.replicate(mesh)``
  broadcasts it to every device of a serving mesh), and
* passed through ``jit`` / ``shard_map`` as a SINGLE argument -- the
  query-parallel distributed path (launch/wisk_serve.py:serve_sharded) maps
  it with a one-element ``P()`` prefix spec instead of eight per-array specs.

Mutability policy (DESIGN.md §3.4): the snapshot is frozen. Serving *state*
(the monotone frontier width cache) lives in ``serve/plan.py``'s
``PlanCache`` so the same snapshot can be served concurrently by executors
with independent (or shared) planning state, and *object updates* live in
``serve/delta.py``'s ``DeltaBuffer`` (DESIGN.md §7) so the snapshot never
mutates -- adapting to updates or drift always swaps in a freshly built
snapshot atomically (launch/wisk_serve.py:LiveIndex).

Index-parallel serving (DESIGN.md §3.4): for indexes too large to
replicate, ``partition_index`` cuts the level-0 (root) forest into
``n_shards`` balanced sub-hierarchies and ``PartitionedSnapshot`` stacks
the per-shard slabs along axis 0 so one ``shard(mesh)`` placement call
splits the whole pytree over the mesh's ``index`` axis. Inside a
``shard_map`` body each device sees exactly its own slab, and
``local_view()`` re-wraps it as an ordinary ``IndexSnapshot`` -- the
engine's descent runs unchanged per shard (launch/wisk_serve.py:
``serve_index_sharded`` / ``serve_knn_index_sharded``).

Host-only vs traced: ``IndexSnapshot.build`` and ``.replicate`` run on
host; the snapshot's arrays are consumed inside jit-traced descents.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.query import padded_child_table, round_up_bucket
from ..core.types import GeoTextDataset, WiskIndex
from ..kernels.ops import NEVER_RECT


# int16 code capacity per coordinate dictionary: levels whose distinct
# coordinate count exceeds this are served on the f32 planes instead
NARROW_DICT_MAX = 32767

# leaf-local vocabulary capacity: if any leaf's distinct-term count exceeds
# this, the compact leaf bank is not built and verify serves the full-width
# (K, OBJ, W) slab instead (the same disable-on-overflow contract as
# NARROW_DICT_MAX for the int16 MBR planes)
LEAF_DICT_MAX = 32768


def encode_leaf_vocab(leaf_obj_bm, cap: int = LEAF_DICT_MAX):
    """Re-encode the leaf object bitmaps against per-leaf sorted vocabularies.

    Per leaf, the dictionary is the sorted distinct set of global term ids
    present in ANY of the leaf's objects; each object's bitmap is re-packed
    over leaf-LOCAL bit positions into ``Wl`` u32 words, with ``Wl`` the
    power-of-two bucket of the widest leaf's word count. Because every
    object's term set is a subset of its leaf's dictionary, intersecting a
    query's remapped words with the compact slab is EXACTLY the global-width
    test (DESIGN.md §3.5) -- query terms outside the dictionary simply have
    no local bit, and they could not have matched this leaf's objects anyway.

    Returns ``(leaf_terms, leaf_obj_cbm, leaf_obj_sig)``:

    * ``leaf_terms``  (K, 32*Wl) i32 -- global term id per local bit, -1 pad
      (the query-remap gather table);
    * ``leaf_obj_cbm`` (K, OBJ, Wl) u32 -- the compact object bitmap slab;
    * ``leaf_obj_sig`` (K, OBJ) u32 -- per-object OR-fold of the Wl words,
      the one-word signature prefilter tested before the word loop.

    or ``(None, None, None)`` when any leaf's dictionary would exceed
    ``cap`` (serve on the full-width slab instead). Host-only.
    """
    bm = np.asarray(leaf_obj_bm, np.uint32)
    K, OBJ, W = bm.shape
    shifts = np.arange(32, dtype=np.uint32)
    per_leaf = []
    max_terms = 1
    for c in range(K):
        union = np.bitwise_or.reduce(bm[c], axis=0)  # (W,)
        terms = np.flatnonzero(
            ((union[:, None] >> shifts) & 1).reshape(-1)
        ).astype(np.int32)
        if terms.size > cap:
            return None, None, None
        per_leaf.append(terms)
        max_terms = max(max_terms, int(terms.size))
    need = -(-max_terms // 32)
    Wl = 1 << (need - 1).bit_length()  # power-of-two word count, min 1
    leaf_terms = np.full((K, 32 * Wl), -1, np.int32)
    cbm = np.zeros((K, OBJ, Wl), np.uint32)
    for c in range(K):
        terms = per_leaf[c]
        leaf_terms[c, : terms.size] = terms
        if terms.size == 0:
            continue
        obits = ((bm[c][:, :, None] >> shifts) & 1).reshape(OBJ, W * 32)
        local = np.zeros((OBJ, Wl * 32), np.uint32)
        local[:, : terms.size] = obits[:, terms]
        cbm[c] = np.bitwise_or.reduce(
            local.reshape(OBJ, Wl, 32) << shifts, axis=-1
        )
    sig = np.bitwise_or.reduce(cbm, axis=-1)  # (K, OBJ)
    return jnp.asarray(leaf_terms), jnp.asarray(cbm), jnp.asarray(sig)


def encode_mbr_planes(level_mbrs):
    """Rank-encode per-level MBR planes into int16 codes + f32 dictionaries.

    Per level, the x dictionary is the sorted distinct set of {xlo, xhi}
    values (y likewise) and each MBR coordinate is replaced by its rank --
    ``dict[code]`` reconstructs the exact f32 value, so descending on the
    codes is lossless (the "never prunes a node the f32 descent keeps"
    guarantee holds with equality). Returns ``(codes, dicts_x, dicts_y)``
    as parallel per-level lists, or three empty lists when any level's
    dictionary would overflow the int16 code space (``NARROW_DICT_MAX``).
    Host-only (snapshot construction time).
    """
    codes, dicts_x, dicts_y = [], [], []
    for m in level_mbrs:
        m = np.asarray(m, np.float32)
        dx = np.unique(m[:, [0, 2]])
        dy = np.unique(m[:, [1, 3]])
        if dx.size > NARROW_DICT_MAX or dy.size > NARROW_DICT_MAX:
            return [], [], []
        c = np.stack(
            [
                np.searchsorted(dx, m[:, 0]),
                np.searchsorted(dy, m[:, 1]),
                np.searchsorted(dx, m[:, 2]),
                np.searchsorted(dy, m[:, 3]),
            ],
            axis=1,
        ).astype(np.int16)
        codes.append(jnp.asarray(c))
        dicts_x.append(jnp.asarray(dx.astype(np.float32)))
        dicts_y.append(jnp.asarray(dy.astype(np.float32)))
    return codes, dicts_x, dicts_y


@dataclasses.dataclass(frozen=True, eq=False)
class IndexSnapshot:
    """Immutable device-resident arrays for batched serving over a WiskIndex.

    All array fields are pytree leaves; ``obj_per_leaf`` is static aux data
    (it is a compiled-shape parameter, not traced data).
    """

    level_mbrs: List[jnp.ndarray]  # per level: (n, 4) f32
    level_bms: List[jnp.ndarray]  # per level: (n, W) u32
    # CSR children per non-leaf level, padded-table form (frontier path)
    child_table: List[jnp.ndarray]  # (n_up, max_fanout) int32, -1 padded
    child_counts: List[jnp.ndarray]  # (n_up,) int32
    # dense adjacency per non-leaf level (A/B dense path; [] if not built)
    child_matrix: List[jnp.ndarray]  # (n_up, n_down) int8
    leaf_obj_x: jnp.ndarray  # (K, OBJ) padded per-leaf object blocks
    leaf_obj_y: jnp.ndarray
    leaf_obj_bm: jnp.ndarray  # (K, OBJ, W)
    leaf_obj_id: jnp.ndarray  # (K, OBJ) int32, -1 pad
    obj_per_leaf: int
    # Bandwidth-lean shadow MBR planes (DESIGN.md §3.5): per level, int16
    # rank codes into the sorted distinct-coordinate dictionaries below.
    # Lossless -- dict[code] reconstructs the exact f32 coordinate -- so the
    # narrow descent's survivor set is bit-identical to the f32 planes'.
    # Empty lists when a level's dictionary would overflow int16 (the engine
    # then descends on the f32 planes).
    level_mbr_codes: List[jnp.ndarray] = dataclasses.field(default_factory=list)  # (n, 4) i16
    level_dict_x: List[jnp.ndarray] = dataclasses.field(default_factory=list)  # (Dx,) f32
    level_dict_y: List[jnp.ndarray] = dataclasses.field(default_factory=list)  # (Dy,) f32
    # Compact leaf verify bank (DESIGN.md §3.5): per-leaf sorted keyword
    # dictionaries + the object bitmaps re-packed over leaf-local bit ids
    # (encode_leaf_vocab). None when any leaf's vocabulary overflows
    # LEAF_DICT_MAX -- the engine then verifies on the full-width slab.
    leaf_terms: jnp.ndarray = None  # (K, 32*Wl) i32 global term per bit, -1 pad
    leaf_obj_cbm: jnp.ndarray = None  # (K, OBJ, Wl) u32 compact bitmaps
    leaf_obj_sig: jnp.ndarray = None  # (K, OBJ) u32 OR-fold signatures

    @property
    def n_levels(self) -> int:
        return len(self.level_mbrs)

    @property
    def n_leaves(self) -> int:
        return int(self.level_mbrs[-1].shape[0])

    @property
    def n_words(self) -> int:
        return int(self.level_bms[0].shape[1])

    @property
    def has_narrow_planes(self) -> bool:
        """True when every level carries int16 shadow MBR codes (the
        bandwidth-lean descent of DESIGN.md §3.5 is available)."""
        return len(self.level_mbr_codes) == len(self.level_mbrs) > 0

    @property
    def has_compact_bank(self) -> bool:
        """True when the leaf-local compact verify bank was built (no leaf
        vocabulary overflowed ``LEAF_DICT_MAX``; DESIGN.md §3.5)."""
        return self.leaf_obj_cbm is not None

    @property
    def n_compact_words(self) -> int:
        """Wl: u32 words per object in the compact leaf bank (static)."""
        return int(self.leaf_obj_cbm.shape[2])

    def root_width(self) -> int:
        """Bucketed width of the root frontier (static)."""
        return round_up_bucket(int(self.level_mbrs[0].shape[0]))

    def replicate(self, mesh) -> "IndexSnapshot":
        """The snapshot fully replicated over ``mesh`` (one device_put of the
        whole pytree with a single ``P()`` NamedSharding)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(self, NamedSharding(mesh, P()))

    @staticmethod
    def build(
        index: WiskIndex, dataset: GeoTextDataset, dense: bool = False
    ) -> "IndexSnapshot":
        """Freeze a host-side ``WiskIndex`` into the device-resident pytree
        (host-only; the returned snapshot's arrays feed jit-traced descents).

        Args:
            index: the assembled index (``core.index.assemble_index``).
            dataset: the object collection backing the leaf blocks.
            dense: additionally materialize the O(n_up * n_down) child
                matrices the A/B ``mode="dense"`` path needs; the default
                frontier path only builds the CSR arrays.

        Returns:
            An ``IndexSnapshot`` whose leaf object blocks are padded to the
            power-of-two bucket of the largest cluster (``obj_per_leaf``),
            object ids ``-1``-padded.
        """
        mbrs = [jnp.asarray(l.mbrs) for l in index.levels]
        bms = [jnp.asarray(l.bitmaps) for l in index.levels]
        child_table, child_counts, child_matrix = [], [], []
        for li in range(len(index.levels) - 1):
            l = index.levels[li]
            child_table.append(jnp.asarray(padded_child_table(l)))
            child_counts.append(jnp.asarray(np.diff(l.child_ptr), jnp.int32))
            if dense:
                n_down = index.levels[li + 1].n
                m = np.zeros((l.n, n_down), dtype=np.int8)
                for u in range(l.n):
                    m[u, l.child[l.child_ptr[u] : l.child_ptr[u + 1]]] = 1
                child_matrix.append(jnp.asarray(m))
        clusters = index.clusters
        sizes = np.diff(clusters.offsets)
        OBJ = round_up_bucket(int(sizes.max()))
        K = clusters.k
        W = dataset.words
        ox = np.zeros((K, OBJ), np.float32)
        oy = np.zeros((K, OBJ), np.float32)
        obm = np.zeros((K, OBJ, W), np.uint32)
        oid = np.full((K, OBJ), -1, np.int32)
        for c in range(K):
            ids = clusters.order[clusters.offsets[c] : clusters.offsets[c + 1]]
            ox[c, : ids.size] = dataset.locs[ids, 0]
            oy[c, : ids.size] = dataset.locs[ids, 1]
            obm[c, : ids.size] = dataset.kw_bitmap[ids]
            oid[c, : ids.size] = ids
        codes, dicts_x, dicts_y = encode_mbr_planes([l.mbrs for l in index.levels])
        lterms, lcbm, lsig = encode_leaf_vocab(obm)
        return IndexSnapshot(
            level_mbrs=mbrs,
            level_bms=bms,
            child_table=child_table,
            child_counts=child_counts,
            child_matrix=child_matrix,
            leaf_obj_x=jnp.asarray(ox),
            leaf_obj_y=jnp.asarray(oy),
            leaf_obj_bm=jnp.asarray(obm),
            leaf_obj_id=jnp.asarray(oid),
            obj_per_leaf=OBJ,
            level_mbr_codes=codes,
            level_dict_x=dicts_x,
            level_dict_y=dicts_y,
            leaf_terms=lterms,
            leaf_obj_cbm=lcbm,
            leaf_obj_sig=lsig,
        )


_ARRAY_FIELDS = (
    "level_mbrs",
    "level_bms",
    "child_table",
    "child_counts",
    "child_matrix",
    "leaf_obj_x",
    "leaf_obj_y",
    "leaf_obj_bm",
    "leaf_obj_id",
    "level_mbr_codes",
    "level_dict_x",
    "level_dict_y",
    "leaf_terms",
    "leaf_obj_cbm",
    "leaf_obj_sig",
)


def _snapshot_flatten(s: IndexSnapshot):
    return tuple(getattr(s, f) for f in _ARRAY_FIELDS), (s.obj_per_leaf,)


def _snapshot_unflatten(aux, children) -> IndexSnapshot:
    kw = dict(zip(_ARRAY_FIELDS, children))
    return IndexSnapshot(obj_per_leaf=aux[0], **kw)


jax.tree_util.register_pytree_node(
    IndexSnapshot, _snapshot_flatten, _snapshot_unflatten
)


def tree_nbytes(tree) -> int:
    """Total bytes of a pytree's array leaves (host-only; bench/telemetry)."""
    return int(
        sum(
            np.prod(x.shape) * jnp.dtype(x.dtype).itemsize
            for x in jax.tree_util.tree_leaves(tree)
            if hasattr(x, "shape")
        )
    )


# ------------------------------------------------ index-parallel partitioning
@dataclasses.dataclass(frozen=True, eq=False)
class IndexPartition:
    """Host-side cut of the level-0 (root) forest into shard-local subtrees.

    Each root subtree is assigned whole to one shard (greedy LPT on subtree
    leaf counts, deterministic tie-breaks), so every shard's node set is
    closed under the child relation and its sub-hierarchy is a self-contained
    index. ``nodes[li][s]`` lists shard ``s``'s global node ids at level
    ``li`` (sorted ascending -- local id order IS global id order within a
    shard, which the engine's smallest-id tie-breaks rely on);
    ``shard_of``/``local_of`` are the per-level inverse maps. Host-only.
    """

    n_shards: int
    root_to_shard: np.ndarray  # (n_root,) owning shard per root subtree
    nodes: List[List[np.ndarray]]  # [li][s] sorted global node ids
    shard_of: List[np.ndarray]  # [li] (n_li,) owning shard per node
    local_of: List[np.ndarray]  # [li] (n_li,) local index within the shard
    level_pads: Tuple[int, ...]  # stacked per-shard slab height per level
    n_leaves: int  # global leaf count

    @property
    def leaf_pad(self) -> int:
        return self.level_pads[-1]


def partition_index(snap: IndexSnapshot, n_shards: int) -> IndexPartition:
    """Cut ``snap``'s root forest into ``n_shards`` balanced subtree groups.

    Greedy LPT: roots are sorted by descending subtree leaf count (ties:
    smallest root id) and each is assigned to the currently lightest shard
    (ties: lowest shard id) -- deterministic, and within ~max-subtree of the
    optimal balance. Requires ``n_root >= n_shards`` (the level-0 forest is
    the cut line; WISK roots are wide by construction). Host-only.
    """
    L = snap.n_levels
    n_root = int(snap.level_mbrs[0].shape[0])
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_root < n_shards:
        raise ValueError(
            f"cannot cut {n_root} root subtrees into {n_shards} shards; "
            "rebuild with a wider root forest or fewer index shards"
        )
    table = [np.asarray(t) for t in snap.child_table]
    # per-root per-level membership by BFS down the CSR tables
    members: List[List[np.ndarray]] = []
    for r in range(n_root):
        per_level = [np.array([r], np.int64)]
        for li in range(L - 1):
            rows = table[li][per_level[-1]]
            per_level.append(np.sort(rows[rows >= 0]).astype(np.int64))
        members.append(per_level)
    weights = [int(m[-1].size) for m in members]
    order = sorted(range(n_root), key=lambda r: (-weights[r], r))
    load = [0] * n_shards
    root_to_shard = np.zeros(n_root, np.int64)
    for r in order:
        s = min(range(n_shards), key=lambda i: (load[i], i))
        root_to_shard[r] = s
        load[s] += weights[r]
    nodes: List[List[np.ndarray]] = []
    for li in range(L):
        row = []
        for s in range(n_shards):
            ms = [members[r][li] for r in range(n_root) if root_to_shard[r] == s]
            row.append(
                np.sort(np.concatenate(ms)).astype(np.int64)
                if ms
                else np.zeros(0, np.int64)
            )
        nodes.append(row)
    shard_of, local_of = [], []
    for li in range(L):
        n_li = int(snap.level_mbrs[li].shape[0])
        so = np.full(n_li, -1, np.int64)
        lo = np.full(n_li, -1, np.int64)
        for s in range(n_shards):
            so[nodes[li][s]] = s
            lo[nodes[li][s]] = np.arange(nodes[li][s].size)
        shard_of.append(so)
        local_of.append(lo)
    level_pads = tuple(
        max(nodes[li][s].size for s in range(n_shards)) for li in range(L)
    )
    return IndexPartition(
        n_shards=n_shards,
        root_to_shard=root_to_shard,
        nodes=nodes,
        shard_of=shard_of,
        local_of=local_of,
        level_pads=level_pads,
        n_leaves=snap.n_leaves,
    )


def _stack_shard_rows(arr: np.ndarray, ids_per_shard, pad_to: int, fill):
    """Stack per-shard row subsets of ``arr`` into one (S*pad_to, ...) slab.

    Pad rows get ``fill`` (scalar, or a per-column row like ``NEVER_RECT``).
    Host-only partitioning helper: axis 0 of the result is the ``index``
    mesh axis's sharded dimension.
    """
    S = len(ids_per_shard)
    out = np.empty((S * pad_to, *arr.shape[1:]), arr.dtype)
    out[:] = fill
    for s, ids in enumerate(ids_per_shard):
        out[s * pad_to : s * pad_to + ids.size] = arr[ids]
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class PartitionedSnapshot:
    """The index cut into shard-local sub-hierarchies, stacked for shard_map.

    Every per-node / per-leaf array of the base ``IndexSnapshot`` is
    re-laid-out as ``(n_shards * pad, ...)``: shard ``s``'s slab occupies
    rows ``[s*pad, (s+1)*pad)``, padded with inert rows (``NEVER_RECT``
    MBRs, zero bitmaps, ``-1`` ids). Child tables hold shard-LOCAL ids, so
    each slab is a closed sub-hierarchy; ``leaf_obj_id`` keeps GLOBAL object
    ids and ``root_gid``/``leaf_gid`` map local node slots back to global
    ids (the collectives' tie-break currency). The narrow int16 shadow
    planes are re-encoded per shard against shard-local coordinate
    dictionaries (still lossless; disabled for the whole partition if any
    shard's dictionary overflows ``NARROW_DICT_MAX``).

    ``shard(mesh)`` places the pytree with every leaf split over the mesh's
    ``index`` axis (logical axis ``"leaf"`` in sharding/rules.py), so inside
    ``shard_map`` (in_spec prefix ``P("index")``) each device holds exactly
    its own slab and ``local_view()`` re-wraps it as a plain
    ``IndexSnapshot`` for the unchanged engine descent.
    """

    level_mbrs: List[jnp.ndarray]  # per level: (S*Np, 4) f32
    level_bms: List[jnp.ndarray]  # per level: (S*Np, W) u32
    child_table: List[jnp.ndarray]  # (S*Np, fan) i32, shard-LOCAL child ids
    child_counts: List[jnp.ndarray]  # (S*Np,) i32
    leaf_obj_x: jnp.ndarray  # (S*Kp, OBJ) f32
    leaf_obj_y: jnp.ndarray
    leaf_obj_bm: jnp.ndarray  # (S*Kp, OBJ, W) u32
    leaf_obj_id: jnp.ndarray  # (S*Kp, OBJ) i32 GLOBAL object ids, -1 pad
    root_gid: jnp.ndarray  # (S*Np0,) i32 global node id per local root, -1 pad
    leaf_gid: jnp.ndarray  # (S*Kp,) i32 global leaf id per local leaf, -1 pad
    level_counts: jnp.ndarray  # (S, L) i32 real node count per (shard, level)
    obj_per_leaf: int
    n_shards: int
    part: IndexPartition  # host-side cut (aux; hashable by identity)
    # per-shard narrow planes (DESIGN.md §3.5); empty lists when disabled
    level_mbr_codes: List[jnp.ndarray] = dataclasses.field(default_factory=list)
    level_dict_x: List[jnp.ndarray] = dataclasses.field(default_factory=list)  # (S*Dx,)
    level_dict_y: List[jnp.ndarray] = dataclasses.field(default_factory=list)
    # compact leaf verify bank (leaf-local dictionaries ARE shard-local --
    # stacking just selects each shard's leaf rows, Wl stays global); None
    # when the base snapshot has no compact bank
    leaf_terms: jnp.ndarray = None  # (S*Kp, 32*Wl) i32, -1 pad
    leaf_obj_cbm: jnp.ndarray = None  # (S*Kp, OBJ, Wl) u32
    leaf_obj_sig: jnp.ndarray = None  # (S*Kp, OBJ) u32

    @property
    def n_levels(self) -> int:
        return len(self.level_mbrs)

    @property
    def n_leaves_global(self) -> int:
        return self.part.n_leaves

    @property
    def has_narrow_planes(self) -> bool:
        return len(self.level_mbr_codes) == len(self.level_mbrs) > 0

    @property
    def has_compact_bank(self) -> bool:
        return self.leaf_obj_cbm is not None

    def local_root_width(self) -> int:
        """Bucketed width of one shard's root frontier (static)."""
        return round_up_bucket(self.part.level_pads[0])

    def per_shard_bytes(self) -> int:
        """Device-resident bytes per index shard (each device holds exactly
        one slab of every stacked array)."""
        return tree_nbytes(self) // self.n_shards

    def local_view(self) -> IndexSnapshot:
        """Re-wrap (inside a shard_map body) this device's slab as a plain
        ``IndexSnapshot``: after ``shard_map`` slices every leaf over the
        ``index`` axis, the arrays ARE one shard's self-contained
        sub-hierarchy, so the engine descends on them unchanged. Traced."""
        return IndexSnapshot(
            level_mbrs=self.level_mbrs,
            level_bms=self.level_bms,
            child_table=self.child_table,
            child_counts=self.child_counts,
            child_matrix=[],
            leaf_obj_x=self.leaf_obj_x,
            leaf_obj_y=self.leaf_obj_y,
            leaf_obj_bm=self.leaf_obj_bm,
            leaf_obj_id=self.leaf_obj_id,
            obj_per_leaf=self.obj_per_leaf,
            level_mbr_codes=self.level_mbr_codes,
            level_dict_x=self.level_dict_x,
            level_dict_y=self.level_dict_y,
            leaf_terms=self.leaf_terms,
            leaf_obj_cbm=self.leaf_obj_cbm,
            leaf_obj_sig=self.leaf_obj_sig,
        )

    def shard(self, mesh) -> "PartitionedSnapshot":
        """Place the partition over ``mesh``: one ``device_put`` of the whole
        pytree with every array split along axis 0 over the ``index`` mesh
        axis (logical axis ``"leaf"``) -- the index-parallel sibling of
        ``IndexSnapshot.replicate``. Each device ends up holding only its
        own ~1/n_shards slab."""
        from ..sharding.rules import named_sharding

        return jax.device_put(self, named_sharding(mesh, ("leaf",)))

    @staticmethod
    def build(snap: IndexSnapshot, n_shards: int) -> "PartitionedSnapshot":
        """Partition a built ``IndexSnapshot`` into ``n_shards`` stacked
        shard-local sub-hierarchies (host-only; see ``partition_index``)."""
        part = partition_index(snap, n_shards)
        L = snap.n_levels
        S = n_shards
        pads = part.level_pads
        never = np.asarray(NEVER_RECT, np.float32)
        level_mbrs, level_bms, child_table, child_counts = [], [], [], []
        for li in range(L):
            ids = part.nodes[li]
            m = np.asarray(snap.level_mbrs[li], np.float32)
            level_mbrs.append(jnp.asarray(_stack_shard_rows(m, ids, pads[li], never)))
            b = np.asarray(snap.level_bms[li])
            level_bms.append(jnp.asarray(_stack_shard_rows(b, ids, pads[li], 0)))
            if li < L - 1:
                tbl = np.asarray(snap.child_table[li])
                stacked = _stack_shard_rows(tbl, ids, pads[li], -1)
                # remap global child ids -> shard-local ids (children live in
                # the parent's shard: subtrees are assigned whole)
                loc = part.local_of[li + 1][np.clip(stacked, 0, None)]
                child_table.append(
                    jnp.asarray(np.where(stacked >= 0, loc, -1).astype(np.int32))
                )
                cc = np.asarray(snap.child_counts[li])
                child_counts.append(
                    jnp.asarray(_stack_shard_rows(cc, ids, pads[li], 0))
                )
        leaf_ids = part.nodes[L - 1]
        Kp = pads[L - 1]
        leaf_obj_x = _stack_shard_rows(np.asarray(snap.leaf_obj_x), leaf_ids, Kp, 0.0)
        leaf_obj_y = _stack_shard_rows(np.asarray(snap.leaf_obj_y), leaf_ids, Kp, 0.0)
        leaf_obj_bm = _stack_shard_rows(np.asarray(snap.leaf_obj_bm), leaf_ids, Kp, 0)
        leaf_obj_id = _stack_shard_rows(np.asarray(snap.leaf_obj_id), leaf_ids, Kp, -1)
        lt = lcbm = lsig = None
        if snap.has_compact_bank:
            lt = jnp.asarray(_stack_shard_rows(
                np.asarray(snap.leaf_terms), leaf_ids, Kp, -1))
            lcbm = jnp.asarray(_stack_shard_rows(
                np.asarray(snap.leaf_obj_cbm), leaf_ids, Kp, 0))
            lsig = jnp.asarray(_stack_shard_rows(
                np.asarray(snap.leaf_obj_sig), leaf_ids, Kp, 0))
        gid_src = [np.arange(int(snap.level_mbrs[li].shape[0]), dtype=np.int32) for li in (0, L - 1)]
        root_gid = _stack_shard_rows(gid_src[0], part.nodes[0], pads[0], -1)
        leaf_gid = _stack_shard_rows(gid_src[1], leaf_ids, Kp, -1)
        level_counts = np.stack(
            [[part.nodes[li][s].size for li in range(L)] for s in range(S)]
        ).astype(np.int32)
        # per-shard narrow planes: re-encode against shard-local dictionaries
        codes_l, dx_l, dy_l = [], [], []
        narrow_ok = snap.has_narrow_planes
        if narrow_ok:
            per_level = []
            for li in range(L):
                m = np.asarray(snap.level_mbrs[li], np.float32)
                row = []
                for s in range(S):
                    ml = m[part.nodes[li][s]]
                    dx = np.unique(ml[:, [0, 2]])
                    dy = np.unique(ml[:, [1, 3]])
                    if dx.size > NARROW_DICT_MAX or dy.size > NARROW_DICT_MAX:
                        narrow_ok = False
                        break
                    c = np.stack(
                        [
                            np.searchsorted(dx, ml[:, 0]),
                            np.searchsorted(dy, ml[:, 1]),
                            np.searchsorted(dx, ml[:, 2]),
                            np.searchsorted(dy, ml[:, 3]),
                        ],
                        axis=1,
                    ).astype(np.int16)
                    row.append((c, dx.astype(np.float32), dy.astype(np.float32)))
                if not narrow_ok:
                    break
                per_level.append(row)
        if narrow_ok:
            for li in range(L):
                row = per_level[li]
                cp = np.zeros((S * pads[li], 4), np.int16)
                Dx = max(r[1].size for r in row)
                Dy = max(r[2].size for r in row)
                dxp = np.zeros((S * Dx,), np.float32)
                dyp = np.zeros((S * Dy,), np.float32)
                for s, (c, dx, dy) in enumerate(row):
                    cp[s * pads[li] : s * pads[li] + c.shape[0]] = c
                    # pad dictionaries by repeating the last entry: pad slots
                    # are never addressed by a real (in-range) code
                    dxp[s * Dx : (s + 1) * Dx] = np.pad(dx, (0, Dx - dx.size), mode="edge")
                    dyp[s * Dy : (s + 1) * Dy] = np.pad(dy, (0, Dy - dy.size), mode="edge")
                codes_l.append(jnp.asarray(cp))
                dx_l.append(jnp.asarray(dxp))
                dy_l.append(jnp.asarray(dyp))
        return PartitionedSnapshot(
            level_mbrs=level_mbrs,
            level_bms=level_bms,
            child_table=child_table,
            child_counts=child_counts,
            leaf_obj_x=jnp.asarray(leaf_obj_x),
            leaf_obj_y=jnp.asarray(leaf_obj_y),
            leaf_obj_bm=jnp.asarray(leaf_obj_bm),
            leaf_obj_id=jnp.asarray(leaf_obj_id),
            root_gid=jnp.asarray(root_gid),
            leaf_gid=jnp.asarray(leaf_gid),
            level_counts=jnp.asarray(level_counts),
            obj_per_leaf=snap.obj_per_leaf,
            n_shards=S,
            part=part,
            level_mbr_codes=codes_l,
            level_dict_x=dx_l,
            level_dict_y=dy_l,
            leaf_terms=lt,
            leaf_obj_cbm=lcbm,
            leaf_obj_sig=lsig,
        )


_PSNAP_ARRAY_FIELDS = (
    "level_mbrs",
    "level_bms",
    "child_table",
    "child_counts",
    "leaf_obj_x",
    "leaf_obj_y",
    "leaf_obj_bm",
    "leaf_obj_id",
    "root_gid",
    "leaf_gid",
    "level_counts",
    "level_mbr_codes",
    "level_dict_x",
    "level_dict_y",
    "leaf_terms",
    "leaf_obj_cbm",
    "leaf_obj_sig",
)


def _psnap_flatten(s: PartitionedSnapshot):
    children = tuple(getattr(s, f) for f in _PSNAP_ARRAY_FIELDS)
    return children, (s.obj_per_leaf, s.n_shards, s.part)


def _psnap_unflatten(aux, children) -> PartitionedSnapshot:
    kw = dict(zip(_PSNAP_ARRAY_FIELDS, children))
    return PartitionedSnapshot(
        obj_per_leaf=aux[0], n_shards=aux[1], part=aux[2], **kw
    )


jax.tree_util.register_pytree_node(
    PartitionedSnapshot, _psnap_flatten, _psnap_unflatten
)
