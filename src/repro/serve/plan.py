"""Plan layer: batch bucketing/padding and the frontier width discipline.

Serving an ``IndexSnapshot`` needs two kinds of *planning state* that are
not index data (DESIGN.md §3.2 / §3.4):

* **Batch bucketing.** Incoming query batches are padded to power-of-two
  buckets (optionally per data-parallel shard) with inert pad queries, so
  jitted descents retrace at most log2(max batch) times ever.
* **Execution plans.** Each frontier descent runs at per-level expansion
  widths. ``PlanCache`` owns the monotone per-(path, level) width cache --
  serving state, deliberately kept out of the frozen ``IndexSnapshot``; it
  hands the executors an immutable ``ExecutionPlan`` per descent and
  absorbs the observed per-level child-count maxima afterwards. The cache
  is shared by the SKR range path (tag ``"skr"``), the kNN path (tag
  ``"knn"``), and the distributed front doors (launch/wisk_serve.py),
  which key their own tags.

Width discipline (unchanged semantics, new ownership):

* ``plan.widths is None`` -- *exact* mode: the descent blocks on each
  level's batch-max child count (one host sync per level) and the caller
  grows the cache from those host ints. First descent of a path only.
* ``plan.widths = (w0, w1, ...)`` -- *cached* mode: the descent runs
  sync-free at the cached widths and records per-level device maxima; ONE
  batched device->host fetch checks them all at the end. Overflow (a width
  was too narrow: children were dropped) triggers a lossless exact retry.
  Monotone power-of-two growth bounds retries and recompiles at
  log2(level width) per (path, level) for the lifetime of the process.

The sharded path cannot host-sync per level inside ``shard_map``; it uses
``seeded_plan`` (missing widths start at the minimum bucket) and loops
grow-and-redescend to the fixed point -- see launch/wisk_serve.py.

Host-only vs traced: every function in this module runs on host --
``PlanCache`` methods between descents, the padding helpers before them.
Only ``ExecutionPlan.pick_width`` executes *during* a descent, and in
cached mode it stays trace-friendly (it records device scalars without
blocking; exact mode is the one deliberate host sync per level).
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.query import round_up_bucket, sharded_bucket
from ..kernels.ops import NEVER_RECT

MIN_WIDTH_BUCKET = 8


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """One descent's resolved widths: ``widths=None`` is exact (per-level
    sync) mode, a tuple is the sync-free cached mode."""

    tag: str
    widths: Optional[Tuple[int, ...]]

    def pick_width(self, need, li: int, needs: List) -> int:
        """Per-level expansion width under the shared sync discipline: exact
        mode blocks on the batch max and buckets it; cached mode records the
        max as a device scalar for the single batched overflow check."""
        if self.widths is None:
            mx = int(jnp.max(need))
            needs.append(mx)
            return round_up_bucket(mx)
        needs.append(jnp.max(need))
        return self.widths[li]


class PlanCache:
    """Monotone per-(path tag, level) frontier expansion widths.

    ``widths`` is a plain dict keyed ``(tag, level) -> int`` (public: tests
    poison it to exercise the lossless overflow retry).
    """

    def __init__(self) -> None:
        self.widths: Dict[Tuple[str, int], int] = {}

    def plan(self, tag: str, n_links: int) -> ExecutionPlan:
        """Cached-mode plan if every level's width is learned, else exact."""
        ws = [self.widths.get((tag, li)) for li in range(n_links)]
        if any(w is None for w in ws):
            return ExecutionPlan(tag=tag, widths=None)
        return ExecutionPlan(tag=tag, widths=tuple(ws))  # type: ignore[arg-type]

    def seeded_plan(
        self, tag: str, n_links: int, minimum: int = MIN_WIDTH_BUCKET
    ) -> ExecutionPlan:
        """Always-concrete widths (unlearned levels seeded at ``minimum``):
        the shard_map'd descents trace at static widths and converge by
        grow-and-retry instead of per-level syncs."""
        return ExecutionPlan(
            tag=tag,
            widths=tuple(self.widths.get((tag, li), minimum) for li in range(n_links)),
        )

    def seeded_shard_plan(
        self, tag: str, n_shards: int, n_links: int,
        minimum: int = MIN_WIDTH_BUCKET,
    ) -> ExecutionPlan:
        """Per-shard width cache for the index-sharded descents: shard ``s``
        learns under the sub-tag ``f"{tag}/s{s}"``, but every shard of one
        shard_map'd descent must trace the SAME static widths (SPMD), so the
        plan's per-level width is the max over the shard slots. A hot shard
        widens the others' frontiers (pad slots are inert) without a second
        shape family per shard."""
        ws = []
        for li in range(n_links):
            ws.append(max(
                self.widths.get((f"{tag}/s{s}", li), minimum)
                for s in range(n_shards)
            ))
        return ExecutionPlan(tag=tag, widths=tuple(ws))

    def observe_shards(self, tag: str, per_shard_maxima) -> None:
        """Grow the per-shard slots from an (S, n_links) matrix of observed
        child-count maxima (one row per index shard)."""
        per_shard_maxima = np.asarray(per_shard_maxima)
        for s in range(per_shard_maxima.shape[0]):
            self.observe(f"{tag}/s{s}", per_shard_maxima[s])

    def observe(self, tag: str, maxima: Sequence[int]) -> None:
        """Monotone growth from observed per-level child-count maxima keeps
        the compiled shape family log-bounded: each (tag, level) slot can
        only double, at most log2(level width) times."""
        for li, mx in enumerate(maxima):
            w = round_up_bucket(int(mx))
            if w > self.widths.get((tag, li), 0):
                self.widths[(tag, li)] = w

    def check_and_retry(
        self, plan: ExecutionPlan, needs: Sequence, descend: Callable
    ):
        """The single batched sync of a cached-width descent: fetch all
        levels' observed child-count maxima at once; on overflow re-descend
        in exact mode (``descend(exact_plan)``) so the result stays lossless,
        and grow the cache either way. Returns the retried descent output or
        None when the original descent stands."""
        if plan.widths is None:
            self.observe(plan.tag, needs)  # exact descent: needs are host ints
            return None
        if needs:
            maxima = np.asarray(jax.device_get(jnp.stack(list(needs))))
            if np.any(maxima > np.asarray(plan.widths)):
                self.observe(plan.tag, maxima)
                out = descend(ExecutionPlan(tag=plan.tag, widths=None))
                self.observe(plan.tag, out[-1])
                return out
        return None


# Convenience registry for callers that don't manage planning state
# explicitly: one PlanCache per live snapshot, weakly keyed so dropping the
# snapshot drops its learned widths too. Executors fall back to this when no
# cache is passed; the distributed front doors always pass one explicitly.
_DEFAULT_PLANS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def default_plan_cache(snapshot) -> PlanCache:
    """The per-snapshot fallback ``PlanCache`` (host-only): one cache per
    live snapshot, created on first use, dropped with the snapshot."""
    cache = _DEFAULT_PLANS.get(snapshot)
    if cache is None:
        cache = PlanCache()
        _DEFAULT_PLANS[snapshot] = cache
    return cache


# ------------------------------------------------------------ batch padding
def pad_queries_to_bucket(q_rects, q_bm, minimum: int = 8, shards: int = 1):
    """Pad an incoming query batch to its power-of-two bucket (host-only).

    Args:
        q_rects: (m, 4) f32 query rectangles ``(xlo, ylo, xhi, yhi)``.
        q_bm: (m, W) u32 query keyword bitmaps.
        minimum: smallest bucket size.
        shards: pad to ``shards`` equal power-of-two buckets so the batch
            splits evenly over a data-parallel mesh axis.

    Returns:
        ``(rects, bms, m)``: the padded (bucket, 4)/(bucket, W) arrays plus
        the original batch size for slicing results.

    The frontier descent (serve.engine) retraces per (batch, frontier-width)
    shape; bucketing the batch dimension here -- like the planner buckets
    frontier widths -- keeps the set of compiled shapes logarithmic in the
    largest batch ever seen. Pad queries use never-intersecting rects and
    empty bitmaps, so they survive no filter and verify nothing.
    """
    q_rects = np.asarray(q_rects, np.float32)
    q_bm = np.asarray(q_bm, np.uint32)
    m = q_rects.shape[0]
    bucket = sharded_bucket(m, shards, minimum)
    if bucket == m:
        return q_rects, q_bm, m
    pad = bucket - m
    rects = np.concatenate(
        [q_rects, np.tile(np.array([NEVER_RECT], np.float32), (pad, 1))], 0
    )
    bms = np.concatenate([q_bm, np.zeros((pad, q_bm.shape[1]), np.uint32)], 0)
    return rects, bms, m


def pad_knn_queries_to_bucket(points, q_bm, minimum: int = 8, shards: int = 1):
    """kNN twin of ``pad_queries_to_bucket`` (host-only).

    Args:
        points: (m, 2) f32 query points; ``q_bm``: (m, W) u32 bitmaps.
        minimum / shards: as in ``pad_queries_to_bucket``.

    Returns:
        ``(points, bms, m)`` padded to the bucket, plus the original size.

    Pad queries are inert because their all-zero bitmap fails the keyword
    AND, so every frontier slot scores +inf -- they verify nothing and
    return all ``-1`` ids. (The out-of-square pad point is only defensive:
    distance alone would NOT exclude a pad query.)"""
    points = np.asarray(points, np.float32)
    q_bm = np.asarray(q_bm, np.uint32)
    m = points.shape[0]
    bucket = sharded_bucket(m, shards, minimum)
    if bucket == m:
        return points, q_bm, m
    pad = bucket - m
    pts = np.concatenate([points, np.full((pad, 2), 2.0, np.float32)], 0)
    bms = np.concatenate([q_bm, np.zeros((pad, q_bm.shape[1]), np.uint32)], 0)
    return pts, bms, m
