"""Continuous spatio-textual filter queries: pub-sub over the update stream.

WISK serves request/response SKR traffic; production geo-textual systems
also run the inverse problem (FAST, Mahmood et al.): *standing*
subscriptions -- geofences, alert filters, feed rules -- matched against a
stream of arriving objects. This module is that subsystem (DESIGN.md §8):

* ``SubscriptionBlock`` -- the device-resident compiled subscription index.
  Subscriptions become the indexed set: a padded power-of-two block of
  rects ``(S, 4)``, keyword bitmaps ``(S, W)`` and one-word OR-fold
  signatures ``(S, 1)``, grown by doubling with freed-slot reuse exactly
  like the ``DeltaBuffer`` insert buffers. Empty slots carry NEVER_RECT +
  a zero bitmap and are inert in the match kernel.
* ``SubscriptionIndex`` -- the host-side manager and notification log.
  ``subscribe``/``unsubscribe`` edit host mirrors and recompile the block
  lazily; ``match_arrivals`` matches a batch of arriving objects against
  the block on device (kernels/sub_match.py: packed object word planes +
  signature prefilter, cross-product tiles) and queues
  ``(object_id, subscription_id)`` notifications; ``drain()`` hands them
  out exactly once.

Exactly-once contract (pinned by tests/test_streaming_match.py and the
hypothesis suite): every live object id is matched against the block at
most once, guarded by a high-water mark over the *global object id space*
-- ``DeltaLog`` assigns ids monotonically (``base_n, base_n+1, ...``) and a
rebuild swap continues the same sequence (the merged dataset's row count
IS the old ``_next_id``), so the mark survives buffer growth, freed-slot
reuse (a reused slot holds a fresh, higher id), deletes, and
``LiveIndex.maybe_rebuild`` generation swaps without any per-slot state.
``pump(delta_log)`` -- the full-buffer sweep twin of the incremental
``match_arrivals`` hook -- relies on the same mark, so pumping after
incremental matching emits nothing new and the two paths produce identical
notification streams.

Stream semantics, matching ``core.query.SubscriptionOracle`` verbatim: a
subscription sees exactly the objects that arrive while it is live (no
retroactive delivery); deleting an object never retracts a queued
notification; an empty keyword set matches nothing (the Boolean contract
of an empty SKR query); a zero-area rect matches objects exactly at that
point. Notifications are queued in canonical (object id, subscription id)
order per batch.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.types import bitmap_words, ids_to_bitmap
from ..kernels.ops import NEVER_RECT, match_subscriptions

MIN_SUB_SLOTS = 8


@dataclasses.dataclass(frozen=True, eq=False)
class SubscriptionBlock:
    """Immutable device-resident compiled subscription index (§8).

    ``rects`` (S, 4) f32 / ``bm`` (S, W) u32 / ``sig`` (S, 1) u32 with S a
    power-of-two slot bucket; empty slots are NEVER_RECT + zero bitmap
    (signature 0), so the match kernel needs no validity plane. Registered
    as a pytree: the whole block rides through jitted match steps as one
    argument, like the snapshot and the delta buffer.
    """

    rects: jnp.ndarray
    bm: jnp.ndarray
    sig: jnp.ndarray

    @property
    def n_slots(self) -> int:
        return int(self.rects.shape[0])


jax.tree_util.register_pytree_node(
    SubscriptionBlock,
    lambda b: ((b.rects, b.bm, b.sig), None),
    lambda aux, ch: SubscriptionBlock(*ch),
)


class SubscriptionIndex:
    """Host-side manager of the standing-subscription set + notification log.

    Single-writer control plane, like ``DeltaLog``: ``subscribe`` /
    ``unsubscribe`` / ``match_arrivals`` / ``pump`` / ``drain`` are expected
    from one maintenance thread. The device block is compiled lazily and
    cached until the subscription set changes; its slot count only ever
    doubles (power-of-two shape discipline), so jitted match steps see
    O(log S) distinct subscription shapes.
    """

    def __init__(self, vocab_size: int, min_slots: int = MIN_SUB_SLOTS) -> None:
        self.vocab_size = int(vocab_size)
        self.n_words = bitmap_words(self.vocab_size)
        S = int(min_slots)
        self._rects = np.tile(np.asarray(NEVER_RECT, np.float32), (S, 1))
        self._bms = np.zeros((S, self.n_words), np.uint32)
        self._sub_id = np.full(S, -1, np.int32)
        self._slot = {}  # sub_id -> slot
        self._kw = {}  # sub_id -> keyword id array (oracle-comparable mirror)
        self._free: List[int] = []
        self._fill = 0
        self._next_sub = 0
        self._block: Optional[SubscriptionBlock] = None
        # exactly-once high-water mark over the global object id space
        self._seen_max = -1
        self._pending: List[Tuple[int, int]] = []
        self.emitted_total = 0
        self.matched_total = 0

    # ------------------------------------------------------------- editing
    @property
    def n_live(self) -> int:
        return len(self._slot)

    @property
    def n_slots(self) -> int:
        return self._rects.shape[0]

    def subscribe(self, rect, kw_ids) -> int:
        """Register a standing (rect, keyword) filter; returns its id.

        Matches only objects arriving from now on. Slots freed by
        ``unsubscribe`` are reused before the block grows (doubling), the
        same churn discipline as the delta insert buffers.
        """
        rect = np.asarray(rect, np.float32).reshape(4)
        kw = np.asarray(kw_ids, np.int64).reshape(-1)
        bm = ids_to_bitmap(kw.reshape(1, -1).astype(np.int32), self.vocab_size)[0]
        sid = self._next_sub
        self._next_sub += 1
        if self._free:
            slot = self._free.pop()
        else:
            slot = self._fill
            self._fill += 1
            if slot >= self.n_slots:
                grown = self.n_slots * 2
                self._rects = np.concatenate(
                    [self._rects,
                     np.tile(np.asarray(NEVER_RECT, np.float32), (grown - self.n_slots, 1))]
                )
                self._bms = np.concatenate(
                    [self._bms, np.zeros((grown // 2, self.n_words), np.uint32)]
                )
                self._sub_id = np.concatenate(
                    [self._sub_id, np.full(grown // 2, -1, np.int32)]
                )
        self._rects[slot] = rect
        self._bms[slot] = bm
        self._sub_id[slot] = sid
        self._slot[sid] = slot
        self._kw[sid] = kw
        self._block = None
        return sid

    def unsubscribe(self, sub_id: int) -> bool:
        """Retire a subscription; its slot becomes reusable. Notifications
        already queued for it stay queued (they matched while it was live);
        no object arriving after this can match it."""
        slot = self._slot.pop(int(sub_id), None)
        if slot is None:
            return False
        self._kw.pop(int(sub_id), None)
        self._rects[slot] = np.asarray(NEVER_RECT, np.float32)
        self._bms[slot] = 0
        self._sub_id[slot] = -1
        self._free.append(slot)
        self._block = None
        return True

    def block(self) -> SubscriptionBlock:
        """The compiled device block for the current subscription set
        (cached until the set changes)."""
        if self._block is None:
            self._block = SubscriptionBlock(
                rects=jnp.asarray(self._rects),
                bm=jnp.asarray(self._bms),
                sig=jnp.asarray(
                    np.bitwise_or.reduce(self._bms, axis=1).reshape(-1, 1)
                ),
            )
        return self._block

    # ------------------------------------------------------------ matching
    def _match(self, ids: np.ndarray, locs: np.ndarray, bms: np.ndarray) -> int:
        """Device-match pre-filtered arrivals and queue their notifications
        in canonical (object id, subscription id) order; advance the
        exactly-once mark. ``ids`` must all be above the current mark."""
        if ids.size == 0:
            return 0
        self._seen_max = max(self._seen_max, int(ids.max()))
        if not self._slot:
            return 0
        blk = self.block()
        mat = np.asarray(
            match_subscriptions(locs, bms, blk.rects, blk.bm, blk.sig[:, 0])
        )
        oi, sj = np.nonzero(mat)
        if oi.size == 0:
            return 0
        pairs = np.stack([ids[oi], self._sub_id[sj].astype(np.int64)], 1)
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
        self._pending.extend((int(o), int(s)) for o, s in pairs)
        self.matched_total += pairs.shape[0]
        return pairs.shape[0]

    def match_arrivals(self, ids, locs, kw_ids=None, bms=None) -> int:
        """Match one batch of arriving objects against the compiled block --
        the per-insert hook ``LiveIndex.insert`` runs in the same step the
        objects enter the ``DeltaLog``. Ids at or below the high-water mark
        were already matched and are skipped (exactly-once); the mark
        advances even when no subscription is live, so a later subscriber
        never retroactively sees these objects. Returns #queued."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        locs = np.asarray(locs, np.float32).reshape(-1, 2)
        if bms is None:
            bms = ids_to_bitmap(
                np.asarray(kw_ids, np.int32).reshape(ids.size, -1), self.vocab_size
            )
        bms = np.asarray(bms, np.uint32).reshape(ids.size, -1)
        keep = ids > self._seen_max
        if not keep.all():
            ids, locs, bms = ids[keep], locs[keep], bms[keep]
        order = np.argsort(ids, kind="stable")
        return self._match(ids[order], locs[order], bms[order])

    def pump(self, delta_log) -> int:
        """Full-buffer sweep: match every live buffered insert that the
        high-water mark has not covered yet. The batch-matching twin of
        ``match_arrivals`` -- after incremental matching it is a no-op, and
        driving a stream exclusively through ``pump`` yields the identical
        notification sequence (the differential harness checks both). Slots
        freed by deletes carry ``ins_id == -1`` and are skipped; buffer
        growth only pads with more ``-1`` slots, so a sweep after growth
        re-emits nothing. Returns #queued."""
        buf = delta_log.buffer
        ids = np.asarray(buf.ins_id, np.int64).reshape(-1)
        live = (ids >= 0) & (ids > self._seen_max)
        if not live.any():
            return 0
        locs = np.stack(
            [np.asarray(buf.ins_x).reshape(-1)[live],
             np.asarray(buf.ins_y).reshape(-1)[live]], 1
        )
        bms = np.asarray(buf.ins_bm).reshape(ids.size, -1)[live]
        ids = ids[live]
        order = np.argsort(ids, kind="stable")
        return self._match(ids[order], locs[order], bms[order])

    # ------------------------------------------------------- notifications
    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def drain(self) -> np.ndarray:
        """All queued (object_id, subscription_id) notifications, exactly
        once: a second drain (with no arrivals in between) returns an empty
        (0, 2) array."""
        out = np.asarray(self._pending, np.int64).reshape(-1, 2)
        self._pending = []
        self.emitted_total += out.shape[0]
        return out
