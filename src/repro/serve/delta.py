"""Delta layer: incremental object updates merged into serving on the fly.

The snapshot layer (serve/snapshot.py) is frozen by design -- every object
insert or delete would otherwise force a full ``IndexSnapshot.build``. This
module makes the serving stack *incremental* (DESIGN.md §7):

* ``DeltaBuffer`` -- the device-resident, pytree-registered delta state the
  jitted executors (serve/engine.py) merge into every descent:

  - per-leaf **insert buffers** ``ins_x/ins_y/ins_bm/ins_id`` shaped
    ``(K, B)`` (B = ``slots_per_leaf``, a power-of-two bucket): buffered
    objects are verified alongside the snapshot's leaf object blocks in the
    SKR verify stage and the kNN probe/leaf-chunk stages;
  - a **delete mask** ``base_alive`` shaped ``(K, OBJ)``: deleted snapshot
    objects are masked out of verification and the kNN top-k merge (their
    slots can never match); deleted *buffered* objects simply clear their
    ``ins_id`` slot to ``-1``;
  - per-level **augmented filter arrays** ``aug_mbrs``/``aug_bms``: copies
    of the snapshot's level MBRs/bitmaps widened along the ancestor path of
    every buffered insert, so the frontier/kNN descents cannot prune a node
    whose subtree holds a buffered match. Deletes never *shrink* them
    (conservative and therefore still exact -- filtering only prunes).

  Like the snapshot, a ``DeltaBuffer`` is immutable: updates produce a new
  buffer via functional ``.at[]`` scatters, and the whole buffer rides
  through ``jit``/``shard_map`` as one pytree argument (``None`` means "no
  deltas" and is itself a valid empty pytree).

* ``DeltaLog`` -- the host-side manager that owns the current buffer plus
  the host mirrors a rebuild needs: it routes each insert to its nearest
  leaf, widens the augmented arrays up the parent chain, tracks deleted
  ids, grows full leaf buffers by power-of-two doubling, and materializes
  ``merged_dataset()`` (base + inserts, deletes tombstoned) for the
  warm-start rebuild path (core/build.py:warm_start_rebuild).

Host-only vs traced: every ``DeltaLog`` method runs on host (updates are
serving control plane); the ``DeltaBuffer`` arrays are consumed inside
jitted descents. Id convention: buffered inserts get fresh global ids
``base_n, base_n+1, ...`` in arrival order, so a cold rebuild over
``merged_dataset()`` returns bit-identical result ids
(tests/test_delta_maintenance.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.query import _mbr_dist2_f32
from ..core.types import GeoTextDataset, WiskIndex, ids_to_bitmap
from .snapshot import IndexSnapshot

MIN_SLOTS_PER_LEAF = 8


@dataclasses.dataclass(frozen=True, eq=False)
class DeltaBuffer:
    """Immutable device-resident delta state merged by the executors.

    Shapes (K = leaves, B = ``slots_per_leaf``, OBJ = snapshot
    ``obj_per_leaf``, W = bitmap words):

    * ``aug_mbrs``/``aug_bms`` -- per level ``(n, 4)`` f32 / ``(n, W)`` u32,
      the snapshot level arrays widened by buffered inserts;
    * ``ins_x``/``ins_y`` -- ``(K, B)`` f32 buffered insert coordinates;
    * ``ins_bm`` -- ``(K, B, W)`` u32 buffered insert keyword bitmaps;
    * ``ins_id`` -- ``(K, B)`` i32 buffered insert object ids, ``-1`` =
      empty slot (also how a buffered object is deleted);
    * ``base_alive`` -- ``(K, OBJ)`` i8, ``0`` = snapshot object deleted;
    * ``ins_cbm``/``ins_sig`` -- optional ``(K, B, Wl)`` / ``(K, B)`` u32,
      each buffered insert's bitmap remapped into its leaf's compact
      vocabulary plus the OR-fold signature (DESIGN.md §3.5). Present only
      while every buffered term stayed inside its leaf's dictionary
      (``DeltaLog`` drops them -- one retrace -- the moment one does not;
      the executors then verify delta slots on the full-width ``ins_bm``).

    All array fields are pytree leaves; ``slots_per_leaf`` is static aux
    (a compiled-shape parameter). Registered as a pytree so a buffer is ONE
    argument through ``jit``/``shard_map`` and replicates over a mesh with a
    single ``P()`` prefix spec, exactly like the snapshot.
    """

    aug_mbrs: List[jnp.ndarray]
    aug_bms: List[jnp.ndarray]
    ins_x: jnp.ndarray
    ins_y: jnp.ndarray
    ins_bm: jnp.ndarray
    ins_id: jnp.ndarray
    base_alive: jnp.ndarray
    slots_per_leaf: int
    ins_cbm: jnp.ndarray = None  # (K, B, Wl) u32 leaf-local remapped bitmaps
    ins_sig: jnp.ndarray = None  # (K, B) u32 OR-fold signatures

    @property
    def n_levels(self) -> int:
        return len(self.aug_mbrs)

    def n_buffered(self) -> int:
        """Live buffered inserts (host sync; monitoring only)."""
        return int(jnp.sum(self.ins_id >= 0))

    def n_deleted(self) -> int:
        """Deleted snapshot objects (host sync; monitoring only)."""
        masked = jnp.sum(self.base_alive == 0)
        return int(masked)

    def replicate(self, mesh) -> "DeltaBuffer":
        """The buffer fully replicated over ``mesh`` (one ``device_put`` of
        the whole pytree with a single ``P()`` NamedSharding) -- the delta
        twin of ``IndexSnapshot.replicate``."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(self, NamedSharding(mesh, P()))

    @staticmethod
    def empty(snap: IndexSnapshot, slots_per_leaf: int = MIN_SLOTS_PER_LEAF) -> "DeltaBuffer":
        """An all-empty buffer over ``snap``: augmented arrays alias the
        (immutable) snapshot arrays, insert slots are empty, nothing is
        deleted. Serving with an empty buffer returns exactly the plain
        snapshot results."""
        K = snap.n_leaves
        W = snap.n_words
        B = int(slots_per_leaf)
        cbm = sig = None
        if snap.has_compact_bank:
            cbm = jnp.zeros((K, B, snap.n_compact_words), jnp.uint32)
            sig = jnp.zeros((K, B), jnp.uint32)
        return DeltaBuffer(
            aug_mbrs=list(snap.level_mbrs),
            aug_bms=list(snap.level_bms),
            ins_x=jnp.zeros((K, B), jnp.float32),
            ins_y=jnp.zeros((K, B), jnp.float32),
            ins_bm=jnp.zeros((K, B, W), jnp.uint32),
            ins_id=jnp.full((K, B), -1, jnp.int32),
            base_alive=jnp.ones((K, snap.obj_per_leaf), jnp.int8),
            slots_per_leaf=B,
            ins_cbm=cbm,
            ins_sig=sig,
        )

    def grown(self, new_slots: int) -> "DeltaBuffer":
        """The same buffer with the insert capacity padded to ``new_slots``
        (power-of-two growth: compiled shapes stay log-bounded, like every
        other width in the stack)."""
        if new_slots <= self.slots_per_leaf:
            return self
        pad = new_slots - self.slots_per_leaf
        cbm, sig = self.ins_cbm, self.ins_sig
        if cbm is not None:
            cbm = jnp.pad(cbm, ((0, 0), (0, pad), (0, 0)))
            sig = jnp.pad(sig, ((0, 0), (0, pad)))
        return dataclasses.replace(
            self,
            ins_x=jnp.pad(self.ins_x, ((0, 0), (0, pad))),
            ins_y=jnp.pad(self.ins_y, ((0, 0), (0, pad))),
            ins_bm=jnp.pad(self.ins_bm, ((0, 0), (0, pad), (0, 0))),
            ins_id=jnp.pad(self.ins_id, ((0, 0), (0, pad)), constant_values=-1),
            slots_per_leaf=new_slots,
            ins_cbm=cbm,
            ins_sig=sig,
        )


_DELTA_ARRAY_FIELDS = (
    "aug_mbrs",
    "aug_bms",
    "ins_x",
    "ins_y",
    "ins_bm",
    "ins_id",
    "base_alive",
    "ins_cbm",
    "ins_sig",
)


def _delta_flatten(d: DeltaBuffer):
    return tuple(getattr(d, f) for f in _DELTA_ARRAY_FIELDS), (d.slots_per_leaf,)


def _delta_unflatten(aux, children) -> DeltaBuffer:
    kw = dict(zip(_DELTA_ARRAY_FIELDS, children))
    return DeltaBuffer(slots_per_leaf=aux[0], **kw)


jax.tree_util.register_pytree_node(DeltaBuffer, _delta_flatten, _delta_unflatten)


def partition_delta(delta: DeltaBuffer, part) -> DeltaBuffer:
    """Route a replicated ``DeltaBuffer`` to the owning index shards.

    Returns a new ``DeltaBuffer`` whose rows follow the stacked
    ``PartitionedSnapshot`` layout for ``part`` (an ``IndexPartition``):
    level arrays become ``(S*pad_li, ...)`` with each shard's slice holding
    its own nodes' augmented MBRs/bitmaps (pads: never-intersecting rect,
    empty bitmap), insert buffers and the delete mask become ``(S*Kp, ...)``
    with each leaf's buffered inserts and alive mask living only on the
    shard that owns the leaf. Under the shard_map front doors the whole
    buffer shards with the same single ``P("index")`` prefix spec as the
    snapshot, so every shard merges exactly its own deltas (host-only;
    launch/wisk_serve.py memoizes the result per buffer).
    """
    from ..kernels.ops import NEVER_RECT
    from .snapshot import _stack_shard_rows

    L = delta.n_levels
    leaf_ids = part.nodes[L - 1]
    Kp = part.level_pads[L - 1]
    never = np.asarray(NEVER_RECT, np.float32)
    aug_mbrs = []
    aug_bms = []
    for li in range(L):
        mb = np.asarray(delta.aug_mbrs[li])
        bm = np.asarray(delta.aug_bms[li])
        aug_mbrs.append(jnp.asarray(
            _stack_shard_rows(mb, part.nodes[li], part.level_pads[li], never)
        ))
        aug_bms.append(jnp.asarray(
            _stack_shard_rows(bm, part.nodes[li], part.level_pads[li], 0)
        ))
    cbm = sig = None
    if delta.ins_cbm is not None:
        cbm = jnp.asarray(
            _stack_shard_rows(np.asarray(delta.ins_cbm), leaf_ids, Kp, 0)
        )
        sig = jnp.asarray(
            _stack_shard_rows(np.asarray(delta.ins_sig), leaf_ids, Kp, 0)
        )
    return DeltaBuffer(
        aug_mbrs=aug_mbrs,
        aug_bms=aug_bms,
        ins_x=jnp.asarray(_stack_shard_rows(np.asarray(delta.ins_x), leaf_ids, Kp, 0)),
        ins_y=jnp.asarray(_stack_shard_rows(np.asarray(delta.ins_y), leaf_ids, Kp, 0)),
        ins_bm=jnp.asarray(_stack_shard_rows(np.asarray(delta.ins_bm), leaf_ids, Kp, 0)),
        ins_id=jnp.asarray(_stack_shard_rows(np.asarray(delta.ins_id), leaf_ids, Kp, -1)),
        base_alive=jnp.asarray(
            _stack_shard_rows(np.asarray(delta.base_alive), leaf_ids, Kp, 1)
        ),
        slots_per_leaf=delta.slots_per_leaf,
        ins_cbm=cbm,
        ins_sig=sig,
    )


def _remap_insert_bitmap(bm: np.ndarray, terms: np.ndarray):
    """Remap one full-width insert bitmap into a leaf's compact vocabulary.

    ``bm``: (W,) u32; ``terms``: (32*Wl,) i32 sorted leaf dictionary,
    ``-1``-padded. Returns ``(cbm (Wl,), sig, exact)`` where ``exact`` is
    False when the object carries a term missing from the dictionary -- the
    remap would silently drop it, so the caller must fall back to the
    full-width path.
    """
    shifts = np.arange(32, dtype=np.uint32)
    Wl = terms.size // 32
    tpos = np.clip(terms, 0, bm.size * 32 - 1)
    bits = (bm[tpos >> 5] >> (tpos & 31).astype(np.uint32)) & np.uint32(1)
    bits = np.where(terms >= 0, bits, np.uint32(0))
    cbm = np.bitwise_or.reduce(bits.reshape(Wl, 32) << shifts, axis=-1)
    sig = np.bitwise_or.reduce(cbm)
    n_terms = int(np.sum(((bm[:, None] >> shifts) & 1)))
    return cbm, sig, int(bits.sum()) == n_terms


def parent_chains(index: WiskIndex) -> List[np.ndarray]:
    """Per non-root level: ``parents[li][node] = parent id at level li-1``.

    ``parents[0]`` is a placeholder (root nodes have no parent). Host-only;
    computed once per index from the level CSRs and used by ``DeltaLog`` to
    widen the augmented filter arrays along each insert's ancestor path.
    """
    out: List[np.ndarray] = [np.zeros(index.levels[0].n, np.int32)]
    for li in range(len(index.levels) - 1):
        lvl = index.levels[li]
        par = np.zeros(index.levels[li + 1].n, np.int32)
        for u in range(lvl.n):
            par[lvl.child[lvl.child_ptr[u] : lvl.child_ptr[u + 1]]] = u
        out.append(par)
    return out


class DeltaLog:
    """Host-side manager of the incremental update stream over one snapshot.

    Owns the current ``DeltaBuffer`` (``.buffer``), the routing metadata
    (leaf MBRs + parent chains), and the host mirrors (``ins_locs``,
    ``ins_kw_ids``, ``deleted``) that ``merged_dataset()`` feeds to the
    warm-start rebuild. All methods are host-only; every update replaces
    ``.buffer`` with a new immutable pytree (readers holding the old buffer
    keep a consistent view -- the same discipline as the snapshot swap).
    """

    def __init__(
        self,
        index: WiskIndex,
        dataset: GeoTextDataset,
        snapshot: IndexSnapshot,
        slots_per_leaf: int = MIN_SLOTS_PER_LEAF,
    ) -> None:
        self.index = index
        self.dataset = dataset
        self.snapshot = snapshot
        self.buffer: DeltaBuffer = DeltaBuffer.empty(snapshot, slots_per_leaf)
        self._parents = parent_chains(index)
        self._leaf_mbrs = np.asarray(index.levels[-1].mbrs, np.float32)
        # sticky compact-remap flag: flips False (once; one retrace) when a
        # buffered insert carries a term outside its leaf's dictionary
        self.compact_ok = snapshot.has_compact_bank
        self._leaf_terms = (
            np.asarray(snapshot.leaf_terms) if self.compact_ok else None
        )
        # host mirrors of the augmented arrays (updates are host unions; the
        # level arrays are tiny next to the object blocks, so re-uploading a
        # touched level per update batch is cheap and keeps the math simple)
        self._aug_mbrs = [np.asarray(m).copy() for m in snapshot.level_mbrs]
        self._aug_bms = [np.asarray(b).copy() for b in snapshot.level_bms]
        self._fill = np.zeros(snapshot.n_leaves, np.int64)  # high-water slot/leaf
        self._free: Dict[int, List[int]] = {}  # leaf -> reusable (deleted) slots
        # snapshot object id -> (leaf, slot) for delete masking, and the
        # same map for buffered inserts (filled by insert())
        oid = np.asarray(snapshot.leaf_obj_id)
        kk, ss = np.nonzero(oid >= 0)
        self._base_slot: Dict[int, Tuple[int, int]] = {
            int(oid[k, s]): (int(k), int(s)) for k, s in zip(kk, ss)
        }
        self._ins_slot: Dict[int, Tuple[int, int]] = {}
        # host mirrors for merged_dataset / rebuild
        self.ins_locs: List[np.ndarray] = []
        self.ins_kw_ids: List[np.ndarray] = []
        self.ins_leaf: List[int] = []
        self.deleted: set = set()
        self._next_id = dataset.n

    # ------------------------------------------------------------- inserts
    def insert(self, locs: np.ndarray, kw_ids: np.ndarray) -> np.ndarray:
        """Buffer new objects; returns their assigned global ids.

        ``locs``: (n, 2) f32 in the unit square; ``kw_ids``: (n, max_kw)
        i32 padded with ``-1``. Each object is routed to the leaf with the
        smallest point-to-MBR distance (ties: smallest leaf id), its slot is
        scattered into the insert buffers, and the leaf's ancestor chain in
        the augmented MBR/bitmap arrays is widened so every descent can
        reach it. Full leaf buffers grow by doubling (one retrace per
        doubling, bounded like every other width bucket).
        """
        locs = np.asarray(locs, np.float32).reshape(-1, 2)
        kw_ids = np.asarray(kw_ids, np.int32).reshape(locs.shape[0], -1)
        n = locs.shape[0]
        if n == 0:
            return np.zeros(0, np.int64)
        ids = np.arange(self._next_id, self._next_id + n, dtype=np.int64)
        self._next_id += n
        bms = ids_to_bitmap(kw_ids, self.dataset.vocab_size)
        leaf = np.argmin(
            _mbr_dist2_f32(self._leaf_mbrs[None, :, :], locs[:, None, :]), axis=1
        ).astype(np.int64)

        # allocate slots: reuse freed (deleted-buffered) slots first, then
        # extend the high-water mark -- churn does not grow the buffer
        slots = np.zeros(n, np.int64)
        for i, lf in enumerate(leaf):
            free = self._free.get(int(lf))
            if free:
                slots[i] = free.pop()
            else:
                slots[i] = self._fill[lf]
                self._fill[lf] += 1
            self._ins_slot[int(ids[i])] = (int(lf), int(slots[i]))
        max_need = int(self._fill.max()) if self._fill.size else 0
        B = self.buffer.slots_per_leaf
        while B < max_need:
            B *= 2
        buf = self.buffer.grown(B)
        buf = dataclasses.replace(
            buf,
            ins_x=buf.ins_x.at[(leaf, slots)].set(jnp.asarray(locs[:, 0])),
            ins_y=buf.ins_y.at[(leaf, slots)].set(jnp.asarray(locs[:, 1])),
            ins_bm=buf.ins_bm.at[(leaf, slots)].set(jnp.asarray(bms)),
            ins_id=buf.ins_id.at[(leaf, slots)].set(jnp.asarray(ids, jnp.int32)),
        )
        if self.compact_ok:
            Wl = buf.ins_cbm.shape[2]
            cbms = np.zeros((n, Wl), np.uint32)
            sigs = np.zeros((n,), np.uint32)
            exact = True
            for i in range(n):
                cbms[i], sigs[i], ok = _remap_insert_bitmap(
                    np.asarray(bms[i], np.uint32), self._leaf_terms[int(leaf[i])]
                )
                exact = exact and ok
            if exact:
                buf = dataclasses.replace(
                    buf,
                    ins_cbm=buf.ins_cbm.at[(leaf, slots)].set(jnp.asarray(cbms)),
                    ins_sig=buf.ins_sig.at[(leaf, slots)].set(jnp.asarray(sigs)),
                )
            else:
                # a term this leaf has never seen: compact delta slots would
                # be lossy, so drop them for good (executors fall back to
                # the exact full-width ins_bm path)
                self.compact_ok = False
                buf = dataclasses.replace(buf, ins_cbm=None, ins_sig=None)

        # widen the ancestor path per touched (level, node)
        touched: Dict[int, set] = {}
        n_levels = len(self._aug_mbrs)
        for i in range(n):
            node = int(leaf[i])
            for li in range(n_levels - 1, -1, -1):
                mb = self._aug_mbrs[li][node]
                x, y = locs[i, 0], locs[i, 1]
                self._aug_mbrs[li][node] = (
                    min(mb[0], x), min(mb[1], y), max(mb[2], x), max(mb[3], y),
                )
                self._aug_bms[li][node] |= bms[i]
                touched.setdefault(li, set()).add(node)
                node = int(self._parents[li][node])
        aug_mbrs = list(buf.aug_mbrs)
        aug_bms = list(buf.aug_bms)
        for li in touched:
            aug_mbrs[li] = jnp.asarray(self._aug_mbrs[li])
            aug_bms[li] = jnp.asarray(self._aug_bms[li])
        self.buffer = dataclasses.replace(buf, aug_mbrs=aug_mbrs, aug_bms=aug_bms)

        self.ins_locs.append(locs)
        self.ins_kw_ids.append(kw_ids)
        self.ins_leaf.extend(int(l) for l in leaf)
        return ids

    # -------------------------------------------------------------- deletes
    def delete(self, ids) -> int:
        """Mark objects deleted; returns how many ids were newly deleted.

        Snapshot objects flip their ``base_alive`` slot to 0; buffered
        objects clear their ``ins_id`` slot to ``-1``. The augmented filter
        arrays are left wide (conservative: filtering only prunes, and the
        verify/top-k stages mask the deleted slots, so results stay exact).
        Unknown ids are ignored.
        """
        ids = [int(i) for i in np.atleast_1d(np.asarray(ids, np.int64))]
        base_kk, base_ss = [], []
        ins_kk, ins_ss = [], []
        n_new = 0
        buf = self.buffer
        for oid in ids:
            if oid in self.deleted:
                continue
            if oid in self._base_slot:
                k, s = self._base_slot[oid]
                base_kk.append(k)
                base_ss.append(s)
                self.deleted.add(oid)
                n_new += 1
            elif oid in self._ins_slot:
                k, s = self._ins_slot.pop(oid)
                ins_kk.append(k)
                ins_ss.append(s)
                self._free.setdefault(k, []).append(s)
                self.deleted.add(oid)
                n_new += 1
        if ins_kk:
            buf = dataclasses.replace(
                buf,
                ins_id=buf.ins_id.at[(np.asarray(ins_kk), np.asarray(ins_ss))].set(-1),
            )
        if base_kk:
            buf = dataclasses.replace(
                buf,
                base_alive=buf.base_alive.at[
                    (np.asarray(base_kk), np.asarray(base_ss))
                ].set(0),
            )
        self.buffer = buf
        return n_new

    # ------------------------------------------------------------- rebuild
    def n_updates(self) -> int:
        return (self._next_id - self.dataset.n) + len(self.deleted)

    def merged_dataset(self) -> GeoTextDataset:
        """Base dataset + buffered inserts, deletes tombstoned.

        Object ids are row indices, so the merge preserves them: base
        objects keep ``0..n-1``, inserts take ``n..`` in arrival order, and
        deleted objects keep their row with an emptied keyword set -- a
        keywordless object can never match an SKR or Boolean-kNN query, so
        tombstones are inert while every live id stays identical to the
        delta-merged serving path (the id-exactness contract of
        tests/test_delta_maintenance.py).
        """
        base = self.dataset
        max_kw = base.kw_ids.shape[1]
        if self.ins_kw_ids:
            max_kw = max(max_kw, max(k.shape[1] for k in self.ins_kw_ids))

        def pad(a: np.ndarray) -> np.ndarray:
            return np.pad(a, ((0, 0), (0, max_kw - a.shape[1])), constant_values=-1)

        locs = np.concatenate([base.locs, *[l for l in self.ins_locs]], 0) if self.ins_locs else base.locs.copy()
        kw = (
            np.concatenate([pad(base.kw_ids), *[pad(k) for k in self.ins_kw_ids]], 0)
            if self.ins_kw_ids
            else base.kw_ids.copy()
        )
        if self.deleted:
            kw[np.fromiter(self.deleted, np.int64)] = -1
        return GeoTextDataset.from_ids(locs, kw, base.vocab_size)

    def merged_assignment(self) -> np.ndarray:
        """(n_merged,) leaf/cluster assignment extending the snapshot's
        clustering with each buffered insert's routed leaf -- the warm-start
        rebuild's starting partition over the merged dataset."""
        extra = np.asarray(self.ins_leaf, np.int32)
        return np.concatenate([self.index.clusters.assign, extra])
