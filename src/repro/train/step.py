"""train_step / serve_step builders: model + optimizer + sharding specs.

``build_steps(cfg, mesh)`` returns a ``Steps`` object exposing jit-able
functions and the NamedShardings for every argument -- consumed by both the
real training loop (small configs on CPU) and the multi-pod dry-run
(ShapeDtypeStruct lowering at 512 devices).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, SHAPES
from ..models.model import ModelBundle, build_model
from ..models.layers import split_params
from ..optim.optimizers import clip_by_global_norm, cosine_schedule, get_optimizer
from ..sharding.rules import default_rules, named_sharding, spec_for


def opt_state_specs(name: str, param_specs):
    """Mirror param logical specs onto optimizer state leaves."""
    if name == "adamw":
        return type("S", (), {})  # handled structurally below

    return None


def _adamw_specs(pspecs):
    from ..optim.optimizers import AdamWState

    return AdamWState(m=pspecs, v=pspecs)


def _adafactor_specs(pspecs):
    from ..optim.optimizers import AdafactorState

    def vr(s):
        return s[:-1] if len(s) >= 2 else s

    def vc(s):
        return s[:-2] + s[-1:] if len(s) >= 2 else (None,)

    is_spec = lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x)
    return AdafactorState(
        vr=jax.tree.map(vr, pspecs, is_leaf=is_spec),
        vc=jax.tree.map(vc, pspecs, is_leaf=is_spec),
    )


def _sgd_specs(pspecs):
    from ..optim.optimizers import SGDState

    return SGDState(mom=pspecs)


OPT_SPECS = {"adamw": _adamw_specs, "adafactor": _adafactor_specs, "sgd": _sgd_specs}


@dataclasses.dataclass
class Steps:
    cfg: ArchConfig
    bundle: ModelBundle
    mesh: Optional[Mesh]
    rules: Dict

    init_state: Callable  # key -> state dict
    train_step: Callable  # (state, batch) -> (state, metrics)
    prefill_step: Callable  # (params, batch) -> logits
    decode_step: Callable  # (params, cache, tokens, pos) -> (logits, cache)

    state_specs: Any  # logical-name tree mirroring state
    param_specs: Any

    def shardings(self, tree_of_specs):
        mesh = self.mesh
        rules = self.rules
        is_spec = lambda x: isinstance(x, tuple) and all(
            isinstance(i, (str, type(None))) for i in x
        )
        return jax.tree.map(
            lambda s: NamedSharding(mesh, spec_for(s, rules)), tree_of_specs, is_leaf=is_spec
        )

    def batch_spec(self, kind: str, seq: int, batch: int):
        """(abstract batch pytree, logical specs) for a shape kind."""
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        if cfg.family == "encdec":
            enc_s = max(seq // cfg.enc_frames_div, 64)
            b = dict(
                frames=sds((batch, enc_s, cfg.d_model), jnp.bfloat16),
                tokens=sds((batch, seq), jnp.int32),
            )
            s = dict(frames=("batch", None, None), tokens=("batch", None))
        elif cfg.family == "vlm":
            P_ = min(cfg.n_patches, max(seq // 4, 16))
            b = dict(
                patches=sds((batch, P_, cfg.d_model), jnp.bfloat16),
                tokens=sds((batch, max(seq - P_, 8)), jnp.int32),
            )
            s = dict(patches=("batch", None, None), tokens=("batch", None))
        else:
            b = dict(tokens=sds((batch, seq), jnp.int32))
            s = dict(tokens=("batch", None))
        return b, s

    def cache_spec(self, batch: int, seq: int, long_ctx: bool = False):
        """(abstract cache pytree, logical specs). long_ctx reshards the
        sequence dim over every mesh axis and replicates batch (batch=1)."""
        shapes = self.bundle.cache_shape(batch, seq)
        sds = {}
        specs = {}
        for k, (shape, dtype, names) in shapes.items():
            names = tuple(names)
            if long_ctx:
                names = tuple(
                    "kv_seq_all" if n == "kv_seq" else (None if n == "batch" else n)
                    for n in names
                )
            sds[k] = jax.ShapeDtypeStruct(shape, dtype)
            specs[k] = names
        return sds, specs


def build_steps(
    cfg: ArchConfig,
    mesh: Optional[Mesh] = None,
    lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    grad_clip: float = 1.0,
) -> Steps:
    bundle = build_model(cfg)
    if mesh is not None:
        rules = default_rules(mesh)
    else:
        # single-device rules: everything replicated
        rules = {k: None for k in default_rules_keys()}
    if getattr(cfg, "logical_overrides", None):
        rules.update(cfg.logical_overrides if mesh is not None else {})
    if mesh is not None:
        rules["__mesh__"] = mesh  # makes constrain() binding (NamedSharding)
    opt_init, opt_update = get_optimizer(cfg.optimizer)
    sched = cosine_schedule(lr, warmup, total_steps)

    captured = {}

    def init_state(key):
        ptree = bundle.init(key)
        values, specs = split_params(ptree)
        captured["pspecs"] = specs
        opt = opt_init(values)
        return dict(params=values, opt=opt, step=jnp.zeros((), jnp.int32))

    # trace once abstractly to learn the spec tree
    jax.eval_shape(init_state, jax.random.PRNGKey(0))
    pspecs = captured["pspecs"]
    state_specs = dict(
        params=pspecs, opt=OPT_SPECS[cfg.optimizer](pspecs), step=()
    )

    def train_step(state, batch):
        def loss_fn(params):
            return bundle.loss(params, batch, rules, mesh)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr_t = sched(state["step"])
        updates, opt = opt_update(grads, state["opt"], state["params"], lr_t, state["step"])
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), state["params"], updates)
        new_state = dict(params=params, opt=opt, step=state["step"] + 1)
        return new_state, dict(loss=loss, grad_norm=gnorm, lr=lr_t)

    def prefill_step(params, batch):
        return bundle.prefill(params, batch, rules, mesh)

    def decode_step(params, cache, tokens, pos):
        return bundle.decode(params, cache, tokens, pos, rules, mesh)

    return Steps(
        cfg=cfg,
        bundle=bundle,
        mesh=mesh,
        rules=rules,
        init_state=init_state,
        train_step=train_step,
        prefill_step=prefill_step,
        decode_step=decode_step,
        state_specs=state_specs,
        param_specs=pspecs,
    )


def default_rules_keys():
    from ..sharding.rules import default_rules as dr
    import jax as _jax
    from jax.sharding import Mesh as _M

    # keys only; build from a trivial mesh
    dev = np.array(_jax.devices()[:1]).reshape(1, 1)
    m = _M(dev, ("data", "model"))
    return dr(m).keys()
