"""Batched LM decoding loops (moved out of serve/engine.py: the serving
package is spatial-keyword-only; LM inference belongs with the train-side
step builders whose ``decode_step`` it drives)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy_generate(steps, params, cache, prompt_tokens: jnp.ndarray, n_new: int, start_pos: int):
    """Batched greedy decode loop driving steps.decode_step."""
    decode = jax.jit(steps.decode_step)
    tok = prompt_tokens[:, -1:]
    out = []
    pos = start_pos
    for _ in range(n_new):
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1), cache
