"""Training loop with checkpoint/restart, straggler monitoring, and optional
gradient compression -- the fault-tolerance substrate (DESIGN.md §5).

Runs real (small) configs on the host devices; the same loop drives a pod
when the mesh has real TPU devices behind it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..ckpt.checkpoint import AsyncCheckpointer, latest_step, restore
from ..configs.base import ArchConfig
from ..data.tokens import TokenPipeline
from ..optim.compression import EFState, ef_init, int8_tree_roundtrip, topk_with_error_feedback
from ..resilience.straggler import MitigationPlan, StragglerMonitor
from .step import Steps, build_steps


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 50
    batch: int = 8
    seq: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    log_every: int = 10
    grad_compression: Optional[str] = None  # None | "topk" | "int8"
    topk_frac: float = 0.1
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    losses: list
    final_step: int
    restored_from: Optional[int]
    step_times: list
    flagged_hosts: list


def train(cfg: ArchConfig, tc: TrainConfig, mesh=None, steps: Optional[Steps] = None) -> TrainResult:
    steps = steps or build_steps(cfg, mesh)
    pipe = TokenPipeline(vocab=cfg.vocab, seq=tc.seq, batch=tc.batch, seed=tc.seed)

    restored_from = None
    state = jax.jit(steps.init_state)(jax.random.PRNGKey(tc.seed))
    start_step = 0
    ckpt = None
    if tc.ckpt_dir:
        ckpt = AsyncCheckpointer(tc.ckpt_dir)
        last = latest_step(tc.ckpt_dir)
        if last is not None:
            state, start_step = restore(tc.ckpt_dir, state)
            restored_from = start_step
            pipe.skip_to(start_step)

    ef: Optional[EFState] = None
    base_train = steps.train_step

    def train_with_compression(state, batch, ef_res):
        def loss_fn(params):
            return steps.bundle.loss(params, batch, steps.rules, steps.mesh)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        if tc.grad_compression == "topk":
            grads, ef_res = topk_with_error_feedback(grads, ef_res, tc.topk_frac)
        elif tc.grad_compression == "int8":
            grads = int8_tree_roundtrip(grads)
        from ..optim.optimizers import clip_by_global_norm, get_optimizer, cosine_schedule

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        opt_init, opt_update = get_optimizer(cfg.optimizer)
        lr_t = cosine_schedule(3e-4, 100, 10_000)(state["step"])
        updates, opt = opt_update(grads, state["opt"], state["params"], lr_t, state["step"])
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), state["params"], updates)
        return dict(params=params, opt=opt, step=state["step"] + 1), dict(loss=loss, grad_norm=gnorm), ef_res

    if tc.grad_compression:
        grads_template = state["params"]
        ef = ef_init(grads_template)
        step_fn = jax.jit(train_with_compression)
    else:
        step_fn = jax.jit(base_train)

    monitor = StragglerMonitor(n_hosts=1)
    losses, times, flagged_all = [], [], []
    for it in range(start_step, tc.n_steps):
        batch = pipe.next_batch(cfg)
        t0 = time.perf_counter()
        if tc.grad_compression:
            state, metrics, ef = step_fn(state, batch, ef)
        else:
            state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        flagged = monitor.observe(np.array([dt]))
        if flagged:
            flagged_all.extend(flagged)
        losses.append(loss)
        if tc.log_every and (it + 1) % tc.log_every == 0:
            print(f"step {it+1} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if ckpt and (it + 1) % tc.ckpt_every == 0:
            ckpt.submit(it + 1, state)
    if ckpt:
        ckpt.submit(tc.n_steps, state)
        ckpt.close()
    return TrainResult(
        losses=losses,
        final_step=tc.n_steps,
        restored_from=restored_from,
        step_times=times,
        flagged_hosts=flagged_all,
    )
