"""Deterministic synthetic LM token pipeline.

Markov-chain token streams with a fixed seed: reproducible across restarts
(``skip_to(step)`` fast-forwards without replaying), shardable by host. A
real deployment swaps this for a file-backed loader with the same interface
-- determinism + skip are the properties the fault-tolerance layer needs.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

import jax.numpy as jnp


class TokenPipeline:
    def __init__(self, vocab: int, seq: int, batch: int, seed: int = 0):
        self.vocab = vocab
        self.seq = seq
        self.batch = batch
        self.seed = seed
        self.step = 0

    def skip_to(self, step: int):
        self.step = step

    def _batch_tokens(self, step: int) -> np.ndarray:
        # counter-based generation: content depends only on (seed, step)
        rng = np.random.default_rng(np.uint64(self.seed * 1_000_003 + step))
        # zipf-ish marginal + short-range repetition structure
        base = rng.zipf(1.3, size=(self.batch, self.seq)) % self.vocab
        rep = rng.integers(0, 4, size=(self.batch, self.seq)) == 0
        shifted = np.roll(base, 3, axis=1)
        return np.where(rep, shifted, base).astype(np.int32)

    def next_batch(self, cfg) -> Dict[str, jnp.ndarray]:
        toks = self._batch_tokens(self.step)
        self.step += 1
        if cfg.family == "encdec":
            rng = np.random.default_rng(np.uint64(self.seed * 7_000_003 + self.step))
            frames = rng.normal(0, 1, size=(self.batch, max(self.seq // cfg.enc_frames_div, 8), cfg.d_model))
            return dict(frames=jnp.asarray(frames, jnp.float32), tokens=jnp.asarray(toks))
        if cfg.family == "vlm":
            P = min(cfg.n_patches, max(self.seq // 4, 4))
            rng = np.random.default_rng(np.uint64(self.seed * 9_000_003 + self.step))
            patches = rng.normal(0, 1, size=(self.batch, P, cfg.d_model))
            return dict(patches=jnp.asarray(patches, jnp.float32), tokens=jnp.asarray(toks))
        return dict(tokens=jnp.asarray(toks))
