"""Synthetic geo-textual dataset generators.

Real datasets in the paper (FS/SP/BPD/OSM) are POI collections whose
keywords are Zipf-distributed and spatially correlated (restaurants cluster
downtown, trailheads in parks). We reproduce those statistics at laptop
scale:

* locations: mixture of 2-D Gaussians (hotspots) + uniform background;
* keywords: Zipf frequencies over a vocabulary ``V``; each keyword has a
  set of "topic centers" so its objects concentrate spatially -- this is
  what makes workload-aware layouts beat purely spatial ones (paper Fig. 2).

``make_dataset(profile)`` provides FS/SP/BPD/OSM-like presets (scaled).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..core.types import GeoTextDataset


@dataclasses.dataclass
class SynthConfig:
    n: int = 20_000
    vocab: int = 512
    max_kw: int = 6
    zipf_a: float = 1.2
    n_hotspots: int = 12
    hotspot_frac: float = 0.7  # fraction of objects in spatial hotspots
    kw_locality: float = 0.6  # prob a keyword is drawn from the local topic
    topic_centers_per_kw: int = 2
    seed: int = 0


PROFILES = {
    # scaled stand-ins for the paper's datasets (Table 1 ratios preserved-ish)
    "fs": SynthConfig(n=20_000, vocab=462, max_kw=2, zipf_a=1.1, n_hotspots=10),
    "sp": SynthConfig(n=30_000, vocab=2048, max_kw=3, zipf_a=1.3, n_hotspots=16),
    "bpd": SynthConfig(n=60_000, vocab=4096, max_kw=5, zipf_a=1.4, n_hotspots=24),
    "osm": SynthConfig(n=120_000, vocab=8192, max_kw=5, zipf_a=1.5, n_hotspots=32),
}


def make_dataset(profile: str = "fs", n: Optional[int] = None, seed: int = 0) -> GeoTextDataset:
    cfg = dataclasses.replace(PROFILES[profile], seed=seed)
    if n is not None:
        cfg = dataclasses.replace(cfg, n=n)
    return synth_dataset(cfg)


def _zipf_probs(v: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, v + 1) ** a
    return p / p.sum()


def synth_dataset(cfg: SynthConfig) -> GeoTextDataset:
    rng = np.random.default_rng(cfg.seed)
    # --- locations ---
    n_hot = int(cfg.n * cfg.hotspot_frac)
    centers = rng.uniform(0.08, 0.92, size=(cfg.n_hotspots, 2))
    scales = rng.uniform(0.01, 0.06, size=(cfg.n_hotspots, 1))
    which = rng.integers(0, cfg.n_hotspots, size=n_hot)
    hot = centers[which] + rng.normal(0, 1, size=(n_hot, 2)) * scales[which]
    bg = rng.uniform(0, 1, size=(cfg.n - n_hot, 2))
    locs = np.clip(np.concatenate([hot, bg], axis=0), 0.0, 1.0).astype(np.float32)
    rng.shuffle(locs)

    # --- keyword topic fields ---
    topic_centers = rng.uniform(0, 1, size=(cfg.vocab, cfg.topic_centers_per_kw, 2))
    zipf = _zipf_probs(cfg.vocab, cfg.zipf_a)

    n_kw = rng.integers(1, cfg.max_kw + 1, size=cfg.n)
    kw_ids = np.full((cfg.n, cfg.max_kw), -1, dtype=np.int32)

    # global draws (vectorized) then local overrides
    total = int(n_kw.sum())
    glob = rng.choice(cfg.vocab, size=total, p=zipf)
    # local keyword per object: keyword whose topic center is nearest among a
    # random zipf-weighted candidate set (cheap approximation of locality)
    cand = rng.choice(cfg.vocab, size=(cfg.n, 8), p=zipf)
    d = np.linalg.norm(
        topic_centers[cand].reshape(cfg.n, 8 * cfg.topic_centers_per_kw, 2)
        - locs[:, None, :],
        axis=2,
    ).reshape(cfg.n, 8, cfg.topic_centers_per_kw).min(axis=2)
    local_kw = cand[np.arange(cfg.n), d.argmin(axis=1)]

    pos = 0
    use_local = rng.uniform(size=total) < cfg.kw_locality
    for i in range(cfg.n):
        k = int(n_kw[i])
        draws = glob[pos : pos + k].copy()
        draws[use_local[pos : pos + k]] = local_kw[i]
        uniq = np.unique(draws)[: cfg.max_kw]
        kw_ids[i, : uniq.size] = uniq
        pos += k

    return GeoTextDataset.from_ids(locs, kw_ids, cfg.vocab)
