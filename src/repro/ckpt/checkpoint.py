"""Sharded checkpointing with re-shard-on-load (elastic restarts).

Layout: ``<dir>/step_<N>/manifest.json`` + one ``.npz`` per leaf-chunk.
Leaves are saved as host numpy (gathered per-leaf -- at laptop scale the
leaves fit host RAM; on a real pod each host writes its local shards, the
manifest records the global shape so restore can re-shard onto ANY mesh).

Features:
  * atomic publish (write to tmp dir, rename) so a crash mid-save never
    corrupts the latest checkpoint;
  * async writer thread (training continues while the previous step saves);
  * ``restore(..., mesh=new_mesh, shardings=new)`` re-shards onto a
    different device topology (elastic scaling);
  * garbage collection of old steps (keep_n).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, state: Any, keep_n: int = 3) -> str:
    """Synchronous checkpoint save with atomic publish."""
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(state)
    meta = dict(step=step, n_leaves=len(leaves), treedef=str(treedef), time=time.time())
    shapes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if dtype not in ("float64", "float32", "float16", "int64", "int32",
                         "int16", "int8", "uint8", "uint16", "uint32", "uint64", "bool"):
            arr = arr.astype(np.float32)  # bf16 etc: store widened, restore re-casts
        np.savez(tmp / f"leaf_{i}.npz", a=arr)
        shapes.append(dict(shape=list(arr.shape), dtype=dtype))
    meta["leaves"] = shapes
    (tmp / "manifest.json").write_text(json.dumps(meta))
    final = base / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # GC old steps
    steps = sorted(
        (int(p.name.split("_")[1]) for p in base.glob("step_*")), reverse=True
    )
    for s in steps[keep_n:]:
        shutil.rmtree(base / f"step_{s}", ignore_errors=True)
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_*")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None, shardings: Any = None):
    """Restore into the structure of ``template``; optionally re-shard with
    ``shardings`` (a matching pytree of NamedShardings for the NEW mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step}"
    meta = json.loads((d / "manifest.json").read_text())
    t_leaves, treedef = _flatten(template)
    assert len(t_leaves) == meta["n_leaves"], (
        f"checkpoint has {meta['n_leaves']} leaves, template {len(t_leaves)}"
    )
    out = []
    sh_leaves = None
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
    for i, tl in enumerate(t_leaves):
        arr = np.load(d / f"leaf_{i}.npz")["a"]
        val = jax.numpy.asarray(arr).astype(tl.dtype) if hasattr(tl, "dtype") else arr
        if sh_leaves is not None:
            out.append(jax.device_put(val, sh_leaves[i]))
        else:
            out.append(val)
    return treedef.unflatten(out), step


class AsyncCheckpointer:
    """Background writer thread; ``wait()`` drains pending saves."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_n = keep_n
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state = item
            try:
                save(self.ckpt_dir, step, state, self.keep_n)
            except BaseException as e:  # pragma: no cover
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, state: Any):
        if self._err:
            raise self._err
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self._q.put((step, host_state))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join(timeout=10)
