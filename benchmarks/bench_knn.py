"""Fig. 23 (appendix): Boolean kNN query support."""
import time

import numpy as np

from . import common as C
from repro.core.query import knn_query


def run():
    rows = []
    ds = C.dataset()
    art = C.wisk_index()
    rng = np.random.default_rng(0)
    test = C.workload("fs", C.DEFAULT_N, 16, "MIX", 0.0005, 5, 23)
    for k in (5, 15, 30):
        t0 = time.perf_counter()
        for qi in range(test.m):
            point = np.array([
                (test.rects[qi, 0] + test.rects[qi, 2]) / 2,
                (test.rects[qi, 1] + test.rects[qi, 3]) / 2,
            ])
            knn_query(art.index, ds, point, test.kw_bitmap[qi], k)
        dt = (time.perf_counter() - t0) / test.m * 1e6
        rows.append(C.row(f"fig23/k{k}/wisk", dt, ""))
        # brute force reference
        t0 = time.perf_counter()
        for qi in range(test.m):
            match = np.any(ds.kw_bitmap & test.kw_bitmap[qi][None], axis=1)
            d2 = ((ds.locs - ds.locs[qi % ds.n]) ** 2).sum(1)
            d2[~match] = np.inf
            np.argsort(d2)[:k]
        dt = (time.perf_counter() - t0) / test.m * 1e6
        rows.append(C.row(f"fig23/k{k}/bruteforce", dt, ""))
    return rows
