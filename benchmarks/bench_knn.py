"""Fig. 23 (appendix): Boolean kNN -- host vs device serving paths.

For k in {1, 10, 100} reports, per path, mean per-query wall clock plus the
Eq.1-style cost counters (nodes checked, objects verified) and the device
path's leaf pruning ratio: exhaustive-leaf-scan blocks / leaf blocks the
distance-bounded descent actually verified (> 1 means the bound fired).

``--quick`` (the CI fast-lane smoke) swaps the DQN-built index for a tiny
deterministic grid hierarchy, runs k=4 only, and asserts device/host parity
and pruning ratio > 1 so the workflow catches kNN-path breakage cheaply.
"""
import argparse
import time

import numpy as np

from . import common as C
from repro.core.index import assemble_index
from repro.core.packing import HierarchyResult
from repro.core.query import knn_level_sync, knn_query
from repro.core.types import ClusterSet
from repro.launch.wisk_serve import serve_knn_batch
from repro.serve.engine import IndexSnapshot

QUICK_N = 600
QUICK_M = 8
QUICK_K = 4


def _query_points(wl) -> np.ndarray:
    return np.stack(
        [(wl.rects[:, 0] + wl.rects[:, 2]) / 2, (wl.rects[:, 1] + wl.rects[:, 3]) / 2], 1
    ).astype(np.float32)


def _tiny_grid_index(ds, g: int = 5):
    """Deterministic 2-level hierarchy (grid leaves grouped spatially) --
    the smoke's stand-in for the DQN build, mirroring the parity suite's."""
    cell = np.minimum((ds.locs * g).astype(np.int32), g - 1)
    assign = cell[:, 0] * g + cell[:, 1]
    _, assign = np.unique(assign, return_inverse=True)
    clusters = ClusterSet.from_assignment(ds, assign.astype(np.int32))
    cent = np.clip((clusters.mbrs[:, :2] + clusters.mbrs[:, 2:]) / 2, 0.0, 1.0)
    gg = max(2, g // 2)
    pcell = np.minimum((cent * gg).astype(np.int32), gg - 1)
    pid = pcell[:, 0] * gg + pcell[:, 1]
    _, pid = np.unique(pid, return_inverse=True)
    hier = None
    if pid.max() + 1 < clusters.k:
        hier = HierarchyResult(parents=[pid.astype(np.int32)], level_labels=[], packs=[])
    return assemble_index(ds, clusters, hier)


def _bench_path(fn, m: int, reps: int = 3) -> float:
    fn()  # warm (device: compile + learn frontier widths)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps / m * 1e6


def run(quick: bool = False):
    rows = []
    if quick:
        ds = C.dataset("fs", QUICK_N)
        index = _tiny_grid_index(ds)
        test = C.workload("fs", QUICK_N, QUICK_M, "MIX", 0.0005, 5, 23)
        ks = (QUICK_K,)
    else:
        ds = C.dataset()
        index = C.wisk_index().index
        test = C.workload("fs", C.DEFAULT_N, 32, "MIX", 0.0005, 5, 23)
        ks = (1, 10, 100)
    points = _query_points(test)
    bw = IndexSnapshot.build(index, ds)
    m = test.m
    n_leaf = index.levels[-1].n
    tag = "fig23q" if quick else "fig23"
    for k in ks:
        # serial best-first (paper appendix A reference)
        res = [knn_query(index, ds, points[qi], test.kw_bitmap[qi], k) for qi in range(m)]
        us = _bench_path(
            lambda: [knn_query(index, ds, points[qi], test.kw_bitmap[qi], k) for qi in range(m)],
            m,
        )
        nodes = np.mean([r.nodes_accessed for r in res])
        ver = np.mean([r.verified for r in res])
        rows.append(C.row(f"{tag}/k{k}/serial_bestfirst", us, f"nodes={nodes:.1f};verified={ver:.1f}"))

        # vectorized host mirror of the device descent
        sync = knn_level_sync(index, ds, points, test.kw_bitmap, k)
        us = _bench_path(lambda: knn_level_sync(index, ds, points, test.kw_bitmap, k), m)
        rows.append(
            C.row(
                f"{tag}/k{k}/host_levelsync",
                us,
                f"nodes={sync['nodes_checked'].mean():.1f};verified={sync['verified'].mean():.1f}"
                f";leaves={sync['leaves_verified'].mean():.1f}",
            )
        )

        # device distance-bounded frontier descent (via the bucketed front door)
        dev = serve_knn_batch(bw, points, test.kw_bitmap, k)
        us = _bench_path(lambda: serve_knn_batch(bw, points, test.kw_bitmap, k), m)
        prune_ratio = (m * n_leaf) / max(float(dev["leaves_verified"].sum()), 1.0)
        rows.append(
            C.row(
                f"{tag}/k{k}/device_frontier",
                us,
                f"nodes={dev['nodes_checked'].mean():.1f};verified={dev['verified'].mean():.1f}"
                f";leaves={dev['leaves_verified'].mean():.1f};pruning_ratio={prune_ratio:.2f}",
            )
        )

        # brute force over the whole dataset (external ground truth)
        def brute():
            for qi in range(m):
                match = np.any(ds.kw_bitmap & test.kw_bitmap[qi][None], axis=1)
                d2 = ((ds.locs - points[qi]) ** 2).sum(1)
                d2[~match] = np.inf
                np.argsort(d2)[:k]

        rows.append(C.row(f"{tag}/k{k}/bruteforce", _bench_path(brute, m), ""))

        # cross-path result parity (id sequences, not just sets) + pruning gate
        for qi in range(m):
            got = dev["ids"][qi]
            got = got[got >= 0]
            assert np.array_equal(got, res[qi].ids), f"k={k} q={qi}: device != serial"
            hs = sync["ids"][qi]
            assert np.array_equal(hs[hs >= 0], res[qi].ids), f"k={k} q={qi}: levelsync != serial"
        assert prune_ratio > 1.0, f"k={k}: bounded descent did not prune ({prune_ratio:.2f})"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="tiny-index CI smoke (k=4)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(quick=args.quick):
        print(r, flush=True)


if __name__ == "__main__":
    main()
