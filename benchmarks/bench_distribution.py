"""Fig. 8: query time vs query distribution (UNI/LAP/GAU/MIX)."""
from . import common as C
from repro.baselines.conventional import build_grid_index, build_str_rtree
from repro.baselines.learned import build_floodt, build_lsti, build_tfi, tfi_query


def run():
    rows = []
    ds = C.dataset()
    for dist in ("UNI", "LAP", "GAU", "MIX"):
        test = C.workload("fs", C.DEFAULT_N, 24, dist, 0.0005, 5, 7)
        art = C.wisk_index(dist=dist)
        us, st = C.time_queries(art.index, ds, test)
        rows.append(C.row(f"fig8/{dist}/wisk", us, f"cost={st.total_cost:.0f}"))
        for name, idx in (
            ("grid", build_grid_index(ds, 8)),
            ("str-rtree", build_str_rtree(ds)),
            ("flood-t", build_floodt(ds, C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, dist, 0.0005, 5, 107))),
            ("lsti", build_lsti(ds)),
        ):
            us, st = C.time_queries(idx, ds, test)
            rows.append(C.row(f"fig8/{dist}/{name}", us, f"cost={st.total_cost:.0f}"))
        import time
        tfi = build_tfi(ds)
        t0 = time.perf_counter(); st = tfi_query(tfi, ds, test); dt = time.perf_counter() - t0
        rows.append(C.row(f"fig8/{dist}/tfi", dt / test.m * 1e6, f"cost={st.total_cost:.0f}"))
    return rows
