"""Roofline rows: dry-run LLM-arch summary + serving descent bytes moved.

Two row families (EXPERIMENTS.md section Roofline):

* ``roofline/<arch>/<shape>`` -- the launch/dryrun compute/memory/collective
  decomposition (full runs only; needs ``experiments/dryrun`` artifacts).
* ``roofline/descent/*`` -- the analytic bytes-moved model of the serving
  descent (repro.roofline.descent_bytes) priced on the SAME deterministic
  quick config and converged frontier widths as bench_serving's quick A/Bs.
  The ``bytes=`` counters are exact ints diffed deterministically by
  tools/bench_compare.py; the legacy/narrow ratio row is the scoreboard
  evidence for the >=2x descent-bytes reduction of DESIGN.md §3.5, and the
  verify-compact row for the >=2x leaf-verify reduction of the leaf-local
  vocabulary bank (both asserted here so a regression fails the benchmark,
  not just the diff). ``leaf-vocab`` carries the per-leaf word-count
  distribution (wl_max / wl_p50 / wl_p95 / overflow_leaves) the compact
  pricing rests on.
"""
from pathlib import Path

import numpy as np

from . import common as C


def _descent_rows(rows):
    from repro.data.workloads import make_workload
    from repro.kernels import ops
    from repro.roofline import descent_bytes as DB
    from repro.serve.engine import retrieve_workload

    from .bench_serving import SWEEP_M, quick_snapshot

    ds, snap, max_leaves = quick_snapshot()
    test = make_workload(ds, m=SWEEP_M, dist="MIX", seed=7)
    out = retrieve_workload(snap, test, max_leaves=max_leaves)
    widths = [int(w) for w in out["frontier_widths"]]
    M = test.m
    W = snap.n_words
    OBJ = snap.obj_per_leaf
    K = snap.n_leaves
    T = int(np.asarray(out["ids"]).shape[1]) // OBJ
    wids, _ = ops.pack_query_words(np.asarray(test.kw_bitmap))
    Wp = int(wids.shape[1])
    dict_sizes = [
        (int(dx.size), int(dy.size))
        for dx, dy in zip(snap.level_dict_x, snap.level_dict_y)
    ]
    bank = ops.leaf_bank_bytes(K, OBJ, W)
    auto = "prefetch" if bank > ops.FUSED_VMEM_BANK_BYTES else "vmem"

    legacy_f = DB.descent_bytes(M, widths, W)
    narrow_f = DB.descent_bytes(
        M, widths, W, narrow=True, packed_words=Wp, dict_sizes=dict_sizes
    )
    rows.append(C.row(
        "roofline/descent/filter-legacy", 0.0,
        f"bytes={legacy_f.total} ms={legacy_f.total_ms:.4f} widths=[{','.join(map(str, widths))}]"))
    rows.append(C.row(
        "roofline/descent/filter-narrow", 0.0,
        f"bytes={narrow_f.total} ms={narrow_f.total_ms:.4f} wp={Wp}"))
    for variant in ("unfused", "vmem", "prefetch"):
        vb = DB.verify_bytes(M, T, OBJ, W, K, variant)
        rows.append(C.row(
            f"roofline/descent/verify-{variant}", 0.0,
            f"bytes={vb} ms={DB.modeled_ms(vb):.4f}"))
    rows.append(C.row(
        "roofline/descent/bank", 0.0,
        f"bytes={bank} cutoff={ops.FUSED_VMEM_BANK_BYTES} auto={auto}"))

    # leaf-local vocabulary bank (DESIGN.md §3.5): per-leaf word-count
    # distribution + compact verify pricing on the auto-selected variant
    from repro.serve.snapshot import LEAF_DICT_MAX

    obm = np.asarray(snap.leaf_obj_bm)
    shifts = np.arange(32, dtype=np.uint32)
    vocab = (
        (np.bitwise_or.reduce(obm, axis=1)[:, :, None] >> shifts) & 1
    ).sum(axis=(1, 2)).astype(np.int64)
    wl_leaf = np.maximum(-(-vocab // 32), 1)
    overflow = int(np.sum(vocab > LEAF_DICT_MAX))
    assert snap.has_compact_bank, "quick config must keep the compact bank"
    Wl = snap.n_compact_words
    rows.append(C.row(
        "roofline/descent/leaf-vocab", 0.0,
        f"wl={Wl} wl_max={int(wl_leaf.max())} "
        f"wl_p50={int(np.percentile(wl_leaf, 50))} "
        f"wl_p95={int(np.percentile(wl_leaf, 95))} "
        f"overflow_leaves={overflow}"))
    cbank = ops.compact_leaf_bank_bytes(K, OBJ, Wl)
    cauto = "prefetch" if cbank > ops.FUSED_VMEM_BANK_BYTES else "vmem"
    cvb = DB.verify_bytes(M, T, OBJ, W, K, cauto, compact_words=Wl)
    rows.append(C.row(
        "roofline/descent/verify-compact", 0.0,
        f"bytes={cvb} ms={DB.modeled_ms(cvb):.4f} variant={cauto}"))
    rows.append(C.row(
        "roofline/descent/bank-compact", 0.0,
        f"bytes={cbank} cutoff={ops.FUSED_VMEM_BANK_BYTES} auto={cauto}"))
    vmem_vb = DB.verify_bytes(M, T, OBJ, W, K, "vmem")
    assert vmem_vb >= 2 * cvb, (
        f"modeled compact-verify reduction fell below 2x vs verify-vmem: "
        f"{vmem_vb / max(cvb, 1):.2f}x"
    )

    # end-to-end before/after: the seed path (f32 planes + unfused verify)
    # vs the shipping path (narrow planes + compact bank on the auto variant)
    before = DB.descent_bytes(
        M, widths, W, t=T, obj_per_leaf=OBJ, n_leaves=K,
        verify_variant="unfused")
    after = DB.descent_bytes(
        M, widths, W, narrow=True, packed_words=Wp, dict_sizes=dict_sizes,
        t=T, obj_per_leaf=OBJ, n_leaves=K, verify_variant=cauto,
        compact_words=Wl)
    cmp = DB.compare(before, after)
    rows.append(C.row(
        "roofline/descent/total-before", 0.0,
        f"bytes={before.total} ms={before.total_ms:.4f}"))
    rows.append(C.row(
        "roofline/descent/total-after", 0.0,
        f"bytes={after.total} ms={after.total_ms:.4f}"))
    rows.append(C.row(
        "roofline/descent/reduction", 0.0,
        f"ratio={cmp['ratio']:.2f}x filter_ratio="
        f"{legacy_f.total / max(narrow_f.total, 1):.2f}x"))
    assert cmp["ratio"] >= 2.0, (
        f"modeled descent-bytes reduction fell below 2x: {cmp['ratio']:.2f}x"
    )
    return rows


def run_quick():
    """CI lane: descent bytes only (the dryrun artifacts are full-run)."""
    return _descent_rows([])


def run():
    rows = []
    d = Path("experiments/dryrun")
    if not d.exists():
        rows.append(C.row("roofline/missing", 0.0, "run launch/dryrun first"))
    else:
        from repro.roofline.analysis import load_rows

        for r in load_rows(str(d)):
            rows.append(C.row(
                f"roofline/{r.arch}/{r.shape}", 0.0,
                f"compute_ms={r.compute_s*1e3:.2f};memory_ms={r.memory_s*1e3:.2f};"
                f"collective_ms={r.collective_s*1e3:.2f};bound={r.bottleneck};useful={r.useful_ratio:.2f}"))
    return _descent_rows(rows)
