"""Dry-run roofline summary (EXPERIMENTS.md section Roofline)."""
from pathlib import Path

from . import common as C


def run():
    rows = []
    d = Path("experiments/dryrun")
    if not d.exists():
        return [C.row("roofline/missing", 0.0, "run launch/dryrun first")]
    from repro.roofline.analysis import load_rows

    for r in load_rows(str(d)):
        rows.append(C.row(
            f"roofline/{r.arch}/{r.shape}", 0.0,
            f"compute_ms={r.compute_s*1e3:.2f};memory_ms={r.memory_s*1e3:.2f};"
            f"collective_ms={r.collective_s*1e3:.2f};bound={r.bottleneck};useful={r.useful_ratio:.2f}"))
    return rows
