"""Fig. 12: robustness to query-distribution drift (train UNI, test drift)."""
from . import common as C
from repro.baselines.learned import build_floodt


def run():
    rows = []
    ds = C.dataset()
    art = C.wisk_index(dist="UNI")
    floodt = build_floodt(ds, C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "UNI", 0.0005, 5, 111))
    for ratio in (0.2, 0.6, 1.0):
        m_lap = int(24 * ratio)
        lap = C.workload("fs", C.DEFAULT_N, max(m_lap, 1), "LAP", 0.0005, 5, 12)
        uni = C.workload("fs", C.DEFAULT_N, max(24 - m_lap, 1), "UNI", 0.0005, 5, 13)
        test = lap.concat(uni) if m_lap < 24 else lap
        us, st = C.time_queries(art.index, ds, test)
        rows.append(C.row(f"fig12/lap{ratio}/wisk", us, f"cost={st.total_cost:.0f}"))
        us, st = C.time_queries(floodt, ds, test)
        rows.append(C.row(f"fig12/lap{ratio}/flood-t", us, f"cost={st.total_cost:.0f}"))
    return rows
