"""Fig. 21: action mask effect on DQN convergence + reward."""
import numpy as np

from . import common as C
from repro.core.dqn import DQNConfig
from repro.core.packing import PackingConfig, pack_one_level


def run():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 2, (14, 12)).astype(bool)
    rows = []
    for tag, mask in (("mask", True), ("no-mask", False)):
        cfg = PackingConfig(epochs=10, action_mask=mask, dqn=DQNConfig())
        res = pack_one_level(labels, cfg, seed=0)
        final_loss = float(np.mean(res.losses[-10:])) if res.losses else float("nan")
        rows.append(C.row(f"fig21/{tag}", 0.0,
                          f"sum_reward={res.sum_rewards:.2f};final_loss={final_loss:.3f};n_upper={res.n_upper}"))
    return rows
