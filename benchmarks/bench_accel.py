"""Fig. 13: training-time acceleration (query sampling + cluster grouping)."""
import time

from . import common as C
from repro.core.build import build_wisk


def run():
    rows = []
    ds = C.dataset()
    wl = C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", 0.0005, 5, 114)
    test = C.workload("fs", C.DEFAULT_N, 24, "MIX", 0.0005, 5, 15)
    for ratio in (0.1, 0.3, 1.0):
        cfg = C.small_build_config(accelerated=ratio < 1.0, sample_ratio=ratio, cluster_ratio=0.2)
        t0 = time.perf_counter()
        art = build_wisk(ds, wl, cfg)
        build_s = time.perf_counter() - t0
        us, st = C.time_queries(art.index, ds, test)
        rows.append(C.row(f"fig13/sample{ratio}", us,
                          f"build_s={build_s:.1f};cost={st.total_cost:.0f}"))
    return rows
