"""WISK TPU-path serving throughput: sparse frontier vs dense mask vs host.

Reports, per mode, the per-query latency plus the traversal-work counters
(DESIGN.md §3): ``nodes_scanned`` is what the kernels actually touch (padded
frontier widths vs full level widths), ``nodes_checked`` the frontier-
resident nodes -- the gap between the two modes' scanned counts is the
payoff of the sparse descent.
"""
import time

import numpy as np

from . import common as C
from repro.serve.engine import BatchedWisk, retrieve_workload


def _time_mode(bw, test, max_leaves, mode, reps=3):
    out = retrieve_workload(bw, test, max_leaves=max_leaves, mode=mode)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = retrieve_workload(bw, test, max_leaves=max_leaves, mode=mode)
    dt = (time.perf_counter() - t0) / reps / test.m * 1e6
    return dt, out


def run():
    rows = []
    ds = C.dataset()
    art = C.wisk_index()
    test = C.workload("fs", C.DEFAULT_N, 64, "MIX", 0.0005, 5, 24)
    bw = BatchedWisk.build(art.index, ds, dense=True)
    max_leaves = art.partition.clusters.k

    dt_f, out_f = _time_mode(bw, test, max_leaves, "frontier")
    widths = ",".join(str(w) for w in out_f["frontier_widths"])
    rows.append(
        C.row(
            "serving/frontier",
            dt_f,
            f"overflow={int(out_f['overflow'].sum())} "
            f"scanned={int(out_f['nodes_scanned'].sum())} "
            f"checked={int(out_f['nodes_checked'].sum())} widths=[{widths}]",
        )
    )
    dt_d, out_d = _time_mode(bw, test, max_leaves, "dense")
    rows.append(
        C.row(
            "serving/dense-mask",
            dt_d,
            f"overflow={int(out_d['overflow'].sum())} "
            f"scanned={int(out_d['nodes_scanned'].sum())} "
            f"checked={int(out_d['nodes_checked'].sum())}",
        )
    )
    for qf, qd in zip(out_f["ids"], out_d["ids"]):
        assert np.array_equal(np.sort(qf[qf >= 0]), np.sort(qd[qd >= 0])), (
            "frontier/dense result mismatch"
        )
    us, st = C.time_queries(art.index, ds, test)
    rows.append(C.row("serving/serial-host", us, f"cost={st.total_cost:.0f}"))
    return rows
