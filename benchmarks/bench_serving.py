"""WISK TPU-path serving throughput (batched kernels vs serial host)."""
import time

import jax.numpy as jnp

from . import common as C
from repro.serve.engine import BatchedWisk, retrieve_workload


def run():
    rows = []
    ds = C.dataset()
    art = C.wisk_index()
    test = C.workload("fs", C.DEFAULT_N, 48, "MIX", 0.0005, 5, 24)
    bw = BatchedWisk.build(art.index, ds)
    out = retrieve_workload(bw, test, max_leaves=art.partition.clusters.k)  # warm + correctness
    t0 = time.perf_counter()
    for _ in range(3):
        out = retrieve_workload(bw, test, max_leaves=art.partition.clusters.k)
    dt = (time.perf_counter() - t0) / 3 / test.m * 1e6
    rows.append(C.row("serving/batched-kernels", dt, f"overflow={int(out['overflow'].sum())}"))
    us, st = C.time_queries(art.index, ds, test)
    rows.append(C.row("serving/serial-host", us, f"cost={st.total_cost:.0f}"))
    return rows
