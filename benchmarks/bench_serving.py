"""WISK TPU-path serving throughput: sparse frontier vs dense mask vs host,
plus the data-parallel sharded path's device-count scaling sweep.

Reports, per mode, the per-query latency plus the traversal-work counters
(DESIGN.md §3): ``nodes_scanned`` is what the kernels actually touch (padded
frontier widths vs full level widths), ``nodes_checked`` the frontier-
resident nodes -- the gap between the two modes' scanned counts is the
payoff of the sparse descent.

The sharded sweep (DESIGN.md §3.4) serves a larger batch through
``serve_sharded`` -- the real frontier engine shard_mapped over the data
axis -- on meshes of 1, 2, 4, ... of the available devices and reports
aggregate queries/sec, the speedup over the 1-device mesh, and the scaling
efficiency (speedup / device count). Run standalone with a forced
multi-device CPU platform to sweep without a TPU:

    PYTHONPATH=src python -m benchmarks.bench_serving --devices 8
"""
import os
import sys

# --devices N must force the host platform device count BEFORE jax is
# imported (first backend init locks it) -- same discipline as launch/dryrun.
# Appended to (not replacing) any pre-existing XLA_FLAGS so the sweep still
# gets its devices in environments that tune other XLA knobs.
if "--devices" in sys.argv:
    _i = sys.argv.index("--devices") + 1
    if _i >= len(sys.argv) or not sys.argv[_i].isdigit():
        sys.exit("usage: python -m benchmarks.bench_serving [--quick] [--devices N]")
    _flag = f"--xla_force_host_platform_device_count={sys.argv[_i]}"
    if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_flag}".strip()

import time

import numpy as np

from . import common as C
from repro.serve.engine import IndexSnapshot, retrieve_workload
from repro.serve.plan import PlanCache

SWEEP_M = 256  # sharded-sweep batch: large enough to give every shard work

# illustrative per-device HBM budget for the quick config: the full replica
# exceeds it, the 2-way cut fits -- the motivating case of the index-parallel
# regime (DESIGN.md §3.4)
DEVICE_BUDGET = 1 << 20


def _time_mode(bw, test, max_leaves, mode, reps=3, fused=None, **kw):
    out = retrieve_workload(bw, test, max_leaves=max_leaves, mode=mode, fused=fused, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = retrieve_workload(bw, test, max_leaves=max_leaves, mode=mode, fused=fused, **kw)
    dt = (time.perf_counter() - t0) / reps / test.m * 1e6
    return dt, out


def _ab_fused(rows, snap, test, max_leaves, reps=3):
    """Fused vs unfused leaf verification A/B (DESIGN.md §3.5): same frontier
    descent, the leaf gather+verify either fused in one Pallas kernel or
    bounced through HBM as the gathered candidate plane. Ids and Eq.1
    counters must be identical (asserted); only the wall clock may differ."""
    dt_u, out_u = _time_mode(snap, test, max_leaves, "frontier", reps, fused=False)
    dt_f, out_f = _time_mode(snap, test, max_leaves, "frontier", reps, fused=True)
    for key in ("ids", "counts", "verified", "overflow"):
        assert np.array_equal(np.asarray(out_u[key]), np.asarray(out_f[key])), (
            f"fused/unfused {key} mismatch"
        )
    rows.append(
        C.row("serving/verify-unfused", dt_u,
              f"verified={int(out_u['verified'].sum())}")
    )
    rows.append(
        C.row("serving/verify-fused", dt_f,
              f"verified={int(out_f['verified'].sum())}")
    )
    rows.append(
        C.row("serving/fused-speedup", 0.0, f"speedup={dt_u / dt_f:.2f}x")
    )
    return rows


def _ab_quantized(rows, snap, test, max_leaves, reps=3):
    """Narrow vs f32 descent A/B (DESIGN.md §3.5): the same frontier descent
    on the int16-code / packed-word shadow planes vs the full f32/W planes.
    The narrow planes are lossless (exact dictionary dequantization), so ids
    AND every traversal counter must be identical (asserted)."""
    dt_w, out_w = _time_mode(snap, test, max_leaves, "frontier", reps, quantized=False)
    dt_n, out_n = _time_mode(snap, test, max_leaves, "frontier", reps, quantized=True)
    for key in ("ids", "counts", "verified", "overflow", "nodes_scanned", "nodes_checked"):
        assert np.array_equal(np.asarray(out_w[key]), np.asarray(out_n[key])), (
            f"narrow/f32 descent {key} mismatch"
        )
    rows.append(
        C.row("serving/descent-f32", dt_w,
              f"checked={int(out_w['nodes_checked'].sum())}")
    )
    rows.append(
        C.row("serving/descent-narrow", dt_n,
              f"checked={int(out_n['nodes_checked'].sum())}")
    )
    return rows


def _ab_prefetch(rows, snap, test, max_leaves, reps=3):
    """VMEM-fused vs scalar-prefetched fused verify A/B (DESIGN.md §3.5):
    identical frontier descent, the leaf verify either re-streams the whole
    bank per query block (vmem) or issues one DMA per (query, slot) block
    (prefetch). Elementwise-identical outputs asserted -- the prefetch
    variant is what keeps banks beyond VMEM on the fused path."""
    dt_v, out_v = _time_mode(snap, test, max_leaves, "frontier", reps,
                             fused=True, fused_variant="vmem")
    dt_p, out_p = _time_mode(snap, test, max_leaves, "frontier", reps,
                             fused=True, fused_variant="prefetch")
    for key in ("ids", "counts", "verified", "overflow"):
        assert np.array_equal(np.asarray(out_v[key]), np.asarray(out_p[key])), (
            f"vmem/prefetch fused {key} mismatch"
        )
    rows.append(
        C.row("serving/verify-fused-vmem", dt_v,
              f"verified={int(out_v['verified'].sum())}")
    )
    rows.append(
        C.row("serving/verify-fused-prefetch", dt_p,
              f"verified={int(out_p['verified'].sum())}")
    )
    return rows


def _mesh_over(n: int):
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:n]).reshape(n, 1), ("data", "model"))


def _sweep_sharded(rows, snap, test, max_leaves, reps=3):
    import jax

    from repro.launch.wisk_serve import serve_sharded

    n_dev = len(jax.devices())
    counts = [d for d in (1, 2, 4, 8, 16, 32) if d <= n_dev]
    ref = retrieve_workload(snap, test, max_leaves=max_leaves, plan_cache=PlanCache())
    base_qps = scale = None
    for d in counts:
        mesh = _mesh_over(d)
        cache = PlanCache()
        out = serve_sharded(  # warm: converges widths + compiles
            snap, test.rects, test.kw_bitmap, max_leaves=max_leaves,
            mesh=mesh, plan_cache=cache,
        )
        for a, b in zip(out["ids"], ref["ids"]):
            assert np.array_equal(np.sort(a[a >= 0]), np.sort(b[b >= 0])), (
                f"sharded dp{d} result mismatch"
            )
        t0 = time.perf_counter()
        for _ in range(reps):
            serve_sharded(
                snap, test.rects, test.kw_bitmap, max_leaves=max_leaves,
                mesh=mesh, plan_cache=cache,
            )
        dt = (time.perf_counter() - t0) / reps
        qps = test.m / dt
        if base_qps is None:
            base_qps = qps
        scale = qps / base_qps
        rows.append(
            C.row(
                f"serving/sharded-dp{d}",
                dt / test.m * 1e6,
                f"qps={qps:.0f} scale={scale:.2f}x eff={scale / d:.2f}",
            )
        )
    if len(counts) > 1:
        # Caveat for forced-CPU sweeps: the N "devices" share the physical
        # cores, and the interpret-mode kernels' cost also shrinks with the
        # per-shard batch width, so part of the measured speedup is batch-
        # shape effect rather than pure device parallelism. On a real mesh
        # (one chip per device, compiled kernels) the same sweep measures
        # genuine throughput scaling.
        rows.append(
            C.row(
                "serving/sharded-scaling",
                0.0,
                f"devices={counts[-1]} aggregate_speedup={scale:.2f}x "
                f"(forced-host-device sweeps include batch-shape effects)",
            )
        )
    return rows, scale


def _bytes_lane(rows, snap, budget=DEVICE_BUDGET, shard_counts=(1, 2, 4)):
    """Analytic per-device footprint of the index-parallel regime (host-only,
    deterministic -- safe for committed baselines): the bytes each device
    holds when the snapshot is cut into S shard-local sub-hierarchies,
    versus replicating the whole index, plus the smallest S that fits an
    illustrative per-device budget the full replica exceeds."""
    from repro.serve.snapshot import PartitionedSnapshot, tree_nbytes

    replica = tree_nbytes(snap)
    n_root = int(snap.level_mbrs[0].shape[0])
    fits_at = 0
    for s in shard_counts:
        if s > n_root:  # cannot cut finer than the root forest
            continue
        per = PartitionedSnapshot.build(snap, s).per_shard_bytes()
        if not fits_at and per <= budget:
            fits_at = s
        rows.append(
            C.row(
                f"serving/index-shards{s}-bytes", 0.0,
                f"per_device_bytes={per} replica_bytes={replica} shards={s} "
                f"shrink={replica / per:.2f}x",
            )
        )
    rows.append(
        C.row(
            "serving/index-device-budget", 0.0,
            f"budget={budget} fits_at={fits_at} "
            f"(replica {'exceeds' if replica > budget else 'fits'} the budget; "
            f"fits_at = smallest shard count under it, 0 = none swept)",
        )
    )
    return rows


def _sweep_index_sharded(rows, snap, test, max_leaves, n_shards, reps=3):
    """The index-parallel serving lane (DESIGN.md §3.4): cut the snapshot
    into ``n_shards`` sub-hierarchies, serve the batch over the
    (query, index) 2D mesh, assert exact id-set/counter parity with the
    single-device engine, and report throughput next to the per-device
    footprint the regime buys."""
    import jax

    from repro.launch.mesh import make_serving_mesh
    from repro.launch.wisk_serve import serve_index_sharded
    from repro.serve.snapshot import PartitionedSnapshot, tree_nbytes

    n_dev = len(jax.devices())
    if n_dev % n_shards:
        raise SystemExit(
            f"--index-shards {n_shards} needs a device count divisible by it "
            f"(have {n_dev}; combine with --devices)"
        )
    ref = retrieve_workload(snap, test, max_leaves=max_leaves, plan_cache=PlanCache())
    psnap = PartitionedSnapshot.build(snap, n_shards)
    mesh = make_serving_mesh(query=n_dev // n_shards, index=n_shards)
    cache = PlanCache()
    out = serve_index_sharded(  # warm: converges widths + compiles
        psnap, test.rects, test.kw_bitmap, max_leaves=max_leaves,
        mesh=mesh, plan_cache=cache,
    )
    for key in ("counts", "nodes_checked", "verified", "overflow"):
        assert np.array_equal(np.asarray(ref[key]), np.asarray(out[key])), (
            f"index-sharded s{n_shards} {key} mismatch"
        )
    for a, b in zip(out["ids"], ref["ids"]):
        assert np.array_equal(np.sort(a[a >= 0]), np.sort(b[b >= 0])), (
            f"index-sharded s{n_shards} result mismatch"
        )
    t0 = time.perf_counter()
    for _ in range(reps):
        serve_index_sharded(
            psnap, test.rects, test.kw_bitmap, max_leaves=max_leaves,
            mesh=mesh, plan_cache=cache,
        )
    dt = (time.perf_counter() - t0) / reps
    rows.append(
        C.row(
            f"serving/index-sharded-s{n_shards}",
            dt / test.m * 1e6,
            f"qps={test.m / dt:.0f} per_device_bytes={psnap.per_shard_bytes()} "
            f"replica_bytes={tree_nbytes(snap)} shards={n_shards} "
            f"query_par={n_dev // n_shards}",
        )
    )
    return rows


def quick_snapshot():
    """The deterministic quick serving config (no DQN build): a grid
    hierarchy over the fs profile, frozen into a snapshot. Shared with
    bench_roofline so the bytes-moved rows price exactly the config the
    serving A/Bs measure. Returns ``(ds, snap, max_leaves)``."""
    from repro.core.index import assemble_index
    from repro.core.packing import HierarchyResult
    from repro.core.types import ClusterSet
    from repro.data.synth import make_dataset

    ds = make_dataset("fs", n=3000, seed=0)
    g = 8
    cell = np.minimum((ds.locs * g).astype(np.int32), g - 1)
    assign = cell[:, 0] * g + cell[:, 1]
    _, assign = np.unique(assign, return_inverse=True)
    clusters = ClusterSet.from_assignment(ds, assign.astype(np.int32))
    cent = np.clip((clusters.mbrs[:, :2] + clusters.mbrs[:, 2:]) / 2, 0.0, 1.0)
    pc = np.minimum((cent * (g // 2)).astype(np.int32), g // 2 - 1)
    pid = pc[:, 0] * (g // 2) + pc[:, 1]
    _, pid = np.unique(pid, return_inverse=True)
    hier = HierarchyResult(parents=[pid.astype(np.int32)], level_labels=[], packs=[])
    index = assemble_index(ds, clusters, hier)
    snap = IndexSnapshot.build(index, ds)
    return ds, snap, clusters.k


def _index_shards_arg():
    """The ``--index-shards N`` value, or None when the lane is off."""
    if "--index-shards" not in sys.argv:
        return None
    i = sys.argv.index("--index-shards") + 1
    if i >= len(sys.argv) or not sys.argv[i].isdigit():
        sys.exit(
            "usage: python -m benchmarks.bench_serving "
            "[--quick] [--devices N] [--index-shards S]"
        )
    return int(sys.argv[i])


def run_quick():
    """CI smoke: deterministic grid hierarchy (no DQN build), the fused-vs-
    unfused / vmem-vs-prefetch / narrow-vs-f32 A/Bs (identical ids/counters
    asserted), the sharded sweep -- asserts sharded-vs-single-device parity
    on every mesh size and that aggregate throughput scales (>1x) from 1 to
    full mesh -- plus the analytic per-device-bytes lane of the
    index-parallel regime (and its live sweep with ``--index-shards``)."""
    import jax

    from repro.data.workloads import make_workload

    ds, snap, max_leaves = quick_snapshot()
    test = make_workload(ds, m=SWEEP_M, dist="MIX", seed=7)
    rows = _ab_fused([], snap, test, max_leaves=max_leaves)
    rows = _ab_prefetch(rows, snap, test, max_leaves=max_leaves)
    rows = _ab_quantized(rows, snap, test, max_leaves=max_leaves)
    rows, scale = _sweep_sharded(rows, snap, test, max_leaves=max_leaves)
    if 1 < len(jax.devices()) <= (os.cpu_count() or 1):
        # forced host "devices" beyond the physical core count time-slice one
        # CPU -- no real parallelism exists to assert on, so the scaling gate
        # only arms when every device can own a core (the CI lane's runners)
        assert scale > 1.0, f"no aggregate throughput scaling: {scale:.2f}x"
    rows = _bytes_lane(rows, snap)
    n_shards = _index_shards_arg()
    if n_shards:
        rows = _sweep_index_sharded(rows, snap, test, max_leaves, n_shards)
    return rows


def run():
    rows = []
    ds = C.dataset()
    art = C.wisk_index()
    test = C.workload("fs", C.DEFAULT_N, 64, "MIX", 0.0005, 5, 24)
    bw = IndexSnapshot.build(art.index, ds, dense=True)
    max_leaves = art.partition.clusters.k

    dt_f, out_f = _time_mode(bw, test, max_leaves, "frontier")
    widths = ",".join(str(w) for w in out_f["frontier_widths"])
    rows.append(
        C.row(
            "serving/frontier",
            dt_f,
            f"overflow={int(out_f['overflow'].sum())} "
            f"scanned={int(out_f['nodes_scanned'].sum())} "
            f"checked={int(out_f['nodes_checked'].sum())} widths=[{widths}]",
        )
    )
    dt_d, out_d = _time_mode(bw, test, max_leaves, "dense")
    rows.append(
        C.row(
            "serving/dense-mask",
            dt_d,
            f"overflow={int(out_d['overflow'].sum())} "
            f"scanned={int(out_d['nodes_scanned'].sum())} "
            f"checked={int(out_d['nodes_checked'].sum())}",
        )
    )
    for qf, qd in zip(out_f["ids"], out_d["ids"]):
        assert np.array_equal(np.sort(qf[qf >= 0]), np.sort(qd[qd >= 0])), (
            "frontier/dense result mismatch"
        )
    us, st = C.time_queries(art.index, ds, test)
    rows.append(C.row("serving/serial-host", us, f"cost={st.total_cost:.0f}"))
    rows = _ab_fused(rows, bw, test, max_leaves)
    rows = _ab_prefetch(rows, bw, test, max_leaves)
    rows = _ab_quantized(rows, bw, test, max_leaves)

    sweep = C.workload("fs", C.DEFAULT_N, SWEEP_M, "MIX", 0.0005, 5, 25)
    # frontier-only snapshot for the sweep: the dense A/B adjacency matrices
    # would otherwise be replicated to every device without ever being read
    lean = IndexSnapshot.build(art.index, ds)
    rows, _ = _sweep_sharded(rows, lean, sweep, max_leaves)
    rows = _bytes_lane(rows, lean)
    n_shards = _index_shards_arg()
    if n_shards:
        rows = _sweep_index_sharded(rows, lean, sweep, max_leaves, n_shards)
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for r in (run_quick() if "--quick" in sys.argv else run()):
        print(r)


if __name__ == "__main__":
    main()
