"""Figs. 14/15: dynamic workload shift + data insertion with retraining."""
import numpy as np

from . import common as C
from repro.core.build import build_wisk
from repro.core.query import execute_serial
from repro.core.types import GeoTextDataset


def run():
    rows = []
    ds = C.dataset()
    # Fig 14: workload shifts UNI -> LAP; retrain recovers
    art = C.wisk_index(dist="UNI")
    lap_test = C.workload("fs", C.DEFAULT_N, 24, "LAP", 0.0005, 5, 21)
    us_stale, st_stale = C.time_queries(art.index, ds, lap_test)
    lap_train = C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "LAP", 0.0005, 5, 121)
    art2 = build_wisk(ds, lap_train, C.small_build_config())
    us_re, st_re = C.time_queries(art2.index, ds, lap_test)
    rows.append(C.row("fig14/stale-layout", us_stale, f"cost={st_stale.total_cost:.0f}"))
    rows.append(C.row("fig14/retrained", us_re, f"cost={st_re.total_cost:.0f}"))
    # Fig 15: insertion without/with retrain
    rng = np.random.default_rng(0)
    extra_ids = rng.choice(ds.n, 800)
    jitter = rng.normal(0, 0.01, (800, 2)).astype(np.float32)
    new_locs = np.clip(ds.locs[extra_ids] + jitter, 0, 1)
    grown = GeoTextDataset.from_ids(
        np.concatenate([ds.locs, new_locs]),
        np.concatenate([ds.kw_ids, ds.kw_ids[extra_ids]]),
        ds.vocab_size,
    )
    # naive insertion: objects assigned to nearest existing cluster (stale layout)
    test = C.workload("fs", C.DEFAULT_N, 24, "MIX", 0.0005, 5, 22)
    from repro.core.types import ClusterSet
    from repro.core.index import assemble_index

    cl = art.partition.clusters
    cx = (cl.mbrs[:, 0] + cl.mbrs[:, 2]) / 2
    cy = (cl.mbrs[:, 1] + cl.mbrs[:, 3]) / 2
    d2 = (new_locs[:, 0:1] - cx[None]) ** 2 + (new_locs[:, 1:2] - cy[None]) ** 2
    assign = np.concatenate([cl.assign, d2.argmin(1).astype(np.int32)])
    stale = assemble_index(grown, ClusterSet.from_assignment(grown, assign))
    us_n, st_n = C.time_queries(stale, grown, test)
    rows.append(C.row("fig15/insert-no-retrain", us_n, f"cost={st_n.total_cost:.0f}"))
    train = C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", 0.0005, 5, 122)
    art3 = build_wisk(grown, train, C.small_build_config())
    us_r, st_r = C.time_queries(art3.index, grown, test)
    rows.append(C.row("fig15/insert-retrained", us_r, f"cost={st_r.total_cost:.0f}"))
    return rows
