"""Figs. 14/15 + DESIGN.md §7: dynamic workloads and object updates under
the incremental-maintenance subsystem.

Three maintenance strategies are compared on the same shift:

* **cold rebuild** -- re-run the full Alg. 1 pipeline on the new workload
  (the paper's answer, and the only answer the repo had before §7);
* **warm-start rebuild** -- ``core.build.warm_start_rebuild``: reuse the
  CDF bank/itemsets, re-learn splits only for the leaves whose cost
  regressed, graft the DQN-packed hierarchy;
* **serve-through-deltas** -- no rebuild at all: object updates absorbed
  by the ``DeltaBuffer`` and merged into every query on the fly.

Reported per strategy: post-shift Eq.1 cost (and its ratio to the cold
rebuild's) plus the maintenance wall clock (build time, or delta-absorb
time for the no-rebuild arm).

``--quick`` is the CI smoke: a tiny index, and two assertions --
(1) the warm-start rebuild lands within 10% of the cold rebuild's
post-shift Eq.1 cost at measurably lower build time, and (2) delta-served
SKR results are id-exact with a cold rebuild over the merged object set.

    PYTHONPATH=src python -m benchmarks.bench_dynamic --quick
"""
import argparse
import time

import numpy as np

from . import common as C
from repro.core.build import BuildConfig, build_wisk, warm_start_rebuild
from repro.core.cost import DEFAULT_W1, DEFAULT_W2, exact_query_result_ids
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.core.query import execute_level_sync, execute_serial
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.core.query import SubscriptionOracle
from repro.launch.wisk_serve import LiveIndex, serve_batch
from repro.serve.delta import DeltaLog
from repro.serve.engine import IndexSnapshot

QUICK_N = 1500


def _quick_build_config() -> BuildConfig:
    """Smallest honest pipeline: learned splits + DQN-packed hierarchy."""
    return BuildConfig(
        partition=PartitionConfig(max_clusters=24, n_steps=25, n_restarts=2),
        packing=PackingConfig(epochs=3, max_label_queries=16),
        cdf_train_steps=40,
        cdf_force_class="gauss",
        use_itemsets=False,
    )


def _mean_cost(index, ds, wl) -> float:
    return float(execute_level_sync(index, ds, wl).cost.mean())


def run(quick: bool = False):
    rows = []
    tag = "fig14q" if quick else "fig14"
    if quick:
        ds = make_dataset("fs", n=QUICK_N, seed=0)
        cfg = _quick_build_config()
        m_train, m_test = 32, 48
    else:
        ds = C.dataset()
        cfg = C.small_build_config()
        m_train, m_test = C.DEFAULT_M, 48

    # ---- Fig 14 / §7: distribution shift LAP -> UNI ------------------------
    # Train on the concentrated LAP workload (budget spent in its hot
    # region), then shift traffic to UNI: queries land where the layout is
    # coarse and the Eq.1 cost regresses -- the §7.5 dynamic scenario.
    lap_train = make_workload(ds, m=m_train, dist="LAP", seed=1)
    t0 = time.perf_counter()
    art = build_wisk(ds, lap_train, cfg)
    initial_bt = time.perf_counter() - t0
    # post-shift cost averaged over several held-out test workloads: single
    # workloads of tens of queries carry seed noise comparable to the
    # warm-vs-cold gap itself
    uni_tests = [make_workload(ds, m=m_test, dist="UNI", seed=s) for s in (21, 51, 52)]
    lap_test = make_workload(ds, m=m_test, dist="LAP", seed=21)
    pre = _mean_cost(art.index, ds, lap_test)
    stale = float(np.mean([_mean_cost(art.index, ds, t) for t in uni_tests]))
    rows.append(C.row(f"{tag}/pre-shift", initial_bt * 1e6, f"cost={pre:.1f}"))
    rows.append(C.row(f"{tag}/stale-layout", 0.0, f"cost={stale:.1f};regression={stale/pre:.2f}x"))

    uni_train = make_workload(ds, m=m_train, dist="UNI", seed=2)
    t0 = time.perf_counter()
    cold = build_wisk(ds, uni_train, cfg)
    cold_bt = time.perf_counter() - t0
    cold_cost = float(np.mean([_mean_cost(cold.index, ds, t) for t in uni_tests]))
    rows.append(C.row(f"{tag}/cold-rebuild", cold_bt * 1e6, f"cost={cold_cost:.1f};build_s={cold_bt:.2f}"))

    t0 = time.perf_counter()
    warm = warm_start_rebuild(ds, uni_train, art, cfg, regress_ratio=1.0)
    warm_bt = time.perf_counter() - t0
    warm_cost = float(np.mean([_mean_cost(warm.index, ds, t) for t in uni_tests]))
    rows.append(
        C.row(
            f"{tag}/warm-rebuild",
            warm_bt * 1e6,
            f"cost={warm_cost:.1f};build_s={warm_bt:.2f};cost_vs_cold={warm_cost/cold_cost:.3f}"
            f";speedup={cold_bt/max(warm_bt,1e-9):.1f}x"
            f";refined={warm.counters['refined_leaves']}/{art.partition.clusters.k}",
        )
    )
    if quick:
        assert warm_cost <= 1.10 * cold_cost, (
            f"warm-start post-shift cost {warm_cost:.1f} not within 10% of cold {cold_cost:.1f}"
        )
        assert warm_bt < cold_bt, (
            f"warm-start build {warm_bt:.2f}s not cheaper than cold {cold_bt:.2f}s"
        )

    # ---- Fig 15 / §7: object insertion ------------------------------------
    # serve-through-deltas (no rebuild) vs a cold rebuild over the merged set
    tag15 = "fig15q" if quick else "fig15"
    snap = IndexSnapshot.build(art.index, ds)
    log = DeltaLog(art.index, ds, snap)
    rng = np.random.default_rng(0)
    n_ins = 200 if quick else 400
    src = rng.choice(ds.n, n_ins)
    new_locs = np.clip(
        ds.locs[src] + rng.normal(0, 0.01, (n_ins, 2)).astype(np.float32), 0, 1
    )
    t0 = time.perf_counter()
    log.insert(new_locs, ds.kw_ids[src])
    log.delete(rng.choice(ds.n, n_ins // 4, replace=False))
    absorb_t = time.perf_counter() - t0
    merged = log.merged_dataset()

    mixed = make_workload(ds, m=m_test, dist="MIX", seed=22)
    t0 = time.perf_counter()
    delta_out = serve_batch(
        snap, mixed.rects, mixed.kw_bitmap,
        max_leaves=art.partition.clusters.k, delta=log.buffer,
    )
    delta_serve_t = time.perf_counter() - t0

    t0 = time.perf_counter()
    cold15 = build_wisk(merged, make_workload(merged, m=m_train, dist="MIX", seed=3), cfg)
    cold15_bt = time.perf_counter() - t0
    cold15_st = execute_serial(cold15.index, merged, mixed)
    delta_cost = float(
        np.mean(
            DEFAULT_W1 * delta_out["nodes_checked"] + DEFAULT_W2 * delta_out["verified"]
        )
    )
    cold15_cost = float(cold15_st.cost.mean())
    rows.append(
        C.row(
            f"{tag15}/serve-through-deltas",
            delta_serve_t / mixed.m * 1e6,
            f"cost={delta_cost:.1f};absorb_s={absorb_t:.3f};buffered={log.buffer.n_buffered()}",
        )
    )
    rows.append(
        C.row(
            f"{tag15}/cold-rebuild",
            cold15_bt * 1e6,
            f"cost={cold15_cost:.1f};build_s={cold15_bt:.2f}"
            f";cost_ratio={delta_cost/max(cold15_cost,1e-9):.2f}",
        )
    )
    # id-exactness of the merged serving path vs ground truth on merged set
    mismatches = 0
    for qi in range(mixed.m):
        got = np.sort(delta_out["ids"][qi][delta_out["ids"][qi] >= 0])
        truth = np.sort(exact_query_result_ids(merged, mixed.rects[qi], mixed.kw_bitmap[qi]))
        mismatches += int(not np.array_equal(got, truth))
    rows.append(C.row(f"{tag15}/delta-exactness", 0.0, f"mismatches={mismatches}/{mixed.m}"))
    if quick:
        assert mismatches == 0, f"{mismatches} delta-served queries diverged from merged truth"
        assert absorb_t < cold15_bt, "absorbing deltas must be cheaper than a cold rebuild"

    # ---- §8: sustained continuous-filter stream ---------------------------
    # FAST's continuous-query scenario: standing geofence subscriptions
    # matched on device against every insert batch in the same step it
    # enters the delta log, with the host SubscriptionOracle replaying the
    # identical event schedule as in-bench A/B ground truth. The stream
    # deliberately crosses every hazard the exactly-once contract names:
    # concentrated sub-streams force insert-buffer growth, deletes free
    # slots for reuse, filters retire mid-stream, and a forced warm-start
    # rebuild swaps the serving generation with notifications still queued.
    tag_s = "streamq" if quick else "stream"
    live = LiveIndex(
        ds, lap_train, cfg, artifacts=art, slots_per_leaf=4 if quick else 8
    )
    orc = SubscriptionOracle()
    srng = np.random.default_rng(7)
    n_subs = 48 if quick else 96
    n_batches, batch = (10, 24) if quick else (20, 48)

    def _sub_kw():
        # hot 8-term head 70% of the time: guarantees real matches instead
        # of a vacuously-exact empty stream (rare-term draws keep the
        # compact-dictionary fallback path in play too)
        k = int(srng.integers(1, 4))
        kw = np.full(4, -1, np.int64)
        pool = 8 if srng.random() < 0.7 else ds.vocab_size
        kw[:k] = srng.choice(pool, size=min(k, pool), replace=False)
        return kw

    for _ in range(n_subs):
        c = srng.random(2)
        w, h = srng.uniform(0.02, 0.25, size=2)
        rect = np.array([c[0] - w, c[1] - h, c[0] + w, c[1] + h], np.float32)
        kw = _sub_kw()
        assert live.subscribe(rect, kw) == orc.subscribe(rect, kw)

    spot = ds.locs[srng.integers(ds.n)]
    match_t = 0.0
    n_objects = 0
    for bi in range(n_batches):
        src = srng.choice(ds.n, batch)
        if bi % 3 == 0:  # concentrated sub-stream: overflows one leaf's slots
            locs = np.clip(
                spot[None, :] + srng.normal(0, 1e-3, (batch, 2)).astype(np.float32),
                0, 1,
            )
        else:
            locs = ds.locs[src]
        kws = ds.kw_ids[src]
        t0 = time.perf_counter()
        ids = live.insert(locs, kws)  # matched against the block in-step
        match_t += time.perf_counter() - t0
        orc.arrive(ids, locs, kws)
        n_objects += batch
        if bi == n_batches // 3:  # churn: retire filters + delete objects
            for sid in range(6):
                assert live.unsubscribe(sid) and orc.unsubscribe(sid)
            live.delete(ids[: batch // 2])
        if bi == n_batches // 2:  # generation swap mid-stream, queue intact
            live.serve(
                lap_test.rects, lap_test.kw_bitmap,
                max_leaves=art.partition.clusters.k,
            )
            assert live.maybe_rebuild(force=True)
    got = live.drain_notifications()
    want = orc.drain()
    stream_exact = bool(np.array_equal(got, want))
    second = live.drain_notifications()
    subs = live.subscriptions
    rows.append(
        C.row(
            f"{tag_s}/sustained-stream",
            match_t / max(n_objects, 1) * 1e6,
            f"objects={n_objects};subs={n_subs};matched={subs.matched_total}"
            f";emitted={subs.emitted_total};slots={subs.n_slots};swaps={live.swaps}",
        )
    )
    rows.append(
        C.row(
            f"{tag_s}/oracle-ab",
            0.0,
            f"exact={int(stream_exact)};oracle_matched={orc.matched_total}"
            f";second_drain={second.shape[0]}",
        )
    )
    if quick:
        assert stream_exact, "device notification stream diverged from the oracle"
        assert second.shape[0] == 0, "second drain re-emitted notifications"
        assert subs.matched_total == orc.matched_total > 0
        assert live.swaps >= 1, "stream lane must cross a rebuild swap"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--quick", action="store_true",
        help="tiny-index CI smoke (asserts warm-start cost/time + delta exactness)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(quick=args.quick):
        print(r, flush=True)


if __name__ == "__main__":
    main()
