"""Fig. 11: query time vs dataset size (OSM-like scaling)."""
from . import common as C
from repro.baselines.learned import build_floodt, build_lsti


def run():
    rows = []
    for n in (2000, 8000, 24000):
        ds = C.dataset("fs", n)
        test = C.workload("fs", n, 24, "MIX", 0.0005, 5, 10)
        art = C.wisk_index(n=n)
        us, st = C.time_queries(art.index, ds, test)
        rows.append(C.row(f"fig11/n{n}/wisk", us, f"cost={st.total_cost:.0f}"))
        us, st = C.time_queries(build_floodt(ds, C.workload("fs", n, C.DEFAULT_M, "MIX", 0.0005, 5, 110)), ds, test)
        rows.append(C.row(f"fig11/n{n}/flood-t", us, f"cost={st.total_cost:.0f}"))
        us, st = C.time_queries(build_lsti(ds), ds, test)
        rows.append(C.row(f"fig11/n{n}/lsti", us, f"cost={st.total_cost:.0f}"))
    return rows
