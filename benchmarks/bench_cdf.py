"""Fig. 19: CDF model choice (gauss-only vs NN-only vs mixed)."""
import time

from . import common as C
from repro.core.build import build_wisk


def run():
    rows = []
    ds = C.dataset()
    wl = C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", 0.0005, 5, 117)
    test = C.workload("fs", C.DEFAULT_N, 24, "MIX", 0.0005, 5, 18)
    for mode, force in (("mixed", None), ("gauss-only", "gauss"), ("nn-only", "nn")):
        t0 = time.perf_counter()
        art = build_wisk(ds, wl, C.small_build_config(cdf_force_class=force))
        build_s = time.perf_counter() - t0
        us, st = C.time_queries(art.index, ds, test)
        rows.append(C.row(f"fig19/{mode}", us, f"build_s={build_s:.1f};cost={st.total_cost:.0f}"))
    return rows
