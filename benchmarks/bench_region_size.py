"""Fig. 9: query time vs query region size (fraction of space)."""
from . import common as C
from repro.baselines.conventional import build_grid_index
from repro.baselines.learned import build_floodt


def run():
    rows = []
    ds = C.dataset()
    for region in (0.00005, 0.0005, 0.005):
        test = C.workload("fs", C.DEFAULT_N, 24, "MIX", region, 5, 8)
        art = C.wisk_index(region=region)
        us, st = C.time_queries(art.index, ds, test)
        rows.append(C.row(f"fig9/{region}/wisk", us, f"cost={st.total_cost:.0f}"))
        for name, idx in (
            ("grid", build_grid_index(ds, 8)),
            ("flood-t", build_floodt(ds, C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", region, 5, 108))),
        ):
            us, st = C.time_queries(idx, ds, test)
            rows.append(C.row(f"fig9/{region}/{name}", us, f"cost={st.total_cost:.0f}"))
    return rows
