"""Shared benchmark context: cached datasets, workloads, and index builds.

Benchmark scale is laptop-sized (single CPU core): datasets of a few
thousand objects, workloads of tens of queries. Relative orderings (the
paper's claims) are what we measure; EXPERIMENTS.md maps each benchmark to
its paper table/figure.
"""
from __future__ import annotations

import time
from functools import lru_cache
from typing import Dict, Tuple

import numpy as np

from repro.core.build import BuildConfig, build_wisk
from repro.core.dqn import DQNConfig
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.core.query import execute_serial
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload

DEFAULT_N = 4000
DEFAULT_M = 48


@lru_cache(maxsize=8)
def dataset(profile: str = "fs", n: int = DEFAULT_N, seed: int = 0):
    return make_dataset(profile, n=n, seed=seed)


@lru_cache(maxsize=32)
def workload(profile: str, n: int, m: int, dist: str, region: float, nkw: int, seed: int):
    ds = dataset(profile, n)
    return make_workload(ds, m=m, dist=dist, region_frac=region, n_keywords=nkw, seed=seed)


def small_build_config(**over) -> BuildConfig:
    cfg = BuildConfig(
        partition=PartitionConfig(max_clusters=32, n_steps=50, n_restarts=2),
        packing=PackingConfig(epochs=4, max_label_queries=16, dqn=DQNConfig()),
        cdf_train_steps=80,
    )
    for k, v in over.items():
        setattr(cfg, k, v)
    return cfg


_WISK_CACHE: Dict[tuple, object] = {}


def wisk_index(profile="fs", n=DEFAULT_N, dist="MIX", region=0.0005, nkw=5, seed=0, **cfg_over):
    key = (profile, n, dist, region, nkw, seed, tuple(sorted(cfg_over.items())))
    if key not in _WISK_CACHE:
        ds = dataset(profile, n)
        wl = workload(profile, n, DEFAULT_M, dist, region, nkw, seed + 100)
        _WISK_CACHE[key] = build_wisk(ds, wl, small_build_config(**cfg_over))
    return _WISK_CACHE[key]


def time_queries(index, ds, wl, reps: int = 3) -> Tuple[float, object]:
    """Mean per-query serial wall time (us) + stats."""
    st = execute_serial(index, ds, wl)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        st = execute_serial(index, ds, wl)
    dt = (time.perf_counter() - t0) / reps
    return dt / wl.m * 1e6, st


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.2f},{derived}"
