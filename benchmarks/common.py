"""Shared benchmark context: cached datasets, workloads, and index builds.

Benchmark scale is laptop-sized (single CPU core): datasets of a few
thousand objects, workloads of tens of queries. Relative orderings (the
paper's claims) are what we measure; EXPERIMENTS.md maps each benchmark to
its paper table/figure.

Every measurement is a ``Record`` (``row()`` constructs one): it prints as
the historical ``name,us_per_call,derived`` CSV row, and it serializes to
the persistent scoreboard's JSON schema (EXPERIMENTS.md section Scoreboard)
-- structured name / wall-us / parsed derived counters plus the run's config
fingerprint, git sha, and date, so committed ``BENCH_*.json`` baselines can
be diffed mechanically by tools/bench_compare.py.
"""
from __future__ import annotations

import dataclasses
import datetime
import hashlib
import json
import re
import subprocess
import time
from functools import lru_cache
from typing import Dict, List, Tuple

import numpy as np

from repro.core.build import BuildConfig, build_wisk
from repro.core.dqn import DQNConfig
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.core.query import execute_serial
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload

DEFAULT_N = 4000
DEFAULT_M = 48


@lru_cache(maxsize=8)
def dataset(profile: str = "fs", n: int = DEFAULT_N, seed: int = 0):
    return make_dataset(profile, n=n, seed=seed)


@lru_cache(maxsize=32)
def workload(profile: str, n: int, m: int, dist: str, region: float, nkw: int, seed: int):
    ds = dataset(profile, n)
    return make_workload(ds, m=m, dist=dist, region_frac=region, n_keywords=nkw, seed=seed)


def small_build_config(**over) -> BuildConfig:
    cfg = BuildConfig(
        partition=PartitionConfig(max_clusters=32, n_steps=50, n_restarts=2),
        packing=PackingConfig(epochs=4, max_label_queries=16, dqn=DQNConfig()),
        cdf_train_steps=80,
    )
    for k, v in over.items():
        setattr(cfg, k, v)
    return cfg


_WISK_CACHE: Dict[tuple, object] = {}


def wisk_index(profile="fs", n=DEFAULT_N, dist="MIX", region=0.0005, nkw=5, seed=0, **cfg_over):
    key = (profile, n, dist, region, nkw, seed, tuple(sorted(cfg_over.items())))
    if key not in _WISK_CACHE:
        ds = dataset(profile, n)
        wl = workload(profile, n, DEFAULT_M, dist, region, nkw, seed + 100)
        _WISK_CACHE[key] = build_wisk(ds, wl, small_build_config(**cfg_over))
    return _WISK_CACHE[key]


def time_queries(index, ds, wl, reps: int = 3) -> Tuple[float, object]:
    """Mean per-query serial wall time (us) + stats."""
    st = execute_serial(index, ds, wl)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        st = execute_serial(index, ds, wl)
    dt = (time.perf_counter() - t0) / reps
    return dt / wl.m * 1e6, st


# --------------------------------------------- persistent scoreboard records
SCHEMA_VERSION = 1

# key=value tokens inside a derived string; values may be bracketed lists
# ("widths=[8,16]") or braced dicts, else run to the next ';'/whitespace
_DERIVED_TOKEN = re.compile(r"(\w+)=((?:\[[^\]]*\])|(?:\{[^}]*\})|[^;\s]+)")
_INT = re.compile(r"^-?\d+$")
_FLOAT = re.compile(r"^-?\d+(?:\.\d+)?(?:[eE]-?\d+)?x?$")


def _coerce(value: str):
    """int / float (``1.23x`` ratios included) / verbatim string."""
    if _INT.match(value):
        return int(value)
    if _FLOAT.match(value):
        return float(value[:-1] if value.endswith("x") else value)
    return value


def parse_derived(derived: str) -> Dict[str, object]:
    """The ``key=value`` tokens of a derived string as a typed dict.

    Free text between tokens (units, caveat parentheticals) is dropped --
    it is commentary for the CSV reader, not scoreboard data.
    """
    return {k: _coerce(v) for k, v in _DERIVED_TOKEN.findall(derived or "")}


@dataclasses.dataclass
class Record:
    """One benchmark measurement.

    ``str(record)`` is the historical ``name,us_per_call,derived`` CSV row
    (every bench module's ``main()`` prints rows verbatim); ``to_json()``
    is the scoreboard form with the derived counters parsed into a dict.
    """

    name: str
    us_per_call: float
    derived: str = ""

    def __str__(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"

    @property
    def derived_dict(self) -> Dict[str, object]:
        return parse_derived(self.derived)

    def to_json(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "us_per_call": round(float(self.us_per_call), 2),
            "derived": self.derived_dict,
            "derived_raw": self.derived,
        }


def row(name: str, us: float, derived: str = "") -> Record:
    return Record(name, float(us), derived)


def git_sha() -> str:
    """The repo's HEAD sha (short), or "unknown" outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True,
        )
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run_config(quick: bool = False) -> Dict[str, object]:
    """The knobs that shape every benchmark's numbers -- the scoreboard's
    comparability fingerprint. Two runs whose fingerprints differ must not
    be diffed for regressions (bench_compare refuses)."""
    import jax

    return {
        "profile": "fs",
        "default_n": DEFAULT_N,
        "default_m": DEFAULT_M,
        "quick": bool(quick),
        "backend": jax.default_backend(),
        "device_count": len(jax.devices()),
        "jax": jax.__version__,
    }


def config_fingerprint(config: Dict[str, object]) -> str:
    return hashlib.sha1(
        json.dumps(config, sort_keys=True).encode()
    ).hexdigest()[:12]


def scoreboard_payload(module: str, records: List[Record], quick: bool = False,
                       elapsed_s: float = 0.0) -> Dict[str, object]:
    """The ``BENCH_<module>.json`` document (schema SCHEMA_VERSION)."""
    config = run_config(quick)
    return {
        "schema": SCHEMA_VERSION,
        "module": module,
        "git_sha": git_sha(),
        "date": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "config": config,
        "config_fingerprint": config_fingerprint(config),
        "elapsed_s": round(float(elapsed_s), 2),
        "records": [r.to_json() for r in records],
    }


def write_scoreboard(path, payload: Dict[str, object]) -> None:
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=False)
        f.write("\n")
