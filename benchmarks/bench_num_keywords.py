"""Fig. 10: query time vs number of query keywords."""
from . import common as C
from repro.baselines.conventional import build_grid_index
from repro.baselines.learned import build_floodt


def run():
    rows = []
    ds = C.dataset()
    for nkw in (1, 3, 5, 7):
        test = C.workload("fs", C.DEFAULT_N, 24, "MIX", 0.0005, nkw, 9)
        art = C.wisk_index(nkw=nkw)
        us, st = C.time_queries(art.index, ds, test)
        rows.append(C.row(f"fig10/k{nkw}/wisk", us, f"cost={st.total_cost:.0f}"))
        for name, idx in (
            ("grid", build_grid_index(ds, 8)),
            ("flood-t", build_floodt(ds, C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", 0.0005, nkw, 109))),
        ):
            us, st = C.time_queries(idx, ds, test)
            rows.append(C.row(f"fig10/k{nkw}/{name}", us, f"cost={st.total_cost:.0f}"))
    return rows
