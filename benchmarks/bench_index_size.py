"""Table 3: index structure sizes."""
from . import common as C
from repro.baselines.conventional import build_grid_index, build_str_rtree
from repro.baselines.learned import build_floodt, build_lsti, build_tfi


def run():
    rows = []
    ds = C.dataset()
    art = C.wisk_index()
    rows.append(C.row("table3/wisk", 0.0, f"bytes={art.index.nbytes()}"))
    for name, idx in (
        ("grid", build_grid_index(ds, 8)),
        ("str-rtree", build_str_rtree(ds)),
        ("flood-t", build_floodt(ds, C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", 0.0005, 5, 112))),
        ("lsti", build_lsti(ds)),
    ):
        rows.append(C.row(f"table3/{name}", 0.0, f"bytes={idx.nbytes()}"))
    rows.append(C.row("table3/tfi", 0.0, f"bytes={build_tfi(ds).nbytes()}"))
    return rows
