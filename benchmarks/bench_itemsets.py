"""Fig. 20: effect of frequent-itemset mining vs #query keywords."""
from . import common as C
from repro.core.build import build_wisk


def run():
    rows = []
    ds = C.dataset()
    for nkw in (1, 3, 5):
        wl = C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", 0.0005, nkw, 119)
        test = C.workload("fs", C.DEFAULT_N, 24, "MIX", 0.0005, nkw, 20)
        for tag, use in (("fi", True), ("no-fi", False)):
            art = build_wisk(ds, wl, C.small_build_config(use_itemsets=use))
            us, st = C.time_queries(art.index, ds, test)
            rows.append(C.row(f"fig20/k{nkw}/{tag}", us, f"cost={st.total_cost:.0f}"))
    return rows
