"""Table 4: index construction time (incl. Accelerated WISK)."""
import time

from . import common as C
from repro.core.build import build_wisk
from repro.baselines.conventional import build_grid_index, build_str_rtree
from repro.baselines.learned import build_floodt, build_lsti


def run():
    rows = []
    ds = C.dataset()
    wl = C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", 0.0005, 5, 113)

    t0 = time.perf_counter()
    art = build_wisk(ds, wl, C.small_build_config())
    rows.append(C.row("table4/wisk", (time.perf_counter() - t0) * 1e6,
                      f"phase_times={ {k: round(v, 2) for k, v in art.timings.items()} }"))
    t0 = time.perf_counter()
    art_a = build_wisk(ds, wl, C.small_build_config(accelerated=True))
    rows.append(C.row("table4/wisk-accelerated", (time.perf_counter() - t0) * 1e6, ""))
    for name, fn in (
        ("grid", lambda: build_grid_index(ds, 8)),
        ("str-rtree", lambda: build_str_rtree(ds)),
        ("flood-t", lambda: build_floodt(ds, wl)),
        ("lsti", lambda: build_lsti(ds)),
    ):
        t0 = time.perf_counter()
        fn()
        rows.append(C.row(f"table4/{name}", (time.perf_counter() - t0) * 1e6, ""))
    return rows
