"""Table 4: index construction time (incl. Accelerated WISK).

Also the A/B for the construction execution strategies (DESIGN.md §5): the
batched (frontier-parallel splits + scan-compiled RL packing) and sequential
(per-subspace / per-env-step host loops) modes are reported side by side
with per-phase timings and round/dispatch counters.
"""
import time

from . import common as C
from repro.core.build import BuildConfig, build_wisk
from repro.core.dqn import DQNConfig
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.baselines.conventional import build_grid_index, build_str_rtree
from repro.baselines.learned import build_floodt, build_lsti


def _notes(art) -> str:
    phases = {k: round(v, 2) for k, v in art.timings.items()}
    return f"phase_times={phases};counters={art.counters}"


def _quick_config(**over) -> BuildConfig:
    """Sub-minute build: fewer partition steps/restarts, two RL epochs."""
    cfg = BuildConfig(
        partition=PartitionConfig(max_clusters=12, n_steps=20, n_restarts=1),
        packing=PackingConfig(epochs=2, max_label_queries=8, dqn=DQNConfig()),
        cdf_train_steps=30,
    )
    for k, v in over.items():
        setattr(cfg, k, v)
    return cfg


def run_quick():
    """CI-sized Table 4: batched-vs-sequential construction A/B (with the
    dispatch-reduction counter the §5 batching claim rests on) plus the
    conventional baselines, on a ~1/4-scale dataset and quick build config."""
    rows = []
    ds = C.dataset("fs", 1200)
    wl = C.workload("fs", 1200, 16, "MIX", 0.0005, 5, 113)

    arts = {}
    for mode in ("batched", "sequential"):
        t0 = time.perf_counter()
        arts[mode] = build_wisk(ds, wl, _quick_config(construction=mode))
        name = "table4/wisk" if mode == "batched" else "table4/wisk-sequential"
        rows.append(C.row(name, (time.perf_counter() - t0) * 1e6, _notes(arts[mode])))
    ratio = arts["sequential"].counters["construction_dispatches"] / max(
        arts["batched"].counters["construction_dispatches"], 1
    )
    rows.append(
        C.row(
            "table4/dispatch-reduction",
            0.0,
            f"sequential={arts['sequential'].counters['construction_dispatches']};"
            f"batched={arts['batched'].counters['construction_dispatches']};"
            f"ratio={ratio:.1f}x",
        )
    )
    for name, fn in (
        ("grid", lambda: build_grid_index(ds, 8)),
        ("str-rtree", lambda: build_str_rtree(ds)),
    ):
        t0 = time.perf_counter()
        fn()
        rows.append(C.row(f"table4/{name}", (time.perf_counter() - t0) * 1e6, ""))
    return rows


def run():
    rows = []
    ds = C.dataset()
    wl = C.workload("fs", C.DEFAULT_N, C.DEFAULT_M, "MIX", 0.0005, 5, 113)

    arts = {}
    for mode in ("batched", "sequential"):
        t0 = time.perf_counter()
        arts[mode] = build_wisk(ds, wl, C.small_build_config(construction=mode))
        name = "table4/wisk" if mode == "batched" else "table4/wisk-sequential"
        rows.append(C.row(name, (time.perf_counter() - t0) * 1e6, _notes(arts[mode])))
    ratio = arts["sequential"].counters["construction_dispatches"] / max(
        arts["batched"].counters["construction_dispatches"], 1
    )
    rows.append(
        C.row(
            "table4/dispatch-reduction",
            0.0,
            f"sequential={arts['sequential'].counters['construction_dispatches']};"
            f"batched={arts['batched'].counters['construction_dispatches']};"
            f"ratio={ratio:.1f}x",
        )
    )

    t0 = time.perf_counter()
    art_a = build_wisk(ds, wl, C.small_build_config(accelerated=True))
    rows.append(C.row("table4/wisk-accelerated", (time.perf_counter() - t0) * 1e6, _notes(art_a)))
    for name, fn in (
        ("grid", lambda: build_grid_index(ds, 8)),
        ("str-rtree", lambda: build_str_rtree(ds)),
        ("flood-t", lambda: build_floodt(ds, wl)),
        ("lsti", lambda: build_lsti(ds)),
    ):
        t0 = time.perf_counter()
        fn()
        rows.append(C.row(f"table4/{name}", (time.perf_counter() - t0) * 1e6, ""))
    return rows
