"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--only serving,knn`` filters
(comma-separated substrings; an unmatched filter is an error that lists the
valid module names). ``--json`` additionally persists the scoreboard modules'
records as ``BENCH_<module>.json`` documents (git-sha-stamped; see
EXPERIMENTS.md section Scoreboard) into ``--out-dir``; ``--quick`` runs each
module's CI-sized quick path where one exists. Committed baselines at the
repo root are refreshed by re-running with ``--json --quick --out-dir .``
and diffed against fresh runs by tools/bench_compare.py.
"""
import argparse
import importlib
import inspect
import sys
import time
import traceback
from pathlib import Path

MODULES = [
    "bench_distribution",   # Fig 8
    "bench_region_size",    # Fig 9
    "bench_num_keywords",   # Fig 10
    "bench_scalability",    # Fig 11
    "bench_robustness",     # Fig 12
    "bench_index_size",     # Table 3
    "bench_construction",   # Table 4
    "bench_accel",          # Fig 13
    "bench_dynamic",        # Figs 14/15 + DESIGN.md section 7 maintenance A/B
    "bench_packing",        # Figs 16/17/18
    "bench_cdf",            # Fig 19
    "bench_itemsets",       # Fig 20
    "bench_action_mask",    # Fig 21
    "bench_knn",            # Fig 23 (appendix)
    "bench_serving",        # TPU-path serving (DESIGN.md section 3)
    "bench_roofline",       # EXPERIMENTS.md roofline summary
]

# the persistent-scoreboard modules: committed BENCH_*.json baselines live at
# the repo root and CI re-runs + diffs them (EXPERIMENTS.md section Scoreboard)
SCOREBOARD = {
    "bench_serving": "BENCH_serving.json",
    "bench_knn": "BENCH_knn.json",
    "bench_construction": "BENCH_construction.json",
    "bench_dynamic": "BENCH_dynamic.json",
    "bench_roofline": "BENCH_roofline.json",
}


def select_modules(only):
    """The MODULES entries matching the comma-separated substring filter
    (None -> all). Raises ValueError when a filter matches nothing."""
    if not only:
        return list(MODULES)
    pats = [p.strip() for p in only.split(",") if p.strip()]
    selected = [m for m in MODULES if any(p in m for p in pats)]
    if not selected:
        raise ValueError(
            f"--only {only!r} matches no benchmark module; valid names: "
            + ", ".join(MODULES)
        )
    return selected


def run_module(mod, quick: bool):
    """The module's record list: ``run_quick()`` when quick and available,
    else ``run(quick=True)`` when the signature takes it, else ``run()``."""
    if quick and hasattr(mod, "run_quick"):
        return mod.run_quick()
    if quick and "quick" in inspect.signature(mod.run).parameters:
        return mod.run(quick=True)
    return mod.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters on module names")
    ap.add_argument("--quick", action="store_true",
                    help="run each module's CI-sized quick path if it has one")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<module>.json for the scoreboard modules")
    ap.add_argument("--out-dir", default=".",
                    help="directory for --json output (default: cwd, i.e. the "
                         "committed-baseline location when run from the repo root)")
    args = ap.parse_args()
    try:
        selected = select_modules(args.only)
    except ValueError as e:
        sys.exit(str(e))
    out_dir = Path(args.out_dir)
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in selected:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            records = run_module(mod, args.quick)
            for row in records:
                print(row, flush=True)
            elapsed = time.time() - t0
            if args.json and mod_name in SCOREBOARD:
                from . import common as C

                out_dir.mkdir(parents=True, exist_ok=True)
                path = out_dir / SCOREBOARD[mod_name]
                C.write_scoreboard(
                    path,
                    C.scoreboard_payload(mod_name, list(records),
                                         quick=args.quick, elapsed_s=elapsed),
                )
                print(f"# wrote {path}", flush=True)
            print(f"# {mod_name} done in {elapsed:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {mod_name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
