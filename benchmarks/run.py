"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--only fig8`` filters.
"""
import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "bench_distribution",   # Fig 8
    "bench_region_size",    # Fig 9
    "bench_num_keywords",   # Fig 10
    "bench_scalability",    # Fig 11
    "bench_robustness",     # Fig 12
    "bench_index_size",     # Table 3
    "bench_construction",   # Table 4
    "bench_accel",          # Fig 13
    "bench_dynamic",        # Figs 14/15 + DESIGN.md section 7 maintenance A/B
    "bench_packing",        # Figs 16/17/18
    "bench_cdf",            # Fig 19
    "bench_itemsets",       # Fig 20
    "bench_action_mask",    # Fig 21
    "bench_knn",            # Fig 23 (appendix)
    "bench_serving",        # TPU-path serving (DESIGN.md section 3)
    "bench_roofline",       # EXPERIMENTS.md roofline summary
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on module name")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            for row in mod.run():
                print(row, flush=True)
            print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {mod_name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
