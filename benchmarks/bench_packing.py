"""Figs. 16/17/18: processing-time split, packing method, hierarchy effect."""
from . import common as C
from repro.baselines.conventional import build_cdir_over_clusters
from repro.core.index import flat_index
from repro.core.query import execute_serial


def run():
    rows = []
    ds = C.dataset()
    test = C.workload("fs", C.DEFAULT_N, 24, "MIX", 0.0005, 5, 16)
    art = C.wisk_index()
    st = execute_serial(art.index, ds, test)
    # Fig 16: leaf (verification) vs non-leaf (filtering) cost split
    leaf_cost = float(st.verified.sum())
    filt_cost = 0.1 * float(st.nodes_accessed.sum())
    rows.append(C.row("fig16/leaf-vs-filter", 0.0,
                      f"verify={leaf_cost:.0f};filter={filt_cost:.0f};leaf_share={leaf_cost/(leaf_cost+filt_cost):.2f}"))
    # Fig 17: RL packing vs CDIR-style packing over the SAME bottom clusters
    cdir = build_cdir_over_clusters(ds, art.partition.clusters)
    st_c = execute_serial(cdir, ds, test)
    rows.append(C.row("fig17/rl-packing", 0.0, f"nodes={st.nodes_accessed.sum()}"))
    rows.append(C.row("fig17/cdir-packing", 0.0, f"nodes={st_c.nodes_accessed.sum()}"))
    # Fig 18: flat vs hierarchical
    st_f = execute_serial(flat_index(ds, art.partition.clusters), ds, test)
    rows.append(C.row("fig18/flat", 0.0, f"nodes={st_f.nodes_accessed.sum()}"))
    rows.append(C.row("fig18/hierarchy", 0.0, f"nodes={st.nodes_accessed.sum()}"))
    return rows
