"""Cross-path Boolean kNN parity: serial best-first / level-sync / device.

The kNN serving path (DESIGN.md §6) is the third execution path pinned by
the cross-path parity contract: on seeded randomized datasets and indexes,
``knn_query`` (serial best-first), ``knn_level_sync`` (vectorized numpy
distance-bounded sweep) and ``serve.engine.retrieve_knn`` (device
distance-bounded frontier descent) must return *identical* id sequences --
not just sets -- because all three share the (dist^2, object id)
lexicographic tie-break. Brute force over the whole dataset is the external
ground truth. Also covered: distance ties, k larger than the number of
matching objects, empty-keyword queries, padded batches, and the pruning
gate (the bounded descent verifies fewer leaf blocks than an exhaustive
leaf scan).
"""
import numpy as np
import pytest

from repro.core.query import knn_level_sync, knn_query
from repro.core.types import GeoTextDataset
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.launch.wisk_serve import pad_knn_queries_to_bucket, serve_knn_batch
from repro.serve.engine import IndexSnapshot, retrieve_knn

from test_query_parity import _build_index, _grid_clusters, flat_index


def _points_from(wl) -> np.ndarray:
    return np.stack(
        [(wl.rects[:, 0] + wl.rects[:, 2]) / 2, (wl.rects[:, 1] + wl.rects[:, 3]) / 2], 1
    ).astype(np.float32)


def _brute_knn(ds, point, kw_bm, k):
    match = np.any(ds.kw_bitmap & kw_bm[None, :], axis=1)
    dx = ds.locs[:, 0] - np.float32(point[0])
    dy = ds.locs[:, 1] - np.float32(point[1])
    d2 = (dx * dx + dy * dy).astype(np.float32)
    d2[~match] = np.inf
    order = np.lexsort((np.arange(ds.n), d2))[:k]
    return order[np.isfinite(d2[order])].astype(np.int32)


def _trim(row):
    return row[row >= 0]


@pytest.mark.parametrize("seed,levels,k", [(0, 2, 1), (1, 3, 10), (2, 2, 33), (3, 1, 5)])
def test_knn_all_paths_identical(seed, levels, k):
    ds = make_dataset("fs", n=1500, seed=seed)
    if levels == 1:
        index = flat_index(ds, _grid_clusters(ds, 5))
    else:
        index, _ = _build_index(ds, g=6, levels=levels)
    wl = make_workload(ds, m=16, dist="MIX", seed=seed + 20)
    points = _points_from(wl)
    bw = IndexSnapshot.build(index, ds)
    sync = knn_level_sync(index, ds, points, wl.kw_bitmap, k)
    dev = retrieve_knn(bw, points, wl.kw_bitmap, k)
    for qi in range(wl.m):
        serial = knn_query(index, ds, points[qi], wl.kw_bitmap[qi], k)
        want = _brute_knn(ds, points[qi], wl.kw_bitmap[qi], k)
        np.testing.assert_array_equal(serial.ids, want)
        np.testing.assert_array_equal(_trim(sync["ids"][qi]), want)
        np.testing.assert_array_equal(_trim(dev["ids"][qi]), want)
        # distances ride along sorted ascending on every path (XLA may fuse
        # dx*dx+dy*dy into an FMA, so allow 1-ULP drift vs the numpy host)
        assert np.all(np.diff(serial.dist2) >= 0)
        np.testing.assert_allclose(dev["dist2"][qi][: want.size], serial.dist2, rtol=1e-6)


def test_knn_distance_ties_break_by_smallest_id():
    """Clusters of objects at *identical* coordinates straddling the k
    boundary: every path must keep the smallest object ids."""
    ds0 = make_dataset("fs", n=1200, seed=7)
    locs = ds0.locs.copy()
    locs[100:140] = locs[100]  # 40 objects, one exact location
    locs[300:310] = locs[300]
    ds = GeoTextDataset.from_ids(locs, ds0.kw_ids, ds0.vocab_size)
    index, _ = _build_index(ds, g=6, levels=2)
    bw = IndexSnapshot.build(index, ds)
    point = locs[100].astype(np.float32)
    kw_bm = np.bitwise_or.reduce(ds.kw_bitmap[100:140], axis=0)[None, :]
    pts = np.tile(point, (1, 1))
    for k in (3, 10, 39):
        serial = knn_query(index, ds, point, kw_bm[0], k)
        sync = knn_level_sync(index, ds, pts, kw_bm, k)
        dev = retrieve_knn(bw, pts, kw_bm, k)
        want = _brute_knn(ds, point, kw_bm[0], k)
        np.testing.assert_array_equal(serial.ids, want)
        np.testing.assert_array_equal(_trim(sync["ids"][0]), want)
        np.testing.assert_array_equal(_trim(dev["ids"][0]), want)
        # the tied block forces smallest-id selection at the boundary
        assert np.array_equal(np.sort(want), want)


def test_knn_k_exceeds_matches_and_edge_ks():
    ds = make_dataset("fs", n=900, seed=9)
    index, _ = _build_index(ds, g=5, levels=2)
    bw = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=6, dist="UNI", n_keywords=2, seed=11)
    points = _points_from(wl)
    k = ds.n + 50  # more than any query can match
    dev = retrieve_knn(bw, points, wl.kw_bitmap, k)
    sync = knn_level_sync(index, ds, points, wl.kw_bitmap, k)
    for qi in range(wl.m):
        serial = knn_query(index, ds, points[qi], wl.kw_bitmap[qi], k)
        want = _brute_knn(ds, points[qi], wl.kw_bitmap[qi], k)
        assert want.size < k  # genuinely short results
        np.testing.assert_array_equal(serial.ids, want)
        np.testing.assert_array_equal(_trim(sync["ids"][qi]), want)
        np.testing.assert_array_equal(_trim(dev["ids"][qi]), want)
    # k <= 0 returns empty everywhere, no errors
    assert knn_query(index, ds, points[0], wl.kw_bitmap[0], 0).ids.size == 0
    assert retrieve_knn(bw, points, wl.kw_bitmap, 0)["ids"].shape == (wl.m, 0)
    assert knn_level_sync(index, ds, points, wl.kw_bitmap, -1)["ids"].shape == (wl.m, 0)


def test_knn_empty_keyword_queries_and_padded_batch():
    """serve_knn_batch pads the batch to its power-of-two bucket; pad queries
    and empty-keyword queries must verify nothing and return all -1."""
    ds = make_dataset("fs", n=1100, seed=13)
    index, _ = _build_index(ds, g=5, levels=2)
    bw = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=13, dist="MIX", seed=14)  # not a power of two
    points = _points_from(wl)
    bms = wl.kw_bitmap.copy()
    bms[4] = 0  # empty-keyword query inside the batch
    pts, pbms, m = pad_knn_queries_to_bucket(points, bms)
    assert m == 13 and pts.shape[0] == 16
    out = serve_knn_batch(bw, points, bms, k=7)
    assert out["ids"].shape == (13, 7)
    direct = retrieve_knn(bw, points, bms, 7)
    np.testing.assert_array_equal(out["ids"], direct["ids"][:13])
    np.testing.assert_array_equal(out["nodes_checked"], direct["nodes_checked"][:13])
    assert (out["ids"][4] == -1).all()
    assert out["verified"][4] == 0 and out["leaves_verified"][4] == 0
    for qi in range(13):
        serial = knn_query(index, ds, points[qi], bms[qi], 7)
        np.testing.assert_array_equal(_trim(out["ids"][qi]), serial.ids)


def test_knn_bounded_descent_prunes_leaves():
    """The acceptance gate of the kNN rewrite: the distance-bounded descent
    verifies strictly fewer leaf blocks than an exhaustive leaf scan, and the
    pruned counter shows the bound firing."""
    ds = make_dataset("fs", n=2500, seed=5)
    index, _ = _build_index(ds, g=8, levels=3)
    bw = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=24, dist="MIX", seed=6)
    points = _points_from(wl)
    out = retrieve_knn(bw, points, wl.kw_bitmap, 10)
    n_leaf = index.levels[-1].n
    assert out["leaves_verified"].sum() < wl.m * n_leaf / 2  # pruning ratio > 2
    assert out["pruned"].sum() > 0
    # and the counters stay consistent with the host mirror's verify set
    sync = knn_level_sync(index, ds, points, wl.kw_bitmap, 10)
    for a, b in zip(out["ids"], sync["ids"]):
        np.testing.assert_array_equal(_trim(a), _trim(b))


# --------------------------------------------- bf16 sweep (ROADMAP item 5)
def test_knn_bf16_sweep_matches_f32_exactly():
    """``knn_dtype="bf16"`` prunes the bounded sweep on bf16-rounded node
    distances but must stay id- and distance-identical to f32: object
    distances are exact, and a conservative risk bound retries the batch in
    f32 whenever a rounded-down prune could have clipped a true neighbor."""
    ds = make_dataset("fs", n=2500, seed=5)
    index, _ = _build_index(ds, g=8, levels=3)
    snap = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=24, dist="MIX", seed=6)
    points = _points_from(wl)
    for k in (1, 10):
        f32 = retrieve_knn(snap, points, wl.kw_bitmap, k)
        bf = retrieve_knn(snap, points, wl.kw_bitmap, k, knn_dtype="bf16")
        np.testing.assert_array_equal(f32["ids"], bf["ids"])
        np.testing.assert_array_equal(f32["dist2"], bf["dist2"])
        assert bf["knn_dtype_retried"] in (False, True)
        assert "knn_dtype_retried" not in f32  # flag only on the bf16 path
    with pytest.raises(ValueError, match="knn_dtype"):
        retrieve_knn(snap, points, wl.kw_bitmap, 5, knn_dtype="f16")


def test_knn_bf16_forced_retry_falls_back_to_exact(monkeypatch):
    """When the risk bound reaches the final k-th distance the whole batch
    re-runs in f32. Inflating the risk tolerance to 100% makes every prune
    look risky, so the retry MUST fire -- and the output must be the exact
    f32 answer with ``knn_dtype_retried=True``."""
    import repro.serve.engine as engine

    ds = make_dataset("fs", n=1500, seed=4)
    index, _ = _build_index(ds, g=6, levels=2)
    snap = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=11, dist="MIX", seed=8)
    points = _points_from(wl)
    f32 = retrieve_knn(snap, points, wl.kw_bitmap, 3)
    assert f32["pruned"].sum() > 0  # the bound genuinely fires here
    monkeypatch.setattr(engine, "_BF16_RISK_TOL", 1.0)
    bf = retrieve_knn(snap, points, wl.kw_bitmap, 3, knn_dtype="bf16")
    assert bf["knn_dtype_retried"] is True
    np.testing.assert_array_equal(f32["ids"], bf["ids"])
    np.testing.assert_array_equal(f32["dist2"], bf["dist2"])
