"""Per-architecture smoke tests (reduced configs): one train step + decode
on CPU, asserting output shapes and no NaNs -- as required for each of the
10 assigned architectures. Plus MoE dense-path internals and roofline
param-count sanity."""
import numpy as np
import pytest

# compiling a train step per architecture takes minutes on CPU; excluded
# from the CI fast lane (`pytest -m "not slow"`)
pytestmark = pytest.mark.slow

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.train.step import build_steps


def _batch_for(cfg, B=2, S=64):
    if cfg.family == "encdec":
        return dict(
            frames=jnp.ones((B, S // 4, cfg.d_model), jnp.float32),
            tokens=jnp.ones((B, S), jnp.int32),
        )
    if cfg.family == "vlm":
        P = 16
        return dict(
            patches=jnp.ones((B, P, cfg.d_model), jnp.float32),
            tokens=jnp.ones((B, S - P), jnp.int32),
        )
    return dict(tokens=jnp.ones((B, S), jnp.int32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_and_decode(arch):
    cfg = get_config(arch).reduced()
    steps = build_steps(cfg)
    state = jax.jit(steps.init_state)(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    new_state, metrics = jax.jit(steps.train_step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: NaN loss"
    assert loss > 0
    assert int(new_state["step"]) == 1
    # params changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda p, q: float(jnp.sum(jnp.abs(p.astype(jnp.float32) - q.astype(jnp.float32)))),
            state["params"],
            new_state["params"],
        ),
    )
    assert delta > 0, f"{arch}: train step did not update params"
    # decode one token
    B, S = 2, 64
    cache_sds, _ = steps.cache_spec(B, S)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
    logits, cache = jax.jit(steps.decode_step)(
        new_state["params"], cache, jnp.ones((B, 1), jnp.int32), jnp.int32(0)
    )
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), f"{arch}: NaN decode"


def test_decode_matches_prefill_tinyllama():
    """Decoding tokens one-by-one must match the teacher-forced forward."""
    cfg = get_config("tinyllama-1.1b").reduced()
    steps = build_steps(cfg)
    state = jax.jit(steps.init_state)(jax.random.PRNGKey(1))
    params = state["params"]
    B, S = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    # full forward logits at last position
    logits_full = steps.prefill_step(params, dict(tokens=toks))
    # decode step-by-step
    cache_sds, _ = steps.cache_spec(B, S)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
    dec = jax.jit(steps.decode_step)
    for t in range(S):
        logits_dec, cache = dec(params, cache, toks[:, t : t + 1], jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=0.05, atol=0.05,
    )


def test_moe_routing_respects_capacity():
    from repro.models.moe import _capacity, _route, init_moe, moe_ffn_dense
    from repro.models.layers import split_params

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    params, _ = split_params(init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    x2d = x.reshape(-1, cfg.d_model)
    probs, top_idx = _route(x2d, params["w_router"], cfg)
    assert probs.shape[1] >= cfg.n_experts
    # no token routed to padding experts
    assert int(jnp.max(top_idx)) < cfg.n_experts
    out = moe_ffn_dense(params, x, cfg, {})
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_padded_heads_are_exact():
    """starcoder2 pads 36 -> 48 heads: padded heads must contribute zero."""
    import dataclasses
    from repro.models.layers import attention, init_attention, split_params

    cfg = get_config("starcoder2-7b").reduced()
    cfg = dataclasses.replace(cfg, n_heads=6, n_kv_heads=2, pad_heads_to=8, d_model=96, head_dim=16)
    p_pad, _ = split_params(init_attention(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model), jnp.float32)
    out_pad = attention(p_pad, x, cfg, {})
    # drop the padded per-group slots -> same output (padding is per KV
    # group: g=3 real q-heads of g_pad=4 slots per kv head)
    import numpy as _np

    KV, g, g_pad, hd, d = 2, 3, 4, 16, cfg.d_model
    keep = _np.concatenate([_np.arange(k * g_pad, k * g_pad + g) for k in range(KV)])
    cfg_np = dataclasses.replace(cfg, pad_heads_to=0)
    p_np = dict(
        wq=p_pad["wq"][:, keep], wk=p_pad["wk"], wv=p_pad["wv"], wo=p_pad["wo"][keep]
    )
    out_np = attention(p_np, x, cfg_np, {})
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out_np), atol=1e-5)


def test_causal_impls_agree():
    import dataclasses
    from repro.models.layers import _chunked_causal_attn

    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 4, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 4, 16), jnp.float32)
    a = _chunked_causal_attn(q, k, v, 16, True, "masked_scan")
    b = _chunked_causal_attn(q, k, v, 16, True, "unrolled_prefix")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_param_counts_sane():
    from repro.roofline.analysis import param_counts

    tl = param_counts(get_config("tinyllama-1.1b"))
    assert 0.9e9 < tl["total"] < 1.4e9, tl
    ds3 = param_counts(get_config("deepseek-v3-671b"))
    assert 6.0e11 < ds3["total"] < 7.5e11, ds3
    assert 3.0e10 < ds3["active"] < 5.0e10, ds3  # ~37B active
    star = param_counts(get_config("starcoder2-7b"))
    # counted with gated-MLP convention + 48-head TP padding -> above the
    # published 7.2B; bound documents the accounting, not the HF number
    assert 6e9 < star["total"] < 1.2e10, star
