"""End-to-end behaviour tests: WISK build -> query correctness -> cost wins."""
import numpy as np
import pytest

# the module-scoped build fixture runs the full partition+DQN pipeline (>30s);
# the CI fast lane runs `pytest -m "not slow"` and relies on
# tests/test_query_parity.py for quick cross-path coverage.
pytestmark = pytest.mark.slow

from repro.core.build import BuildConfig, build_wisk
from repro.core.cost import exact_query_results, exact_workload_cost
from repro.core.dqn import DQNConfig
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.core.query import execute_level_sync, execute_serial
from repro.core.types import ClusterSet
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload


@pytest.fixture(scope="module")
def built():
    ds = make_dataset("fs", n=3000, seed=0)
    wl = make_workload(ds, m=48, dist="MIX", seed=1)
    cfg = BuildConfig(
        partition=PartitionConfig(max_clusters=32, n_steps=50, n_restarts=2),
        packing=PackingConfig(epochs=4, max_label_queries=16, dqn=DQNConfig()),
        cdf_train_steps=80,
    )
    art = build_wisk(ds, wl, cfg)
    test_wl = make_workload(ds, m=24, dist="MIX", seed=2)
    return ds, wl, test_wl, art


def test_build_produces_partition(built):
    ds, wl, _, art = built
    clusters = art.partition.clusters
    assert clusters.k > 1, "partitioner should split the space"
    assert clusters.assign.shape[0] == ds.n
    sizes = clusters.sizes()
    assert sizes.sum() == ds.n
    assert (sizes >= 0).all()


def test_serial_query_exact(built):
    ds, _, test_wl, art = built
    st = execute_serial(art.index, ds, test_wl)
    gt = exact_query_results(ds, test_wl)
    got = np.array([len(r) for r in st.results])
    np.testing.assert_array_equal(got, gt)


def test_level_sync_matches_serial(built):
    ds, _, test_wl, art = built
    s1 = execute_serial(art.index, ds, test_wl)
    s2 = execute_level_sync(art.index, ds, test_wl)
    for a, b in zip(s1.results, s2.results):
        np.testing.assert_array_equal(a, b)


def test_wisk_beats_single_cluster(built):
    ds, _, test_wl, art = built
    flat1 = ClusterSet.from_assignment(ds, np.zeros(ds.n, dtype=np.int32))
    c_flat = exact_workload_cost(ds, flat1, test_wl).total
    c_wisk = exact_workload_cost(ds, art.partition.clusters, test_wl).total
    assert c_wisk < c_flat * 0.5, f"expected >2x cost win, got {c_flat} -> {c_wisk}"


def test_hierarchy_reduces_node_accesses(built):
    ds, wl, test_wl, art = built
    from repro.core.index import flat_index

    flat = flat_index(ds, art.partition.clusters)
    st_h = execute_serial(art.index, ds, test_wl)
    st_f = execute_serial(flat, ds, test_wl)
    for a, b in zip(st_h.results, st_f.results):
        np.testing.assert_array_equal(a, b)
    # Triage note: WISK's packing reward (Eq. 5) is the reduction in the
    # expected number of accessed nodes *under the training workload* -- the
    # Eq. 1 cost the optimizer sees. On a held-out workload the hierarchy may
    # access a few more nodes than the flat index (extra upper-level checks
    # that fail to prune, as observed with the seed's test_wl here), and that
    # is expected behaviour for a workload-aware index, not a packing or
    # assembly bug. The guarantee we can assert is on the workload the DQN
    # optimized:
    if art.index.height > 1:
        tr_h = execute_serial(art.index, ds, wl)
        tr_f = execute_serial(flat, ds, wl)
        assert tr_h.nodes_accessed.sum() <= tr_f.nodes_accessed.sum()


def test_batched_engine_matches_serial(built):
    ds, _, test_wl, art = built
    from repro.serve.engine import IndexSnapshot, retrieve_workload

    bw = IndexSnapshot.build(art.index, ds, dense=True)
    st = execute_serial(art.index, ds, test_wl)
    for mode in ("frontier", "dense"):
        out = retrieve_workload(bw, test_wl, max_leaves=art.partition.clusters.k, mode=mode)
        assert (out["overflow"] == 0).all()
        got = [np.sort(row[row >= 0]) for row in out["ids"]]
        for a, b in zip(got, st.results):
            np.testing.assert_array_equal(a, np.sort(b))
        np.testing.assert_array_equal(out["nodes_checked"], st.nodes_accessed)


def test_knn_matches_bruteforce(built):
    ds, _, test_wl, art = built
    from repro.core.query import knn_query

    rng = np.random.default_rng(0)
    for qi in range(4):
        point = rng.uniform(0.2, 0.8, 2).astype(np.float32)
        kw_bm = test_wl.kw_bitmap[qi]
        k = 10
        res = knn_query(art.index, ds, point, kw_bm, k)
        match = np.any(ds.kw_bitmap & kw_bm[None, :], axis=1)
        d2 = ((ds.locs - point) ** 2).sum(1)
        d2[~match] = np.inf
        want = np.argsort(d2)[:k]
        np.testing.assert_allclose(np.sort(d2[res.ids]), np.sort(d2[want]), rtol=1e-6)
        assert res.nodes_accessed > 0 and res.verified >= res.ids.size
