"""Substrate tests: optimizers, checkpoint/restart, straggler, elastic."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.optim.optimizers import adafactor, adamw, cosine_schedule, get_optimizer, sgd
from repro.resilience.elastic import data_skip_offset, plan_remesh
from repro.resilience.straggler import StragglerConfig, StragglerMonitor


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_optimizer_minimizes_quadratic(name):
    init, update = get_optimizer(name)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 8)).astype(np.float32))}
    state = init(params)
    target = jnp.ones((8, 8))

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for t in range(200):
        g = jax.grad(loss)(params)
        upd, state = update(g, state, params, 0.05, jnp.int32(t))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(loss(params)) < l0 * 0.05


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup=10, total=100)
    assert abs(float(f(jnp.int32(0))) - 0.1) < 1e-6  # warmup starts at lr/warmup, not 0
    assert abs(float(f(jnp.int32(9))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) < 1e-6


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    save(str(tmp_path), 7, state)
    got, step = restore(str(tmp_path), state)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10, dtype=np.float32))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_gc_and_latest(tmp_path):
    state = {"a": jnp.zeros(4)}
    for s in [1, 2, 3, 4, 5]:
        save(str(tmp_path), s, state, keep_n=2)
    assert latest_step(str(tmp_path)) == 5
    import pathlib

    kept = sorted(int(p.name.split("_")[1]) for p in pathlib.Path(tmp_path).glob("step_*"))
    assert kept == [4, 5]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    for s in [10, 20]:
        ck.submit(s, {"w": jnp.full((4,), s, jnp.float32)})
    ck.close()
    got, step = restore(str(tmp_path), {"w": jnp.zeros(4)})
    assert step == 20
    np.testing.assert_array_equal(np.asarray(got["w"]), np.full(4, 20.0))


def test_train_restart_resumes(tmp_path):
    from repro.configs import get_config
    from repro.train.loop import TrainConfig, train

    cfg = get_config("tinyllama-1.1b").reduced()
    tc = TrainConfig(n_steps=6, batch=2, seq=32, ckpt_dir=str(tmp_path), ckpt_every=3, log_every=0)
    r1 = train(cfg, tc)
    assert r1.restored_from is None
    # simulate crash + restart: loop restores from latest and continues
    tc2 = TrainConfig(n_steps=8, batch=2, seq=32, ckpt_dir=str(tmp_path), ckpt_every=3, log_every=0)
    r2 = train(cfg, tc2)
    assert r2.restored_from == 6
    assert len(r2.losses) == 2  # only steps 6..8 run
    assert all(np.isfinite(r1.losses)) and all(np.isfinite(r2.losses))


def test_straggler_detector_flags_injected_delay():
    mon = StragglerMonitor(n_hosts=4, cfg=StragglerConfig(min_steps=4, patience=2))
    flagged = []
    for step in range(20):
        times = np.array([0.1, 0.1, 0.1, 0.1])
        if step >= 10:
            times[2] = 0.5  # host 2 becomes slow
        flagged = mon.observe(times)
    assert flagged == [2]


def test_straggler_no_false_positives():
    mon = StragglerMonitor(n_hosts=4)
    rng = np.random.default_rng(0)
    for _ in range(50):
        flagged = mon.observe(0.1 + rng.normal(0, 0.001, 4))
    assert flagged == []


@pytest.mark.parametrize("n,expect_model", [(512, 16), (256, 16), (96, 16), (24, 8), (3, 1)])
def test_plan_remesh(n, expect_model):
    plan = plan_remesh(n)
    assert plan.shape[1] == expect_model
    assert plan.shape[0] * plan.shape[1] + plan.dropped_devices == n


def test_data_skip_deterministic():
    from repro.configs import get_config
    from repro.data.tokens import TokenPipeline

    cfg = get_config("tinyllama-1.1b").reduced()
    p1 = TokenPipeline(vocab=cfg.vocab, seq=16, batch=2, seed=0)
    for _ in range(5):
        p1.next_batch(cfg)
    b5 = p1.next_batch(cfg)
    p2 = TokenPipeline(vocab=cfg.vocab, seq=16, batch=2, seed=0)
    p2.skip_to(5)
    b5b = p2.next_batch(cfg)
    np.testing.assert_array_equal(np.asarray(b5["tokens"]), np.asarray(b5b["tokens"]))
    assert data_skip_offset(10, 256) == 2560
