"""Differential harness for the continuous-filter pub-sub subsystem
(DESIGN.md §8): the device match path vs the brute-force host oracle.

Ground truth throughout is ``core.query.match_subscriptions_bruteforce`` /
``SubscriptionOracle`` -- pure set semantics, none of the bitmap / packed
word-plane / signature machinery the device path uses, so a representation
bug cannot hide on both sides. The contract under test:

* **Kernel parity.** The Pallas ``sub_match`` kernel (and its ``ops``
  wrapper padding) equals the oracle's (N, S) match matrix bit-exactly on
  padded AND ragged block shapes, including empty-keyword and zero-area
  subscriptions, empty-keyword objects, and boundary-exact points.
* **Exactly-once notifications.** Across subscription churn (freed-slot
  reuse), object insert/delete/re-insert churn, delta-buffer growth, a
  ``maybe_rebuild`` generation swap, and repeated drains, the emitted
  (object_id, subscription_id) stream equals the oracle replay exactly --
  no misses, no duplicates -- whether arrivals are matched incrementally
  (``match_arrivals``) or by full-buffer sweeps (``pump``).
* **Compact-vocab independence.** An arriving object whose keywords fall
  outside its leaf's compact vocabulary flips the DeltaLog's sticky
  fallback (PR 9); the notification stream must not care.

Fast deterministic grid indexes cover the delta interactions; the
rebuild-swap atomicity test builds one tiny real WISK index per module
(same budget as test_delta_maintenance.py).
"""
import numpy as np
import pytest

from repro.core.build import BuildConfig
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.core.query import SubscriptionOracle, match_subscriptions_bruteforce
from repro.core.types import ids_to_bitmap
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.kernels.ops import match_subscriptions
from repro.kernels.ref import sub_match_ref
from repro.launch.wisk_serve import LiveIndex
from repro.serve.delta import DeltaLog
from repro.serve.engine import IndexSnapshot
from repro.serve.subscribe import SubscriptionIndex

from test_query_parity import _build_index


# ------------------------------------------------------------ shared helpers
def _rand_rect(rng):
    c = rng.random(2)
    h = rng.random(2) * 0.35
    return np.concatenate([np.maximum(c - h, 0), np.minimum(c + h, 1)]).astype(
        np.float32
    )


def _rand_kw(rng, v, lo=0, hi=4):
    k = rng.integers(lo, hi)
    kw = np.full(max(hi, 1), -1, np.int64)
    if k:
        kw[:k] = rng.choice(v, size=k, replace=False)
    return kw


def _rand_subs(rng, s, v):
    """Ragged subscription set with adversarial members: empty keyword
    sets, zero-area rects, full-universe rects."""
    rects = np.stack([_rand_rect(rng) for _ in range(s)])
    kws = [_rand_kw(rng, v, lo=1) for _ in range(s)]
    if s >= 3:
        kws[0][:] = -1  # empty keyword set: matches nothing
        pt = rng.random(2).astype(np.float32)
        rects[1] = np.concatenate([pt, pt])  # zero-area rect
        rects[2] = (0.0, 0.0, 1.0, 1.0)  # whole universe
    return rects, kws


def _rand_objs(rng, n, v):
    locs = rng.random((n, 2)).astype(np.float32)
    kw = np.stack([_rand_kw(rng, v) for _ in range(n)])
    return locs, kw


# --------------------------------------------------- kernel vs oracle parity
@pytest.mark.parametrize(
    "seed,n,s,v",
    [
        (0, 1, 1, 7),        # single pair (max padding on both axes)
        (1, 7, 5, 33),       # ragged everywhere
        (2, 40, 13, 64),     # ragged vs the bs=128 sub tile
        (3, 130, 129, 200),  # past one full tile on both axes
    ],
)
def test_match_matrix_equals_bruteforce(seed, n, s, v):
    rng = np.random.default_rng(seed)
    rects, kws = _rand_subs(rng, s, v)
    locs, okw = _rand_objs(rng, n, v)
    # a boundary-exact arrival: corner of sub 0's rect, sharing a keyword
    locs[0] = rects[0][:2]
    if s >= 2:
        locs[min(1, n - 1)] = rects[1][:2]  # on the zero-area sub
    obm = ids_to_bitmap(okw.astype(np.int32), v)
    sbm = ids_to_bitmap(np.stack(kws).astype(np.int32), v)
    got = np.asarray(match_subscriptions(locs, obm, rects, sbm)).astype(bool)
    want = match_subscriptions_bruteforce(locs, okw, rects, kws)
    np.testing.assert_array_equal(got, want)
    # and the full-width ref twin agrees with both
    ref = np.asarray(sub_match_ref(locs, obm, rects, sbm)).astype(bool)
    np.testing.assert_array_equal(ref, want)


def test_block_padding_is_inert():
    """Compiled-block padding (NEVER_RECT + zero bitmap past the live
    fill) can never match, even for a universe-rect object sweep."""
    rng = np.random.default_rng(7)
    v = 40
    idx = SubscriptionIndex(v)
    sid = idx.subscribe((0.0, 0.0, 1.0, 1.0), [0, 1, 2])
    blk = idx.block()
    assert blk.n_slots == 8 and idx.n_live == 1  # 7 padded slots
    locs, okw = _rand_objs(rng, 50, v)
    okw[:, 0] = 0  # every object shares keyword 0
    mat = np.asarray(
        match_subscriptions(locs, ids_to_bitmap(okw.astype(np.int32), v),
                            blk.rects, blk.bm, blk.sig[:, 0])
    )
    assert mat[:, 1:].sum() == 0  # only the live slot can match
    assert mat[:, 0].all()
    assert idx.unsubscribe(sid)
    blk = idx.block()
    mat = np.asarray(
        match_subscriptions(locs, ids_to_bitmap(okw.astype(np.int32), v),
                            blk.rects, blk.bm, blk.sig[:, 0])
    )
    assert mat.sum() == 0  # a freed slot is immediately inert


# -------------------------------------------- streaming churn vs the oracle
def test_subscription_churn_with_slot_reuse():
    """Interleaved subscribe/unsubscribe/arrive: freed subscription slots
    are reused by later subscribers without leaking old filters, and the
    notification stream equals the oracle replay verbatim."""
    rng = np.random.default_rng(3)
    v = 48
    idx, orc = SubscriptionIndex(v), SubscriptionOracle()
    live = []
    next_id = 0
    for step in range(12):
        # churn: drop a random third of live subs, add a fresh batch
        drop = [s for s in live if rng.random() < 0.33]
        for s in drop:
            assert idx.unsubscribe(s) == orc.unsubscribe(s)
            live.remove(s)
        for _ in range(rng.integers(1, 4)):
            r, kw = _rand_rect(rng), _rand_kw(rng, v, lo=0)
            a, b = idx.subscribe(r, kw), orc.subscribe(r, kw)
            assert a == b
            live.append(a)
        n = int(rng.integers(1, 20))
        ids = np.arange(next_id, next_id + n)
        next_id += n
        locs, okw = _rand_objs(rng, n, v)
        idx.match_arrivals(ids, locs, kw_ids=okw)
        orc.arrive(ids, locs, okw)
        if step % 3 == 2:  # drain mid-stream: exactly-once, in order
            np.testing.assert_array_equal(idx.drain(), orc.drain())
    np.testing.assert_array_equal(idx.drain(), orc.drain())
    assert idx.drain().shape == (0, 2)  # duplicate suppression
    assert idx.matched_total == orc.matched_total
    assert idx.n_slots <= 32  # slot reuse bounded the block growth


def _grid_serving(n=1000, seed=0, slots_per_leaf=4):
    ds = make_dataset("fs", n=n, seed=seed)
    index, _ = _build_index(ds, g=5, levels=2)
    snap = IndexSnapshot.build(index, ds)
    return ds, DeltaLog(index, ds, snap, slots_per_leaf=slots_per_leaf)


def test_delta_churn_freed_slots_and_growth_exactly_once():
    """Insert/delete/re-insert churn through a real DeltaLog: freed insert
    slots are reused by fresh (higher-id) objects and re-matched; deleted
    objects keep their already-queued notifications; buffer growth never
    re-emits. Incremental matching and full-buffer pumps interleave."""
    rng = np.random.default_rng(5)
    ds, log = _grid_serving(seed=1)
    idx, orc = SubscriptionIndex(ds.vocab_size), SubscriptionOracle()
    for _ in range(10):
        r, kw = _rand_rect(rng), _rand_kw(rng, ds.vocab_size, lo=1)
        assert idx.subscribe(r, kw) == orc.subscribe(r, kw)
    spot = ds.locs[rng.integers(ds.n)]
    inserted = []
    for rnd in range(6):
        n = int(rng.integers(2, 8))
        # concentrate on one spot so one leaf's 4-slot budget overflows
        locs = np.clip(
            spot[None, :] + rng.normal(0, 1e-3, (n, 2)).astype(np.float32), 0, 1
        )
        okw = np.stack([_rand_kw(rng, ds.vocab_size) for _ in range(n)])
        ids = log.insert(locs, okw)
        idx.match_arrivals(ids, locs, kw_ids=okw)
        orc.arrive(ids, locs, okw)
        inserted.extend(int(i) for i in ids)
        assert idx.pump(log) == 0  # sweep after incremental: nothing new
        if rnd >= 2:  # delete some buffered objects -> slots freed, reused
            dels = rng.choice(inserted, size=min(3, len(inserted)), replace=False)
            log.delete(dels)
            inserted = [i for i in inserted if i not in set(int(d) for d in dels)]
    assert log.buffer.slots_per_leaf > 4  # growth actually happened
    np.testing.assert_array_equal(idx.drain(), orc.drain())
    assert idx.pump(log) == 0 and idx.drain().shape == (0, 2)


def test_pump_only_stream_equals_incremental_stream():
    """Driving the same schedule exclusively through full-buffer ``pump``
    sweeps yields the identical notification sequence as per-batch
    ``match_arrivals`` -- growth, freed-slot reuse and all."""
    rng = np.random.default_rng(9)
    ds, log_a = _grid_serving(seed=2)
    _, log_b = _grid_serving(seed=2)
    inc, swp = SubscriptionIndex(ds.vocab_size), SubscriptionIndex(ds.vocab_size)
    for _ in range(8):
        r, kw = _rand_rect(rng), _rand_kw(rng, ds.vocab_size, lo=1)
        inc.subscribe(r, kw)
        swp.subscribe(r, kw)
    for rnd in range(5):
        n = int(rng.integers(1, 10))
        locs, okw = _rand_objs(rng, n, ds.vocab_size)
        ids_a = log_a.insert(locs, okw)
        ids_b = log_b.insert(locs, okw)
        np.testing.assert_array_equal(ids_a, ids_b)
        inc.match_arrivals(ids_a, locs, kw_ids=okw)
        swp.pump(log_b)
        if rnd == 2:
            dels = ids_a[: n // 2]
            log_a.delete(dels)
            log_b.delete(dels)
    np.testing.assert_array_equal(inc.drain(), swp.drain())
    assert inc.matched_total == swp.matched_total


def test_out_of_vocabulary_arrival_keeps_notifications_exact():
    """An arrival whose keywords miss its leaf's compact dictionary flips
    the DeltaLog sticky fallback (PR 9); the notification stream is
    identical either way."""
    rng = np.random.default_rng(11)
    ds, log = _grid_serving(n=600, seed=3, slots_per_leaf=8)
    if not log.snapshot.has_compact_bank:
        pytest.skip("snapshot built without a compact bank")
    idx, orc = SubscriptionIndex(ds.vocab_size), SubscriptionOracle()
    for _ in range(6):
        r = _rand_rect(rng)
        kw = _rand_kw(rng, ds.vocab_size, lo=1)
        idx.subscribe(r, kw)
        orc.subscribe(r, kw)
    # universe-rect subscription on a rare term so the OOV arrival matches
    rare = int(np.argmin(ds.kw_freq))
    idx.subscribe((0.0, 0.0, 1.0, 1.0), [rare])
    orc.subscribe((0.0, 0.0, 1.0, 1.0), [rare])
    assert log.compact_ok
    flipped = False
    for _ in range(20):
        locs, okw = _rand_objs(rng, 4, ds.vocab_size)
        okw[0, 0] = rare  # rare term: almost surely outside some leaf dict
        ids = log.insert(locs, okw)
        idx.match_arrivals(ids, locs, kw_ids=okw)
        orc.arrive(ids, locs, okw)
        flipped = flipped or not log.compact_ok
        if flipped:
            break
    assert flipped, "schedule never left the compact vocabulary; weak test"
    np.testing.assert_array_equal(idx.drain(), orc.drain())


# -------------------------------------- LiveIndex front door + rebuild swap
def _tiny_build_config():
    return BuildConfig(
        partition=PartitionConfig(max_clusters=24, n_steps=25, n_restarts=2),
        packing=PackingConfig(epochs=3, max_label_queries=16),
        cdf_train_steps=40,
        cdf_force_class="gauss",
        use_itemsets=False,
    )


@pytest.fixture(scope="module")
def live_index():
    ds = make_dataset("fs", n=1500, seed=0)
    train = make_workload(ds, m=32, dist="LAP", seed=1)
    return LiveIndex(ds, train, _tiny_build_config()), ds


def test_notifications_atomic_across_rebuild_swap(live_index):
    """The §8 exactly-once contract across ``maybe_rebuild``: notifications
    queued before the swap survive it, objects baked into the new snapshot
    are never re-matched, the id sequence (and therefore the high-water
    mark) continues, and post-swap arrivals match the same subscriptions.
    The whole stream equals the oracle replay."""
    rng = np.random.default_rng(21)
    live, ds = live_index
    orc = SubscriptionOracle()
    for _ in range(8):
        r, kw = _rand_rect(rng), _rand_kw(rng, ds.vocab_size, lo=1)
        assert live.subscribe(r, kw) == orc.subscribe(r, kw)

    def arrive(n):
        src = rng.choice(ds.n, n)
        locs = np.clip(
            ds.locs[src] + rng.normal(0, 0.02, (n, 2)).astype(np.float32), 0, 1
        )
        okw = ds.kw_ids[src]
        ids = live.insert(locs, okw)
        orc.arrive(ids, locs, okw)
        return ids

    pre_ids = arrive(30)  # queued, deliberately NOT drained before the swap
    live.delete(pre_ids[:5])  # deletes never retract queued notifications
    orc_pre = orc.matched_total
    assert live.subscriptions.matched_total == orc_pre

    wl = make_workload(ds, m=24, dist="UNI", seed=41)
    live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)  # populate recent window
    assert live.maybe_rebuild(force=True)
    new_gen = live.generation
    assert new_gen.delta_log.n_updates() == 0

    # baked-in objects sit below the high-water mark: a full sweep of the
    # fresh generation's (empty) buffer re-emits nothing
    assert live.subscriptions.pump(new_gen.delta_log) == 0

    post_ids = arrive(20)  # the id sequence continues across the swap
    assert int(post_ids.min()) > int(pre_ids.max())
    got, want = live.drain_notifications(), orc.drain()
    np.testing.assert_array_equal(got, want)
    assert (got[:, 0] <= int(pre_ids.max())).sum() > 0 or orc_pre == 0
    # repeated drains: exactly-once
    assert live.drain_notifications().shape == (0, 2)
    # unsubscribe after the swap still works against the surviving state
    assert live.unsubscribe(0) and orc.unsubscribe(0)
    final = arrive(10)
    assert final.size == 10
    np.testing.assert_array_equal(live.drain_notifications(), orc.drain())
