"""Sharded-vs-single-device serving parity (DESIGN.md §3.4).

The data-parallel front doors (``serve_sharded`` / ``serve_knn_sharded``)
shard_map the REAL hierarchical engine -- frontier SKR descent and
distance-bounded kNN descent -- over the mesh's data axes with the
``IndexSnapshot`` replicated. They must be *id-sequence- and
counter-identical* to the single-device engine, including ragged
(non-divisible) batch sizes, inert pad queries, width-cache growth across
shards, and ``max_leaves`` overflow.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
multi-device lane) these tests exercise true 8-way query sharding; on a
single device they still pin the shard_map path against the plain engine.

Also here: the regression for the flat leaf-sharded fallback's two-stage
verification, whose ``stage2_cap`` overflow used to be silently discarded
(``counts + 0 * overflow``) -- it is now psum'd over ``model`` and returned.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.query import execute_serial, sharded_bucket
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.launch.mesh import make_host_mesh
from repro.launch.wisk_serve import (
    OBJ_PER_LEAF,
    TOP_LEAVES_LOCAL,
    default_serving_mesh,
    mesh_dp_size,
    serve_knn_sharded,
    serve_sharded,
    wisk_serve_step,
)
from repro.serve.engine import IndexSnapshot, retrieve_knn, retrieve_workload
from repro.serve.plan import PlanCache
from repro.sharding.compat import shard_map

from test_query_parity import _build_index, _grid_clusters, flat_index


SKR_KEYS = ("ids", "counts", "nodes_checked", "nodes_scanned", "verified", "overflow")
KNN_KEYS = ("ids", "dist2", "nodes_checked", "verified", "leaves_verified", "pruned")


def _points_from(wl) -> np.ndarray:
    return np.stack(
        [(wl.rects[:, 0] + wl.rects[:, 2]) / 2, (wl.rects[:, 1] + wl.rects[:, 3]) / 2], 1
    ).astype(np.float32)


def _assert_same(single, sharded, keys):
    for k in keys:
        np.testing.assert_array_equal(single[k], sharded[k], err_msg=k)
    np.testing.assert_array_equal(
        single["frontier_widths"], sharded["frontier_widths"], err_msg="frontier_widths"
    )


def test_serving_mesh_uses_all_devices():
    """The default serving mesh puts every local device on the data axis --
    under the CI 8-device CPU platform the parity tests below genuinely
    exercise 8-way query sharding."""
    mesh = default_serving_mesh()
    assert mesh_dp_size(mesh) == len(jax.devices())
    assert sharded_bucket(13, 8) == 64 and sharded_bucket(16, 1) == 16


@pytest.mark.parametrize("seed,levels,m", [(0, 2, 13), (2, 3, 20), (3, 1, 5)])
def test_skr_sharded_matches_single_device(seed, levels, m):
    """Identical ids and Eq.1 counters, including ragged batches that do not
    divide by the shard count and hierarchies of different heights."""
    ds = make_dataset("fs", n=1500, seed=seed)
    if levels == 1:
        index, clusters = flat_index(ds, _grid_clusters(ds, 5)), _grid_clusters(ds, 5)
    else:
        index, clusters = _build_index(ds, g=6, levels=levels)
    wl = make_workload(ds, m=m, dist="MIX", seed=seed + 10)
    snap = IndexSnapshot.build(index, ds)
    single = retrieve_workload(snap, wl, max_leaves=clusters.k, plan_cache=PlanCache())
    sharded = serve_sharded(
        snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, plan_cache=PlanCache()
    )
    assert sharded["ids"].shape[0] == m  # padding sliced back off
    _assert_same(single, sharded, SKR_KEYS)
    st = execute_serial(index, ds, wl)
    np.testing.assert_array_equal(sharded["nodes_checked"], st.nodes_accessed)
    np.testing.assert_array_equal(sharded["counts"], [len(r) for r in st.results])


def test_skr_sharded_width_growth_and_overflow_parity():
    """Wide queries force the seeded widths to grow through the
    grow-and-redescend loop, and small ``max_leaves`` forces leaf spill:
    converged widths, dropped leaves, and overflow counters must all match
    the single-device engine exactly."""
    ds = make_dataset("fs", n=2500, seed=5)
    index, clusters = _build_index(ds, g=8, levels=3)
    wl = make_workload(ds, m=16, dist="UNI", region_frac=0.2, n_keywords=4, seed=9)
    snap = IndexSnapshot.build(index, ds)
    for max_leaves in (2, clusters.k):
        single = retrieve_workload(
            snap, wl, max_leaves=max_leaves, plan_cache=PlanCache()
        )
        cache = PlanCache()
        sharded = serve_sharded(
            snap, wl.rects, wl.kw_bitmap, max_leaves=max_leaves, plan_cache=cache
        )
        _assert_same(single, sharded, SKR_KEYS)
        # the sharded loop converged to the exact-mode widths
        n_links = snap.n_levels - 1
        assert cache.seeded_plan("skr", n_links).widths == tuple(
            single["frontier_widths"][1:]
        )
    assert serve_sharded(
        snap, wl.rects, wl.kw_bitmap, max_leaves=2, plan_cache=PlanCache()
    )["overflow"].sum() > 0


def test_skr_sharded_reuses_learned_widths():
    """A warm PlanCache serves sharded batches without re-descending: the
    second call must hit the fixed point on its first shard_map dispatch
    (observed maxima never exceed the cached widths)."""
    ds = make_dataset("fs", n=1500, seed=1)
    index, clusters = _build_index(ds, g=6, levels=2)
    wl = make_workload(ds, m=24, dist="MIX", seed=11)
    snap = IndexSnapshot.build(index, ds)
    cache = PlanCache()
    first = serve_sharded(
        snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, plan_cache=cache
    )
    learned = dict(cache.widths)
    again = serve_sharded(
        snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, plan_cache=cache
    )
    assert dict(cache.widths) == learned
    _assert_same(first, again, SKR_KEYS)


@pytest.mark.parametrize("seed,levels,k,m", [(0, 2, 1, 13), (1, 3, 10, 16), (3, 1, 5, 6)])
def test_knn_sharded_matches_single_device(seed, levels, k, m):
    """kNN twin: identical id sequences, distances, and counters across the
    sharded and single-device bounded descents, ragged batches included."""
    ds = make_dataset("fs", n=1500, seed=seed)
    if levels == 1:
        index = flat_index(ds, _grid_clusters(ds, 5))
    else:
        index, _ = _build_index(ds, g=6, levels=levels)
    wl = make_workload(ds, m=m, dist="MIX", seed=seed + 20)
    points = _points_from(wl)
    snap = IndexSnapshot.build(index, ds)
    single = retrieve_knn(snap, points, wl.kw_bitmap, k, plan_cache=PlanCache())
    sharded = serve_knn_sharded(
        snap, points, wl.kw_bitmap, k, plan_cache=PlanCache()
    )
    assert sharded["ids"].shape == (m, k)
    for key in KNN_KEYS:
        np.testing.assert_array_equal(single[key], sharded[key], err_msg=key)
    # k <= 0 degenerates identically too
    assert serve_knn_sharded(snap, points, wl.kw_bitmap, 0)["ids"].shape == (m, 0)


def test_sharded_pad_queries_are_inert():
    """Padding to n_shards power-of-two buckets (sharded_bucket) must not
    perturb real queries: a 3-query batch padded up to the full mesh width
    returns exactly the unpadded engine's results."""
    ds = make_dataset("fs", n=1200, seed=12)
    index, clusters = _build_index(ds, g=5, levels=2)
    snap = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=3, dist="MIX", seed=13)
    single = retrieve_workload(snap, wl, max_leaves=clusters.k, plan_cache=PlanCache())
    sharded = serve_sharded(
        snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, plan_cache=PlanCache()
    )
    _assert_same(single, sharded, SKR_KEYS)


# ------------------------- flat leaf-sharded fallback: overflow regression
def _fallback_mesh():
    return make_host_mesh(data=2, model=4)


def _run_fallback(mesh, q_rects, q_bm, leaf_mbrs, leaf_bm, obj, two_stage, cap):
    from functools import partial

    from repro.sharding.rules import default_rules, dp_axes, spec_for
    from jax.sharding import PartitionSpec as P

    rules = default_rules(mesh)
    dp = dp_axes(mesh)
    qspec = spec_for(("query", None), rules)
    lspec = spec_for(("leaf", None), rules)
    ospec = spec_for(("leaf", "obj_slot", "word"), rules)
    fn = shard_map(
        partial(wisk_serve_step, two_stage=two_stage, stage2_cap=cap),
        mesh=mesh,
        in_specs=(qspec, qspec, lspec, lspec, lspec, lspec, ospec, lspec),
        out_specs=(P(dp), P(dp), P(dp)),
        check_vma=False,
    )
    ox, oy, obm, oval = obj
    return jax.jit(fn)(q_rects, q_bm, leaf_mbrs, leaf_bm, ox, oy, obm, oval)


def test_two_stage_overflow_is_surfaced_not_discarded():
    """Regression: ``wisk_serve_step``'s two-stage verify used to drop every
    match beyond ``stage2_cap`` silently (``counts + 0 * overflow``). The
    psum'd overflow is now a first-class output: with every object in-rect
    and keyword-matching, ``counts + overflow`` must reconcile with the
    exhaustive single-stage counts, and the overflow must actually fire."""
    mesh = _fallback_mesh()
    n_model = mesh.shape["model"]
    M = 8 * max(mesh_dp_size(mesh) // 8, 1)
    K = TOP_LEAVES_LOCAL * n_model  # every device keeps TOP_LEAVES_LOCAL leaves
    W = 2
    q_rects = np.tile(np.array([[0.0, 0.0, 1.0, 1.0]], np.float32), (M, 1))
    q_bm = np.ones((M, W), np.uint32)
    leaf_mbrs = np.tile(np.array([[0.0, 0.0, 1.0, 1.0]], np.float32), (K, 1))
    leaf_bm = np.ones((K, W), np.uint32)
    rng = np.random.default_rng(0)
    ox = rng.uniform(0.1, 0.9, (K, OBJ_PER_LEAF)).astype(np.float32)
    oy = rng.uniform(0.1, 0.9, (K, OBJ_PER_LEAF)).astype(np.float32)
    obm = np.ones((K, OBJ_PER_LEAF, W), np.uint32)
    oval = np.ones((K, OBJ_PER_LEAF), np.int8)
    obj = (ox, oy, obm, oval)

    cap = 8
    counts2, scanned2, over2 = map(
        np.asarray, _run_fallback(mesh, q_rects, q_bm, leaf_mbrs, leaf_bm, obj, True, cap)
    )
    counts1, scanned1, over1 = map(
        np.asarray, _run_fallback(mesh, q_rects, q_bm, leaf_mbrs, leaf_bm, obj, False, cap)
    )
    per_dev_total = TOP_LEAVES_LOCAL * OBJ_PER_LEAF
    np.testing.assert_array_equal(counts1, np.full(M, per_dev_total * n_model))
    assert (over2 > 0).all()  # the capacity bound genuinely fired
    np.testing.assert_array_equal(counts2 + over2, counts1)  # nothing silent
    np.testing.assert_array_equal(over1, np.zeros(M, over1.dtype))
    np.testing.assert_array_equal(scanned1, scanned2)


def test_lower_wisk_serve_surfaces_overflow_output():
    """The dry-run lowering of the fallback now exposes three outputs
    (counts, scanned, overflow), all sharded over the data axes."""
    from repro.configs.wisk import WiskServeConfig
    from repro.launch.wisk_serve import lower_wisk_serve

    mesh = _fallback_mesh()
    cfg = WiskServeConfig(n_queries=32, n_nodes=64, vocab=64)
    lowered = lower_wisk_serve(mesh, cfg, two_stage=True)
    compiled = lowered.compile()
    assert len(compiled.output_shardings) == 3
