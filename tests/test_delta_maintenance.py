"""Dynamic-behavior coverage for the incremental-maintenance subsystem
(DESIGN.md §7): delta-buffer merge parity, drift triggering, and snapshot
swap consistency.

The contract under test:

* **Update parity.** Serving with N buffered inserts + M deletes must be
  *id-exact* with a from-scratch rebuild over the merged object set, for
  both batched SKR and batched kNN (and the sharded SKR path) -- buffered
  objects verified alongside leaf blocks, deletions masked in the
  verify/top-k stages, augmented filter arrays keeping every descent able
  to reach buffered matches.
* **Drift detection.** The EWMA monitor learns its baseline from the
  warmup window, does NOT trip on same-distribution resampling, DOES trip
  when the query distribution shifts away from the trained one, and
  re-arms through a fresh warmup after a swap.
* **Swap atomicity.** ``LiveIndex.maybe_rebuild`` replaces the serving
  generation with ONE reference store: an in-flight batch holding the old
  generation keeps getting identical, consistent results after the swap.

Fast deterministic indexes (grid clusters, no DQN) cover the parity tests;
the drift/warm-rebuild integration builds one tiny real WISK index per
module (session fixture, ~30 s -- same budget as test_build_parity.py).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.build import BuildConfig, build_wisk, warm_start_rebuild
from repro.core.cost import exact_query_result_ids
from repro.core.drift import DriftConfig, DriftMonitor, observed_workload
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.core.query import execute_level_sync
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.launch.wisk_serve import LiveIndex, serve_batch, serve_knn_batch
from repro.serve.delta import DeltaBuffer, DeltaLog
from repro.serve.engine import IndexSnapshot, retrieve, retrieve_knn

from test_query_parity import _build_index, _grid_clusters, flat_index


# ------------------------------------------------------------ shared helpers
def _updated_log(ds, index, snap, n_ins=40, n_del=30, seed=3, jitter=0.05):
    """A DeltaLog with jittered-copy inserts and mixed base/buffered deletes."""
    log = DeltaLog(index, ds, snap)
    rng = np.random.default_rng(seed)
    src = rng.choice(ds.n, n_ins)
    locs = np.clip(
        ds.locs[src] + rng.normal(0, jitter, (n_ins, 2)).astype(np.float32), 0, 1
    )
    new_ids = log.insert(locs, ds.kw_ids[src])
    dels = list(rng.choice(ds.n, n_del, replace=False))
    if n_ins >= 2:
        dels += [int(new_ids[0]), int(new_ids[-1])]  # buffered deletes too
    log.delete(dels)
    return log


def _cold_rebuild_snapshot(log):
    """From-scratch snapshot over the merged object set (same grid layout)."""
    merged = log.merged_dataset()
    index, _ = _build_index(merged, g=6, levels=2)
    return merged, IndexSnapshot.build(index, merged)


def _sorted_ids(row):
    return np.sort(row[row >= 0])


# ------------------------------------------------- update parity (SKR + kNN)
@pytest.mark.parametrize("seed", [0, 1])
def test_skr_delta_parity_vs_cold_rebuild(seed):
    ds = make_dataset("fs", n=1200, seed=seed)
    index, clusters = _build_index(ds, g=6, levels=2)
    snap = IndexSnapshot.build(index, ds)
    log = _updated_log(ds, index, snap, seed=seed + 3)
    merged, cold_snap = _cold_rebuild_snapshot(log)

    wl = make_workload(ds, m=24, dist="MIX", seed=seed + 7)
    out = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, delta=log.buffer)
    cold = serve_batch(cold_snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k)
    for qi in range(wl.m):
        got = _sorted_ids(out["ids"][qi])
        ref = _sorted_ids(cold["ids"][qi])
        assert np.array_equal(got, ref), f"q{qi}: delta-served != cold rebuild"
        truth = np.sort(exact_query_result_ids(merged, wl.rects[qi], wl.kw_bitmap[qi]))
        assert np.array_equal(got, truth), f"q{qi}: delta-served != ground truth"


@pytest.mark.parametrize("seed,k", [(0, 10), (1, 33)])
def test_knn_delta_parity_vs_cold_rebuild(seed, k):
    ds = make_dataset("fs", n=1200, seed=seed)
    index, _ = _build_index(ds, g=6, levels=2)
    snap = IndexSnapshot.build(index, ds)
    log = _updated_log(ds, index, snap, seed=seed + 3)
    merged, cold_snap = _cold_rebuild_snapshot(log)

    wl = make_workload(ds, m=16, dist="MIX", seed=seed + 7)
    pts = np.stack(
        [(wl.rects[:, 0] + wl.rects[:, 2]) / 2, (wl.rects[:, 1] + wl.rects[:, 3]) / 2], 1
    ).astype(np.float32)
    out = serve_knn_batch(snap, pts, wl.kw_bitmap, k, delta=log.buffer)
    cold = serve_knn_batch(cold_snap, pts, wl.kw_bitmap, k)
    # id *sequences* (not sets): the (dist^2, id) order must survive the merge
    for qi in range(wl.m):
        got = out["ids"][qi][out["ids"][qi] >= 0]
        ref = cold["ids"][qi][cold["ids"][qi] >= 0]
        assert np.array_equal(got, ref), f"q{qi}: delta kNN != cold rebuild kNN"


def test_sharded_delta_parity():
    """The shard_map'd SKR path merges the replicated delta identically.

    Needs >=2 devices; on a single-device box (the first jax import locked
    the platform, so the count can't be raised in-process) the test re-execs
    itself in a subprocess with a forced 2-device host platform instead of
    skipping -- the sharded delta-merge contract is load-bearing and must
    gate everywhere, not only on CI's pre-forced 8-device lane."""
    import jax
    from repro.launch.wisk_serve import serve_sharded

    if len(jax.devices()) < 2:
        assert "_DELTA_SHARDED_REEXEC" not in os.environ, (
            "re-exec with a forced 2-device host platform still saw <2 devices"
        )
        env = dict(os.environ)
        flag = "--xla_force_host_platform_device_count=2"
        env["XLA_FLAGS"] = f"{env.get('XLA_FLAGS', '')} {flag}".strip()
        env["_DELTA_SHARDED_REEXEC"] = "1"
        proc = subprocess.run(
            [sys.executable, "-m", "pytest", "-x", "-q",
             f"{os.path.abspath(__file__)}::test_sharded_delta_parity"],
            env=env, capture_output=True, text=True, timeout=900,
        )
        assert proc.returncode == 0, (
            f"forced 2-device re-exec failed:\n{proc.stdout}\n{proc.stderr}"
        )
        return
    ds = make_dataset("fs", n=1200, seed=0)
    index, clusters = _build_index(ds, g=6, levels=2)
    snap = IndexSnapshot.build(index, ds)
    log = _updated_log(ds, index, snap)
    wl = make_workload(ds, m=24, dist="MIX", seed=7)
    single = retrieve(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, delta=log.buffer)
    shard = serve_sharded(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, delta=log.buffer)
    for qi in range(wl.m):
        assert np.array_equal(_sorted_ids(single["ids"][qi]), _sorted_ids(shard["ids"][qi]))
    np.testing.assert_array_equal(single["nodes_checked"], shard["nodes_checked"])
    np.testing.assert_array_equal(single["verified"], shard["verified"])


def test_empty_delta_is_inert():
    """Serving with an all-empty DeltaBuffer returns exactly the plain
    snapshot results and counters."""
    ds = make_dataset("fs", n=1000, seed=2)
    index, clusters = _build_index(ds, g=5, levels=2)
    snap = IndexSnapshot.build(index, ds)
    empty = DeltaBuffer.empty(snap)
    wl = make_workload(ds, m=16, dist="MIX", seed=5)
    base = retrieve(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k)
    with_d = retrieve(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, delta=empty)
    for qi in range(wl.m):
        assert np.array_equal(_sorted_ids(base["ids"][qi]), _sorted_ids(with_d["ids"][qi]))
    np.testing.assert_array_equal(base["counts"], with_d["counts"])
    np.testing.assert_array_equal(base["nodes_checked"], with_d["nodes_checked"])


def test_insert_buffer_growth_keeps_parity():
    """Overflowing one leaf's insert buffer grows it by doubling (a new
    compiled shape) without losing a single object."""
    ds = make_dataset("fs", n=1000, seed=1)
    index, clusters = _build_index(ds, g=5, levels=2)
    snap = IndexSnapshot.build(index, ds)
    log = DeltaLog(index, ds, snap, slots_per_leaf=4)
    # aim 24 inserts at one spot -> one leaf must grow 4 -> 32
    rng = np.random.default_rng(0)
    spot = ds.locs[rng.integers(ds.n)]
    locs = np.clip(spot[None, :] + rng.normal(0, 1e-3, (24, 2)).astype(np.float32), 0, 1)
    kw = ds.kw_ids[rng.choice(ds.n, 24)]
    log.insert(locs, kw)
    assert log.buffer.slots_per_leaf >= 24
    merged = log.merged_dataset()
    wl = make_workload(merged, m=12, dist="MIX", seed=9)
    out = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, delta=log.buffer)
    for qi in range(wl.m):
        truth = np.sort(exact_query_result_ids(merged, wl.rects[qi], wl.kw_bitmap[qi]))
        assert np.array_equal(_sorted_ids(out["ids"][qi]), truth)


def test_multi_growth_churn_keeps_parity():
    """Sustained insert traffic that overflows one leaf's slot budget more
    than once: the buffer doubles repeatedly under interleaved deletes with
    freed-slot reuse, and serving stays id-exact with the merged ground
    truth after EVERY round (each growth is a new compiled shape; a bug
    that drops or duplicates a slot across a retrace shows up here)."""
    ds = make_dataset("fs", n=1000, seed=6)
    index, clusters = _build_index(ds, g=5, levels=2)
    snap = IndexSnapshot.build(index, ds)
    log = DeltaLog(index, ds, snap, slots_per_leaf=4)
    rng = np.random.default_rng(2)
    spot = ds.locs[rng.integers(ds.n)]
    grown = [log.buffer.slots_per_leaf]
    alive = []
    for rnd in range(4):
        locs = np.clip(
            spot[None, :] + rng.normal(0, 1e-3, (12, 2)).astype(np.float32), 0, 1
        )
        ids = log.insert(locs, ds.kw_ids[rng.choice(ds.n, 12)])
        alive.extend(int(i) for i in ids)
        if rnd:  # churn: freed slots get reused before the next doubling
            dels = rng.choice(alive, 4, replace=False)
            log.delete(dels)
            alive = [i for i in alive if i not in set(int(d) for d in dels)]
        if log.buffer.slots_per_leaf != grown[-1]:
            grown.append(log.buffer.slots_per_leaf)
        merged = log.merged_dataset()
        wl = make_workload(merged, m=8, dist="MIX", seed=13 + rnd)
        out = serve_batch(
            snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, delta=log.buffer
        )
        for qi in range(wl.m):
            truth = np.sort(
                exact_query_result_ids(merged, wl.rects[qi], wl.kw_bitmap[qi])
            )
            assert np.array_equal(_sorted_ids(out["ids"][qi]), truth), (
                f"round {rnd} (slots={log.buffer.slots_per_leaf}): q{qi} diverged"
            )
    assert len(grown) >= 3, f"slot budget grew only {grown}; wanted >=2 doublings"


def test_partition_delta_memo_correct_after_growth():
    """Index-sharded serving memoizes the shard-routed delta per *buffer
    object* (launch.wisk_serve._PARTITIONED_DELTA): growth replaces the
    buffer, so the grown buffer must be partitioned afresh -- serving the
    stale memo would silently drop the newest inserts on every shard.
    Needs >=2 devices; re-execs itself with a forced 2-device host platform
    otherwise (same discipline as test_sharded_delta_parity)."""
    import jax

    if len(jax.devices()) < 2:
        assert "_DELTA_MEMO_REEXEC" not in os.environ, (
            "re-exec with a forced 2-device host platform still saw <2 devices"
        )
        env = dict(os.environ)
        flag = "--xla_force_host_platform_device_count=2"
        env["XLA_FLAGS"] = f"{env.get('XLA_FLAGS', '')} {flag}".strip()
        env["_DELTA_MEMO_REEXEC"] = "1"
        proc = subprocess.run(
            [sys.executable, "-m", "pytest", "-x", "-q",
             f"{os.path.abspath(__file__)}::test_partition_delta_memo_correct_after_growth"],
            env=env, capture_output=True, text=True, timeout=900,
        )
        assert proc.returncode == 0, (
            f"forced 2-device re-exec failed:\n{proc.stdout}\n{proc.stderr}"
        )
        return
    from repro.launch.wisk_serve import _PARTITIONED_DELTA, serve_index_sharded
    from repro.serve.snapshot import PartitionedSnapshot

    ds = make_dataset("fs", n=1000, seed=3)
    index, clusters = _build_index(ds, g=5, levels=2)
    snap = IndexSnapshot.build(index, ds)
    psnap = PartitionedSnapshot.build(snap, 2)
    log = DeltaLog(index, ds, snap, slots_per_leaf=4)
    rng = np.random.default_rng(4)
    spot = ds.locs[rng.integers(ds.n)]
    wl = make_workload(ds, m=12, dist="MIX", seed=17)

    def _assert_exact():
        merged = log.merged_dataset()
        out = serve_index_sharded(
            psnap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, delta=log.buffer
        )
        for qi in range(wl.m):
            truth = np.sort(
                exact_query_result_ids(merged, wl.rects[qi], wl.kw_bitmap[qi])
            )
            assert np.array_equal(_sorted_ids(out["ids"][qi]), truth)

    def _grow(n):
        locs = np.clip(
            spot[None, :] + rng.normal(0, 1e-3, (n, 2)).astype(np.float32), 0, 1
        )
        log.insert(locs, ds.kw_ids[rng.choice(ds.n, n)])

    _grow(6)  # 4 -> 8: first growth
    b1 = log.buffer
    _assert_exact()
    assert b1 in _PARTITIONED_DELTA, "first buffer's routing was not memoized"
    _grow(20)  # second growth: a NEW buffer object
    b2 = log.buffer
    assert b2 is not b1 and b2.slots_per_leaf > b1.slots_per_leaf
    _assert_exact()  # must re-partition b2, not serve b1's stale memo
    assert b2 in _PARTITIONED_DELTA


def test_delete_everything_in_a_leaf():
    """A fully-deleted leaf serves zero results but stays traversable."""
    ds = make_dataset("fs", n=800, seed=4)
    index, clusters = _build_index(ds, g=4, levels=2)
    snap = IndexSnapshot.build(index, ds)
    log = DeltaLog(index, ds, snap)
    # delete every member of leaf 0
    members = clusters.order[clusters.offsets[0] : clusters.offsets[1]]
    log.delete(members)
    merged = log.merged_dataset()
    wl = make_workload(ds, m=16, dist="MIX", seed=11)
    out = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k, delta=log.buffer)
    for qi in range(wl.m):
        truth = np.sort(exact_query_result_ids(merged, wl.rects[qi], wl.kw_bitmap[qi]))
        assert np.array_equal(_sorted_ids(out["ids"][qi]), truth)
        assert not np.intersect1d(out["ids"][qi], members).size


# --------------------------------------------------------- drift state machine
def test_drift_monitor_state_machine():
    cfg = DriftConfig(alpha=0.2, threshold=1.5, min_queries=16)
    mon = DriftMonitor(None, cfg)
    assert mon.state == "warmup"
    rng = np.random.default_rng(0)
    base = 10.0 + rng.normal(0, 0.5, 16)
    mon.observe(base)  # warmup window -> baseline learned
    assert mon.state == "armed"
    assert abs(mon.baseline - base.mean()) < 1e-9
    # same-distribution noise: no trigger
    mon.observe(10.0 + rng.normal(0, 0.5, 64))
    assert mon.state == "armed" and not mon.triggered
    # regression: 3x the baseline trips the EWMA past threshold
    mon.observe(np.full(64, 30.0))
    assert mon.triggered and mon.ratio > cfg.threshold
    # triggered is sticky until rearm
    mon.observe(np.full(8, 10.0))
    assert mon.triggered
    # rearm -> warmup doubles as cooldown: high costs set the NEW baseline
    mon.rearm()
    assert mon.state == "warmup" and not mon.triggered
    mon.observe(np.full(16, 30.0))
    assert mon.state == "armed" and abs(mon.baseline - 30.0) < 1e-9
    mon.observe(np.full(64, 31.0))
    assert not mon.triggered  # 31 ~ the new normal


def test_observed_workload_roundtrip():
    ds = make_dataset("fs", n=600, seed=0)
    wl = make_workload(ds, m=12, dist="MIX", seed=3)
    rec = observed_workload(wl.rects, wl.kw_bitmap, ds.vocab_size)
    np.testing.assert_array_equal(rec.rects, wl.rects)
    np.testing.assert_array_equal(rec.kw_bitmap, wl.kw_bitmap)
    for qi in range(wl.m):
        a = np.sort(wl.kw_ids[qi][wl.kw_ids[qi] >= 0])
        b = np.sort(rec.kw_ids[qi][rec.kw_ids[qi] >= 0])
        np.testing.assert_array_equal(np.unique(a), b)


# ------------------------------------------- integration: LiveIndex lifecycle
def _tiny_build_config():
    """Smallest honest build: learned splits + DQN-packed hierarchy, sized
    so the whole module builds one index (~30 s, jit-compile dominated)."""
    return BuildConfig(
        partition=PartitionConfig(max_clusters=24, n_steps=25, n_restarts=2),
        packing=PackingConfig(epochs=3, max_label_queries=16),
        cdf_train_steps=40,
        cdf_force_class="gauss",
        use_itemsets=False,
    )


@pytest.fixture(scope="module")
def live_index():
    ds = make_dataset("fs", n=1500, seed=0)
    train = make_workload(ds, m=32, dist="LAP", seed=1)
    # threshold below the measured ~1.5x LAP->UNI regression, above the
    # ~1.1x resampling noise of this dataset/config
    cfg = DriftConfig(alpha=0.05, threshold=1.3, min_queries=48)
    return LiveIndex(ds, train, _tiny_build_config(), cfg), ds


def test_drift_fires_on_shift_not_on_resample(live_index):
    """Same-distribution resampling keeps the monitor armed; shifting the
    distribution away from the trained LAP workload (the §7.5 dynamic
    scenario) trips it."""
    live, ds = live_index
    # warmup + same-distribution traffic: fresh LAP samples, unseen seeds
    for seed in (21, 22, 23, 24):
        wl = make_workload(ds, m=24, dist="LAP", seed=seed)
        live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)
    assert live.monitor.state == "armed", (
        f"resampled traffic must not trigger (ratio {live.monitor.ratio:.2f})"
    )
    assert live.monitor.ratio < live.monitor.config.threshold
    # distribution shift: uniform traffic regresses the learned layout
    for seed in (31, 32, 33, 34, 35, 36):
        wl = make_workload(ds, m=24, dist="UNI", seed=seed)
        live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)
    assert live.monitor.triggered, (
        f"shifted traffic must trigger (ratio {live.monitor.ratio:.2f})"
    )


def test_swap_leaves_in_flight_generation_consistent(live_index):
    """The rebuild swap is one reference store: a reader that grabbed the
    old generation keeps serving identical results; the new generation
    starts with an empty delta log and serves the merged object set."""
    live, ds = live_index
    # buffered updates on the pre-swap generation
    rng = np.random.default_rng(5)
    src = rng.choice(ds.n, 20)
    locs = np.clip(ds.locs[src] + rng.normal(0, 0.03, (20, 2)).astype(np.float32), 0, 1)
    new_ids = live.insert(locs, ds.kw_ids[src])
    live.delete(rng.choice(ds.n, 10, replace=False))

    wl = make_workload(ds, m=24, dist="UNI", seed=41)
    old_gen = live.generation  # the "in-flight" reader's view
    before = serve_batch(
        old_gen.snapshot, wl.rects, wl.kw_bitmap, max_leaves=64,
        plan_cache=old_gen.plan_cache, delta=old_gen.delta(),
    )
    live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)  # populate recent window

    swapped = live.maybe_rebuild(force=True)
    assert swapped and live.generation.seq == old_gen.seq + 1

    # the in-flight reader's generation is untouched: identical results
    after = serve_batch(
        old_gen.snapshot, wl.rects, wl.kw_bitmap, max_leaves=64,
        plan_cache=old_gen.plan_cache, delta=old_gen.delta(),
    )
    for qi in range(wl.m):
        assert np.array_equal(before["ids"][qi], after["ids"][qi])
    np.testing.assert_array_equal(before["counts"], after["counts"])

    # the new generation: empty delta log, merged objects baked in
    new_gen = live.generation
    assert new_gen.delta_log.n_updates() == 0 and new_gen.delta() is None
    assert new_gen.dataset.n == ds.n + 20
    out = live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)
    for qi in range(wl.m):
        truth = np.sort(
            exact_query_result_ids(new_gen.dataset, wl.rects[qi], wl.kw_bitmap[qi])
        )
        assert np.array_equal(_sorted_ids(out["ids"][qi]), truth)
    # buffered inserts survived the rebuild; the monitor is re-warming
    assert int(new_ids[0]) in {
        int(i) for row in out["ids"] for i in row[row >= 0]
    } or True  # presence depends on query rects; the truth check above is the gate
    assert live.monitor.state == "warmup"


def test_warm_start_rebuild_reuses_unregressed_layout(live_index):
    """The warm rebuild re-learns only regressed leaves and grafts the
    packed hierarchy; kept clusters' membership is preserved."""
    live, ds = live_index
    art = live.generation.artifacts
    shifted = make_workload(ds, m=32, dist="UNI", seed=2)
    gen_ds = live.generation.dataset
    warm = warm_start_rebuild(
        gen_ds, shifted, art,
        live.build_config,
        assign=art.partition.clusters.assign,
    )
    assert warm.counters["kept_clusters"] > 0
    assert warm.counters["packing_dispatches"] == 0  # graft, no RL
    assert warm.index.meta["warm_start"]
    # post-shift cost: warm within 10% of a cold rebuild trained the same
    # way (averaged over held-out workloads: single small workloads carry
    # seed noise comparable to the gap itself)
    cold = build_wisk(gen_ds, shifted, live.build_config)
    tests = [make_workload(gen_ds, m=32, dist="UNI", seed=s) for s in (51, 52, 53)]
    warm_c = float(np.mean([execute_level_sync(warm.index, gen_ds, t).cost.mean() for t in tests]))
    cold_c = float(np.mean([execute_level_sync(cold.index, gen_ds, t).cost.mean() for t in tests]))
    stale_c = float(np.mean([execute_level_sync(art.index, gen_ds, t).cost.mean() for t in tests]))
    assert warm_c <= 1.1 * cold_c, f"warm {warm_c:.1f} vs cold {cold_c:.1f}"
    assert warm_c <= stale_c, f"warm {warm_c:.1f} did not improve on stale {stale_c:.1f}"
    # and it reused the bank verbatim
    assert warm.bank is art.bank
