"""Unit tests: CDF estimates, partitioner, DQN packing, baselines, workloads."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cdf import build_cdf_bank, est_count_rect
from repro.core.cost import exact_query_results
from repro.core.itemsets import expand_queries, mine_frequent_itemsets
from repro.core.packing import PackingConfig, build_hierarchy, pack_one_level, spectral_group
from repro.core.dqn import DQNConfig
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload, stratified_sample


@pytest.fixture(scope="module")
def ds():
    return make_dataset("fs", n=2500, seed=3)


def test_cdf_estimates_close(ds):
    bank = build_cdf_bank(ds, n_steps=150)
    tables = bank.jax_tables()
    rng = np.random.default_rng(0)
    # evaluate counts for the most frequent keywords over random rects
    top_kw = np.argsort(ds.kw_freq)[::-1][:10]
    rel_errs = []
    for k in top_kw:
        members = np.nonzero((ds.kw_ids == k).any(1))[0]
        for _ in range(5):
            lo = rng.uniform(0, 0.5, 2)
            hi = lo + rng.uniform(0.2, 0.5, 2)
            rect = jnp.asarray([lo[0], lo[1], hi[0], hi[1]], jnp.float32)
            est = float(est_count_rect(tables, bank.nn_params, jnp.asarray([k]), rect)[0])
            pts = ds.locs[members]
            exact = int(
                (
                    (pts[:, 0] >= lo[0]) & (pts[:, 0] <= hi[0])
                    & (pts[:, 1] >= lo[1]) & (pts[:, 1] <= hi[1])
                ).sum()
            )
            rel_errs.append(abs(est - exact) / max(exact, 10))
    assert np.median(rel_errs) < 0.35, f"median CDF error too high: {np.median(rel_errs)}"


def test_query_expansion_signs(ds):
    wl = make_workload(ds, m=16, n_keywords=5, seed=0)
    its, mem = mine_frequent_itemsets(ds, min_support=1e-4, max_size=2)
    ent, sgn = expand_queries(wl, its, ds.vocab_size)
    assert ent.shape == sgn.shape
    # singletons positive, pairs negative
    assert ((sgn == 1.0) | (sgn == -1.0) | (sgn == 0.0)).all()
    assert (sgn[ent >= ds.vocab_size] == -1.0).all()
    assert (sgn[(ent >= 0) & (ent < ds.vocab_size)] == 1.0).all()


def test_packing_beats_random(ds):
    rng = np.random.default_rng(0)
    N, m = 16, 12
    labels = rng.integers(0, 2, (N, m)).astype(bool)
    cfg = PackingConfig(epochs=10, dqn=DQNConfig(eps_decay=0.9))
    res = pack_one_level(labels, cfg, seed=0)

    def avg_accesses(assign):
        n_up = assign.max() + 1
        upper = np.zeros((n_up, m), bool)
        for i, a in enumerate(assign):
            upper[a] |= labels[i]
        return upper.sum(0).mean()

    learned = avg_accesses(res.assign)
    rand_scores = []
    for s in range(20):
        r = np.random.default_rng(s).integers(0, max(res.n_upper, 2), N)
        _, r = np.unique(r, return_inverse=True)
        rand_scores.append(avg_accesses(r.astype(np.int32)))
    assert learned <= np.median(rand_scores) + 1e-9


def test_action_mask_limits_empty_slots():
    from repro.core.packing import _Env

    labels = np.eye(6, dtype=bool)
    env = _Env(labels, use_mask=True)
    m = env.mask()
    assert m.sum() == 1  # all empty -> exactly one slot exposed
    env.step(0)
    m = env.mask()
    assert m.sum() == 2  # one used + one empty


def test_spectral_group_shapes():
    rng = np.random.default_rng(0)
    mbrs = rng.uniform(0, 1, (20, 4)).astype(np.float32)
    g = spectral_group(mbrs, 5)
    assert g.shape == (20,)
    assert g.max() + 1 <= 5


def test_hierarchy_labels_propagate():
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 2, (12, 8)).astype(bool)
    mbrs = rng.uniform(0, 1, (12, 4)).astype(np.float32)
    h = build_hierarchy(labels, mbrs, PackingConfig(epochs=4))
    lv = h.level_labels
    for i, parent in enumerate(h.parents):
        lower, upper = lv[i], lv[i + 1]
        for j, p in enumerate(parent):
            assert (upper[p] | lower[j]).tolist() == upper[p].tolist()


def test_stratified_sample_ratio(ds):
    wl = make_workload(ds, m=200, seed=0)
    idx = stratified_sample(wl, 0.3, seed=0)
    assert 0.2 <= idx.size / wl.m <= 0.4
    assert np.unique(idx).size == idx.size


@pytest.mark.parametrize("dist", ["UNI", "LAP", "GAU", "MIX"])
def test_workload_valid(ds, dist):
    wl = make_workload(ds, m=50, dist=dist, region_frac=0.001, n_keywords=3, seed=1)
    assert (wl.rects[:, 0] <= wl.rects[:, 2]).all()
    assert (wl.rects[:, 1] <= wl.rects[:, 3]).all()
    assert (wl.rects >= 0).all() and (wl.rects <= 1).all()
    assert ((wl.kw_ids == -1) | (wl.kw_ids < ds.vocab_size)).all()
    # every query has at least one keyword
    assert ((wl.kw_ids >= 0).sum(1) >= 1).all()


def test_baselines_exact(ds):
    from repro.baselines.conventional import build_grid_index, build_str_rtree
    from repro.baselines.learned import build_floodt, build_lsti, build_tfi, tfi_query
    from repro.core.query import execute_serial

    wl = make_workload(ds, m=20, seed=5)
    gt = exact_query_results(ds, wl)
    train = make_workload(ds, m=40, seed=6)
    for idx in [build_grid_index(ds, 6), build_str_rtree(ds), build_floodt(ds, train), build_lsti(ds)]:
        st = execute_serial(idx, ds, wl)
        np.testing.assert_array_equal(np.array([len(r) for r in st.results]), gt)
    tfi = build_tfi(ds)
    st = tfi_query(tfi, ds, wl)
    np.testing.assert_array_equal(np.array([len(r) for r in st.results]), gt)
