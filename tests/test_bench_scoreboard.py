"""Persistent perf scoreboard: Record schema round-trip, bench_compare
verdicts, the run.py module filter, and the docstring doc-reference checker
(EXPERIMENTS.md section Scoreboard)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks import common as C  # noqa: E402
from benchmarks.run import MODULES, SCOREBOARD, select_modules  # noqa: E402
from tools import bench_compare as BC  # noqa: E402
from tools import check_docs as CD  # noqa: E402


# ------------------------------------------------------------ Record schema
def test_record_prints_legacy_csv_row():
    r = C.row("serving/frontier", 123.456, "overflow=0 scanned=1520")
    assert str(r) == "serving/frontier,123.46,overflow=0 scanned=1520"


def test_derived_parsing_types_and_commentary():
    d = C.parse_derived("qps=318 scale=1.25x eff=0.62 widths=[8,16] "
                        "mismatches=0/64 (free-text caveat dropped)")
    assert d == {"qps": 318, "scale": 1.25, "eff": 0.62,
                 "widths": "[8,16]", "mismatches": "0/64"}
    assert isinstance(d["qps"], int) and isinstance(d["scale"], float)


def test_derived_parsing_braced_dicts():
    d = C.parse_derived("phase_times={'partition': 1.2};counters={'x': 3}")
    assert set(d) == {"phase_times", "counters"}


def test_payload_schema_and_json_round_trip(tmp_path):
    recs = [C.row("a/b", 10.0, "cost=5"), C.row("a/c", 0.0)]
    p = C.scoreboard_payload("bench_serving", recs, quick=True, elapsed_s=1.5)
    assert p["schema"] == C.SCHEMA_VERSION
    assert p["module"] == "bench_serving"
    assert p["git_sha"] and p["date"].endswith("Z")
    assert p["config"]["quick"] is True
    assert p["config_fingerprint"] == C.config_fingerprint(p["config"])
    path = tmp_path / "BENCH_serving.json"
    C.write_scoreboard(path, p)
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(p))  # JSON-stable
    rec = loaded["records"][0]
    assert rec == {"name": "a/b", "us_per_call": 10.0,
                   "derived": {"cost": 5}, "derived_raw": "cost=5"}


def test_fingerprint_distinguishes_quick_from_full():
    assert (C.config_fingerprint(C.run_config(quick=True))
            != C.config_fingerprint(C.run_config(quick=False)))


# -------------------------------------------------------- bench_compare
def _payload(records, quick=True):
    return C.scoreboard_payload("bench_serving", records, quick=quick)


def test_compare_ok_within_noise_band():
    base = _payload([C.row("a", 500.0, "scanned=10")])
    cur = _payload([C.row("a", 700.0, "scanned=10")])  # 1.4x < 1.6x
    vs = BC.compare_records("serving", base, cur)
    assert [v.status for v in vs] == ["ok"]


def test_compare_flags_wall_clock_regression():
    base = _payload([C.row("a", 500.0)])
    cur = _payload([C.row("a", 900.0)])  # 1.8x > 1.6x
    vs = BC.compare_records("serving", base, cur)
    assert [v.status for v in vs] == ["regression"]
    assert BC.is_fatal(vs[0])


def test_compare_ignores_sub_floor_timings():
    base = _payload([C.row("a", 5.0)])
    cur = _payload([C.row("a", 50.0)])  # 10x but both under min_us
    vs = BC.compare_records("serving", base, cur)
    assert [v.status for v in vs] == ["ok"]


def test_compare_counter_drift_is_exact_regression():
    base = _payload([C.row("a", 5.0, "verified=10")])
    cur = _payload([C.row("a", 5.0, "verified=11")])  # tiny timing, exact drift
    vs = BC.compare_records("serving", base, cur)
    assert vs[0].status == "regression" and "counter drift" in vs[0].detail


def test_compare_reports_improvement_not_fatal():
    base = _payload([C.row("a", 900.0)])
    cur = _payload([C.row("a", 300.0)])
    vs = BC.compare_records("serving", base, cur)
    assert [v.status for v in vs] == ["improvement"]
    assert not BC.is_fatal(vs[0])


def test_compare_new_and_vanished_records():
    base = _payload([C.row("old", 500.0)])
    cur = _payload([C.row("new", 500.0)])
    statuses = {v.name: v.status for v in BC.compare_records("serving", base, cur)}
    assert statuses == {"old": "missing-current", "new": "missing-baseline"}
    assert BC.is_fatal(BC.Verdict("m", "old", "missing-current"))
    assert not BC.is_fatal(BC.Verdict("m", "new", "missing-baseline"))


def test_compare_refuses_mismatched_fingerprints():
    base = _payload([C.row("a", 500.0)], quick=True)
    cur = _payload([C.row("a", 500.0)], quick=False)
    vs = BC.compare_records("serving", base, cur)
    assert vs[0].status == "regression" and "fingerprint" in vs[0].detail


def test_compare_dirs_missing_baseline_file(tmp_path):
    b, c = tmp_path / "b", tmp_path / "c"
    b.mkdir(), c.mkdir()
    C.write_scoreboard(c / "BENCH_knn.json", _payload([C.row("a", 5.0)]))
    vs = BC.compare_dirs(b, c)
    assert [(v.module, v.status) for v in vs] == [("knn", "missing-baseline")]
    assert not any(BC.is_fatal(v) for v in vs)


def test_compare_cli_exit_codes(tmp_path):
    b, c = tmp_path / "b", tmp_path / "c"
    b.mkdir(), c.mkdir()
    C.write_scoreboard(b / "BENCH_serving.json", _payload([C.row("a", 500.0)]))
    C.write_scoreboard(c / "BENCH_serving.json", _payload([C.row("a", 520.0)]))
    assert BC.main(["--baseline-dir", str(b), "--current-dir", str(c)]) == 0
    C.write_scoreboard(c / "BENCH_serving.json", _payload([C.row("a", 5000.0)]))
    assert BC.main(["--baseline-dir", str(b), "--current-dir", str(c)]) == 1


# ------------------------------------------------------------ run.py filter
def test_select_modules_substring_and_commas():
    assert select_modules(None) == MODULES
    assert select_modules("serving") == ["bench_serving"]
    got = select_modules("serving,knn")
    assert got == ["bench_knn", "bench_serving"]  # MODULES order preserved


def test_select_modules_no_match_raises_with_names():
    with pytest.raises(ValueError) as ei:
        select_modules("no_such_bench")
    msg = str(ei.value)
    assert "bench_serving" in msg and "bench_construction" in msg


def test_run_cli_no_match_exits_nonzero():
    env = dict(os.environ, PYTHONPATH=f"src{os.pathsep}" + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "no_such_bench"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode != 0
    assert "bench_serving" in proc.stdout + proc.stderr  # lists valid names


def test_scoreboard_modules_are_known():
    assert set(SCOREBOARD) <= set(MODULES)
    assert set(SCOREBOARD.values()) == {
        "BENCH_serving.json", "BENCH_knn.json",
        "BENCH_construction.json", "BENCH_dynamic.json",
        "BENCH_roofline.json",
    }


# ------------------------------------------- committed baselines (repo root)
@pytest.mark.parametrize("fname", sorted(SCOREBOARD.values()))
def test_committed_baseline_is_valid_scoreboard(fname):
    path = ROOT / fname
    assert path.exists(), f"committed scoreboard baseline {fname} missing"
    doc = json.loads(path.read_text())
    assert doc["schema"] == C.SCHEMA_VERSION
    assert doc["records"], "baseline has no records"
    assert doc["git_sha"] not in ("", "unknown")
    assert doc["config_fingerprint"] == C.config_fingerprint(doc["config"])
    for rec in doc["records"]:
        assert set(rec) == {"name", "us_per_call", "derived", "derived_raw"}
        assert C.parse_derived(rec["derived_raw"]) == rec["derived"]


def test_committed_serving_baseline_fused_no_slower():
    doc = json.loads((ROOT / "BENCH_serving.json").read_text())
    us = {r["name"]: r["us_per_call"] for r in doc["records"]}
    assert us["serving/verify-fused"] <= us["serving/verify-unfused"], (
        "committed quick baseline shows the fused verify path slower than "
        "the unfused one -- re-measure or fix the kernel before committing"
    )


# ----------------------------------------- docstring doc-reference checker
def test_docstring_checker_flags_missing_doc(tmp_path):
    py = tmp_path / "mod.py"
    py.write_text('"""Refers to NO_SUCH_DOC.md for details."""\n')
    errors = []
    CD.check_docstring_refs(py, errors)
    assert len(errors) == 1 and "NO_SUCH_DOC.md" in errors[0]


def test_docstring_checker_flags_missing_section(tmp_path):
    py = tmp_path / "mod.py"
    py.write_text('"""See EXPERIMENTS.md section Nonexistent for details."""\n')
    errors = []
    CD.check_docstring_refs(py, errors)
    assert len(errors) == 1 and "no such heading" in errors[0]


def test_docstring_checker_accepts_valid_refs(tmp_path):
    py = tmp_path / "mod.py"
    py.write_text(
        '"""Top doc: EXPERIMENTS.md section Perf."""\n'
        "def f():\n"
        '    """Nested: DESIGN.md, EXPERIMENTS.md section Roofline."""\n'
    )
    errors = []
    CD.check_docstring_refs(py, errors)
    assert errors == []


def test_repo_docstrings_reference_only_real_docs():
    assert CD.check_py_docstrings() == []
