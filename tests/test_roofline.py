"""Roofline machinery: trip-count-aware HLO stats + term assembly."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.roofline.hlo_stats import analyze, cost_analysis_dict
from repro.roofline.analysis import model_flops, roofline_from_record


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_cost_analysis_counts_while_body_once():
    """Documents the XLA behaviour the corrected parser exists for."""

    def body(c, _):
        return c @ c, None

    x = jnp.ones((128, 128))
    c = _compile(lambda x: jax.lax.scan(body, x, None, length=8)[0], x)
    raw = cost_analysis_dict(c)["flops"]
    assert raw == pytest.approx(2 * 128**3, rel=0.01)  # ONE body, not 8


def test_hlo_stats_multiplies_trip_counts():
    def body(c, _):
        return c @ c, None

    x = jnp.ones((128, 128))
    c = _compile(lambda x: jax.lax.scan(body, x, None, length=8)[0], x)
    st = analyze(c.as_text())
    assert st["flops"] == pytest.approx(8 * 2 * 128**3, rel=0.01)
    assert 8 in st["while_trips"]


def test_hlo_stats_nested_scans():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        c2, _ = jax.lax.scan(inner, c, None, length=4)
        return c2, None

    x = jnp.ones((64, 64))
    c = _compile(lambda x: jax.lax.scan(outer, x, None, length=3)[0], x)
    st = analyze(c.as_text())
    assert st["flops"] == pytest.approx(12 * 2 * 64**3, rel=0.01)


def test_hlo_stats_plain_matmul():
    x = jnp.ones((64, 32))
    y = jnp.ones((32, 48))
    c = _compile(lambda a, b: a @ b, x, y)
    st = analyze(c.as_text())
    assert st["flops"] == pytest.approx(2 * 64 * 32 * 48, rel=0.01)


def test_model_flops_shapes():
    from repro.configs import get_config

    cfg = get_config("tinyllama-1.1b")
    f_train = model_flops(cfg, "train_4k")
    f_prefill = model_flops(cfg, "prefill_32k")
    f_decode = model_flops(cfg, "decode_32k")
    # 6*N*D with N~1.1B, D=1M tokens
    assert 5e15 < f_train < 1e16, f_train
    assert f_prefill == pytest.approx(f_train / 3, rel=0.01)  # same tokens, 2ND vs 6ND
    assert f_decode < f_prefill / 1000  # one token per sequence


def test_roofline_from_record_picks_bottleneck():
    rec = dict(
        arch="tinyllama-1.1b",
        shape="train_4k",
        mesh="pod16x16",
        devices=256,
        hlo_corrected=dict(dot_flops_per_device=3e13, collective_total_per_device=2e9),
        cost={"flops": 1e12},
    )
    row = roofline_from_record(rec)
    assert row.bottleneck in ("compute", "memory", "collective")
    assert row.compute_s > 0 and row.memory_s > 0 and row.collective_s > 0
    assert 0 < row.useful_ratio < 2.0


def test_descent_bytes_model_arithmetic():
    """The descent byte model is exact integer arithmetic (scoreboard
    counters diff bit-for-bit): hand-computed terms for a tiny config."""
    from repro.roofline import descent_bytes as DB

    # legacy filter: M*F*(16+4W) + M*(16+4W) + M*F
    assert DB.filter_level_bytes(2, 8, 3) == 2 * 8 * 28 + 2 * 28 + 2 * 8
    # narrow: M*F*(8+4Wp) + M*(16+4Wp) + M*F + (Dx+Dy)*4
    got = DB.filter_level_bytes(
        2, 8, 3, narrow=True, packed_words=2, dict_sizes=(5, 7))
    assert got == 2 * 8 * 16 + 2 * 24 + 2 * 8 + 12 * 4
    per_obj = 12 + 4 * 3
    assert DB.verify_bytes(4, 2, 8, 3, 16, "unfused") == 3 * 4 * 2 * 8 * per_obj
    assert DB.verify_bytes(4, 2, 8, 3, 16, "prefetch") == 4 * 2 * 8 * per_obj
    # vmem: ceil(M/bm) blocks re-stream the whole K*OBJ bank
    assert DB.verify_bytes(9, 2, 8, 3, 16, "vmem", bm=8) == 2 * 16 * 8 * per_obj
    import pytest

    with pytest.raises(ValueError):
        DB.verify_bytes(1, 1, 1, 1, 1, "hbm")


def test_descent_bytes_narrow_always_cheaper():
    """For any config with Wp <= W the narrow filter term can't exceed the
    legacy one by more than the dictionary overhead, and the aggregate
    helper sums levels + the chosen verify variant."""
    from repro.roofline import descent_bytes as DB

    legacy = DB.descent_bytes(16, [32, 8], 15)
    narrow = DB.descent_bytes(
        16, [32, 8], 15, narrow=True, packed_words=4,
        dict_sizes=[(10, 10), (6, 6)])
    assert legacy.total == sum(legacy.per_level)
    assert narrow.total < legacy.total
    both = DB.descent_bytes(
        16, [32, 8], 15, t=4, obj_per_leaf=8, n_leaves=32,
        verify_variant="prefetch")
    assert both.total == both.filter_bytes + both.verify_bytes
    assert both.verify_bytes == DB.verify_bytes(16, 4, 8, 15, 32, "prefetch")
    cmp = DB.compare(legacy, narrow)
    assert cmp["ratio"] > 1.0 and cmp["legacy_bytes"] == legacy.total
