"""Hypothesis property tests on system invariants.

hypothesis lives in requirements-test.txt, not the runtime deps; the module
skips cleanly (instead of failing collection) where it isn't installed.
This is the one intentional tier-1 skip on bare-runtime boxes: CI's tier-1
lane installs requirements-test.txt, so every property test runs (and
gates) there -- the local skip trades nothing away.
"""
import functools

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (requirements-test.txt; installed "
    "and enforced in the CI tier-1 lane -- only bare-runtime boxes skip)",
)
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.itemsets import mine_frequent_itemsets
from repro.core.types import GeoTextDataset, ids_to_bitmap, bitmap_intersects
from repro.optim.compression import (
    ef_init,
    int8_dequantize,
    int8_quantize,
    topk_compress,
    topk_with_error_feedback,
)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 40),
    v=st.integers(2, 40),
    seed=st.integers(0, 1000),
)
def test_bitmap_equals_set_semantics(n, v, seed):
    rng = np.random.default_rng(seed)
    a_ids = np.full((n, 4), -1, np.int32)
    b_ids = np.full((n, 4), -1, np.int32)
    for i in range(n):
        ka = rng.choice(v, size=rng.integers(0, min(4, v + 1)), replace=False)
        kb = rng.choice(v, size=rng.integers(1, min(4, v + 1)), replace=False)
        a_ids[i, : ka.size] = ka
        b_ids[i, : kb.size] = kb
    a_bm = ids_to_bitmap(a_ids, v)
    b_bm = ids_to_bitmap(b_ids, v)
    got = bitmap_intersects(a_bm, b_bm)
    want = np.array(
        [bool(set(a_ids[i][a_ids[i] >= 0]) & set(b_ids[i][b_ids[i] >= 0])) for i in range(n)]
    )
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 80), v=st.integers(4, 12), seed=st.integers(0, 100))
def test_apriori_matches_bruteforce_pairs(n, v, seed):
    rng = np.random.default_rng(seed)
    kw_ids = np.full((n, 3), -1, np.int32)
    for i in range(n):
        ks = rng.choice(v, size=rng.integers(1, 4), replace=False)
        kw_ids[i, : ks.size] = ks
    locs = rng.uniform(0, 1, (n, 2)).astype(np.float32)
    ds = GeoTextDataset.from_ids(locs, kw_ids, v)
    min_support = 3 / n
    itemsets, members = mine_frequent_itemsets(ds, min_support=min_support, max_size=2)
    got_pairs = {s for s in itemsets if len(s) == 2}
    # brute force
    want = set()
    sets = [set(kw_ids[i][kw_ids[i] >= 0].tolist()) for i in range(n)]
    for a in range(v):
        for b in range(a + 1, v):
            cnt = sum(1 for s in sets if a in s and b in s)
            if cnt >= max(2, int(np.ceil(min_support * n))):
                want.add((a, b))
    assert got_pairs == want
    # member lists exact
    for s, mem in zip(itemsets, members):
        if len(s) == 2:
            a, b = s
            want_mem = [i for i in range(n) if a in sets[i] and b in sets[i]]
            np.testing.assert_array_equal(np.sort(mem), want_mem)


@settings(max_examples=20, deadline=None)
@given(
    size=st.integers(4, 300),
    frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 1000),
)
def test_topk_contraction(size, frac, seed):
    """||x - topk(x)|| <= ||x|| with equality only when nothing kept."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, size).astype(np.float32))
    c = topk_compress(x, frac)
    err = np.linalg.norm(np.asarray(x - c))
    assert err <= np.linalg.norm(np.asarray(x)) + 1e-6
    k = max(1, int(size * frac))
    assert int((np.asarray(c) != 0).sum()) <= size  # kept entries bounded
    # kept entries are the largest-magnitude ones
    kept_mag = np.abs(np.asarray(c)[np.asarray(c) != 0])
    dropped_mag = np.abs(np.asarray(x))[np.asarray(c) == 0]
    if kept_mag.size and dropped_mag.size:
        assert kept_mag.min() >= dropped_mag.max() - 1e-6


@settings(max_examples=20, deadline=None)
@given(size=st.integers(2, 200), seed=st.integers(0, 1000))
def test_int8_quantization_error_bound(size, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 3, size).astype(np.float32))
    q, s = int8_quantize(x)
    back = int8_dequantize(q, s)
    max_err = float(jnp.max(jnp.abs(back - x)))
    assert max_err <= float(s) * 0.5 + 1e-6


@settings(max_examples=30, deadline=None)
@given(
    n_down=st.integers(1, 60),
    m=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_csr_frontier_propagation_matches_dense_matmul(n_down, m, seed):
    """CSR frontier expansion == dense 0/1 adjacency matmul on random parents.

    This is the invariant the serving engine's sparse frontier descent rests
    on (DESIGN.md §3): expanding the surviving frontier through the padded
    CSR child table must activate exactly the children the dense
    ``hit @ child_matrix > 0`` mask would.
    """
    from repro.core.query import padded_child_table, propagate_hits

    rng = np.random.default_rng(seed)
    n_up = int(rng.integers(1, n_down + 1))
    parent = rng.integers(0, n_up, n_down)
    parent[rng.integers(0, n_down)] = n_up - 1  # keep the parent count exact
    order = np.argsort(parent, kind="stable").astype(np.int32)
    ptr = np.zeros(n_up + 1, np.int64)
    np.cumsum(np.bincount(parent, minlength=n_up), out=ptr[1:])

    class _Level:
        child_ptr, child, n = ptr, order, n_up

    hit = rng.integers(0, 2, (m, n_up)).astype(bool)
    got = propagate_hits(hit, padded_child_table(_Level), n_down)
    adj = np.zeros((n_up, n_down), np.int8)
    adj[parent, np.arange(n_down)] = 1
    np.testing.assert_array_equal(got, (hit @ adj) > 0)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 30),
    n_groups=st.integers(1, 35),
    seed=st.integers(0, 1000),
)
def test_spectral_group_properties(n, n_groups, seed):
    """spectral_group (packing accel §6) contracts: deterministic under a
    fixed seed, group ids compact in 0..G-1, and identity when n_groups >= n."""
    from repro.core.packing import spectral_group

    rng = np.random.default_rng(seed)
    lo = rng.uniform(0, 1, (n, 2))
    mbrs = np.concatenate([lo, lo + rng.uniform(0, 0.2, (n, 2))], axis=1).astype(np.float32)
    g1 = spectral_group(mbrs, n_groups, seed=seed)
    g2 = spectral_group(mbrs, n_groups, seed=seed)
    np.testing.assert_array_equal(g1, g2)  # determinism under a fixed seed
    assert g1.shape == (n,) and g1.dtype == np.int32
    # compact ids: every group in 0..G-1 is used
    G = int(g1.max()) + 1
    assert G <= min(max(n_groups, 1), n)
    np.testing.assert_array_equal(np.unique(g1), np.arange(G))
    if n_groups >= n:  # no grouping requested: identity assignment
        np.testing.assert_array_equal(g1, np.arange(n, dtype=np.int32))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 40),
    m=st.integers(1, 8),
    w=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
def test_quantized_frontier_is_superset_filter(n, m, w, seed):
    """The narrow (int16-code / packed-word) frontier never prunes a slot
    the f32 frontier keeps -- the safety contract of the bandwidth-lean
    descent (DESIGN.md §3.5). The rank-code planes are lossless, so the
    implementation actually delivers the stronger bit-identical guarantee;
    both are asserted (superset is the contract, equality the mechanism).
    """
    from repro.kernels import ops
    from repro.kernels.ref import frontier_filter_narrow_ref, frontier_filter_ref
    from repro.serve.snapshot import encode_mbr_planes

    rng = np.random.default_rng(seed)
    lo = rng.uniform(0, 1, (n, 2)).astype(np.float32)
    mbrs = np.concatenate(
        [lo, lo + rng.uniform(0, 0.3, (n, 2)).astype(np.float32)], axis=1
    )
    codes, dicts_x, dicts_y = encode_mbr_planes([mbrs])
    assert codes, "tiny MBR sets must never overflow the int16 dictionaries"
    n_bm = rng.integers(0, 2**32, (n, w), dtype=np.uint64).astype(np.uint32)
    # query word planes with zeroed words so pack_query_words really packs
    q_bm = rng.integers(0, 2**32, (m, w), dtype=np.uint64).astype(np.uint32)
    q_bm *= rng.random((m, w)) < 0.5
    q_lo = rng.uniform(0, 1, (m, 2)).astype(np.float32)
    q_rects = np.concatenate(
        [q_lo, q_lo + rng.uniform(0, 0.4, (m, 2)).astype(np.float32)], axis=1
    )
    F = int(rng.integers(1, 2 * n + 1))
    idx = rng.integers(0, n, (m, F))
    valid = rng.integers(0, 2, (m, F)).astype(np.int8)

    legacy = np.asarray(
        frontier_filter_ref(q_rects, q_bm, mbrs[idx], n_bm[idx], valid)
    )
    wids, bits = ops.pack_query_words(q_bm)
    wids = np.asarray(wids)
    narrow = np.asarray(
        frontier_filter_narrow_ref(
            q_rects,
            bits,
            np.asarray(codes[0])[idx],
            n_bm[idx[:, :, None], wids[:, None, :]],
            valid,
            dicts_x[0],
            dicts_y[0],
        )
    )
    assert np.all(narrow >= legacy), "narrow frontier pruned a surviving slot"
    np.testing.assert_array_equal(narrow, legacy)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 6),
    t=st.integers(1, 4),
    k=st.integers(1, 8),
    obj=st.integers(1, 16),
    w=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_compact_verify_preserves_verified_ids(m, t, k, obj, w, seed):
    """The leaf-local vocabulary remap + one-word signature prefilter never
    change the verified id set or the per-slot Eq.1 counts (DESIGN.md §3.5):
    for ANY leaf bank -- dense or sparse vocabularies, dirty leaf ids, -1
    object pads, invalid slots -- the compact reference is elementwise
    identical to the full-width fused reference. Exactness is structural
    (object term sets are subsets of their leaf dictionary; the signature
    test is implied by the word test), so equality must hold unconditionally,
    not just on distributions the encoder was designed for.
    """
    from repro.kernels import ops
    from repro.kernels.ref import fused_verify_compact_ref, fused_verify_ref
    from repro.serve.snapshot import encode_leaf_vocab

    rng = np.random.default_rng(seed)
    lo = rng.uniform(0, 0.8, (m, 2)).astype(np.float32)
    qr = np.concatenate([lo, lo + rng.uniform(0.01, 0.4, (m, 2)).astype(np.float32)], 1)
    qb = rng.integers(0, 2**32, (m, w), dtype=np.uint64).astype(np.uint32)
    qb *= rng.random((m, w)) < 0.6
    ob = rng.integers(0, 2**32, (k, obj, w), dtype=np.uint64).astype(np.uint32)
    ob *= rng.random((k, obj, w)) < 0.4
    tl = rng.integers(-1, k + 2, (m, t)).astype(np.int32)  # deliberately dirty
    ok = rng.integers(0, 2, (m, t)).astype(np.int8)
    ox = rng.uniform(0, 1, (k, obj)).astype(np.float32)
    oy = rng.uniform(0, 1, (k, obj)).astype(np.float32)
    oid = np.where(
        rng.integers(0, 4, (k, obj)) > 0,
        rng.integers(0, 10 * k * obj, (k, obj)), -1,
    ).astype(np.int32)

    lt, cbm, sig = encode_leaf_vocab(ob)
    assert lt is not None, "tiny banks must never overflow LEAF_DICT_MAX"
    q_cbm, q_sig = ops.remap_query_words(jnp.asarray(qb), lt, jnp.asarray(tl))
    wide_ids, wide_kwv = fused_verify_ref(
        *map(jnp.asarray, (qr, qb, tl, ok, ox, oy, ob, oid))
    )
    comp_ids, comp_kwv = fused_verify_compact_ref(
        *map(jnp.asarray, (qr, q_cbm, q_sig, tl, ok, ox, oy, cbm, sig, oid))
    )
    np.testing.assert_array_equal(np.asarray(comp_ids), np.asarray(wide_ids))
    np.testing.assert_array_equal(np.asarray(comp_kwv), np.asarray(wide_kwv))


def test_error_feedback_recovers_dropped_mass():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, 64).astype(np.float32))}
    ef = ef_init(g)
    total_sent = np.zeros(64, np.float32)
    for _ in range(50):
        sent, ef = topk_with_error_feedback(g, ef, frac=0.1)
        total_sent += np.asarray(sent["w"])
    # with constant gradient, EF ensures average transmitted -> gradient
    np.testing.assert_allclose(total_sent / 50, np.asarray(g["w"]), atol=0.25)


# ------------------------------------------------------------------------
# Continuous-filter pub-sub (DESIGN.md §8): device notification stream ==
# brute-force oracle replay, exactly, for arbitrary schedules.
@functools.lru_cache(maxsize=1)
def _streaming_serving():
    """One tiny grid-served dataset shared across examples (fresh DeltaLog /
    SubscriptionIndex per example keeps examples independent)."""
    from repro.data.synth import make_dataset
    from repro.serve.engine import IndexSnapshot
    from test_query_parity import _build_index

    ds = make_dataset("fs", n=500, seed=0)
    index, _ = _build_index(ds, g=4, levels=2)
    return ds, index, IndexSnapshot.build(index, ds)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 100_000),
    n_subs=st.integers(0, 10),
    n_events=st.integers(1, 8),
)
def test_streaming_notifications_equal_oracle_multiset(seed, n_subs, n_events):
    """For arbitrary subscription sets and object streams -- subscription
    churn, object deletes with slot reuse, buffer growth, interleaved
    full-buffer pumps and mid-stream drains -- the emitted notification
    multiset equals the oracle's exactly (stronger: the canonical-order
    sequences are identical): no misses, no duplicates. Object keywords are
    drawn from the whole vocabulary, so arrivals routinely fall outside
    their leaf's compact dictionary and flip the PR 9 sticky fallback
    mid-schedule; the stream must not care."""
    from repro.core.query import SubscriptionOracle
    from repro.serve.delta import DeltaLog
    from repro.serve.subscribe import SubscriptionIndex

    ds, index, snap = _streaming_serving()
    log = DeltaLog(index, ds, snap, slots_per_leaf=4)
    idx, orc = SubscriptionIndex(ds.vocab_size), SubscriptionOracle()
    rng = np.random.default_rng(seed)
    live_subs, live_objs = [], []

    def rand_kw(lo=0):
        # mostly a hot 8-term head (so subscriptions and arrivals actually
        # intersect and the test is not vacuous), sometimes the full
        # vocabulary (so arrivals carry terms outside their leaf's compact
        # dictionary and flip the PR 9 sticky fallback)
        k = int(rng.integers(lo, 4))
        kw = np.full(4, -1, np.int64)
        pool = 8 if rng.random() < 0.7 else ds.vocab_size
        if k:
            kw[:k] = rng.choice(pool, size=min(k, pool), replace=False)
        return kw

    for _ in range(n_subs):
        c, h = rng.random(2), rng.random(2) * 0.5
        rect = np.concatenate([np.maximum(c - h, 0), np.minimum(c + h, 1)])
        if rng.random() < 0.2:
            rect[2:] = rect[:2]  # zero-area geofence
        kw = rand_kw()
        a, b = idx.subscribe(rect, kw), orc.subscribe(rect, kw)
        assert a == b
        live_subs.append(a)
    for _ in range(n_events):
        op = rng.random()
        if op < 0.55 or not live_objs:  # arrivals (biased: streams are long)
            n = int(rng.integers(1, 12))
            locs = rng.random((n, 2)).astype(np.float32)
            okw = np.stack([rand_kw() for _ in range(n)])
            ids = log.insert(locs, okw)
            idx.match_arrivals(ids, locs, kw_ids=okw)
            orc.arrive(ids, locs, okw)
            live_objs.extend(int(i) for i in ids)
        elif op < 0.7 and live_subs:  # subscription churn
            s = live_subs.pop(int(rng.integers(len(live_subs))))
            assert idx.unsubscribe(s) == orc.unsubscribe(s)
        elif op < 0.85:  # object deletes free slots for reuse
            k = int(rng.integers(1, min(4, len(live_objs)) + 1))
            dels = rng.choice(live_objs, size=k, replace=False)
            log.delete(dels)
            live_objs = [o for o in live_objs if o not in set(int(d) for d in dels)]
        else:  # a redundant full-buffer sweep must emit nothing new
            assert idx.pump(log) == 0
        if rng.random() < 0.25:  # mid-stream drain: exactly-once, in order
            np.testing.assert_array_equal(idx.drain(), orc.drain())
    np.testing.assert_array_equal(idx.drain(), orc.drain())
    assert idx.pump(log) == 0
    assert idx.drain().shape == (0, 2) and orc.drain().shape == (0, 2)
    assert idx.matched_total == orc.matched_total
    assert idx.emitted_total == orc.emitted_total
