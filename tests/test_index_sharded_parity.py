"""Index-parallel serving parity (DESIGN.md §3.4, index-sharded regime).

``PartitionedSnapshot`` cuts the root forest into shard-local
sub-hierarchies and ``serve_index_sharded`` / ``serve_knn_index_sharded``
descend each shard from its local root frontier, combining per-shard
results with collectives (id-union + psum'd Eq.1 counters for SKR; global
top-k merge with bound exchange for kNN). The contract under test:

* **Partitioner invariants** (host-only): every node lands in exactly one
  shard, shards are closed under the child relation, the greedy-LPT cut is
  deterministic and balanced, bad shard counts raise, and per-device bytes
  genuinely shrink ~1/S versus a full replica.
* **SKR parity**: identical result-id SETS (shard-concat order differs
  from single-device id order by construction) and exactly identical
  ``counts`` / ``nodes_checked`` / ``verified`` / ``kw_scanned`` /
  ``overflow`` -- through ragged batches, width growth from a cold
  ``PlanCache``, ``max_leaves`` overflow, and a live ``DeltaBuffer``.
* **kNN parity**: bit-identical id sequences, distances, and counters --
  the canonical-shard probe election, shared-bound sweep, and global-rank
  leaf merge reproduce the single-device bounded descent exactly.
* **LiveIndex routing**: ``index_shards > 1`` serves through the
  partitioned generation (updates included) with unchanged results.

Multi-device tests need the 8-device mesh (4 query x 2 index, and 2 x 4);
on a single-device box they re-exec in a subprocess with a forced 8-device
host platform (pattern of test_delta_maintenance.py) -- the index-sharded
contract gates everywhere, not only on CI's pre-forced lane.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.launch.mesh import make_serving_mesh
from repro.launch.wisk_serve import (
    LiveIndex,
    default_index_mesh,
    mesh_index_size,
    serve_index_sharded,
    serve_knn_index_sharded,
)
from repro.serve.engine import IndexSnapshot, retrieve, retrieve_knn
from repro.serve.plan import PlanCache
from repro.serve.snapshot import (
    PartitionedSnapshot,
    partition_index,
    tree_nbytes,
)

from test_delta_maintenance import _updated_log
from test_query_parity import _build_index

# exact-counter keys shared by both regimes ("nodes_scanned" excluded: the
# padded frontier width differs per shard, so the index-sharded regime
# reports sum-over-shards of its own widths -- documented, not parity;
# "verified" is the psum'd Eq.1 kw_scanned cost)
SKR_EXACT = ("counts", "nodes_checked", "verified", "overflow")
KNN_KEYS = ("ids", "dist2", "nodes_checked", "verified", "leaves_verified", "pruned")

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 devices (runs via the re-exec lane)"
)


def _fixture(n=1500, seed=0, g=6, levels=2, m=13, wl_seed=10, **wl_kw):
    ds = make_dataset("fs", n=n, seed=seed)
    index, clusters = _build_index(ds, g=g, levels=levels)
    snap = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=m, dist=wl_kw.pop("dist", "MIX"), seed=wl_seed, **wl_kw)
    return ds, index, clusters, snap, wl


def _points_from(wl) -> np.ndarray:
    return np.stack(
        [(wl.rects[:, 0] + wl.rects[:, 2]) / 2, (wl.rects[:, 1] + wl.rects[:, 3]) / 2], 1
    ).astype(np.float32)


def _sorted_ids(row):
    return np.sort(row[row >= 0])


def _assert_skr_same(single, sharded, m):
    for k in SKR_EXACT:
        np.testing.assert_array_equal(
            np.asarray(single[k])[:m], np.asarray(sharded[k])[:m], err_msg=k
        )
    for qi in range(m):
        assert np.array_equal(
            _sorted_ids(np.asarray(single["ids"][qi])),
            _sorted_ids(np.asarray(sharded["ids"][qi])),
        ), f"q{qi}: result-id sets differ"


# --------------------------------------------------- partitioner (host-only)
@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
def test_partition_covers_disjointly_and_is_closed(n_shards):
    """Each level's node set is exactly partitioned, and every child of a
    shard's node lives in the same shard (subtrees are assigned whole)."""
    _, _, _, snap, _ = _fixture()
    part = partition_index(snap, n_shards)
    for li in range(snap.n_levels):
        n_li = int(snap.level_mbrs[li].shape[0])
        all_ids = np.concatenate([part.nodes[li][s] for s in range(n_shards)])
        assert np.array_equal(np.sort(all_ids), np.arange(n_li))
        assert all(np.array_equal(ids, np.sort(ids)) for ids in part.nodes[li])
        np.testing.assert_array_equal(
            part.shard_of[li][part.nodes[li][0]], 0
        )
    for li in range(snap.n_levels - 1):
        table = np.asarray(snap.child_table[li])
        for s in range(n_shards):
            kids = table[part.nodes[li][s]]
            kids = kids[kids >= 0]
            assert (part.shard_of[li + 1][kids] == s).all(), (
                f"level {li} shard {s} leaks children across the cut"
            )


def test_partition_is_deterministic_and_balanced():
    """Same input -> identical cut, and greedy LPT keeps the leaf-count
    imbalance within the heaviest single subtree (the theoretical bound for
    whole-subtree assignment)."""
    _, _, _, snap, _ = _fixture()
    a, b = partition_index(snap, 3), partition_index(snap, 3)
    np.testing.assert_array_equal(a.root_to_shard, b.root_to_shard)
    for li in range(snap.n_levels):
        for s in range(3):
            np.testing.assert_array_equal(a.nodes[li][s], b.nodes[li][s])
    table = np.asarray(snap.child_table[0])
    subtree_leaves = (table >= 0).sum(axis=1)
    loads = [a.nodes[-1][s].size for s in range(3)]
    assert max(loads) - min(loads) <= int(subtree_leaves.max())


def test_partition_rejects_bad_shard_counts():
    _, _, _, snap, _ = _fixture()
    with pytest.raises(ValueError, match="n_shards"):
        partition_index(snap, 0)
    n_root = int(snap.level_mbrs[0].shape[0])
    with pytest.raises(ValueError, match="root subtrees"):
        partition_index(snap, n_root + 1)


def test_partitioned_snapshot_layout_and_gid_maps():
    """Stacked slabs carry the right rows: global-id maps round-trip, child
    tables hold in-range shard-local ids, and pad rows are inert (-1)."""
    _, _, _, snap, _ = _fixture()
    psnap = PartitionedSnapshot.build(snap, 2)
    part = psnap.part
    L = snap.n_levels
    Kp = part.leaf_pad
    leaf_gid = np.asarray(psnap.leaf_gid)
    root_gid = np.asarray(psnap.root_gid)
    counts = np.asarray(psnap.level_counts)
    for s in range(2):
        n_leaf = part.nodes[L - 1][s].size
        np.testing.assert_array_equal(
            leaf_gid[s * Kp : s * Kp + n_leaf], part.nodes[L - 1][s]
        )
        assert (leaf_gid[s * Kp + n_leaf : (s + 1) * Kp] == -1).all()
        n_root = part.nodes[0][s].size
        p0 = part.level_pads[0]
        np.testing.assert_array_equal(
            root_gid[s * p0 : s * p0 + n_root], part.nodes[0][s]
        )
        np.testing.assert_array_equal(
            counts[s], [part.nodes[li][s].size for li in range(L)]
        )
        # shard-local child ids stay inside the shard's next-level slab
        for li in range(L - 1):
            tbl = np.asarray(psnap.child_table[li])[s * part.level_pads[li] : (s + 1) * part.level_pads[li]]
            kids = tbl[tbl >= 0]
            assert kids.size and (kids < part.nodes[li + 1][s].size).all()
        # the original MBRs landed in their slab rows
        m0 = np.asarray(snap.level_mbrs[L - 1])[part.nodes[L - 1][s]]
        np.testing.assert_array_equal(
            np.asarray(psnap.level_mbrs[L - 1])[s * Kp : s * Kp + n_leaf], m0
        )


def test_per_shard_bytes_shrink_with_shard_count():
    """The point of the regime: each device holds ~1/S of the index. Byte
    telemetry must reflect that against the full-replica footprint."""
    _, _, _, snap, _ = _fixture()
    replica = tree_nbytes(snap)
    per = {s: PartitionedSnapshot.build(snap, s).per_shard_bytes() for s in (1, 2, 4)}
    assert per[4] < per[2] < replica
    assert per[2] < 0.75 * replica  # ~1/2 + pad overhead
    assert per[4] < 0.45 * replica  # ~1/4 + pad overhead


def test_partition_narrow_planes_decode_losslessly():
    """Per-shard int16 shadow planes must reconstruct the exact f32 MBRs of
    every real (non-pad) row through the shard-local dictionaries."""
    _, _, _, snap, _ = _fixture()
    if not snap.has_narrow_planes:
        pytest.skip("base snapshot has no narrow planes")
    psnap = PartitionedSnapshot.build(snap, 2)
    assert psnap.has_narrow_planes
    part = psnap.part
    for li in range(psnap.n_levels):
        pad = part.level_pads[li]
        codes = np.asarray(psnap.level_mbr_codes[li]).astype(np.int64)
        dx = np.asarray(psnap.level_dict_x[li]).reshape(2, -1)
        dy = np.asarray(psnap.level_dict_y[li]).reshape(2, -1)
        for s in range(2):
            n = part.nodes[li][s].size
            c = codes[s * pad : s * pad + n]
            rec = np.stack(
                [dx[s][c[:, 0]], dy[s][c[:, 1]], dx[s][c[:, 2]], dy[s][c[:, 3]]], 1
            )
            np.testing.assert_array_equal(
                rec, np.asarray(snap.level_mbrs[li])[part.nodes[li][s]]
            )


def test_default_index_mesh_validates_device_count():
    n = len(jax.devices())
    mesh = default_index_mesh(1)
    assert mesh_index_size(mesh) == 1
    with pytest.raises(ValueError, match="devices"):
        default_index_mesh(n + 1 if n == 1 else 3 if n % 3 else n + 1)


# ------------------------------------------------- multi-device parity lane
def test_index_sharded_reexec_with_forced_devices():
    """On a single-device box the multi-device tests below skip; this
    launcher re-runs the whole file in a subprocess with a forced 8-device
    host platform so the index-sharded contract still gates. Under the CI
    8-device lane the tests run inline and this launcher is a no-op."""
    if len(jax.devices()) >= 8:
        pytest.skip("multi-device tests ran inline")
    assert "_IX_SHARDED_REEXEC" not in os.environ, (
        "re-exec with a forced 8-device host platform still saw <8 devices"
    )
    env = dict(os.environ)
    flag = "--xla_force_host_platform_device_count=8"
    env["XLA_FLAGS"] = f"{env.get('XLA_FLAGS', '')} {flag}".strip()
    env["_IX_SHARDED_REEXEC"] = "1"
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    assert proc.returncode == 0, (
        f"forced 8-device re-exec failed:\n{proc.stdout[-4000:]}\n{proc.stderr[-2000:]}"
    )


@needs8
@pytest.mark.parametrize("n_shards,query", [(2, 4), (4, 2)])
def test_ix_skr_matches_single_device(n_shards, query):
    """Ragged 13-query batch: identical id sets and exact Eq.1 counters
    across 2- and 4-way index sharding on both 2D mesh shapes."""
    _, _, clusters, snap, wl = _fixture()
    single = retrieve(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k,
                      plan_cache=PlanCache())
    psnap = PartitionedSnapshot.build(snap, n_shards)
    mesh = make_serving_mesh(query=query, index=n_shards)
    out = serve_index_sharded(psnap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k,
                              mesh=mesh, plan_cache=PlanCache())
    assert out["ids"].shape[0] == wl.m  # padding sliced back off
    _assert_skr_same(single, out, wl.m)


@needs8
def test_ix_skr_width_growth_overflow_and_warm_cache():
    """A cold PlanCache converges through the grow-and-redescend loop to
    the same results; max_leaves=2 forces leaf spill with exact overflow
    parity; and the warmed cache reproduces the batch identically."""
    _, _, clusters, snap, wl = _fixture(
        n=2500, seed=5, g=8, levels=3, m=16, wl_seed=9,
        dist="UNI", region_frac=0.2, n_keywords=4,
    )
    psnap = PartitionedSnapshot.build(snap, 2)
    mesh = make_serving_mesh(query=4, index=2)
    for max_leaves in (2, clusters.k):
        single = retrieve(snap, wl.rects, wl.kw_bitmap, max_leaves=max_leaves,
                          plan_cache=PlanCache())
        cache = PlanCache()
        first = serve_index_sharded(psnap, wl.rects, wl.kw_bitmap,
                                    max_leaves=max_leaves, mesh=mesh, plan_cache=cache)
        _assert_skr_same(single, first, wl.m)
        again = serve_index_sharded(psnap, wl.rects, wl.kw_bitmap,
                                    max_leaves=max_leaves, mesh=mesh, plan_cache=cache)
        _assert_skr_same(single, again, wl.m)
    assert serve_index_sharded(
        psnap, wl.rects, wl.kw_bitmap, max_leaves=2, mesh=mesh, plan_cache=PlanCache()
    )["overflow"].sum() > 0


@needs8
def test_ix_skr_delta_parity():
    """Live DeltaBuffer (inserts + base/buffered deletes) routed to its
    owning shards: id sets and counters still match the single-device
    delta-merged descent."""
    ds, index, clusters, snap, wl = _fixture(seed=1, wl_seed=7)
    log = _updated_log(ds, index, snap, seed=7)
    single = retrieve(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k,
                      plan_cache=PlanCache(), delta=log.buffer)
    for n_shards in (2, 4):
        psnap = PartitionedSnapshot.build(snap, n_shards)
        mesh = make_serving_mesh(query=8 // n_shards, index=n_shards)
        out = serve_index_sharded(psnap, wl.rects, wl.kw_bitmap,
                                  max_leaves=clusters.k, mesh=mesh,
                                  plan_cache=PlanCache(), delta=log.buffer)
        _assert_skr_same(single, out, wl.m)


@needs8
@pytest.mark.parametrize("n_shards,query,k", [(2, 4, 5), (4, 2, 5), (2, 4, 1)])
def test_ix_knn_matches_single_device(n_shards, query, k):
    """Bit-identical kNN: id sequences, distances, and every counter --
    the canonical-shard probe + shared-bound sweep + global-rank leaf merge
    reproduce the single-device bounded descent exactly."""
    _, _, _, snap, wl = _fixture()
    points = _points_from(wl)
    single = retrieve_knn(snap, points, wl.kw_bitmap, k, plan_cache=PlanCache())
    psnap = PartitionedSnapshot.build(snap, n_shards)
    mesh = make_serving_mesh(query=query, index=n_shards)
    out = serve_knn_index_sharded(psnap, points, wl.kw_bitmap, k,
                                  mesh=mesh, plan_cache=PlanCache())
    assert out["ids"].shape == (wl.m, k)
    for key in KNN_KEYS:
        np.testing.assert_array_equal(
            np.asarray(single[key])[:wl.m], np.asarray(out[key])[:wl.m], err_msg=key
        )
    # k <= 0 degenerates identically
    assert serve_knn_index_sharded(
        psnap, points, wl.kw_bitmap, 0, mesh=mesh
    )["ids"].shape == (wl.m, 0)


@needs8
def test_ix_knn_delta_parity():
    ds, index, _, snap, wl = _fixture(seed=1, wl_seed=7)
    points = _points_from(wl)
    log = _updated_log(ds, index, snap, seed=7)
    single = retrieve_knn(snap, points, wl.kw_bitmap, 5,
                          plan_cache=PlanCache(), delta=log.buffer)
    psnap = PartitionedSnapshot.build(snap, 2)
    mesh = make_serving_mesh(query=4, index=2)
    out = serve_knn_index_sharded(psnap, points, wl.kw_bitmap, 5, mesh=mesh,
                                  plan_cache=PlanCache(), delta=log.buffer)
    for key in KNN_KEYS:
        np.testing.assert_array_equal(
            np.asarray(single[key])[:wl.m], np.asarray(out[key])[:wl.m], err_msg=key
        )


@needs8
def test_liveindex_routes_through_partitioned_generation():
    """index_shards=2 serves SKR and kNN through the partitioned snapshot
    with unchanged results, and a live insert is visible to the very next
    sharded batch (the delta is re-routed to its owning shards)."""
    from types import SimpleNamespace

    ds, index, clusters, snap, wl = _fixture()
    points = _points_from(wl)
    mesh = make_serving_mesh(query=4, index=2)
    li = LiveIndex(ds, wl, artifacts=SimpleNamespace(index=index),
                   index_shards=2, index_mesh=mesh)
    assert li.generation.partitioned is not None
    single = retrieve(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k,
                      plan_cache=PlanCache())
    _assert_skr_same(single, li.serve(wl.rects, wl.kw_bitmap, max_leaves=clusters.k), wl.m)
    ksingle = retrieve_knn(snap, points, wl.kw_bitmap, 5, plan_cache=PlanCache())
    kout = li.serve_knn(points, wl.kw_bitmap, 5)
    np.testing.assert_array_equal(np.asarray(ksingle["ids"])[:wl.m], kout["ids"][:wl.m])
    # live update: the buffered insert reaches the sharded path on the very
    # next batch, at exact parity with the single-device delta merge
    r0 = wl.rects[0]
    loc = np.array([[(r0[0] + r0[2]) / 2, (r0[1] + r0[3]) / 2]], np.float32)
    new_id = li.insert(loc, ds.kw_ids[:1])
    out = li.serve(wl.rects, wl.kw_bitmap, max_leaves=clusters.k)
    want = set(_sorted_ids(np.asarray(out["ids"][0])).tolist())
    got_single = retrieve(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k,
                          plan_cache=PlanCache(), delta=li.generation.delta())
    assert want == set(_sorted_ids(np.asarray(got_single["ids"][0])).tolist())
    assert int(new_id[0]) >= ds.n
