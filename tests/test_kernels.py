"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _rand_rects(rng, n):
    lo = rng.uniform(0, 0.8, (n, 2)).astype(np.float32)
    hi = lo + rng.uniform(0.01, 0.2, (n, 2)).astype(np.float32)
    return np.concatenate([lo, hi], axis=1)


@pytest.mark.parametrize("m,k,w", [(1, 1, 1), (7, 33, 3), (64, 128, 15), (130, 257, 16), (128, 128, 32)])
def test_skr_filter_sweep(m, k, w):
    rng = np.random.default_rng(m * 1000 + k + w)
    qr = _rand_rects(rng, m)
    nm = _rand_rects(rng, k)
    qb = (rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, w), dtype=np.uint32))
    nb = rng.integers(0, 2 ** 32, (k, w), dtype=np.uint32)
    out = np.asarray(ops.filter_pairs(qr, qb, nm, nb))
    exp = np.asarray(ref.skr_filter_ref(*map(jnp.asarray, (qr, qb, nm, nb))))
    np.testing.assert_array_equal(out, exp)


@pytest.mark.parametrize("m,c,w", [(1, 8, 1), (5, 100, 4), (16, 512, 15), (33, 1000, 8)])
def test_skr_verify_sweep(m, c, w):
    rng = np.random.default_rng(m + c + w)
    qr = _rand_rects(rng, m)
    qb = rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
    cx = rng.uniform(0, 1, (m, c)).astype(np.float32)
    cy = rng.uniform(0, 1, (m, c)).astype(np.float32)
    cb = (rng.integers(0, 2 ** 32, (m, c, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, c, w), dtype=np.uint32))
    cv = rng.integers(0, 2, (m, c)).astype(np.int8)
    out = np.asarray(ops.verify_candidates(qr, qb, cx, cy, cb, cv))
    exp = np.asarray(ref.skr_verify_ref(*map(jnp.asarray, (qr, qb, cx, cy, cb, cv))))
    np.testing.assert_array_equal(out, exp)


@pytest.mark.parametrize("n,b,h", [(1, 1, 16), (65, 23, 16), (301, 64, 16), (256, 130, 8)])
def test_cdf_mlp_sweep(n, b, h):
    rng = np.random.default_rng(n + b)
    params = {
        "w0": rng.normal(0, 1, (b, 1, h)), "b0": rng.normal(0, 1, (b, h)),
        "w1": rng.normal(0, 0.5, (b, h, h)), "b1": rng.normal(0, 0.5, (b, h)),
        "w2": rng.normal(0, 0.5, (b, h, h)), "b2": rng.normal(0, 0.5, (b, h)),
        "w3": rng.normal(0, 0.5, (b, h, 1)), "b3": rng.normal(0, 0.5, (b, 1)),
    }
    params = {k: jnp.asarray(v, jnp.float32) for k, v in params.items()}
    x = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    out = np.asarray(ops.cdf_bank_forward(params, x))
    exp = np.asarray(ref.cdf_mlp_ref(params, x))
    np.testing.assert_allclose(out, exp, atol=2e-6)


@pytest.mark.parametrize(
    "m,f,w",
    [
        (1, 1, 1),  # degenerate single-slot frontier
        (5, 37, 3),  # nothing a multiple of the 128-lane tile
        (9, 130, 4),  # frontier just past one lane tile
        (33, 257, 8),  # queries and frontier both off-tile
        (8, 128, 16),  # exact tile for contrast
    ],
)
def test_frontier_filter_sweep(m, f, w):
    """Pallas frontier kernel (interpret) vs jnp oracle, incl. pad slots."""
    rng = np.random.default_rng(m * 7919 + f * 31 + w)
    qr = _rand_rects(rng, m)
    qb = (rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, w), dtype=np.uint32))
    fm = _rand_rects(rng, m * f).reshape(m, f, 4).astype(np.float32)
    fb = (rng.integers(0, 2 ** 32, (m, f, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, f, w), dtype=np.uint32))
    fv = rng.integers(0, 2, (m, f)).astype(np.int8)
    out = np.asarray(ops.filter_frontier(qr, qb, fm, fb, fv))
    exp = np.asarray(ref.frontier_filter_ref(*map(jnp.asarray, (qr, qb, fm, fb, fv))))
    np.testing.assert_array_equal(out, exp)


@pytest.mark.parametrize(
    "m,f,w",
    [
        (1, 1, 1),  # degenerate single-slot frontier
        (5, 37, 3),  # nothing a multiple of the 128-lane tile
        (9, 130, 4),  # frontier just past one lane tile
        (33, 257, 8),  # queries and frontier both off-tile
        (8, 128, 16),  # exact tile for contrast
    ],
)
def test_knn_filter_sweep(m, f, w):
    """Pallas kNN distance kernel (interpret) vs jnp oracle, incl. the +inf
    sentinel at invalid / keyword-miss slots and points inside MBRs (d=0)."""
    rng = np.random.default_rng(m * 613 + f * 17 + w)
    qp = rng.uniform(0, 1, (m, 2)).astype(np.float32)
    qb = (rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, w), dtype=np.uint32))
    fm = _rand_rects(rng, m * f).reshape(m, f, 4).astype(np.float32)
    fb = (rng.integers(0, 2 ** 32, (m, f, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, f, w), dtype=np.uint32))
    fv = rng.integers(0, 2, (m, f)).astype(np.int8)
    out = np.asarray(ops.knn_frontier_dist(qp, qb, fm, fb, fv))
    exp = np.asarray(ref.knn_filter_ref(*map(jnp.asarray, (qp, qb, fm, fb, fv))))
    # float kernel: +inf sentinel pattern must match exactly, finite
    # distances to float tolerance (FMA fusion may differ by 1 ULP)
    np.testing.assert_array_equal(np.isinf(out), np.isinf(exp))
    np.testing.assert_allclose(out[np.isfinite(out)], exp[np.isfinite(exp)], rtol=1e-6)
    assert np.isinf(out[(fv == 0)]).all()


def test_knn_filter_block_size_invariance():
    rng = np.random.default_rng(3)
    m, f, w = 21, 70, 5
    qp = rng.uniform(0, 1, (m, 2)).astype(np.float32)
    qb = rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
    fm = _rand_rects(rng, m * f).reshape(m, f, 4).astype(np.float32)
    fb = rng.integers(0, 2 ** 32, (m, f, w), dtype=np.uint32)
    fv = rng.integers(0, 2, (m, f)).astype(np.int8)
    a = np.asarray(ops.knn_frontier_dist(qp, qb, fm, fb, fv, bm=4, bf=16))
    b = np.asarray(ops.knn_frontier_dist(qp, qb, fm, fb, fv, bm=8, bf=128))
    np.testing.assert_array_equal(np.isinf(a), np.isinf(b))
    np.testing.assert_allclose(a[np.isfinite(a)], b[np.isfinite(b)], rtol=1e-6)


def test_frontier_filter_block_size_invariance():
    rng = np.random.default_rng(1)
    m, f, w = 21, 70, 5
    qr = _rand_rects(rng, m)
    qb = rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
    fm = _rand_rects(rng, m * f).reshape(m, f, 4).astype(np.float32)
    fb = rng.integers(0, 2 ** 32, (m, f, w), dtype=np.uint32)
    fv = rng.integers(0, 2, (m, f)).astype(np.int8)
    a = np.asarray(ops.filter_frontier(qr, qb, fm, fb, fv, bm=4, bf=16))
    b = np.asarray(ops.filter_frontier(qr, qb, fm, fb, fv, bm=8, bf=128))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("m,k,w", [(3, 5, 2), (127, 129, 7)])
def test_skr_filter_off_tile_padding(m, k, w):
    """skr_filter on shapes straddling the 128-lane tile boundary."""
    rng = np.random.default_rng(m + k * 13 + w)
    qr = _rand_rects(rng, m)
    nm = _rand_rects(rng, k)
    qb = rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
    nb = (rng.integers(0, 2 ** 32, (k, w), dtype=np.uint32)
          * rng.integers(0, 2, (k, w), dtype=np.uint32))
    out = np.asarray(ops.filter_pairs(qr, qb, nm, nb))
    exp = np.asarray(ref.skr_filter_ref(*map(jnp.asarray, (qr, qb, nm, nb))))
    np.testing.assert_array_equal(out, exp)


@pytest.mark.parametrize("m,c,w", [(2, 3, 1), (9, 513, 5)])
def test_skr_verify_off_tile_padding(m, c, w):
    """skr_verify on candidate widths just past the block size."""
    rng = np.random.default_rng(m * 3 + c + w)
    qr = _rand_rects(rng, m)
    qb = rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
    cx = rng.uniform(0, 1, (m, c)).astype(np.float32)
    cy = rng.uniform(0, 1, (m, c)).astype(np.float32)
    cb = rng.integers(0, 2 ** 32, (m, c, w), dtype=np.uint32)
    cv = rng.integers(0, 2, (m, c)).astype(np.int8)
    out = np.asarray(ops.verify_candidates(qr, qb, cx, cy, cb, cv))
    exp = np.asarray(ref.skr_verify_ref(*map(jnp.asarray, (qr, qb, cx, cy, cb, cv))))
    np.testing.assert_array_equal(out, exp)


def test_filter_block_size_invariance():
    rng = np.random.default_rng(0)
    m, k, w = 50, 90, 5
    qr = _rand_rects(rng, m)
    nm = _rand_rects(rng, k)
    qb = rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
    nb = rng.integers(0, 2 ** 32, (k, w), dtype=np.uint32)
    a = np.asarray(ops.filter_pairs(qr, qb, nm, nb, bm=16, bk=32))
    b = np.asarray(ops.filter_pairs(qr, qb, nm, nb, bm=128, bk=128))
    np.testing.assert_array_equal(a, b)


def _fused_operands(rng, m, t, k, obj, w):
    """Random fused-verify operands incl. out-of-range leaf ids and -1 pads."""
    qr = _rand_rects(rng, m)
    qb = (rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, w), dtype=np.uint32))
    tl = rng.integers(-1, k + 2, (m, t)).astype(np.int32)  # deliberately dirty
    ok = rng.integers(0, 2, (m, t)).astype(np.int8)
    ox = rng.uniform(0, 1, (k, obj)).astype(np.float32)
    oy = rng.uniform(0, 1, (k, obj)).astype(np.float32)
    ob = (rng.integers(0, 2 ** 32, (k, obj, w), dtype=np.uint32)
          * rng.integers(0, 2, (k, obj, w), dtype=np.uint32))
    oid = np.where(rng.integers(0, 4, (k, obj)) > 0,
                   rng.integers(0, 10 * k * obj, (k, obj)), -1).astype(np.int32)
    return qr, qb, tl, ok, ox, oy, ob, oid


@pytest.mark.parametrize(
    "m,t,k,obj,w",
    [
        (1, 1, 1, 1, 1),    # fully degenerate
        (5, 3, 9, 16, 3),   # nothing tile-aligned
        (9, 8, 36, 64, 15), # the fs-profile word width
        (33, 4, 17, 32, 8), # queries past the default bm tile
        (8, 16, 64, 8, 4),  # wide selection, narrow leaves
    ],
)
def test_fused_verify_sweep(m, t, k, obj, w):
    """Fused gather+verify kernel (interpret) vs jnp oracle: elementwise-
    identical ids (ordering included) and per-slot verified counts, under
    invalid slots, -1 object pads, and out-of-range leaf ids."""
    rng = np.random.default_rng(m * 7919 + t * 131 + k * 17 + obj + w)
    args = _fused_operands(rng, m, t, k, obj, w)
    ids, kwv = ops.fused_gather_verify(*args)
    eids, ekwv = ref.fused_verify_ref(*map(jnp.asarray, args))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(eids))
    np.testing.assert_array_equal(np.asarray(kwv), np.asarray(ekwv))


def test_fused_verify_block_size_invariance():
    rng = np.random.default_rng(11)
    args = _fused_operands(rng, 21, 5, 12, 24, 5)
    a_ids, a_kwv = ops.fused_gather_verify(*args, bm=4)
    b_ids, b_kwv = ops.fused_gather_verify(*args, bm=16)
    np.testing.assert_array_equal(np.asarray(a_ids), np.asarray(b_ids))
    np.testing.assert_array_equal(np.asarray(a_kwv), np.asarray(b_kwv))


def test_fused_verify_matches_unfused_gather_pipeline():
    """The fused kernel's contract with the engine: identical output to the
    host-side gather -> skr_verify pipeline it replaces (candidate order
    leaf-slot-major, -1 at non-matches)."""
    rng = np.random.default_rng(23)
    qr, qb, tl, ok, ox, oy, ob, oid = _fused_operands(rng, 10, 4, 8, 16, 4)
    m, t = tl.shape
    k, obj = ox.shape
    safe = np.clip(tl, 0, k - 1)
    cx = ox[safe].reshape(m, -1)
    cy = oy[safe].reshape(m, -1)
    cb = ob[safe].reshape(m, t * obj, -1)
    cid = oid[safe].reshape(m, -1)
    cval = ((cid >= 0) & np.repeat(ok > 0, obj, axis=1)).astype(np.int8)
    match = np.asarray(ops.verify_candidates(qr, qb, cx, cy, cb, cval))
    exp_ids = np.where(match > 0, cid, -1)
    ids, _ = ops.fused_gather_verify(qr, qb, tl, ok, ox, oy, ob, oid)
    np.testing.assert_array_equal(np.asarray(ids), exp_ids)

# ------------------------------------------------- narrow (bandwidth-lean) path

def _narrow_operands(rng, m, f, w):
    """Random narrow-descent operands: rank-coded MBR planes gathered at
    random frontier slots + packed query word planes (DESIGN.md §3.5)."""
    from repro.serve.snapshot import encode_mbr_planes

    n = max(2 * f, 4)
    lo = rng.uniform(0, 1, (n, 2)).astype(np.float32)
    mbrs = np.concatenate(
        [lo, lo + rng.uniform(0, 0.3, (n, 2)).astype(np.float32)], axis=1
    )
    codes, dicts_x, dicts_y = encode_mbr_planes([mbrs])
    n_bm = (rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32)
            * rng.integers(0, 2, (n, w), dtype=np.uint32))
    qb = (rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, w), dtype=np.uint32))
    wids, bits = ops.pack_query_words(qb)
    wids = np.asarray(wids)
    idx = rng.integers(0, n, (m, f))
    f_codes = np.asarray(codes[0])[idx]
    f_bm = n_bm[idx[:, :, None], wids[:, None, :]]
    fv = rng.integers(0, 2, (m, f)).astype(np.int8)
    full = (qb, mbrs[idx], n_bm[idx])  # f32/full-width twins for cross-checks
    return bits, f_codes, f_bm, fv, dicts_x[0], dicts_y[0], full


@pytest.mark.parametrize(
    "m,f,w",
    [
        (1, 1, 1),   # degenerate single-slot frontier
        (5, 37, 3),  # nothing a multiple of the 128-lane tile
        (9, 130, 4),  # frontier just past one lane tile
        (33, 257, 8),  # queries and frontier both off-tile
        (8, 128, 15),  # the fs-profile word width
    ],
)
def test_frontier_filter_narrow_sweep(m, f, w):
    """Narrow frontier kernel (interpret) vs its jnp oracle AND the f32
    full-width reference: the rank-code/packed-word descent is lossless, so
    all three survivor masks must be bit-identical."""
    rng = np.random.default_rng(m * 7919 + f * 31 + w + 1)
    qr = _rand_rects(rng, m)
    bits, fc, fb, fv, dx, dy, (qb, fm_full, fb_full) = _narrow_operands(rng, m, f, w)
    out = np.asarray(ops.filter_frontier_narrow(qr, bits, fc, fb, fv, dx, dy))
    exp = np.asarray(ref.frontier_filter_narrow_ref(
        *map(jnp.asarray, (qr, bits, fc, fb, fv)), dx, dy))
    np.testing.assert_array_equal(out, exp)
    wide = np.asarray(ref.frontier_filter_ref(
        *map(jnp.asarray, (qr, qb, fm_full, fb_full, fv))))
    np.testing.assert_array_equal(out, wide)


@pytest.mark.parametrize(
    "m,f,w",
    [
        (1, 1, 1),
        (5, 37, 3),
        (9, 130, 4),
        (33, 257, 8),
        (8, 128, 15),
    ],
)
def test_knn_filter_narrow_sweep(m, f, w):
    """Narrow kNN distance kernel (interpret) vs oracle + f32 reference:
    identical +inf sentinel pattern, distances to float tolerance."""
    rng = np.random.default_rng(m * 613 + f * 17 + w + 1)
    qp = rng.uniform(0, 1, (m, 2)).astype(np.float32)
    bits, fc, fb, fv, dx, dy, (qb, fm_full, fb_full) = _narrow_operands(rng, m, f, w)
    out = np.asarray(ops.knn_frontier_dist_narrow(qp, bits, fc, fb, fv, dx, dy))
    exp = np.asarray(ref.knn_filter_narrow_ref(
        *map(jnp.asarray, (qp, bits, fc, fb, fv)), dx, dy))
    np.testing.assert_array_equal(np.isinf(out), np.isinf(exp))
    np.testing.assert_allclose(out[np.isfinite(out)], exp[np.isfinite(exp)], rtol=1e-6)
    wide = np.asarray(ref.knn_filter_ref(
        *map(jnp.asarray, (qp, qb, fm_full, fb_full, fv))))
    np.testing.assert_array_equal(np.isinf(out), np.isinf(wide))
    np.testing.assert_allclose(out[np.isfinite(out)], wide[np.isfinite(wide)], rtol=1e-6)


@pytest.mark.parametrize("m,w,seed", [(1, 1, 0), (7, 15, 1), (16, 15, 2), (5, 32, 3)])
def test_pack_query_words_properties(m, w, seed):
    """pack_query_words contracts: packed width a power-of-two bucket (or
    the full W when the bucket would exceed it), every nonzero word preserved
    at its original id, pad slots inert, and the AND-any keyword predicate
    invariant under packing."""
    rng = np.random.default_rng(seed)
    q = (rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
         * rng.integers(0, 2, (m, w), dtype=np.uint32))
    wids, bits = ops.pack_query_words(q)
    wids, bits = np.asarray(wids), np.asarray(bits)
    wp = wids.shape[1]
    assert bits.shape == (m, wp) and wp <= w
    assert wp >= min(4, w)
    assert (wp & (wp - 1)) == 0 or wp == w  # power-of-two bucket, capped at W
    assert int((q != 0).sum(axis=1).max(initial=0)) <= wp  # nothing dropped
    for i in range(m):
        got = {(int(a), int(b)) for a, b in zip(wids[i], bits[i]) if b}
        want = {(int(j), int(q[i, j])) for j in range(w) if q[i, j]}
        assert got == want
    node = (rng.integers(0, 2 ** 32, (m, 6, w), dtype=np.uint32)
            * rng.integers(0, 2, (m, 6, w), dtype=np.uint32))
    full = np.any((node & q[:, None, :]) != 0, axis=-1)
    packed = np.any(
        (node[np.arange(m)[:, None, None], np.arange(6)[None, :, None],
              wids[:, None, :]] & bits[:, None, :]) != 0, axis=-1)
    np.testing.assert_array_equal(packed, full)


@pytest.mark.parametrize(
    "m,t,k,obj,w",
    [
        (1, 1, 1, 1, 1),    # fully degenerate
        (5, 3, 9, 16, 3),   # nothing tile-aligned
        (9, 8, 36, 64, 15), # the fs-profile word width
        (33, 4, 17, 32, 8), # queries past the default bm tile
    ],
)
def test_fused_verify_prefetch_sweep(m, t, k, obj, w):
    """Scalar-prefetched fused kernel (interpret) vs the same jnp oracle the
    VMEM variant is held to, under dirty leaf ids / -1 pads / invalid slots."""
    rng = np.random.default_rng(m * 7919 + t * 131 + k * 17 + obj + w + 1)
    args = _fused_operands(rng, m, t, k, obj, w)
    ids, kwv = ops.fused_gather_verify(*args, variant="prefetch")
    eids, ekwv = ref.fused_verify_ref(*map(jnp.asarray, args))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(eids))
    np.testing.assert_array_equal(np.asarray(kwv), np.asarray(ekwv))


def test_fused_verify_prefetch_equals_vmem():
    """The two fused variants are elementwise interchangeable -- the engine's
    auto-selection can never change results."""
    rng = np.random.default_rng(29)
    args = _fused_operands(rng, 13, 5, 11, 16, 6)
    v_ids, v_kwv = ops.fused_gather_verify(*args, variant="vmem")
    p_ids, p_kwv = ops.fused_gather_verify(*args, variant="prefetch")
    np.testing.assert_array_equal(np.asarray(v_ids), np.asarray(p_ids))
    np.testing.assert_array_equal(np.asarray(v_kwv), np.asarray(p_kwv))


def test_fused_verify_beyond_vmem_bank_stays_fused():
    """A leaf bank genuinely above FUSED_VMEM_BANK_BYTES: variant="auto"
    must resolve to the prefetch kernel (observed via monkeypatch counters)
    and still match the oracle bit-for-bit -- the no-fallback guarantee of
    DESIGN.md §3.5."""
    k, obj, w = 512, 256, 15
    assert ops.leaf_bank_bytes(k, obj, w) > ops.FUSED_VMEM_BANK_BYTES
    rng = np.random.default_rng(31)
    args = _fused_operands(rng, 4, 2, k, obj, w)
    calls = []
    import repro.kernels.ops as ops_mod

    real = ops_mod.fused_verify_prefetch
    try:
        ops_mod.fused_verify_prefetch = (
            lambda *a, **kw: calls.append("prefetch") or real(*a, **kw)
        )
        ids, kwv = ops.fused_gather_verify(*args, variant="auto")
    finally:
        ops_mod.fused_verify_prefetch = real
    assert calls == ["prefetch"], "auto picked the VMEM kernel above the cutoff"
    eids, ekwv = ref.fused_verify_ref(*map(jnp.asarray, args))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(eids))
    np.testing.assert_array_equal(np.asarray(kwv), np.asarray(ekwv))


def test_invalid_fused_variant_rejected():
    rng = np.random.default_rng(37)
    args = _fused_operands(rng, 2, 2, 4, 8, 2)
    with pytest.raises(ValueError, match="variant"):
        ops.fused_gather_verify(*args, variant="hbm")
