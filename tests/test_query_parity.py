"""Cross-path parity: serial / level-sync / batched dense / batched frontier.

The serving rewrite (sparse frontier descent, DESIGN.md §3) is only
acceptable if it is provably exact: on seeded randomized datasets and
workloads all four execution paths must return identical result-id sets and
consistent Eq.1 cost counters, including flat (no-hierarchy) indexes and
small-``max_leaves`` overflow. Index construction here is deterministic
(grid clusters + spatial grouping) so the suite is fast and seed-stable --
it does not run the DQN packer.
"""
import numpy as np
import pytest

from repro.core.index import assemble_index, flat_index
from repro.core.packing import HierarchyResult
from repro.core.query import (
    execute_level_sync,
    execute_serial,
    padded_child_table,
    propagate_hits,
)
from repro.core.types import ClusterSet
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.serve.engine import IndexSnapshot, retrieve_workload, round_up_bucket


def _grid_clusters(ds, g):
    cell = np.minimum((ds.locs * g).astype(np.int32), g - 1)
    assign = cell[:, 0] * g + cell[:, 1]
    _, assign = np.unique(assign, return_inverse=True)
    return ClusterSet.from_assignment(ds, assign.astype(np.int32))


def _spatial_parents(mbrs, g):
    cent = np.clip((mbrs[:, :2] + mbrs[:, 2:]) / 2, 0.0, 1.0)
    cell = np.minimum((cent * g).astype(np.int32), g - 1)
    pid = cell[:, 0] * g + cell[:, 1]
    _, pid = np.unique(pid, return_inverse=True)
    return pid.astype(np.int32)


def _build_index(ds, g=6, levels=2):
    """Deterministic hierarchy: grid leaves grouped spatially, bottom-up."""
    clusters = _grid_clusters(ds, g)
    parents = []
    mbrs = clusters.mbrs
    gg = max(2, g // 2)
    for _ in range(levels - 1):
        p = _spatial_parents(mbrs, gg)
        if p.max() + 1 >= mbrs.shape[0]:  # grouping stopped shrinking
            break
        parents.append(p)
        n_up = int(p.max()) + 1
        up = np.zeros((n_up, 4), np.float32)
        for u in range(n_up):
            mb = mbrs[p == u]
            up[u] = (mb[:, 0].min(), mb[:, 1].min(), mb[:, 2].max(), mb[:, 3].max())
        mbrs = up
        gg = max(2, gg // 2)
    hier = HierarchyResult(parents=parents, level_labels=[], packs=[]) if parents else None
    return assemble_index(ds, clusters, hier), clusters


def _result_sets(out):
    return [np.sort(row[row >= 0]) for row in out["ids"]]


@pytest.mark.parametrize("seed,levels", [(0, 2), (1, 2), (2, 3), (3, 1)])
def test_all_paths_identical(seed, levels):
    ds = make_dataset("fs", n=1500, seed=seed)
    if levels == 1:
        index, clusters = flat_index(ds, _grid_clusters(ds, 5)), _grid_clusters(ds, 5)
    else:
        index, clusters = _build_index(ds, g=6, levels=levels)
    wl = make_workload(ds, m=20, dist="MIX", seed=seed + 10)
    st_serial = execute_serial(index, ds, wl)
    st_sync = execute_level_sync(index, ds, wl)
    bw = IndexSnapshot.build(index, ds, dense=True)
    outs = {
        mode: retrieve_workload(bw, wl, max_leaves=clusters.k, mode=mode)
        for mode in ("dense", "frontier")
    }
    for a, b in zip(st_serial.results, st_sync.results):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(st_serial.nodes_accessed, st_sync.nodes_accessed)
    np.testing.assert_array_equal(st_serial.verified, st_sync.verified)
    for mode, out in outs.items():
        assert (out["overflow"] == 0).all(), mode
        for got, want in zip(_result_sets(out), st_serial.results):
            np.testing.assert_array_equal(got, np.sort(want), err_msg=mode)
        np.testing.assert_array_equal(out["nodes_checked"], st_serial.nodes_accessed)
        np.testing.assert_array_equal(out["verified"], st_serial.verified)
        np.testing.assert_array_equal(out["counts"], [len(r) for r in st_serial.results])


def test_frontier_scans_fewer_nodes_than_dense_mask():
    """The acceptance gate of the rewrite: per-level kernel work is the
    bucketed frontier width, not the level width, so on a hierarchical index
    the frontier path touches strictly fewer slots than the dense mask --
    and examines exactly the nodes the paper-faithful traversal does."""
    ds = make_dataset("fs", n=2500, seed=5)
    index, clusters = _build_index(ds, g=8, levels=3)
    assert index.height >= 2
    wl = make_workload(ds, m=32, dist="MIX", seed=7)
    bw = IndexSnapshot.build(index, ds, dense=True)
    dense = retrieve_workload(bw, wl, max_leaves=clusters.k, mode="dense")
    frontier = retrieve_workload(bw, wl, max_leaves=clusters.k, mode="frontier")
    assert frontier["nodes_scanned"].sum() < dense["nodes_scanned"].sum()
    assert frontier["nodes_checked"].sum() < dense["nodes_scanned"].sum()
    st = execute_serial(index, ds, wl)
    np.testing.assert_array_equal(frontier["nodes_checked"], st.nodes_accessed)
    # per-level width never exceeds its bucketed level size
    for w, lvl in zip(frontier["frontier_widths"], index.levels):
        assert w <= round_up_bucket(lvl.n)


def test_max_leaves_overflow_parity():
    """Small max_leaves: dense and frontier must drop the SAME leaves (ids
    and overflow counts identical) and return subsets of the exact results."""
    ds = make_dataset("fs", n=1500, seed=8)
    index, clusters = _build_index(ds, g=6, levels=2)
    # big rectangles so queries touch many leaves and actually overflow
    wl = make_workload(ds, m=16, dist="UNI", region_frac=0.2, n_keywords=4, seed=9)
    bw = IndexSnapshot.build(index, ds, dense=True)
    st = execute_serial(index, ds, wl)
    for max_leaves in (1, 2, 4):
        dense = retrieve_workload(bw, wl, max_leaves=max_leaves, mode="dense")
        frontier = retrieve_workload(bw, wl, max_leaves=max_leaves, mode="frontier")
        np.testing.assert_array_equal(dense["overflow"], frontier["overflow"])
        for a, b in zip(_result_sets(dense), _result_sets(frontier)):
            np.testing.assert_array_equal(a, b)
        for got, want in zip(_result_sets(frontier), st.results):
            assert np.isin(got, want).all()
    assert retrieve_workload(bw, wl, max_leaves=1, mode="frontier")["overflow"].sum() > 0
    # with full capacity the overflow vanishes and results are exact again
    full = retrieve_workload(bw, wl, max_leaves=clusters.k, mode="frontier")
    assert (full["overflow"] == 0).all()
    for got, want in zip(_result_sets(full), st.results):
        np.testing.assert_array_equal(got, np.sort(want))


def test_csr_propagation_matches_dense_matmul():
    """CSR frontier expansion == dense adjacency matmul on random parents
    (non-hypothesis twin of the property test in test_properties.py)."""
    rng = np.random.default_rng(11)
    for _ in range(10):
        n_down = int(rng.integers(2, 40))
        n_up = int(rng.integers(1, n_down + 1))
        parent = rng.integers(0, n_up, n_down)
        parent[rng.integers(0, n_down)] = n_up - 1  # ensure last parent used
        ptr = np.zeros(n_up + 1, np.int64)
        order = np.argsort(parent, kind="stable").astype(np.int32)
        np.cumsum(np.bincount(parent, minlength=n_up), out=ptr[1:])

        class L:
            child_ptr, child, n = ptr, order, n_up

        table = padded_child_table(L)
        hit = rng.integers(0, 2, (5, n_up)).astype(bool)
        got = propagate_hits(hit, table, n_down)
        adj = np.zeros((n_up, n_down), np.int8)
        adj[parent, np.arange(n_down)] = 1
        np.testing.assert_array_equal(got, (hit @ adj) > 0)


def test_frontier_width_cache_stays_lossless():
    """The batched-sync width discipline (DESIGN.md §3.2), now owned by the
    explicit PlanCache: the first descent learns per-level widths with exact
    syncs; cached descents run sync-free; a deliberately-poisoned (too
    narrow) cache must trigger the lossless overflow retry and still return
    exact results and counters."""
    from repro.serve.plan import PlanCache

    ds = make_dataset("fs", n=2500, seed=5)
    index, clusters = _build_index(ds, g=8, levels=3)
    wl = make_workload(ds, m=16, dist="UNI", region_frac=0.2, n_keywords=4, seed=9)
    st = execute_serial(index, ds, wl)
    bw = IndexSnapshot.build(index, ds)
    cache = PlanCache()
    first = retrieve_workload(bw, wl, max_leaves=clusters.k, mode="frontier", plan_cache=cache)
    learned = dict(cache.widths)
    assert learned  # exact first descent populated the cache
    assert cache.plan("skr", bw.n_levels - 1).widths is not None
    # cached descent: identical results, widths from the cache
    cached = retrieve_workload(bw, wl, max_leaves=clusters.k, mode="frontier", plan_cache=cache)
    for a, b in zip(_result_sets(first), _result_sets(cached)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(cached["nodes_checked"], st.nodes_accessed)
    # poison every width to the minimum bucket: children would be dropped,
    # so the batched overflow check must fire and re-descend exactly
    for key in list(cache.widths):
        cache.widths[key] = 8
    retried = retrieve_workload(bw, wl, max_leaves=clusters.k, mode="frontier", plan_cache=cache)
    for got, want in zip(_result_sets(retried), st.results):
        np.testing.assert_array_equal(got, np.sort(want))
    np.testing.assert_array_equal(retried["nodes_checked"], st.nodes_accessed)
    np.testing.assert_array_equal(retried["verified"], st.verified)
    assert dict(cache.widths) == learned  # retry re-learned the real widths
    # an independent cache starts unlearned: plans resolve to exact mode
    assert PlanCache().plan("skr", bw.n_levels - 1).widths is None


def test_bucketing_pads_are_inert():
    """serve_batch pads the batch to its power-of-two bucket; pad queries
    must not change real queries' results or counters."""
    from repro.launch.wisk_serve import pad_queries_to_bucket, serve_batch

    ds = make_dataset("fs", n=1200, seed=12)
    index, clusters = _build_index(ds, g=5, levels=2)
    bw = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=13, dist="MIX", seed=13)  # not a power of two
    rects, bms, m = pad_queries_to_bucket(wl.rects, wl.kw_bitmap)
    assert m == 13 and rects.shape[0] == 16
    out = serve_batch(bw, wl.rects, wl.kw_bitmap, max_leaves=clusters.k)
    direct = retrieve_workload(bw, wl, max_leaves=clusters.k, mode="frontier")
    assert out["ids"].shape[0] == 13
    for a, b in zip(_result_sets(out), _result_sets(direct)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(out["nodes_checked"], direct["nodes_checked"])


# ------------------------------------- fused leaf verification (DESIGN.md §3.5)
@pytest.mark.parametrize("seed,levels,mode", [
    (0, 1, "frontier"), (1, 2, "frontier"), (2, 3, "frontier"),
    (0, 2, "dense"), (3, 2, "dense"),
])
def test_fused_verify_elementwise_parity(seed, levels, mode):
    """The fused gather+verify path must be ELEMENTWISE-identical to the
    unfused gather -> skr_verify path -- same ids in the same slots, same
    Eq.1 counters -- not merely set-equal, across hierarchy depths and both
    descent modes."""
    ds = make_dataset("fs", n=1200, seed=seed)
    index, clusters = _build_index(ds, g=5, levels=levels)
    snap = IndexSnapshot.build(index, ds, dense=True)
    wl = make_workload(ds, m=24, dist="MIX", seed=seed + 40)
    a = retrieve_workload(snap, wl, max_leaves=clusters.k, mode=mode, fused=False)
    b = retrieve_workload(snap, wl, max_leaves=clusters.k, mode=mode, fused=True)
    c = retrieve_workload(snap, wl, max_leaves=clusters.k, mode=mode)  # auto
    for key in ("ids", "counts", "nodes_checked", "nodes_scanned", "verified", "overflow"):
        np.testing.assert_array_equal(np.asarray(a[key]), np.asarray(b[key]), err_msg=key)
        np.testing.assert_array_equal(np.asarray(a[key]), np.asarray(c[key]), err_msg=key)


@pytest.mark.parametrize("max_leaves", [1, 2, 5])
def test_fused_verify_overflow_parity(max_leaves):
    """Capacity-overflow configs: the fused path must spill identically
    (same selected leaves, same overflow counts, same partial results)."""
    ds = make_dataset("fs", n=1200, seed=5)
    index, _ = _build_index(ds, g=6, levels=2)
    snap = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=16, dist="UNI", region_frac=0.2, n_keywords=4, seed=9)
    a = retrieve_workload(snap, wl, max_leaves=max_leaves, fused=False)
    b = retrieve_workload(snap, wl, max_leaves=max_leaves, fused=True)
    assert np.asarray(a["overflow"]).sum() > 0  # the config actually spills
    for key in ("ids", "counts", "verified", "overflow"):
        np.testing.assert_array_equal(np.asarray(a[key]), np.asarray(b[key]), err_msg=key)


def test_fused_stays_on_with_delta():
    """fused=None must keep the fused kernel on the base leaf blocks when a
    DeltaBuffer is live (the PR 6 gap: it used to fall back to the wholesale
    unfused pipeline on any delta): deleted snapshot objects are masked into
    pad slots for the fused pass and only the insert-buffer slots take the
    unfused merge, elementwise-identical -- same ids in the same candidate
    slots, same counters -- to the forced-unfused baseline."""
    from repro.serve.delta import DeltaLog

    ds = make_dataset("fs", n=1000, seed=6)
    index, clusters = _build_index(ds, g=5, levels=2)
    snap = IndexSnapshot.build(index, ds)
    log = DeltaLog(index, ds, snap)
    rng = np.random.default_rng(0)
    log.insert(rng.uniform(0.4, 0.6, (8, 2)).astype(np.float32),
               [[1, 2, 3]] * 8)
    log.delete(np.arange(0, 200, 13))  # the fused pass must mask deletes too
    delta = log.buffer
    wl = make_workload(ds, m=12, dist="MIX", seed=50)
    # pin one query onto the inserted objects so the delta is visible
    R = np.asarray(wl.rects).copy()
    B = np.asarray(wl.kw_bitmap).copy()
    R[0] = (0.35, 0.35, 0.65, 0.65)
    B[0] = 0
    B[0, 0] = (1 << 1) | (1 << 2) | (1 << 3)
    import dataclasses as _dc

    wl = _dc.replace(wl, rects=R, kw_bitmap=B)
    unfused = retrieve_workload(
        snap, wl, max_leaves=clusters.k, delta=delta, fused=False
    )
    for fused in (None, True):
        out = retrieve_workload(
            snap, wl, max_leaves=clusters.k, delta=delta, fused=fused
        )
        for key in ("ids", "counts", "verified", "overflow"):
            np.testing.assert_array_equal(
                np.asarray(out[key]), np.asarray(unfused[key]),
                err_msg=f"{key} (fused={fused})",
            )
    # and the delta actually changed results vs the delta-free descent
    base = retrieve_workload(snap, wl, max_leaves=clusters.k)
    assert any(
        not np.array_equal(np.sort(p[p >= 0]), np.sort(q[q >= 0]))
        for p, q in zip(np.asarray(unfused["ids"]), np.asarray(base["ids"]))
    )


@pytest.mark.parametrize("seed,levels", [(0, 2), (3, 3)])
def test_narrow_descent_engine_parity(seed, levels):
    """The bandwidth-lean narrow descent (DESIGN.md §3.5) vs the forced-f32
    descent at the engine level: ids AND every traversal counter identical
    (the shadow planes are lossless), and the snapshot actually carries the
    int16 codes so quantized=None really exercised the narrow path."""
    ds = make_dataset("fs", n=1500, seed=seed)
    index, clusters = _build_index(ds, g=6, levels=levels)
    snap = IndexSnapshot.build(index, ds)
    assert snap.has_narrow_planes
    wl = make_workload(ds, m=20, dist="MIX", seed=seed + 10)
    wide = retrieve_workload(snap, wl, max_leaves=clusters.k, quantized=False)
    narrow = retrieve_workload(snap, wl, max_leaves=clusters.k, quantized=True)
    auto = retrieve_workload(snap, wl, max_leaves=clusters.k)
    for key in ("ids", "counts", "verified", "overflow",
                "nodes_scanned", "nodes_checked"):
        np.testing.assert_array_equal(
            np.asarray(wide[key]), np.asarray(narrow[key]), err_msg=key)
        np.testing.assert_array_equal(
            np.asarray(narrow[key]), np.asarray(auto[key]), err_msg=key)
