"""Serving front-door unit coverage (DESIGN.md §3.5): HotQueryCache LRU and
key-quantization behavior, and MicroBatcher ticket/flush lifecycle.

These are host-side control-plane contracts the integration tests only
exercise incidentally: eviction order under capacity pressure, jittered
re-issues folding onto one cache key (and genuinely different probes NOT
folding), empty/double flushes, unknown tickets, and the auto-flush knob.
"""
import numpy as np
import pytest

from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.launch.wisk_serve import HotQueryCache, MicroBatcher, serve_batch
from repro.serve.engine import IndexSnapshot
from repro.serve.plan import PlanCache

from test_query_parity import _build_index


# ------------------------------------------------------------ HotQueryCache
def _bm(*words):
    b = np.zeros(2, np.uint32)
    for w in words:
        b[w // 32] |= np.uint32(1 << (w % 32))
    return b


def test_hot_query_cache_evicts_lru_not_mru():
    """Capacity pressure drops the least-recently-USED entry: a get()
    refreshes recency, so the untouched entry goes first."""
    c = HotQueryCache(maxsize=2)
    ra, rb, rc = ([0.1, 0.1, 0.2, 0.2], [0.3, 0.3, 0.4, 0.4], [0.5, 0.5, 0.6, 0.6])
    bm = _bm(3)
    c.put(ra, bm, {"row": "A"})
    c.put(rb, bm, {"row": "B"})
    assert c.get(ra, bm) == {"row": "A"}  # refresh A; B is now LRU
    c.put(rc, bm, {"row": "C"})  # evicts B
    assert len(c) == 2
    assert c.get(rb, bm) is None
    assert c.get(ra, bm) == {"row": "A"}
    assert c.get(rc, bm) == {"row": "C"}
    assert c.hits == 3 and c.misses == 1


def test_hot_query_cache_quantized_keys_fold_jitter():
    """Re-issues jittered inside the 1/quant grid share one key and return
    the FIRST issuer's exact cached row; jitter past the grid pitch is a
    distinct probe and must miss. Different bitmaps never collide."""
    c = HotQueryCache(maxsize=8, quant=4096.0)
    rect = np.array([0.25, 0.25, 0.5, 0.5], np.float32)
    bm = _bm(1, 7)
    c.put(rect, bm, {"row": "first"})
    tiny = rect + 1e-5  # ~0.04 grid cells: quantizes identically
    assert c.key(tiny, bm) == c.key(rect, bm)
    assert c.get(tiny, bm) == {"row": "first"}
    far = rect + 1.0 / 4096.0  # a full grid cell away
    assert c.key(far, bm) != c.key(rect, bm)
    assert c.get(far, bm) is None
    assert c.get(rect, _bm(2)) is None  # same rect, other keywords
    assert c.hits == 1 and c.misses == 2


def test_hot_query_cache_invalidate_drops_everything():
    c = HotQueryCache(maxsize=4)
    bm = _bm(0)
    for i in range(3):
        c.put([i * 0.1, 0.0, i * 0.1 + 0.05, 0.05], bm, {"i": i})
    assert len(c) == 3
    c.invalidate()
    assert len(c) == 0 and c.invalidations == 1
    assert c.get([0.0, 0.0, 0.05, 0.05], bm) is None


# -------------------------------------------------------------- MicroBatcher
@pytest.fixture(scope="module")
def frontdoor():
    ds = make_dataset("fs", n=800, seed=3)
    index, clusters = _build_index(ds, g=5, levels=2)
    snap = IndexSnapshot.build(index, ds)
    wl = make_workload(ds, m=6, dist="MIX", seed=4)
    return snap, clusters, wl


def test_micro_batcher_rejects_bad_flush_at(frontdoor):
    snap, clusters, _ = frontdoor
    with pytest.raises(ValueError, match="flush_at"):
        MicroBatcher(snap, max_leaves=clusters.k, flush_at=0)


def test_micro_batcher_empty_and_double_flush(frontdoor):
    """Flushing an empty queue is a free no-op (returns 0, no dispatch
    counted), including immediately after a real flush drained it."""
    snap, clusters, wl = frontdoor
    mb = MicroBatcher(snap, max_leaves=clusters.k, flush_at=64,
                      plan_cache=PlanCache())
    assert mb.flush() == 0 and mb.flushes == 0
    t = mb.submit(wl.rects[0], wl.kw_bitmap[0])
    assert mb.flush() == 1 and mb.flushes == 1
    assert mb.flush() == 0 and mb.flushes == 1  # double flush: drained
    assert mb.result(t)["counts"] >= 0
    assert mb.served == 1


def test_micro_batcher_unknown_ticket_raises(frontdoor):
    """A ticket that was never issued (or already popped) is a hard
    KeyError -- results are single-consumption rows, not a cache."""
    snap, clusters, wl = frontdoor
    mb = MicroBatcher(snap, max_leaves=clusters.k, flush_at=64,
                      plan_cache=PlanCache())
    t = mb.submit(wl.rects[0], wl.kw_bitmap[0])
    row = mb.result(t)  # implicit flush, then pop
    assert "ids" in row
    with pytest.raises(KeyError):
        mb.result(t)  # already consumed
    with pytest.raises(KeyError):
        mb.result(10_000)  # never issued


def test_micro_batcher_auto_flush_and_row_parity(frontdoor):
    """flush_at triggers the dispatch on the Nth submit, and every ticket's
    row matches the plain batched engine call row-for-row."""
    snap, clusters, wl = frontdoor
    mb = MicroBatcher(snap, max_leaves=clusters.k, flush_at=3,
                      plan_cache=PlanCache())
    tickets = []
    for i in range(6):
        tickets.append(mb.submit(wl.rects[i], wl.kw_bitmap[i]))
        assert mb.pending == (i + 1) % 3  # drained on every 3rd submit
    assert mb.flushes == 2 and mb.served == 6
    ref = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=clusters.k,
                      plan_cache=PlanCache())
    for i, t in enumerate(tickets):
        row = mb.result(t)
        assert row["counts"] == ref["counts"][i]
        np.testing.assert_array_equal(
            np.sort(row["ids"][row["ids"] >= 0]),
            np.sort(ref["ids"][i][ref["ids"][i] >= 0]),
        )


def test_micro_batcher_with_cache_marks_hot_rows(frontdoor):
    """Behind a HotQueryCache a repeated probe comes back flagged
    ``cached`` with the identical result row, and the second flush serves
    only the misses."""
    snap, clusters, wl = frontdoor
    cache = HotQueryCache(maxsize=16)
    mb = MicroBatcher(snap, max_leaves=clusters.k, flush_at=64, cache=cache,
                      plan_cache=PlanCache())
    t1 = mb.submit(wl.rects[0], wl.kw_bitmap[0])
    mb.flush()
    first = mb.result(t1)
    assert not bool(first["cached"])
    t2 = mb.submit(wl.rects[0], wl.kw_bitmap[0])  # hot re-issue
    t3 = mb.submit(wl.rects[1], wl.kw_bitmap[1])
    mb.flush()
    hot, cold = mb.result(t2), mb.result(t3)
    assert bool(hot["cached"]) and not bool(cold["cached"])
    assert hot["counts"] == first["counts"]
    np.testing.assert_array_equal(
        hot["ids"][hot["ids"] >= 0], first["ids"][first["ids"] >= 0]
    )
    assert cache.hits == 1
