"""Leaf-local vocabulary compression coverage (DESIGN.md §3.5).

Four layers of the compact-verify contract:

* **Kernel sweeps.** The compact Pallas kernels (interpret) vs their jnp
  oracles AND the full-width references -- the remap + one-word signature
  prefilter is exact, so ids/counts must be bit-identical to the global-W
  predicate, not merely to the compact oracle.
* **Remap edge cases.** Single-word leaves (Wl == 1), query terms outside
  every leaf dictionary (signature kill), and the ``cap`` overflow path
  returning the disable-all sentinel.
* **Engine parity.** ``compact=None`` vs ``compact=False`` across fused
  variants and kNN -- identical ids and Eq.1 counters; a snapshot without
  a compact bank transparently serves on the full-width slab.
* **Delta compact.** In-dictionary inserts keep ``compact_ok`` and the
  remapped insert slabs; a term new to its leaf flips the sticky fallback
  to full-width insert verification -- with serving parity either way.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.kernels import ops, ref
from repro.launch.wisk_serve import serve_batch, serve_knn_batch
from repro.serve.delta import DeltaLog
from repro.serve.engine import IndexSnapshot
from repro.serve.snapshot import encode_leaf_vocab

from test_query_parity import _build_index


def _rand_rects(rng, n):
    lo = rng.uniform(0, 0.8, (n, 2)).astype(np.float32)
    hi = lo + rng.uniform(0.01, 0.2, (n, 2)).astype(np.float32)
    return np.concatenate([lo, hi], axis=1)


def _clustered_bank(rng, k, obj, w, pool_size=24, max_kw=6):
    """A leaf bank whose objects draw terms from a small per-leaf pool, so
    the leaf dictionaries genuinely compress (Wl well below W)."""
    nbits = 32 * w
    ob = np.zeros((k, obj, w), np.uint32)
    for c in range(k):
        pool = rng.choice(nbits, size=min(pool_size, nbits), replace=False)
        for o in range(obj):
            picks = pool[: rng.integers(0, min(max_kw, pool.size) + 1)]
            np.bitwise_or.at(
                ob[c, o], picks >> 5, np.uint32(1) << (picks & 31).astype(np.uint32)
            )
    return ob


def _compact_case(rng, m, t, k, obj, w, **bank_kw):
    """Full-width fused-verify operands (dirty leaf ids, -1 pads, invalid
    slots) plus their compact encoding and per-slot remapped query words."""
    qr = _rand_rects(rng, m)
    qb = (rng.integers(0, 2 ** 32, (m, w), dtype=np.uint32)
          * rng.integers(0, 2, (m, w), dtype=np.uint32))
    tl = rng.integers(-1, k + 2, (m, t)).astype(np.int32)  # deliberately dirty
    ok = rng.integers(0, 2, (m, t)).astype(np.int8)
    ox = rng.uniform(0, 1, (k, obj)).astype(np.float32)
    oy = rng.uniform(0, 1, (k, obj)).astype(np.float32)
    ob = _clustered_bank(rng, k, obj, w, **bank_kw)
    oid = np.where(rng.integers(0, 4, (k, obj)) > 0,
                   rng.integers(0, 10 * k * obj, (k, obj)), -1).astype(np.int32)
    lt, cbm, sig = encode_leaf_vocab(ob)
    assert lt is not None, "clustered pools must never overflow the cap"
    q_cbm, q_sig = ops.remap_query_words(jnp.asarray(qb), lt, jnp.asarray(tl))
    full = (qr, qb, tl, ok, ox, oy, ob, oid)
    compact = (qr, q_cbm, q_sig, tl, ok, ox, oy, cbm, sig, oid)
    return full, compact


_SWEEP = [
    (1, 1, 1, 1, 1),    # fully degenerate
    (5, 3, 9, 16, 3),   # nothing tile-aligned
    (9, 8, 36, 64, 15), # the fs-profile word width
    (33, 4, 17, 32, 8), # queries past the default bm tile
]


@pytest.mark.parametrize("m,t,k,obj,w", _SWEEP)
def test_skr_verify_compact_sweep(m, t, k, obj, w):
    """Unfused compact verify kernel (interpret) vs its jnp oracle AND the
    full-width verify on the same gathered candidates: bit-identical."""
    rng = np.random.default_rng(m * 7919 + t * 131 + k * 17 + obj + w)
    full, compact = _compact_case(rng, m, t, k, obj, w)
    qr, qb, tl, ok, ox, oy, ob, oid = full
    _, q_cbm, q_sig, _, _, _, _, cbm, sig, _ = compact
    safe = np.clip(tl, 0, k - 1)
    cx = ox[safe].reshape(m, -1)
    cy = oy[safe].reshape(m, -1)
    cid = oid[safe].reshape(m, -1)
    cval = ((cid >= 0) & np.repeat(ok > 0, obj, axis=1)).astype(np.int8)
    ccbm = np.asarray(cbm)[safe].reshape(m, t * obj, -1)
    csig = np.asarray(sig)[safe].reshape(m, -1)
    out = np.asarray(ops.verify_candidates_compact(
        qr, q_cbm, q_sig, cx, cy, ccbm, csig, cval))
    exp = np.asarray(ref.skr_verify_compact_ref(
        *map(jnp.asarray, (qr, q_cbm, q_sig, cx, cy, ccbm, csig, cval))))
    np.testing.assert_array_equal(out, exp)
    wide = np.asarray(ref.skr_verify_ref(*map(jnp.asarray, (
        qr, qb, cx, cy, ob[safe].reshape(m, t * obj, -1), cval))))
    np.testing.assert_array_equal(out, wide)


@pytest.mark.parametrize("variant", ["vmem", "prefetch"])
@pytest.mark.parametrize("m,t,k,obj,w", _SWEEP)
def test_fused_verify_compact_sweep(variant, m, t, k, obj, w):
    """Both fused compact kernels (interpret) vs the compact oracle AND the
    full-width fused reference -- same ids in the same candidate slots,
    same per-slot Eq.1 counts."""
    rng = np.random.default_rng(m * 613 + t * 37 + k * 5 + obj + w)
    full, compact = _compact_case(rng, m, t, k, obj, w)
    ids, kwv = ops.fused_gather_verify_compact(*compact, variant=variant)
    eids, ekwv = ref.fused_verify_compact_ref(*map(jnp.asarray, compact))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(eids))
    np.testing.assert_array_equal(np.asarray(kwv), np.asarray(ekwv))
    wids, wkwv = ref.fused_verify_ref(*map(jnp.asarray, full))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wids))
    np.testing.assert_array_equal(np.asarray(kwv), np.asarray(wkwv))


def test_fused_verify_compact_variants_equal():
    """VMEM and prefetch compact kernels are elementwise interchangeable --
    the engine's auto-selection can never change results."""
    rng = np.random.default_rng(43)
    _, compact = _compact_case(rng, 13, 5, 11, 16, 6)
    v_ids, v_kwv = ops.fused_gather_verify_compact(*compact, variant="vmem")
    p_ids, p_kwv = ops.fused_gather_verify_compact(*compact, variant="prefetch")
    np.testing.assert_array_equal(np.asarray(v_ids), np.asarray(p_ids))
    np.testing.assert_array_equal(np.asarray(v_kwv), np.asarray(p_kwv))


def test_compact_auto_prices_compact_bank(monkeypatch):
    """variant="auto" prices the COMPACT bank bytes, not the full-width
    bank: with the cutoff between the two, the VMEM compact kernel must be
    selected even though the full-width bank would have forced prefetch."""
    rng = np.random.default_rng(47)
    k, obj, w = 16, 16, 8
    _, compact = _compact_case(rng, 6, 3, k, obj, w)
    Wl = int(np.asarray(compact[7]).shape[2])
    cut = (ops.compact_leaf_bank_bytes(k, obj, Wl)
           + ops.leaf_bank_bytes(k, obj, w)) // 2
    assert ops.compact_leaf_bank_bytes(k, obj, Wl) < cut < ops.leaf_bank_bytes(k, obj, w)
    monkeypatch.setattr(ops, "FUSED_VMEM_BANK_BYTES", cut)
    calls = []
    real = ops.fused_verify_compact
    monkeypatch.setattr(
        ops, "fused_verify_compact",
        lambda *a, **kw: calls.append("vmem") or real(*a, **kw))
    ids, kwv = ops.fused_gather_verify_compact(*compact, variant="auto")
    assert calls == ["vmem"], "auto priced the full-width bank"
    eids, ekwv = ref.fused_verify_compact_ref(*map(jnp.asarray, compact))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(eids))
    np.testing.assert_array_equal(np.asarray(kwv), np.asarray(ekwv))


def test_single_word_leaf_and_out_of_vocab_query():
    """Wl == 1 leaves (vocab <= 32 terms) verify exactly, and a query whose
    terms all fall outside every leaf dictionary is killed by the remap:
    zero signature, zero matches -- exactly what the full-width predicate
    says (those terms match no object in any leaf)."""
    rng = np.random.default_rng(53)
    m, t, k, obj, w = 7, 3, 6, 8, 4
    # per-leaf pools drawn only from the low 20 bits -> Wl == 1
    nlow = 20
    ob = np.zeros((k, obj, w), np.uint32)
    for c in range(k):
        pool = rng.choice(nlow, size=10, replace=False)
        for o in range(obj):
            picks = pool[: rng.integers(1, 5)]
            np.bitwise_or.at(
                ob[c, o], picks >> 5, np.uint32(1) << (picks & 31).astype(np.uint32))
    lt, cbm, sig = encode_leaf_vocab(ob)
    assert lt.shape[1] == 32, "vocab <= 32 terms must pack into one word"
    qr = np.tile(np.array([[0.0, 0.0, 1.0, 1.0]], np.float32), (m, 1))
    # query terms strictly above every pool: remap must kill them all
    qb = np.zeros((m, w), np.uint32)
    qb[:, w - 1] = rng.integers(1, 2 ** 31, m, dtype=np.uint32)
    tl = rng.integers(0, k, (m, t)).astype(np.int32)
    ok = np.ones((m, t), np.int8)
    q_cbm, q_sig = ops.remap_query_words(jnp.asarray(qb), lt, jnp.asarray(tl))
    assert not np.asarray(q_sig).any(), "out-of-vocab terms must zero the signature"
    ox = rng.uniform(0, 1, (k, obj)).astype(np.float32)
    oy = rng.uniform(0, 1, (k, obj)).astype(np.float32)
    oid = np.arange(k * obj, dtype=np.int32).reshape(k, obj)
    ids, kwv = ops.fused_gather_verify_compact(
        qr, q_cbm, q_sig, tl, ok, ox, oy, cbm, sig, oid)
    assert (np.asarray(ids) == -1).all() and not np.asarray(kwv).any()
    wids, wkwv = ref.fused_verify_ref(*map(jnp.asarray, (
        qr, qb, tl, ok, ox, oy, ob, oid)))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wids))
    np.testing.assert_array_equal(np.asarray(kwv), np.asarray(wkwv))
    # and in-vocab queries on the same Wl == 1 bank still verify exactly
    qb2 = np.zeros((m, w), np.uint32)
    qb2[:, 0] = rng.integers(1, 1 << nlow, m, dtype=np.uint32)
    q_cbm2, q_sig2 = ops.remap_query_words(jnp.asarray(qb2), lt, jnp.asarray(tl))
    ids2, kwv2 = ops.fused_gather_verify_compact(
        qr, q_cbm2, q_sig2, tl, ok, ox, oy, cbm, sig, oid)
    wids2, wkwv2 = ref.fused_verify_ref(*map(jnp.asarray, (
        qr, qb2, tl, ok, ox, oy, ob, oid)))
    np.testing.assert_array_equal(np.asarray(ids2), np.asarray(wids2))
    np.testing.assert_array_equal(np.asarray(kwv2), np.asarray(wkwv2))


def test_encode_leaf_vocab_overflow_disables_bank():
    """Any single leaf over the cap returns the (None, None, None) sentinel
    -- the disable-all contract (mirrors NARROW_DICT_MAX)."""
    rng = np.random.default_rng(59)
    ob = _clustered_bank(rng, 4, 8, 2, pool_size=12)
    ob[2, 0, :] = 0xFFFFFFFF  # one leaf with 64 terms
    lt, cbm, sig = encode_leaf_vocab(ob, cap=16)
    assert lt is None and cbm is None and sig is None
    lt, cbm, sig = encode_leaf_vocab(ob, cap=64)  # at the cap: still encodes
    assert lt is not None


# ------------------------------------------------------------- engine parity
def _quick_snap():
    ds = make_dataset("fs", n=1000, seed=6)
    index, clusters = _build_index(ds, g=5, levels=2)
    return ds, IndexSnapshot.build(index, ds), clusters.k


def test_engine_compact_parity_skr_and_knn():
    """compact=None (the default, bank present) vs compact=False: identical
    ids and Eq.1 counters across fused variants, and identical kNN
    sequences -- the engine-level exactness gate of the compact bank."""
    ds, snap, max_leaves = _quick_snap()
    assert snap.has_compact_bank
    wl = make_workload(ds, m=16, dist="MIX", seed=31)
    base = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=max_leaves,
                       fused=False, compact=False)
    for fused in (False, True, None):
        out = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=max_leaves,
                          fused=fused, compact=None)
        for key in ("ids", "counts", "verified", "overflow"):
            np.testing.assert_array_equal(
                np.asarray(out[key]), np.asarray(base[key]),
                err_msg=f"{key} (fused={fused})")
    pts = np.stack([(wl.rects[:, 0] + wl.rects[:, 2]) / 2,
                    (wl.rects[:, 1] + wl.rects[:, 3]) / 2], 1).astype(np.float32)
    kb = serve_knn_batch(snap, pts, wl.kw_bitmap, 10, compact=False)
    kc = serve_knn_batch(snap, pts, wl.kw_bitmap, 10, compact=None)
    for key in ("ids", "dist2", "verified", "nodes_checked"):
        np.testing.assert_array_equal(
            np.asarray(kc[key]), np.asarray(kb[key]), err_msg=key)


def test_engine_without_compact_bank_falls_back():
    """A snapshot whose compact bank was disabled (overflow sentinel) serves
    identically on the full-width slab with compact left at the default."""
    ds, snap, max_leaves = _quick_snap()
    stripped = dataclasses.replace(
        snap, leaf_terms=None, leaf_obj_cbm=None, leaf_obj_sig=None)
    assert snap.has_compact_bank and not stripped.has_compact_bank
    wl = make_workload(ds, m=12, dist="MIX", seed=37)
    a = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=max_leaves)
    b = serve_batch(stripped, wl.rects, wl.kw_bitmap, max_leaves=max_leaves)
    for key in ("ids", "counts", "verified", "overflow"):
        np.testing.assert_array_equal(
            np.asarray(a[key]), np.asarray(b[key]), err_msg=key)


# ------------------------------------------------------------- delta compact
def _pinned_workload(ds, loc, kw_bits, m=12, seed=41):
    """A MIX workload with query 0 pinned over ``loc`` carrying ``kw_bits``."""
    wl = make_workload(ds, m=m, dist="MIX", seed=seed)
    R = np.asarray(wl.rects).copy()
    B = np.asarray(wl.kw_bitmap).copy()
    R[0] = (loc[0] - 0.1, loc[1] - 0.1, loc[0] + 0.1, loc[1] + 0.1)
    B[0] = kw_bits
    return dataclasses.replace(wl, rects=R, kw_bitmap=B)


def _delta_parity(ds, snap, max_leaves, log, wl):
    base = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=max_leaves,
                       delta=log.buffer, compact=False)
    for fused in (False, True, None):
        out = serve_batch(snap, wl.rects, wl.kw_bitmap, max_leaves=max_leaves,
                          delta=log.buffer, fused=fused, compact=None)
        for key in ("ids", "counts", "verified", "overflow"):
            np.testing.assert_array_equal(
                np.asarray(out[key]), np.asarray(base[key]),
                err_msg=f"{key} (fused={fused})")
    return base


def _insert_leaf(log, new_id):
    """The (leaf, slot) a buffered insert landed in."""
    where = np.argwhere(np.asarray(log.buffer.ins_id) == int(new_id))
    assert where.shape[0] == 1
    return int(where[0, 0])


def test_delta_insert_in_dict_keeps_compact():
    """Inserts whose terms are already in their leaf's dictionary keep the
    remapped insert slabs live (compact_ok True) and serve bit-identically
    to the full-width delta path."""
    ds, snap, max_leaves = _quick_snap()
    index, _ = _build_index(ds, g=5, levels=2)
    log = DeltaLog(index, ds, snap)
    # a probe insert discovers the routing leaf for this location
    rng = np.random.default_rng(0)
    src = int(rng.integers(ds.n))
    loc = ds.locs[src]
    probe = DeltaLog(index, ds, snap)
    pid = probe.insert(loc[None, :], ds.kw_ids[src][None])
    leaf = _insert_leaf(probe, pid[0])
    terms = np.asarray(snap.leaf_terms)[leaf]
    terms = terms[terms >= 0]
    assert terms.size >= 2, "routing leaf needs a usable dictionary"
    kw = terms[:2].astype(np.int64)
    new = log.insert(loc[None, :], kw[None, :])
    assert log.compact_ok and log.buffer.ins_cbm is not None
    assert _insert_leaf(log, new[0]) == leaf, "probe and real insert diverged"
    bits = np.zeros(snap.n_words, np.uint32)
    np.bitwise_or.at(bits, kw >> 5, np.uint32(1) << (kw & 31).astype(np.uint32))
    wl = _pinned_workload(ds, loc, bits)
    out = _delta_parity(ds, snap, max_leaves, log, wl)
    assert int(new[0]) in set(np.asarray(out["ids"][0]).tolist()), (
        "pinned query must see the compact-verified insert")


def test_delta_insert_out_of_dict_falls_back():
    """A buffered insert carrying a term NEW to its leaf flips the sticky
    compact_ok fallback (insert slabs dropped, delta slots verified on the
    full-width plane) -- and serving stays bit-identical."""
    ds, snap, max_leaves = _quick_snap()
    index, _ = _build_index(ds, g=5, levels=2)
    log = DeltaLog(index, ds, snap)
    rng = np.random.default_rng(1)
    src = int(rng.integers(ds.n))
    loc = ds.locs[src]
    probe = DeltaLog(index, ds, snap)
    pid = probe.insert(loc[None, :], ds.kw_ids[src][None])
    leaf = _insert_leaf(probe, pid[0])
    terms = np.asarray(snap.leaf_terms)[leaf]
    fresh = np.setdiff1d(np.arange(ds.vocab_size), terms[terms >= 0])
    assert fresh.size, "dataset vocab must exceed one leaf's dictionary"
    kw = np.array([int(fresh[0])], np.int64)
    new = log.insert(loc[None, :], kw[None, :])
    assert not log.compact_ok and log.buffer.ins_cbm is None
    bits = np.zeros(snap.n_words, np.uint32)
    np.bitwise_or.at(bits, kw >> 5, np.uint32(1) << (kw & 31).astype(np.uint32))
    wl = _pinned_workload(ds, loc, bits, seed=43)
    out = _delta_parity(ds, snap, max_leaves, log, wl)
    assert int(new[0]) in set(np.asarray(out["ids"][0]).tolist()), (
        "pinned query must see the full-width-verified insert")
