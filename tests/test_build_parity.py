"""Construction-path parity: batched (device-resident) vs sequential loops.

The construction refactor (frontier-parallel split learning + scan-compiled
RL packing, DESIGN.md §5) is only acceptable if it is provably equivalent:

* the lax.scan packing rollout must reproduce the Python-loop episode under
  matched RNG streams -- same actions, rewards, replay contents, and final
  DQN parameters;
* batched split learning must accept/reject the same splits as the
  sequential heap loop on a deterministic fixture -- with non-binding AND
  binding cluster budgets -- yielding the identical bottom partition;
* the batched pipeline must issue >= 5x fewer device dispatches than the
  sequential one (the counters bench_construction.py reports);
* `build_wisk` must be deterministic under a fixed seed.

Everything here is sized tiny so the suite stays in the CI fast lane -- the
end-to-end build checks double as the batched-construction smoke test.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.build import BuildConfig, build_wisk
from repro.core.cdf import build_cdf_bank
from repro.core.cost import exact_query_results
from repro.core.dqn import DQNConfig, replay_init, train_state_init
from repro.core.itemsets import expand_queries
from repro.core.packing import (
    PackingConfig,
    _Env,
    _rollout_episode,
    _run_episode,
    pack_one_level,
)
from repro.core.partition import PartitionConfig, generate_bottom_clusters
from repro.core.query import execute_serial
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload


def _tiny_build_config(**over) -> BuildConfig:
    cfg = BuildConfig(
        # min_objects terminates the recursion well before max_clusters, so
        # the budget is non-binding and both modes accept identical splits
        partition=PartitionConfig(
            max_clusters=64, n_steps=20, n_restarts=2, min_objects=64,
            query_pad=16, max_split_batch=8,
        ),
        packing=PackingConfig(epochs=2, max_label_queries=8, dqn=DQNConfig()),
        cdf_train_steps=40,
        use_itemsets=False,
    )
    for k, v in over.items():
        setattr(cfg, k, v)
    return cfg


@pytest.fixture(scope="module")
def tiny_builds():
    """One tiny dataset/workload built batched (twice) and sequential (once)."""
    ds = make_dataset("fs", n=600, seed=21)
    wl = make_workload(ds, m=16, dist="MIX", seed=22)
    arts = {
        "batched": build_wisk(ds, wl, _tiny_build_config(construction="batched")),
        "batched2": build_wisk(ds, wl, _tiny_build_config(construction="batched")),
        "sequential": build_wisk(ds, wl, _tiny_build_config(construction="sequential")),
    }
    return ds, wl, arts


# ------------------------------------------------------------ packing parity
def _episode_fixture(seed=0, N=6, m=5):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, (N, m)).astype(bool)
    cfg = PackingConfig(epochs=4, dqn=DQNConfig(batch_size=8, capacity=64))
    state_dim = (m + 1) * N + m
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    ts = train_state_init(k0, state_dim, N, cfg.dqn)
    buf = replay_init(cfg.dqn.capacity, state_dim, N)
    return labels, cfg, ts, buf, key


@pytest.mark.parametrize("eps,train", [(0.7, True), (0.0, True), (0.0, False)])
def test_scan_rollout_matches_python_episode(eps, train):
    """The scan-compiled rollout reproduces the host-loop episode: same
    actions, rewards, replay contents, and final params under one RNG key."""
    labels, cfg, ts, buf, key = _episode_fixture()
    key, k = jax.random.split(key)
    env = _Env(labels, cfg.action_mask)
    a_s, tot_s, buf_s, ts_s, loss_s, _ = _run_episode(env, ts, buf, k, eps, cfg, train=train)
    a_b, r_b, buf_b, ts_b, loss_b, trained_b = _rollout_episode(
        jnp.asarray(labels), ts, buf, k, eps, cfg.dqn, train, cfg.action_mask
    )
    np.testing.assert_array_equal(a_s, np.asarray(a_b))
    np.testing.assert_allclose(tot_s, float(jnp.sum(r_b)), atol=1e-6)
    if train:
        for name in ("s", "a", "r", "s2", "mask2", "done", "ptr", "size"):
            np.testing.assert_allclose(
                np.asarray(getattr(buf_s, name)), np.asarray(getattr(buf_b, name)),
                atol=1e-6, err_msg=f"replay field {name}",
            )
        for ls, lb in zip(
            jax.tree.leaves(ts_s.params), jax.tree.leaves(ts_b.params)
        ):
            np.testing.assert_allclose(np.asarray(ls), np.asarray(lb), atol=1e-5)
        np.testing.assert_allclose(
            loss_s, np.asarray(loss_b)[np.asarray(trained_b)], atol=1e-5
        )


def test_multi_episode_training_parity():
    """Across several episodes (replay warm, train steps firing) the two
    rollout paths keep producing the same actions and the same parameters."""
    labels, cfg, ts, buf, key = _episode_fixture(seed=3, N=8, m=6)
    env = _Env(labels, cfg.action_mask)
    ts_b, buf_b = ts, buf
    eps = 1.0
    trained_any = False
    for ep in range(6):
        key, k = jax.random.split(key)
        a_s, _, buf, ts, loss_s, _ = _run_episode(env, ts, buf, k, eps, cfg, train=True)
        a_b, _, buf_b, ts_b, _, trained = _rollout_episode(
            jnp.asarray(labels), ts_b, buf_b, k, eps, cfg.dqn, True, cfg.action_mask
        )
        np.testing.assert_array_equal(a_s, np.asarray(a_b), err_msg=f"episode {ep}")
        trained_any = trained_any or bool(np.asarray(trained).any())
        eps = max(cfg.dqn.eps_end, eps * 0.7)
    assert trained_any, "fixture must actually exercise dqn_train_step"
    for ls, lb in zip(jax.tree.leaves(ts.params), jax.tree.leaves(ts_b.params)):
        np.testing.assert_allclose(np.asarray(ls), np.asarray(lb), atol=1e-5)


def test_pack_one_level_modes_agree():
    rng = np.random.default_rng(7)
    labels = rng.integers(0, 2, (10, 8)).astype(bool)
    cfg = PackingConfig(epochs=6, dqn=DQNConfig(batch_size=16, capacity=128))
    seq = pack_one_level(labels, cfg, seed=1, mode="sequential")
    bat = pack_one_level(labels, cfg, seed=1, mode="batched")
    np.testing.assert_array_equal(seq.assign, bat.assign)
    assert seq.n_upper == bat.n_upper
    np.testing.assert_allclose(seq.reward_curve, bat.reward_curve, atol=1e-5)
    # the dispatch collapse is the point of the refactor
    assert bat.n_dispatches * 5 <= seq.n_dispatches
    assert seq.n_env_steps == bat.n_env_steps


def test_parallel_episode_exploration_knob():
    """parallel_episodes > 1 is a schedule change, not a correctness change:
    the packing still returns a valid compacted assignment."""
    rng = np.random.default_rng(9)
    labels = rng.integers(0, 2, (8, 6)).astype(bool)
    cfg = PackingConfig(
        epochs=3, parallel_episodes=4, dqn=DQNConfig(batch_size=16, capacity=128)
    )
    res = pack_one_level(labels, cfg, seed=2, mode="batched")
    assert res.assign.shape == (8,)
    assert res.assign.min() == 0 and res.assign.max() == res.n_upper - 1
    assert np.unique(res.assign).size == res.n_upper
    assert res.n_env_steps == (3 * 4 + 1) * 8


# ---------------------------------------------------------- partition parity
def _partition_fixture():
    ds = make_dataset("fs", n=500, seed=31)
    wl = make_workload(ds, m=16, dist="MIX", seed=32)
    bank = build_cdf_bank(ds, n_steps=50)
    qe, qs = expand_queries(wl, [], ds.vocab_size, use_itemsets=False)
    return ds, wl, bank, qe, qs


def _partition_sets(res):
    a = res.clusters.assign
    return sorted(tuple(np.nonzero(a == c)[0]) for c in range(res.clusters.k))


def _decisions(res):
    return [
        (h["nq"], h["no"], h["dim"], round(h["val"], 5), h["gain"] > h["loss"])
        for h in res.history
    ]


def test_batched_split_decisions_match_sequential():
    """Frontier-parallel rounds accept and reject exactly the splits the
    sequential heap loop does -- in the same walk order -- and produce the
    identical bottom partition (cluster numbering aside)."""
    ds, wl, bank, qe, qs = _partition_fixture()
    cfg = PartitionConfig(
        max_clusters=64, n_steps=20, n_restarts=2, min_objects=32,
        query_pad=16, max_split_batch=8,
    )
    seq = generate_bottom_clusters(ds, wl, bank, qe, qs, cfg, mode="sequential")
    bat = generate_bottom_clusters(ds, wl, bank, qe, qs, cfg, mode="batched")
    assert seq.n_splits == bat.n_splits
    # budget non-binding here: no speculative learning, identical work
    assert seq.n_sgd_calls == bat.n_sgd_calls
    assert seq.clusters.k == bat.clusters.k
    assert _partition_sets(seq) == _partition_sets(bat)
    # the heap-walk replay preserves decision *order*, not just the set
    assert _decisions(seq) == _decisions(bat)
    # rounds scale with depth, not node count
    assert bat.n_rounds < seq.n_sgd_calls
    assert bat.n_dispatches < seq.n_dispatches

    # binding budget: the pop-time max_clusters check is replayed exactly,
    # so the (truncated) cluster sets still agree
    cfg_b = PartitionConfig(
        max_clusters=5, n_steps=20, n_restarts=2, min_objects=32,
        query_pad=16, max_split_batch=8,
    )
    seq_b = generate_bottom_clusters(ds, wl, bank, qe, qs, cfg_b, mode="sequential")
    bat_b = generate_bottom_clusters(ds, wl, bank, qe, qs, cfg_b, mode="batched")
    assert seq_b.clusters.k == bat_b.clusters.k <= 5
    assert _partition_sets(seq_b) == _partition_sets(bat_b)
    assert _decisions(seq_b) == _decisions(bat_b)


# ----------------------------------------------- end-to-end smoke + counters
def test_batched_construction_smoke(tiny_builds):
    """Tiny-size batched build exercised on every PR (CI fast lane): the
    pipeline must produce a real partition and exact query results."""
    ds, wl, arts = tiny_builds
    art = arts["batched"]
    assert art.partition.mode == "batched"
    assert art.partition.clusters.k > 1
    st = execute_serial(art.index, ds, wl)
    gt = exact_query_results(ds, wl)
    np.testing.assert_array_equal(np.array([len(r) for r in st.results]), gt)
    assert art.counters["partition_rounds"] >= 1
    assert art.counters["construction_dispatches"] >= 1


def test_construction_dispatch_reduction(tiny_builds):
    """Acceptance gate: batched mode issues >= 5x fewer device dispatches
    than sequential mode for the same build."""
    _, _, arts = tiny_builds
    seq = arts["sequential"].counters
    bat = arts["batched"].counters
    assert seq["partition_problems"] == bat["partition_problems"]
    assert bat["construction_dispatches"] * 5 <= seq["construction_dispatches"], (
        f"batched={bat} sequential={seq}"
    )


def test_modes_agree_end_to_end(tiny_builds):
    """Both construction modes learn the same bottom partition end-to-end
    and return exact query results."""
    ds, wl, arts = tiny_builds

    def partition_sets(art):
        a = art.partition.clusters.assign
        return sorted(tuple(np.nonzero(a == c)[0]) for c in range(art.partition.clusters.k))

    assert partition_sets(arts["batched"]) == partition_sets(arts["sequential"])
    st = execute_serial(arts["sequential"].index, ds, wl)
    gt = exact_query_results(ds, wl)
    np.testing.assert_array_equal(np.array([len(r) for r in st.results]), gt)


def test_build_determinism(tiny_builds):
    """Same seed twice -> identical cluster assignments and hierarchy parents
    (guards the RNG threading through the scan rollout)."""
    _, _, arts = tiny_builds
    a, b = arts["batched"], arts["batched2"]
    np.testing.assert_array_equal(a.partition.clusters.assign, b.partition.clusters.assign)
    assert (a.hierarchy is None) == (b.hierarchy is None)
    if a.hierarchy is not None:
        assert len(a.hierarchy.parents) == len(b.hierarchy.parents)
        for pa, pb in zip(a.hierarchy.parents, b.hierarchy.parents):
            np.testing.assert_array_equal(pa, pb)
    assert a.index.height == b.index.height
    for la, lb in zip(a.index.levels, b.index.levels):
        np.testing.assert_allclose(la.mbrs, lb.mbrs)
