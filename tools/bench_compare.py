"""Diff fresh BENCH_*.json scoreboard runs against committed baselines.

CI runs ``benchmarks.run --json --quick --out-dir bench_out`` and then::

    python tools/bench_compare.py --baseline-dir . --current-dir bench_out

Per record (matched by ``name`` within each module file) the verdict is:

* ``regression``  -- wall clock grew beyond ``--threshold`` (default 1.6x,
  CI boxes are noisy) AND both sides exceed the ``--min-us`` floor (tiny
  timings are pure jitter), OR a deterministic derived counter changed
  (those are exact: any drift is a semantic change, not noise);
* ``improvement`` -- wall clock shrank beyond the same threshold (reported,
  never fatal; commit a refreshed baseline to bank it);
* ``ok``          -- within the noise band;
* ``missing-baseline`` / ``missing-current`` -- the record (or whole module
  file) exists on only one side. New records are fine (the PR adding them
  also commits the refreshed baseline); vanished records are a regression.

Config fingerprints must match -- comparing a quick run against a full run
(or different backend/device count) would flag phantom regressions, so the
diff refuses instead. Exit status: 1 when any regression (or vanished
record, or fingerprint mismatch) was found, else 0.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

MODULE_FILES = (
    "BENCH_serving.json",
    "BENCH_knn.json",
    "BENCH_construction.json",
    "BENCH_dynamic.json",
    "BENCH_roofline.json",
)

# derived keys that are deterministic given (dataset seed, config): traversal
# and result counters -- exact equality required. Wall-clock-ish derived keys
# (qps, scale, speedup, build_s, phase times) are NOT listed: they are noise.
# "bytes"/"cutoff"/"wp" are the roofline descent model's exact byte counters
# (analytic ints, not measurements) -- any drift is a model/layout change.
# "wl*"/"overflow_leaves" are the leaf-local vocabulary distribution
# (bench_roofline leaf-vocab row): exact given the dataset seed.
# "objects"/"subs"/"matched"/"emitted"/"slots"/"swaps"/"exact"/
# "oracle_matched"/"second_drain" are the continuous-filter stream lane's
# notification counters (bench_dynamic stream rows): the device match
# stream is oracle-exact by contract, so any drift is a real §8 change.
DETERMINISTIC_KEYS = (
    "scanned", "checked", "verified", "overflow", "cost", "mismatches",
    "nodes", "sequential", "batched", "devices", "bytes", "cutoff", "wp",
    "per_device_bytes", "replica_bytes", "shards",
    "wl", "wl_max", "wl_p50", "wl_p95", "overflow_leaves",
    "objects", "subs", "matched", "emitted", "slots", "swaps",
    "exact", "oracle_matched", "second_drain",
)


@dataclasses.dataclass
class Verdict:
    module: str
    name: str
    status: str  # regression | improvement | ok | missing-baseline | missing-current
    detail: str = ""

    def __str__(self) -> str:
        return f"[{self.status:>16}] {self.module}:{self.name} {self.detail}".rstrip()


def load_records(path: Path) -> Optional[Dict]:
    if not path.exists():
        return None
    with open(path) as f:
        return json.load(f)


def compare_records(
    module: str,
    baseline: Dict,
    current: Dict,
    threshold: float = 1.6,
    min_us: float = 100.0,
) -> List[Verdict]:
    """Verdicts for one module's baseline/current payload pair."""
    out: List[Verdict] = []
    if baseline.get("config_fingerprint") != current.get("config_fingerprint"):
        out.append(
            Verdict(module, "<config>", "regression",
                    f"config fingerprint mismatch "
                    f"({baseline.get('config_fingerprint')} vs "
                    f"{current.get('config_fingerprint')}): runs not comparable")
        )
        return out
    base = {r["name"]: r for r in baseline.get("records", [])}
    cur = {r["name"]: r for r in current.get("records", [])}
    for name in base:
        if name not in cur:
            out.append(Verdict(module, name, "missing-current",
                               "baseline record vanished from the fresh run"))
    for name, c in cur.items():
        b = base.get(name)
        if b is None:
            out.append(Verdict(module, name, "missing-baseline",
                               "new record (refresh the committed baseline)"))
            continue
        # deterministic counters first: exact, so drift beats any timing noise
        drifted = [
            k for k in DETERMINISTIC_KEYS
            if k in b.get("derived", {}) and k in c.get("derived", {})
            and b["derived"][k] != c["derived"][k]
        ]
        if drifted:
            detail = "; ".join(
                f"{k}: {b['derived'][k]} -> {c['derived'][k]}" for k in drifted
            )
            out.append(Verdict(module, name, "regression",
                               f"deterministic counter drift: {detail}"))
            continue
        bu, cu = float(b["us_per_call"]), float(c["us_per_call"])
        if bu >= min_us and cu >= min_us:
            if cu > bu * threshold:
                out.append(Verdict(module, name, "regression",
                                   f"{bu:.0f}us -> {cu:.0f}us ({cu / bu:.2f}x)"))
                continue
            if cu * threshold < bu:
                out.append(Verdict(module, name, "improvement",
                                   f"{bu:.0f}us -> {cu:.0f}us ({cu / bu:.2f}x)"))
                continue
        out.append(Verdict(module, name, "ok"))
    return out


def compare_dirs(
    baseline_dir: Path,
    current_dir: Path,
    threshold: float = 1.6,
    min_us: float = 100.0,
    modules=MODULE_FILES,
) -> List[Verdict]:
    out: List[Verdict] = []
    for fname in modules:
        module = fname[len("BENCH_"):-len(".json")]
        b = load_records(baseline_dir / fname)
        c = load_records(current_dir / fname)
        if b is None and c is None:
            continue
        if b is None:
            out.append(Verdict(module, "<file>", "missing-baseline",
                               f"no committed {fname} (commit one to start the "
                               f"scoreboard for this module)"))
            continue
        if c is None:
            out.append(Verdict(module, "<file>", "missing-current",
                               f"fresh run produced no {fname}"))
            continue
        out.extend(compare_records(module, b, c, threshold, min_us))
    return out


def is_fatal(v: Verdict) -> bool:
    return v.status in ("regression", "missing-current")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", type=Path, default=Path("."),
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--current-dir", type=Path, required=True,
                    help="directory of freshly generated BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=1.6,
                    help="wall-clock growth ratio that counts as a regression")
    ap.add_argument("--min-us", type=float, default=100.0,
                    help="ignore wall-clock drift below this many us/call")
    args = ap.parse_args(argv)
    verdicts = compare_dirs(args.baseline_dir, args.current_dir,
                            args.threshold, args.min_us)
    fatal = 0
    for v in verdicts:
        if v.status != "ok":
            print(v)
        fatal += is_fatal(v)
    n_ok = sum(v.status == "ok" for v in verdicts)
    print(f"# {len(verdicts)} records compared: {n_ok} ok, {fatal} fatal")
    return 1 if fatal else 0


if __name__ == "__main__":
    sys.exit(main())
