"""Link/reference checker for the repo's markdown documentation (CI docs lane).

Checks, per file:

* **Internal anchors** -- ``[text](#anchor)`` must match a heading slug in
  the same file (GitHub slug rules: lowercase, spaces -> dashes,
  punctuation dropped).
* **Relative links** -- ``[text](path)`` (non-http, non-anchor) must exist
  on disk relative to the repo root.
* **Path-like code spans** -- `` `src/.../x.py` ``-style inline code that
  looks like a repo path must exist (suffix forms like
  ``core/query.py:knn_query`` and ``serve/engine.py`` are resolved against
  the known source roots).
* **Commands** -- fenced-code or indented lines invoking ``python`` are
  smoke-parsed: ``python -m pkg.mod`` must resolve to a file under the
  documented roots and ``ast.parse`` cleanly; ``python path/to/file.py``
  likewise. Env-var prefixes (``PYTHONPATH=src ...``, ``XLA_FLAGS=...``)
  and trailing arguments are understood. Nothing is *executed*.

With ``--py-docstrings`` it additionally walks every ``.py`` file under
src/, tests/, benchmarks/, tools/ and examples/ and checks each docstring's
markdown-doc references: a mentioned doc (``DESIGN.md``, ``EXPERIMENTS.md``,
...) must exist at the repo root, and the ``EXPERIMENTS.md section Perf`` /
``§Perf`` forms must match a heading of that document -- code pointing
readers at documentation that does not exist is how stale docs hide.

Exit code 0 when every reference resolves, 1 otherwise (each failure on
its own line).

    python tools/check_docs.py --py-docstrings README.md DESIGN.md
"""
from __future__ import annotations

import ast
import functools
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# module roots for `python -m` resolution (PYTHONPATH=src plus the repo
# root, matching every documented command)
MODULE_ROOTS = [REPO / "src", REPO]
# directories whose file mentions in code spans must exist
PATH_PREFIXES = ("src/", "tests/", "benchmarks/", "examples/", "tools/", ".github/")
# bare-suffix mentions like `core/query.py` resolve against these
SUFFIX_ROOTS = [REPO / "src" / "repro", REPO]


def _slug(heading: str) -> str:
    s = heading.strip().lower()
    s = re.sub(r"[`*]", "", s)
    s = re.sub(r"[^\w\s§./-]", "", s, flags=re.UNICODE)
    s = re.sub(r"[\s]+", "-", s.strip())
    return re.sub(r"[./]", "", s)


def _headings(text: str):
    return [m.group(2) for m in re.finditer(r"^(#{1,6})\s+(.*)$", text, re.M)]


def _module_file(mod: str):
    rel = Path(*mod.split("."))
    for root in MODULE_ROOTS:
        for cand in (root / rel.with_suffix(".py"), root / rel / "__init__.py"):
            if cand.exists():
                return cand
        # namespace packages: a dir with .py members but no __init__.py
        if (root / rel).is_dir():
            return root / rel
    return None


def _check_python_cmd(cmd: str, errors: list, where: str) -> None:
    toks = cmd.split()
    # strip env assignments and line-continuations
    while toks and ("=" in toks[0] and not toks[0].startswith("-")):
        toks = toks[1:]
    if not toks or toks[0] not in ("python", "python3"):
        return
    toks = toks[1:]
    if not toks:
        return
    if toks[0] == "-m":
        if len(toks) < 2:
            errors.append(f"{where}: dangling `python -m`")
            return
        mod = toks[1]
        top = mod.split(".")[0]
        # only repo-local packages are checkable (pytest etc. are external)
        if not any((root / top).exists() or (root / f"{top}.py").exists() for root in MODULE_ROOTS):
            return
        f = _module_file(mod)
        if f is None:
            errors.append(f"{where}: module `{mod}` not found under {', '.join(str(r) for r in MODULE_ROOTS)}")
        elif f.suffix == ".py":
            _parse(f, errors, where)
    elif toks[0] == "-c":
        return  # inline snippets are not smoke-parsed
    elif toks[0].endswith(".py"):
        f = REPO / toks[0]
        if not f.exists():
            errors.append(f"{where}: script `{toks[0]}` does not exist")
        else:
            _parse(f, errors, where)


def _parse(f: Path, errors: list, where: str) -> None:
    try:
        ast.parse(f.read_text(), filename=str(f))
    except SyntaxError as e:
        errors.append(f"{where}: `{f}` does not parse: {e}")


def _iter_command_lines(text: str):
    """Lines inside fenced code blocks plus 4-space-indented lines."""
    fence = False
    buf = ""
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if line.startswith("```"):
            fence = not fence
            continue
        if not (fence or raw.startswith("    ")):
            continue
        if buf:  # continuation from a trailing backslash
            line = buf + line
            buf = ""
        if line.endswith("\\"):
            buf = line[:-1]
            continue
        if line:
            yield ln, line


def check_file(path: Path) -> list:
    errors: list = []
    text = path.read_text()
    name = path.name
    slugs = {_slug(h) for h in _headings(text)}

    # markdown links
    for m in re.finditer(r"\[[^\]]+\]\(([^)\s]+)\)", text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:].lower() not in slugs:
                errors.append(f"{name}: anchor `{target}` matches no heading")
        else:
            rel = target.split("#")[0]
            if rel and not (REPO / rel).exists() and not (path.parent / rel).exists():
                errors.append(f"{name}: linked path `{rel}` does not exist")

    # path-like code spans
    for m in re.finditer(r"`([^`\n]+)`", text):
        span = m.group(1).strip()
        base = span.split(":")[0].split("::")[0]  # drop :symbol / ::test suffixes
        if not re.fullmatch(r"[\w./-]+", base) or "/" not in base:
            continue
        if base.startswith(PATH_PREFIXES):
            if not (REPO / base).exists():
                errors.append(f"{name}: referenced path `{base}` does not exist")
        elif base.endswith(".py"):
            if not any((root / base).exists() for root in SUFFIX_ROOTS) and not (
                REPO / base
            ).exists():
                errors.append(f"{name}: referenced file `{base}` not found in source roots")

    # commands
    for ln, line in _iter_command_lines(text):
        if re.search(r"\bpython3?\b", line):
            _check_python_cmd(line, errors, f"{name}:{ln}")
    return errors


# ------------------------------------------- Python-docstring doc references
# roots whose .py docstrings are scanned with --py-docstrings
PY_ROOTS = ("src", "tests", "benchmarks", "tools", "examples")
# `SOMEDOC.md`, optionally followed by a `section Name` / `§Name` pointer
_MD_REF = re.compile(r"\b([A-Za-z][\w-]*\.md)(?:[`'\")\],:;]*\s+(?:section\s+|§\s*)([A-Za-z][\w.-]*))?")


def _docstrings(tree: ast.AST):
    """(lineno, text) of every module/class/function docstring in the tree."""
    nodes = [n for n in ast.walk(tree)
             if isinstance(n, (ast.Module, ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef))]
    for n in nodes:
        doc = ast.get_docstring(n, clean=False)
        if doc:
            body = n.body[0]
            yield body.lineno, doc


@functools.lru_cache(maxsize=32)
def _doc_headings(md: str):
    """Heading texts of a root-level markdown doc (None: no such doc)."""
    path = REPO / md
    if not path.exists():
        return None
    return tuple(_headings(path.read_text()))


def check_docstring_refs(py: Path, errors: list) -> None:
    """Every markdown-doc mention in ``py``'s docstrings must exist at the
    repo root; section pointers must match one of the doc's headings."""
    try:
        rel = py.relative_to(REPO)
    except ValueError:  # scanning a file outside the repo (tests)
        rel = py
    try:
        tree = ast.parse(py.read_text(), filename=str(py))
    except SyntaxError as e:
        errors.append(f"{rel}: does not parse: {e}")
        return
    for lineno, doc in _docstrings(tree):
        for m in _MD_REF.finditer(doc):
            md, section = m.group(1), m.group(2)
            if section:
                section = section.rstrip(".,;:-")
            headings = _doc_headings(md)
            if headings is None:
                errors.append(f"{rel}:{lineno}: docstring references `{md}` "
                              f"which does not exist at the repo root")
            elif section and not any(section.lower() in h.lower() for h in headings):
                errors.append(f"{rel}:{lineno}: docstring references `{md} "
                              f"section {section}` but {md} has no such heading")


def check_py_docstrings() -> list:
    errors: list = []
    for root in PY_ROOTS:
        base = REPO / root
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            check_docstring_refs(py, errors)
    return errors


def main(argv) -> int:
    scan_py = "--py-docstrings" in argv
    argv = [a for a in argv if a != "--py-docstrings"]
    files = [Path(a) for a in argv] or [REPO / "README.md", REPO / "DESIGN.md"]
    all_errors: list = []
    for f in files:
        if not f.exists():
            all_errors.append(f"{f}: file does not exist")
            continue
        all_errors.extend(check_file(f))
    if scan_py:
        all_errors.extend(check_py_docstrings())
    if all_errors:
        print(f"doc check FAILED ({len(all_errors)} problems):")
        for e in all_errors:
            print(" -", e)
        return 1
    print(f"doc check OK ({', '.join(str(f) for f in files)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
