"""End-to-end serving driver: batched SKR queries through the TPU-path
pipeline (Pallas filter/verify kernels, interpret-mode on CPU), validated
against the serial reference.

    PYTHONPATH=src python examples/serve_skr_batched.py
"""
import time

import numpy as np

from repro.core.build import BuildConfig, build_wisk
from repro.core.partition import PartitionConfig
from repro.core.query import execute_serial
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.serve.engine import IndexSnapshot, retrieve_workload


def main():
    ds = make_dataset("fs", n=4000, seed=0)
    train = make_workload(ds, m=64, dist="MIX", seed=1)
    art = build_wisk(ds, train, BuildConfig(partition=PartitionConfig(max_clusters=32, n_steps=50)))
    bw = IndexSnapshot.build(art.index, ds)

    test = make_workload(ds, m=64, dist="MIX", seed=3)
    out = retrieve_workload(bw, test, max_leaves=art.partition.clusters.k)
    st = execute_serial(art.index, ds, test)
    agree = all(
        np.array_equal(np.sort(row[row >= 0]), np.sort(ref))
        for row, ref in zip(out["ids"], st.results)
    )
    t0 = time.perf_counter()
    for _ in range(3):
        retrieve_workload(bw, test, max_leaves=art.partition.clusters.k)
    dt = (time.perf_counter() - t0) / 3
    widths = ",".join(str(w) for w in out["frontier_widths"])
    print(f"batched pipeline: {test.m} queries in {dt*1e3:.1f} ms "
          f"({dt/test.m*1e6:.0f} us/query), exact={agree}, "
          f"verified/query={out['verified'].mean():.1f}, "
          f"frontier widths=[{widths}], "
          f"nodes checked/scanned per query="
          f"{out['nodes_checked'].mean():.1f}/{out['nodes_scanned'].mean():.1f}")


if __name__ == "__main__":
    main()
