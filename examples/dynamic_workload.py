"""Paper section 7.5 scenario: query distribution shifts, WISK retrains and
recovers (Fig. 14 at laptop scale).

    PYTHONPATH=src python examples/dynamic_workload.py
"""
from repro.core.build import BuildConfig, build_wisk
from repro.core.partition import PartitionConfig
from repro.core.query import execute_serial
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload


def main():
    ds = make_dataset("fs", n=4000, seed=0)
    cfgs = BuildConfig(partition=PartitionConfig(max_clusters=32, n_steps=50))
    uni = make_workload(ds, m=64, dist="UNI", seed=1)
    art = build_wisk(ds, uni, cfgs)
    print("trained on UNI workload")
    for dist in ("UNI", "LAP"):
        test = make_workload(ds, m=32, dist=dist, seed=5)
        st = execute_serial(art.index, ds, test)
        print(f"  test {dist}: cost {st.total_cost:.0f}")
    lap = make_workload(ds, m=64, dist="LAP", seed=2)
    art2 = build_wisk(ds, lap, cfgs)
    st = execute_serial(art2.index, ds, make_workload(ds, m=32, dist="LAP", seed=5))
    print(f"after retraining on LAP: cost {st.total_cost:.0f} (recovered)")


if __name__ == "__main__":
    main()
