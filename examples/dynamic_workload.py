"""Paper §7.5 scenario under the incremental-maintenance subsystem
(DESIGN.md §7): the query distribution shifts, the drift monitor notices,
and a warm-start rebuild is atomically swapped in -- while object updates
are absorbed by delta buffers without ever rebuilding.

Walkthrough:

1. Build a WISK index on a LAP (spatially concentrated) training workload
   and stand up a ``LiveIndex`` serving front door.
2. Serve same-distribution traffic: the drift monitor learns its baseline
   during warmup and stays armed.
3. Insert and delete objects mid-serving: they are buffered in the
   ``DeltaBuffer`` and merged into every query on the fly (results include
   fresh inserts immediately; deleted objects vanish immediately).
4. Shift traffic to UNI: the observed Eq.1 cost regresses, the monitor
   trips, and ``maybe_rebuild()`` warm-start rebuilds (re-learning splits
   only for regressed leaves, grafting the DQN-packed hierarchy) and swaps
   the fresh snapshot in atomically -- the generation counter advances,
   buffered updates are baked in, and cost recovers.

    PYTHONPATH=src python examples/dynamic_workload.py
"""
import numpy as np

from repro.core.build import BuildConfig
from repro.core.drift import DriftConfig
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.launch.wisk_serve import LiveIndex


def main():
    ds = make_dataset("fs", n=1500, seed=0)
    cfg = BuildConfig(
        partition=PartitionConfig(max_clusters=24, n_steps=25, n_restarts=2),
        packing=PackingConfig(epochs=3, max_label_queries=16),
        cdf_train_steps=40,
        cdf_force_class="gauss",
        use_itemsets=False,
    )
    train = make_workload(ds, m=32, dist="LAP", seed=1)
    print(f"building WISK on {ds.n} objects, LAP training workload ...")
    live = LiveIndex(ds, train, cfg, DriftConfig(threshold=1.3, min_queries=48))
    print(f"  {live.generation.artifacts.partition.clusters.k} bottom clusters, "
          f"{live.generation.artifacts.index.height} levels")

    # 2) same-distribution traffic: baseline learned, monitor stays armed
    for seed in (21, 22, 23):
        wl = make_workload(ds, m=24, dist="LAP", seed=seed)
        out = live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)
    print(f"steady state: monitor={live.monitor.state}, "
          f"baseline cost/query={live.monitor.baseline:.1f}")

    # 3) object updates absorbed by the delta buffers, no rebuild
    rng = np.random.default_rng(5)
    src = rng.choice(ds.n, 30)
    locs = np.clip(ds.locs[src] + rng.normal(0, 0.02, (30, 2)).astype(np.float32), 0, 1)
    new_ids = live.insert(locs, ds.kw_ids[src])
    n_del = live.delete(rng.choice(ds.n, 15, replace=False))
    wl = make_workload(ds, m=24, dist="LAP", seed=24)
    out = live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)
    served = {int(i) for row in out["ids"] for i in row[row >= 0]}
    print(f"buffered {len(new_ids)} inserts + {n_del} deletes; "
          f"delta holds {live.generation.delta_log.n_updates()} updates; "
          f"fresh inserts already served: {bool(served & set(map(int, new_ids)))}")

    # 4) distribution shift -> drift trigger -> warm-start rebuild + swap
    for seed in (31, 32, 33, 34, 35, 36):
        wl = make_workload(ds, m=24, dist="UNI", seed=seed)
        live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)
    print(f"after shift: monitor={live.monitor.state}, "
          f"cost ratio={live.monitor.ratio:.2f}x")
    old_seq = live.generation.seq
    if live.maybe_rebuild():
        art = live.generation.artifacts
        print(f"warm-start rebuild swapped in: generation {old_seq} -> "
              f"{live.generation.seq}, refined "
              f"{art.counters['refined_leaves']} leaves, kept "
              f"{art.counters['kept_clusters']} clusters, "
              f"build {art.timings['total']:.2f}s, "
              f"dataset now {live.generation.dataset.n} objects")
    # post-swap traffic re-learns the baseline on the adapted index
    for seed in (41, 42):
        wl = make_workload(ds, m=24, dist="UNI", seed=seed)
        live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)
    print(f"recovered: monitor={live.monitor.state}, "
          f"baseline cost/query={live.monitor.baseline:.1f}")


if __name__ == "__main__":
    main()
