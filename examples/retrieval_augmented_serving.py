"""WISK + LM: geo-textual retrieval feeding a small LM decode loop -- the
framework's two halves working together (DESIGN.md section 4).

    PYTHONPATH=src python examples/retrieval_augmented_serving.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.build import BuildConfig, build_wisk
from repro.core.partition import PartitionConfig
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.serve.engine import IndexSnapshot, retrieve_workload
from repro.train.decode import greedy_generate
from repro.train.step import build_steps


def main():
    # 1) retrieval: SKR queries over the geo-textual corpus
    ds = make_dataset("fs", n=3000, seed=0)
    train = make_workload(ds, m=48, dist="MIX", seed=1)
    art = build_wisk(ds, train, BuildConfig(partition=PartitionConfig(max_clusters=24, n_steps=40)))
    bw = IndexSnapshot.build(art.index, ds)
    queries = make_workload(ds, m=4, dist="MIX", seed=9)
    hits = retrieve_workload(bw, queries, max_leaves=art.partition.clusters.k)
    print("retrieved per query:", hits["counts"].tolist())

    # 2) generation: retrieved object keyword ids prompt a small LM
    cfg = get_config("tinyllama-1.1b").reduced()
    steps = build_steps(cfg)
    state = jax.jit(steps.init_state)(jax.random.PRNGKey(0))
    B, S = 4, 64
    cache_sds, _ = steps.cache_spec(B, S)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
    prompt = jnp.asarray(hits["ids"][:, :1] % cfg.vocab).astype(jnp.int32)
    toks, _ = greedy_generate(steps, state["params"], cache, prompt, n_new=8, start_pos=0)
    print("generated token ids:", toks.tolist())


if __name__ == "__main__":
    main()
