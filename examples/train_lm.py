"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps on CPU with checkpoints + straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py --arch tinyllama-1.1b --steps 300
(defaults to a ~100M reduced config so it runs in minutes on CPU)
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    # ~100M params: 8 layers x d=512 x vocab 32k
    cfg = dataclasses.replace(
        cfg, d_model=args.d_model, n_layers=args.layers, n_heads=8, n_kv_heads=4,
        head_dim=args.d_model // 8, d_ff=args.d_model * 3, vocab=32000,
    )
    tc = TrainConfig(n_steps=args.steps, batch=4, seq=256, ckpt_dir=args.ckpt,
                     ckpt_every=50, log_every=20)
    res = train(cfg, tc)
    print(f"done: {len(res.losses)} steps, loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}, "
          f"restored_from={res.restored_from}")


if __name__ == "__main__":
    main()
