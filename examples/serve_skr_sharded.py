"""Data-parallel SKR serving walkthrough (DESIGN.md §3.4).

The layered serving stack, end to end:

1. **Snapshot** -- ``IndexSnapshot.build`` freezes the learned index into an
   immutable pytree and ``.replicate(mesh)`` broadcasts it to every device
   with a single ``device_put`` (it happens inside ``serve_sharded`` too;
   shown here for the walkthrough).
2. **Plan** -- a ``PlanCache`` carries the monotone frontier widths; the
   sharded path converges them by grow-and-redescend, then serves sync-free.
3. **Executor** -- ``serve_sharded`` shard_maps the real frontier descent
   over the mesh's data axis: index replicated, query batch sharded,
   per-query ids + Eq.1 counters returned, identical to the single-device
   engine.

Force a multi-device CPU platform to see the query sharding without a TPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_skr_sharded.py
"""
import time

import numpy as np

import jax

from repro.core.build import BuildConfig, build_wisk
from repro.core.partition import PartitionConfig
from repro.core.query import execute_serial
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.launch.wisk_serve import default_serving_mesh, mesh_dp_size, serve_sharded
from repro.serve.engine import IndexSnapshot
from repro.serve.plan import PlanCache


def main():
    ds = make_dataset("fs", n=4000, seed=0)
    train = make_workload(ds, m=64, dist="MIX", seed=1)
    art = build_wisk(ds, train, BuildConfig(partition=PartitionConfig(max_clusters=32, n_steps=50)))

    # snapshot layer: immutable pytree, replicated over the serving mesh
    snap = IndexSnapshot.build(art.index, ds)
    mesh = default_serving_mesh()
    snap = snap.replicate(mesh)
    print(f"mesh: {mesh} ({mesh_dp_size(mesh)} query shards)")

    # plan layer: explicit width state, shared across batches
    cache = PlanCache()

    test = make_workload(ds, m=128, dist="MIX", seed=3)
    out = serve_sharded(
        snap, test.rects, test.kw_bitmap,
        max_leaves=art.partition.clusters.k, mesh=mesh, plan_cache=cache,
    )
    st = execute_serial(art.index, ds, test)
    agree = all(
        np.array_equal(np.sort(row[row >= 0]), np.sort(ref))
        for row, ref in zip(out["ids"], st.results)
    )

    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        serve_sharded(
            snap, test.rects, test.kw_bitmap,
            max_leaves=art.partition.clusters.k, mesh=mesh, plan_cache=cache,
        )
    dt = (time.perf_counter() - t0) / reps
    widths = ",".join(str(w) for w in out["frontier_widths"])
    print(
        f"sharded pipeline: {test.m} queries over {len(jax.devices())} device(s) "
        f"in {dt*1e3:.1f} ms ({test.m/dt:.0f} q/s), exact={agree}, "
        f"widths=[{widths}], learned={sorted(cache.widths.items())}"
    )


if __name__ == "__main__":
    main()
