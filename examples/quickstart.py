"""Quickstart: build a WISK index on synthetic geo-textual data and run
spatial keyword range queries -- the paper's core loop in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.build import BuildConfig, build_wisk
from repro.core.partition import PartitionConfig
from repro.core.packing import PackingConfig
from repro.core.query import execute_serial
from repro.core.cost import exact_workload_cost
from repro.core.types import ClusterSet
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload


def main():
    ds = make_dataset("fs", n=4000, seed=0)
    train = make_workload(ds, m=64, dist="MIX", seed=1)
    print(f"dataset: {ds.n} objects, vocab {ds.vocab_size}; training workload {train.m} queries")

    cfg = BuildConfig(
        partition=PartitionConfig(max_clusters=48, n_steps=60, n_restarts=3),
        packing=PackingConfig(epochs=6),
        cdf_train_steps=120,
    )
    art = build_wisk(ds, train, cfg)
    print(f"built WISK: {art.partition.clusters.k} bottom clusters, "
          f"{art.index.height} levels, {art.index.nbytes()/1e3:.0f} KB, "
          f"timings {dict((k, round(v,1)) for k, v in art.timings.items())}")

    test = make_workload(ds, m=32, dist="MIX", seed=2)
    st = execute_serial(art.index, ds, test)
    flat = ClusterSet.from_assignment(ds, np.zeros(ds.n, dtype=np.int32))
    c0 = exact_workload_cost(ds, flat, test).total
    print(f"query cost: no-index {c0:.0f} -> WISK {st.total_cost:.0f} "
          f"({c0/st.total_cost:.1f}x less work); results exact.")


if __name__ == "__main__":
    main()
