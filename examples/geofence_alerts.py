"""Continuous spatio-textual filters on the serving front door
(DESIGN.md §8): geofence alerts over a live object stream.

WISK answers request/response SKR queries; this walkthrough runs the
inverse, FAST-style problem on the same ``LiveIndex``: *standing*
subscriptions (rect + keyword filter) compiled into a device-resident
subscription block, matched against every insert batch in the same step
it enters the delta log.

Walkthrough:

1. Build a WISK index and stand up a ``LiveIndex``.
2. Register geofence subscriptions (``subscribe``): each is a rect plus a
   keyword set under the Boolean SKR contract -- an arriving object
   notifies a geofence when its point lies inside the rect AND it shares
   at least one keyword.
3. Stream object inserts: notifications are queued on device at insert
   time; ``drain_notifications()`` hands out (object_id, subscription_id)
   pairs exactly once.
4. Churn the filter set (``unsubscribe`` frees a slot for reuse), delete
   objects (queued notifications are never retracted), and force a
   warm-start rebuild mid-stream: the subscription state lives on the
   front door, so queued notifications and the exactly-once mark survive
   the generation swap untouched.

    PYTHONPATH=src python examples/geofence_alerts.py
"""
import numpy as np

from repro.core.build import BuildConfig
from repro.core.packing import PackingConfig
from repro.core.partition import PartitionConfig
from repro.data.synth import make_dataset
from repro.data.workloads import make_workload
from repro.launch.wisk_serve import LiveIndex


def main():
    ds = make_dataset("fs", n=1500, seed=0)
    cfg = BuildConfig(
        partition=PartitionConfig(max_clusters=24, n_steps=25, n_restarts=2),
        packing=PackingConfig(epochs=3, max_label_queries=16),
        cdf_train_steps=40,
        cdf_force_class="gauss",
        use_itemsets=False,
    )
    train = make_workload(ds, m=32, dist="LAP", seed=1)
    print(f"building WISK on {ds.n} objects ...")
    live = LiveIndex(ds, train, cfg)

    # 2) standing geofences: rects around dataset hot spots, keyword
    # filters drawn from the head of the vocabulary
    rng = np.random.default_rng(7)
    n_subs = 24
    for _ in range(n_subs):
        c = ds.locs[rng.integers(ds.n)]
        w, h = rng.uniform(0.05, 0.2, size=2)
        rect = [c[0] - w, c[1] - h, c[0] + w, c[1] + h]
        kw = rng.choice(8, size=rng.integers(1, 4), replace=False)
        live.subscribe(rect, kw)
    print(f"registered {n_subs} geofence subscriptions "
          f"({live.subscriptions.n_slots} block slots)")

    # 3) object stream: every insert batch is matched on device in-step
    for _ in range(4):
        src = rng.choice(ds.n, 25)
        locs = np.clip(
            ds.locs[src] + rng.normal(0, 0.02, (25, 2)).astype(np.float32), 0, 1
        )
        live.insert(locs, ds.kw_ids[src])
    alerts = live.drain_notifications()
    print(f"streamed 100 objects -> {alerts.shape[0]} alerts queued, e.g. "
          f"{[(int(o), int(s)) for o, s in alerts[:3]]} (object_id, subscription_id)")

    # 4) churn + rebuild mid-stream: exactly-once survives all of it
    for sid in range(4):
        live.unsubscribe(sid)  # freed slots are reused by later subscribes
    src = rng.choice(ds.n, 25)
    ids = live.insert(ds.locs[src], ds.kw_ids[src])
    live.delete(ids[:10])  # deletion never retracts a queued notification
    for seed in (21, 22):  # recent traffic steers the forced rebuild
        wl = make_workload(ds, m=24, dist="LAP", seed=seed)
        live.serve(wl.rects, wl.kw_bitmap, max_leaves=64)
    queued_before = live.subscriptions.n_pending
    assert live.maybe_rebuild(force=True)
    src = rng.choice(ds.n, 25)
    live.insert(ds.locs[src], ds.kw_ids[src])  # stream continues post-swap
    alerts = live.drain_notifications()
    assert live.drain_notifications().shape[0] == 0  # exactly once
    print(f"rebuild swapped mid-stream (generation {live.generation.seq}); "
          f"{queued_before} queued alerts survived the swap, "
          f"{alerts.shape[0]} drained after it, second drain empty")
    print(f"stream totals: matched={live.subscriptions.matched_total} "
          f"emitted={live.subscriptions.emitted_total}")


if __name__ == "__main__":
    main()
